"""End-to-end driver: train a ~100M-parameter dense model from scratch.

Full substrate: synthetic byte-level corpus -> sharded data pipeline ->
scanned/remat transformer -> AdamW with cosine schedule -> npz checkpoints.
On the CPU container use --steps 30 --d-model 256 for a smoke run; the
default config is a genuine ~100M model for a few hundred steps.

Run:  PYTHONPATH=src python examples/train_dense_100m.py --steps 300
"""
import argparse

from repro.configs.base import ModelConfig
from repro.train.loop import train


def build_cfg(d_model: int, layers: int) -> ModelConfig:
    return ModelConfig(
        name=f"dense-{d_model}x{layers}",
        family="dense",
        source="examples/train_dense_100m.py",
        num_layers=layers,
        d_model=d_model,
        num_heads=max(d_model // 64, 1),
        num_kv_heads=max(d_model // 128, 1),
        d_ff=d_model * 4,
        vocab_size=512,          # byte-level tokenizer + specials
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=512)
    ap.add_argument("--d-model", type=int, default=768)   # ~100M params
    ap.add_argument("--layers", type=int, default=12)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/harvest_dense_ckpt")
    args = ap.parse_args()

    cfg = build_cfg(args.d_model, args.layers)
    print(f"model: {cfg.name}  "
          f"(~{cfg.param_counts()['total'] / 1e6:.0f}M params)")

    params, opt, history = train(
        cfg, steps=args.steps, batch=args.batch, seq_len=args.seq_len,
        lr=args.lr, ckpt_dir=args.ckpt_dir, ckpt_every=100, log_every=10)

    first, last = history[0]["loss"], history[-1]["loss"]
    print(f"\nloss {first:.3f} -> {last:.3f} over {args.steps} steps "
          f"({(1 - last / first) * 100:.1f}% reduction)")
    print(f"checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
