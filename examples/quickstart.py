"""Quickstart: the Harvest public API in ~70 lines.

One :class:`HarvestRuntime` composes the allocator, the availability
monitor and the transfer engine; a :class:`HarvestStore` client places
tiered objects with a durability class.  The trace shrinks a peer's
budget, revocation fires, and the two durability classes diverge: BACKED
objects fall back to host, RECONSTRUCTIBLE objects become LOST.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import (ClusterTraceConfig, Durability, HarvestRuntime,
                        Residency)

GiB = 2**30


def main():
    # Four peer devices with 16 GiB of harvestable HBM each, pressured by
    # the Fig-2-calibrated cluster trace.
    runtime = HarvestRuntime(
        {d: 16 * GiB for d in range(4)},
        trace_config=ClusterTraceConfig(num_devices=4,
                                        capacity_bytes=16 * GiB, seed=42),
        reserve_bytes=1 * GiB)
    alloc = runtime.allocator

    # --- harvest_alloc: the paper's §3.2 API, still the floor -----------
    handles = []
    for i in range(6):
        h = alloc.harvest_alloc(3 * GiB, hints={"purpose": f"kv-shard-{i}"})
        if h is None:
            print(f"alloc {i}: no peer capacity (graceful failure)")
            continue
        print(f"alloc {i}: device={h.device} offset={h.offset >> 30}GiB "
              f"size={h.size >> 30}GiB")
        handles.append(h)
    for h in list(alloc.live_handles()):
        alloc.harvest_free(h)

    # --- HarvestStore: tiered objects with a durability class -----------
    # Any object class plugs into the same seam — here, LoRA adapters.
    store = runtime.create_store("lora", object_nbytes=2 * GiB)
    for i in range(4):
        store.register(("adapter", i), state=Residency.HOST,
                       durability=(Durability.BACKED if i % 2 == 0
                                   else Durability.RECONSTRUCTIBLE))
        store.touch_hotness(("adapter", i), float(i), alpha=0.0)

    migrated = sum(1 for key, _ in store.hottest(Residency.HOST)
                   if store.promote_to_peer(key))
    print(f"\npromoted {migrated} adapters to peer HBM; "
          f"tiers={store.tier_counts()}")

    # --- external pressure: the trace shrinks peer budgets --------------
    for t in range(12):
        budgets = runtime.tick()
        live = len(alloc.live_handles())
        print(f"t={t:2d} budgets(GiB)="
              f"{[round(b / GiB, 1) for b in budgets.values()]} live={live}")

    # --- durability under revocation: BACKED -> host, else -> LOST ------
    # a sudden external job fills every peer device: all budgets -> 0
    for d in range(4):
        alloc.update_budget(d, 0)
    tiers = store.tier_counts()
    print(f"\nafter full memory crunch: tiers={tiers}")
    for i in range(4):
        ent = store.table[("adapter", i)]
        print(f"  adapter {i}: {ent.durability.value:15s} -> "
              f"{ent.state.value}")

    print("\nunified metrics:", runtime.stats())


if __name__ == "__main__":
    main()
