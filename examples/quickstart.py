"""Quickstart: the Harvest API in 60 lines.

Allocates peer memory opportunistically, registers a revocation callback,
watches the cluster trace shrink a peer's budget, and shows the fallback.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
from repro.core.allocator import HarvestAllocator
from repro.core.monitor import ClusterTrace, ClusterTraceConfig, PeerMonitor

GiB = 2**30


def main():
    # Four peer devices with 16 GiB of harvestable HBM each.
    alloc = HarvestAllocator({d: 16 * GiB for d in range(4)})

    # --- harvest_alloc: opportunistic peer allocation --------------------
    handles = []
    for i in range(6):
        h = alloc.harvest_alloc(3 * GiB, hints={"purpose": f"kv-shard-{i}"})
        if h is None:
            print(f"alloc {i}: no peer capacity (graceful failure)")
            continue
        print(f"alloc {i}: device={h.device} offset={h.offset >> 30}GiB "
              f"size={h.size >> 30}GiB")
        handles.append(h)

    # --- harvest_register_cb: revocation notification --------------------
    def on_revoked(handle):
        print(f"  -> REVOKED device={handle.device} size={handle.size >> 30}GiB"
              f" (falling back to host DRAM copy)")

    for h in handles:
        alloc.harvest_register_cb(h, on_revoked)

    # --- external pressure: a cluster trace shrinks peer budgets ---------
    trace = ClusterTrace(ClusterTraceConfig(num_devices=4,
                                            capacity_bytes=16 * GiB, seed=42))
    mon = PeerMonitor(alloc, trace, capacity_bytes=16 * GiB,
                      reserve_bytes=1 * GiB)
    for t in range(12):
        budgets = mon.tick()
        live = len(alloc.live_handles())
        print(f"t={t:2d} budgets(GiB)="
              f"{[round(b / GiB, 1) for b in budgets.values()]} live={live}")

    # --- harvest_free: explicit release ----------------------------------
    for h in list(alloc.live_handles()):
        alloc.harvest_free(h)
    print("stats:", alloc.stats)


if __name__ == "__main__":
    main()
