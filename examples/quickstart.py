"""Quickstart: the Harvest public API in ~100 lines.

One :class:`HarvestRuntime` composes the allocator, the availability
monitor and the transfer engine; a :class:`HarvestStore` client places
tiered objects with a durability class.  The trace shrinks a peer's
budget, revocation fires, and the two durability classes diverge: BACKED
objects fall back to host, RECONSTRUCTIBLE objects become LOST.

The second half serves a real (tiny) model through the request-lifecycle
API: ``runtime.server(...)`` wraps the engine in a :class:`HarvestServer`,
a seeded Poisson :class:`Workload` drives SLO-classed requests onto the
simulated clock, tokens stream through a callback, and the stats report
per-class TTFT/TPOT percentiles and SLO-goodput.

The last section turns on the harvested prefix cache
(``prefix_cache=True``): requests sharing a system prompt reuse the
retired KV blocks of earlier requests instead of re-prefilling them,
with bit-identical tokens and a hit-rate line in the summary.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import (ClusterTraceConfig, Durability, HarvestRuntime,
                        Residency)

GiB = 2**30


def main():
    # Four peer devices with 16 GiB of harvestable HBM each, pressured by
    # the Fig-2-calibrated cluster trace.
    runtime = HarvestRuntime(
        {d: 16 * GiB for d in range(4)},
        trace_config=ClusterTraceConfig(num_devices=4,
                                        capacity_bytes=16 * GiB, seed=42),
        reserve_bytes=1 * GiB)
    alloc = runtime.allocator

    # --- harvest_alloc: the paper's §3.2 API, still the floor -----------
    handles = []
    for i in range(6):
        h = alloc.harvest_alloc(3 * GiB, hints={"purpose": f"kv-shard-{i}"})
        if h is None:
            print(f"alloc {i}: no peer capacity (graceful failure)")
            continue
        print(f"alloc {i}: device={h.device} offset={h.offset >> 30}GiB "
              f"size={h.size >> 30}GiB")
        handles.append(h)
    for h in list(alloc.live_handles()):
        alloc.harvest_free(h)

    # --- HarvestStore: tiered objects with a durability class -----------
    # Any object class plugs into the same seam — here, LoRA adapters.
    store = runtime.create_store("lora", object_nbytes=2 * GiB)
    for i in range(4):
        store.register(("adapter", i), state=Residency.HOST,
                       durability=(Durability.BACKED if i % 2 == 0
                                   else Durability.RECONSTRUCTIBLE))
        store.touch_hotness(("adapter", i), float(i), alpha=0.0)

    migrated = sum(1 for key, _ in store.hottest(Residency.HOST)
                   if store.promote_to_peer(key))
    print(f"\npromoted {migrated} adapters to peer HBM; "
          f"tiers={store.tier_counts()}")

    # --- external pressure: the trace shrinks peer budgets --------------
    for t in range(12):
        budgets = runtime.tick()
        live = len(alloc.live_handles())
        print(f"t={t:2d} budgets(GiB)="
              f"{[round(b / GiB, 1) for b in budgets.values()]} live={live}")

    # --- durability under revocation: BACKED -> host, else -> LOST ------
    # a sudden external job fills every peer device: all budgets -> 0
    for d in range(4):
        alloc.update_budget(d, 0)
    tiers = store.tier_counts()
    print(f"\nafter full memory crunch: tiers={tiers}")
    for i in range(4):
        ent = store.table[("adapter", i)]
        print(f"  adapter {i}: {ent.durability.value:15s} -> "
              f"{ent.state.value}")

    print("\nunified metrics:", runtime.stats())

    # --- request-lifecycle serving: HarvestServer + workload -------------
    serve_quickstart()

    # --- harvested prefix cache: cross-request KV sharing ----------------
    prefix_cache_quickstart()


def serve_quickstart():
    """Serve a tiny model under a clock-driven, SLO-classed workload."""
    import jax

    from repro.configs.base import ModelConfig
    from repro.models import model as M
    from repro.serving import ServeRequest, TenantSpec, Workload

    cfg = ModelConfig(name="tiny-dense", family="dense", source="example",
                      num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                      d_ff=128, vocab_size=256)
    params = M.init_params(jax.random.PRNGKey(0), cfg)

    runtime = HarvestRuntime({1: 64 * 2**20})
    server = runtime.server(cfg, params, max_batch=2, block_size=8,
                            num_local_slots=12, scheduler="fair",
                            admission="deadline")

    # one hand-submitted streaming request…
    streamed = []
    handle = server.submit(ServeRequest(
        prompt=[5, 7, 11], max_new_tokens=6, slo="latency",
        ttft_slo_s=2e-3, on_token=lambda tok, _r: streamed.append(tok)))

    # …plus a seeded two-tenant Poisson mix arriving on the clock
    workload = Workload(
        num_requests=8, arrival="poisson", rate=30_000.0, seed=42,
        vocab=(3, 250),
        tenants=(TenantSpec("interactive", weight=2, slo="latency",
                            priority=1, prompt_len=(4, 12),
                            max_new_tokens=6, ttft_slo_s=2e-3),
                 TenantSpec("background", weight=1, slo="batch",
                            prompt_len=(12, 32), max_new_tokens=8)))
    stats = server.run(workload)

    print("\n--- request-lifecycle serving ---")
    print(stats.summary())
    if handle.rejected:
        print(f"streamed request {handle.req_id}: shed by admission")
    else:
        print(f"streamed request {handle.req_id}: tokens={streamed} "
              f"ttft={handle.ttft_s * 1e6:.1f}us "
              f"e2e={handle.e2e_s * 1e6:.1f}us")
    for h in server.handles[1:4]:
        if h.rejected:   # deadline admission may shed under tight SLOs
            print(f"  req {h.req_id}: arrival {h.arrival_t * 1e6:7.1f}us "
                  f"-> shed  [{h.state}]")
            continue
        print(f"  req {h.req_id}: arrival {h.arrival_t * 1e6:7.1f}us -> "
              f"admit {h.admit_t * 1e6:7.1f}us -> first token "
              f"{h.first_token_t * 1e6:7.1f}us -> finish "
              f"{h.finish_t * 1e6:7.1f}us  [{h.state}]")


def prefix_cache_quickstart():
    """Share one system prompt across requests via the prefix cache.

    Four requests open with the same 16-token system prompt.  With
    ``prefix_cache=True`` the first request prefills it once; when it
    retires, its KV blocks are published into a radix trie over the
    block store (zero bytes move — the blocks are re-keyed in place) and
    every later request *adopts* them instead of re-prefilling.  Tokens
    are bit-identical to the cache-off run: adoption is zero-copy reuse
    of the exact bytes prefill would have produced, never an
    approximation.
    """
    import jax

    from repro.configs.base import ModelConfig
    from repro.models import model as M
    from repro.serving import ServeRequest

    cfg = ModelConfig(name="tiny-dense", family="dense", source="example",
                      num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                      d_ff=128, vocab_size=256)
    params = M.init_params(jax.random.PRNGKey(0), cfg)

    system_prompt = list(range(40, 56))          # 2 blocks of 8 tokens
    prompts = [system_prompt + [60 + i, 70 + i] for i in range(4)]

    def serve(prefix_cache):
        runtime = HarvestRuntime({1: 64 * 2**20})
        server = runtime.server(cfg, params, max_batch=2, block_size=8,
                                num_local_slots=12, scheduler="fair",
                                prefix_cache=prefix_cache)
        # stagger arrivals so earlier requests retire (and publish their
        # blocks) before later ones prefill
        for i, p in enumerate(prompts):
            server.submit(ServeRequest(prompt=p, max_new_tokens=4,
                                       arrival_t=i * 1e-4))
        stats = server.run()
        return [tuple(h.tokens) for h in server.handles], stats

    tokens_on, stats_on = serve(True)
    tokens_off, _ = serve(False)

    print("\n--- harvested prefix cache ---")
    assert tokens_on == tokens_off, "cache must never change tokens"
    print(f"tokens bit-identical with cache on/off: {tokens_on == tokens_off}")
    saved = [r.cached_prefix_blocks for r in stats_on.records()]
    print(f"prompt blocks served from the cache per request: {saved}")
    print(stats_on.summary())


if __name__ == "__main__":
    main()
