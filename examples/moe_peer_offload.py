"""MoE expert offload with Harvest (paper §4).

Loads Qwen2-MoE's architecture, offloads half the experts, and runs the
CGOPipe-style decode simulation twice — expert misses served from host DRAM
(PCIe) vs from harvested peer HBM (NVLink) — while the Expert Rebalancer
migrates the hottest experts into peer memory as capacity appears and falls
back transparently when the trace revokes it.

Run:  PYTHONPATH=src python examples/moe_peer_offload.py [--arch qwen2-moe]
"""
import argparse

import numpy as np

from repro.configs import get_config
from repro.core import HarvestRuntime
from repro.core.monitor import ClusterTraceConfig
from repro.core.simulator import AccessModelConfig, ExpertAccessModel, \
    simulate_moe_decode
from repro.core.tiers import H100_NVLINK, Tier, expert_bytes

GiB = 2**30


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-moe")
    ap.add_argument("--offload", type=float, default=0.5)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    hw = H100_NVLINK
    eb = expert_bytes(cfg)
    print(f"{cfg.name}: {cfg.moe.num_experts} experts x {eb / 2**20:.0f} MiB, "
          f"top-{cfg.moe.top_k}, {args.offload:.0%} offloaded\n")

    # -- one runtime composes allocator + monitor + transfer accounting --
    runtime = HarvestRuntime(
        {0: 8 * GiB, 1: 8 * GiB}, hardware=hw,
        trace_config=ClusterTraceConfig(num_devices=2,
                                        capacity_bytes=8 * GiB, seed=1))

    # -- throughput: host offload vs Harvest peer offload -----------------
    host = simulate_moe_decode(cfg, hw, args.offload, use_peer=False,
                               decode_steps=8, runtime=runtime)
    peer = simulate_moe_decode(cfg, hw, args.offload, use_peer=True,
                               decode_steps=8, runtime=runtime)
    print(f"CPU offload   : {host.tokens_per_s:8.1f} tok/s")
    print(f"Harvest (peer): {peer.tokens_per_s:8.1f} tok/s  "
          f"(+{(peer.tokens_per_s / host.tokens_per_s - 1) * 100:.0f}%)\n")

    # -- the rebalancer reacting to live peer availability ----------------
    reb = runtime.rebalancer(cfg, local_fraction=1 - args.offload)
    am = ExpertAccessModel(cfg.moe.num_experts, cfg.moe.top_k,
                           AccessModelConfig(seed=0))

    for step in range(16):
        experts = np.unique(am.sample_microbatch(324))
        for li in range(min(cfg.num_moe_layers, 4)):
            reb.record_access(li, experts)
        migrated = reb.rebalance(max_migrations=8)
        runtime.tick()
        frac = reb.residency_fractions()
        print(f"step {step:2d}: migrated {migrated:2d}  residency "
              f"local={frac[Tier.LOCAL_HBM.value]:.2f} "
              f"peer={frac[Tier.PEER_HBM.value]:.2f} "
              f"host={frac[Tier.HOST_DRAM.value]:.2f}  "
              f"revocations={reb.stats['revocations']}")

    print("\nrebalancer stats:", dict(reb.stats))
    print("unified metrics :", {k: v for k, v in runtime.stats().items()
                                if k in ("moe", "allocator")})


if __name__ == "__main__":
    main()
