"""KV-cache paging with Harvest under a fair scheduler (paper §5 + §6.3).

Serves a reduced Yi-6B with a deliberately tight local KV pool and a
completely-fair scheduler: preempted requests' KV blocks are evicted into
harvested peer HBM and reloaded over the fast path when they resume.
Decoded tokens are bit-identical to an all-local run.

Run:  PYTHONPATH=src python examples/kv_paging_long_context.py
"""
import dataclasses

import jax

from repro.configs import get_config
from repro.core import HarvestRuntime
from repro.core.tiers import H100_NVLINK
from repro.models import model as M
from repro.serving.engine import HarvestServingEngine

MiB = 2**20


def build():
    cfg = dataclasses.replace(get_config("yi-6b").reduced(), num_layers=2)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def serve(cfg, params, *, slots, peer_budgets=None, scheduler="fcfs"):
    runtime = HarvestRuntime(peer_budgets or {}, hardware=H100_NVLINK)
    eng = HarvestServingEngine(
        cfg, params, max_batch=2, block_size=8, num_local_slots=slots,
        max_seq_len=128, runtime=runtime, scheduler=scheduler)
    prompts = [[3 + i, 141, 59, 26, 5 + i, 35] for i in range(6)]
    reqs = [eng.submit(p, max_new_tokens=24) for p in prompts]
    stats = eng.run(max_steps=2000)
    return eng, reqs, stats


def main():
    cfg, params = build()

    print("1) baseline: tight local pool (12 blocks), fair scheduler, "
          "evictions fall back to host DRAM (no peer capacity)")
    eng0, ref, s0 = serve(cfg, params, slots=12, scheduler="fair")
    kv0 = eng0.kv_mgr.stats
    print(f"   preemptions {s0.preemptions}, evict->host "
          f"{kv0['evict_to_host']}, host reloads {kv0['reload_host']}, "
          f"reload time {s0.reload_s * 1e3:.2f} ms\n")

    print("2) Harvest: same pool + fair scheduler, peer tier enabled")
    eng, out, s1 = serve(cfg, params, slots=12,
                         peer_budgets={1: 256 * MiB}, scheduler="fair")
    kv = eng.kv_mgr.stats
    print(f"   preemptions          : {s1.preemptions}")
    print(f"   blocks evicted->peer : {kv['evict_to_peer']}")
    print(f"   peer reloads         : {kv['reload_peer']}")
    print(f"   reload time          : {s1.reload_s * 1e3:.2f} ms "
          f"({s0.reload_s / max(s1.reload_s, 1e-12):.1f}x faster than host)")

    # The paper's correctness contract: WHERE a miss is served from (peer
    # HBM vs host DRAM) never changes the result — slot dynamics and math
    # are identical, only the transfer path differs.
    identical = all(a.output == b.output for a, b in zip(ref, out))
    print(f"\n   tokens identical to host-fallback run: {identical}")
    assert identical, "the peer tier must never change decoded tokens"
    assert s1.reload_s < s0.reload_s, "peer reloads must be faster"


if __name__ == "__main__":
    main()
