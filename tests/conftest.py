import os
import sys

# Tests run on the single real CPU device — the 512-device XLA flag is
# set ONLY inside repro.launch.dryrun (per the build instructions).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
