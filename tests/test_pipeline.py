"""Staged serving pipeline + event-driven transfer timeline (PR 2).

Covers the tentpole refactor:
  * TransferEngine timeline semantics (submit/drain/wait, duplex lanes,
    same-key write-back -> reload chaining, queue metrics);
  * the engine's clock modes: sync reproduces the legacy accounting,
    async+prefetch generates IDENTICAL tokens with a simulated clock no
    worse than sync on the fig7-style preemption workload, and reports
    prefetch hit/waste counters;
  * the EngineStats clock identity (satellite: the prefill/eviction
    accounting drift is now an explicit writeback class);
  * scheduler satellite: Request identity semantics and the
    CompletelyFairScheduler quantum guard.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import (H100_NVLINK, HarvestRuntime, PrefetchConfig,
                        Prefetcher, Tier, TransferEngine, channel_name)
from repro.core.tiers import TPU_V5E
from repro.serving.scheduler import CompletelyFairScheduler, Request

MiB = 2**20


# ---------------------------------------------------------------------------
# TransferEngine timeline
# ---------------------------------------------------------------------------


def test_timeline_fifo_and_duplex_lanes():
    te = TransferEngine(TPU_V5E)
    reloads = [te.submit(te.transfer(("r", i), 8 * MiB, Tier.PEER_HBM,
                                     Tier.LOCAL_HBM)) for i in range(3)]
    writeback = te.submit(te.transfer(("w", 0), 8 * MiB, Tier.LOCAL_HBM,
                                      Tier.PEER_HBM))
    # per-lane FIFO: ready times non-decreasing in submit order
    assert reloads[0].ready_t <= reloads[1].ready_t <= reloads[2].ready_t
    assert all(t.channel == "peer_in" for t in reloads)
    # duplex: the write-back rides the outbound lane, not behind the reads
    assert writeback.channel == "peer_out"
    assert writeback.ready_t == pytest.approx(writeback.seconds)
    # inbound lane serialises
    assert reloads[2].ready_t == pytest.approx(
        sum(t.seconds for t in reloads))
    # nothing completes before the clock reaches it
    assert te.drain_until(reloads[0].ready_t / 2) == []
    assert not reloads[0].done
    done = te.drain_until(reloads[1].ready_t)
    assert reloads[0] in done and reloads[1] in done
    assert writeback.done  # its lane ran concurrently
    te.wait_for(reloads)
    assert te.pending() == 0 and reloads[2].done
    assert te.now == pytest.approx(reloads[2].ready_t)


def test_timeline_same_key_chains_writeback_then_reload():
    """A reload of a block whose eviction write-back is still on the wire
    must wait for the write-back even though the lanes are distinct."""
    te = TransferEngine(TPU_V5E)
    out = te.submit(te.transfer("blk", 4 * MiB, Tier.LOCAL_HBM,
                                Tier.PEER_HBM))
    back = te.submit(te.transfer("blk", 4 * MiB, Tier.PEER_HBM,
                                 Tier.LOCAL_HBM))
    assert back.ready_t == pytest.approx(out.ready_t + back.seconds)
    # once drained, a fresh transfer of the same key does not chain
    te.wait_for([back])
    again = te.submit(te.transfer("blk", 4 * MiB, Tier.PEER_HBM,
                                  Tier.LOCAL_HBM))
    assert again.ready_t == pytest.approx(te.now + again.seconds)


def test_timeline_queue_metrics_and_sync_totals():
    te = TransferEngine(TPU_V5E)
    ops = [te.transfer(i, 2 * MiB, Tier.HOST_DRAM, Tier.LOCAL_HBM)
           for i in range(4)]
    for op in ops:
        te.submit(op)
    stats = te.metrics.snapshot()["transfer"]
    assert stats["q.host_in.submitted"] == 4
    assert stats["q.host_in.depth"] == 4 and stats["q.host_in.peak"] == 4
    # a single lane drains in exactly the legacy serial-schedule time
    makespan = max(op.ready_t for op in ops)
    assert makespan == pytest.approx(te.schedule(ops))
    te.drain_until(makespan)
    stats = te.metrics.snapshot()["transfer"]
    assert stats["q.host_in.completed"] == 4 and stats["q.host_in.depth"] == 0


def test_channel_name_directions():
    assert channel_name(Tier.PEER_HBM, Tier.LOCAL_HBM) == "peer_in"
    assert channel_name(Tier.LOCAL_HBM, Tier.PEER_HBM) == "peer_out"
    assert channel_name(Tier.HOST_DRAM, Tier.LOCAL_HBM) == "host_in"
    assert channel_name(Tier.HOST_DRAM, Tier.PEER_HBM) == "host_out"
    assert channel_name(Tier.LOCAL_HBM, Tier.LOCAL_HBM) == "hbm"


# ---------------------------------------------------------------------------
# scheduler satellites
# ---------------------------------------------------------------------------


def test_request_identity_semantics():
    a = Request(0, [1, 2, 3], 8)
    b = Request(0, [1, 2, 3], 8)      # same fields, distinct request
    assert a != b and a == a
    assert b not in [a], "membership must be identity, not field equality"
    assert len({a, b}) == 2


def test_fair_scheduler_rejects_bad_quantum():
    with pytest.raises(ValueError):
        CompletelyFairScheduler(quantum=0)
    with pytest.raises(ValueError):
        CompletelyFairScheduler(quantum=-3)
    assert CompletelyFairScheduler(quantum=2).quantum == 2


# ---------------------------------------------------------------------------
# staged engine: sync vs async+prefetch on the fig7-style preemption workload
# ---------------------------------------------------------------------------

# fig7 regime: decode of trillion-class models is memory-bandwidth-bound,
# so one decode window dwarfs a block transfer.  Scaling hbm_bw down gives
# the REDUCED test model the same window-to-transfer ratio on H100 links.
MEMORY_BOUND_HW = dataclasses.replace(H100_NVLINK, hbm_bw=5e10)


@pytest.fixture(scope="module")
def served_model():
    import jax
    from repro.configs import get_config
    from repro.models import model as M
    cfg = dataclasses.replace(get_config("yi-6b").reduced(), num_layers=2)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _run(served_model, mode, prefetch=None, hardware=MEMORY_BOUND_HW):
    from repro.serving.engine import HarvestServingEngine
    cfg, params = served_model
    runtime = HarvestRuntime({1: 64 * MiB}, hardware=hardware)
    eng = HarvestServingEngine(
        cfg, params, max_batch=2, block_size=8, num_local_slots=10,
        max_seq_len=96, runtime=runtime, scheduler="fair", mode=mode,
        prefetch=prefetch)
    reqs = [eng.submit([2 + i, 5, 7, 11, 13 + i], max_new_tokens=12)
            for i in range(4)]
    stats = eng.run(max_steps=800)
    return eng, [r.output for r in reqs], stats


def test_async_prefetch_same_tokens_and_no_worse_clock(served_model):
    _, out_sync, st_sync = _run(served_model, "sync")
    _, out_async, st_async = _run(served_model, "async")
    eng, out_pf, st_pf = _run(served_model, "async",
                              prefetch=PrefetchConfig())
    # the pipeline changes WHEN bytes move, never what is decoded
    assert out_sync == out_async == out_pf
    # the preemption workload actually exercised the tiers
    assert st_sync.metrics["kv"]["evict_to_peer"] > 0
    assert st_sync.preemptions > 0
    # reload time disappears under compute instead of being charged serially
    assert st_async.clock_s <= st_sync.clock_s
    assert st_pf.clock_s <= st_async.clock_s
    # prefetch hit/waste counters are reported through the unified metrics
    pf = st_pf.metrics["prefetch"]
    assert pf["issued"] > 0 and pf["hits"] > 0
    assert pf["hits"] + pf["wasted"] <= pf["issued"]
    assert eng.prefetcher.stats is not None
    # per-link queue occupancy counters made it into the snapshot
    q = {k: v for k, v in st_pf.metrics["transfer"].items()
         if k.startswith("q.")}
    assert q.get("q.peer_in.submitted", 0) > 0
    assert q.get("q.peer_in.completed") == q.get("q.peer_in.submitted")


def test_clock_identity_holds_in_both_modes(served_model):
    _, _, st_sync = _run(served_model, "sync")
    _, _, st_async = _run(served_model, "async",
                          prefetch=PrefetchConfig())
    assert st_sync.check_clock_identity()
    assert st_async.check_clock_identity()
    # the drifted seconds are now explicit: prefill/preemption evictions
    assert st_sync.writeback_s > 0
    assert st_sync.clock_s == pytest.approx(
        st_sync.prefill_s + st_sync.compute_s
        + st_sync.critical_reload_s - st_sync.hidden_s)
    # async charges stalls instead of serial reload time
    assert st_async.stall_s <= st_sync.critical_reload_s


def test_identity_violation_is_detected():
    from repro.serving.engine import EngineStats
    st = EngineStats(clock_s=1.0, compute_s=0.25)
    with pytest.raises(AssertionError):
        st.check_clock_identity()


def test_prefetcher_waste_accounting(served_model):
    """A prefetched block whose owner is freed before any read is waste."""
    cfg, _params = served_model
    runtime = HarvestRuntime({1: 64 * MiB}, hardware=MEMORY_BOUND_HW)
    kv = runtime.kv_manager(cfg, block_size=8, num_local_slots=4)
    pf = Prefetcher(kv, runtime.transfers,
                    PrefetchConfig(min_free_slots=1, resume_lookahead=4),
                    metrics=runtime.metrics)
    kv.allocate_block(7, 0, 0)
    kv.evict_request(7)                      # -> peer
    req = Request(7, [1, 2, 3], 4)
    req.needs_prefill = False

    issued = pf.run(window_s=1.0, running=[], waiting=[req])
    assert len(issued) == 1 and pf.stats["issued"] == 1
    assert kv.table[(7, 0)].state.value == "local"
    pf.cancel_owner(7)
    assert pf.stats["wasted"] == 1 and pf.stats["hits"] == 0
    # and a claimed prefetch is a hit
    kv.evict_request(7)
    pf.run(window_s=1.0, running=[], waiting=[req])
    assert pf.claim((7, 0)) is not None
    assert pf.stats["hits"] == 1


def test_prefetch_respects_slot_floor(served_model):
    cfg, _params = served_model
    runtime = HarvestRuntime({1: 64 * MiB}, hardware=MEMORY_BOUND_HW)
    kv = runtime.kv_manager(cfg, block_size=8, num_local_slots=2)
    pf = Prefetcher(kv, runtime.transfers,
                    PrefetchConfig(min_free_slots=2, resume_lookahead=4),
                    metrics=runtime.metrics)
    kv.allocate_block(3, 0, 0)
    kv.evict_request(3)
    req = Request(3, [1, 2, 3], 4)
    req.needs_prefill = False
    assert pf.run(window_s=1.0, running=[], waiting=[req]) == []
    assert pf.stats["skipped_slots"] == 1
    assert kv.table[(3, 0)].state.value == "peer", \
        "prefetch must never consume the slot floor"


def test_prefetcher_promotes_experts_on_the_timeline():
    """The rebalancer hook rides the event timeline and the link budget."""
    from repro.configs import get_config
    runtime = HarvestRuntime({0: 8 * 2**30, 1: 8 * 2**30},
                             hardware=H100_NVLINK)
    cfg = get_config("qwen2-moe")
    kv = runtime.kv_manager(get_config("yi-6b").reduced(), block_size=8,
                            num_local_slots=4)
    reb = runtime.rebalancer(cfg, local_fraction=0.5)
    for e in range(cfg.moe.num_experts):
        reb.store.touch_hotness((0, e), float(e), alpha=0.0)
    pf = Prefetcher(kv, runtime.transfers,
                    PrefetchConfig(expert_migrations=4),
                    rebalancer=reb, metrics=runtime.metrics)
    pf.run(window_s=1.0)
    assert pf.stats["expert_promotions"] == 4
    assert reb.stats["migrations"] == 4
    # the promotions are in flight on the host->peer lane, FIFO-queued
    assert runtime.transfers.pending("host_out") == 4
    # and a zero budget issues none
    n = pf.stats["expert_promotions"]
    pf.run(window_s=0.0)
    assert pf.stats["expert_promotions"] == n
    assert pf.stats["skipped_budget"] > 0


def test_simulator_timeline_mode():
    """The event-driven CGOPipe path: same placement inputs, real
    queueing; peer serving must still beat host serving."""
    from repro.configs import get_config
    from repro.core import simulate_moe_decode
    cfg = get_config("qwen2-moe")
    kw = dict(micro_batch=32, num_micro_batches=3, decode_steps=1)
    runtime = HarvestRuntime(hardware=H100_NVLINK)
    peer = simulate_moe_decode(cfg, H100_NVLINK, 0.5, use_peer=True,
                               runtime=runtime, use_timeline=True, **kw)
    host = simulate_moe_decode(cfg, H100_NVLINK, 0.5, use_peer=False,
                               runtime=runtime, use_timeline=True, **kw)
    assert peer.tokens_per_s > host.tokens_per_s
    assert peer.t_fetch > 0 and host.t_fetch > 0
    # the timeline actually ran: the shared clock advanced and drained
    assert runtime.clock > 0
    assert runtime.transfers.pending() == 0
    # timeline mode is pessimistic-or-equal vs the analytic max() overlap
    # (cold-start fill + FIFO queueing are modelled, not assumed away)
    analytic = simulate_moe_decode(cfg, H100_NVLINK, 0.5, use_peer=True,
                                   **kw)
    assert peer.tokens_per_s <= analytic.tokens_per_s * (1 + 1e-9)


def test_engine_rejects_prefetch_without_async(served_model):
    from repro.serving.engine import HarvestServingEngine
    cfg, params = served_model
    with pytest.raises(AssertionError):
        HarvestServingEngine(cfg, params, mode="sync",
                             prefetch=PrefetchConfig())
