"""Seed-equivalence tests for the HarvestStore/HarvestRuntime refactor.

The golden numbers below were captured from the pre-refactor repo (the
hand-wired KVOffloadManager / ExpertRebalancer implementations) on
fixed-seed workloads.  The thin-client rewrite must reproduce them
EXACTLY: same decoded tokens, same eviction/reload/revocation counts,
same simulated clock — the refactor moves residency mechanics into the
store without changing a single placement decision or transfer time.
"""
import dataclasses

import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (AccessModelConfig, ClusterTrace, ClusterTraceConfig,
                        ExpertAccessModel, H100_NVLINK, HarvestRuntime,
                        simulate_moe_decode)

MiB = 2**20
GiB = 2**30

# --- golden: serving engine, yi-6b reduced 2L, 4 reqs x 12 tokens, fair
# scheduler, 10 local slots, peer budget 64 MiB on device 1 (seed commit)
ENGINE_GOLDEN = {
    "outputs": [
        [380, 87, 109, 233, 267, 437, 437, 233, 241, 109, 241, 109],
        [250, 250, 437, 437, 437, 437, 437, 437, 25, 25, 57, 61],
        [501, 250, 250, 250, 312, 364, 364, 364, 364, 364, 364, 364],
        [437, 437, 437, 437, 216, 8, 216, 8, 216, 8, 216, 8],
    ],
    "kv_stats": {"evict_to_peer": 4, "evict_to_host": 0, "reload_peer": 4,
                 "reload_host": 0, "revocations": 0, "recomputes": 0,
                 "allocated": 8, "freed": 8},
    "alloc_stats": {"allocs": 4, "failed": 0, "revocations": 0, "frees": 4},
    "clock_s": 0.0001582013302897278,
    "compute_s": 1.807619820895522e-05,
    "reload_s": 0.0002736771011764706,
    "steps": 22,
    "tokens_out": 48,
    "preemptions": 2,
}

# --- golden: rebalancer under the seed-1 cluster trace, qwen2-moe,
# 16 steps x 8 migrations, fetches over the first 8 experts (seed commit)
REBALANCER_GOLDEN = {
    "stats": {"peer_hits": 0, "host_hits": 0, "local_hits": 128,
              "migrations": 128, "revocations": 18},
    "fractions": {"local": 0.5, "peer": 0.07161458333333333,
                  "host": 0.4283854166666667},
    "fetch_s": 0.000661072391641791,
}

# --- golden: CGOPipe simulator, qwen2-moe @ 50% offload, peer, 2 steps
SIM_GOLDEN = {"tokens_per_s": 1167.7043190686936,
              "t_fetch": 2.6860521929788317}


def test_engine_stats_match_seed_behavior():
    import jax
    from repro.models import model as M
    from repro.serving.engine import HarvestServingEngine

    cfg = dataclasses.replace(get_config("yi-6b").reduced(), num_layers=2)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    runtime = HarvestRuntime({1: 64 * MiB}, hardware=H100_NVLINK)
    eng = HarvestServingEngine(
        cfg, params, max_batch=2, block_size=8, num_local_slots=10,
        max_seq_len=96, runtime=runtime, scheduler="fair")
    reqs = [eng.submit([2 + i, 5, 7, 11, 13 + i], max_new_tokens=12)
            for i in range(4)]
    stats = eng.run(max_steps=800)

    g = ENGINE_GOLDEN
    assert [r.output for r in reqs] == g["outputs"], \
        "the refactor changed decoded tokens"
    assert {k: eng.kv_mgr.stats[k] for k in g["kv_stats"]} == g["kv_stats"]
    assert {k: eng.allocator.stats[k]
            for k in g["alloc_stats"]} == g["alloc_stats"]
    assert stats.clock_s == pytest.approx(g["clock_s"], rel=1e-9)
    assert stats.compute_s == pytest.approx(g["compute_s"], rel=1e-9)
    assert stats.reload_s == pytest.approx(g["reload_s"], rel=1e-9)
    assert (stats.steps, stats.tokens_out, stats.preemptions) == \
        (g["steps"], g["tokens_out"], g["preemptions"])
    # every block was freed at end-of-run in the seed too
    counts = eng.kv_mgr.tier_counts()
    assert all(v == 0 for v in counts.values())


def test_rebalancer_stats_match_seed_behavior():
    cfg = get_config("qwen2-moe")
    runtime = HarvestRuntime(
        {0: 8 * GiB, 1: 8 * GiB}, hardware=H100_NVLINK,
        trace=ClusterTrace(ClusterTraceConfig(
            num_devices=2, capacity_bytes=8 * GiB, seed=1)))
    reb = runtime.rebalancer(cfg, local_fraction=0.5)
    am = ExpertAccessModel(cfg.moe.num_experts, cfg.moe.top_k,
                           AccessModelConfig(seed=0))
    fetch_s = 0.0
    for _ in range(16):
        experts = np.unique(am.sample_microbatch(324))
        for li in range(min(cfg.num_moe_layers, 4)):
            reb.record_access(li, experts)
        reb.rebalance(max_migrations=8)
        runtime.tick()
        for e in experts[:8]:
            _tier, s = reb.fetch(0, int(e))
            fetch_s += s

    g = REBALANCER_GOLDEN
    assert {k: reb.stats[k] for k in g["stats"]} == g["stats"]
    fracs = reb.residency_fractions()
    for tier, v in g["fractions"].items():
        assert fracs[tier] == pytest.approx(v, rel=1e-12)
    assert fetch_s == pytest.approx(g["fetch_s"], rel=1e-9)


def test_simulator_matches_seed_behavior():
    cfg = get_config("qwen2-moe")
    runtime = HarvestRuntime(hardware=H100_NVLINK)
    sim = simulate_moe_decode(cfg, H100_NVLINK, 0.5, use_peer=True,
                              decode_steps=2, runtime=runtime)
    assert sim.tokens_per_s == pytest.approx(SIM_GOLDEN["tokens_per_s"],
                                             rel=1e-9)
    assert sim.t_fetch == pytest.approx(SIM_GOLDEN["t_fetch"], rel=1e-9)
    # and the runtime's transfer engine saw every peer fetch
    xfer = runtime.stats()["transfer"]
    assert xfer["sim.peer_s"] == pytest.approx(sim.fetch_by_tier["peer"],
                                               rel=1e-9)
