"""serve_step (paged Harvest KV pools / recurrent state) must reproduce the
full-sequence forward logits for every architecture family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.models import model as M


def pools_from_prefill(kvs, b, s, bs, npb, dtype=jnp.float32):
    k, v = kvs
    Lk, nkv, hd = k.shape[0], k.shape[3], k.shape[4]
    n_slots = b * npb
    pool_k = np.zeros((Lk, n_slots, bs, nkv, hd), np.float32)
    pool_v = np.zeros_like(pool_k)
    slot_req = np.full((n_slots,), -1, np.int32)
    slot_base = np.zeros((n_slots,), np.int32)
    for r in range(b):
        for j in range(npb):
            slot = r * npb + j
            slot_req[slot] = r
            slot_base[slot] = j * bs
            lo, hi = j * bs, min((j + 1) * bs, s)
            if lo < s:
                pool_k[:, slot, :hi - lo] = np.asarray(k[:, r, lo:hi], np.float32)
                pool_v[:, slot, :hi - lo] = np.asarray(v[:, r, lo:hi], np.float32)
    return (jnp.asarray(pool_k, dtype), jnp.asarray(pool_v, dtype),
            jnp.asarray(slot_req), jnp.asarray(slot_base))


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_decode_matches_forward(arch):
    cfg = get_config(arch).reduced()
    rng = jax.random.PRNGKey(0)
    params = M.init_params(rng, cfg)
    b, s, bs, n_extra = 2, 21, 8, 4
    npre = cfg.modality.num_prefix_embeddings if cfg.modality else 0
    ncb = cfg.modality.num_codebooks if cfg.modality else 1
    audio = cfg.family == "audio" and ncb > 1
    tshape = (b, s + n_extra, ncb) if audio else (b, s + n_extra)
    tokens = jax.random.randint(rng, tshape, 0, cfg.vocab_size)
    S_all = s + n_extra + npre
    positions = jnp.broadcast_to(jnp.arange(S_all), (b, S_all))

    def batch_for(n):
        bd = {"tokens": tokens[:, :n],
              "positions": positions[:, :n + npre]}
        if npre:
            bd["prefix_embeddings"] = 0.02 * jax.random.normal(
                rng, (b, npre, cfg.d_model))
        if cfg.rope_style == "mrope":
            bd["positions_3d"] = jnp.broadcast_to(
                jnp.arange(n + npre)[:, None], (b, n + npre, 3))
        return bd

    ref_logits, _ = M.forward(params, batch_for(s + n_extra), cfg)
    _, out = M.prefill(params, batch_for(s), cfg)

    npb = (s + npre + n_extra + bs - 1) // bs
    kv = None
    if out.kv is not None:
        # positions in the pool include the modality prefix
        pk, pv, sr, sb = pools_from_prefill(out.kv, b, s + npre, bs, npb)
        kv = M.KVPools(pk, pv, sr, sb, jnp.zeros((b,), jnp.int32),
                       jnp.zeros((b,), jnp.int32))
    st = M.DecodeState(
        tokens=tokens[:, s], pos=jnp.full((b,), s + npre, jnp.int32),
        kv=kv, peer=None, states=out.states,
        positions_3d=(jnp.full((b, 3), s + npre, jnp.int32)
                      if cfg.rope_style == "mrope" else None))
    maxerr = 0.0
    for t in range(n_extra):
        pos = s + npre + t
        if kv is not None:
            aslot = jnp.array([r * npb + pos // bs for r in range(b)], jnp.int32)
            aoff = jnp.full((b,), pos % bs, jnp.int32)
            st = st._replace(kv=st.kv._replace(append_slot=aslot,
                                               append_off=aoff))
        st = st._replace(tokens=tokens[:, s + t],
                         pos=jnp.full((b,), pos, jnp.int32),
                         positions_3d=(jnp.full((b, 3), pos, jnp.int32)
                                       if cfg.rope_style == "mrope" else None))
        logits, st = M.serve_step(params, st, cfg)
        ref = ref_logits[:, npre + s + t]
        if logits.ndim == 3:      # audio: (b, ncb, V)
            ref = ref_logits[:, npre + s + t]
        maxerr = max(maxerr, float(jnp.max(jnp.abs(
            logits.astype(jnp.float32) - ref.astype(jnp.float32)))))
    assert maxerr < 0.02, maxerr
