"""Closed-loop stability controller (PR 10).

Covers the tentpole subsystem:
  * estimator primitives (windowed rates, EWMA means) and their
    validation;
  * the stability region + hysteresis: an overload engages the
    controller, draining the window disengages it, and every actuator
    (batch cap, prefetch throttle, churn scale) is restored to its
    passive value on disengage;
  * :class:`StabilityAdmission`: verbatim delegation while disengaged,
    deadline-reachability shedding / divergent-queue shedding / row+block
    deferral while engaged, and the no-deadlock starvation guard;
  * synchronized revocation storms in :class:`ClusterTrace` consume no
    rng draws (storm-free configs stay draw-for-draw legacy-exact);
  * the new ``ramp``/``flood`` arrival generators;
  * satellites: all-requests-shed runs produce a clean zero summary
    (never a division error), ``SweepResult.max_rss_mb``, engine
    ``controller=`` plumbing and its async-mode guard.
"""
import dataclasses
import math

import numpy as np
import pytest

from repro.core import HarvestRuntime
from repro.core.monitor import ClusterTrace, ClusterTraceConfig
from repro.serving import (ControllerConfig, EwmaMean, HarvestServer,
                           StabilityAdmission, StabilityController,
                           TenantSpec, WindowedRate, WindowedSum, Workload)
from repro.serving.admission import AdmissionPolicy, AdmissionView
from repro.serving.engine import EngineStats
from repro.serving.scheduler import Request
from repro.serving.sweep import SweepConfig, SweepTrace, simulate
from repro.serving.workload import flood_arrivals, ramp_arrivals

MiB = 2**20


@pytest.fixture(scope="module")
def served_model():
    import jax
    from repro.configs import get_config
    from repro.models import model as M
    cfg = dataclasses.replace(get_config("yi-6b").reduced(), num_layers=2)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _server(served_model, *, budget=64 * MiB, **kw):
    cfg, params = served_model
    runtime = HarvestRuntime({1: budget})
    kw.setdefault("max_batch", 2)
    kw.setdefault("block_size", 8)
    kw.setdefault("num_local_slots", 10)
    kw.setdefault("scheduler", "fair")
    return HarvestServer(cfg, params, runtime=runtime, **kw)


def _latency_workload(rate, n=12, seed=7, **tenant_kw):
    return Workload(
        num_requests=n, rate=rate, seed=seed, vocab=(3, 250),
        tenants=(TenantSpec("t0", slo="latency", prompt_len=(8, 16),
                            max_new_tokens=(4, 8), **tenant_kw),))


# ---------------------------------------------------------------------------
# estimator primitives
# ---------------------------------------------------------------------------

def test_windowed_rate_counts_only_the_window():
    wr = WindowedRate(window_s=1.0)
    for t in (0.1, 0.2, 0.9, 1.05, 1.6):
        wr.observe(t)
    # at now=2.0 the window is (1.0, 2.0]: events at 1.05 and 1.6
    assert wr.count(2.0) == 2
    assert wr.rate(2.0) == pytest.approx(2.0)
    # purge is permanent: moving further forward empties it
    assert wr.rate(5.0) == 0.0


def test_windowed_sum_weights_events():
    ws = WindowedSum(window_s=2.0)
    ws.observe(0.5, 10.0)
    ws.observe(1.5, 4.0)
    assert ws.rate(2.0) == pytest.approx(7.0)     # (10 + 4) / 2
    assert ws.rate(3.0) == pytest.approx(2.0)     # only the 1.5 event


def test_ewma_mean_first_sample_initialises():
    m = EwmaMean(alpha=0.5)
    assert m.get(default=3.0) == 3.0
    m.update(8.0)
    assert m.value == 8.0                         # no zero bias
    m.update(4.0)
    assert m.value == pytest.approx(6.0)
    assert m.n == 2


def test_estimator_validation():
    with pytest.raises(ValueError):
        WindowedRate(0.0)
    with pytest.raises(ValueError):
        WindowedSum(-1.0)
    with pytest.raises(ValueError):
        EwmaMean(alpha=0.0)


def test_controller_config_validation():
    with pytest.raises(ValueError):
        ControllerConfig(headroom=0.95)
    with pytest.raises(ValueError):
        ControllerConfig(enter_rho=0.5, exit_rho=0.8)
    with pytest.raises(ValueError):
        ControllerConfig(tick_interval_s=0.0)
    with pytest.raises(ValueError):
        ControllerConfig(min_prefetch_scale=0.0)
    with pytest.raises(ValueError):
        ControllerConfig(min_actual_samples=0)


# ---------------------------------------------------------------------------
# region + hysteresis + actuators
# ---------------------------------------------------------------------------

def _fake_request(i, t, *, prompt=12, out=8, slo="latency"):
    return Request(i, list(range(3, 3 + prompt)), out, arrival_t=t,
                   slo=slo, enqueue_t=t)


def test_hysteresis_engage_disengage_restores_actuators(served_model):
    srv = _server(served_model, mode="async",
                  controller=ControllerConfig(
                      tick_interval_s=1e-6, window_s=1e-4))
    eng = srv.engine
    ctrl = eng.controller
    te = eng.runtime.transfers
    ctrl.poll(eng._now())                 # first poll only sets the baseline
    # flood the window with synthetic arrivals far above capacity
    for i in range(400):
        ctrl.on_arrival(_fake_request(i, te.now + i * 1e-8))
    te.advance(5e-6)
    ctrl.poll(eng._now())
    assert ctrl.rho > 1.0 and ctrl.engaged
    assert int(ctrl.stats["engages"]) == 1
    assert ctrl.batch_cap <= eng.B
    # drain: advance past the window so the arrival estimate collapses
    te.advance(10 * ctrl.window_s)
    ctrl.poll(eng._now())
    assert ctrl.rho < ctrl.cfg.exit_rho and not ctrl.engaged
    assert int(ctrl.stats["disengages"]) == 1
    # every actuator restored to its passive value
    assert ctrl.batch_cap == eng.B
    assert ctrl.prefetch_scale == 1.0
    assert ctrl.churn_scale == 1.0
    line = ctrl.summary()
    assert "rho" in line and "idle" in line


def test_controller_requires_async_mode(served_model):
    with pytest.raises(AssertionError, match="event timeline"):
        _server(served_model, mode="sync", controller="stability")
    with pytest.raises(ValueError, match="unknown controller"):
        _server(served_model, mode="async", controller="bogus")


def test_controller_publishes_ctrl_metrics(served_model):
    srv = _server(served_model, mode="async", controller="stability")
    stats = srv.run(_latency_workload(2e3), max_steps=4000)
    ctrl = stats.metrics.get("ctrl")
    assert ctrl is not None and ctrl["ticks"] > 0
    for key in ("rho", "rho_mem", "rho_rows", "eff_blocks", "batch_cap"):
        assert key in ctrl
    assert "ctrl:" in stats.summary()
    stats.check_clock_identity()


# ---------------------------------------------------------------------------
# StabilityAdmission
# ---------------------------------------------------------------------------

class _StubController:
    """Duck-typed controller for admission-policy unit tests."""

    def __init__(self, *, engaged=True, batch_cap=4, budget=100,
                 tpot=1e-6, max_wait=1.0):
        self.engaged = engaged
        self.batch_cap = batch_cap
        self.cfg = ControllerConfig()
        self.stats = {"shed": 0, "deferred": 0}
        self._budget = budget
        self._tpot = tpot
        self._max_wait = max_wait

    def block_budget(self, view=None):
        return self._budget

    def tpot_plan(self, slo=None):
        return self._tpot

    def shed_wait_s(self):
        return self._max_wait


def _view(now=0.0, *, pinned=0, running=0, rows=4):
    return AdmissionView(
        now=now, free_rows=rows, num_slots=100, pinned_blocks=pinned,
        num_running=running, blocks_needed=lambda r: 2,
        est_prefill_s=lambda r: 1e-5, pending_prefill_s=0.0)


def test_stability_admission_delegates_when_disengaged():
    class Marker(AdmissionPolicy):
        def select(self, waiting, view):
            return list(reversed(waiting)), []
    pol = StabilityAdmission(_StubController(engaged=False), inner=Marker())
    reqs = [_fake_request(i, 0.0) for i in range(3)]
    eligible, shed = pol.select(reqs, _view())
    assert eligible == list(reversed(reqs)) and shed == []
    assert pol.ctrl.stats["shed"] == 0


def test_stability_admission_sheds_unreachable_deadlines():
    ctrl = _StubController(tpot=1e-6)
    pol = StabilityAdmission(ctrl)
    ok = _fake_request(0, 0.0)
    ok.ttft_slo_s = 1.0
    late_ttft = _fake_request(1, 0.0)
    late_ttft.ttft_slo_s = 1e-9          # prefill alone blows it
    late_e2e = _fake_request(2, 0.0, out=100)
    late_e2e.e2e_slo_s = 1e-8            # 100 tokens at 1us each cannot fit
    eligible, shed = pol.select([ok, late_ttft, late_e2e], _view())
    assert ok in eligible
    assert late_ttft in shed and late_e2e in shed
    assert ctrl.stats["shed"] == 2


def test_stability_admission_sheds_divergent_queue_waiters():
    ctrl = _StubController(max_wait=0.5)
    pol = StabilityAdmission(ctrl)
    fresh = _fake_request(0, 0.0)
    stale = _fake_request(1, 0.0)
    stale.enqueue_t = -1.0               # queued for 1s > max_wait
    eligible, shed = pol.select([fresh, stale], _view(now=0.0))
    assert fresh in eligible and stale in shed


def test_stability_admission_defers_beyond_row_and_block_caps():
    ctrl = _StubController(batch_cap=2, budget=100)
    pol = StabilityAdmission(ctrl)
    reqs = [_fake_request(i, i * 1e-9) for i in range(5)]
    eligible, shed = pol.select(reqs, _view(running=1))
    assert len(eligible) == 1 and not shed       # cap 2 - 1 running = 1 row
    assert ctrl.stats["deferred"] == 4
    # block budget binds instead of rows: 2 blocks each, budget 5 -> 2 fit
    pol2 = StabilityAdmission(_StubController(batch_cap=8, budget=5))
    eligible, shed = pol2.select(reqs, _view())
    assert len(eligible) == 2 and not shed


def test_stability_admission_starvation_guard():
    # budget too small for even one request: with nothing running the
    # head of line must still be admitted (no deadlock)
    ctrl = _StubController(batch_cap=4, budget=1)
    pol = StabilityAdmission(ctrl)
    reqs = [_fake_request(i, i * 1e-9) for i in range(3)]
    eligible, shed = pol.select(reqs, _view(running=0))
    assert eligible == [reqs[0]] and not shed


def test_stability_admission_priority_order():
    ctrl = _StubController(batch_cap=8)
    pol = StabilityAdmission(ctrl)
    lo = _fake_request(0, 0.0)
    hi = _fake_request(1, 1e-9)
    hi.priority = 5
    eligible, _ = pol.select([lo, hi], _view())
    assert eligible[0] is hi


# ---------------------------------------------------------------------------
# synchronized revocation storms
# ---------------------------------------------------------------------------

def test_storm_schedule_consumes_no_rng_draws():
    base = dict(num_devices=3, capacity_bytes=64 * MiB, seed=11)
    plain = ClusterTrace(ClusterTraceConfig(**base))
    storm = ClusterTrace(ClusterTraceConfig(
        **base, storm_interval=10, storm_duration=2, storm_frac=0.4))
    boosted = clean = 0
    for _ in range(40):
        u_plain = plain.step()
        u_storm = storm.step()
        if storm.t % 10 < 2:
            # storm tick: every device's usage is >= the storm-free trace
            assert np.all(u_storm >= u_plain)
            boosted += 1
        else:
            # clean tick: bit-exact with the legacy trace — the storm
            # schedule consumed no draws
            assert np.array_equal(u_storm, u_plain)
            clean += 1
    assert boosted > 0 and clean > 0


def test_storm_hits_all_devices_at_once():
    tr = ClusterTrace(ClusterTraceConfig(
        num_devices=4, capacity_bytes=64 * MiB, seed=2,
        noise=0.0, job_arrival_p=0.0,
        storm_interval=6, storm_duration=2, storm_frac=0.9))
    quiet = tr.step()                      # t=1: inside the first window
    for _ in range(4):                     # advance to t=5 (clean)
        quiet = tr.step()
    stormy = tr.step()                     # t=6: 6 % 6 == 0 -> storm
    assert np.all(stormy > quiet)          # every peer slammed together


def test_storm_config_validation():
    with pytest.raises(ValueError):
        ClusterTraceConfig(storm_interval=0)
    with pytest.raises(ValueError):
        ClusterTraceConfig(storm_interval=5, storm_duration=9)
    with pytest.raises(ValueError):
        ClusterTraceConfig(storm_interval=5, storm_duration=2,
                           storm_frac=0.0)


# ---------------------------------------------------------------------------
# ramp / flood arrival generators
# ---------------------------------------------------------------------------

def test_ramp_arrivals_rate_climbs():
    rng = np.random.default_rng(0)
    ts = ramp_arrivals(rng, 1000.0, 4000, start_ratio=0.25, end_ratio=4.0)
    assert len(ts) == 4000 and np.all(np.diff(ts) >= 0)
    # inter-arrival gaps shrink as the ramp climbs
    first = np.diff(ts[:1000]).mean()
    last = np.diff(ts[-1000:]).mean()
    assert last < first / 2


def test_flood_arrivals_surge_window():
    rng = np.random.default_rng(1)
    ts = flood_arrivals(rng, 1000.0, 6000, flood_ratio=6.0,
                        flood_start=0.3, flood_frac=0.4)
    assert len(ts) == 6000 and np.all(np.diff(ts) >= 0)
    mean_rate = 1000.0 * (1.0 + 5.0 * 0.4)
    span = 6000 / mean_rate
    lo, hi = 0.3 * span, 0.7 * span
    inside = np.sum((ts >= lo) & (ts < hi)) / (hi - lo)
    outside = np.sum(ts < lo) / lo
    assert inside > 3.0 * outside          # ~6x in expectation


def test_ramp_flood_validation():
    rng = np.random.default_rng(0)
    with pytest.raises(ValueError):
        ramp_arrivals(rng, 100.0, 10, start_ratio=2.0, end_ratio=1.0)
    with pytest.raises(ValueError):
        ramp_arrivals(rng, 0.0, 10)
    with pytest.raises(ValueError):
        flood_arrivals(rng, 100.0, 10, flood_ratio=0.5)
    with pytest.raises(ValueError):
        flood_arrivals(rng, 100.0, 10, flood_start=0.8, flood_frac=0.4)
    # registered in the Workload front door
    Workload(num_requests=4, arrival="ramp", rate=100.0)
    Workload(num_requests=4, arrival="flood", rate=100.0)


# ---------------------------------------------------------------------------
# satellites: all-shed summary, sweep RSS
# ---------------------------------------------------------------------------

def test_all_requests_shed_clean_summary(served_model):
    # every request arrives at clock 0 with an unreachable TTFT deadline:
    # the deadline policy sheds the lot, the clock never advances, and the
    # summary must still render with zero percentiles — no ZeroDivision
    srv = _server(served_model, mode="async", admission="deadline")
    wl = Workload(
        num_requests=5, arrival="trace", rate=1.0, seed=0,
        arrival_kwargs={"times": [0.0] * 5},
        tenants=(TenantSpec("t", slo="latency", prompt_len=(8, 16),
                            max_new_tokens=4, ttft_slo_s=1e-12),))
    stats = srv.run(wl, max_steps=200)
    assert stats.rejected == 5
    assert stats.clock_s == 0.0
    assert stats.throughput() == 0.0
    assert stats.goodput() == 0.0
    pc = stats.latency_percentiles("latency")
    assert pc["n"] == 0.0 and pc["ttft_p99"] == 0.0
    assert "goodput 0 tok/s" in stats.summary()


def test_latency_percentiles_empty_is_zero():
    pc = EngineStats().latency_percentiles()
    assert pc["n"] == 0.0
    assert all(v == 0.0 for v in pc.values())


def test_sweep_records_peak_rss():
    trace = SweepTrace.generate("poisson", 1000.0, n=200, seed=0)
    res = simulate(trace, SweepConfig(hosts=2))
    assert res.max_rss_mb > 0.0
    assert math.isfinite(res.max_rss_mb)
