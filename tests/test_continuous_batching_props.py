"""Property tests for continuous batching (hypothesis; skipped cleanly
when hypothesis is absent — the tier1-minimal-deps CI leg).

Over seeded Poisson/bursty workloads the continuous-batching engine must
hold three invariants regardless of chunk size, arrival pattern or seed:

  1. **no idle rows while queued** — the time-weighted batch occupancy
     measured over windows where the ready queue is non-empty is exactly
     1.0 (``q.batch.q_row_s == q.batch.q_cap_s``): iteration-level refill
     never lets a row sit empty while work is waiting;
  2. **chunked == unchunked tokens** — chunking reschedules *when* prefill
     flops run, never *which* tokens greedy decode emits;
  3. **clock identity with bubble_s** — every accounting class (including
     the new bubble class) still sums to the clock.
"""
import dataclasses

import jax
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (optional test dep)")
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.core.runtime import HarvestRuntime
from repro.core.tiers import H100_NVLINK
from repro.models import model as M
from repro.serving import HarvestServer, TenantSpec, Workload

CFG = dataclasses.replace(get_config("yi-6b").reduced(), num_layers=2)
PARAMS = M.init_params(jax.random.PRNGKey(0), CFG)


def _workload(arrival: str, seed: int) -> Workload:
    # open-loop rate far above service capacity: the ready queue is
    # non-empty for most of the run, which is exactly the regime the
    # occupancy invariant is about
    return Workload(
        num_requests=6, arrival=arrival, rate=1e6, seed=seed, vocab=(3, 250),
        tenants=(TenantSpec("t", weight=1, slo="batch",
                            prompt_len=(4, 18), max_new_tokens=3),))


def _serve(workload: Workload, chunk):
    srv = HarvestServer(
        CFG, PARAMS,
        runtime=HarvestRuntime({1: 64 * 2**20}, hardware=H100_NVLINK),
        max_batch=2, block_size=8, num_local_slots=16,
        scheduler="fcfs", mode="async", chunk_prefill_tokens=chunk)
    stats = srv.run(workload, max_steps=2000)
    tokens = [tuple(h.tokens) for h in srv.handles]
    return stats, tokens


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 10_000),
       arrival=st.sampled_from(["poisson", "bursty"]),
       chunk=st.sampled_from([3, 8, 17]))
def test_continuous_batching_invariants(seed, arrival, chunk):
    wl = _workload(arrival, seed)
    st_plain, tok_plain = _serve(wl, chunk=None)
    st_chunk, tok_chunk = _serve(wl, chunk=chunk)

    # (2) chunked and unchunked prefill emit bit-identical tokens
    assert tok_plain == tok_chunk

    for stats in (st_plain, st_chunk):
        # (3) the clock identity holds with the bubble_s class folded in
        assert stats.check_clock_identity()
        assert stats.bubble_s >= 0.0

        # (1) no batch row is ever idle while the ready queue is non-empty;
        # windows with a non-empty queue accumulate row_s == cap_s exactly,
        # so the ratio is float-exact at 1.0
        xfer = stats.metrics.get("transfer", {})
        assert xfer.get("q.batch.q_cap_s", 0.0) > 0.0
        assert xfer["q.batch.q_occupancy"] == 1.0
        assert xfer["q.batch.q_row_s"] == xfer["q.batch.q_cap_s"]
