"""Per-kernel shape/dtype sweeps against the pure-jnp oracles.

Every Pallas kernel runs in interpret mode on CPU (the kernel body executes
in Python) and must match its ``ref.py`` oracle to dtype-appropriate
tolerance across a sweep of shapes, dtypes, and masking variants.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import mha
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.harvest_copy.ops import (copy_blocks, dequantize_blocks,
                                            gather_blocks, quantize_blocks,
                                            scatter_blocks)
from repro.kernels.harvest_copy.ref import (dequantize_reload_ref,
                                            harvest_copy_ref,
                                            harvest_gather_ref,
                                            harvest_scatter_ref,
                                            quantize_demote_ref)
from repro.kernels.moe_ffn.ops import expert_ffn
from repro.kernels.moe_ffn.ref import moe_ffn_ref
from repro.kernels.paged_attention.ops import decode_attention
from repro.kernels.paged_attention.ref import paged_attention_ref

TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


def _tol(dtype):
    return TOL[jnp.bfloat16 if dtype == jnp.bfloat16 else jnp.float32]


# ---------------------------------------------------------------------------
# flash attention (prefill)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("b,sq,nq,nkv,hd", [
    (1, 128, 4, 4, 64),        # MHA, single q block
    (2, 256, 8, 2, 64),        # GQA 4:1, 2 q blocks
    (1, 384, 4, 1, 128),       # MQA, ragged block count
    (2, 128, 6, 3, 32),        # non-pow2 heads
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(b, sq, nq, nkv, hd, dtype):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(b, sq, nq, hd)), dtype)
    k = jnp.asarray(rng.normal(size=(b, sq, nkv, hd)), dtype)
    v = jnp.asarray(rng.normal(size=(b, sq, nkv, hd)), dtype)
    out = mha(q, k, v, interpret=True)

    gq = nq // nkv
    qf = q.reshape(b, sq, nkv, gq, hd).transpose(0, 2, 3, 1, 4)
    qf = qf.reshape(b * nkv, gq * sq, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(b * nkv, sq, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(b * nkv, sq, hd)
    ref = flash_attention_ref(qf, kf, vf, sq=sq)
    ref = ref.reshape(b, nkv, gq, sq, hd).transpose(0, 3, 1, 2, 4)
    ref = ref.reshape(b, sq, nq, hd)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("window,chunk", [(64, None), (None, 128), (32, None)])
def test_flash_attention_masks(window, chunk):
    b, sq, nq, nkv, hd = 1, 256, 4, 2, 64
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(b, sq, nq, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, sq, nkv, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, sq, nkv, hd)), jnp.float32)
    out = mha(q, k, v, sliding_window=window, attention_chunk=chunk,
              interpret=True)
    gq = nq // nkv
    qf = q.reshape(b, sq, nkv, gq, hd).transpose(0, 2, 3, 1, 4)
    qf = qf.reshape(b * nkv, gq * sq, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(b * nkv, sq, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(b * nkv, sq, hd)
    ref = flash_attention_ref(qf, kf, vf, sq=sq, sliding_window=window,
                              attention_chunk=chunk)
    ref = ref.reshape(b, nkv, gq, sq, hd).transpose(0, 3, 1, 2, 4)
    ref = ref.reshape(b, sq, nq, hd)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# paged decode attention
# ---------------------------------------------------------------------------


def _make_paged(rng, b, nq, nkv, hd, n_slots, bs, max_blk, dtype):
    q = jnp.asarray(rng.normal(size=(b, nq, hd)), dtype)
    pool_k = jnp.asarray(rng.normal(size=(n_slots, bs, nkv, hd)), dtype)
    pool_v = jnp.asarray(rng.normal(size=(n_slots, bs, nkv, hd)), dtype)
    # each request owns a run of blocks; some table entries are -1 (absent)
    table = np.full((b, max_blk), -1, np.int32)
    slot = 0
    q_pos = np.zeros((b,), np.int32)
    for r in range(b):
        nb = rng.integers(1, max_blk + 1)
        for j in range(nb):
            table[r, j] = slot
            slot += 1
        q_pos[r] = nb * bs - rng.integers(1, bs + 1)
    return q, pool_k, pool_v, jnp.asarray(table), jnp.asarray(q_pos)


@pytest.mark.parametrize("b,nq,nkv,hd,bs,max_blk", [
    (2, 4, 4, 64, 16, 3),
    (3, 8, 2, 64, 32, 4),
    (2, 4, 1, 128, 16, 2),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_attention_matches_ref(b, nq, nkv, hd, bs, max_blk, dtype):
    rng = np.random.default_rng(2)
    n_slots = b * max_blk + 2
    q, pk, pv, table, q_pos = _make_paged(rng, b, nq, nkv, hd, n_slots, bs,
                                          max_blk, dtype)
    out = decode_attention(q, pk, pv, table, q_pos, interpret=True)
    ref = paged_attention_ref(q, pk, pv, table, q_pos)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("window", [16, 48])
def test_paged_attention_sliding_window(window):
    rng = np.random.default_rng(3)
    b, nq, nkv, hd, bs, max_blk = 2, 4, 2, 64, 16, 4
    n_slots = b * max_blk + 1
    q, pk, pv, table, q_pos = _make_paged(rng, b, nq, nkv, hd, n_slots, bs,
                                          max_blk, jnp.float32)
    out = decode_attention(q, pk, pv, table, q_pos, sliding_window=window,
                           interpret=True)
    ref = paged_attention_ref(q, pk, pv, table, q_pos, sliding_window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# fused expert FFN
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("e,c,d,f", [
    (2, 128, 64, 256),
    (4, 256, 128, 512),
    (1, 128, 32, 128),
])
@pytest.mark.parametrize("activation", ["silu", "gelu", "relu2"])
def test_moe_ffn_matches_ref(e, c, d, f, activation):
    rng = np.random.default_rng(4)
    xd = jnp.asarray(rng.normal(size=(e, c, d)), jnp.float32)
    wi = jnp.asarray(rng.normal(size=(e, d, f)) * 0.05, jnp.float32)
    wg = jnp.asarray(rng.normal(size=(e, d, f)) * 0.05, jnp.float32)
    wo = jnp.asarray(rng.normal(size=(e, f, d)) * 0.05, jnp.float32)
    out = expert_ffn(xd, wi, wg, wo, activation=activation, interpret=True)
    ref = moe_ffn_ref(xd, wi, wg, wo, activation=activation)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("dtype", [jnp.bfloat16])
def test_moe_ffn_bf16(dtype):
    rng = np.random.default_rng(5)
    e, c, d, f = 2, 128, 64, 256
    xd = jnp.asarray(rng.normal(size=(e, c, d)), dtype)
    wi = jnp.asarray(rng.normal(size=(e, d, f)) * 0.05, dtype)
    wg = jnp.asarray(rng.normal(size=(e, d, f)) * 0.05, dtype)
    wo = jnp.asarray(rng.normal(size=(e, f, d)) * 0.05, dtype)
    out = expert_ffn(xd, wi, wg, wo, interpret=True)
    ref = moe_ffn_ref(xd, wi, wg, wo)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), rtol=4e-2,
                               atol=4e-2)


# ---------------------------------------------------------------------------
# harvest block copy (gather/scatter)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_slots,n_move,block_elems", [
    (16, 4, 2048),       # KV-block-sized payloads, flat layout
    (64, 64, 256),       # move the whole pool
    (8, 1, 128),         # single block
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_harvest_gather_scatter_roundtrip(n_slots, n_move, block_elems, dtype):
    rng = np.random.default_rng(6)
    src = jnp.asarray(rng.normal(size=(n_slots, block_elems)), dtype)
    dst = jnp.asarray(rng.normal(size=(n_slots, block_elems)), dtype)
    ids = jnp.asarray(rng.choice(n_slots, size=n_move, replace=False)
                      .astype(np.int32))

    got = gather_blocks(src, ids, interpret=True)
    ref = harvest_gather_ref(src, ids)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))

    new_dst = scatter_blocks(dst, got, ids)
    ref_dst = harvest_scatter_ref(dst, ref, ids)
    np.testing.assert_array_equal(np.asarray(new_dst), np.asarray(ref_dst))
    # round-trip: gathered-from-src blocks landed in dst at the same slots
    np.testing.assert_array_equal(np.asarray(new_dst[ids]),
                                  np.asarray(src[ids]))


@pytest.mark.parametrize("block_elems,chunk", [
    (1000, 512),     # non-divisible: 512 + 488 tail
    (130, 64),       # tiny ragged tail
    (7, 512),        # chunk larger than the block
    (96, 96),        # exactly one chunk
])
def test_harvest_gather_non_divisible_chunk(block_elems, chunk):
    """Regression: elems % chunk != 0 used to assert; the trailing chunk is
    now padded and the result sliced back — bit-exact with the oracle."""
    rng = np.random.default_rng(7)
    src = jnp.asarray(rng.normal(size=(12, block_elems)), jnp.float32)
    ids = jnp.asarray([4, 0, 11, 7], jnp.int32)
    got = gather_blocks(src, ids, chunk=chunk, interpret=True)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(harvest_gather_ref(src, ids)))


def test_harvest_scatter_rejects_out_of_range_ids():
    """Regression: mode="drop" silently discarded writes for bad slot ids —
    a reload landing nowhere is data loss, so they raise now."""
    rng = np.random.default_rng(8)
    dst = jnp.asarray(rng.normal(size=(8, 64)), jnp.float32)
    staging = jnp.asarray(rng.normal(size=(2, 64)), jnp.float32)
    with pytest.raises(IndexError, match="out of range"):
        scatter_blocks(dst, staging, jnp.asarray([3, 8], jnp.int32))
    with pytest.raises(IndexError, match="out of range"):
        scatter_blocks(dst, staging, jnp.asarray([-1, 2], jnp.int32))
    # in-range ids still scatter exactly
    ok = scatter_blocks(dst, staging, jnp.asarray([3, 5], jnp.int32))
    np.testing.assert_array_equal(
        np.asarray(ok),
        np.asarray(harvest_scatter_ref(dst, staging,
                                       jnp.asarray([3, 5], jnp.int32))))


def test_harvest_gather_rejects_out_of_range_ids():
    rng = np.random.default_rng(9)
    src = jnp.asarray(rng.normal(size=(8, 64)), jnp.float32)
    with pytest.raises(IndexError, match="out of range"):
        gather_blocks(src, jnp.asarray([0, 9], jnp.int32), interpret=True)


@pytest.mark.parametrize("n_src,n_dst,m,block_elems,chunk", [
    (16, 16, 4, 2048, 512),    # KV-block-sized payloads
    (8, 12, 3, 256, 64),       # pools of different slot counts
    (6, 6, 6, 1000, 512),      # whole pool, non-divisible chunk
    (4, 4, 1, 64, 512),        # single block, chunk > block
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_harvest_copy_fused_matches_ref(n_src, n_dst, m, block_elems, chunk,
                                        dtype):
    """The fused gather→scatter skips the staging buffer: copied blocks
    land bit-exact and every untouched destination slot is preserved."""
    rng = np.random.default_rng(10)
    src = jnp.asarray(rng.normal(size=(n_src, block_elems)), dtype)
    dst = jnp.asarray(rng.normal(size=(n_dst, block_elems)), dtype)
    sids = jnp.asarray(rng.choice(n_src, size=m, replace=False), jnp.int32)
    dids = jnp.asarray(rng.choice(n_dst, size=m, replace=False), jnp.int32)

    got = copy_blocks(src, dst, sids, dids, chunk=chunk, interpret=True)
    ref = harvest_copy_ref(src, dst, sids, dids)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
    # equivalent to the two-kernel staging path, without the staging buffer
    staged = scatter_blocks(dst, gather_blocks(src, sids, chunk=chunk,
                                               interpret=True), dids)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(staged))
    # untouched rows preserved
    untouched = np.setdiff1d(np.arange(n_dst), np.asarray(dids))
    np.testing.assert_array_equal(np.asarray(got[untouched]),
                                  np.asarray(dst[untouched]))


def test_harvest_copy_rejects_out_of_range_ids():
    rng = np.random.default_rng(11)
    src = jnp.asarray(rng.normal(size=(4, 64)), jnp.float32)
    dst = jnp.asarray(rng.normal(size=(4, 64)), jnp.float32)
    with pytest.raises(IndexError, match="out of range"):
        copy_blocks(src, dst, jnp.asarray([0, 4], jnp.int32),
                    jnp.asarray([0, 1], jnp.int32), interpret=True)
    with pytest.raises(IndexError, match="out of range"):
        copy_blocks(src, dst, jnp.asarray([0, 1], jnp.int32),
                    jnp.asarray([0, -2], jnp.int32), interpret=True)


# ---------------------------------------------------------------------------
# fused quantize-on-demote / dequantize-on-reload
# ---------------------------------------------------------------------------

#: round-trip error ceiling per wire fidelity, as a fraction of the
#: block's absmax (int8/int4: half a quantization step with headroom;
#: fp8 e4m3: 2^-3 relative mantissa step with headroom)
FID_ERR = {"int8": 1 / 127, "fp8": 0.07, "int4": 1 / 7}


@pytest.mark.parametrize("fidelity", ["int8", "fp8", "int4"])
@pytest.mark.parametrize("n_slots,m,block_elems", [
    (16, 4, 2048),       # KV-block-sized payloads
    (8, 8, 256),         # whole pool
    (6, 2, 129),         # odd element count (int4 packs a padded column)
    (4, 1, 2),           # minimal block
])
def test_quantize_demote_matches_ref(fidelity, n_slots, m, block_elems):
    rng = np.random.default_rng(12)
    src = jnp.asarray(rng.normal(size=(n_slots, block_elems)) * 3,
                      jnp.float32)
    ids = jnp.asarray(rng.choice(n_slots, size=m, replace=False), jnp.int32)
    values, scales = quantize_blocks(src, ids, fidelity=fidelity,
                                     interpret=True)
    ref_v, ref_s = quantize_demote_ref(src, ids, fidelity=fidelity)
    np.testing.assert_array_equal(np.asarray(values), np.asarray(ref_v))
    np.testing.assert_allclose(np.asarray(scales), np.asarray(ref_s),
                               rtol=1e-6)


@pytest.mark.parametrize("fidelity", ["int8", "fp8", "int4"])
@pytest.mark.parametrize("n_slots,m,block_elems", [
    (16, 4, 2048),
    (8, 8, 256),
    (6, 2, 129),         # odd width: reload slices the padded column off
])
def test_quantize_dequantize_roundtrip_bounded(fidelity, n_slots, m,
                                               block_elems):
    """Demote → reload must reconstruct every touched block within the
    documented per-fidelity error bound and leave untouched slots
    bit-exact (``input_output_aliases`` scatters in place)."""
    rng = np.random.default_rng(13)
    src = jnp.asarray(rng.normal(size=(n_slots, block_elems)) * 2,
                      jnp.float32)
    dst = jnp.asarray(rng.normal(size=(n_slots, block_elems)), jnp.float32)
    ids = jnp.asarray(rng.choice(n_slots, size=m, replace=False), jnp.int32)

    values, scales = quantize_blocks(src, ids, fidelity=fidelity,
                                     interpret=True)
    got = dequantize_blocks(dst, values, scales, ids, fidelity=fidelity,
                            interpret=True)
    ref = dequantize_reload_ref(dst, values, scales, ids, fidelity=fidelity)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-6, atol=1e-6)
    # per-block error bound relative to the block's absmax
    for row, sid in enumerate(np.asarray(ids)):
        orig = np.asarray(src[sid])
        absmax = np.abs(orig).max()
        err = np.abs(np.asarray(got[sid]) - orig).max()
        assert err <= FID_ERR[fidelity] * absmax + 1e-7, \
            f"{fidelity} row {row}: err {err} > bound"
    # untouched destination rows preserved bit-exact
    untouched = np.setdiff1d(np.arange(n_slots), np.asarray(ids))
    np.testing.assert_array_equal(np.asarray(got[untouched]),
                                  np.asarray(dst[untouched]))


def test_quantize_zero_block_roundtrips_exactly():
    """An all-zero block must survive (guarded scale, no 0/0)."""
    src = jnp.zeros((4, 64), jnp.float32)
    dst = jnp.asarray(np.random.default_rng(14).normal(size=(4, 64)),
                      jnp.float32)
    ids = jnp.asarray([1, 3], jnp.int32)
    for fidelity in ("int8", "fp8", "int4"):
        values, scales = quantize_blocks(src, ids, fidelity=fidelity,
                                         interpret=True)
        got = dequantize_blocks(dst, values, scales, ids, fidelity=fidelity,
                                interpret=True)
        np.testing.assert_array_equal(np.asarray(got[ids]),
                                      np.zeros((2, 64), np.float32))


def test_quantize_rejects_bad_inputs():
    src = jnp.asarray(np.zeros((4, 8)), jnp.float32)
    ids = jnp.asarray([0, 2], jnp.int32)
    with pytest.raises(ValueError, match="fidelity"):
        quantize_blocks(src, ids, fidelity="fp16", interpret=True)
    with pytest.raises(ValueError, match="2-D"):
        quantize_blocks(src.reshape(-1), ids, interpret=True)
    with pytest.raises(TypeError, match="floating"):
        quantize_blocks(src.astype(jnp.int32), ids, interpret=True)
    values, scales = quantize_blocks(src, ids, interpret=True)
    with pytest.raises(ValueError, match="shape"):
        dequantize_blocks(src, values[:1], scales, ids, interpret=True)


# ---------------------------------------------------------------------------
# hypothesis round-trip properties (skipped on the minimal-deps CI leg)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # minimal-deps environments run without it
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:

    @settings(max_examples=40, deadline=None)
    @given(fidelity=st.sampled_from(["int8", "fp8", "int4"]),
           n_slots=st.integers(1, 10),
           block_elems=st.integers(1, 300),   # odd widths hit the int4 pad
           scale_pow=st.integers(-8, 8),
           seed=st.integers(0, 2**31 - 1),
           data=st.data())
    def test_quantize_roundtrip_property(fidelity, n_slots, block_elems,
                                         scale_pow, seed, data):
        """For ANY pool shape (ragged tails included), slot subset, and
        magnitude: the round-trip error stays under the documented bound
        and untouched slots are preserved bit-exact — never an assert on
        non-divisible widths."""
        m = data.draw(st.integers(1, n_slots))
        rng = np.random.default_rng(seed)
        src = jnp.asarray(rng.normal(size=(n_slots, block_elems))
                          * 2.0 ** scale_pow, jnp.float32)
        dst = jnp.asarray(rng.normal(size=(n_slots, block_elems)),
                          jnp.float32)
        ids = jnp.asarray(rng.choice(n_slots, size=m, replace=False),
                          jnp.int32)
        values, scales = quantize_blocks(src, ids, fidelity=fidelity,
                                         interpret=True)
        got = dequantize_blocks(dst, values, scales, ids, fidelity=fidelity,
                                interpret=True)
        for sid in np.asarray(ids):
            orig = np.asarray(src[sid])
            absmax = float(np.abs(orig).max())
            err = float(np.abs(np.asarray(got[sid]) - orig).max())
            assert err <= FID_ERR[fidelity] * absmax + 1e-12
        untouched = np.setdiff1d(np.arange(n_slots), np.asarray(ids))
        np.testing.assert_array_equal(np.asarray(got[untouched]),
                                      np.asarray(dst[untouched]))

    @settings(max_examples=25, deadline=None)
    @given(fidelity=st.sampled_from(["int8", "fp8", "int4"]),
           block_elems=st.integers(1, 200),
           seed=st.integers(0, 2**31 - 1))
    def test_quantize_kernel_equals_ref_property(fidelity, block_elems,
                                                 seed):
        """The Pallas kernel and the jnp oracle agree bit-exact on packed
        values for any width, including int4's padded odd column."""
        rng = np.random.default_rng(seed)
        src = jnp.asarray(rng.normal(size=(6, block_elems)) * 4, jnp.float32)
        ids = jnp.asarray([5, 0, 3], jnp.int32)
        values, scales = quantize_blocks(src, ids, fidelity=fidelity,
                                         interpret=True)
        ref_v, ref_s = quantize_demote_ref(src, ids, fidelity=fidelity)
        np.testing.assert_array_equal(np.asarray(values), np.asarray(ref_v))
        np.testing.assert_allclose(np.asarray(scales), np.asarray(ref_s),
                                   rtol=1e-6)

else:

    @pytest.mark.skip(reason="property tests need the optional hypothesis "
                             "dep")
    def test_quantize_roundtrip_property():
        pass
