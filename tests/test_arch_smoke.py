"""Per-architecture smoke tests: reduced variant (2 layers, d_model<=512,
<=4 experts) runs one forward + one train step on CPU; asserts output shapes
and no NaNs.  Required by the assignment for every architecture."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED_ARCHS, PAPER_ARCHS, get_config
from repro.models import model as M
from repro.train.optim import adamw_init, train_step

B, S = 2, 32


def make_batch(cfg, rng):
    npre = cfg.modality.num_prefix_embeddings if cfg.modality else 0
    ncb = cfg.modality.num_codebooks if cfg.modality else 1
    shape = (B, S, ncb) if (cfg.family == "audio" and ncb > 1) else (B, S)
    batch = {
        "tokens": jax.random.randint(rng, shape, 0, cfg.vocab_size),
        "labels": jax.random.randint(rng, shape, 0, cfg.vocab_size),
        "positions": jnp.broadcast_to(jnp.arange(S + npre), (B, S + npre)),
    }
    if npre:
        batch["prefix_embeddings"] = 0.02 * jax.random.normal(
            rng, (B, npre, cfg.d_model))
    if cfg.rope_style == "mrope":
        batch["positions_3d"] = jnp.broadcast_to(
            jnp.arange(S + npre)[:, None], (B, S + npre, 3))
    return batch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS + PAPER_ARCHS)
def test_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    rng = jax.random.PRNGKey(0)
    params = M.init_params(rng, cfg)
    batch = make_batch(cfg, rng)

    logits, out = M.forward(params, batch, cfg)
    npre = cfg.modality.num_prefix_embeddings if cfg.modality else 0
    ncb = cfg.modality.num_codebooks if cfg.modality else 1
    exp = (B, S + npre, ncb, cfg.vocab_size) \
        if (cfg.family == "audio" and ncb > 1) else (B, S + npre, cfg.vocab_size)
    assert logits.shape == exp
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())

    opt = adamw_init(params)
    new_params, new_opt, metrics = train_step(params, opt, batch, cfg)
    assert not bool(jnp.isnan(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0
    # params actually moved
    moved = any(
        bool(jnp.any(a != b))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params)))
    assert moved


@pytest.mark.parametrize("arch", ["yi-6b", "zamba2-7b", "xlstm-1.3b"])
def test_two_steps_reduce_loss_direction(arch):
    """Two identical-batch steps: loss must drop (optimizer sanity)."""
    cfg = get_config(arch).reduced()
    rng = jax.random.PRNGKey(1)
    params = M.init_params(rng, cfg)
    batch = make_batch(cfg, rng)
    opt = adamw_init(params)
    losses = []
    for _ in range(3):
        params, opt, m = train_step(params, opt, batch, cfg, lr=1e-3)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]
