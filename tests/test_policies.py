"""Placement-policy rankers and allocator drain-ordering tests.

These run without hypothesis (the property suites skip when it is absent),
and cover the two previously-untested rankers — FairnessPolicy and
StabilityPolicy — plus the begin_io/end_io drain contract around
revocation.
"""
import pytest

from repro.core import (BestFitPolicy, FairnessPolicy, HarvestAllocator,
                        StabilityPolicy, WorstFitPolicy)
from repro.core.policy import PlacementRequest


# ---------------------------------------------------------------------------
# FairnessPolicy
# ---------------------------------------------------------------------------


def test_fairness_caps_per_client_and_releases_on_free():
    pol = FairnessPolicy(BestFitPolicy(), per_client_bytes=500)
    a = HarvestAllocator({0: 10_000}, policy=pol)
    h1 = a.harvest_alloc(400, client="tenant-a")
    assert h1 is not None
    assert a.harvest_alloc(200, client="tenant-a") is None, \
        "over-cap request must be refused"
    # another client is unaffected by tenant-a's usage
    assert a.harvest_alloc(400, client="tenant-b") is not None
    # releasing budget reopens capacity for the capped client
    a.harvest_free(h1)
    pol.on_free("tenant-a", 400)
    assert a.harvest_alloc(200, client="tenant-a") is not None


def test_fairness_rank_empty_when_over_cap():
    pol = FairnessPolicy(BestFitPolicy(), per_client_bytes=100)
    req = PlacementRequest(size=200, client="kv")
    assert pol.rank({0: {"largest_free": 10_000}}, req) == []


def test_fairness_wraps_inner_policy_order():
    pol = FairnessPolicy(WorstFitPolicy(), per_client_bytes=10_000)
    a = HarvestAllocator({0: 1000, 1: 500}, policy=pol)
    h = a.harvest_alloc(100, client="kv")
    assert h.device == 0, "worst-fit inner policy must pick the roomier device"


# ---------------------------------------------------------------------------
# StabilityPolicy
# ---------------------------------------------------------------------------


def test_stability_prefers_low_churn_device():
    a = HarvestAllocator({0: 1000, 1: 1000}, policy=StabilityPolicy())
    # device 0's budget thrashes; device 1 is quiet.  (update_budget feeds
    # the churn EWMA the policy ranks by.)
    for b in (500, 1000, 300, 1000, 400, 1000):
        a.update_budget(0, b)
    h = a.harvest_alloc(100)
    assert h.device == 1, "placement must avoid the churny device"


def test_stability_ties_break_best_fit():
    pol = StabilityPolicy()
    devices = {
        0: {"largest_free": 800, "churn": 0.0},
        1: {"largest_free": 300, "churn": 0.0},
    }
    order = pol.rank(devices, PlacementRequest(size=100))
    assert order == [1, 0], "equal churn falls back to tightest fit"


# ---------------------------------------------------------------------------
# allocator drain ordering (begin_io / end_io vs revocation)
# ---------------------------------------------------------------------------


def test_revocation_waits_for_drain_then_proceeds_newest_first():
    a = HarvestAllocator({0: 1000})
    h1 = a.harvest_alloc(300)
    h2 = a.harvest_alloc(300)
    h3 = a.harvest_alloc(300)
    a.begin_io(h1)                      # oldest allocation has in-flight DMA

    # newest-first revocation reaches h1 and must refuse to complete
    with pytest.raises(RuntimeError, match="in-flight"):
        a.update_budget(0, 0)
    # h3 and h2 (no IO) were revoked before the drain stopped at h1
    assert not a.is_live(h3) and not a.is_live(h2)
    assert a.is_live(h1), "a draining region must survive the pass"

    a.end_io(h1)                        # stream-sync completes
    revoked = a.update_budget(0, 0)
    assert [h.handle_id for h in revoked] == [h1.handle_id]
    assert not a.live_handles()


def test_nested_io_blocks_until_fully_drained():
    a = HarvestAllocator({0: 100})
    h = a.harvest_alloc(100)
    a.begin_io(h)
    a.begin_io(h)                       # two outstanding ops on the region
    a.end_io(h)
    with pytest.raises(RuntimeError):
        a.update_budget(0, 0)
    a.end_io(h)
    assert a.update_budget(0, 0)[0].handle_id == h.handle_id


def test_io_on_untouched_device_does_not_block_other_revocations():
    a = HarvestAllocator({0: 100, 1: 100})
    h0 = a.harvest_alloc(100)           # best-fit: both fit equally; pin by device
    h1 = a.harvest_alloc(100)
    busy, idle = (h0, h1) if h0.device == 0 else (h1, h0)
    a.begin_io(busy)
    # shrinking the OTHER device only touches the idle handle
    revoked = a.update_budget(idle.device, 0)
    assert [h.handle_id for h in revoked] == [idle.handle_id]
    a.end_io(busy)
