"""Property test: the vectorized sweep loop is bit-identical to the
scalar reference (hypothesis; skipped cleanly when hypothesis is absent
— the tier1-minimal-deps CI leg).

Over seeded Poisson/bursty workloads and randomized cluster geometry,
``simulate(trace, cfg, vectorized=True)`` must reproduce the scalar
loop's per-request admit/first-token/finish times, tokens, per-host
clocks and cluster clock EXACTLY — float equality, not approximate.
The vectorized loop advances the clock through the same sequence of
IEEE-754 adds; run-leaping batches the bookkeeping around those adds,
never the adds themselves.
"""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (optional test dep)")
from hypothesis import given, settings, strategies as st

from repro.serving import SweepConfig, SweepTrace, simulate


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000),
       process=st.sampled_from(["poisson", "bursty"]),
       family=st.sampled_from(["h100", "v5e"]),
       hosts=st.integers(1, 4),
       max_batch=st.integers(1, 8),
       refill=st.integers(1, 12),
       local_slots=st.integers(0, 48),
       disagg=st.booleans(),
       workers=st.integers(1, 4),
       rate=st.floats(50.0, 5e4))
def test_vectorized_loop_bit_identical(seed, process, family, hosts,
                                       max_batch, refill, local_slots,
                                       disagg, workers, rate):
    trace = SweepTrace.generate(process, rate=rate, n=160, seed=seed,
                                prompt_len=(4, 64), out_len=(1, 33))
    cfg = SweepConfig.from_family(
        family, hosts=hosts, max_batch=max_batch, refill_interval=refill,
        local_slots=local_slots, disaggregated=disagg,
        prefill_workers=workers)
    rs = simulate(trace, cfg, vectorized=False)
    rv = simulate(trace, cfg, vectorized=True)
    assert rs.clock_s == rv.clock_s
    np.testing.assert_array_equal(rs.host_clock_s, rv.host_clock_s)
    np.testing.assert_array_equal(rs.admit_t, rv.admit_t)
    np.testing.assert_array_equal(rs.first_token_t, rv.first_token_t)
    np.testing.assert_array_equal(rs.finish_t, rv.finish_t)
    np.testing.assert_array_equal(rs.tokens, rv.tokens)
    # both loops decoded the same token count per host
    for h in range(hosts):
        assert rs.metrics.get(f"h{h}.decoded", 0.0) \
            == rv.metrics.get(f"h{h}.decoded", 0.0)
