"""Request-lifecycle serving API (PR 5).

Covers the tentpole redesign:
  * the legacy ``submit``/``run`` compat wrapper stays bit-exact against
    the seed goldens (same tokens, same clock) — the lifecycle API is
    additive;
  * clock-driven admission: requests become visible at ``arrival_t`` on
    the engine clock, idle gaps are their own accounting class, and a
    Poisson workload decodes IDENTICAL tokens in sync and async modes
    with the async clock no worse;
  * per-request lifecycle records (queue wait, TTFT, TPOT, e2e) with
    p50/p99 and SLO-goodput aggregation in ``EngineStats.summary()``;
  * admission policies: headroom deferral, deadline shedding, priority
    ordering;
  * satellites: clock timestamps on ``Request`` (sync derives them from
    the step clock), divide-by-zero guards, submit validation, CFS
    preemption + resume accounting (re-prefill charged once, TTFT
    stable under later preemption), workload generator determinism.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import H100_NVLINK, HarvestRuntime
from repro.serving import (HarvestServer, KVHeadroomAdmission, RequestRecord,
                           ServeRequest, SLODeadlineAdmission, TenantSpec,
                           Workload)
from repro.serving.engine import EngineStats
from repro.serving.scheduler import Request
from repro.serving.workload import (bursty_arrivals, diurnal_arrivals,
                                    poisson_arrivals, sample_length,
                                    trace_arrivals)

MiB = 2**20

# fig7 regime (see test_pipeline): decode memory-bandwidth-bound so a
# decode window dwarfs a block transfer on H100 links
MEMORY_BOUND_HW = dataclasses.replace(H100_NVLINK, hbm_bw=5e10)

# --- golden: serving engine, yi-6b reduced 2L, 4 reqs x 12 tokens, fair
# scheduler, 10 local slots, peer budget 64 MiB (captured at the seed
# commit; test_runtime_equivalence asserts the engine path, this file
# asserts the HarvestServer compat path reproduces it too)
GOLDEN_OUTPUTS = [
    [380, 87, 109, 233, 267, 437, 437, 233, 241, 109, 241, 109],
    [250, 250, 437, 437, 437, 437, 437, 437, 25, 25, 57, 61],
    [501, 250, 250, 250, 312, 364, 364, 364, 364, 364, 364, 364],
    [437, 437, 437, 437, 216, 8, 216, 8, 216, 8, 216, 8],
]
GOLDEN_CLOCK_S = 0.0001582013302897278


@pytest.fixture(scope="module")
def served_model():
    import jax
    from repro.configs import get_config
    from repro.models import model as M
    cfg = dataclasses.replace(get_config("yi-6b").reduced(), num_layers=2)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _server(served_model, *, hardware=MEMORY_BOUND_HW, budget=64 * MiB,
            **kw):
    cfg, params = served_model
    runtime = HarvestRuntime({1: budget}, hardware=hardware)
    kw.setdefault("max_batch", 2)
    kw.setdefault("block_size", 8)
    kw.setdefault("num_local_slots", 10)
    kw.setdefault("scheduler", "fair")
    return HarvestServer(cfg, params, runtime=runtime, **kw)


def _mixed_workload(rate, seed=3, n=6, **tenant_kw):
    return Workload(
        num_requests=n, arrival="poisson", rate=rate, seed=seed,
        vocab=(3, 250),
        tenants=(TenantSpec("chat", weight=2, slo="latency", priority=1,
                            prompt_len=(6, 14), max_new_tokens=8,
                            **tenant_kw),
                 TenantSpec("bulk", weight=1, slo="batch",
                            prompt_len=(14, 30), max_new_tokens=10)))


# ---------------------------------------------------------------------------
# legacy compat: bit-exact against the seed goldens
# ---------------------------------------------------------------------------


def test_compat_wrapper_reproduces_seed_golden(served_model):
    """The PR 1 golden workload through the HarvestServer front door's
    compat wrapper: same tokens, same clock, to the last bit."""
    srv = _server(served_model, hardware=H100_NVLINK, scheduler="fair",
                  mode="sync")
    reqs = [srv.engine.submit([2 + i, 5, 7, 11, 13 + i], max_new_tokens=12)
            for i in range(4)]
    stats = srv.engine.run(max_steps=800)
    assert [r.output for r in reqs] == GOLDEN_OUTPUTS
    assert stats.clock_s == pytest.approx(GOLDEN_CLOCK_S, rel=1e-9)
    # the lifecycle machinery observed the legacy run without changing it
    assert stats.idle_s == 0.0 and stats.rejected == 0
    assert len(stats.requests) == 4
    assert all(rec.state == "done" for rec in stats.requests)


def test_lifecycle_submission_same_tokens_as_legacy(served_model):
    """Spreading the SAME prompts over clocked arrivals re-times the
    requests but never re-decodes them."""
    prompts = [[2 + i, 5, 7, 11, 13 + i] for i in range(4)]
    srv_legacy = _server(served_model)
    legacy = [srv_legacy.engine.submit(p, max_new_tokens=12)
              for p in prompts]
    srv_legacy.engine.run(max_steps=800)

    srv = _server(served_model)
    handles = [srv.submit(ServeRequest(p, max_new_tokens=12,
                                       arrival_t=i * 2e-3))
               for i, p in enumerate(prompts)]
    st = srv.run(max_steps=800)
    assert [h.tokens for h in handles] == [r.output for r in legacy]
    assert st.idle_s > 0.0          # the clock slept between arrivals
    st.check_clock_identity()


# ---------------------------------------------------------------------------
# clock-driven workloads: sync vs async
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("rate", [2e4, 2e5])
def test_poisson_workload_sync_async_token_exact(served_model, rate):
    def drive(mode):
        srv = _server(served_model, mode=mode)
        st = srv.run(_mixed_workload(rate), max_steps=2000)
        return [tuple(h.tokens) for h in srv.handles], st

    toks_sync, st_sync = drive("sync")
    toks_async, st_async = drive("async")
    assert toks_sync == toks_async, \
        "the clock mode changes WHEN bytes move, never what is decoded"
    assert st_async.clock_s <= st_sync.clock_s + 1e-15
    assert st_sync.check_clock_identity()
    assert st_async.check_clock_identity()
    # both modes agree on the arrival schedule (idle gaps included)
    assert st_async.idle_s == pytest.approx(st_sync.idle_s, rel=1e-6,
                                            abs=1e-12)


def test_arrivals_become_visible_on_the_clock(served_model):
    srv = _server(served_model)
    late = srv.submit(ServeRequest([9, 8, 7], max_new_tokens=4,
                                   arrival_t=1e-3))
    early = srv.submit(ServeRequest([1, 2, 3], max_new_tokens=4,
                                    arrival_t=1e-5))
    assert srv.engine.next_arrival_t() == pytest.approx(1e-5)
    st = srv.run(max_steps=400)
    # the late request could not have been admitted before its arrival
    assert late.admit_t >= 1e-3 - 1e-12
    assert early.admit_t < late.admit_t
    assert early.first_token_t < late.first_token_t <= late.finish_t
    assert st.idle_s > 0.0


def test_run_until_lands_exactly_and_keeps_future_work(served_model):
    srv = _server(served_model)
    h1 = srv.submit(ServeRequest([4, 5, 6], max_new_tokens=4,
                                 arrival_t=1e-5))
    h2 = srv.submit(ServeRequest([6, 5, 4], max_new_tokens=4,
                                 arrival_t=5.0))   # far future
    st = srv.run_until(1e-3)
    assert h1.finished and not h2.finished
    assert srv.now == pytest.approx(1e-3)
    assert st.check_clock_identity()
    # a later drive picks the queued arrival up
    srv.run_until(5.1)
    assert h2.finished
    assert srv.now == pytest.approx(5.1)


def test_streaming_callback_fires_per_token(served_model):
    streamed = []
    srv = _server(served_model)
    h = srv.submit(ServeRequest([5, 6, 7], max_new_tokens=5,
                                on_token=lambda tok, r:
                                streamed.append((tok, r.req_id))))
    srv.run(max_steps=400)
    assert [t for t, _ in streamed] == h.tokens
    assert all(rid == h.req_id for _, rid in streamed)


# ---------------------------------------------------------------------------
# per-request records + aggregation
# ---------------------------------------------------------------------------


def test_records_and_percentiles_in_summary(served_model):
    srv = _server(served_model)
    st = srv.run(_mixed_workload(2e5, ttft_slo_s=1e-2, e2e_slo_s=1e-1),
                 max_steps=2000)
    assert len(st.requests) == 6
    for rec in st.requests:
        assert rec.state == "done"
        assert rec.enqueue_t == pytest.approx(rec.arrival_t)
        assert rec.admit_t >= rec.arrival_t - 1e-12
        assert rec.first_token_t >= rec.admit_t - 1e-12
        assert rec.finish_t >= rec.first_token_t
        assert rec.queue_wait_s >= 0 and rec.ttft_s > 0
        assert rec.tpot_s >= 0 and rec.e2e_s >= rec.ttft_s
    lat = st.latency_percentiles("latency")
    assert lat["n"] > 0
    assert 0 < lat["ttft_p50"] <= lat["ttft_p99"]
    assert 0 <= lat["tpot_p50"] <= lat["tpot_p99"]
    # generous SLOs: everything good -> goodput equals class throughput
    assert st.slo_attainment("latency") == 1.0
    assert st.goodput() == pytest.approx(st.throughput())
    text = st.summary()
    assert "latency" in text and "batch" in text
    assert "ttft p50/p99" in text and "goodput" in text and "SLO" in text


def test_stats_guards_zero_runs():
    st = EngineStats()
    assert st.throughput() == 0.0
    assert st.goodput() == 0.0
    assert st.slo_attainment() == 0.0
    assert st.latency_percentiles()["ttft_p99"] == 0.0
    assert "0 tokens / 0 steps" in st.summary()   # must not raise
    st2 = EngineStats(tokens_out=5)               # tokens but zero clock
    assert st2.throughput() == 0.0
    rec = RequestRecord(req_id=0, slo="latency", tenant="t",
                        state="rejected", arrival_t=0.0, enqueue_t=0.0,
                        admit_t=None, first_token_t=None, finish_t=1.0,
                        prompt_tokens=3, output_tokens=0, preemptions=0)
    assert rec.queue_wait_s is None and rec.ttft_s is None
    assert rec.tpot_s is None and not rec.slo_ok


def test_submit_validation(served_model):
    srv = _server(served_model)
    with pytest.raises(ValueError, match="empty prompt"):
        srv.submit(ServeRequest([], max_new_tokens=4))
    with pytest.raises(ValueError, match="max_new_tokens"):
        srv.submit(ServeRequest([1, 2], max_new_tokens=0))
    with pytest.raises(ValueError, match="max_new_tokens"):
        srv.engine.submit([1, 2], -3)
    with pytest.raises(ValueError, match="SLO class"):
        srv.submit(ServeRequest([1, 2], max_new_tokens=4, slo="gold"))
    # arrivals in the engine's past are rejected once the clock moved
    srv.submit(ServeRequest([1, 2, 3], max_new_tokens=4))
    srv.run(max_steps=200)
    with pytest.raises(ValueError, match="past"):
        srv.submit(ServeRequest([1, 2], max_new_tokens=4, arrival_t=0.0))


# ---------------------------------------------------------------------------
# admission policies
# ---------------------------------------------------------------------------


def test_headroom_admission_defers_but_never_starves(served_model):
    srv = _server(served_model,
                  admission=KVHeadroomAdmission(headroom_frac=0.4))
    handles = [srv.submit(ServeRequest([2 + i, 5, 7, 11, 13 + i],
                                       max_new_tokens=6))
               for i in range(4)]
    st = srv.run(max_steps=800)
    assert all(h.state == "done" for h in handles)
    assert st.rejected == 0
    with pytest.raises(ValueError):
        KVHeadroomAdmission(headroom_frac=1.0)


def test_deadline_admission_sheds_hopeless_requests(served_model):
    srv = _server(served_model, admission=SLODeadlineAdmission())
    ok = srv.submit(ServeRequest([1, 2, 3], max_new_tokens=4,
                                 slo="latency", ttft_slo_s=1.0))
    # TTFT deadline far below even one prefill window: unservable
    hopeless = srv.submit(ServeRequest([4, 5, 6], max_new_tokens=4,
                                       slo="latency", ttft_slo_s=1e-12))
    st = srv.run(max_steps=400)
    assert ok.state == "done" and ok.ttft_s <= 1.0
    assert hopeless.rejected and hopeless.tokens == []
    assert st.rejected == 1
    rej = [r for r in st.requests if r.state == "rejected"]
    assert len(rej) == 1 and rej[0].output_tokens == 0
    assert not rej[0].slo_ok
    # shed requests drag attainment but never add goodput
    assert st.slo_attainment("latency") == 0.5
    assert st.goodput("latency") > 0


def test_deadline_admission_priority_order(served_model):
    """Latency-class priority jumps the queue ahead of earlier batch
    arrivals once both are visible."""
    srv = _server(served_model, admission=SLODeadlineAdmission(),
                  max_batch=1, scheduler="fcfs")
    lo = srv.submit(ServeRequest([7, 8, 9], max_new_tokens=6, slo="batch",
                                 priority=0))
    hi = srv.submit(ServeRequest([1, 2, 3], max_new_tokens=6,
                                 slo="latency", priority=5))
    srv.run(max_steps=600)
    assert hi.admit_t <= lo.admit_t
    assert hi.first_token_t < lo.first_token_t


# ---------------------------------------------------------------------------
# CFS preemption + resume accounting under clocked admission
# ---------------------------------------------------------------------------


def test_preemption_resume_keeps_ttft_and_charges_reprefill_once(
        served_model):
    """A preempted request's TTFT is pinned at its FIRST token; the
    normal resume path reloads (no re-prefill at all), and the lossy
    path re-prefills exactly once per rebuild."""
    cfg, params = served_model
    prefills = []
    srv = _server(served_model, mode="async")
    orig = srv.engine._prefill
    srv.engine._prefill = lambda r: (prefills.append(r.req_id), orig(r))[1]
    handles = [srv.submit(ServeRequest([2 + i, 5, 7, 11, 13 + i],
                                       max_new_tokens=12,
                                       arrival_t=i * 1e-6))
               for i in range(4)]
    st = srv.run(max_steps=800)
    assert st.preemptions > 0, "the workload must exercise CFS preemption"
    assert st.metrics["kv"]["evict_to_peer"] > 0
    assert st.recomputes == 0, "host-backed resume must not re-prefill"
    # one prefill per request, ever — resumes reloaded instead
    assert sorted(prefills) == sorted(h.req_id for h in handles)
    preempted = [r for r in st.requests if r.preemptions > 0]
    assert preempted, "records must carry the preemption count"
    for rec in preempted:
        # TTFT pinned at the first token, which happened BEFORE the
        # preemption (the victim had decoded past the CFS quantum)
        assert rec.first_token_t < rec.finish_t
        assert rec.ttft_s < rec.e2e_s
    st.check_clock_identity()


def test_lossy_revocation_reprefill_once_ttft_stable(served_model):
    """Lossy durability: a revoked prefix forces ONE re-prefill on
    resume and leaves the recorded TTFT untouched."""
    srv = _server(served_model, durability="lossy")
    eng = srv.engine
    handles = [srv.submit(ServeRequest([2 + i, 5, 7, 11, 13 + i],
                                       max_new_tokens=12))
               for i in range(4)]
    for _ in range(400):
        if eng.kv_mgr.stats["evict_to_peer"] > 0 or not eng.step():
            break
    assert eng.kv_mgr.stats["evict_to_peer"] > 0
    victim = next(r for r in eng.waiting if r.state == "preempted")
    ttft_before = victim.first_token_t
    assert ttft_before is not None
    n_out_before = len(victim.output)
    eng.allocator.update_budget(1, 0)          # crunch: peer blocks LOST
    st = srv.run(max_steps=800)
    assert st.recomputes > 0
    assert all(h.state == "done" for h in handles)
    assert victim.first_token_t == ttft_before, \
        "re-prefill must not re-timestamp the first token"
    assert len(victim.output) == 12 and n_out_before <= 12
    rec = next(r for r in st.requests if r.req_id == victim.req_id)
    assert rec.first_token_t == ttft_before
    st.check_clock_identity()


# ---------------------------------------------------------------------------
# workload generators
# ---------------------------------------------------------------------------


def test_workload_deterministic_and_sorted():
    wl = _mixed_workload(5e4, seed=11, n=32)
    a, b = wl.generate(), wl.generate()
    assert [r.prompt for r in a] == [r.prompt for r in b]
    assert [r.arrival_t for r in a] == [r.arrival_t for r in b]
    times = [r.arrival_t for r in a]
    assert times == sorted(times) and times[0] >= 0
    assert {r.tenant for r in a} == {"chat", "bulk"}
    assert all(r.slo in ("latency", "batch") for r in a)
    # rate changes re-time but never re-draw the prompts
    c = dataclasses.replace(wl, rate=5e5).generate()
    assert [r.prompt for r in c] == [r.prompt for r in a]
    assert max(r.arrival_t for r in c) < max(times)


def test_arrival_processes_shapes():
    rng = np.random.default_rng(0)
    p = poisson_arrivals(rng, 100.0, 500)
    assert len(p) == 500 and np.all(np.diff(p) > 0)
    assert np.mean(np.diff(p)) == pytest.approx(1e-2, rel=0.2)
    b = bursty_arrivals(np.random.default_rng(0), 100.0, 400, burst=8,
                        duty=0.2)
    assert len(b) == 400 and np.all(np.diff(b) > 0)
    # bursty: highly variable inter-arrivals (CV well above Poisson's ~1)
    gaps = np.diff(b)
    assert np.std(gaps) / np.mean(gaps) > 1.2
    d = diurnal_arrivals(np.random.default_rng(0), 100.0, 400,
                         peak_ratio=4.0)
    assert len(d) == 400 and np.all(np.diff(d) > 0)
    t = trace_arrivals([0.0, 0.5, 0.5, 2.0])
    assert list(t) == [0.0, 0.5, 0.5, 2.0]
    with pytest.raises(ValueError):
        trace_arrivals([1.0, 0.5])
    with pytest.raises(ValueError):
        poisson_arrivals(rng, 0.0, 4)
    with pytest.raises(ValueError):
        bursty_arrivals(rng, 10.0, 4, duty=0.0)


def test_workload_validation():
    with pytest.raises(ValueError):
        Workload(num_requests=0)
    with pytest.raises(ValueError):
        Workload(arrival="weibull")
    with pytest.raises(ValueError):
        TenantSpec("t", weight=0.0)
    with pytest.raises(ValueError):
        TenantSpec("t", slo="platinum")
    rng = np.random.default_rng(0)
    assert sample_length(rng, 7) == 7
    assert 3 <= sample_length(rng, (3, 9)) < 9
    ln = sample_length(rng, {"lognormal": (2.0, 0.5), "lo": 2, "hi": 64})
    assert 2 <= ln <= 64
    with pytest.raises(ValueError):
        sample_length(rng, (9, 3))
    with pytest.raises(ValueError):
        sample_length(rng, 0)
    with pytest.raises(ValueError):
        Workload(arrival="trace", num_requests=3,
                 arrival_kwargs={"times": [0.0]}).generate()


def test_request_timestamp_fields_vs_step_index(served_model):
    """The satellite: ``enqueue_step`` stays a step index, the ``*_t``
    fields are clock seconds — no more conflation."""
    srv = _server(served_model)
    srv.submit(ServeRequest([1, 2, 3], max_new_tokens=4))
    srv.run(max_steps=200)
    h2 = srv.submit(ServeRequest([3, 2, 1], max_new_tokens=4))
    assert h2._req.enqueue_step == srv.stats.steps      # a step COUNT
    assert h2._req.enqueue_t == pytest.approx(srv.now)  # clock seconds
    srv.run(max_steps=200)
    rec = srv.stats.requests[-1]
    assert rec.enqueue_t > 0.0
