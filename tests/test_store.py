"""Unit tests for the HarvestStore tiered-object layer.

Covers the pieces the tentpole refactor introduced: the explicit LOST
residency state (vs the old filled==0 sentinel), durability semantics
under revocation, the promote/demote/pin primitives, the TransferEngine's
batched/overlap scheduling, and the unified MetricsRegistry.
"""
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (Durability, HarvestAllocator, HarvestRuntime,
                        KVOffloadManager, LostObjectError, MetricsRegistry,
                        Residency, Tier, TransferEngine)
from repro.core.tiers import TPU_V5E

MiB = 2**20


def _kv(durability, slots=2, budget_mib=64):
    cfg = get_config("yi-6b").reduced()
    alloc = HarvestAllocator({0: budget_mib * MiB})
    kv = KVOffloadManager(cfg, alloc, TPU_V5E, block_size=16,
                          num_local_slots=slots, durability=durability)
    return kv, alloc


# ---------------------------------------------------------------------------
# explicit LOST state
# ---------------------------------------------------------------------------


def test_fresh_block_is_not_lost():
    """The old sentinel (tier=HOST, filled=0, no host copy) could mistake a
    freshly evicted-but-unfilled block for a dropped one; the explicit
    LOST state cannot."""
    kv, alloc = _kv("lossy", slots=1)
    kv.allocate_block(0, 0, 0)          # filled stays 0 — not yet written
    kv.allocate_block(1, 0, 0)          # evicts (0,0) to peer
    ent = kv.table[(0, 0)]
    assert ent.state is Residency.PEER and ent.filled == 0
    assert not kv.is_lost(0, 0), \
        "an unfilled but live peer block must not read as lost"


def test_lossy_revocation_is_explicit_lost():
    kv, alloc = _kv("lossy", slots=1)
    kv.allocate_block(0, 0, 0)
    kv.write_payload(0, 0, np.ones((2, 2)))
    kv.allocate_block(1, 0, 0)          # evicts (0,0) to peer
    assert kv.table[(0, 0)].state is Residency.PEER
    alloc.update_budget(0, 0)           # revoke everything
    assert kv.is_lost(0, 0)
    assert kv.table[(0, 0)].state is Residency.LOST
    assert kv.table[(0, 0)].tier is None, "a lost block is in NO tier"
    assert kv.stats["recomputes"] == 1
    # touching a lost object is a programming error, not a silent reload
    with pytest.raises(LostObjectError):
        kv.ensure_resident(0, 0)
    # the lost block stays tracked (the client decides how to rebuild)
    assert kv.tier_counts()["lost"] == 1
    kv.free_request(0)
    assert kv.tier_counts()["lost"] == 0


def test_backed_revocation_falls_back_to_host():
    kv, alloc = _kv("host_backed", slots=1)
    kv.allocate_block(0, 0, 0)
    kv.allocate_block(1, 0, 0)          # evicts (0,0) to peer + host copy
    alloc.update_budget(0, 0)
    ent = kv.table[(0, 0)]
    assert ent.state is Residency.HOST and not kv.is_lost(0, 0)
    # and it reloads over the host link
    kv.free_request(1)
    ops = kv.ensure_resident(0, 0)
    assert kv.stats["reload_host"] == 1
    assert ops[-1].src == Tier.HOST_DRAM and ops[-1].seconds > 0


def test_lossy_block_evicted_to_host_survives_revocation():
    """Host evictions write through, so even a lossy block that ONCE hit
    host DRAM keeps that copy and survives a later peer revocation."""
    kv, alloc = _kv("lossy", slots=1, budget_mib=0)
    kv.allocate_block(0, 0, 0)
    kv.allocate_block(1, 0, 0)          # no peer budget -> host eviction
    assert kv.table[(0, 0)].state is Residency.HOST
    assert kv.table[(0, 0)].host_copy
    kv.free_request(1)
    kv.ensure_resident(0, 0)            # back to local
    alloc.update_budget(0, 64 * MiB)    # now peer capacity appears
    kv.allocate_block(1, 0, 0)          # evicts (0,0) to peer this time
    alloc.update_budget(0, 0)           # revoke
    assert kv.table[(0, 0)].state is Residency.HOST, \
        "a block with a host copy falls back instead of getting lost"


# ---------------------------------------------------------------------------
# store primitives via the runtime seam
# ---------------------------------------------------------------------------


def test_new_object_class_plugs_into_the_seam():
    """A brand-new cacheable class (here: LoRA adapters) gets residency,
    revocation and accounting without any new client code."""
    rt = HarvestRuntime({0: 8 * MiB})
    store = rt.create_store("lora", object_nbytes=1 * MiB)
    for i in range(4):
        store.register(("a", i), state=Residency.HOST,
                       durability=Durability.RECONSTRUCTIBLE)
        store.touch_hotness(("a", i), float(i), alpha=0.0)

    # hotness-ranked promotion: hottest first
    order = [k for k, _ in store.hottest(Residency.HOST)]
    assert order[0] == ("a", 3)
    assert all(store.promote_to_peer(k) for k in order)
    assert store.tier_counts()["peer"] == 4
    assert rt.allocator.stats["allocs"] == 4

    # demote is voluntary and frees the peer segment
    store.demote(("a", 0))
    assert store.table[("a", 0)].state is Residency.HOST
    assert rt.allocator.stats["frees"] == 1

    # revocation: reconstructible objects promoted off-host are LOST
    rt.allocator.update_budget(0, 0)
    assert store.tier_counts()["lost"] == 3
    assert store.stats["revocations"] == 3


def test_pinned_entries_are_never_evicted():
    rt = HarvestRuntime({0: 64 * MiB})
    cfg = get_config("yi-6b").reduced()
    kv = rt.kv_manager(cfg, block_size=16, num_local_slots=2)
    kv.allocate_block(7, 0, 0)
    kv.store.pin((7, 0))
    kv.allocate_block(8, 0, 0)
    kv.store.pin((8, 0))
    with pytest.raises(RuntimeError):
        kv.allocate_block(9, 0, 0)   # both slots pinned: nothing evictable


# ---------------------------------------------------------------------------
# TransferEngine
# ---------------------------------------------------------------------------


def test_transfer_engine_matches_hardware_model():
    te = TransferEngine(TPU_V5E)
    t = te.transfer("x", 4 * MiB, Tier.HOST_DRAM, Tier.LOCAL_HBM)
    assert t.seconds == pytest.approx(
        TPU_V5E.transfer_time(4 * MiB, Tier.HOST_DRAM, Tier.LOCAL_HBM))
    t2 = te.transfer("y", 4 * MiB, Tier.PEER_HBM, Tier.LOCAL_HBM,
                     extra_latency=1e-3)
    assert t2.seconds == pytest.approx(
        TPU_V5E.transfer_time(4 * MiB, Tier.PEER_HBM, Tier.LOCAL_HBM) + 1e-3)


def test_transfer_engine_schedule_serial_vs_overlap():
    te = TransferEngine(TPU_V5E)
    ops = [te.transfer(i, 8 * MiB, Tier.PEER_HBM, Tier.LOCAL_HBM)
           for i in range(3)]
    ops += [te.transfer(9, 8 * MiB, Tier.HOST_DRAM, Tier.LOCAL_HBM)]
    serial = te.schedule(ops)
    assert serial == pytest.approx(sum(o.seconds for o in ops))
    # link-aware: the host copy overlaps the peer batch — wall time is the
    # busier link, strictly less than the serial sum
    overlapped = te.schedule(ops, overlap_links=True)
    peer_s = sum(o.seconds for o in ops[:3])
    host_s = ops[3].seconds
    assert overlapped == pytest.approx(max(peer_s, host_s))
    assert overlapped < serial
    # CGOPipe-style compute overlap
    assert te.overlap(1.0, 0.25) == 1.0
    assert te.overlap(1.0, 0.25, enabled=False) == 1.25


def test_transfer_metrics_accumulate_per_link():
    reg = MetricsRegistry()
    te = TransferEngine(TPU_V5E, metrics=reg)
    te.transfer("x", 2 * MiB, Tier.LOCAL_HBM, Tier.PEER_HBM, client="kv")
    te.transfer("y", 2 * MiB, Tier.LOCAL_HBM, Tier.HOST_DRAM, client="kv")
    snap = reg.snapshot()["transfer"]
    assert snap["kv.peer_n"] == 1 and snap["kv.host_n"] == 1
    assert snap["kv.peer_bytes"] == 2 * MiB


# ---------------------------------------------------------------------------
# unified metrics registry
# ---------------------------------------------------------------------------


def test_runtime_metrics_are_one_registry():
    """Allocator, every client store and the transfer engine all land in
    the runtime's single registry — no more parallel ad-hoc stats dicts."""
    rt = HarvestRuntime({0: 64 * MiB, 1: 64 * MiB})
    cfg = get_config("yi-6b").reduced()
    kv = rt.kv_manager(cfg, block_size=16, num_local_slots=1)
    moe = rt.rebalancer(get_config("qwen2-moe"), local_fraction=0.5)

    kv.allocate_block(0, 0, 0)
    kv.allocate_block(0, 1, 16)     # forces an eviction -> a transfer
    moe.rebalance(max_migrations=2)

    snap = rt.stats()
    assert {"allocator", "kv", "moe", "transfer"} <= set(snap)
    assert snap["kv"]["evict_to_peer"] == 1
    assert snap["moe"]["migrations"] == 2
    assert snap["allocator"]["allocs"] == 3
    # the client-facing stats views ARE the registry namespaces
    assert kv.stats is rt.metrics.counters("kv")
    assert moe.stats is rt.metrics.counters("moe")
    assert rt.tier_counts()["moe"]["peer"] == 2
