"""Config system: exact assigned specs, param counts, reduced variants."""
import pytest

from repro.configs import (ASSIGNED_ARCHS, INPUT_SHAPES, PAPER_ARCHS,
                           all_configs, dryrun_pairs, get_config)

EXPECTED = {
    # arch: (layers, d_model, heads, kv, d_ff, vocab)
    "qwen2-vl-72b": (80, 8192, 64, 8, 29568, 152064),
    "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048),
    "zamba2-7b": (81, 3584, 32, 32, 14336, 32000),
    "command-r-35b": (40, 8192, 64, 8, 22528, 256000),
    "xlstm-1.3b": (48, 2048, 4, 4, 0, 50304),
    "nemotron-4-15b": (32, 6144, 48, 8, 24576, 256000),
    "h2o-danube-3-4b": (24, 3840, 32, 8, 10240, 32000),
    "yi-6b": (32, 4096, 32, 4, 11008, 64000),
    "musicgen-medium": (48, 1536, 24, 24, 6144, 2048),
    "dbrx-132b": (40, 6144, 48, 8, 10752, 100352),
}

# total-parameter sanity bands (billions)
PARAM_BANDS = {
    "qwen2-vl-72b": (65, 80), "llama4-maverick-400b-a17b": (360, 440),
    "zamba2-7b": (5.5, 8), "command-r-35b": (28, 38), "xlstm-1.3b": (1.0, 2.2),
    "nemotron-4-15b": (14, 17), "h2o-danube-3-4b": (3.3, 4.6),
    "yi-6b": (5.4, 7), "musicgen-medium": (1.0, 2.0), "dbrx-132b": (120, 140),
}


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_exact_assigned_spec(arch):
    cfg = get_config(arch)
    exp = EXPECTED[arch]
    assert (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
            cfg.d_ff, cfg.vocab_size) == exp


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_param_count_band(arch):
    total = get_config(arch).param_counts()["total"] / 1e9
    lo, hi = PARAM_BANDS[arch]
    assert lo <= total <= hi, total


def test_moe_specifics():
    l4 = get_config("llama4-maverick-400b-a17b")
    assert l4.moe.num_experts == 128 and l4.moe.top_k == 1
    assert l4.moe.layer_period == 2
    dbrx = get_config("dbrx-132b")
    assert dbrx.moe.num_experts == 16 and dbrx.moe.top_k == 4
    assert get_config("zamba2-7b").ssm.state_dim == 64


def test_paper_table1_active_params():
    # paper Table 1 active-parameter column
    for arch, active in [("mixtral-8x7b", 13.0), ("qwen2-moe", 2.7),
                         ("phi-3.5-moe", 6.6)]:
        got = get_config(arch).param_counts()["active"] / 1e9
        assert abs(got - active) / active < 0.15, (arch, got)


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS + PAPER_ARCHS)
def test_reduced_constraints(arch):
    r = get_config(arch).reduced()
    assert r.num_layers == 2
    assert r.d_model <= 512
    if r.moe:
        assert r.moe.num_experts <= 4
    assert r.num_heads % r.num_kv_heads == 0


def test_dryrun_pairs_skips():
    pairs = dryrun_pairs()
    assert ("yi-6b", "long_500k") not in pairs          # full attention
    assert ("zamba2-7b", "long_500k") in pairs          # hybrid: O(1) state
    assert ("h2o-danube-3-4b", "long_500k") in pairs    # SWA
    assert ("llama4-maverick-400b-a17b", "long_500k") in pairs  # chunked
    assert len(pairs) == 34
