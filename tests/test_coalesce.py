"""Transfer coalescing + chunked multi-lane striping (PR 4).

Covers the TransferPlanner tentpole:
  * coalescing invariants — property tests (hypothesis): a coalesced
    batch's lane time is <= the sum of its singles and >= its largest
    member, per-member completion order and byte conservation hold;
  * striping semantics — chunk-granular completion, prefix waits, sub-lane
    routing, same-key chaining through a striped reload;
  * reload-plan dedup satellite — repeated keys submit once and a block
    already on the wire attaches its in-flight transfer;
  * end-to-end — async+coalesce produces bit-identical tokens to async
    per-object with a clock no worse (strictly better on the reload-heavy
    workload), and the planner refuses to run on the sync compat path.

The unit tests always run; the ``@given`` property tests skip
individually when the optional ``hypothesis`` dep is absent.
"""
import dataclasses

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:             # minimal-deps env: skip ONLY property tests
    def given(*_a, **_k):
        return lambda f: pytest.mark.skip(
            "property tests need the optional hypothesis dep")(f)

    def settings(*_a, **_k):
        return lambda f: f

    class _StubStrategies:
        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _StubStrategies()

from repro.core import (CoalesceConfig, HarvestRuntime, Tier, TransferEngine,
                        TransferPlanner)
from repro.core.tiers import H100_NVLINK, TPU_V5E, tpu_v5e_torus

MiB = 2**20
KiB = 2**10


# ---------------------------------------------------------------------------
# coalescing invariants
# ---------------------------------------------------------------------------


def _mint(te, sizes, src=Tier.PEER_HBM, dst=Tier.LOCAL_HBM):
    return [te.transfer(("b", i), nb, src, dst) for i, nb in enumerate(sizes)]


@settings(max_examples=60, deadline=None)
@given(st.lists(st.integers(1, 32 * MiB), min_size=1, max_size=24))
def test_coalesced_batch_time_bounds(sizes):
    """Batch lane time <= sum of singles, >= the largest single member, and
    every member's ready_t is its cumulative byte boundary (per-object
    completion inside the batch)."""
    te = TransferEngine(TPU_V5E)
    pl = TransferPlanner(te, CoalesceConfig(max_batch=len(sizes)))
    ops = _mint(te, sizes)
    singles = [t.seconds for t in ops]
    done, eff = pl.submit(ops)
    makespan = max(t.ready_t for t in done) - te.now
    assert makespan <= sum(singles) + 1e-15
    assert makespan >= max(singles) - 1e-15
    assert eff == pytest.approx(makespan)
    # non-decreasing per-member completion at cumulative boundaries
    ready = sorted(t.ready_t for t in done)
    acc = te.now
    for t in done:
        acc += t.lane_s
    assert acc == pytest.approx(max(ready))
    # bytes conserved through scheduling
    assert sum(t.nbytes for t in done) == sum(sizes)


@settings(max_examples=40, deadline=None)
@given(st.lists(st.integers(1, 8 * MiB), min_size=2, max_size=16),
       st.integers(2, 6))
def test_coalesce_max_batch_cap(sizes, cap):
    te = TransferEngine(H100_NVLINK)
    pl = TransferPlanner(te, CoalesceConfig(max_batch=cap))
    done, _eff = pl.submit(_mint(te, sizes))
    # no batch exceeds the cap
    by_batch = {}
    for t in done:
        if t.batch_id:
            by_batch.setdefault(t.batch_id, []).append(t)
    assert all(len(m) <= cap for m in by_batch.values())
    # batching saves exactly the members' setup latencies beyond the first
    lat = H100_NVLINK.peer_link.latency
    saved = sum(t.seconds - t.lane_s for t in done)
    extra = sum(len(m) - 1 for m in by_batch.values())
    assert saved == pytest.approx(extra * lat)


def test_coalesce_one_setup_per_lane():
    """8 small same-lane transfers: coalesced makespan is one setup plus
    summed bytes — the simulated analogue of one batched harvest_gather."""
    te = TransferEngine(H100_NVLINK)
    pl = TransferPlanner(te, CoalesceConfig(max_batch=16))
    ops = _mint(te, [64 * KiB] * 8)
    done, _ = pl.submit(ops)
    link = H100_NVLINK.peer_link
    expect = link.latency + 8 * (64 * KiB) / link.bandwidth
    assert max(t.ready_t for t in done) == pytest.approx(expect)
    q = te.metrics.snapshot()["transfer"]
    assert q["q.peer_in.coalesced"] == 1
    assert q["q.peer_in.coalesced_members"] == 8
    assert q["q.peer_in.coalesced_saved_s"] == pytest.approx(7 * link.latency)


def test_coalesce_refuses_mixed_fidelity_batch():
    """Regression (fidelity tiers): one coalesced submission models ONE
    fused gather kernel call packing ONE wire dtype, so transfers of
    different fidelity on the same lane must split into separate
    fidelity-homogeneous batches instead of merging."""
    from repro.core.tiers import Fidelity
    te = TransferEngine(H100_NVLINK)
    pl = TransferPlanner(te, CoalesceConfig(max_batch=16))
    ops = [te.transfer(("fp", i), 64 * KiB, Tier.PEER_HBM, Tier.LOCAL_HBM)
           for i in range(3)]
    ops += [te.transfer(("q", i), 64 * KiB, Tier.PEER_HBM, Tier.LOCAL_HBM,
                        fidelity=Fidelity.INT8) for i in range(3)]
    done, _eff = pl.submit(ops)
    by_batch = {}
    for t in done:
        assert t.batch_id, "same-lane groups of 3 must still coalesce"
        by_batch.setdefault(t.batch_id, []).append(t)
    assert len(by_batch) == 2, "mixed fidelities must split the lane batch"
    for members in by_batch.values():
        fids = {t.fidelity for t in members}
        assert len(fids) == 1, f"fidelity-mixed batch: {fids}"
        assert len(members) == 3
    # direct engine-level submission refuses the merge too: the mixed
    # member rides solo rather than silently joining the batch
    te2 = TransferEngine(H100_NVLINK)
    mixed = [te2.transfer(("m", 0), 64 * KiB, Tier.PEER_HBM, Tier.LOCAL_HBM),
             te2.transfer(("m", 1), 64 * KiB, Tier.PEER_HBM, Tier.LOCAL_HBM),
             te2.transfer(("m", 2), 64 * KiB, Tier.PEER_HBM, Tier.LOCAL_HBM,
                          fidelity=Fidelity.INT4)]
    done2 = te2.submit_coalesced(mixed)
    batched = [t for t in done2 if t.batch_id]
    assert all(t.fidelity is Fidelity.FP16 for t in batched)
    assert not done2[2].batch_id, "the int4 member must go solo"


def test_coalesce_respects_same_key_dependency():
    """A member whose object has an in-flight write-back cannot ride the
    batch — it chains behind its dependency on the solo path."""
    te = TransferEngine(TPU_V5E)
    pl = TransferPlanner(te, CoalesceConfig())
    wb = te.submit(te.transfer("hot", 4 * MiB, Tier.LOCAL_HBM,
                               Tier.PEER_HBM))
    ops = [te.transfer("hot", 4 * MiB, Tier.PEER_HBM, Tier.LOCAL_HBM),
           te.transfer("cold", 4 * MiB, Tier.PEER_HBM, Tier.LOCAL_HBM)]
    done, _ = pl.submit(ops)
    dep = next(t for t in done if t.key == "hot")
    free = next(t for t in done if t.key == "cold")
    assert dep.ready_t >= wb.ready_t + dep.seconds - 1e-15
    assert free.ready_t == pytest.approx(free.seconds)   # rode the open lane
    assert dep.batch_id == 0, "dependency-blocked members must not batch"


def test_coalesce_disabled_is_per_object():
    te = TransferEngine(TPU_V5E)
    pl = TransferPlanner(te, CoalesceConfig(enabled=False))
    ops = _mint(te, [MiB] * 4)
    done, eff = pl.submit(ops)
    assert all(t.batch_id == 0 for t in done)
    assert eff == pytest.approx(sum(t.seconds for t in done))
    assert max(t.ready_t for t in done) == pytest.approx(eff)


# ---------------------------------------------------------------------------
# chunked striping
# ---------------------------------------------------------------------------


def _striped(ways=4, chunk=1 * MiB, nbytes=8 * MiB + 321):
    topo = tpu_v5e_torus((2, 2))
    te = TransferEngine(None, topology=topo)
    pl = TransferPlanner(te, CoalesceConfig(
        stripe_ways=ways, chunk_nbytes=chunk, min_stripe_nbytes=2 * MiB))
    op = te.transfer("expert", nbytes, Tier.PEER_HBM, Tier.LOCAL_HBM,
                     device=1)
    return te, pl, op, pl.prepare([op])


def test_stripe_chunks_conserve_bytes_and_route_sublanes():
    te, _pl, op, chunks = _striped()
    assert len(chunks) == 9                       # 8 full + short tail
    assert sum(c.nbytes for c in chunks) == op.nbytes
    assert chunks[-1].nbytes == op.nbytes - 8 * MiB
    lanes = {c.lane for c in chunks}
    assert lanes == {f"peer_in.s{k}" for k in range(4)}
    assert all(c.parent == op.key for c in chunks)
    offsets = [c.offset for c in chunks]
    assert offsets == sorted(offsets) and offsets[0] == 0


def test_stripe_small_objects_pass_through():
    te, pl, _op, _ = _striped()
    small = te.transfer("kvblk", 64 * KiB, Tier.PEER_HBM, Tier.LOCAL_HBM,
                        device=1)
    assert pl.prepare([small]) == [small]


def test_stripe_prefix_wait_returns_early():
    te, pl, op, chunks = _striped()
    done, _ = pl.submit(chunks)
    t_half = te.wait_for(done, prefix_nbytes=op.nbytes // 2)
    t_full = max(c.ready_t for c in done)
    assert t_half < t_full
    te.wait_for(done)
    assert te.now == pytest.approx(t_full)


def test_coalesce_config_rejects_degenerate_knobs():
    """Regression: chunk_nbytes=0 (e.g. --stripe-chunk-kb 0) used to spin
    split() forever appending zero-byte chunks."""
    with pytest.raises(ValueError, match="zero-byte"):
        CoalesceConfig(chunk_nbytes=0)
    with pytest.raises(ValueError, match="zero-byte"):
        CoalesceConfig(min_stripe_nbytes=0)
    with pytest.raises(ValueError, match="max_batch"):
        CoalesceConfig(max_batch=1)
    with pytest.raises(ValueError, match="stripe_ways"):
        CoalesceConfig(stripe_ways=-1)


def test_stripe_writeback_and_reload_never_merge():
    """Regression: a striped write-back and a striped reload of the SAME
    object submitted in one plan must stay two ordered stripes — the
    reload's first chunk starts only after the write-back's last chunk —
    not merge into one concurrent stripe that reads before the write."""
    te, pl, _op, _ = _striped()
    out_op = te.transfer("dual", 8 * MiB, Tier.LOCAL_HBM, Tier.PEER_HBM,
                         device=1)
    in_op = te.transfer("dual", 8 * MiB, Tier.PEER_HBM, Tier.LOCAL_HBM,
                        device=1)
    done, _ = pl.submit(pl.prepare([out_op, in_op]))
    wb = [t for t in done if t.dst is Tier.PEER_HBM]
    rl = [t for t in done if t.dst is Tier.LOCAL_HBM]
    assert wb and rl
    wb_tail = max(t.ready_t for t in wb)
    assert min(t.ready_t - t.lane_s for t in rl) >= wb_tail - 1e-15


def test_stripe_chains_same_key_writeback():
    """A striped reload of an object whose write-back is on the wire must
    start after the write-back, and a LATER same-key transfer chains on
    the stripe's last-finishing chunk."""
    te, pl, _op, _ = _striped()
    wb = te.submit(te.transfer("expert2", 8 * MiB, Tier.LOCAL_HBM,
                               Tier.PEER_HBM, device=1))
    op2 = te.transfer("expert2", 8 * MiB, Tier.PEER_HBM, Tier.LOCAL_HBM,
                      device=1)
    chunks = pl.prepare([op2])
    done, _ = pl.submit(chunks)
    assert min(c.ready_t - c.lane_s for c in done) >= wb.ready_t - 1e-15
    tail = max(c.ready_t for c in done)
    again = te.submit(te.transfer("expert2", 1 * MiB, Tier.LOCAL_HBM,
                                  Tier.PEER_HBM, device=1))
    assert again.ready_t >= tail + again.seconds - 1e-15


@settings(max_examples=30, deadline=None)
@given(st.integers(2 * MiB, 32 * MiB), st.integers(2, 4),
       st.integers(128 * KiB, 2 * MiB))
def test_stripe_completion_never_beats_physics(nbytes, ways, chunk):
    """Striped completion is bounded below by the bytes over the link's
    aggregate bandwidth plus one setup, and above by the single-path
    serial time."""
    te, pl, _op, _ = _striped()
    op = te.transfer(("e", nbytes), nbytes, Tier.PEER_HBM, Tier.LOCAL_HBM,
                     device=1)
    pl.cfg = dataclasses.replace(
        pl.cfg, stripe_ways=ways, chunk_nbytes=chunk,
        min_stripe_nbytes=1 * MiB)
    t0 = te.now
    done, _ = pl.submit(pl.prepare([op]))
    full = max(t.ready_t for t in done) - t0
    link = te.link_spec(Tier.PEER_HBM, Tier.LOCAL_HBM, 1)
    assert full >= link.latency + nbytes / link.bandwidth - 1e-15
    assert full <= link.latency * len(done) + nbytes / link.path_bandwidth \
        + 1e-12


# ---------------------------------------------------------------------------
# reload-plan dedup satellite
# ---------------------------------------------------------------------------


@pytest.fixture()
def kv_runtime():
    from repro.configs import get_config
    cfg = dataclasses.replace(get_config("yi-6b").reduced(), num_layers=2)
    runtime = HarvestRuntime({1: 64 * MiB}, hardware=H100_NVLINK)
    kv = runtime.kv_manager(cfg, block_size=8, num_local_slots=6)
    return runtime, kv


def test_plan_reloads_dedups_repeated_keys(kv_runtime):
    _runtime, kv = kv_runtime
    for j in range(3):
        kv.allocate_block(1, j, j * 8)
    kv.evict_request(1)
    plan = kv.plan_reloads([(1, 0), (1, 1), (1, 0), (1, 1), (1, 2), (1, 0)])
    assert plan.deduped == 3
    assert kv.stats["reload_deduped"] == 3
    assert plan.touched == [(1, 0), (1, 1), (1, 2)]
    assert len(plan.ops) == 3                   # one reload per block, once
    assert set(plan.by_lane(kv.store.transfers)) == {"peer_in"}


def test_plan_reloads_attaches_inflight_transfer(kv_runtime):
    """A block already on the wire (e.g. a prefetch) must not resubmit —
    the critical waiter attaches to the existing transfer."""
    runtime, kv = kv_runtime
    kv.allocate_block(2, 0, 0)
    kv.evict_request(2)
    first = kv.plan_reloads([(2, 0)])
    assert len(first.ops) == 1
    tr = runtime.transfers.submit(first.ops[0])   # reload now in flight
    again = kv.plan_reloads([(2, 0)])
    assert again.ops == []                        # no double submission
    assert again.attached == [tr]
    runtime.transfers.wait_for([tr])
    quiet = kv.plan_reloads([(2, 0)])
    assert quiet.ops == [] and quiet.attached == []


def test_plan_reloads_stops_at_lost_block(kv_runtime):
    from repro.core.store import Residency
    _runtime, kv = kv_runtime
    for j in range(3):
        kv.allocate_block(3, j, j * 8)
    kv.evict_request(3)
    ent = kv.table[(3, 1)]
    ent.state = Residency.LOST
    ent.handle = None
    plan = kv.plan_reloads([(3, 0), (3, 1), (3, 2)])
    assert plan.lost == (3, 1)
    assert plan.touched == [(3, 0)], "ops before the loss still planned"
    assert len(plan.ops) == 1


# ---------------------------------------------------------------------------
# end-to-end: async+coalesce vs async per-object
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def served_model():
    import jax
    from repro.configs import get_config
    from repro.models import model as M
    cfg = dataclasses.replace(get_config("yi-6b").reduced(), num_layers=2)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _run_engine(served_model, coalesce, stripe=False):
    from repro.serving.engine import HarvestServingEngine
    cfg, params = served_model
    co = None
    if coalesce:
        co = CoalesceConfig(stripe_ways=4 if stripe else 0,
                            min_stripe_nbytes=1 * MiB)
    runtime = HarvestRuntime({1: 64 * MiB}, hardware=H100_NVLINK,
                             coalesce=co)
    eng = HarvestServingEngine(
        cfg, params, max_batch=2, block_size=8, num_local_slots=10,
        max_seq_len=96, runtime=runtime, scheduler="fair", mode="async")
    reqs = [eng.submit([2 + i, 5, 7, 11, 13 + i, 17, 19, 23, 29, 31],
                       max_new_tokens=12) for i in range(4)]
    stats = eng.run(max_steps=800)
    return eng, [r.output for r in reqs], stats


def test_async_coalesce_same_tokens_lower_clock(served_model):
    _, out_obj, st_obj = _run_engine(served_model, coalesce=False)
    eng, out_co, st_co = _run_engine(served_model, coalesce=True)
    # the planner changes WHEN bytes move, never what is decoded
    assert out_obj == out_co
    # the workload exercised the tiers and the batcher
    assert st_obj.metrics["kv"]["evict_to_peer"] > 0
    co = st_co.metrics["coalesce"]
    assert co["batches"] > 0 and co["batch_members"] >= 2 * co["batches"]
    assert co["saved_setup_s"] > 0
    # reload-heavy: coalescing strictly tightens the clock here
    assert st_co.clock_s < st_obj.clock_s
    assert st_co.reload_s < st_obj.reload_s
    st_co.check_clock_identity()
    # the batch/stripe reporting lines render
    assert "coalesce:" in st_co.summary()
    q = st_co.metrics["transfer"]
    assert sum(v for k, v in q.items() if k.endswith(".coalesced")) \
        == co["batches"]


def test_engine_rejects_coalesce_on_sync_path(served_model):
    from repro.serving.engine import HarvestServingEngine
    cfg, params = served_model
    runtime = HarvestRuntime({1: 64 * MiB}, hardware=H100_NVLINK,
                             coalesce=CoalesceConfig())
    with pytest.raises(AssertionError):
        HarvestServingEngine(cfg, params, runtime=runtime, mode="sync")


def test_simulator_timeline_coalesce_no_worse():
    """The event-driven CGOPipe path with a planner: identical placement,
    per-lane batched fetches — throughput must not regress."""
    from repro.configs import get_config
    from repro.core import simulate_moe_decode
    cfg = get_config("qwen2-moe")
    kw = dict(micro_batch=32, num_micro_batches=3, decode_steps=1)
    base = HarvestRuntime(hardware=H100_NVLINK)
    plain = simulate_moe_decode(cfg, H100_NVLINK, 0.5, use_peer=True,
                                runtime=base, use_timeline=True, **kw)
    co = HarvestRuntime(hardware=H100_NVLINK,
                        coalesce=CoalesceConfig(max_batch=64))
    batched = simulate_moe_decode(cfg, H100_NVLINK, 0.5, use_peer=True,
                                  runtime=co, use_timeline=True, **kw)
    assert batched.tokens_per_s >= plain.tokens_per_s * (1 - 1e-9)
    assert co.planner.stats["batches"] > 0
