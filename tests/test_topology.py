"""Topology-aware multi-peer harvesting (PR 3).

Covers the tentpole refactor:
  * the interconnect ``Topology`` presets and their per-device LinkSpecs
    (2-GPU NVLink compat, NVLink mesh, PCIe switch, v5e ICI torus with
    striped multi-link paths);
  * per-peer-device directional lanes in the TransferEngine — transfers
    to distinct peers pipeline in parallel, same-peer transfers keep FIFO
    order, device 1 keeps the legacy lane names;
  * HarvestStore charging the actual device of each HarvestHandle
    (per-device lane, per-device link time, per-device counters);
  * TopologyAwarePolicy scoring (bandwidth-weighted, churn-averse,
    lane-spreading);
  * timeline-driven PeerMonitor ticks (pressure lands mid-pipeline);
  * the async serving engine over a mesh: same tokens as sync, strictly
    better clock with more peers, per-device q.* lane metrics.
"""
import dataclasses

import pytest

from repro.core import (H100_NVLINK, TPU_V5E, ClusterTrace,
                        ClusterTraceConfig, HarvestAllocator, HarvestRuntime,
                        PeerMonitor, Tier, TopologyAwarePolicy,
                        TransferEngine, channel_name, get_topology,
                        nvlink_2gpu, nvlink_mesh, pcie_switch, tpu_v5e_torus)

MiB = 2**20


# ---------------------------------------------------------------------------
# presets
# ---------------------------------------------------------------------------


def test_2gpu_preset_is_the_legacy_hardware_model():
    topo = nvlink_2gpu()
    assert topo.devices == (1,)
    # compat shim: device-less and device-1 lookups both degrade to the
    # flat HardwareModel link, so pre-topology cost models are bit-exact
    for dev in (None, 1):
        assert topo.link(Tier.PEER_HBM, Tier.LOCAL_HBM, dev) \
            == H100_NVLINK.peer_link
    assert topo.link(Tier.HOST_DRAM, Tier.LOCAL_HBM) == H100_NVLINK.host_link
    nbytes = 64 * MiB
    assert topo.transfer_time(nbytes, Tier.PEER_HBM, Tier.LOCAL_HBM) \
        == H100_NVLINK.transfer_time(nbytes, Tier.PEER_HBM, Tier.LOCAL_HBM)


def test_mesh_and_pcie_presets():
    mesh = nvlink_mesh(3)
    assert mesh.devices == (1, 2, 3)
    assert all(mesh.peer_link(d) == H100_NVLINK.peer_link
               for d in mesh.devices)
    pcie = pcie_switch(3)
    assert pcie.devices == (1, 2, 3)
    t_mesh = mesh.transfer_time(64 * MiB, Tier.PEER_HBM, Tier.LOCAL_HBM, 2)
    t_pcie = pcie.transfer_time(64 * MiB, Tier.PEER_HBM, Tier.LOCAL_HBM, 2)
    assert t_pcie > 5 * t_mesh, "the PCIe-switch path must be a last resort"
    assert mesh.device_budgets(8 * MiB) == {1: 8 * MiB, 2: 8 * MiB,
                                            3: 8 * MiB}


def test_v5e_torus_striping_and_hops():
    torus = tpu_v5e_torus((2, 2), stripe=True)
    assert torus.devices == (1, 2, 3)
    # striping multiplies bandwidth by the 4 link-disjoint torus paths
    assert torus.peer_link(1).bandwidth == pytest.approx(
        4 * TPU_V5E.peer_link.bandwidth)
    flat = tpu_v5e_torus((2, 2), stripe=False)
    assert flat.peer_link(1).bandwidth == TPU_V5E.peer_link.bandwidth
    # hop count: on a 4x1 ring slice, device 2 is two hops out -> 2x latency
    ring = tpu_v5e_torus((4, 1))
    assert ring.peer_link(2).latency == pytest.approx(
        2 * TPU_V5E.peer_link.latency)
    assert ring.peer_link(1).latency == pytest.approx(
        TPU_V5E.peer_link.latency)
    # wrap-around: device 3 on the 4-ring is ONE hop the other way
    assert ring.peer_link(3).latency == pytest.approx(
        TPU_V5E.peer_link.latency)


def test_topology_registry():
    assert get_topology("nvlink-mesh-4").num_peers == 3
    with pytest.raises(KeyError):
        get_topology("nonexistent-fabric")


# ---------------------------------------------------------------------------
# per-device lanes
# ---------------------------------------------------------------------------


def test_channel_name_per_device_with_legacy_mapping():
    assert channel_name(Tier.PEER_HBM, Tier.LOCAL_HBM) == "peer_in"
    # device 1 IS the legacy lane (2-device presets put their peer there)
    assert channel_name(Tier.PEER_HBM, Tier.LOCAL_HBM, 1) == "peer_in"
    assert channel_name(Tier.LOCAL_HBM, Tier.PEER_HBM, 1) == "peer_out"
    assert channel_name(Tier.PEER_HBM, Tier.LOCAL_HBM, 2) == "peer2_in"
    assert channel_name(Tier.LOCAL_HBM, Tier.PEER_HBM, 3) == "peer3_out"
    # one physical host link regardless of the peer involved
    assert channel_name(Tier.HOST_DRAM, Tier.PEER_HBM, 2) == "host_out"


def test_transfers_to_distinct_peers_pipeline_in_parallel():
    te = TransferEngine(H100_NVLINK, topology=nvlink_mesh(4))
    ops = [te.submit(te.transfer(("blk", d), 32 * MiB, Tier.PEER_HBM,
                                 Tier.LOCAL_HBM, device=d))
           for d in (1, 2, 3, 4)]
    # each peer's lane is idle, so every transfer is ready after its OWN
    # link time — the batch makespan is one transfer, not four
    for op in ops:
        assert op.ready_t == pytest.approx(op.seconds)
    assert len({op.channel for op in ops}) == 4
    # same-peer transfers still serialise FIFO on their shared lane
    dup = te.submit(te.transfer(("blk2", 2), 32 * MiB, Tier.PEER_HBM,
                                Tier.LOCAL_HBM, device=2))
    assert dup.ready_t == pytest.approx(ops[1].ready_t + dup.seconds)
    te.wait_for(ops + [dup])
    assert te.pending() == 0


def test_transfer_charged_at_the_devices_link():
    ring = tpu_v5e_torus((4, 1), stripe=False)
    te = TransferEngine(TPU_V5E, topology=ring)
    near = te.transfer("a", 8 * MiB, Tier.PEER_HBM, Tier.LOCAL_HBM, device=1)
    far = te.transfer("b", 8 * MiB, Tier.PEER_HBM, Tier.LOCAL_HBM, device=2)
    assert far.seconds > near.seconds, \
        "a two-hop ICI peer must cost more than a neighbour"
    assert far.seconds - near.seconds == pytest.approx(
        TPU_V5E.peer_link.latency)


def test_store_charges_the_actual_handle_device():
    topo = nvlink_mesh(3)
    rt = HarvestRuntime(topo.device_budgets(64 * MiB), topology=topo,
                        policy=TopologyAwarePolicy(topo))
    store = rt.create_store("obj", object_nbytes=1 * MiB, num_local_slots=1)
    store.allocate_local("a")
    store.allocate_local("b")        # evicts "a" to SOME peer device
    dev = store.device_of("a")
    assert dev in topo.devices
    assert store.stats[f"dev{dev}.evictions"] == 1
    ops = store.ensure_local("a")    # reload charges the same device
    assert ops[-1].device == dev
    assert store.stats[f"dev{dev}.reloads"] == 1
    ch = channel_name(Tier.LOCAL_HBM, Tier.PEER_HBM, dev)
    assert ch in ("peer_out", "peer2_out", "peer3_out")


# ---------------------------------------------------------------------------
# topology-aware placement
# ---------------------------------------------------------------------------


def _snapshot(alloc):
    return alloc.device_view()


def test_policy_avoids_high_churn_devices():
    topo = nvlink_mesh(2)
    alloc = HarvestAllocator(topo.device_budgets(64 * MiB),
                             policy=TopologyAwarePolicy(topo))
    # device 1's budget thrashes; device 2 is rock steady
    for b in (32, 64, 16, 64, 24, 64):
        alloc.update_budget(1, b * MiB)
    h = alloc.harvest_alloc(1 * MiB)
    assert h.device == 2, "placement must avoid the churny device"


def test_policy_spreads_concurrent_placements_across_lanes():
    topo = nvlink_mesh(4)
    alloc = HarvestAllocator(topo.device_budgets(64 * MiB),
                             policy=TopologyAwarePolicy(topo))
    devices = [alloc.harvest_alloc(1 * MiB, hints={"hot": 1.0}).device
               for _ in range(4)]
    assert len(set(devices)) > 1, \
        "hot placements must fan out across link lanes, not pile on one FIFO"


def test_policy_prefers_faster_links():
    ring = tpu_v5e_torus((8, 1), stripe=False)   # 1..7 at 1..~4 hops
    pol = TopologyAwarePolicy(ring)
    alloc = HarvestAllocator(ring.device_budgets(64 * MiB), policy=pol)
    h = alloc.harvest_alloc(1 * MiB)
    assert h.device in (1, 7), "nearest ICI neighbours first"


def test_policy_degrades_to_best_fit_on_single_peer():
    topo = nvlink_2gpu()
    pol = TopologyAwarePolicy(topo)
    alloc = HarvestAllocator({1: 64 * MiB}, policy=pol)
    assert alloc.harvest_alloc(1 * MiB).device == 1


# ---------------------------------------------------------------------------
# timeline-driven pressure
# ---------------------------------------------------------------------------


def test_monitor_poll_fires_on_the_simulated_clock():
    topo = nvlink_mesh(2)
    alloc = HarvestAllocator(topo.device_budgets(64 * MiB))
    trace = ClusterTrace(ClusterTraceConfig(num_devices=2,
                                            capacity_bytes=64 * MiB, seed=3))
    mon = PeerMonitor(alloc, trace, capacity_bytes=64 * MiB,
                      tick_interval_s=1e-3, devices=list(topo.devices))
    assert mon.poll(0.0) == 0          # arms the poll clock
    assert mon.poll(0.5e-3) == 0       # not a full interval yet
    assert mon.poll(3.6e-3) == 3       # 3 whole intervals elapsed
    assert trace.t == 3
    # budgets landed on the TOPOLOGY's device ids, not 0..n-1
    view = alloc.device_view()
    assert set(view) == {1, 2}
    assert all(v["budget"] < 64 * MiB for v in view.values())


def test_trace_volatility_and_correlation_extensions():
    base = ClusterTraceConfig(num_devices=4, capacity_bytes=64 * MiB, seed=7)
    hot = dataclasses.replace(base, volatility=4.0, correlation=0.9)
    t0, t1 = ClusterTrace(base), ClusterTrace(hot)
    import numpy as np
    d0 = np.stack([t0.step() for _ in range(40)]).astype(float)
    d1 = np.stack([t1.step() for _ in range(40)]).astype(float)
    # compare temporal MOTION (step-to-step deltas), not base levels
    assert np.abs(np.diff(d1, axis=0)).mean() \
        > np.abs(np.diff(d0, axis=0)).mean(), \
        "volatility must amplify budget motion"
    # defaults reproduce the legacy trace draw-for-draw
    again = ClusterTrace(ClusterTraceConfig(num_devices=4,
                                            capacity_bytes=64 * MiB, seed=7))
    d2 = np.stack([again.step() for _ in range(40)]).astype(float)
    assert (d0 == d2).all()


# ---------------------------------------------------------------------------
# engine over a mesh
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def served_model():
    import jax
    from repro.configs import get_config
    from repro.models import model as M
    cfg = dataclasses.replace(get_config("yi-6b").reduced(), num_layers=2)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _run_mesh(served_model, num_peers, mode, volatility=0.0):
    from repro.serving.engine import HarvestServingEngine
    cfg, params = served_model
    topo = nvlink_mesh(num_peers)
    trace = None
    if volatility > 0:
        trace = ClusterTrace(ClusterTraceConfig(
            num_devices=num_peers, capacity_bytes=4 * MiB, seed=0,
            volatility=volatility, correlation=0.5))
    rt = HarvestRuntime(topo.device_budgets(4 * MiB), topology=topo,
                        policy=TopologyAwarePolicy(topo), trace=trace,
                        monitor_interval_s=50e-6 if trace else None)
    eng = HarvestServingEngine(
        cfg, params, max_batch=2, block_size=8, num_local_slots=10,
        max_seq_len=96, runtime=rt, scheduler="fair", mode=mode)
    reqs = [eng.submit([2 + i, 5, 7, 11, 13 + i], max_new_tokens=12)
            for i in range(4)]
    stats = eng.run(max_steps=800)
    return eng, [r.output for r in reqs], stats


def test_mesh_engine_same_tokens_better_clock(served_model):
    _, out1, st1 = _run_mesh(served_model, 1, "async")
    eng, out4, st4 = _run_mesh(served_model, 4, "async")
    _, out_sync, st_sync = _run_mesh(served_model, 4, "sync")
    # per-device lanes change WHEN bytes move, never what is decoded
    assert out1 == out4 == out_sync
    assert st4.clock_s <= st1.clock_s
    assert st4.clock_s <= st_sync.clock_s
    st1.check_clock_identity()
    st4.check_clock_identity()
    # per-device q.* lane metrics prove multiple peers carried traffic
    q = {k: v for k, v in st4.metrics["transfer"].items()
         if k.startswith("q.peer") and k.endswith(".submitted")}
    assert len(q) >= 2, f"expected multiple peer lanes, saw {sorted(q)}"
    # and the device namespace reports occupancy/churn for every peer
    dev = st4.metrics["device"]
    assert {f"dev{d}.churn" for d in (1, 2, 3, 4)} <= set(dev)
    assert "devices:" in st4.summary()


def test_mesh_engine_with_timeline_pressure(served_model):
    eng, outs, stats = _run_mesh(served_model, 2, "async", volatility=3.0)
    assert eng._timeline_ticks is not None and eng._timeline_ticks > 0, \
        "trace ticks must fire on the simulated timeline"
    assert eng.monitor.stats["ticks"] == eng._timeline_ticks
    assert all(len(o) == 12 for o in outs)
    stats.check_clock_identity()
