"""Property tests for the stability controller (hypothesis; skipped
cleanly when hypothesis is absent — the tier1-minimal-deps CI leg).

Two invariant families:

  1. **estimator convergence** — the windowed arrival-rate estimate over
     seeded Poisson/bursty streams converges to the true long-run rate
     within tolerance once the window holds enough events (relative
     error ~ 1/sqrt(lam * W)), and the windowed token-rate (occupancy)
     estimate tracks a known token stream the same way;
  2. **in-region no-op** — on workloads that never leave the stability
     region the controller is a bit-exact no-op: identical tokens AND
     identical clock to the controller-free engine, every seed.
"""
import dataclasses

import jax
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (optional test dep)")
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.core import HarvestRuntime
from repro.models import model as M
from repro.serving import (HarvestServer, TenantSpec, WindowedRate,
                           WindowedSum, Workload)
from repro.serving.workload import bursty_arrivals, poisson_arrivals

MiB = 2**20
CFG = dataclasses.replace(get_config("yi-6b").reduced(), num_layers=2)
PARAMS = M.init_params(jax.random.PRNGKey(0), CFG)


# ---------------------------------------------------------------------------
# estimator convergence
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000),
       rate=st.sampled_from([200.0, 1e3, 5e4]),
       arrival=st.sampled_from(["poisson", "bursty"]))
def test_windowed_rate_converges_to_true_rate(seed, rate, arrival):
    rng = np.random.default_rng(seed)
    n = 4000
    times = (poisson_arrivals(rng, rate, n) if arrival == "poisson"
             else bursty_arrivals(rng, rate, n, burst=8, duty=0.25))
    # window sized to hold ~1500 events: relative error ~ 1/sqrt(1500),
    # bursty adds burst-boundary variance — 25% tolerance covers both
    window = 1500.0 / rate
    wr = WindowedRate(window)
    for t in times:
        wr.observe(t)
    now = float(times[-1])
    assert wr.rate(now) == pytest.approx(rate, rel=0.25)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000),
       rate=st.sampled_from([500.0, 2e4]),
       tokens=st.integers(1, 32))
def test_windowed_token_rate_tracks_occupancy(seed, rate, tokens):
    # each retirement carries a fixed token count: the windowed sum must
    # converge to rate * tokens (the throughput the controller divides
    # capacity by)
    rng = np.random.default_rng(seed)
    times = poisson_arrivals(rng, rate, 3000)
    window = 1200.0 / rate
    ws = WindowedSum(window)
    for t in times:
        ws.observe(t, float(tokens))
    now = float(times[-1])
    assert ws.rate(now) == pytest.approx(rate * tokens, rel=0.25)


# ---------------------------------------------------------------------------
# in-region no-op
# ---------------------------------------------------------------------------

def _serve(workload: Workload, controller):
    srv = HarvestServer(
        CFG, PARAMS, runtime=HarvestRuntime({1: 64 * MiB}),
        max_batch=2, block_size=8, num_local_slots=10,
        scheduler="fair", mode="async", controller=controller)
    stats = srv.run(workload, max_steps=4000)
    tokens = {r.req_id: r.output_tokens for r in stats.requests}
    return stats, tokens


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 10_000),
       arrival=st.sampled_from(["poisson", "bursty"]))
def test_controller_is_noop_inside_stability_region(seed, arrival):
    # rate far below the service capacity of the reduced model: the
    # controller must never engage, so tokens AND clock are bit-exact
    wl = Workload(
        num_requests=8, arrival=arrival, rate=2e3, seed=seed,
        vocab=(3, 250),
        tenants=(TenantSpec("t", slo="latency", prompt_len=(6, 18),
                            max_new_tokens=(3, 8)),))
    base, base_tokens = _serve(wl, None)
    ctrl, ctrl_tokens = _serve(wl, "stability")
    assert ctrl_tokens == base_tokens
    assert ctrl.clock_s == base.clock_s          # bit-exact, not approx
    assert ctrl.idle_s == base.idle_s
    assert ctrl.bubble_s == base.bubble_s
    assert ctrl.metrics["ctrl"]["engages"] == 0
    ctrl.check_clock_identity()
