"""The fully-manual expert-parallel MoE path (shard_map over data x model)
must match the global-dispatch path numerically, gradients included.

Runs in a subprocess because the 4-device CPU mesh needs
XLA_FLAGS=--xla_force_host_platform_device_count=4 before jax initializes
(the main test process must keep the single real device).
"""
import os
import subprocess
import sys
import textwrap

PROG = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs.base import ModelConfig, MoEConfig
    from repro.models.sharding import ShardingRules, DEFAULT_RULES
    from repro.models import model as M
    from repro.models.moe import moe_layer

    cfg = ModelConfig(name="t", family="moe", source="", num_layers=2,
                      d_model=32, num_heads=4, num_kv_heads=2, d_ff=64,
                      vocab_size=64,
                      moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=64,
                                    capacity_factor=8.0))
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    lp = jax.tree.map(lambda t: t[0], params["layers"])
    mesh = jax.make_mesh((2, 2), ("data", "model"))
    rules = ShardingRules(mesh, dict(DEFAULT_RULES))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 32), jnp.float32)

    y_ref, _ = jax.jit(lambda x, p: moe_layer(x, p, cfg, None))(x, lp["moe"])
    with mesh:
        y_mesh, _ = jax.jit(lambda x, p: moe_layer(x, p, cfg, rules))(
            x, lp["moe"])
    assert np.allclose(np.asarray(y_ref), np.asarray(y_mesh), atol=2e-5), \
        np.abs(np.asarray(y_ref) - np.asarray(y_mesh)).max()

    g = jax.jit(jax.grad(lambda p: moe_layer(x, p, cfg, None)[0].sum()))(
        lp["moe"])
    with mesh:
        gm = jax.jit(jax.grad(lambda p: moe_layer(x, p, cfg, rules)[0].sum()))(
            lp["moe"])
    err = max(jax.tree.leaves(jax.tree.map(
        lambda a, b: float(np.abs(np.asarray(a) - np.asarray(b)).max()),
        g, gm)))
    assert err < 2e-3, f"grad mismatch {err}"
    print("OK")
""")


def test_moe_local_dispatch_matches_global():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", PROG], env=env,
                       capture_output=True, text=True, timeout=480)
    assert r.returncode == 0 and "OK" in r.stdout, r.stderr[-2000:]
