"""Harvested prefix cache (PR 6): radix-trie cross-request KV sharing.

Covers the tentpole subsystem:
  * chained block digests: position-dependent, collision iff identical
    full prefix, partial tail blocks excluded;
  * publish-on-retire (rekey, zero copy), dedup of already-cached
    content, and the refcount contract — the trie's hold is the base
    ownership, every lease is one extra reference, whichever of
    {trie eviction, lessee retire} happens last performs the free;
  * the ``free_request`` double-free regression: a retiring lessee can
    never free a block the trie (or a later lessee) still references;
  * adopt-or-COW: one lessee per content block, the second concurrent
    consumer gets a private copy whose payload is never aliased;
  * trie eviction: leaf-first LRU, leased leaves unevictable;
  * tier transparency: published blocks ride the store's eviction /
    revocation ladder under their stable content key (including the
    revocation-callback rekey);
  * property tests (hypothesis): random publish/adopt/free/evict
    interleavings preserve refcount conservation and longest-prefix
    consistency;
  * end-to-end: a cache-enabled engine decodes bit-identical tokens to a
    cache-disabled one, records per-request ``cached_prefix_blocks``,
    and spends strictly less prefill time in a compute-bound regime;
  * satellites: shared-prefix workload generation (seeded, stream-stable)
    and the ``EngineStats.summary()`` prefix line.
"""
import dataclasses
import math

import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (H100_NVLINK, HarvestRuntime, PrefixCache,
                        PrefixCacheConfig, Residency, block_digests)
from repro.serving import TenantSpec, Workload
from repro.serving.engine import EngineStats

MiB = 2**20
BS = 4


def _mgr(slots=16, budget_mib=256):
    cfg = get_config("yi-6b").reduced()
    rt = HarvestRuntime({1: budget_mib * MiB})
    kv = rt.kv_manager(cfg, block_size=BS, num_local_slots=slots,
                       store_payload=True)
    return kv, rt


def _prefill_blocks(kv, req, tokens):
    """Simulate a prefill: allocate and fill the request's non-adopted
    blocks, with a content-determined payload per block."""
    nb = math.ceil(len(tokens) / BS)
    for j in range(nb):
        if (req, j) in kv.shared or (req, j) in kv.table:
            continue
        kv.allocate_block(req, j, j * BS)
        kv.table[(req, j)].filled = min(BS, len(tokens) - j * BS)
        kv.write_payload(req, j, np.asarray(
            tokens[j * BS:(j + 1) * BS], dtype=np.float64))


def _serve(kv, pc, req, tokens):
    """One request's block-table lifecycle: match, adopt-or-COW, prefill
    the rest.  Returns the matched chain."""
    matched = pc.match(tokens)
    for j, ckey in matched:
        if kv.lessee_of(ckey) is not None:
            kv.cow_split(req, j, ckey)
        else:
            kv.adopt_block(req, j, ckey)
    _prefill_blocks(kv, req, tokens)
    return matched


# ---------------------------------------------------------------------------
# digests
# ---------------------------------------------------------------------------


def test_digests_chained_and_position_dependent():
    a = [1, 2, 3, 4, 5, 6, 7, 8]
    assert len(block_digests(a, 4)) == 2
    # identical prefixes share the chain
    assert block_digests(a + [9], 4) == block_digests(a, 4)
    # same block content at a different position gets a different digest
    rep = [1, 2, 3, 4, 1, 2, 3, 4]
    d = block_digests(rep, 4)
    assert d[0] != d[1]
    # diverging first block changes every later digest
    b = [9, 2, 3, 4, 5, 6, 7, 8]
    assert block_digests(b, 4)[1] != block_digests(a, 4)[1]
    # partial tail blocks are never digested
    assert block_digests([1, 2, 3], 4) == []


def test_digests_validate_block_size():
    with pytest.raises(ValueError):
        block_digests([1, 2], 0)


# ---------------------------------------------------------------------------
# publish / match / refcounts
# ---------------------------------------------------------------------------


def test_publish_rekeys_and_match_hits():
    kv, _ = _mgr()
    pc = PrefixCache(kv)
    toks = list(range(10, 19))             # 2 full blocks + 1-token tail
    _prefill_blocks(kv, 0, toks)
    assert pc.publish(0, toks) == 2
    # the full blocks transferred to content keys (zero copy, same entry)
    assert (0, 0) not in kv.table and (0, 1) not in kv.table
    assert len(pc) == 2 and pc.stats["nodes"] == 2
    kv.free_request(0)                     # frees only the private tail
    m = pc.match(toks)
    assert [j for j, _ in m] == [0, 1]
    # payloads followed the rekey
    for j, ckey in m:
        np.testing.assert_array_equal(
            kv.store.read_payload(ckey),
            np.asarray(toks[j * BS:(j + 1) * BS], dtype=np.float64))
    assert pc.stats["hit_blocks"] == 2 and pc.stats["hit_tokens"] == 2 * BS


def test_publish_dedup_frees_private_twin():
    kv, _ = _mgr()
    pc = PrefixCache(kv)
    toks = list(range(8))
    _prefill_blocks(kv, 0, toks)
    pc.publish(0, toks)
    kv.free_request(0)
    # request 1 prefills the SAME prompt privately (no adoption)
    _prefill_blocks(kv, 1, toks)
    assert pc.publish(1, toks) == 0
    assert pc.stats["dedup"] == 2
    kv.free_request(1)                     # private twins free normally
    assert (1, 0) not in kv.table and (1, 1) not in kv.table
    assert len(pc) == 2                    # trie entries untouched
    assert all(pc._entry_alive(n) is not None for n in pc.nodes.values())


def test_free_request_double_free_regression():
    """A retiring lessee must drop a reference, not free the trie's block
    — and a second free_request must be a no-op (the double-free class
    this PR routes through the store refcount)."""
    kv, _ = _mgr()
    pc = PrefixCache(kv)
    toks = list(range(8))
    _prefill_blocks(kv, 0, toks)
    pc.publish(0, toks)
    kv.free_request(0)
    freed0 = kv.stats["freed"]

    m = _serve(kv, pc, 1, toks)
    assert len(m) == 2
    for _, ckey in m:
        assert kv.store.table[ckey].refcount == 1
    kv.free_request(1)                     # lease returns: refcount drop
    assert kv.stats["freed"] == freed0, \
        "retiring a lessee freed a block the trie still references"
    assert kv.stats["ref_drops"] >= 2
    for _, ckey in m:
        assert kv.store.table[ckey].refcount == 0
        assert pc._entry_alive(pc.nodes[ckey[1]]) is not None
    kv.free_request(1)                     # idempotent: nothing left to free
    assert kv.stats["freed"] == freed0
    # the cache still serves the prefix
    assert len(pc.match(toks)) == 2


def test_last_holder_frees_trie_eviction_vs_lessee_retire():
    """Whichever of {trie eviction, lessee retire} happens LAST frees."""
    kv, _ = _mgr()
    pc = PrefixCache(kv, PrefixCacheConfig(capacity_blocks=1))
    toks = list(range(8))                  # 2 blocks > capacity 1
    _prefill_blocks(kv, 0, toks)
    pc.publish(0, toks)                    # capacity evicts the leaf (block 1)
    kv.free_request(0)
    assert len(pc) == 1 and pc.stats["evictions"] == 1
    (j, ckey), = pc.match(toks[:BS])
    kv.adopt_block(1, j, ckey)
    # order A: trie eviction first (leased -> survives), retire frees
    pc._unlink(pc.nodes[ckey[1]], "evictions")
    assert ckey in kv.store.table, "leased entry freed under the lessee"
    freed0 = kv.stats["freed"]
    kv.free_request(1)
    assert ckey not in kv.store.table and kv.stats["freed"] == freed0 + 1


def test_leased_leaf_unevictable():
    kv, _ = _mgr()
    pc = PrefixCache(kv, PrefixCacheConfig(capacity_blocks=4))
    toks = list(range(4))
    _prefill_blocks(kv, 0, toks)
    pc.publish(0, toks)
    kv.free_request(0)
    (j, ckey), = pc.match(toks)
    kv.adopt_block(1, j, ckey)
    # flood the trie past capacity with other one-block prompts
    for r in range(2, 10):
        other = [100 * r + i for i in range(4)]
        _prefill_blocks(kv, r, other)
        pc.publish(r, other)
        kv.free_request(r)
    assert len(pc) <= 4 + 1                # leased leaf may overflow by one
    assert ckey in kv.store.table and ckey[1] in pc.nodes, \
        "capacity eviction dropped a leased leaf"
    kv.free_request(1)


# ---------------------------------------------------------------------------
# adopt-or-COW
# ---------------------------------------------------------------------------


def test_second_concurrent_consumer_cow_splits():
    kv, _ = _mgr()
    pc = PrefixCache(kv)
    toks = list(range(8))
    _prefill_blocks(kv, 0, toks)
    pc.publish(0, toks)
    kv.free_request(0)

    m1 = _serve(kv, pc, 1, toks)           # adopts (no other lessee)
    assert [kv.lessee_of(ck) for _, ck in m1] == [1, 1]
    assert kv.resolve((1, 0)) == m1[0][1]
    m2 = _serve(kv, pc, 2, toks)           # same blocks: must COW
    # both matched blocks became private copies, not second leases
    assert (2, 0) in kv.table and (2, 1) in kv.table
    assert kv.resolve((2, 0)) == (2, 0)
    assert [kv.lessee_of(ck) for _, ck in m2] == [1, 1]
    # COW never aliases payloads: equal content, distinct buffers
    for j, ckey in m2:
        shared = kv.store.read_payload(ckey)
        private = kv.read_payload(2, j)
        np.testing.assert_array_equal(shared, private)
        assert not np.shares_memory(shared, private)
        private[...] = -1.0
        assert not np.array_equal(kv.store.read_payload(ckey), private)
    kv.free_request(1)
    kv.free_request(2)
    assert not kv.lessee and not kv.shared


def test_adopt_block_rejects_double_lease():
    kv, _ = _mgr()
    pc = PrefixCache(kv)
    toks = list(range(4))
    _prefill_blocks(kv, 0, toks)
    pc.publish(0, toks)
    kv.free_request(0)
    (j, ckey), = pc.match(toks)
    kv.adopt_block(1, j, ckey)
    with pytest.raises(AssertionError):
        kv.adopt_block(2, j, ckey)


# ---------------------------------------------------------------------------
# tier ladder transparency
# ---------------------------------------------------------------------------


def test_published_blocks_ride_tiers_and_survive_revocation():
    """A published block demoted to peer stays matchable under its stable
    content key; external revocation falls back to host (backed mode) —
    which requires the revocation callback to follow the rekey."""
    kv, rt = _mgr(slots=2)
    pc = PrefixCache(kv)
    toks = list(range(8))
    _prefill_blocks(kv, 0, toks)
    kv.evict_request(0)                    # both blocks now PEER
    pc.publish(0, toks)
    kv.free_request(0)
    m = pc.match(toks)
    assert len(m) == 2
    states = [kv.store.table[ck].state for _, ck in m]
    assert all(s is Residency.PEER for s in states)
    rt.allocator.update_budget(1, 0)       # revoke the whole peer budget
    states = [kv.store.table[ck].state for _, ck in m]
    assert all(s is Residency.HOST for s in states), \
        "revocation missed the rekeyed entry (stale callback key)"
    # still matchable; adoption reloads from host
    m2 = pc.match(toks)
    assert len(m2) == 2
    ops = kv.adopt_block(3, 0, m2[0][1])
    assert ops and kv.store.table[m2[0][1]].state is Residency.LOCAL
    kv.free_request(3)


def test_lossy_revocation_prunes_chain():
    cfg = get_config("yi-6b").reduced()
    rt = HarvestRuntime({1: 256 * MiB})
    kv = rt.kv_manager(cfg, block_size=BS, num_local_slots=2,
                       durability="lossy", store_payload=True)
    pc = PrefixCache(kv)
    toks = list(range(8))
    _prefill_blocks(kv, 0, toks)
    kv.evict_request(0)
    pc.publish(0, toks)
    kv.free_request(0)
    rt.allocator.update_budget(1, 0)       # lossy: blocks go LOST
    assert pc.match(toks) == []
    assert pc.stats["lost_pruned"] >= 1 and len(pc) == 0


def test_probe_is_side_effect_free():
    kv, _ = _mgr()
    pc = PrefixCache(kv)
    toks = list(range(8))
    _prefill_blocks(kv, 0, toks)
    pc.publish(0, toks)
    kv.free_request(0)
    before = dict(pc.stats)
    assert pc.probe(toks + [99]) == 2 * BS
    assert pc.probe([99] + toks) == 0
    assert dict(pc.stats) == before


# ---------------------------------------------------------------------------
# property tests (hypothesis)
# ---------------------------------------------------------------------------


def _assert_invariants(kv, pc):
    # refcount conservation: trie hold is base (0); each lease adds one
    for digest, node in pc.nodes.items():
        ent = kv.store.table.get(node.key)
        assert ent is not None, f"trie node {digest} lost its entry"
        expect = 1 if kv.lessee_of(node.key) is not None else 0
        assert ent.refcount == expect, \
            f"refcount {ent.refcount} != {expect} for {node.key}"
    # no orphaned unleased content entries outside the trie
    for key in kv.store.table:
        if isinstance(key, tuple) and key[0] == "px" \
                and key[1] not in pc.nodes:
            assert kv.lessee_of(key) is not None, \
                f"orphaned unleased content entry {key}"
    assert pc.stats["nodes"] == len(pc.nodes)


try:                                      # optional dep: only the two
    from hypothesis import given, settings, strategies as st  # noqa: E402
    _HAS_HYPOTHESIS = True
except ImportError:                       # property tests skip, not the file
    _HAS_HYPOTHESIS = False

    def given(*a, **k):                   # no-op decorators so the module
        return lambda fn: fn              # still imports without the dep

    settings = given

    class st:                             # noqa: N801 — strategy stub
        def __getattr__(self, name):
            return lambda *a, **k: None
    st = st()

needs_hypothesis = pytest.mark.skipif(
    not _HAS_HYPOTHESIS,
    reason="property tests need the optional hypothesis dep")


@needs_hypothesis
@settings(max_examples=25, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 2), st.integers(1, 3)),
                min_size=1, max_size=12),
       st.integers(1, 6))
def test_trie_interleavings_preserve_invariants(seq, capacity):
    """Random publish/adopt/COW/free/evict interleavings: refcounts
    conserve, matches are consistent longest prefixes, COW never aliases.

    Prompts are chains over 3 distinct content blocks, so shared prefixes
    (and concurrent leases, via the two-live-requests window) arise
    naturally."""
    kv, _ = _mgr(slots=24, budget_mib=512)
    pc = PrefixCache(kv, PrefixCacheConfig(capacity_blocks=capacity))
    blocks = [[v] * BS for v in (7, 8, 9)]
    live = []
    for req, (first, nblocks) in enumerate(seq):
        toks = sum((blocks[(first + k) % 3] for k in range(nblocks)), [])
        if len(live) == 2:                 # keep two requests in flight
            kv.free_request(live.pop(0))
        matched = pc.match(toks)
        digests = block_digests(toks, BS)
        # longest-prefix consistency: contiguous from 0, digests line up
        assert [j for j, _ in matched] == list(range(len(matched)))
        for j, ckey in matched:
            assert ckey == ("px", digests[j])
            assert pc._entry_alive(pc.nodes[digests[j]]) is not None
        for j, ckey in matched:
            if kv.lessee_of(ckey) is not None:
                kv.cow_split(req, j, ckey)
                private = kv.read_payload(req, j)
                shared = kv.store.read_payload(ckey)
                if private is not None and shared is not None:
                    assert not np.shares_memory(shared, private)
            else:
                kv.adopt_block(req, j, ckey)
        _prefill_blocks(kv, req, toks)
        pc.publish(req, toks)
        live.append(req)
        _assert_invariants(kv, pc)
    for req in live:
        kv.free_request(req)
    _assert_invariants(kv, pc)
    assert not kv.lessee and not kv.shared
    # with no leases left, capacity is a hard bound
    assert len(pc) <= capacity


@needs_hypothesis
@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(0, 2), min_size=1, max_size=8))
def test_match_agrees_with_digest_model(firsts):
    """match() returns exactly the longest published prefix — checked
    against a pure-python digest-set model (no eviction pressure)."""
    kv, _ = _mgr(slots=32, budget_mib=512)
    pc = PrefixCache(kv, PrefixCacheConfig(capacity_blocks=1024))
    blocks = [[v] * BS for v in (4, 5, 6)]
    published = set()
    for req, first in enumerate(firsts):
        toks = sum((blocks[(first + k) % 3] for k in range(3)), [])
        digests = block_digests(toks, BS)
        expect = 0
        while expect < len(digests) and digests[expect] in published:
            expect += 1
        assert len(pc.match(toks)) == expect
        _serve(kv, pc, req, toks)
        pc.publish(req, toks)
        published.update(digests)
        kv.free_request(req)


# ---------------------------------------------------------------------------
# workload generation (satellite)
# ---------------------------------------------------------------------------


def _tenant(**kw):
    kw.setdefault("prompt_len", (4, 10))
    kw.setdefault("max_new_tokens", 4)
    return TenantSpec("chat", **kw)


def test_workload_prefix_share_validation():
    with pytest.raises(ValueError):
        _tenant(prefix_share=1.5)
    with pytest.raises(ValueError):
        _tenant(prefix_share=0.5, num_prefixes=0)


def test_workload_shared_prefixes_deterministic_and_pooled():
    w = Workload(num_requests=40, rate=1e4, seed=7,
                 tenants=(_tenant(prefix_share=0.7, num_prefixes=2,
                                  prefix_len=8),))
    a, b = w.generate(), w.generate()
    assert [r.prompt for r in a] == [r.prompt for r in b]
    assert [r.arrival_t for r in a] == [r.arrival_t for r in b]
    # carriers draw from a pool of exactly num_prefixes distinct prefixes
    prefixes = {tuple(r.prompt[:8]) for r in a if len(r.prompt) > 10}
    assert 1 <= len(prefixes) <= 2
    share = sum(len(r.prompt) > 10 for r in a) / len(a)
    assert 0.4 < share < 1.0               # ~0.7 of 40 draws


def test_workload_prefix_stream_is_additive():
    """prefix_share=0 consumes nothing from the prefix stream (knob
    changes are invisible), and share>0 only PREPENDS to the legacy
    bodies — arrivals and body draws are untouched."""
    base = Workload(num_requests=24, rate=1e4, seed=11,
                    tenants=(_tenant(prefix_share=0.0),))
    knobs = Workload(num_requests=24, rate=1e4, seed=11,
                     tenants=(_tenant(prefix_share=0.0, num_prefixes=9,
                                      prefix_len=99),))
    assert [r.prompt for r in base.generate()] == \
        [r.prompt for r in knobs.generate()]
    shared = Workload(num_requests=24, rate=1e4, seed=11,
                      tenants=(_tenant(prefix_share=0.6, prefix_len=8),))
    for r0, r1 in zip(base.generate(), shared.generate()):
        assert r1.arrival_t == r0.arrival_t
        assert r1.prompt[-len(r0.prompt):] == r0.prompt
        assert len(r1.prompt) in (len(r0.prompt), len(r0.prompt) + 8)


def test_workload_prefix_stream_survives_retiming():
    """Rate changes re-time arrivals but never re-draw prompts or
    prefix-carrier picks."""
    slow = Workload(num_requests=24, rate=1e3, seed=5,
                    tenants=(_tenant(prefix_share=0.5, prefix_len=8),))
    fast = Workload(num_requests=24, rate=1e5, seed=5,
                    tenants=(_tenant(prefix_share=0.5, prefix_len=8),))
    assert [r.prompt for r in slow.generate()] == \
        [r.prompt for r in fast.generate()]


# ---------------------------------------------------------------------------
# stats summary (satellite)
# ---------------------------------------------------------------------------


def test_summary_prefix_line_and_guards():
    s = EngineStats()
    s.metrics = {"prefix": {k: 0 for k in ("lookups", "lookup_blocks",
                                           "hit_blocks", "peer_hits")}}
    assert "prefix:" not in s.summary()    # all-zero: no line, no crash
    s.metrics = {"prefix": {"lookups": 4, "lookup_blocks": 8,
                            "hit_blocks": 4, "peer_hits": 1,
                            "cow_splits": 2, "evictions": 1, "nodes": 3}}
    line = [ln for ln in s.summary().splitlines() if "prefix:" in ln]
    assert line and "50%" in line[0] and "peer-hit 25%" in line[0]
    # hits without lookup_blocks (degenerate) must not divide by zero
    s.metrics = {"prefix": {"lookups": 1}}
    assert "0%" in [ln for ln in s.summary().splitlines()
                    if "prefix:" in ln][0]


# ---------------------------------------------------------------------------
# end-to-end: bit identity + prefill savings
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def served_model():
    import jax
    from repro.models import model as M
    cfg = dataclasses.replace(get_config("yi-6b").reduced(), num_layers=2)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


# compute-bound regime: prefill flops dominate the weights-read floor for
# prompts beyond ~9 tokens, so cached-prefix savings are visible in TTFT
COMPUTE_BOUND_HW = dataclasses.replace(H100_NVLINK, peak_flops=3e13)


def _run(served_model, prompts, *, prefix_cache, max_batch=2, **kw):
    from repro.serving import HarvestServer
    cfg, params = served_model
    runtime = HarvestRuntime({1: 64 * MiB}, hardware=COMPUTE_BOUND_HW)
    kw.setdefault("scheduler", "fair")
    srv = HarvestServer(cfg, params, runtime=runtime, max_batch=max_batch,
                        block_size=8, num_local_slots=10,
                        prefix_cache=prefix_cache, **kw)
    for p in prompts:
        srv.engine.submit(p, 8)
    stats = srv.engine.run()
    return [r.output for r in srv.engine.finished], stats


def test_e2e_cache_hit_bit_identity(served_model):
    """The acceptance bit: decode under the cache is bit-identical to
    decode without it — adoption changes where prefill KV comes from,
    never its values — while hits, COW splits and per-request savings
    are recorded and prefill time strictly drops."""
    shared = list(range(3, 27))            # 3 full blocks at bs=8
    prompts = [shared + [40 + i] for i in range(4)]
    out_off, s_off = _run(served_model, prompts, prefix_cache=False)
    out_on, s_on = _run(served_model, prompts, prefix_cache=True)
    assert out_on == out_off, "prefix-cache hits changed decoded tokens"
    pfx = s_on.metrics["prefix"]
    assert pfx["hit_blocks"] >= 6 and pfx["published"] >= 3
    assert s_on.prefill_s < s_off.prefill_s, \
        "cached prefixes did not reduce prefill time (compute-bound)"
    saved = [r.cached_prefix_blocks for r in s_on.requests]
    assert sorted(saved) == [0, 0, 3, 3]   # first pair prefills, rest hit
    assert all(r.cached_prefix_blocks == 0 for r in s_off.requests)
    s_on.check_clock_identity()
    assert "prefix:" in s_on.summary()


def test_e2e_sequential_hits_lower_ttft(served_model):
    """Back-to-back identical prompts (max_batch=1, FCFS): every later
    request adopts the whole published prefix and its TTFT — measured
    from its own admission — beats the cold request's."""
    shared = list(range(50, 74))
    prompts = [list(shared) for _ in range(3)]
    out_on, s_on = _run(served_model, prompts, prefix_cache=True,
                        max_batch=1, scheduler="fcfs")
    out_off, _ = _run(served_model, prompts, prefix_cache=False,
                      max_batch=1, scheduler="fcfs")
    assert out_on == out_off
    recs = sorted(s_on.requests, key=lambda r: r.req_id)
    assert [r.cached_prefix_blocks for r in recs] == [0, 3, 3]
    cold = recs[0].first_token_t - recs[0].admit_t
    for warm in recs[1:]:
        assert warm.first_token_t - warm.admit_t < cold
