"""Continuous batching: iteration-level slot refill, chunked prefill,
the speculative-decode cost seam, bubble accounting, and the
deadline-admission prefill-backlog fix.

Token fidelity is the anchor invariant: refill timing, chunk size and
the spec seam change only the simulated clock, never which tokens the
engine emits (greedy decode is per-row deterministic).  The sync mode is
the bit-exact legacy path and refuses the new knobs.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.runtime import HarvestRuntime
from repro.core.tiers import H100_NVLINK
from repro.models import model as M
from repro.serving import HarvestServingEngine, Request, SpecDecodeConfig
from repro.serving.admission import AdmissionView, SLODeadlineAdmission

CFG = dataclasses.replace(get_config("yi-6b").reduced(), num_layers=2)
PARAMS = M.init_params(jax.random.PRNGKey(0), CFG)


def _engine(**kw):
    kw.setdefault("runtime",
                  HarvestRuntime({1: 64 * 2**20}, hardware=H100_NVLINK))
    kw.setdefault("max_batch", 2)
    kw.setdefault("block_size", 8)
    kw.setdefault("num_local_slots", 12)
    return HarvestServingEngine(CFG, PARAMS, **kw)


def _submit_mix(eng, n=4, seed=7, max_new=6):
    rng = np.random.default_rng(seed)
    for _ in range(n):
        eng.submit(list(rng.integers(3, 250,
                                     size=int(rng.integers(5, 30)))),
                   max_new)


def _outputs(eng):
    return [tuple(r.output)
            for r in sorted(eng.finished, key=lambda r: r.req_id)]


# ------------------------------------------------------- knob validation
def test_chunk_prefill_tokens_must_be_positive():
    for bad in (0, -4):
        with pytest.raises(ValueError, match="chunk_prefill_tokens"):
            _engine(mode="async", chunk_prefill_tokens=bad)


def test_chunked_prefill_needs_async_mode():
    with pytest.raises(AssertionError, match="async"):
        _engine(mode="sync", chunk_prefill_tokens=8)


def test_iter_refill_needs_async_mode():
    with pytest.raises(AssertionError, match="async"):
        _engine(mode="sync", iter_refill=True)


def test_spec_config_validation():
    with pytest.raises(ValueError, match="draft_tokens"):
        SpecDecodeConfig(draft_tokens=0)
    with pytest.raises(ValueError, match="accept_rate"):
        SpecDecodeConfig(draft_tokens=2, accept_rate=1.5)
    with pytest.raises(ValueError, match="schedule"):
        SpecDecodeConfig(draft_tokens=3, accept_rate=(0.5, 0.5))
    with pytest.raises(ValueError, match="draft_cost_frac"):
        SpecDecodeConfig(draft_tokens=2, draft_cost_frac=0.0)
    # E[accepted] = 1 (verify bonus) + a1 + a1*a2
    sd = SpecDecodeConfig(draft_tokens=2, accept_rate=(1.0, 0.5))
    assert sd.expected_accepted() == pytest.approx(2.5)


# --------------------------------------------------- token bit-identity
def test_chunked_prefill_tokens_bit_identical():
    eng_sync = _engine(mode="sync", iter_refill=False)
    eng_chunk = _engine(mode="async", chunk_prefill_tokens=5)
    for eng in (eng_sync, eng_chunk):
        _submit_mix(eng)
        eng.run()
    assert _outputs(eng_sync) == _outputs(eng_chunk)
    st = eng_chunk.stats
    assert st.prefill_s > 0
    st.check_clock_identity()


def test_spec_seam_tokens_invariant_and_counters():
    eng_plain = _engine(mode="async")
    eng_spec = _engine(mode="async",
                       spec_decode=SpecDecodeConfig(draft_tokens=3,
                                                    accept_rate=0.6))
    for eng in (eng_plain, eng_spec):
        _submit_mix(eng)
        eng.run()
    assert _outputs(eng_plain) == _outputs(eng_spec)
    spec = eng_spec.stats.metrics.get("spec", {})
    assert spec.get("draft_tokens", 0) > 0
    assert spec.get("verify_tokens", 0) > spec["draft_tokens"] / 3
    # the seam charges a different clock for the same tokens
    assert eng_spec.stats.clock_s != eng_plain.stats.clock_s
    eng_spec.stats.check_clock_identity()


# ------------------------------------------------- iteration-level refill
def test_retired_row_refills_in_the_same_step():
    eng = _engine(mode="async", max_batch=1)   # refill defaults on (async)
    a = eng.submit([5, 7, 11], 3)
    b = eng.submit([13, 17, 19], 3)
    for _ in range(100):
        eng.step()
        if a.state == "done":
            break
    assert a.state == "done"
    # the row a freed was refilled inside the SAME step() call
    assert b.state == "running"


def test_legacy_refill_waits_for_the_next_step():
    eng = _engine(mode="async", max_batch=1, iter_refill=False)
    a = eng.submit([5, 7, 11], 3)
    b = eng.submit([13, 17, 19], 3)
    for _ in range(100):
        eng.step()
        if a.state == "done":
            break
    assert a.state == "done"
    assert b.state == "waiting"   # batch-granularity admission (PR 6)


def test_chunked_prefill_resumes_across_steps():
    eng = _engine(mode="async", chunk_prefill_tokens=4)
    r = eng.submit(list(range(3, 33)), 2)      # 30 prompt tokens
    eng.step()
    assert r.needs_prefill
    assert 0 < r.prefill_pos < 30
    assert eng._remaining_prefill_s(r) > 0
    eng.run()
    assert r.state == "done" and not r.needs_prefill
    assert len(r.output) == 2


def test_chunked_first_token_streams_exactly_once():
    eng = _engine(mode="async", chunk_prefill_tokens=6)
    streamed = {}
    reqs = []
    rng = np.random.default_rng(11)
    for i in range(3):
        def on_token(tok, r, i=i):
            streamed.setdefault(i, []).append(tok)
        reqs.append(eng.submit_request(
            prompt=list(rng.integers(3, 250, size=10 + 7 * i)),
            max_new_tokens=4, on_token=on_token))
    eng.run()
    for i, r in enumerate(reqs):
        assert streamed[i] == r.output          # no token twice, none lost
        assert r.first_token_t is not None
        assert r.first_token_t >= r.arrival_t


# ---------------------------------------------------- bubble accounting
def test_bubble_charged_when_batch_empty_but_queued():
    # a prompt whose working set can never fit the local pool: admission
    # holds it forever, and the async engine must advance the clock as
    # bubble_s (the legacy sync engine spun at zero clock)
    eng = _engine(mode="async", num_local_slots=3)
    eng.submit(list(range(3, 43)), 2)          # needs ~7 blocks > 3 slots
    st = eng.run(max_steps=20)
    assert st.bubble_s > 0
    assert st.clock_s >= st.bubble_s
    st.check_clock_identity()                  # identity holds with bubble_s
    assert st.tokens_out == 0


# -------------------------------------- deadline backlog (admission fix)
def _view(now=0.0, pending=0.0, est=1.0):
    return AdmissionView(
        now=now, free_rows=2, num_slots=16, pinned_blocks=0, num_running=0,
        blocks_needed=lambda r: 2, est_prefill_s=lambda r: est,
        pending_prefill_s=pending)


def _req(i, ttft=None, priority=0):
    return Request(i, [3, 5, 7], 4, arrival_t=0.0, ttft_slo_s=ttft,
                   priority=priority)


def test_deadline_admission_counts_committed_backlog():
    # each request alone makes its 1.5s deadline behind a 1.0s prefill,
    # but the second queues behind the first's prefill: the old policy
    # admitted the convoy and then missed the tail
    pol = SLODeadlineAdmission()
    keep, shed = pol.select([_req(0, ttft=1.5), _req(1, ttft=1.5)], _view())
    assert [r.req_id for r in keep] == [0]
    assert [r.req_id for r in shed] == [1]


def test_deadline_admission_sees_inflight_chunk_backlog():
    # prefill work already committed to running chunked prefills counts
    # against every queued candidate
    pol = SLODeadlineAdmission()
    keep, shed = pol.select([_req(0, ttft=1.5)], _view(pending=1.0))
    assert not keep and [r.req_id for r in shed] == [0]


def test_deadline_admission_orders_before_walking_backlog():
    # the high-priority latecomer is judged first and survives; the
    # low-priority head absorbs the backlog and is shed
    pol = SLODeadlineAdmission()
    lo, hi = _req(0, ttft=1.5), _req(1, ttft=1.5, priority=5)
    keep, shed = pol.select([lo, hi], _view())
    assert [r.req_id for r in keep] == [1]
    assert [r.req_id for r in shed] == [0]


def test_deadline_admission_never_sheds_deadline_free():
    pol = SLODeadlineAdmission()
    keep, shed = pol.select([_req(0), _req(1)], _view(pending=99.0))
    assert len(keep) == 2 and not shed


# -------------------------------------------------------------- summary
def test_summary_prints_occupancy_and_bubble():
    eng = _engine(mode="async")
    _submit_mix(eng, n=3)
    st = eng.run()
    assert "q.batch.occupancy" in st.metrics.get("transfer", {})
    text = st.summary()
    assert "batch occupancy" in text
    assert "bubble" in text
