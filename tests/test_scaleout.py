"""Scale-out tier tests: multi-host topology presets, DCN lane routing
and charging, PR 4/PR 8 composition (coalesce / stripe / fidelity) on
DCN lanes, disaggregated prefill/decode, the ``run_until`` horizon
boundary, and the sweep model's scalar/vectorized loop equivalence.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import (H100_DCN_LINK, V5E_DCN_LINK, Fidelity,
                        MetricsRegistry, Tier, TransferEngine, channel_name,
                        get_topology)
from repro.serving import SweepConfig, SweepTrace, simulate

MiB = 1 << 20


# ---------------------------------------------------------------------------
# multi-host topology presets
# ---------------------------------------------------------------------------
class TestMultiHostPresets:

    @pytest.mark.parametrize("name,hosts,dcn", [
        ("h100-dcn-2host", 2, H100_DCN_LINK),
        ("h100-dcn-4host", 4, H100_DCN_LINK),
        ("v5e-dcn-2host", 2, V5E_DCN_LINK),
        ("v5e-dcn-4host", 4, V5E_DCN_LINK),
    ])
    def test_preset_geometry(self, name, hosts, dcn):
        topo = get_topology(name)
        assert topo.num_hosts == hosts
        assert topo.hosts == tuple(range(hosts))
        # host 0 is the local (ICI/NVLink) domain; device 0 lives there
        assert topo.host_of(0) == 0
        # every remote host contributes harvestable devices priced at DCN
        for h in range(1, hosts):
            devs = topo.devices_on(h)
            assert devs, f"host {h} exposes no devices"
            assert topo.dcn_link(h) is dcn
            for d in devs:
                assert topo.host_of(d) == h
                assert topo.peer_links[d] is dcn
        # devices_on partitions the device set
        every = [d for h in range(hosts) for d in topo.devices_on(h)]
        assert sorted(every) == sorted(topo.devices)
        # budgets cover every harvestable device, local and remote
        budgets = topo.device_budgets(8 * MiB)
        assert set(budgets) == set(topo.devices)

    def test_lane_naming(self):
        P, L = Tier.PEER_HBM, Tier.LOCAL_HBM
        # remote-host peers share their host's DCN NIC pair
        assert channel_name(P, L, device=5, host=2) == "dcn2_in"
        assert channel_name(L, P, device=5, host=2) == "dcn2_out"
        # local peers keep per-device lanes; device 1 keeps legacy names
        assert channel_name(P, L, device=3) == "peer3_in"
        assert channel_name(P, L, device=1) == "peer_in"

    def test_dcn_lane_routing_and_charging(self):
        topo = get_topology("h100-dcn-2host")
        te = TransferEngine(topo.hardware, MetricsRegistry(), topology=topo)
        remote = topo.devices_on(1)[0]
        assert te.lane_for(Tier.PEER_HBM, Tier.LOCAL_HBM, remote) == "dcn1_in"
        assert te.lane_for(Tier.LOCAL_HBM, Tier.PEER_HBM, remote) \
            == "dcn1_out"
        # the minted transfer is charged the DCN link's time, not NVLink's
        nb = 4 * MiB
        t = te.transfer(("kv", 0), nb, Tier.PEER_HBM, Tier.LOCAL_HBM,
                        device=remote)
        assert t.seconds == pytest.approx(H100_DCN_LINK.transfer_time(nb))
        assert t.seconds > topo.hardware.peer_link.transfer_time(nb)
        te.submit(t)
        snap = te.metrics.snapshot()["transfer"]
        assert snap["q.dcn1_in.submitted"] == 1
        assert snap["q.dcn1_in.busy_s"] == pytest.approx(t.seconds)

    def test_dcn_coalesce_one_setup(self):
        """PR 4 composition: same-host DCN members batch into one lane
        occupancy paying the wire setup once; members bound for a
        different host (a different lane) fall back to solo submission."""
        topo = get_topology("h100-dcn-4host")
        te = TransferEngine(topo.hardware, MetricsRegistry(), topology=topo)
        d1, d2 = topo.devices_on(1)[0], topo.devices_on(2)[0]
        nb = 2 * MiB
        mk = lambda k, dev: te.transfer(("kv", k), nb, Tier.PEER_HBM,
                                        Tier.LOCAL_HBM, device=dev)
        out = te.submit_coalesced([mk(0, d1), mk(1, d1), mk(2, d2)])
        assert len(out) == 3
        snap = te.metrics.snapshot()["transfer"]
        assert snap["q.dcn1_in.coalesced"] == 1
        assert snap["q.dcn1_in.coalesced_members"] == 2
        # the second member dropped its setup latency
        assert snap["q.dcn1_in.busy_s"] == pytest.approx(
            H100_DCN_LINK.latency + 2 * nb / H100_DCN_LINK.bandwidth)
        # the cross-host member rode its own NIC pair, solo
        assert snap["q.dcn2_in.submitted"] == 1
        assert "q.dcn2_in.coalesced" not in snap

    def test_dcn_stripe_composition(self):
        """PR 4 striping on a DCN lane: chunks ride ``dcn{h}_in.s{k}``
        sub-lanes bounded by the link's path count, bytes conserved."""
        topo = get_topology("h100-dcn-2host")
        te = TransferEngine(topo.hardware, MetricsRegistry(), topology=topo)
        remote = topo.devices_on(1)[0]
        nb = 64 * MiB
        t = te.transfer(("kv", 9), nb, Tier.PEER_HBM, Tier.LOCAL_HBM,
                        device=remote)
        # ways is capped by the DCN link's path count
        chunks = te.split(t, ways=2 * H100_DCN_LINK.paths,
                          chunk_nbytes=4 * MiB)
        assert len(chunks) == 16
        assert sum(c.nbytes for c in chunks) == nb
        lanes = {c.lane for c in chunks}
        assert lanes == {f"dcn1_in.s{k}"
                         for k in range(H100_DCN_LINK.paths)}
        done = te.submit_chunks(chunks)
        # link-disjoint sub-lanes run concurrently: the stripe finishes
        # well before the chunks would serialized on one path
        assert max(c.ready_t for c in done) \
            < sum(c.seconds for c in chunks)
        snap = te.metrics.snapshot()["transfer"]
        assert snap["q.dcn1_in.stripe_chunks"] == 16
        assert snap["q.dcn1_in.stripe_ways"] == H100_DCN_LINK.paths

    def test_fidelity_wire_bytes_on_dcn(self):
        """PR 8 composition: a quantized transfer moves (and is charged)
        only its wire bytes on the DCN link."""
        topo = get_topology("v5e-dcn-2host")
        te = TransferEngine(topo.hardware, MetricsRegistry(), topology=topo)
        remote = topo.devices_on(1)[0]
        nb = 8 * MiB
        t = te.transfer(("kv", 1), nb, Tier.LOCAL_HBM, Tier.PEER_HBM,
                        device=remote, fidelity=Fidelity.INT4)
        wire = Fidelity.INT4.wire_bytes(nb)
        assert t.nbytes == wire < nb
        assert t.seconds == pytest.approx(V5E_DCN_LINK.transfer_time(wire))
        te.submit(t)
        snap = te.metrics.snapshot()["transfer"]
        assert snap["default.peer_bytes"] == wire
        assert snap["q.dcn1_out.submitted"] == 1

    def test_submit_not_before_floors_start(self):
        """The production-event floor: a transfer (or coalesced batch)
        whose payload is minted by a future non-transfer event starts no
        earlier than that event."""
        topo = get_topology("h100-dcn-2host")
        te = TransferEngine(topo.hardware, MetricsRegistry(), topology=topo)
        remote = topo.devices_on(1)[0]
        nb = MiB
        mk = lambda k: te.transfer(("kv", k), nb, Tier.PEER_HBM,
                                   Tier.LOCAL_HBM, device=remote)
        t = te.submit(mk(0), not_before=5.0)
        assert t.ready_t == pytest.approx(5.0 + t.seconds)
        batch = te.submit_coalesced([mk(1), mk(2)], not_before=9.0)
        assert batch[0].ready_t == pytest.approx(9.0 + batch[0].seconds)
        assert batch[1].ready_t > batch[0].ready_t > 9.0

    def test_hot_state_is_slotted(self):
        """The hot per-event records carry no per-instance dict: a
        million-request sweep holds every one of them live at once."""
        from repro.core.store import Transfer
        from repro.serving.scheduler import Request
        t = Transfer(("k",), Tier.LOCAL_HBM, Tier.HOST_DRAM, 1, 1e-6)
        r = Request(req_id=0, prompt=[1], max_new_tokens=1)
        for obj in (t, r):
            assert not hasattr(obj, "__dict__")
            with pytest.raises(AttributeError):
                obj.not_a_field = 1


# ---------------------------------------------------------------------------
# disaggregated prefill/decode + run_until horizon (real engine)
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def served_model():
    import jax

    from repro.configs import get_config
    from repro.models import model as M
    cfg = dataclasses.replace(get_config("yi-6b").reduced(), num_layers=2)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _scaleout_server(cfg, params, disaggregated, topo_name="h100-dcn-2host"):
    from repro.core import (HarvestRuntime, TopologyAwarePolicy,
                            kv_block_bytes)
    from repro.serving import HarvestServer
    topo = get_topology(topo_name)
    budget = 4 * 5 * kv_block_bytes(cfg, 8)
    rt = HarvestRuntime(topo.device_budgets(budget), topology=topo,
                        policy=TopologyAwarePolicy(topo))
    kw = dict(disaggregated=True, prefill_workers=2) if disaggregated else {}
    return HarvestServer(cfg, params, runtime=rt, max_batch=2, block_size=8,
                         num_local_slots=10, scheduler="fcfs", mode="async",
                         **kw)


class TestDisaggregatedServing:

    def test_tokens_bit_identical_and_streams_over_dcn(self, served_model):
        from repro.serving import TenantSpec, Workload
        cfg, params = served_model
        wl = lambda: Workload(
            num_requests=6, arrival="poisson", rate=4e5, seed=3,
            vocab=(3, 250),
            tenants=(TenantSpec("t", prompt_len=(18, 23),
                                max_new_tokens=8),))
        outs, stats = {}, {}
        for disagg in (False, True):
            srv = _scaleout_server(cfg, params, disagg)
            stats[disagg] = srv.run(wl(), max_steps=4000)
            stats[disagg].check_clock_identity()
            outs[disagg] = [tuple(h.tokens) for h in srv.handles]
        # disaggregation re-times requests, never re-decodes them
        assert outs[True] == outs[False]
        xfer = stats[True].metrics["transfer"]
        # the prefill pool ran, and its KV streamed over the DCN NIC
        assert xfer.get("q.pf0.submitted", 0) > 0
        assert xfer.get("q.dcn1_in.submitted", 0) > 0
        assert xfer.get("q.dcn1_in.coalesced", 0) > 0
        coloc_xfer = stats[False].metrics["transfer"]
        assert "q.pf0.submitted" not in coloc_xfer

    def test_run_until_admits_horizon_arrival(self, served_model):
        """Regression: an arrival stamped exactly ``t`` is inside
        ``run_until(t)``'s horizon — it must land in the waiting queue
        (enqueue at ``t``), while arrivals after ``t`` stay queued.  The
        old ``next_arrival >= t`` comparison broke one event short."""
        from repro.serving import ServeRequest
        cfg, params = served_model
        srv = _scaleout_server(cfg, params, disaggregated=False)
        hs = [srv.submit(ServeRequest([2, 5, 7], max_new_tokens=4,
                                      arrival_t=at))
              for at in (0.5, 1.0, 1.5)]
        srv.run_until(1.0)
        eng = srv.engine
        # the 0.5 arrival was served outright; the 1.0 arrival was
        # admitted at the horizon; the 1.5 arrival is still in the future
        assert hs[0].finished and hs[0].tokens
        assert eng.next_arrival_t() == 1.5
        queued = [r for r in eng.waiting if r.req_id == hs[1].req_id]
        assert queued and queued[0].enqueue_t == pytest.approx(1.0)
        assert srv.now >= 1.0
        # the next drive picks the queued work up where the horizon left it
        srv.run_until(2.0)
        assert all(h.finished and h.tokens for h in hs)

    def test_run_until_disaggregated_streams_survive_horizon(
            self, served_model):
        """A disaggregated drive must not strand in-flight prefill
        streams: ``run_until`` keeps stepping while ``_pf_jobs`` is
        non-empty even when nothing is waiting or running."""
        from repro.serving import ServeRequest
        cfg, params = served_model
        srv = _scaleout_server(cfg, params, disaggregated=True)
        hs = [srv.submit(ServeRequest([2 + i, 5, 7, 11], max_new_tokens=4,
                                      arrival_t=0.25))
              for i in range(3)]
        srv.run_until(1.0)
        assert all(h.finished and h.tokens for h in hs)
        assert srv.now >= 1.0


# ---------------------------------------------------------------------------
# sweep model: scalar vs vectorized loop
# ---------------------------------------------------------------------------
def _assert_identical(rs, rv):
    assert rs.clock_s == rv.clock_s
    np.testing.assert_array_equal(rs.host_clock_s, rv.host_clock_s)
    np.testing.assert_array_equal(rs.admit_t, rv.admit_t)
    np.testing.assert_array_equal(rs.first_token_t, rv.first_token_t)
    np.testing.assert_array_equal(rs.finish_t, rv.finish_t)
    np.testing.assert_array_equal(rs.tokens, rv.tokens)


class TestSweepModel:

    @pytest.mark.parametrize("hosts", [1, 3])
    @pytest.mark.parametrize("disagg", [False, True])
    @pytest.mark.parametrize("process", ["poisson", "bursty"])
    def test_scalar_vector_bit_identical(self, hosts, disagg, process):
        trace = SweepTrace.generate(process, rate=800.0, n=400, seed=11)
        cfg = SweepConfig.from_family("h100", hosts=hosts,
                                      disaggregated=disagg,
                                      max_batch=4, local_slots=12,
                                      refill_interval=3)
        _assert_identical(simulate(trace, cfg, vectorized=False),
                          simulate(trace, cfg, vectorized=True))

    def test_refill_interval_one_matches_engine_style(self):
        # per-step refill (no run-leaping headroom) must stay identical
        trace = SweepTrace.generate("poisson", rate=500.0, n=200, seed=5)
        cfg = SweepConfig.from_family("v5e", hosts=2, refill_interval=1)
        _assert_identical(simulate(trace, cfg, vectorized=False),
                          simulate(trace, cfg, vectorized=True))

    def test_disagg_improves_ttft(self):
        trace = SweepTrace.generate("diurnal", rate=2e3, n=4000, seed=2)
        base = SweepConfig.from_family("h100", hosts=4)
        r_c = simulate(trace, base)
        r_d = simulate(trace, base.with_(disaggregated=True))
        assert r_d.ttft(trace).mean() < r_c.ttft(trace).mean()
        assert r_d.clock_s < r_c.clock_s       # prefill left the decode clock

    def test_trace_generation_is_deterministic(self):
        a = SweepTrace.generate("diurnal", rate=1e3, n=5000, seed=42)
        b = SweepTrace.generate("diurnal", rate=1e3, n=5000, seed=42)
        np.testing.assert_array_equal(a.arrival_t, b.arrival_t)
        np.testing.assert_array_equal(a.prompt_len, b.prompt_len)
        np.testing.assert_array_equal(a.out_len, b.out_len)
        assert np.all(np.diff(a.arrival_t) >= 0)

    def test_validation(self):
        with pytest.raises(ValueError):
            SweepConfig(hosts=0)
        with pytest.raises(ValueError):
            SweepConfig(refill_interval=0)
        with pytest.raises(ValueError):
            SweepConfig.from_family("a100")
        with pytest.raises(ValueError):
            SweepTrace(np.array([2.0, 1.0]), np.array([4, 4]),
                       np.array([4, 4]))
        with pytest.raises(ValueError):
            SweepTrace.generate("weibull")
