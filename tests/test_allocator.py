"""Harvest allocator: unit + hypothesis property tests."""
import pytest

pytest.importorskip("hypothesis",
                    reason="property tests need the optional hypothesis dep")
from hypothesis import given, settings, strategies as st

from repro.core import (BestFitPolicy, FairnessPolicy, HarvestAllocator,
                        LocalityPolicy, RevokedError, StabilityPolicy,
                        WorstFitPolicy)
from repro.core.allocator import _FreeList


def test_alloc_free_roundtrip():
    a = HarvestAllocator({0: 1000})
    h = a.harvest_alloc(400)
    assert h is not None and h.size == 400
    assert a.device_view()[0]["free"] == 600
    a.harvest_free(h)
    assert a.device_view()[0]["free"] == 1000
    with pytest.raises(RevokedError):
        a.harvest_free(h)


def test_alloc_failure_returns_none():
    a = HarvestAllocator({0: 100})
    assert a.harvest_alloc(101) is None
    assert a.stats["failed"] == 1


def test_best_fit_picks_tightest_device():
    a = HarvestAllocator({0: 1000, 1: 500})
    h = a.harvest_alloc(450)
    assert h.device == 1          # tighter fit


def test_revocation_order_and_callback():
    a = HarvestAllocator({0: 1000})
    h1, h2, h3 = (a.harvest_alloc(300) for _ in range(3))
    revoked = []
    for h in (h1, h2, h3):
        a.harvest_register_cb(h, lambda hh: revoked.append(hh.handle_id))
    out = a.update_budget(0, 350)
    # newest-first revocation until usage fits
    assert [h.handle_id for h in out] == [h3.handle_id, h2.handle_id]
    assert revoked == [h3.handle_id, h2.handle_id]
    assert a.is_live(h1) and not a.is_live(h2)


def test_drain_blocks_revocation_with_inflight_io():
    a = HarvestAllocator({0: 100})
    h = a.harvest_alloc(100)
    a.begin_io(h)
    with pytest.raises(RuntimeError):
        a.update_budget(0, 0)
    a.end_io(h)
    assert a.update_budget(0, 0)[0].handle_id == h.handle_id


def test_fairness_policy_caps_client():
    pol = FairnessPolicy(BestFitPolicy(), per_client_bytes=500)
    a = HarvestAllocator({0: 10_000}, policy=pol)
    assert a.harvest_alloc(400, client="tenant-a") is not None
    assert a.harvest_alloc(400, client="tenant-a") is None
    assert a.harvest_alloc(400, client="tenant-b") is not None


def test_locality_policy_prefers_near_device():
    pol = LocalityPolicy(num_devices=8)
    a = HarvestAllocator({d: 1000 for d in range(8)}, policy=pol)
    h = a.harvest_alloc(100, hints={"requester_device": 3})
    assert h.device == 3
    h2 = a.harvest_alloc(1000, hints={"requester_device": 3})
    assert h2.device in (2, 4)    # ring-adjacent once 3 can't fit


@settings(max_examples=200, deadline=None)
@given(st.lists(st.tuples(st.booleans(), st.integers(1, 64)), max_size=60))
def test_freelist_invariants(ops):
    """Property: free bytes conserved; segments sorted, coalesced, disjoint."""
    fl = _FreeList(256)
    live = []
    for is_alloc, size in ops:
        if is_alloc:
            off = fl.best_fit(size)
            if off is not None:
                live.append((off, size))
        elif live:
            off, size = live.pop()
            fl.release(off, size)
    # invariant 1: conservation
    assert fl.free_bytes == 256 - sum(s for _, s in live)
    # invariant 2: sorted, coalesced, non-overlapping
    segs = fl.segments
    for (o1, s1), (o2, s2) in zip(segs, segs[1:]):
        assert o1 + s1 < o2, "adjacent free segments must be coalesced"
    # invariant 3: no free segment overlaps a live allocation
    for off, size in live:
        for o, s in segs:
            assert off + size <= o or o + s <= off


@settings(max_examples=100, deadline=None)
@given(st.lists(st.integers(10, 200), min_size=1, max_size=20),
       st.integers(0, 1000))
def test_budget_shrink_always_fits(sizes, new_budget):
    """Property: after update_budget, usage <= budget (or no allocs left)."""
    a = HarvestAllocator({0: 2000})
    for s in sizes:
        a.harvest_alloc(s)
    a.update_budget(0, new_budget)
    used = sum(h.size for h in a.live_handles())
    assert used <= max(new_budget, 0) or not a.live_handles()
