"""Harvest allocator: unit + hypothesis property tests.

The unit tests (including the freelist double-free regressions) always
run; the ``@given`` property tests skip individually when the optional
``hypothesis`` dep is absent instead of skipping the whole module.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:             # minimal-deps env: skip ONLY property tests
    def given(*_a, **_k):
        return lambda f: pytest.mark.skip(
            "property tests need the optional hypothesis dep")(f)

    def settings(*_a, **_k):
        return lambda f: f

    class _StubStrategies:
        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _StubStrategies()

from repro.core import (BestFitPolicy, FairnessPolicy, HarvestAllocator,
                        LocalityPolicy, RevokedError, StabilityPolicy,
                        WorstFitPolicy)
from repro.core.allocator import _FreeList


def test_alloc_free_roundtrip():
    a = HarvestAllocator({0: 1000})
    h = a.harvest_alloc(400)
    assert h is not None and h.size == 400
    assert a.device_view()[0]["free"] == 600
    a.harvest_free(h)
    assert a.device_view()[0]["free"] == 1000
    with pytest.raises(RevokedError):
        a.harvest_free(h)


def test_alloc_failure_returns_none():
    a = HarvestAllocator({0: 100})
    assert a.harvest_alloc(101) is None
    assert a.stats["failed"] == 1


def test_best_fit_picks_tightest_device():
    a = HarvestAllocator({0: 1000, 1: 500})
    h = a.harvest_alloc(450)
    assert h.device == 1          # tighter fit


def test_revocation_order_and_callback():
    a = HarvestAllocator({0: 1000})
    h1, h2, h3 = (a.harvest_alloc(300) for _ in range(3))
    revoked = []
    for h in (h1, h2, h3):
        a.harvest_register_cb(h, lambda hh: revoked.append(hh.handle_id))
    out = a.update_budget(0, 350)
    # newest-first revocation until usage fits
    assert [h.handle_id for h in out] == [h3.handle_id, h2.handle_id]
    assert revoked == [h3.handle_id, h2.handle_id]
    assert a.is_live(h1) and not a.is_live(h2)


def test_drain_blocks_revocation_with_inflight_io():
    a = HarvestAllocator({0: 100})
    h = a.harvest_alloc(100)
    a.begin_io(h)
    with pytest.raises(RuntimeError):
        a.update_budget(0, 0)
    a.end_io(h)
    assert a.update_budget(0, 0)[0].handle_id == h.handle_id


def test_fairness_policy_caps_client():
    pol = FairnessPolicy(BestFitPolicy(), per_client_bytes=500)
    a = HarvestAllocator({0: 10_000}, policy=pol)
    assert a.harvest_alloc(400, client="tenant-a") is not None
    assert a.harvest_alloc(400, client="tenant-a") is None
    assert a.harvest_alloc(400, client="tenant-b") is not None


def test_locality_policy_prefers_near_device():
    pol = LocalityPolicy(num_devices=8)
    a = HarvestAllocator({d: 1000 for d in range(8)}, policy=pol)
    h = a.harvest_alloc(100, hints={"requester_device": 3})
    assert h.device == 3
    h2 = a.harvest_alloc(1000, hints={"requester_device": 3})
    assert h2.device in (2, 4)    # ring-adjacent once 3 can't fit


# ---------------------------------------------------------------------------
# _FreeList.release hardening: double frees / overlapping segments used to
# be silently coalesced into corrupted state; now they are rejected loudly
# ---------------------------------------------------------------------------


def test_release_rejects_double_free():
    fl = _FreeList(256)
    off = fl.best_fit(64)
    fl.release(off, 64)
    with pytest.raises(ValueError, match="double free"):
        fl.release(off, 64)
    # state is unchanged by the rejected release
    assert fl.free_bytes == 256
    assert fl.segments == [(0, 256)]


def test_release_rejects_partial_overlap():
    fl = _FreeList(256)
    a = fl.best_fit(64)
    b = fl.best_fit(64)
    fl.release(a, 64)
    with pytest.raises(ValueError, match="double free"):
        fl.release(b - 8, 64)        # tail overlaps the freed [a, a+64)
    fl.release(b, 64)                # the exact segment is still fine
    assert fl.free_bytes == 256


def test_release_rejects_out_of_range_and_degenerate():
    fl = _FreeList(128)
    fl.best_fit(128)
    with pytest.raises(ValueError, match="outside freelist"):
        fl.release(64, 128)          # runs past capacity
    with pytest.raises(ValueError, match="outside freelist"):
        fl.release(-8, 8)
    with pytest.raises(ValueError, match="outside freelist"):
        fl.release(0, 0)


@settings(max_examples=100, deadline=None)
@given(st.lists(st.tuples(st.booleans(), st.integers(1, 64)), max_size=60),
       st.integers(0, 255), st.integers(1, 64))
def test_freelist_rejects_any_overlapping_release(ops, off, size):
    """Property: releasing a region that intersects free space always
    raises, and the rejected call never mutates the free list."""
    fl = _FreeList(256)
    live = []
    for is_alloc, sz in ops:
        if is_alloc:
            o = fl.best_fit(sz)
            if o is not None:
                live.append((o, sz))
        elif live:
            o, sz = live.pop()
            fl.release(o, sz)
    overlaps_free = any(off < o + s and o < off + size
                        for o, s in fl.segments)
    in_range = 0 <= off and off + size <= 256
    before = list(fl.segments)
    if overlaps_free or not in_range:
        with pytest.raises(ValueError):
            fl.release(off, size)
        assert fl.segments == before
    else:
        fl.release(off, size)
        assert fl.free_bytes == sum(s for _, s in before) + size


@settings(max_examples=200, deadline=None)
@given(st.lists(st.tuples(st.booleans(), st.integers(1, 64)), max_size=60))
def test_freelist_invariants(ops):
    """Property: free bytes conserved; segments sorted, coalesced, disjoint."""
    fl = _FreeList(256)
    live = []
    for is_alloc, size in ops:
        if is_alloc:
            off = fl.best_fit(size)
            if off is not None:
                live.append((off, size))
        elif live:
            off, size = live.pop()
            fl.release(off, size)
    # invariant 1: conservation
    assert fl.free_bytes == 256 - sum(s for _, s in live)
    # invariant 2: sorted, coalesced, non-overlapping
    segs = fl.segments
    for (o1, s1), (o2, s2) in zip(segs, segs[1:]):
        assert o1 + s1 < o2, "adjacent free segments must be coalesced"
    # invariant 3: no free segment overlaps a live allocation
    for off, size in live:
        for o, s in segs:
            assert off + size <= o or o + s <= off


@settings(max_examples=100, deadline=None)
@given(st.lists(st.integers(10, 200), min_size=1, max_size=20),
       st.integers(0, 1000))
def test_budget_shrink_always_fits(sizes, new_budget):
    """Property: after update_budget, usage <= budget (or no allocs left)."""
    a = HarvestAllocator({0: 2000})
    for s in sizes:
        a.harvest_alloc(s)
    a.update_budget(0, new_budget)
    used = sum(h.size for h in a.live_handles())
    assert used <= max(new_budget, 0) or not a.live_handles()


@settings(max_examples=100, deadline=None)
@given(st.lists(st.integers(10, 200), min_size=1, max_size=20),
       st.integers(0, 1000))
def test_revocation_is_drain_then_invalidate_then_notify(sizes, new_budget):
    """Property: revocation strictly follows drain -> invalidate -> notify.

    * drain: while ANY region has in-flight IO, the budget shrink refuses
      to complete (stream-sync stand-in);
    * invalidate: inside the callback the handle is already dead and its
      segment already back on the free list;
    * notify: callbacks fire newest-first, exactly once per handle.
    """
    a = HarvestAllocator({0: 2000})
    handles = []
    for s in sizes:
        h = a.harvest_alloc(s)
        if h is not None:
            handles.append(h)
    order = []

    def cb(h):
        assert not a.is_live(h), "invalidate must precede notify"
        # the segment is already back on the free list at notify time
        fl = a._devices[0].freelist
        assert any(o <= h.offset and h.offset + h.size <= o + s
                   for o, s in fl.segments)
        order.append(h.handle_id)

    for h in handles:
        a.harvest_register_cb(h, cb)
    will_revoke = sum(h.size for h in handles) > new_budget
    if handles and will_revoke:
        # IO on the NEWEST handle — the first revocation victim — so the
        # drain gate is guaranteed to be on the revocation path
        pinned = handles[-1]
        a.begin_io(pinned)       # drain gate: revocation must refuse
        with pytest.raises(RuntimeError):
            a.update_budget(0, new_budget)
        assert order == [], "no notification may fire before drain passes"
        a.end_io(pinned)
    revoked = a.update_budget(0, new_budget)
    assert order == [h.handle_id for h in revoked]
    assert len(order) == len(set(order)), "notify fires exactly once"
    # newest-first revocation order
    alloc_order = [h.handle_id for h in handles]
    assert order == sorted(order, key=alloc_order.index, reverse=True)
    used = sum(h.size for h in a.live_handles())
    assert a._devices[0].freelist.free_bytes + used == 2000
