"""Property-based tests (hypothesis) on the Harvest runtime's invariants.

Invariants under arbitrary alloc/free/budget-update interleavings:
  * no two live allocations on a device overlap (exclusive segments);
  * per-device usage == sum of live allocation sizes, and never exceeds the
    device budget after every operation settles;
  * free-list bytes + used bytes == freelist capacity (conservation);
  * revocation fires the callback exactly once, after invalidation
    (``is_live`` is already False inside the callback);
  * freeing or re-registering a revoked handle raises;
  * the KV block table never maps a block to two tiers at once, and lost
    blocks are reported lost until rewritten.

Transfer-timeline invariants under random submit batches:
  * no transfer completes before it was issued (ready >= issue + seconds);
  * per-lane FIFO order holds (ready times non-decreasing in submit order);
  * each lane drains in exactly the legacy ``schedule()`` serial sum of
    its transfers, and the batch makespan is the busiest lane — the
    event-driven clock and the sync-mode reduction agree;
  * ``drain_until(t)`` completes exactly the transfers with ready <= t.
"""
from __future__ import annotations

import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="property tests need the optional hypothesis dep")
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.core.allocator import HarvestAllocator, RevokedError
from repro.core.kv_manager import KVOffloadManager
from repro.core.monitor import ClusterTrace, ClusterTraceConfig, PeerMonitor
from repro.core.policy import (BestFitPolicy, LocalityPolicy, StabilityPolicy,
                               WorstFitPolicy)
from repro.core.store import TransferEngine
from repro.core.tiers import TPU_V5E, Tier

MiB = 2**20

op_strategy = st.lists(
    st.one_of(
        st.tuples(st.just("alloc"), st.integers(1, 64)),       # size MiB
        st.tuples(st.just("free"), st.integers(0, 200)),       # index
        st.tuples(st.just("budget"),
                  st.integers(0, 3), st.integers(0, 256)),     # dev, MiB
    ),
    min_size=1, max_size=120,
)


def _check_invariants(alloc: HarvestAllocator):
    for dev_id, dev in alloc._devices.items():
        live = [h for h in alloc.live_handles() if h.device == dev_id]
        # exclusive segments
        segs = sorted((h.offset, h.size) for h in live)
        for (o1, s1), (o2, _) in zip(segs, segs[1:]):
            assert o1 + s1 <= o2, "overlapping live allocations"
        # usage accounting
        assert dev.used == sum(h.size for h in live)
        assert dev.used <= max(dev.budget, 0) or not live
        # conservation: freelist + live == capacity
        assert dev.freelist.free_bytes + dev.used == dev.freelist.capacity


@settings(max_examples=60, deadline=None)
@given(ops=op_strategy, policy_idx=st.integers(0, 3))
def test_allocator_invariants_under_interleaving(ops, policy_idx):
    policy = [BestFitPolicy(), WorstFitPolicy(), LocalityPolicy(4),
              StabilityPolicy()][policy_idx]
    alloc = HarvestAllocator({d: 256 * MiB for d in range(4)}, policy=policy)
    handles = []
    revoked = []

    def cb(h):
        assert not alloc.is_live(h), "callback must fire after invalidation"
        revoked.append(h.handle_id)

    for op in ops:
        if op[0] == "alloc":
            h = alloc.harvest_alloc(op[1] * MiB)
            if h is not None:
                alloc.harvest_register_cb(h, cb)
                handles.append(h)
        elif op[0] == "free":
            if handles:
                h = handles.pop(op[1] % len(handles))
                if alloc.is_live(h):
                    alloc.harvest_free(h)
        else:
            _, dev, mib = op
            alloc.update_budget(dev, mib * MiB)
        _check_invariants(alloc)

    # each revocation fired exactly once
    assert len(revoked) == len(set(revoked)) == alloc.stats["revocations"]


@settings(max_examples=30, deadline=None)
@given(budget=st.integers(0, 64), size=st.integers(1, 16))
def test_revoked_handle_is_dead(budget, size):
    alloc = HarvestAllocator({0: 64 * MiB})
    h = alloc.harvest_alloc(size * MiB)
    assert h is not None
    alloc.update_budget(0, 0)          # revoke everything
    assert not alloc.is_live(h)
    try:
        alloc.harvest_free(h)
        raise AssertionError("free of revoked handle must raise")
    except RevokedError:
        pass
    try:
        alloc.harvest_register_cb(h, lambda _: None)
        raise AssertionError("register on revoked handle must raise")
    except RevokedError:
        pass
    alloc.update_budget(0, budget * MiB)
    h2 = alloc.harvest_alloc(size * MiB)
    assert (h2 is not None) == (budget >= size)


@settings(max_examples=40, deadline=None)
@given(seq=st.lists(st.integers(0, 2), min_size=1, max_size=60),
       seed=st.integers(0, 5))
def test_drain_blocks_revocation(seq, seed):
    """Revocation must not complete while IO is in flight on the region."""
    alloc = HarvestAllocator({0: 8 * MiB})
    h = alloc.harvest_alloc(4 * MiB)
    alloc.begin_io(h)
    try:
        alloc.update_budget(0, 0)
        raise AssertionError("revocation with in-flight IO must raise")
    except RuntimeError:
        pass
    alloc.end_io(h)
    revoked = alloc.update_budget(0, 0)
    assert [r.handle_id for r in revoked] == [h.handle_id]


@settings(max_examples=25, deadline=None)
@given(steps=st.integers(1, 40), seed=st.integers(0, 100))
def test_monitor_budgets_track_trace(steps, seed):
    cfgm = ClusterTraceConfig(num_devices=4, capacity_bytes=256 * MiB,
                              seed=seed)
    trace = ClusterTrace(cfgm)
    alloc = HarvestAllocator({d: 256 * MiB for d in range(4)})
    mon = PeerMonitor(alloc, trace, capacity_bytes=256 * MiB,
                      reserve_bytes=16 * MiB)
    # grab as much as possible, then let the trace churn
    while alloc.harvest_alloc(8 * MiB) is not None:
        pass
    for _ in range(steps):
        budgets = mon.tick()
        for d, b in budgets.items():
            assert b >= 0
            assert alloc._devices[d].used <= max(b, 0) or b == 0
        _check_invariants(alloc)


# ---------------------------------------------------------------------------
# transfer timeline
# ---------------------------------------------------------------------------

# (src, dst) pairs covering all four duplex lanes
_ROUTES = [(Tier.PEER_HBM, Tier.LOCAL_HBM), (Tier.LOCAL_HBM, Tier.PEER_HBM),
           (Tier.HOST_DRAM, Tier.LOCAL_HBM), (Tier.LOCAL_HBM, Tier.HOST_DRAM)]

batch_strategy = st.lists(
    st.tuples(st.integers(0, 3), st.integers(1, 64)),   # route, size MiB
    min_size=1, max_size=40)


@settings(max_examples=60, deadline=None)
@given(batch=batch_strategy)
def test_timeline_fifo_and_sync_equivalence(batch):
    te = TransferEngine(TPU_V5E)
    ops = []
    for i, (route, mib) in enumerate(batch):
        src, dst = _ROUTES[route]
        ops.append(te.submit(te.transfer(i, mib * MiB, src, dst)))

    by_lane = {}
    for op in ops:
        # no transfer completes before issue (+ its own link time)
        assert op.ready_t >= op.issue_t + op.seconds - 1e-15
        by_lane.setdefault(op.channel, []).append(op)

    for lane_ops in by_lane.values():
        # per-lane FIFO: ready times non-decreasing in submit order
        for a, b in zip(lane_ops, lane_ops[1:]):
            assert a.ready_t <= b.ready_t + 1e-15
        # each lane drains in exactly the legacy schedule() serial sum
        assert lane_ops[-1].ready_t == pytest.approx(
            te.schedule(lane_ops), rel=1e-12)

    # batch makespan == busiest lane == link-overlapped legacy schedule
    makespan = max(op.ready_t for op in ops)
    assert makespan == pytest.approx(
        max(te.schedule(v) for v in by_lane.values()), rel=1e-12)
    # and the serial legacy total is the sum over lanes
    assert te.schedule(ops) == pytest.approx(
        sum(te.schedule(v) for v in by_lane.values()), rel=1e-12)

    done = te.drain_until(makespan)
    assert len(done) == len(ops) and all(op.done for op in ops)
    assert te.pending() == 0


@settings(max_examples=40, deadline=None)
@given(batch=batch_strategy, cut=st.floats(0.0, 1.0))
def test_timeline_partial_drain(batch, cut):
    te = TransferEngine(TPU_V5E)
    ops = []
    for i, (route, mib) in enumerate(batch):
        src, dst = _ROUTES[route]
        ops.append(te.submit(te.transfer(i, mib * MiB, src, dst)))
    t = cut * max(op.ready_t for op in ops)
    done = {op.key for op in te.drain_until(t)}
    for op in ops:
        assert (op.key in done) == (op.ready_t <= t)
        assert op.done == (op.ready_t <= t)
    assert te.pending() == len(ops) - len(done)


@settings(max_examples=20, deadline=None)
@given(n_req=st.integers(1, 6), blocks_per=st.integers(1, 8),
       evictions=st.integers(0, 30), seed=st.integers(0, 50))
def test_kv_block_table_residency(n_req, blocks_per, evictions, seed):
    """Every block is in exactly one tier; lost blocks stay lost."""
    rng = np.random.default_rng(seed)
    cfg = get_config("yi-6b").reduced()
    n_blocks = n_req * blocks_per
    local_slots = max(n_blocks // 2, 2)
    alloc = HarvestAllocator({1: 64 * MiB})
    kv = KVOffloadManager(cfg, alloc, TPU_V5E, block_size=16,
                          num_local_slots=local_slots)
    for r in range(n_req):
        for j in range(blocks_per):
            kv.allocate_block(r, j, j * 16)

    for _ in range(evictions):
        r = int(rng.integers(0, n_req))
        if rng.random() < 0.5:
            kv.evict_request(r)
        else:
            for op in kv.ensure_resident(r, int(rng.integers(0, blocks_per))):
                assert op.seconds > 0
        # every tracked block is in exactly one tier (tier is a function)
        counts = kv.tier_counts()
        assert sum(counts.values()) == len(kv.table)
        # no local slot double-booked
        slots = [e.local_slot for e in kv.table.values()
                 if e.tier == Tier.LOCAL_HBM]
        assert len(slots) == len(set(slots))
        assert len(slots) + len(kv.free_slots) == local_slots
    # device budgets respected throughout
    _check_invariants(alloc)
