"""Fidelity-tiered KV + SSD cold tier (the fidelity-tiers PR).

Covers the tentpole end to end:
  * Fidelity wire-byte math (exact FP16 identity, integer quantized
    ratios + per-block scale overhead);
  * fidelity-aware TransferEngine estimates and byte accounting;
  * the HarvestStore demote path: ``fidelity_fn`` decides the precision
    BEFORE the evict hook fires, the allocator is charged wire bytes,
    quantize/dequantize compute rides the engine clock, and a reloaded
    slot is always full precision again;
  * the SSD cold-tier rung: RECONSTRUCTIBLE evictions take SSD over
    host when peer allocation fails, BACKED write-backs overflow onto
    SSD once ``host_capacity_bytes`` is spent, both reload over the
    calibrated SSD link;
  * FidelityPolicy per-SLO mapping + validation;
  * prefix-cache content digests never alias across fidelities;
  * engine e2e: latency-class tokens bit-identical to the fidelity-off
    baseline, quantized batch-class decode completes within tolerance,
    and the constructor/CLI knobs validate their inputs.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (FIDELITY_POLICIES, Fidelity, FidelityPolicy,
                        HarvestAllocator, HarvestRuntime, KVOffloadManager,
                        Residency, Tier, TransferEngine)
from repro.core.prefix_cache import PrefixCache, PrefixCacheConfig
from repro.core.tiers import (FIDELITY_SCALE_BYTES, H100_NVLINK, TPU_V5E)
from repro.serving.engine import HarvestServingEngine

MiB = 2**20


def _kv(durability, slots=2, budget_mib=64, hw=TPU_V5E, **kw):
    cfg = get_config("yi-6b").reduced()
    alloc = HarvestAllocator({0: budget_mib * MiB})
    kv = KVOffloadManager(cfg, alloc, hw, block_size=16,
                          num_local_slots=slots, durability=durability, **kw)
    return kv, alloc


# ---------------------------------------------------------------------------
# Fidelity math
# ---------------------------------------------------------------------------


def test_fp16_wire_bytes_is_exact_identity():
    """FP16 is the seed path: wire bytes == object bytes, no scale tax —
    this is what keeps fidelity-off runs byte- and clock-exact."""
    for nb in (0, 1, 7, 4096, 13 * MiB):
        assert Fidelity.FP16.wire_bytes(nb) == nb
    assert not Fidelity.FP16.is_quantized


@pytest.mark.parametrize("fid,num,den", [
    (Fidelity.INT8, 1, 2), (Fidelity.FP8, 1, 2), (Fidelity.INT4, 1, 4)])
def test_quantized_wire_bytes_ratio(fid, num, den):
    for nb in (2, 64, 4096, 3 * MiB):
        assert fid.wire_bytes(nb) == nb * num // den + FIDELITY_SCALE_BYTES
    assert fid.is_quantized


def test_transfer_engine_estimate_scales_by_fidelity():
    te = TransferEngine(H100_NVLINK)
    nb = 4 * MiB
    full = te.estimate(nb, Tier.LOCAL_HBM, Tier.PEER_HBM)
    int8 = te.estimate(nb, Tier.LOCAL_HBM, Tier.PEER_HBM,
                       fidelity=Fidelity.INT8)
    int4 = te.estimate(nb, Tier.LOCAL_HBM, Tier.PEER_HBM,
                       fidelity=Fidelity.INT4)
    link = H100_NVLINK.peer_link
    assert full == pytest.approx(link.latency + nb / link.bandwidth)
    assert int8 == pytest.approx(
        link.latency + Fidelity.INT8.wire_bytes(nb) / link.bandwidth)
    assert int4 < int8 < full


def test_transfer_carries_wire_bytes_and_fidelity():
    te = TransferEngine(TPU_V5E)
    t = te.transfer(("b", 0), 1 * MiB, Tier.LOCAL_HBM, Tier.PEER_HBM,
                    fidelity=Fidelity.INT8)
    assert t.fidelity is Fidelity.INT8
    assert t.nbytes == Fidelity.INT8.wire_bytes(1 * MiB)
    # byte counters account what actually crossed the wire
    snap = te.metrics.snapshot()["transfer"]
    moved = sum(v for k, v in snap.items() if k.endswith("_bytes"))
    assert moved == t.nbytes


def test_ssd_link_is_calibrated_and_routed():
    """LOCAL_SSD pairs route over the hardware's ssd_link in both preset
    families, below the host-DRAM rung in bandwidth."""
    for hw in (H100_NVLINK, TPU_V5E):
        est = TransferEngine(hw).estimate(8 * MiB, Tier.LOCAL_SSD,
                                          Tier.LOCAL_HBM)
        assert est == pytest.approx(
            hw.ssd_link.latency + 8 * MiB / hw.ssd_link.bandwidth)
        assert hw.ssd_link.bandwidth < hw.host_link.bandwidth
    assert H100_NVLINK.ssd_link.bandwidth > TPU_V5E.ssd_link.bandwidth


def test_ssd_transfers_ride_their_own_lanes():
    te = TransferEngine(H100_NVLINK)
    out = te.transfer(("s", 0), MiB, Tier.LOCAL_HBM, Tier.LOCAL_SSD)
    back = te.transfer(("s", 0), MiB, Tier.LOCAL_SSD, Tier.LOCAL_HBM)
    assert te.lane_of(out) != te.lane_of(back)
    assert {te.lane_of(out), te.lane_of(back)} == {"ssd_out", "ssd_in"}


# ---------------------------------------------------------------------------
# store demote/reload accounting
# ---------------------------------------------------------------------------


def test_store_quantized_demote_charges_wire_bytes():
    kv, alloc = _kv("host_backed", slots=1)
    kv.fidelity_fn = lambda key: Fidelity.INT8
    kv.allocate_block(0, 0, 0)
    ops = kv.allocate_block(1, 0, 0)[1]      # evicts (0,0) to peer
    ent = kv.table[(0, 0)]
    wire = Fidelity.INT8.wire_bytes(kv.block_nbytes)
    assert ent.state is Residency.PEER
    assert ent.fidelity is Fidelity.INT8
    assert ent.nbytes == kv.block_nbytes, \
        "bookkeeping size stays full precision; fidelity describes the copy"
    # the allocator granted a WIRE-sized peer segment (half the slot)
    assert alloc.device_view()[0]["used"] == wire
    # the eviction transfer moved wire bytes + the quantize compute pass
    evict = ops[-1]
    assert evict.fidelity is Fidelity.INT8 and evict.nbytes == wire
    te = kv.store.transfers
    quant_s = kv.block_nbytes / te.hw.hbm_bw
    assert evict.seconds == pytest.approx(
        te.estimate(wire, Tier.LOCAL_HBM, Tier.PEER_HBM, device=0) + quant_s)
    fid = kv.store.fid_stats
    assert fid["demote_quantized"] == 1 and fid["demote_int8"] == 1
    assert fid["bytes_saved"] == kv.block_nbytes - wire
    assert fid["quant_s"] == pytest.approx(quant_s)


def test_store_reload_dequantizes_and_restores_fp16():
    kv, _ = _kv("host_backed", slots=1)
    kv.fidelity_fn = lambda key: Fidelity.INT4
    kv.allocate_block(0, 0, 0)
    kv.allocate_block(1, 0, 0)
    kv.free_request(1)
    seen = {}
    kv.reload_hook = lambda key, slot: seen.setdefault(
        "fid", kv.table[key].fidelity)
    ops = kv.ensure_resident(0, 0)
    ent = kv.table[(0, 0)]
    te = kv.store.transfers
    wire = Fidelity.INT4.wire_bytes(kv.block_nbytes)
    dequant_s = kv.block_nbytes / te.hw.hbm_bw
    # the hook saw the wire precision (it picks the dequantize kernel)...
    assert seen["fid"] is Fidelity.INT4
    # ...but the local slot is full precision again afterwards
    assert ent.fidelity is Fidelity.FP16
    assert ops[-1].nbytes == wire
    assert ops[-1].seconds == pytest.approx(
        te.estimate(wire, Tier.PEER_HBM, Tier.LOCAL_HBM, device=0)
        + dequant_s)
    fid = kv.store.fid_stats
    assert fid["reload_dequantized"] == 1
    assert fid["dequant_s"] == pytest.approx(dequant_s)


def test_fidelity_decided_before_evict_hook_fires():
    """The evict hook must be able to read ``ent.fidelity`` to pick the
    quantize kernel — the regression is deciding the fidelity after."""
    kv, _ = _kv("host_backed", slots=1)
    kv.fidelity_fn = lambda key: Fidelity.FP8
    at_hook = {}
    kv.evict_hook = lambda key, slot: at_hook.setdefault(
        key, kv.table[key].fidelity)
    kv.allocate_block(0, 0, 0)
    kv.allocate_block(1, 0, 0)
    assert at_hook[(0, 0)] is Fidelity.FP8


def test_default_fidelity_path_is_seed_exact():
    """No fidelity_fn (the default): every demotion is FP16 and the fid
    counters never move — byte-for-byte the seed behaviour."""
    kv, alloc = _kv("host_backed", slots=1)
    kv.allocate_block(0, 0, 0)
    kv.allocate_block(1, 0, 0)
    assert kv.table[(0, 0)].fidelity is Fidelity.FP16
    assert alloc.device_view()[0]["used"] == kv.block_nbytes
    assert all(v == 0 for v in kv.store.fid_stats.values())
    counts = {f: n for f, n in kv.store.fidelity_counts().items() if n}
    assert counts == {"fp16": 2}


def test_fidelity_counts_census():
    kv, _ = _kv("host_backed", slots=1)
    kv.fidelity_fn = lambda key: Fidelity.INT8
    kv.allocate_block(0, 0, 0)
    kv.allocate_block(1, 0, 0)
    counts = {f: n for f, n in kv.store.fidelity_counts().items() if n}
    assert counts == {"fp16": 1, "int8": 1}


# ---------------------------------------------------------------------------
# SSD cold tier
# ---------------------------------------------------------------------------


def test_reconstructible_eviction_takes_ssd_over_host():
    """With the cold tier on, a RECONSTRUCTIBLE block whose peer
    allocation fails lands on SSD (durable, cheaper than host) instead
    of the host write-through."""
    kv, _ = _kv("lossy", slots=1, budget_mib=0, ssd_tier=True)
    kv.allocate_block(0, 0, 0)
    ops = kv.allocate_block(1, 0, 0)[1]
    ent = kv.table[(0, 0)]
    assert ent.state is Residency.SSD
    assert ent.tier is Tier.LOCAL_SSD
    assert ops[-1].dst is Tier.LOCAL_SSD
    assert kv.stats["evict_to_ssd"] == 1 and kv.stats["evict_to_host"] == 0
    # and it reloads over the SSD link, not the host link
    kv.free_request(1)
    back = kv.ensure_resident(0, 0)
    assert kv.stats["reload_ssd"] == 1
    assert back[-1].src is Tier.LOCAL_SSD
    assert kv.table[(0, 0)].state is Residency.LOCAL


def test_backed_eviction_overflows_host_onto_ssd():
    """BACKED blocks keep using host DRAM until ``host_capacity_bytes``
    is spent; the overflow takes the SSD rung."""
    kv, _ = _kv("host_backed", slots=1, budget_mib=0, ssd_tier=True)
    # capacity for exactly one full-precision block
    kv.store.host_capacity_bytes = kv.block_nbytes
    kv.allocate_block(0, 0, 0)
    kv.allocate_block(1, 0, 0)      # evicts (0,0): host has room
    assert kv.table[(0, 0)].state is Residency.HOST
    kv.allocate_block(2, 0, 0)      # evicts (1,0): host budget spent
    assert kv.table[(1, 0)].state is Residency.SSD
    assert kv.stats["evict_to_host"] == 1 and kv.stats["evict_to_ssd"] == 1


def test_ssd_off_keeps_the_seed_ladder():
    kv, _ = _kv("lossy", slots=1, budget_mib=0)
    kv.allocate_block(0, 0, 0)
    kv.allocate_block(1, 0, 0)
    assert kv.table[(0, 0)].state is Residency.HOST
    assert kv.stats["evict_to_ssd"] == 0


# ---------------------------------------------------------------------------
# FidelityPolicy
# ---------------------------------------------------------------------------


def test_fidelity_policy_slo_mapping():
    pol = FidelityPolicy(mode="slo")
    assert pol.fidelity_for("latency") is Fidelity.FP16
    assert pol.fidelity_for("throughput") is Fidelity.INT8
    assert pol.fidelity_for("batch") is Fidelity.INT8
    assert pol.fidelity_for(None) is Fidelity.FP16
    assert pol.fidelity_for("batch", shared=True) is Fidelity.FP16, \
        "shared trie blocks default FP16: one demotion serves every class"


def test_fidelity_policy_modes_and_overrides():
    off = FidelityPolicy(mode="off")
    assert off.fidelity_for("batch") is Fidelity.FP16
    always = FidelityPolicy(mode="always", batch=Fidelity.INT4)
    assert always.fidelity_for("latency") is Fidelity.INT4
    assert always.fidelity_for("batch", shared=True) is Fidelity.INT4
    custom = FidelityPolicy(mode="slo", throughput=Fidelity.FP8)
    assert custom.fidelity_for("throughput") is Fidelity.FP8


def test_fidelity_policy_validates():
    with pytest.raises(ValueError, match="mode"):
        FidelityPolicy(mode="sometimes")
    with pytest.raises(TypeError, match="Fidelity"):
        FidelityPolicy(mode="slo", batch="int8")
    assert set(FIDELITY_POLICIES) == {"off", "slo", "always"}


# ---------------------------------------------------------------------------
# prefix-cache digest non-aliasing
# ---------------------------------------------------------------------------


def test_prefix_digests_never_alias_across_fidelities():
    """A quantized cached block must never be served where a
    full-precision one is expected: the content key includes the cache's
    fidelity, and the FP16 key keeps the legacy 2-tuple shape."""
    cfg = get_config("yi-6b").reduced()
    rt = HarvestRuntime({0: 8 * MiB})
    kv = rt.kv_manager(cfg, block_size=4, num_local_slots=8,
                       num_kv_layers=2)
    fp16 = PrefixCache(kv, PrefixCacheConfig(), metrics=rt.metrics)
    int8 = PrefixCache(kv, PrefixCacheConfig(fidelity=Fidelity.INT8),
                       metrics=rt.metrics)
    digest = ("d", 123)
    assert fp16.content_key(digest) == ("px", digest)
    assert int8.content_key(digest) == ("px", digest, "int8")
    assert fp16.content_key(digest) != int8.content_key(digest)
    with pytest.raises(TypeError, match="Fidelity"):
        PrefixCacheConfig(fidelity="int8")


# ---------------------------------------------------------------------------
# engine e2e
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def served_model():
    from repro.models import model as M
    cfg = dataclasses.replace(get_config("yi-6b").reduced(), num_layers=2)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _run(cfg, params, *, policy=None, slo="batch", mode="sync",
         durability="host_backed", cold=False, host_cap=None):
    eng = HarvestServingEngine(
        cfg, params, max_batch=2, block_size=8, num_local_slots=10,
        max_seq_len=96, allocator=HarvestAllocator({1: 64 * MiB}),
        hardware=H100_NVLINK, scheduler="fair", mode=mode,
        durability=durability, fidelity_policy=policy, cold_tier=cold,
        host_capacity_bytes=host_cap)
    prompts = [[2 + i, 5, 7, 11, 13 + i] for i in range(4)]
    reqs = [eng.submit_request(prompt=p, max_new_tokens=12, slo=slo)
            for p in prompts]
    stats = eng.run(max_steps=800)
    return eng, [tuple(r.output) for r in reqs], stats


def test_latency_class_tokens_bit_identical(served_model):
    """The headline fidelity-off equivalence: when only latency-class
    traffic runs, the slo policy demotes everything at FP16 and tokens,
    bytes and clock match the fidelity-off baseline exactly."""
    cfg, params = served_model
    _, tok_off, st_off = _run(cfg, params, slo="latency")
    eng, tok_slo, st_slo = _run(cfg, params, policy="slo", slo="latency")
    assert tok_off == tok_slo
    assert st_off.clock_s == st_slo.clock_s
    assert eng.runtime.stats()["fid"]["demote_quantized"] == 0


def test_batch_class_quantizes_and_decodes_within_tolerance(served_model):
    """Batch-class traffic under the slo policy rides int8: demotions
    quantize, reloads dequantize, the clock is no worse than fidelity-off
    (fewer wire bytes beat the added quantize pass), and decode still
    emits every token."""
    cfg, params = served_model
    _, tok_off, st_off = _run(cfg, params)
    eng, tok_slo, st_slo = _run(cfg, params, policy="slo")
    fid = eng.runtime.stats()["fid"]
    assert fid["demote_quantized"] > 0 and fid["reload_dequantized"] > 0
    assert fid["bytes_saved"] > 0
    assert st_slo.clock_s <= st_off.clock_s + 1e-12
    # decode completed: the quantized KV path emitted the full budget
    assert all(len(t) == 12 for t in tok_slo)
    assert len(tok_slo) == len(tok_off)
    st_slo.check_clock_identity()


def test_engine_degrade_is_lossy_but_bounded(served_model):
    cfg, params = served_model
    eng, _, _ = _run(cfg, params, policy="slo")
    rng = np.random.default_rng(0)
    data = rng.normal(size=(2, 2, 8, 2, 4)).astype(np.float32)
    deg = eng._degrade(data, Fidelity.INT8)
    assert deg.shape == data.shape and deg.dtype == data.dtype
    assert not np.array_equal(deg, data), "int8 round-trip must be lossy"
    absmax = np.abs(data).max()
    assert np.abs(deg - data).max() <= absmax / 127 + 1e-7


def test_engine_cold_tier_reloads_from_ssd(served_model):
    cfg, params = served_model
    eng, toks, st = _run(cfg, params, mode="async", durability="lossy",
                         cold=True)
    # starve the peer so the ladder reaches the SSD rung
    eng2, toks2, _ = _run(cfg, params, mode="async", durability="lossy",
                          cold=True)
    assert all(len(t) == 12 for t in toks)
    st.check_clock_identity()
    # direct starved run: no peer budget at all
    eng3 = HarvestServingEngine(
        cfg, params, max_batch=2, block_size=8, num_local_slots=10,
        max_seq_len=96, allocator=HarvestAllocator({1: 0}),
        hardware=H100_NVLINK, scheduler="fair", mode="async",
        durability="lossy", cold_tier=True)
    reqs = [eng3.submit_request(prompt=[2 + i, 5, 7, 11, 13 + i],
                                max_new_tokens=12, slo="batch")
            for i in range(4)]
    st3 = eng3.run(max_steps=800)
    assert eng3.kv_mgr.stats["evict_to_ssd"] > 0
    assert eng3.kv_mgr.stats["reload_ssd"] > 0
    assert all(len(r.output) == 12 for r in reqs), \
        "SSD round-trips must not drop tokens"
    st3.check_clock_identity()


def test_engine_knobs_validate(served_model):
    cfg, params = served_model
    with pytest.raises(ValueError, match="fidelity policy"):
        HarvestServingEngine(cfg, params, fidelity_policy="bogus")
    with pytest.raises(AssertionError, match="event timeline"):
        HarvestServingEngine(cfg, params, mode="sync", cold_tier=True)


def test_serve_cli_validates(monkeypatch, capsys):
    from repro.launch import serve
    monkeypatch.setattr("sys.argv",
                        ["serve", "--fidelity-policy", "bogus"])
    with pytest.raises(SystemExit):
        serve.main()
    assert "fidelity-policy" in capsys.readouterr().err
    monkeypatch.setattr("sys.argv", ["serve", "--cold-tier"])
    with pytest.raises(SystemExit):
        serve.main()
    assert "--cold-tier needs" in capsys.readouterr().err
