"""End-to-end behaviour tests for the Harvest system.

Covers the full stack: training loop convergence, checkpoint round-trip,
the serving engine under memory pressure (evict -> reload must not change
tokens), lossy revocation recovery, fair-scheduling preemption, and the
paper's headline property (peer offload beats host offload).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import ModelConfig
from repro.core.allocator import HarvestAllocator
from repro.core.monitor import ClusterTrace, ClusterTraceConfig, PeerMonitor
from repro.core.simulator import simulate_moe_decode
from repro.core.tiers import H100_NVLINK
from repro.serving.engine import HarvestServingEngine
from repro.train.loop import train

MiB = 2**20

TINY = ModelConfig(
    name="tiny-dense", family="dense", source="test",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=256,
)


# ---------------------------------------------------------------------------
# training
# ---------------------------------------------------------------------------


def test_train_loss_decreases(tmp_path):
    params, _opt, history = train(TINY, steps=30, batch=8, seq_len=32,
                                  lr=1e-3, log_every=5, seed=0,
                                  ckpt_dir=str(tmp_path), ckpt_every=25)
    losses = [h["loss"] for h in history]
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0] * 0.9, f"no learning: {losses}"
    # checkpointing happened and is loadable
    assert list(tmp_path.glob("*.npz")), "no checkpoint written"


def test_train_resume_matches(tmp_path):
    """Training 10 steps == training 5, checkpointing, resuming 5."""
    _, _, h_full = train(TINY, steps=10, batch=4, seq_len=16, lr=5e-4,
                         log_every=1, seed=3)
    # same 10-step schedule, checkpointing at step 5 along the way
    train(TINY, steps=10, batch=4, seq_len=16, lr=5e-4, log_every=1,
          seed=3, ckpt_dir=str(tmp_path), ckpt_every=5)
    ckpt = tmp_path / "step_000005.npz"
    _, _, h_res = train(TINY, steps=10, batch=4, seq_len=16, lr=5e-4,
                        log_every=1, seed=3, resume=str(ckpt))
    # the resumed run's final loss equals the uninterrupted run's
    assert abs(h_full[-1]["loss"] - h_res[-1]["loss"]) < 1e-4


# ---------------------------------------------------------------------------
# serving engine
# ---------------------------------------------------------------------------


def _engine(cfg, params, *, slots, alloc=None, monitor=None, **kw):
    return HarvestServingEngine(
        cfg, params, max_batch=2, block_size=8, num_local_slots=slots,
        max_seq_len=96, allocator=alloc, monitor=monitor,
        hardware=H100_NVLINK, **kw)


@pytest.fixture(scope="module")
def served_model():
    from repro.models import model as M
    cfg = dataclasses.replace(get_config("yi-6b").reduced(), num_layers=2)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _run_engine(cfg, params, *, slots, alloc=None, monitor=None, **kw):
    eng = _engine(cfg, params, slots=slots, alloc=alloc, monitor=monitor, **kw)
    prompts = [[2 + i, 5, 7, 11, 13 + i] for i in range(4)]
    reqs = [eng.submit(p, max_new_tokens=12) for p in prompts]
    stats = eng.run(max_steps=800)
    return eng, reqs, stats


def test_engine_eviction_reload_token_exact(served_model):
    """Preemption-driven offload to the peer tier must not change tokens.

    The engine's admission control never over-subscribes the local pool, so
    evictions happen on the paper's fair-decoding path (S6.3): a preempted
    request's blocks move to peer HBM and reload when it resumes.
    """
    cfg, params = served_model
    _, reqs_ref, _ = _run_engine(cfg, params, slots=64)  # everything local
    alloc = HarvestAllocator({1: 64 * MiB})
    eng, reqs, stats = _run_engine(cfg, params, slots=10, alloc=alloc,
                                   scheduler="fair")

    for a, b in zip(reqs_ref, reqs):
        assert a.output == b.output, "offloading changed decoded tokens"
    assert all(len(r.output) == 12 for r in reqs)
    assert eng.kv_mgr.stats["evict_to_peer"] > 0, \
        "test must exercise the peer tier"
    assert eng.kv_mgr.stats["reload_peer"] > 0
    assert stats.reload_s > 0


def test_engine_revocation_falls_back(served_model):
    """Mid-run revocations (budget -> 0) must not break decoding."""
    cfg, params = served_model
    _, reqs_ref, _ = _run_engine(cfg, params, slots=64)

    class CrunchTrace(ClusterTrace):
        def step(self):
            # after a few ticks the peer device fills up entirely
            self.t += 1
            frac = 0.0 if self.t < 4 else 1.0
            return np.array([int(frac * self.cfg.capacity_bytes)] * 1)

    alloc = HarvestAllocator({0: 64 * MiB})
    trace = CrunchTrace(ClusterTraceConfig(num_devices=1,
                                           capacity_bytes=64 * MiB))
    mon = PeerMonitor(alloc, trace, capacity_bytes=64 * MiB)
    eng, reqs, _ = _run_engine(cfg, params, slots=10, alloc=alloc,
                               monitor=mon, scheduler="fair")

    assert eng.kv_mgr.stats["revocations"] > 0, \
        "test must exercise revocation"
    assert eng.kv_mgr.stats["reload_host"] > 0, \
        "revoked blocks must fall back to the host tier"
    for a, b in zip(reqs_ref, reqs):
        assert a.output == b.output, "revocation changed decoded tokens"


def test_engine_lossy_revocation_while_preempted_recomputes(served_model):
    """A preempted request whose peer blocks are revoked under LOSSY
    durability hits the explicit LOST state on resume and must recompute
    its prefix — not crash, not decode garbage."""
    cfg, params = served_model
    alloc = HarvestAllocator({1: 64 * MiB})
    eng = _engine(cfg, params, slots=10, alloc=alloc, scheduler="fair",
                  durability="lossy")
    reqs = [eng.submit([2 + i, 5, 7, 11, 13 + i], max_new_tokens=12)
            for i in range(4)]
    # step until a preemption has pushed blocks to the peer tier…
    for _ in range(400):
        if eng.kv_mgr.stats["evict_to_peer"] > 0 or not eng.step():
            break
    assert eng.kv_mgr.stats["evict_to_peer"] > 0, \
        "test must exercise the peer tier"
    # …then a full memory crunch revokes them: lossy blocks become LOST
    alloc.update_budget(1, 0)
    assert eng.kv_mgr.stats["revocations"] > 0
    assert eng.kv_mgr.tier_counts()["lost"] > 0
    stats = eng.run(max_steps=800)
    assert all(len(r.output) == 12 for r in reqs)
    assert all(r.state == "done" for r in reqs)
    assert eng.kv_mgr.stats["recomputes"] > 0
    assert stats.recomputes > 0, "the engine must account the rebuild"


def test_engine_fair_scheduler_preempts(served_model):
    cfg, params = served_model
    eng = _engine(cfg, params, slots=24, scheduler="fair")
    reqs = [eng.submit([3 + i, 9, 4], max_new_tokens=10) for i in range(5)]
    stats = eng.run(max_steps=800)
    assert stats.preemptions > 0, "fair scheduler should preempt"
    assert all(len(r.output) == 10 for r in reqs)
    assert all(r.state == "done" for r in reqs)


def test_engine_throughput_accounting(served_model):
    cfg, params = served_model
    eng, reqs, stats = _run_engine(cfg, params, slots=64)
    assert stats.tokens_out == sum(len(r.output) for r in reqs)
    assert stats.clock_s > 0 and stats.throughput() > 0


# ---------------------------------------------------------------------------
# the paper's headline property
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["mixtral-8x7b", "qwen2-moe"])
def test_peer_offload_beats_host_offload(arch):
    cfg = get_config(arch)
    peer = simulate_moe_decode(cfg, H100_NVLINK, 0.5, use_peer=True,
                               decode_steps=2)
    host = simulate_moe_decode(cfg, H100_NVLINK, 0.5, use_peer=False,
                               decode_steps=2)
    assert peer.tokens_per_s > host.tokens_per_s * 1.2, \
        "peer caching must outperform host offload by a clear margin"


def test_offload_fraction_monotone_host_only():
    """More host offload -> lower throughput; peer stays ~flat (Fig 6)."""
    cfg = get_config("qwen2-moe")
    host = [simulate_moe_decode(cfg, H100_NVLINK, f, use_peer=False,
                                decode_steps=2).tokens_per_s
            for f in (0.0, 0.5, 1.0)]
    peer = [simulate_moe_decode(cfg, H100_NVLINK, f, use_peer=True,
                                decode_steps=2).tokens_per_s
            for f in (0.0, 0.5, 1.0)]
    assert host[0] >= host[1] >= host[2]
    assert min(peer) > max(peer) * 0.95
