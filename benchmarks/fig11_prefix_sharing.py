"""Fig 11 (repo-original) — harvested prefix cache: cross-request KV sharing.

Production multi-tenant serving is dominated by a few system prompts per
tenant; the harvested prefix cache (:mod:`repro.core.prefix_cache`)
publishes retired prompts' KV blocks into a radix trie over the
:class:`~repro.core.store.HarvestStore` so later requests sharing the
prefix skip that part of prefill.  This benchmark sweeps the traffic
shape that monetises it and serves each cell twice — cache on vs cache
off — through the request-lifecycle API.

Axes per hardware family (H100+NVLink / TPU v5e+ICI):

  * **prefix share** — the fraction of requests carrying a shared
    system prompt (``TenantSpec.prefix_share``): 0 is the legacy
    no-sharing stream, 0.9 is assistant-style traffic where nearly
    every request opens with the tenant's system prompt.
  * **tenant count** — more tenants means more distinct system prompts
    competing for trie capacity and local slots (cache diversity).

The hardware is made *compute-bound* for prefill (``peak_flops`` scaled
so the weight-read floor crosses over at ~8 tokens) — on the stock
memory-bound models every short prefill costs one weight sweep and
cached blocks save no clock, which is itself a finding the stock fig10
records; here we measure the regime the paper's prefix reuse targets.

Headline checks: decoded tokens are BIT-IDENTICAL with the cache on and
off at every cell (block adoption is zero-copy, never recomputed-and-
approximated); at prefix share >= 0.6 the cache strictly lowers mean
TTFT and saves >= 2x prefill blocks; at share 0 random prompts produce
zero hits (no false sharing from the content addressing).
"""
from __future__ import annotations

import dataclasses
import math
from pathlib import Path
from typing import List

from benchmarks.common import Check, fmt_table, save_result

SHARES = (0.0, 0.6, 0.9)
TENANT_COUNTS = (1, 2)
NUM_REQUESTS = 12
MAX_NEW_TOKENS = 6
PREFIX_LEN = 64                # 8 blocks of shared system prompt
BODY_LEN = (2, 6)              # small unique tail per request
BLOCK_SIZE = 8
LOCAL_SLOTS = 24
MAX_BATCH = 2
RATE = 5e3
SEED = 11

HW_MODELS = {"h100-nvlink-2gpu": "H100_NVLINK", "tpu-v5e": "TPU_V5E"}


def _hardware(hw: str):
    """The family's model, re-balanced so prefill is compute-bound.

    ``peak_flops = 8 * hbm_bw`` puts the compute/weight-read crossover
    at ~8 prompt tokens (stock H100 is ~295), so skipping cached prefix
    blocks shortens the prefill window instead of vanishing under the
    per-step weight-sweep floor.  Interconnect and capacity stay stock.
    """
    from repro.core import tiers
    base = getattr(tiers, HW_MODELS[hw])
    return dataclasses.replace(base, peak_flops=8.0 * base.hbm_bw)


def _workload(share: float, tenants: int):
    from repro.serving import TenantSpec, Workload
    return Workload(
        num_requests=NUM_REQUESTS, arrival="poisson", rate=RATE, seed=SEED,
        vocab=(3, 250),
        tenants=tuple(
            TenantSpec(f"tenant{i}", prompt_len=BODY_LEN,
                       max_new_tokens=MAX_NEW_TOKENS, prefix_share=share,
                       num_prefixes=1, prefix_len=PREFIX_LEN)
            for i in range(tenants)))


def _server(cfg, params, hw: str, cache: bool):
    from repro.core import HarvestRuntime
    from repro.serving import HarvestServer
    runtime = HarvestRuntime({1: 64 << 20}, hardware=_hardware(hw))
    return HarvestServer(cfg, params, runtime=runtime, max_batch=MAX_BATCH,
                         block_size=BLOCK_SIZE, num_local_slots=LOCAL_SLOTS,
                         scheduler="fair", mode="sync", prefix_cache=cache)


def _run_cell(cfg, params, hw: str, cache: bool, share: float, tenants: int):
    srv = _server(cfg, params, hw, cache)
    stats = srv.run(_workload(share, tenants), max_steps=4000)
    outputs = [tuple(h.tokens) for h in srv.handles]
    recs = [r for r in stats.records() if r.state == "done"]
    ttfts = [r.ttft_s for r in recs if r.ttft_s is not None]
    blocks = sum(math.ceil(r.prompt_tokens / BLOCK_SIZE) for r in recs)
    pfx = stats.metrics.get("prefix", {})
    return {
        "clock_s": stats.clock_s,
        "prefill_s": stats.prefill_s,
        "tokens": stats.tokens_out,
        "goodput": stats.goodput(),
        "mean_ttft_s": sum(ttfts) / len(ttfts) if ttfts else 0.0,
        "prompt_blocks": blocks,
        "cached_blocks": sum(r.cached_prefix_blocks for r in recs),
        "hit_blocks": pfx.get("hit_blocks", 0),
        "local_hits": pfx.get("local_hits", 0),
        "peer_hits": pfx.get("peer_hits", 0),
        "host_hits": pfx.get("host_hits", 0),
        "cow_splits": pfx.get("cow_splits", 0),
        "published": pfx.get("published", 0),
        "evictions": pfx.get("evictions", 0),
    }, outputs, stats


def run(out_dir: Path, hw: str = "h100-nvlink-2gpu", fast: bool = False
        ) -> dict:
    import jax

    from repro.configs import get_config
    from repro.models import model as M

    if hw not in HW_MODELS:
        raise ValueError(f"unknown hardware family {hw!r}; expected one of "
                         f"{sorted(HW_MODELS)}")
    shares, tenant_counts = SHARES, TENANT_COUNTS
    if fast:
        shares = (0.0, max(SHARES))
        tenant_counts = tenant_counts[:1]

    cfg = dataclasses.replace(get_config("yi-6b").reduced(), num_layers=2)
    params = M.init_params(jax.random.PRNGKey(0), cfg)

    rows: List[dict] = []
    table = []
    snapshot = None
    for tenants in tenant_counts:
        for share in shares:
            on, out_on, st_on = _run_cell(cfg, params, hw, True, share,
                                          tenants)
            off, out_off, _ = _run_cell(cfg, params, hw, False, share,
                                        tenants)
            prefilled_on = on["prompt_blocks"] - on["cached_blocks"]
            row = {
                "tenants": tenants, "share": share,
                "tokens_match": out_on == out_off,
                "cache": on, "no_cache": off,
                "ttft_lift": (off["mean_ttft_s"] / on["mean_ttft_s"]
                              if on["mean_ttft_s"] else float("inf")),
                "goodput_lift": (on["goodput"] / off["goodput"]
                                 if off["goodput"] else float("inf")),
                "block_savings": (off["prompt_blocks"] / prefilled_on
                                  if prefilled_on else float("inf")),
            }
            rows.append(row)
            table.append([
                tenants, f"{share:.1f}",
                "yes" if row["tokens_match"] else "NO",
                f"{on['mean_ttft_s'] * 1e6:.1f}",
                f"{off['mean_ttft_s'] * 1e6:.1f}",
                f"{row['ttft_lift']:.2f}x",
                f"{off['prompt_blocks']}/{prefilled_on}",
                f"{row['block_savings']:.2f}x",
                f"{on['local_hits']}/{on['peer_hits']}/{on['host_hits']}",
                on["cow_splits"], on["published"], on["evictions"]])
            if share == max(shares) and tenants == tenant_counts[-1]:
                snapshot = st_on.metrics
    print(f"Fig 11 — harvested prefix cache, cache on vs off ({hw}, "
          f"compute-bound prefill):")
    print(fmt_table(
        ["tenants", "share", "tokens=", "ttft on us", "ttft off us", "lift",
         "blocks off/on", "savings", "hits L/P/H", "cow", "pub", "evict"],
        table))
    print()

    high = [r for r in rows if r["share"] >= 0.6]
    low = [r for r in rows if r["share"] == 0.0]
    checks = [
        Check("fig11.tokens_invariant",
              float(all(r["tokens_match"] for r in rows)), lo=1.0,
              note="decode is bit-identical with the prefix cache on and "
                   "off at every cell: adopted blocks are the exact KV "
                   "bytes prefill would have produced"),
        Check("fig11.ttft_improves_high_share",
              min(r["ttft_lift"] for r in high), lo=1.0 + 1e-9,
              note="at prefix share >= 0.6 the cache strictly lowers mean "
                   "TTFT (prefill windows shrink by the adopted blocks)"),
        Check("fig11.prefill_block_savings",
              min(r["block_savings"] for r in high), lo=2.0,
              note="at prefix share >= 0.6 the cache prefills >= 2x fewer "
                   "prompt blocks than the no-cache system"),
        Check("fig11.no_false_sharing",
              float(max(r["cache"]["hit_blocks"] for r in low)), hi=0.0,
              note="with random prompts (share 0) content addressing "
                   "produces zero hits — chained digests never alias "
                   "distinct prefixes"),
        Check("fig11.trie_exercised",
              float(max(r["cache"]["published"] for r in high)), lo=1.0,
              note="retired prompts were actually published into the trie "
                   "(the savings come from cross-request sharing, not "
                   "batching artifacts)"),
    ]

    payload = {"name": "fig11_prefix_sharing", "hw": hw, "rows": rows,
               "checks": [c.to_dict() for c in checks],
               "metrics": snapshot or {}}
    save_result(out_dir, "fig11_prefix_sharing", payload)
    return payload


if __name__ == "__main__":
    import argparse

    from benchmarks.common import RESULTS_DIR
    ap = argparse.ArgumentParser()
    ap.add_argument("--hw", default="h100-nvlink-2gpu",
                    choices=sorted(HW_MODELS))
    ap.add_argument("--tiny", "--fast", dest="fast", action="store_true",
                    help="CI mode: two shares, one tenant")
    args = ap.parse_args()
    run(RESULTS_DIR, hw=args.hw, fast=args.fast)
