"""§Roofline — per (arch x shape) roofline terms from the compiled dry-run.

Reads ``results/dryrun.jsonl`` (written by ``repro.launch.dryrun``) and
reports, for the single-pod production mesh (16 x 16 = 256 chips):

    compute term    = HLO_FLOPs / (chips x 197 TFLOP/s bf16)
    memory term     = HLO_bytes / (chips x 819 GB/s HBM)
    collective term = collective_bytes / (chips x ~50 GB/s/link ICI)

plus the dominant term, MODEL_FLOPS = 6ND (dense) / 6N_active D (MoE) and
the useful-compute ratio MODEL_FLOPS / HLO_FLOPs.  The dry-run already
computes the terms (repro.launch.dryrun); this benchmark validates
completeness (every non-skipped pair present and ok on BOTH meshes) and
renders the table EXPERIMENTS.md §Roofline embeds.
"""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import Check, RESULTS_DIR, fmt_table, save_result
from repro.configs import dryrun_pairs

MOVE_HINT = {
    "compute": "raise per-chip utilisation: fuse elementwise chains, avoid "
               "remat of matmuls, or widen the batch per chip",
    "memory": "cut HBM traffic: larger fused blocks (flash/paged kernels), "
              "bf16 everywhere, reuse KV pool reads across heads",
    "collective": "reduce bytes over ICI: reshard to cut all-gathers, "
                  "replicate hot weights in harvested peer HBM, overlap "
                  "collectives with compute",
}


def load_rows(path: Path):
    best = {}
    for line in path.read_text().splitlines():
        try:
            r = json.loads(line)
        except json.JSONDecodeError:
            continue
        key = (r["arch"], r["shape"], r["mesh"],
               r.get("harvest_inplace", False), r.get("peer_fraction", 0.0))
        best[key] = r          # later lines win (re-runs supersede)
    return list(best.values())


def run(out_dir: Path, dryrun_path: Path = None) -> dict:
    path = dryrun_path or (RESULTS_DIR / "dryrun.jsonl")
    rows = load_rows(path)
    baseline = [r for r in rows if not r.get("harvest_inplace")
                and not r.get("peer_fraction")]
    pod = {(r["arch"], r["shape"]): r for r in baseline if r["mesh"] == "pod"}
    multipod = {(r["arch"], r["shape"]): r for r in baseline
                if r["mesh"] == "multipod"}

    expected = dryrun_pairs()
    missing_pod = [p for p in expected if p not in pod or not pod[p]["ok"]]
    missing_mp = [p for p in expected
                  if p not in multipod or not multipod[p]["ok"]]

    table_rows, out_rows = [], []
    for arch, shape in expected:
        r = pod.get((arch, shape))
        if r is None or not r.get("ok"):
            table_rows.append([arch, shape, "MISSING", "", "", "", "", ""])
            continue
        rf = r["roofline"]
        ct, mt, lt = (rf["compute_term_s"], rf["memory_term_s"],
                      rf["collective_term_s"])
        ratio = rf.get("useful_flops_ratio")
        table_rows.append([
            arch, shape, f"{ct*1e3:.2f}", f"{mt*1e3:.2f}", f"{lt*1e3:.2f}",
            rf["bottleneck"],
            f"{ratio:.2f}" if ratio is not None else "-",
            f"{r['mem']['total_bytes']/2**30:.1f}",
        ])
        out_rows.append({
            "arch": arch, "shape": shape,
            "compute_term_s": ct, "memory_term_s": mt,
            "collective_term_s": lt, "bottleneck": rf["bottleneck"],
            "useful_flops_ratio": ratio,
            "mem_gib_per_device": r["mem"]["total_bytes"] / 2**30,
            "hint": MOVE_HINT[rf["bottleneck"]],
        })

    checks = [
        Check("roofline.pod_pairs_ok", len(expected) - len(missing_pod),
              lo=len(expected),
              note=f"all {len(expected)} (arch x shape) pairs compile on the "
                   f"single-pod mesh; missing: {missing_pod}"),
        Check("roofline.multipod_pairs_ok", len(expected) - len(missing_mp),
              lo=len(expected),
              note=f"all pairs compile on the 2-pod mesh; missing: "
                   f"{missing_mp}"),
    ]
    # memory per device must fit v5e HBM (16 GiB) for every decode shape;
    # train/prefill shapes may spill into remat territory but still compile.
    worst_decode = max((r["mem_gib_per_device"] for r in out_rows
                        if "decode" in r["shape"] or "500k" in r["shape"]
                        or r["shape"] == "long_500k"), default=0.0)
    checks.append(Check("roofline.worst_decode_mem_gib", worst_decode,
                        hi=16.0, note="decode states fit v5e HBM/device"))

    print("§Roofline — single-pod (256-chip) baseline, per (arch x shape):")
    print(fmt_table(
        ["arch", "shape", "compute ms", "memory ms", "collective ms",
         "bottleneck", "useful-FLOP ratio", "GiB/dev"], table_rows))

    payload = {"name": "roofline", "rows": out_rows,
               "checks": [c.to_dict() for c in checks]}
    save_result(out_dir, "roofline", payload)
    return payload


if __name__ == "__main__":
    run(RESULTS_DIR)
