"""Render EXPERIMENTS.md tables from results/ artifacts.

Usage:  PYTHONPATH=src python -m benchmarks.report \
            [--section dryrun|roofline|claims|fidelity|scaleout|stability|metrics]
Prints markdown; EXPERIMENTS.md embeds the output.
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

from benchmarks.common import RESULTS_DIR
from benchmarks.roofline import load_rows
from repro.configs import dryrun_pairs


def md_table(headers, rows):
    out = ["| " + " | ".join(headers) + " |",
           "|" + "|".join("---" for _ in headers) + "|"]
    for r in rows:
        out.append("| " + " | ".join(str(c) for c in r) + " |")
    return "\n".join(out)


def baseline_rows(path=None):
    rows = load_rows(path or (RESULTS_DIR / "dryrun.jsonl"))
    return [r for r in rows if not r.get("harvest_inplace")
            and not r.get("peer_fraction")]


def section_dryrun():
    rows = baseline_rows()
    expected = dryrun_pairs()
    out_rows = []
    for arch, shape in expected:
        cells = [arch, shape]
        for mesh in ("pod", "multipod"):
            r = next((x for x in rows if x["arch"] == arch
                      and x["shape"] == shape and x["mesh"] == mesh), None)
            if r is None or not r.get("ok"):
                cells.append("**FAIL**")
                continue
            gib = r["mem"]["total_bytes"] / 2**30
            cells.append(f"ok {gib:.1f} GiB/dev "
                         f"({r['lower_s'] + r['compile_s']:.0f}s)")
        coll = r["collectives"]["counts"] if r and r.get("ok") else {}
        cells.append(", ".join(f"{k.split('-')[-1] if False else k}:{int(v)}"
                               for k, v in coll.items() if v))
        out_rows.append(cells)
    print(md_table(["arch", "shape", "pod (16x16)", "multipod (2x16x16)",
                    "collectives (multipod, count x trip)"], out_rows))


def section_roofline():
    rows = baseline_rows()
    pod = {(r["arch"], r["shape"]): r for r in rows if r["mesh"] == "pod"}
    out_rows = []
    for arch, shape in dryrun_pairs():
        r = pod.get((arch, shape))
        if r is None or not r.get("ok"):
            out_rows.append([arch, shape] + ["-"] * 6)
            continue
        rf = r["roofline"]
        ct, mt, lt = (rf["compute_term_s"], rf["memory_term_s"],
                      rf["collective_term_s"])
        ratio = rf.get("useful_flops_ratio")
        out_rows.append([
            arch, shape, f"{ct:.3f}", f"{mt:.3f}", f"{lt:.3f}",
            f"**{rf['bottleneck']}**",
            f"{ratio:.2f}" if ratio is not None else "-",
            f"{r['mem']['total_bytes'] / 2**30:.1f}",
        ])
    print(md_table(["arch", "shape", "compute s", "memory s", "collective s",
                    "bottleneck", "6ND/HLO", "GiB/dev"], out_rows))


def section_metrics():
    """Unified MetricsRegistry snapshots recorded by the benchmarks:
    per-client transfer totals, per-link queue occupancy (``q.<lane>.*``)
    and prefetch hit/waste counters, replacing per-benchmark ad-hoc
    stats printouts."""
    rows = []
    for p in sorted(RESULTS_DIR.glob("*.json")):
        payload = json.loads(p.read_text())
        snap = payload.get("metrics")
        if not snap:
            continue
        for ns in sorted(snap):
            counters = snap[ns]
            if not counters:
                continue
            for k in sorted(counters):
                v = counters[k]
                rows.append([payload.get("name", p.stem), ns, k,
                             f"{v:.6g}" if isinstance(v, float) else v])
    if not rows:
        print("_no metrics snapshots recorded yet — run the benchmarks_")
        return
    print(md_table(["artifact", "namespace", "counter", "value"], rows))


def section_fidelity():
    """Fidelity-tier counters from the fig13 artifact: quantized demotes
    and dequantizing reloads, link bytes saved, and the dequantize share
    of the clock, per capacity/SLO cell."""
    p = RESULTS_DIR / "fig13_fidelity_tiers.json"
    if not p.exists():
        print("_no fig13 artifact yet — run `python -m benchmarks.run "
              "--only fig13`_")
        return
    payload = json.loads(p.read_text())
    rows = []
    for r in payload.get("rows", []):
        fid = r["fidelity"]
        clock = fid["clock_s"]
        share = fid["dequant_s"] / clock if clock else 0.0
        rows.append([
            payload.get("hw", "-"), r["capacity"], r["slo"],
            "yes" if r["tokens_match"] else "no",
            fid["demote_quantized"], fid["reload_dequantized"],
            f"{fid['bytes_saved'] / 2**10:.1f}",
            f"{r['link_bytes_ratio']:.2f}x",
            f"{share:.2%}"])
    print(md_table(["hw", "capacity", "class", "tokens=", "demotes",
                    "dequant reloads", "KiB saved", "link ratio",
                    "dequant share"], rows))


def section_scaleout():
    """Scale-out artifact (fig14): the disaggregated-vs-colocated knee
    cells and the scalar-vs-vectorized event-loop walltimes."""
    p = RESULTS_DIR / "fig14_scaleout.json"
    if not p.exists():
        print("_no fig14 artifact yet — run `python -m benchmarks.run "
              "--only fig14`_")
        return
    payload = json.loads(p.read_text())
    a = payload.get("part_a", {})
    rows = []
    for mode in ("colocated", "disaggregated"):
        cell = a.get(mode, {})
        rows.append([
            payload.get("hw", "-"), a.get("topology", "-"), mode,
            "yes" if a.get("tokens_match") else "no",
            f"{cell.get('goodput_latency', 0):.0f}",
            f"{cell.get('slo_attainment_latency', 0):.0%}",
            f"{cell.get('ttft_p99_latency', 0) * 1e6:.1f}",
            f"{cell.get('dcn_coalesced', 0):.0f}"])
    print(md_table(["hw", "topology", "mode", "tokens=", "goodput tok/s",
                    "SLO%", "ttft99 us", "dcn coalesced"], rows))
    perf = payload.get("part_c", {}).get("perf", {})
    if perf:
        print()
        print(md_table(
            ["perf trace", "hosts", "scalar s", "vector s", "speedup",
             "bit-identical"],
            [[f"{perf.get('n', 0):,}", perf.get("hosts", "-"),
              f"{perf.get('scalar_walltime_s', 0):.2f}",
              f"{perf.get('vector_walltime_s', 0):.2f}",
              f"{perf.get('speedup', 0):.1f}x",
              "yes" if perf.get("identical") else "no"]]))


def section_stability():
    """Stability-control artifact (fig15): per-scenario closed-loop vs
    static admission cells plus the controller's final region state."""
    p = RESULTS_DIR / "fig15_stability.json"
    if not p.exists():
        print("_no fig15 artifact yet — run `python -m benchmarks.run "
              "--only fig15`_")
        return
    payload = json.loads(p.read_text())
    rows = []
    for r in payload.get("rows", []):
        rows.append([
            payload.get("hw", "-"), r["scenario"], r["policy"],
            f"{r['goodput_latency']:.0f}", f"{r['goodput']:.0f}",
            f"{r['ttft_p99_latency'] * 1e6:.1f}",
            f"{r['slo_attainment']:.0%}", r["done"], r["rejected"],
            r["engages"] or "-"])
    print(md_table(["hw", "scenario", "policy", "lat goodput", "goodput",
                    "ttft99 us", "SLO%", "done", "shed", "engages"], rows))
    noop = payload.get("noop", {})
    if noop:
        print()
        print(f"in-region no-op: tokens_match={noop.get('tokens_match')} "
              f"clock_match={noop.get('clock_match')} "
              f"engages={noop.get('engages')}")


def section_claims():
    names = ["fig2_cluster_cdf", "fig3_transfer_latency", "table1_model_zoo",
             "fig5_moe_throughput", "fig6_offload_sweep", "fig7_kv_latency",
             "fig8_peer_scaling", "fig9_coalescing", "fig10_slo_serving",
             "fig11_prefix_sharing", "fig12_continuous_batching",
             "fig13_fidelity_tiers", "fig14_scaleout", "fig15_stability",
             "roofline"]
    rows = []
    for n in names:
        p = RESULTS_DIR / f"{n}.json"
        if not p.exists():
            rows.append([n, "-", "missing"])
            continue
        checks = json.loads(p.read_text()).get("checks", [])
        ok = sum(1 for c in checks if c.get("ok"))
        rows.append([n, f"{ok}/{len(checks)}",
                     "PASS" if ok == len(checks) else "FAIL"])
        for c in checks:
            band = f"[{c.get('lo')}, {c.get('hi')}]"
            rows.append([f"&nbsp;&nbsp;{c['name']}",
                         f"{c['value']:.4g} in {band}",
                         "pass" if c.get("ok") else "**FAIL**"])
    print(md_table(["claim check", "value", "status"], rows))


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--section", default="all")
    a = ap.parse_args()
    if a.section in ("dryrun", "all"):
        print("\n### Dry-run matrix\n")
        section_dryrun()
    if a.section in ("roofline", "all"):
        print("\n### Roofline (single-pod, per device)\n")
        section_roofline()
    if a.section in ("claims", "all"):
        print("\n### Paper-claim checks\n")
        section_claims()
    if a.section in ("fidelity", "all"):
        print("\n### Fidelity tiers (fig13)\n")
        section_fidelity()
    if a.section in ("scaleout", "all"):
        print("\n### Scale-out (fig14)\n")
        section_scaleout()
    if a.section in ("stability", "all"):
        print("\n### Stability control (fig15)\n")
        section_stability()
    if a.section in ("metrics", "all"):
        print("\n### Runtime metrics (transfer queues, prefetch)\n")
        section_metrics()
