"""Paper Table 1 — MoE model architecture comparison.

Validates that our config files reproduce the paper's model zoo:

    Model         | Params (B) | Active (B) | Experts | Active Exp.
    Mixtral-8x7B  | 47.0       | 13.0       | 8       | 2
    Phi-3.5-MoE   | 60.8       | 6.6        | 16      | 2
    Phi-tiny-MoE  | 3.8        | 1.1        | 16      | 2
    Qwen2-MoE     | 14.3       | 2.7        | 64      | 4

Param counts are recomputed from the architecture dims (config ->
``param_counts()``), so this doubles as a regression test on the configs.
Note: the paper lists Phi-3.5-MoE at 60.8B; the official model card
(microsoft/Phi-3.5-MoE-instruct) reports 16x3.8B with 42B total — our
config follows the architecture dims (d_ff_expert=6400, 16 experts,
32 layers) which yield ~42B, so the Phi-3.5 total is checked against the
model-card number and the discrepancy with the paper's table is recorded.
"""
from __future__ import annotations

from pathlib import Path

from benchmarks.common import Check, fmt_table, save_result
from repro.configs import get_config

# (arch, paper_total_B, paper_active_B, experts, top_k, check_total_B)
TABLE1 = [
    ("mixtral-8x7b", 47.0, 13.0, 8, 2, 47.0),
    ("phi-3.5-moe", 60.8, 6.6, 16, 2, 42.0),   # model-card total (see module doc)
    ("phi-tiny-moe", 3.8, 1.1, 16, 2, 3.8),
    ("qwen2-moe", 14.3, 2.7, 64, 4, 14.3),
]


def run(out_dir: Path) -> dict:
    rows, out_rows, checks = [], [], []
    for arch, p_total, p_active, experts, top_k, chk_total in TABLE1:
        cfg = get_config(arch)
        pc = cfg.param_counts()
        total_b = pc["total"] / 1e9
        active_b = pc["active"] / 1e9
        rows.append([arch, f"{total_b:.1f} (paper {p_total})",
                     f"{active_b:.1f} (paper {p_active})",
                     f"{cfg.moe.num_experts} (paper {experts})",
                     f"{cfg.moe.top_k} (paper {top_k})"])
        out_rows.append({"model": arch, "total_b": total_b,
                         "active_b": active_b,
                         "experts": cfg.moe.num_experts,
                         "top_k": cfg.moe.top_k,
                         "paper_total_b": p_total,
                         "paper_active_b": p_active})
        checks += [
            Check(f"table1.{arch}.total_b", total_b,
                  lo=chk_total * 0.85, hi=chk_total * 1.15),
            Check(f"table1.{arch}.active_b", active_b,
                  lo=p_active * 0.6, hi=p_active * 1.25,
                  note="active params (attn share approximated)"),
            Check(f"table1.{arch}.experts", cfg.moe.num_experts,
                  lo=experts, hi=experts),
            Check(f"table1.{arch}.top_k", cfg.moe.top_k, lo=top_k, hi=top_k),
        ]

    print("Table 1 — MoE model zoo (recomputed from configs):")
    print(fmt_table(["model", "params B", "active B", "experts", "top-k"],
                    rows))

    payload = {"name": "table1_model_zoo", "rows": out_rows,
               "checks": [c.to_dict() for c in checks]}
    save_result(out_dir, "table1_model_zoo", payload)
    return payload


if __name__ == "__main__":
    from benchmarks.common import RESULTS_DIR
    run(RESULTS_DIR)
