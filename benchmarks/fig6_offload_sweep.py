"""Paper Fig 6 — throughput as a function of expert offload percentage.

Claims validated:
  * Harvest (peer offload) throughput stays flat (or degrades minimally)
    from 0% to 100% experts offloaded;
  * CPU offload degrades significantly with the offloaded fraction;
  * the qualitative anchors: Qwen2-MoE peer stays ~constant while CPU
    offload loses >=15% at full offload; Mixtral loses >=20%.

(The paper's absolute tokens/s — Qwen2 ~975 peer vs ~810 host at 100% —
come from its H100 test bench; our simulator reproduces the *shape* and
relative degradation.  Note the paper's Fig 5 (+53% for Qwen2 at 50%
offload) and Fig 6 (-17% at 100% offload) are not mutually consistent; we
validate each figure's claim on its own terms and record both numbers.)
"""
from __future__ import annotations

from pathlib import Path

import numpy as np

from benchmarks.common import Check, fmt_table, save_result
from repro.configs import get_config
from repro.core.runtime import HarvestRuntime
from repro.core.simulator import AccessModelConfig, simulate_moe_decode
from repro.core.tiers import H100_NVLINK

MODELS = ["mixtral-8x7b", "qwen2-moe", "phi-tiny-moe"]  # the 3 shown in Fig 6
FRACTIONS = [0.0, 0.25, 0.5, 0.75, 1.0]


def run(out_dir: Path, decode_steps: int = 4) -> dict:
    hw = H100_NVLINK
    runtime = HarvestRuntime(hardware=hw)
    out_rows, checks = [], []
    for arch in MODELS:
        cfg = get_config(arch)
        peer_curve, host_curve = [], []
        for f in FRACTIONS:
            am = AccessModelConfig(seed=0)
            p = simulate_moe_decode(cfg, hw, f, use_peer=True,
                                    decode_steps=decode_steps, access=am,
                                    runtime=runtime)
            h = simulate_moe_decode(cfg, hw, f, use_peer=False,
                                    decode_steps=decode_steps, access=am,
                                    runtime=runtime)
            peer_curve.append(p.tokens_per_s)
            host_curve.append(h.tokens_per_s)
        out_rows.append({"model": arch, "fractions": FRACTIONS,
                         "peer_tps": peer_curve, "host_tps": host_curve})

        peer_drop = 1 - min(peer_curve) / peer_curve[0]
        host_drop = 1 - host_curve[-1] / host_curve[0]
        host_monotone = all(host_curve[i] >= host_curve[i + 1] - 1e-6
                            for i in range(len(host_curve) - 1))
        checks += [
            Check(f"fig6.{arch}.peer_drop_pct", peer_drop * 100, hi=5.0,
                  note="Harvest throughput stays ~flat vs offload fraction"),
            Check(f"fig6.{arch}.host_drop_pct", host_drop * 100, lo=15.0,
                  note="CPU offload degrades significantly at full offload"),
            Check(f"fig6.{arch}.host_monotone", float(host_monotone), lo=1.0,
                  note="CPU-offload curve decreases monotonically"),
        ]

        print(f"Fig 6 — {arch}: throughput vs offload fraction")
        print(fmt_table(
            ["offloaded", "Harvest tok/s", "CPU offload tok/s"],
            [[f"{int(f*100)}%", f"{p:.0f}", f"{h:.0f}"]
             for f, p, h in zip(FRACTIONS, peer_curve, host_curve)]))
        print()

    snap = runtime.stats()
    payload = {"name": "fig6_offload_sweep", "rows": out_rows,
               "metrics": snap,
               "transfer_metrics": snap.get("transfer", {}),  # back-compat
               "checks": [c.to_dict() for c in checks]}
    save_result(out_dir, "fig6_offload_sweep", payload)
    return payload


if __name__ == "__main__":
    from benchmarks.common import RESULTS_DIR
    run(RESULTS_DIR)
