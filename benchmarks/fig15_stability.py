"""Fig 15 (repo-original) — closed-loop stability control under load
that crosses the stability boundary.

Every prior serving benchmark picks a *static* admission policy and
sweeps load past the knee; this one closes the loop.  The
:class:`~repro.serving.control.StabilityController` estimates per-class
arrival rate, service time, and KV footprint online, compares the
offered load against the effective harvestable capacity (a stability
region in the queueing sense), and — only while the system is outside
that region — jointly actuates admission shedding, a batch-size cap,
prefetch throttling, and harvest churn aversion.

Three adversarial scenarios per hardware family, each engineered so
that **no static policy wins**:

  * **ramp** — a diurnal-style ramp from a quarter of the knee rate to
    ~3x past it: a fixed admission threshold is either too timid below
    the knee or too permissive above it.
  * **storm** — bursty arrivals over a peer topology whose cluster
    trace fires *synchronized* revocation storms (every peer spikes at
    once): harvested capacity collapses exactly when the burst lands,
    so the region boundary itself moves.
  * **flood** — a two-tenant mix where a deadline-light bulk tenant
    floods the queue at 6x its normal rate; its requests carry only an
    e2e deadline, which the static deadline policy never sheds on — the
    flood eats rows while blowing every deadline it carries.

Per scenario the controller competes against every static admission
policy (``all``, ``headroom``, ``deadline``) on the *same* seeded
workload.  Deadlines are calibrated fig10-style, but against the
*in-region* tail: 8x the latency-class p99 of an uncontrolled run at a
third of the knee rate.

Headline checks: the controller keeps latency-class p99 TTFT within
the SLO in every scenario, achieves strictly higher SLO-goodput than
every static policy, never re-decodes (tokens of every admitted request
are bit-identical to the uncontrolled run), satisfies the clock
identity in every cell, and is a bit-exact no-op (tokens AND clock) on
an in-region workload.
"""
from __future__ import annotations

import dataclasses
from pathlib import Path
from typing import Dict, List, Optional, Tuple

from benchmarks.common import Check, fmt_table, save_result

NUM_REQUESTS = 32
MAX_NEW_TOKENS = 10
BLOCK_SIZE = 8
LOCAL_SLOTS = 10
MAX_BATCH = 2
SEED = 11
MAX_STEPS = 20_000
MONITOR_INTERVAL_S = 15e-6   # storm-trace tick cadence on the sim clock
STATIC_POLICIES = ("all", "headroom", "deadline")

HW_MODELS = {"h100-nvlink-2gpu": "H100_NVLINK", "tpu-v5e": "TPU_V5E"}


def _hardware(hw: str):
    from repro.core import tiers
    return getattr(tiers, HW_MODELS[hw])


def _t_weights(cfg, hw: str) -> float:
    """The weight-read-bound decode step time the engine itself uses."""
    pc = cfg.param_counts()
    return 2 * pc["active"] / _hardware(hw).hbm_bw


def _knee_rate(cfg, hw: str) -> float:
    """Approximate request service rate at full batch: the weight-bound
    decode step serves MAX_BATCH rows per ``t_weights``, each request
    needs MAX_NEW_TOKENS steps."""
    return MAX_BATCH / (MAX_NEW_TOKENS * _t_weights(cfg, hw))


def _controller_cfg(rate: float, hw: str, cfg):
    """Controller clocked fast enough to observe a run of NUM_REQUESTS
    arrivals: the estimator window holds ~16 inter-arrival gaps, well
    inside the overload phase of each scenario."""
    from repro.serving import ControllerConfig
    t_weights = _t_weights(cfg, hw)
    window_s = max(16.0 / rate, 8 * t_weights)
    return ControllerConfig(tick_interval_s=2 * t_weights,
                            window_s=window_s)


def _workloads(rate: float, slo: Optional[Dict[str, float]]):
    """The three adversarial scenarios (same seeds across policies)."""
    from repro.serving import TenantSpec, Workload
    slo = slo or {}
    lat = dict(slo="latency", priority=1, prompt_len=(18, 23),
               max_new_tokens=MAX_NEW_TOKENS,
               ttft_slo_s=slo.get("ttft"), e2e_slo_s=slo.get("e2e"))
    # a minority best-effort tenant rides along in every scenario, as in
    # any production mix: e2e deadline only, so the static deadline
    # policy (which sheds on TTFT reachability) can never shed it and
    # burns overload capacity serving doomed batch work the controller
    # sheds as e2e-unreachable
    bulk = dict(slo="batch", prompt_len=(18, 23),
                max_new_tokens=MAX_NEW_TOKENS, e2e_slo_s=slo.get("e2e"))
    return {
        "ramp": Workload(
            num_requests=NUM_REQUESTS, arrival="ramp", rate=rate,
            seed=SEED, vocab=(3, 250),
            arrival_kwargs={"start_ratio": 0.25, "end_ratio": 4.0},
            tenants=(TenantSpec("interactive", weight=3, **lat),
                     TenantSpec("bulk", weight=1, **bulk))),
        "storm": Workload(
            num_requests=NUM_REQUESTS, arrival="bursty", rate=1.5 * rate,
            seed=SEED, vocab=(3, 250),
            arrival_kwargs={"burst": 6, "duty": 0.3},
            tenants=(TenantSpec("interactive", weight=3, **lat),
                     TenantSpec("bulk", weight=1, **bulk))),
        "flood": Workload(
            num_requests=NUM_REQUESTS, arrival="flood", rate=0.75 * rate,
            seed=SEED, vocab=(3, 250),
            arrival_kwargs={"flood_ratio": 6.0, "flood_start": 0.25,
                            "flood_frac": 0.45},
            tenants=(TenantSpec("interactive", weight=2, **lat),
                     # the flooding tenant is the bulk class itself
                     TenantSpec("bulk", weight=1, **bulk))),
    }


def _server(cfg, params, hw: str, scenario: str, policy: str, rate: float):
    from repro.core import (ClusterTrace, ClusterTraceConfig,
                            HarvestRuntime, TopologyAwarePolicy,
                            kv_block_bytes, nvlink_mesh, tpu_v5e_torus)
    from repro.serving import HarvestServer

    block_bytes = kv_block_bytes(cfg, BLOCK_SIZE)
    budget = 6 * block_bytes
    if scenario == "storm":
        topology = (tpu_v5e_torus((3, 1)) if hw == "tpu-v5e"
                    else nvlink_mesh(2))
        trace = ClusterTrace(ClusterTraceConfig(
            num_devices=topology.num_peers, capacity_bytes=budget,
            seed=SEED, volatility=2.0, correlation=0.6,
            job_arrival_p=0.15, job_size_frac=(0.4, 0.9),
            job_lifetime=(4, 16),
            # synchronized multi-peer revocation storms: every peer
            # loses storm_frac of its capacity for 4 of every 10 ticks
            storm_interval=10, storm_duration=4, storm_frac=0.9))
        runtime = HarvestRuntime(
            topology.device_budgets(budget), topology=topology,
            policy=TopologyAwarePolicy(topology), trace=trace,
            monitor_interval_s=MONITOR_INTERVAL_S,
            hardware=_hardware(hw))
    else:
        runtime = HarvestRuntime({1: 4 * budget}, hardware=_hardware(hw))
    kwargs = {}
    if policy == "ctrl":
        kwargs["controller"] = _controller_cfg(rate, hw, cfg)
    else:
        kwargs["admission"] = policy
    return HarvestServer(cfg, params, runtime=runtime, max_batch=MAX_BATCH,
                         block_size=BLOCK_SIZE, num_local_slots=LOCAL_SLOTS,
                         scheduler="fair", mode="async", **kwargs)


def _run_cell(cfg, params, hw: str, scenario: str, policy: str,
              rate: float, workload):
    srv = _server(cfg, params, hw, scenario, policy, rate)
    stats = srv.run(workload, max_steps=MAX_STEPS)
    stats.check_clock_identity()
    done = {r.req_id for r in stats.records() if r.state == "done"}
    tokens = {h.req_id: tuple(h.tokens) for h in srv.handles
              if h.req_id in done}
    lat = stats.latency_percentiles("latency")
    ctrl_ns = stats.metrics.get("ctrl", {})
    return {
        "scenario": scenario, "policy": policy,
        "clock_s": stats.clock_s, "tokens": stats.tokens_out,
        "goodput": stats.goodput(),
        "goodput_latency": stats.goodput("latency"),
        "slo_attainment": stats.slo_attainment(),
        "ttft_p99_latency": lat["ttft_p99"],
        "e2e_p99_latency": lat["e2e_p99"],
        "done": len(done), "rejected": stats.rejected,
        "preemptions": stats.preemptions,
        "engages": ctrl_ns.get("engages", 0),
        "engaged_ticks": ctrl_ns.get("engaged_ticks", 0),
        "ctrl_shed": ctrl_ns.get("shed", 0),
        "ctrl_deferred": ctrl_ns.get("deferred", 0),
    }, tokens, stats


SLO_MARGIN = 8.0


def _calibrate_slo(cfg, params, hw: str, rate: float) -> Dict[str, float]:
    """8x the uncontrolled system's latency-class p99 at a third of the
    knee rate — the targets an operator provisions over the *in-region*
    tail, with enough margin that a request queued behind a couple of
    service times still meets them.  Calibrating at the knee itself
    would bake queueing collapse into the SLO and nothing would ever
    miss it; a bare 2x of the in-region tail (microseconds) would let
    nothing QUEUED ever meet it."""
    from repro.serving import TenantSpec, Workload
    wl = Workload(
        num_requests=NUM_REQUESTS, arrival="poisson", rate=0.3 * rate,
        seed=SEED, vocab=(3, 250),
        tenants=(TenantSpec("interactive", slo="latency", priority=1,
                            prompt_len=(18, 23),
                            max_new_tokens=MAX_NEW_TOKENS),))
    srv = _server(cfg, params, hw, "calib", "all", rate)
    stats = srv.run(wl, max_steps=MAX_STEPS)
    lat = stats.latency_percentiles("latency")
    return {"ttft": SLO_MARGIN * lat["ttft_p99"],
            "e2e": SLO_MARGIN * lat["e2e_p99"]}


def _noop_cell(cfg, params, hw: str, rate: float) -> dict:
    """In-region workload: the controller must be a bit-exact no-op —
    identical tokens AND identical clock decomposition."""
    from repro.serving import TenantSpec, Workload
    wl = Workload(
        num_requests=8, arrival="poisson", rate=0.05 * rate, seed=SEED,
        vocab=(3, 250),
        tenants=(TenantSpec("interactive", slo="latency",
                            prompt_len=(6, 18), max_new_tokens=(3, 8)),))
    out = {}
    for policy in ("all", "ctrl"):
        srv = _server(cfg, params, hw, "in_region", policy, rate)
        stats = srv.run(wl, max_steps=MAX_STEPS)
        stats.check_clock_identity()
        out[policy] = {
            "tokens": [tuple(h.tokens) for h in srv.handles],
            "clock_s": stats.clock_s, "idle_s": stats.idle_s,
            "bubble_s": stats.bubble_s,
            "engages": stats.metrics.get("ctrl", {}).get("engages", 0)}
    return {
        "tokens_match": out["ctrl"]["tokens"] == out["all"]["tokens"],
        "clock_match": (
            out["ctrl"]["clock_s"] == out["all"]["clock_s"]
            and out["ctrl"]["idle_s"] == out["all"]["idle_s"]
            and out["ctrl"]["bubble_s"] == out["all"]["bubble_s"]),
        "engages": out["ctrl"]["engages"],
        "clock_s": out["ctrl"]["clock_s"],
    }


def run(out_dir: Path, hw: str = "h100-nvlink-2gpu",
        fast: bool = False) -> dict:
    import time

    import jax

    from repro.configs import get_config
    from repro.models import model as M

    wall_t0 = time.perf_counter()
    if hw not in HW_MODELS:
        raise ValueError(f"unknown hardware family {hw!r}; expected one of "
                         f"{sorted(HW_MODELS)}")
    global NUM_REQUESTS
    n_full = NUM_REQUESTS
    if fast:
        NUM_REQUESTS = 24

    try:
        cfg = dataclasses.replace(get_config("yi-6b").reduced(),
                                  num_layers=2)
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        rate = _knee_rate(cfg, hw)

        noop = _noop_cell(cfg, params, hw, rate)
        slo = _calibrate_slo(cfg, params, hw, rate)
        workloads = _workloads(rate, slo)

        rows: List[dict] = []
        table = []
        tokens_ok = True
        snapshot: Optional[Dict[str, dict]] = None
        for scenario, wl in workloads.items():
            cells: Dict[str, dict] = {}
            toks: Dict[str, Dict[int, tuple]] = {}
            for policy in ("ctrl",) + STATIC_POLICIES:
                cell, tk, st = _run_cell(cfg, params, hw, scenario, policy,
                                         rate, wl)
                cells[policy], toks[policy] = cell, tk
                rows.append(cell)
                if scenario == "storm" and policy == "ctrl":
                    snapshot = st.metrics
            # every request the controller admitted to completion decoded
            # the exact tokens the uncontrolled system decoded for it —
            # admission re-times, never re-decodes
            uncontrolled = toks["all"]
            for policy in ("ctrl", "headroom", "deadline"):
                for rid, t in toks[policy].items():
                    if rid in uncontrolled and uncontrolled[rid] != t:
                        tokens_ok = False
            # the contest metric is the *latency-class* SLO-goodput: that
            # is the contract the controller protects; the bulk tenant is
            # best-effort by construction, and the controller may trade a
            # doomed bulk e2e for latency wins (overall goodput is still
            # reported per cell)
            best_static = max(cells[p]["goodput_latency"]
                              for p in STATIC_POLICIES)
            ctrl = cells["ctrl"]
            for policy in ("ctrl",) + STATIC_POLICIES:
                c = cells[policy]
                table.append([
                    scenario, policy, f"{c['goodput_latency']:.0f}",
                    f"{c['goodput']:.0f}",
                    f"{c['ttft_p99_latency'] * 1e6:.1f}",
                    f"{c['slo_attainment']:.0%}", c["done"], c["rejected"],
                    c["engages"] or ""])
            ctrl["goodput_lift"] = (ctrl["goodput_latency"] / best_static
                                    if best_static else float("inf"))

        print(f"Fig 15 — closed-loop stability control ({hw}; SLO = "
              f"{SLO_MARGIN:g}x in-region p99, knee ~{rate:.0f} req/s):")
        print(fmt_table(
            ["scenario", "policy", "lat goodput", "all goodput",
             "ttft99 us", "SLO%", "done", "shed", "engages"], table))
        print(f"in-region no-op: tokens_match={noop['tokens_match']} "
              f"clock_match={noop['clock_match']} "
              f"engages={noop['engages']}")
        print()

        ctrl_rows = [r for r in rows if r["policy"] == "ctrl"]
        checks = [
            Check("fig15.noop_in_region",
                  float(noop["tokens_match"] and noop["clock_match"]
                        and noop["engages"] == 0), lo=1.0,
                  note="inside the stability region the controller is a "
                       "bit-exact no-op: identical tokens, clock, idle "
                       "and bubble time, zero engagements"),
            Check("fig15.controller_engages",
                  float(min(r["engages"] for r in ctrl_rows)), lo=1.0,
                  note="every adversarial scenario drove the controller "
                       "outside the stability region at least once"),
            Check("fig15.goodput_strict_win",
                  min(r["goodput_lift"] for r in ctrl_rows), lo=1.0 + 1e-3,
                  note="closed-loop control achieves strictly higher "
                       "latency-class SLO-goodput than EVERY static "
                       "admission policy in every scenario"),
            Check("fig15.ttft_bounded",
                  float(all(r["ttft_p99_latency"] <= slo["ttft"] + 1e-12
                            for r in ctrl_rows)), lo=1.0,
                  note="the controller keeps latency-class p99 TTFT "
                       "within the calibrated SLO in every scenario "
                       "(static admit-all blows through it)"),
            Check("fig15.tokens_bit_identical", float(tokens_ok), lo=1.0,
                  note="every admitted request decodes tokens "
                       "bit-identical to the uncontrolled run — the "
                       "control loop re-times and sheds, never "
                       "re-decodes"),
        ]

        payload = {"name": "fig15_stability", "hw": hw,
                   "rate_knee": rate, "slo": slo, "noop": noop,
                   "rows": rows,
                   "checks": [c.to_dict() for c in checks],
                   # wall-clock of this run() — the CI perf gate compares
                   # the fast runtime against benchmarks/perf_baseline.json
                   # and fails on a >2x regression
                   "runtime_s": time.perf_counter() - wall_t0,
                   "fast": fast,
                   "metrics": snapshot or {}}
        save_result(out_dir, "fig15_stability", payload)
        return payload
    finally:
        NUM_REQUESTS = n_full


if __name__ == "__main__":
    import argparse

    from benchmarks.common import RESULTS_DIR
    ap = argparse.ArgumentParser()
    ap.add_argument("--hw", default="h100-nvlink-2gpu",
                    choices=sorted(HW_MODELS))
    ap.add_argument("--tiny", "--fast", dest="fast", action="store_true",
                    help="CI mode: fewer requests per cell")
    args = ap.parse_args()
    run(RESULTS_DIR, hw=args.hw, fast=args.fast)
