"""Paper Fig 2 — CDF of GPU memory consumption across a production cluster.

The paper plots the Alibaba gpu-v2020 trace (959,080 machine snapshots,
6,500 GPUs): ~68% of machines consume <=20% of GPU memory and ~87% consume
<=50%.  Our synthetic cluster-trace generator (repro.core.monitor) is
calibrated to those anchors; this benchmark samples it at trace scale and
validates the two anchor points within +-3pp, plus the dynamic trace's
long-run distribution within +-6pp (the OU/job dynamics wander around the
band mixture).
"""
from __future__ import annotations

from pathlib import Path

import numpy as np

from benchmarks.common import Check, fmt_table, save_result
from repro.core.monitor import ClusterTrace, ClusterTraceConfig


def run(out_dir: Path) -> dict:
    trace = ClusterTrace(ClusterTraceConfig(num_devices=64, seed=7))

    # static snapshot distribution (what Fig 2 actually plots)
    snaps = trace.sample_usage_fractions(n_machines=1800, n_snapshots=533)
    flat = snaps.reshape(-1)          # ~959k machine snapshots
    levels = [0.1, 0.2, 0.3, 0.5, 0.75, 0.9]
    cdf = {lv: float((flat <= lv).mean()) for lv in levels}

    # dynamic trace distribution (what drives revocations at runtime)
    dyn = []
    t2 = ClusterTrace(ClusterTraceConfig(num_devices=256, seed=11))
    for _ in range(400):
        dyn.append(t2.step() / t2.cfg.capacity_bytes)
    dyn = np.concatenate(dyn)
    dyn_cdf = {lv: float((dyn <= lv).mean()) for lv in levels}

    rows = [[f"<= {int(lv*100)}%", f"{cdf[lv]:.3f}", f"{dyn_cdf[lv]:.3f}"]
            for lv in levels]
    checks = [
        Check("fig2.snapshots", float(flat.size), lo=900_000,
              note="paper: 959,080 machine snapshots"),
        Check("fig2.cdf_at_20pct", cdf[0.2], lo=0.65, hi=0.71,
              note="paper: ~68% of machines use <=20% of GPU memory"),
        Check("fig2.cdf_at_50pct", cdf[0.5], lo=0.84, hi=0.90,
              note="paper: ~87% of machines use <=50% of GPU memory"),
        Check("fig2.dynamic_cdf_at_20pct", dyn_cdf[0.2], lo=0.62, hi=0.74,
              note="runtime trace stays near the calibrated mixture"),
        Check("fig2.dynamic_cdf_at_50pct", dyn_cdf[0.5], lo=0.81, hi=0.93),
    ]

    print("Fig 2 — cluster GPU-memory-consumption CDF "
          "(static snapshots / dynamic trace):")
    print(fmt_table(["usage level", "CDF (snapshots)", "CDF (dynamic)"], rows))

    payload = {"name": "fig2_cluster_cdf",
               "cdf": cdf, "dynamic_cdf": dyn_cdf,
               "n_snapshots": int(flat.size),
               "checks": [c.to_dict() for c in checks]}
    save_result(out_dir, "fig2_cluster_cdf", payload)
    return payload


if __name__ == "__main__":
    from benchmarks.common import RESULTS_DIR, summarize_checks
    out = run(RESULTS_DIR)
    print(summarize_checks([Check(**{k: v for k, v in c.items() if k != "ok"})
                            for c in out["checks"]]))
