"""Shared helpers for the paper-artifact benchmarks.

Every ``figN_*.py`` / ``table1_*.py`` module exposes ``run(out_dir) -> dict``
returning::

    {"name": ..., "rows": [...], "checks": [CheckResult-as-dict, ...]}

``run.py`` aggregates the checks into the PASS/FAIL summary that validates
the reproduction against the paper's own claims (EXPERIMENTS.md
§Paper-claims reads the emitted JSON).
"""
from __future__ import annotations

import json
from dataclasses import asdict, dataclass
from pathlib import Path
from typing import List, Optional

RESULTS_DIR = Path(__file__).resolve().parent.parent / "results"


@dataclass
class Check:
    """One claim-validation: value must land in [lo, hi] (inclusive)."""
    name: str
    value: float
    lo: Optional[float] = None
    hi: Optional[float] = None
    note: str = ""

    @property
    def ok(self) -> bool:
        if self.lo is not None and self.value < self.lo:
            return False
        if self.hi is not None and self.value > self.hi:
            return False
        return True

    def to_dict(self) -> dict:
        d = asdict(self)
        d["ok"] = self.ok
        return d


def save_result(out_dir: Path, name: str, payload: dict) -> Path:
    out_dir.mkdir(parents=True, exist_ok=True)
    p = out_dir / f"{name}.json"
    p.write_text(json.dumps(payload, indent=2, default=float))
    return p


def fmt_table(headers: List[str], rows: List[list]) -> str:
    """Plain-text aligned table for bench stdout."""
    cols = [headers] + [[str(c) for c in r] for r in rows]
    widths = [max(len(r[i]) for r in cols) for i in range(len(headers))]
    def line(r):
        return "  ".join(str(c).ljust(w) for c, w in zip(r, widths))
    sep = "  ".join("-" * w for w in widths)
    return "\n".join([line(headers), sep] + [line(r) for r in rows])


def summarize_checks(checks: List[Check]) -> str:
    lines = []
    for c in checks:
        band = ""
        if c.lo is not None or c.hi is not None:
            band = f" (band [{c.lo}, {c.hi}])"
        mark = "PASS" if c.ok else "FAIL"
        lines.append(f"  [{mark}] {c.name}: {c.value:.4g}{band}"
                     + (f" — {c.note}" if c.note else ""))
    return "\n".join(lines)
