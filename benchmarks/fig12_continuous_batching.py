"""Fig 12 (repo-original) — continuous batching: iteration-level slot
refill + chunked prefill (+ the speculative-decode cost seam).

The engine PR 6 shipped still scheduled like a static-batch system in
two ways: a retired request's batch row sat empty until the next
end-of-step admit pass, and a long prompt's prefill monopolized the
clock, stalling every latency-class decode queued behind it.  This
benchmark measures what iteration-level scheduling buys at the fig10
knee, per hardware family (H100+NVLink / TPU v5e+ICI):

  * **baseline** — async engine with ``iter_refill=False`` and no
    chunking: the PR 6 behaviour (batch-granularity admission, whole
    prompts prefill inline).
  * **continuous** — same engine with same-step slot refill and
    ``chunk_prefill_tokens``-sized resumable prefill chunks riding the
    decode weight read.
  * **continuous+spec** — adds the :class:`SpecDecodeConfig` seam,
    charging draft/verify windows on the same clock.

The workload mixes short latency-class requests (TTFT + e2e deadlines)
with long deadline-free batch prompts — the shape where chunked prefill
matters: without it every latency decode behind a long prompt eats the
whole prefill window.  Deadlines are calibrated like fig10: the
continuous system runs the knee rate once without deadlines and the SLO
is set at 2x its latency-class p99.

Headline checks: decoded tokens are BIT-IDENTICAL across baseline /
chunked / spec (scheduling changes when tokens land, never which), SLO
goodput at the knee is strictly higher than the PR 6 baseline,
``q.batch.q_occupancy`` >= 0.95 (rows never idle while work is queued),
and the clock identity holds with the new ``bubble_s`` class.
"""
from __future__ import annotations

import dataclasses
from pathlib import Path
from typing import Dict, List, Optional

from benchmarks.common import Check, fmt_table, save_result

RATES = (2e4, 4e5)             # below the knee + the fig10 knee
NUM_REQUESTS = 8
MAX_NEW_TOKENS = 10
BLOCK_SIZE = 8
LOCAL_SLOTS = 16
MAX_BATCH = 2
CHUNK_TOKENS = 16
SEED = 3

HW_MODELS = {"h100-nvlink-2gpu": "H100_NVLINK", "tpu-v5e": "TPU_V5E"}


def _hardware(hw: str):
    from repro.core import tiers
    return getattr(tiers, HW_MODELS[hw])


def _workload(rate: float, slo: Optional[Dict[str, float]]):
    from repro.serving import TenantSpec, Workload
    slo = slo or {}
    return Workload(
        num_requests=NUM_REQUESTS, arrival="poisson", rate=rate, seed=SEED,
        vocab=(3, 250),
        tenants=(
            TenantSpec("interactive", weight=2, slo="latency", priority=1,
                       prompt_len=(6, 10), max_new_tokens=MAX_NEW_TOKENS,
                       ttft_slo_s=slo.get("ttft"), e2e_slo_s=slo.get("e2e")),
            TenantSpec("background", weight=1, slo="batch",
                       prompt_len=(40, 56), max_new_tokens=MAX_NEW_TOKENS)))


def _server(cfg, params, hw: str, continuous: bool, spec: bool = False):
    from repro.core import HarvestRuntime, kv_block_bytes
    from repro.serving import HarvestServer, SpecDecodeConfig
    budget = 4 * 5 * kv_block_bytes(cfg, BLOCK_SIZE)
    runtime = HarvestRuntime({1: budget}, hardware=_hardware(hw))
    return HarvestServer(
        cfg, params, runtime=runtime, max_batch=MAX_BATCH,
        block_size=BLOCK_SIZE, num_local_slots=LOCAL_SLOTS,
        scheduler="fair", mode="async",
        iter_refill=continuous,
        chunk_prefill_tokens=CHUNK_TOKENS if continuous else None,
        spec_decode=(SpecDecodeConfig(draft_tokens=4, accept_rate=0.7)
                     if spec else None))


def _run_cell(cfg, params, hw: str, continuous: bool, rate: float,
              slo: Optional[Dict[str, float]], spec: bool = False):
    srv = _server(cfg, params, hw, continuous, spec=spec)
    stats = srv.run(_workload(rate, slo), max_steps=4000)
    outputs = [tuple(h.tokens) for h in srv.handles]
    lat = stats.latency_percentiles("latency")
    xfer = stats.metrics.get("transfer", {})
    return {
        "clock_s": stats.clock_s,
        "tokens": stats.tokens_out,
        "goodput": stats.goodput(),
        "goodput_latency": stats.goodput("latency"),
        "slo_attainment_latency": stats.slo_attainment("latency"),
        "ttft_p99_latency": lat["ttft_p99"],
        "e2e_p99_latency": lat["e2e_p99"],
        "preemptions": stats.preemptions,
        "bubble_s": stats.bubble_s,
        "occupancy": xfer.get("q.batch.occupancy", 0.0),
        "q_occupancy": xfer.get("q.batch.q_occupancy"),
        "identity_ok": float(stats.check_clock_identity()),
    }, outputs, stats


def _calibrate_slo(cfg, params, hw: str) -> Dict[str, float]:
    """2x the continuous system's latency-class p99 at the knee rate."""
    cell, _, _ = _run_cell(cfg, params, hw, continuous=True,
                           rate=max(RATES), slo=None)
    return {"ttft": 2.0 * cell["ttft_p99_latency"],
            "e2e": 2.0 * cell["e2e_p99_latency"]}


def run(out_dir: Path, hw: str = "h100-nvlink-2gpu", rates=RATES,
        fast: bool = False) -> dict:
    import jax

    from repro.configs import get_config
    from repro.models import model as M

    if hw not in HW_MODELS:
        raise ValueError(f"unknown hardware family {hw!r}; expected one of "
                         f"{sorted(HW_MODELS)}")
    if fast:
        rates = (max(rates),)

    cfg = dataclasses.replace(get_config("yi-6b").reduced(), num_layers=2)
    params = M.init_params(jax.random.PRNGKey(0), cfg)

    slo = _calibrate_slo(cfg, params, hw)
    rows: List[dict] = []
    table = []
    snapshot: Optional[Dict[str, dict]] = None
    for rate in rates:
        base, out_base, _ = _run_cell(cfg, params, hw, False, rate, slo)
        cont, out_cont, st_cont = _run_cell(cfg, params, hw, True, rate, slo)
        spec, out_spec, st_spec = _run_cell(cfg, params, hw, True, rate, slo,
                                            spec=True)
        row = {
            "rate": rate,
            "slo_ttft_s": slo["ttft"], "slo_e2e_s": slo["e2e"],
            "tokens_match_chunked": out_base == out_cont,
            "tokens_match_spec": out_cont == out_spec,
            "baseline": base, "continuous": cont, "spec": spec,
            "goodput_lift": (cont["goodput"] / base["goodput"]
                             if base["goodput"] else float("inf")),
        }
        rows.append(row)
        table.append([
            f"{rate:g}",
            "yes" if row["tokens_match_chunked"]
            and row["tokens_match_spec"] else "NO",
            f"{base['goodput']:.0f}", f"{cont['goodput']:.0f}",
            f"{row['goodput_lift']:.2f}x",
            f"{base['ttft_p99_latency'] * 1e6:.1f}",
            f"{cont['ttft_p99_latency'] * 1e6:.1f}",
            f"{cont['occupancy']:.0%}",
            "-" if cont["q_occupancy"] is None
            else f"{cont['q_occupancy']:.0%}",
            f"{cont['bubble_s'] * 1e6:.2f}"])
        if rate == max(rates):
            # the knee cell's metrics (q.batch.* occupancy counters) merged
            # with the spec cell's "spec" namespace for report --section
            # metrics
            snapshot = dict(st_cont.metrics)
            snapshot["spec"] = st_spec.metrics.get("spec", {})
    print(f"Fig 12 — continuous batching at the fig10 knee ({hw}; "
          f"SLO = 2x continuous p99 at the top rate):")
    print(fmt_table(
        ["req/s", "tokens=", "base tok/s", "cont tok/s", "lift",
         "ttft99 base us", "ttft99 cont us", "occ", "occ@queued",
         "bubble us"], table))
    print()

    knee = max(rows, key=lambda r: r["rate"])
    q_occ = knee["continuous"]["q_occupancy"]
    checks = [
        Check("fig12.tokens_chunked_invariant",
              float(all(r["tokens_match_chunked"] for r in rows)), lo=1.0,
              note="chunked and unchunked prefill emit bit-identical "
                   "tokens: scheduling changes when tokens land, never "
                   "which"),
        Check("fig12.tokens_spec_invariant",
              float(all(r["tokens_match_spec"] for r in rows)), lo=1.0,
              note="the speculative-decode seam charges clock only — "
                   "emitted tokens are unchanged"),
        Check("fig12.goodput_knee_lift", knee["goodput_lift"], lo=1.0 + 1e-3,
              note="iteration-level refill + chunked prefill strictly "
                   "lift SLO goodput over the PR 6 baseline at the knee"),
        Check("fig12.occupancy_while_queued",
              q_occ if q_occ is not None else 0.0, lo=0.95,
              note="batch rows are >= 95% occupied (time-weighted) while "
                   "the ready queue is non-empty"),
        Check("fig12.clock_identity_with_bubble",
              float(all(r[sys]["identity_ok"] for r in rows
                        for sys in ("baseline", "continuous", "spec"))),
              lo=1.0,
              note="clock identity holds in every cell with the bubble_s "
                   "accounting class folded in"),
    ]

    payload = {"name": "fig12_continuous_batching", "hw": hw, "rows": rows,
               "checks": [c.to_dict() for c in checks],
               "metrics": snapshot or {}}
    save_result(out_dir, "fig12_continuous_batching", payload)
    return payload


if __name__ == "__main__":
    import argparse

    from benchmarks.common import RESULTS_DIR
    ap = argparse.ArgumentParser()
    ap.add_argument("--hw", default="h100-nvlink-2gpu",
                    choices=sorted(HW_MODELS))
    ap.add_argument("--tiny", "--fast", dest="fast", action="store_true",
                    help="CI mode: knee rate only")
    args = ap.parse_args()
    run(RESULTS_DIR, hw=args.hw, fast=args.fast)
