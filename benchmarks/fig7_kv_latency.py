"""Paper Fig 7 — KV-cache transfer latency, CPU reload vs peer-GPU reload.

The paper measures the time to reload chunks of {100, 500, 1000, 2000,
4000, 8000} FP16 KV-cache entries for DeepSeek-V3, Mistral-Large-3-675B and
Kimi-K2 via (i) host->GPU copies (vanilla vLLM swap-in) and (ii) peer->GPU
copies (Harvest).  Claims: Kimi-K2 speedup 5.42x @100 entries -> 5.68x
@8000; Mistral-Large-3 ~3x -> 5.65x; gap widens with sequence length.

Cost model: a reload of C entries issues one copy per layer-resident KV
tensor (vLLM keeps KV per layer), so

    t = n_tensors * staging + C * entry_bytes / bw_effective

with per-model staging constants calibrated to the paper's measured
endpoints (the paper's Fig 7 implies per-model copy-path overheads: the
MLA models see higher host staging, Mistral's many-tensor GQA layout sees
higher peer staging — we record the calibration rather than hide it).
KV-entry sizes derive from the model cards:
  * DeepSeek-V3 / Kimi-K2: 61 layers, MLA compressed KV (512 latent + 64
    rope dims) -> 1,152 B/layer/token, one tensor per layer.
  * Mistral-Large-3-675B: 88 layers, GQA 8 kv-heads x head_dim 128 ->
    4,096 B/layer/token, K and V tensors per layer.
"""
from __future__ import annotations

from dataclasses import dataclass
from pathlib import Path

from benchmarks.common import Check, fmt_table, save_result

ENTRY_COUNTS = [100, 500, 1000, 2000, 4000, 8000]

# effective copy bandwidths for the block-scatter KV path (lower than the
# contiguous Fig-3 path: vLLM copies per-layer tensors of paged blocks)
BW_HOST = 52.8e9
BW_PEER = 300e9


@dataclass(frozen=True)
class KVModel:
    name: str
    n_tensors: int          # per-layer KV tensors copied per reload
    entry_bytes: int        # bytes of ONE token's KV across all layers
    host_staging: float     # s per tensor copy, host path
    peer_staging: float     # s per tensor copy, peer path


MODELS = [
    KVModel("deepseek-v3", n_tensors=61, entry_bytes=61 * 1152,
            host_staging=90e-6 / 61, peer_staging=20e-6 / 61),
    KVModel("mistral-large-3-675b", n_tensors=176, entry_bytes=176 * 2048,
            host_staging=6e-6 / 176, peer_staging=110e-6 / 176),
    KVModel("kimi-k2", n_tensors=61, entry_bytes=61 * 1152,
            host_staging=97e-6 / 61, peer_staging=19e-6 / 61),
]


def reload_time(m: KVModel, entries: int, peer: bool) -> float:
    nbytes = entries * m.entry_bytes
    if peer:
        return m.n_tensors * m.peer_staging + nbytes / BW_PEER
    return m.n_tensors * m.host_staging + nbytes / BW_HOST


def run(out_dir: Path) -> dict:
    out_rows, checks = [], []
    for m in MODELS:
        speedups = []
        rows = []
        for c in ENTRY_COUNTS:
            th = reload_time(m, c, peer=False)
            tp = reload_time(m, c, peer=True)
            speedups.append(th / tp)
            rows.append([c, f"{th*1e3:.3f}", f"{tp*1e3:.3f}",
                         f"{th/tp:.2f}x"])
        out_rows.append({"model": m.name, "entries": ENTRY_COUNTS,
                         "host_ms": [reload_time(m, c, False) * 1e3
                                     for c in ENTRY_COUNTS],
                         "peer_ms": [reload_time(m, c, True) * 1e3
                                     for c in ENTRY_COUNTS],
                         "speedups": speedups})
        monotone = all(speedups[i] <= speedups[i + 1] + 1e-9
                       for i in range(len(speedups) - 1))
        checks.append(Check(f"fig7.{m.name}.gap_widens", float(monotone),
                            lo=1.0, note="speedup grows with entry count"))
        print(f"Fig 7 — {m.name} (KV entry = "
              f"{m.entry_bytes/1024:.1f} KiB/token):")
        print(fmt_table(["entries", "host ms", "peer ms", "speedup"], rows))
        print()

    by = {r["model"]: r["speedups"] for r in out_rows}
    checks += [
        Check("fig7.kimi_k2.speedup_at_100", by["kimi-k2"][0],
              lo=5.2, hi=5.6, note="paper: ~5.42x at 100 KV entries"),
        Check("fig7.kimi_k2.speedup_at_8000", by["kimi-k2"][-1],
              lo=5.5, hi=5.8, note="paper: ~5.68x at 8000 KV entries"),
        Check("fig7.mistral.speedup_at_100",
              by["mistral-large-3-675b"][0], lo=2.8, hi=3.2,
              note="paper: ~3x at 100 KV entries"),
        Check("fig7.mistral.speedup_at_8000",
              by["mistral-large-3-675b"][-1], lo=5.4, hi=5.8,
              note="paper: ~5.65x at 8000 KV entries"),
        Check("fig7.min_speedup",
              min(min(r["speedups"]) for r in out_rows), lo=1.5,
              note="peer reload consistently faster than host reload"),
    ]

    payload = {"name": "fig7_kv_latency", "rows": out_rows,
               "checks": [c.to_dict() for c in checks]}
    save_result(out_dir, "fig7_kv_latency", payload)
    return payload


if __name__ == "__main__":
    from benchmarks.common import RESULTS_DIR
    run(RESULTS_DIR)
