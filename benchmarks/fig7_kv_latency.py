"""Paper Fig 7 — KV-cache transfer latency, CPU reload vs peer-GPU reload.

The paper measures the time to reload chunks of {100, 500, 1000, 2000,
4000, 8000} FP16 KV-cache entries for DeepSeek-V3, Mistral-Large-3-675B and
Kimi-K2 via (i) host->GPU copies (vanilla vLLM swap-in) and (ii) peer->GPU
copies (Harvest).  Claims: Kimi-K2 speedup 5.42x @100 entries -> 5.68x
@8000; Mistral-Large-3 ~3x -> 5.65x; gap widens with sequence length.

Cost model: a reload of C entries issues one copy per layer-resident KV
tensor (vLLM keeps KV per layer), so

    t = n_tensors * staging + C * entry_bytes / bw_effective

with per-model staging constants calibrated to the paper's measured
endpoints (the paper's Fig 7 implies per-model copy-path overheads: the
MLA models see higher host staging, Mistral's many-tensor GQA layout sees
higher peer staging — we record the calibration rather than hide it).
A second, *pipelined* section plays the same reloads through the
TransferEngine's event timeline: the reload is issued at a decode-step
boundary and the table reports how many decode windows pass before the
resumed request's KV is ready — the paper's "reload hides under decode"
claim as a mechanism instead of a ratio.

KV-entry sizes derive from the model cards:
  * DeepSeek-V3 / Kimi-K2: 61 layers, MLA compressed KV (512 latent + 64
    rope dims) -> 1,152 B/layer/token, one tensor per layer.
  * Mistral-Large-3-675B: 88 layers, GQA 8 kv-heads x head_dim 128 ->
    4,096 B/layer/token, K and V tensors per layer.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from pathlib import Path

from benchmarks.common import Check, fmt_table, save_result
from repro.core.store import TransferEngine
from repro.core.tiers import HardwareModel, LinkSpec, Tier

ENTRY_COUNTS = [100, 500, 1000, 2000, 4000, 8000]

# effective copy bandwidths for the block-scatter KV path (lower than the
# contiguous Fig-3 path: vLLM copies per-layer tensors of paged blocks)
BW_HOST = 52.8e9
BW_PEER = 300e9

# the same KV copy paths expressed as a HardwareModel, so the pipelined
# section below can play reloads through the TransferEngine's event clock
# (per-tensor staging is passed per-transfer as extra latency)
KV_PATH_HW = HardwareModel(
    name="fig7-kv-copy-path",
    peer_link=LinkSpec(bandwidth=BW_PEER, latency=0.0),
    host_link=LinkSpec(bandwidth=BW_HOST, latency=0.0),
    hbm_bw=3.35e12, peak_flops=989e12, hbm_bytes=80 * 2**30)

# pipelined-reload demo: one decode iteration of these ~trillion-class
# models is ~2 ms; a preempted request's KV reload is issued when the
# request is re-admitted and hides under the other requests' decode steps
DECODE_WINDOW_S = 2e-3
PIPELINE_ENTRIES = 2000


@dataclass(frozen=True)
class KVModel:
    name: str
    n_tensors: int          # per-layer KV tensors copied per reload
    entry_bytes: int        # bytes of ONE token's KV across all layers
    host_staging: float     # s per tensor copy, host path
    peer_staging: float     # s per tensor copy, peer path


MODELS = [
    KVModel("deepseek-v3", n_tensors=61, entry_bytes=61 * 1152,
            host_staging=90e-6 / 61, peer_staging=20e-6 / 61),
    KVModel("mistral-large-3-675b", n_tensors=176, entry_bytes=176 * 2048,
            host_staging=6e-6 / 176, peer_staging=110e-6 / 176),
    KVModel("kimi-k2", n_tensors=61, entry_bytes=61 * 1152,
            host_staging=97e-6 / 61, peer_staging=19e-6 / 61),
]


def reload_time(m: KVModel, entries: int, peer: bool) -> float:
    nbytes = entries * m.entry_bytes
    if peer:
        return m.n_tensors * m.peer_staging + nbytes / BW_PEER
    return m.n_tensors * m.host_staging + nbytes / BW_HOST


def pipeline_stall_steps(m: KVModel, entries: int,
                         window_s: float = DECODE_WINDOW_S) -> dict:
    """Event-timeline view: how many decode steps does a reload of
    ``entries`` KV entries stall the resumed request for, when issued at a
    step boundary while decode keeps computing in ``window_s`` windows?

    Both paths are submitted on one TransferEngine — they ride different
    links, so the clock models them concurrently, exactly as the serving
    engine's async mode does.
    """
    te = TransferEngine(KV_PATH_HW)
    nbytes = entries * m.entry_bytes
    host = te.submit(te.transfer(
        (m.name, "host"), nbytes, Tier.HOST_DRAM, Tier.LOCAL_HBM,
        extra_latency=m.n_tensors * m.host_staging, client="fig7"))
    peer = te.submit(te.transfer(
        (m.name, "peer"), nbytes, Tier.PEER_HBM, Tier.LOCAL_HBM,
        extra_latency=m.n_tensors * m.peer_staging, client="fig7"))
    te.wait_for([host, peer])
    # timeline sanity: the event clock must agree with the closed form
    assert abs(host.ready_t - reload_time(m, entries, peer=False)) < 1e-12
    assert abs(peer.ready_t - reload_time(m, entries, peer=True)) < 1e-12
    return {"host_steps": math.ceil(host.ready_t / window_s),
            "peer_steps": math.ceil(peer.ready_t / window_s)}


def run(out_dir: Path) -> dict:
    out_rows, checks = [], []
    for m in MODELS:
        speedups = []
        rows = []
        for c in ENTRY_COUNTS:
            th = reload_time(m, c, peer=False)
            tp = reload_time(m, c, peer=True)
            speedups.append(th / tp)
            rows.append([c, f"{th*1e3:.3f}", f"{tp*1e3:.3f}",
                         f"{th/tp:.2f}x"])
        out_rows.append({"model": m.name, "entries": ENTRY_COUNTS,
                         "host_ms": [reload_time(m, c, False) * 1e3
                                     for c in ENTRY_COUNTS],
                         "peer_ms": [reload_time(m, c, True) * 1e3
                                     for c in ENTRY_COUNTS],
                         "speedups": speedups})
        monotone = all(speedups[i] <= speedups[i + 1] + 1e-9
                       for i in range(len(speedups) - 1))
        checks.append(Check(f"fig7.{m.name}.gap_widens", float(monotone),
                            lo=1.0, note="speedup grows with entry count"))
        print(f"Fig 7 — {m.name} (KV entry = "
              f"{m.entry_bytes/1024:.1f} KiB/token):")
        print(fmt_table(["entries", "host ms", "peer ms", "speedup"], rows))
        print()

    # --- pipelined view: a re-admitted request's KV reload on the event
    # timeline, hiding under other requests' decode steps
    pipe_rows, pipe_out = [], []
    for m in MODELS:
        s = pipeline_stall_steps(m, PIPELINE_ENTRIES)
        pipe_rows.append([m.name, s["host_steps"], s["peer_steps"],
                          s["host_steps"] - s["peer_steps"]])
        pipe_out.append({"model": m.name, "entries": PIPELINE_ENTRIES,
                         "window_ms": DECODE_WINDOW_S * 1e3, **s})
    print(f"Fig 7 (pipelined) — decode steps until a {PIPELINE_ENTRIES}-entry "
          f"reload is ready ({DECODE_WINDOW_S*1e3:.0f} ms decode windows):")
    print(fmt_table(["model", "host steps", "peer steps", "steps saved"],
                    pipe_rows))
    print()
    checks.append(Check(
        "fig7.pipeline.steps_saved_min",
        float(min(r["host_steps"] - r["peer_steps"] for r in pipe_out)),
        lo=1.0, note="peer reloads re-enter decode strictly sooner"))
    checks.append(Check(
        "fig7.pipeline.peer_steps_max",
        float(max(r["peer_steps"] for r in pipe_out)), hi=2.0,
        note="peer reloads hide under a decode step or two"))

    by = {r["model"]: r["speedups"] for r in out_rows}
    checks += [
        Check("fig7.kimi_k2.speedup_at_100", by["kimi-k2"][0],
              lo=5.2, hi=5.6, note="paper: ~5.42x at 100 KV entries"),
        Check("fig7.kimi_k2.speedup_at_8000", by["kimi-k2"][-1],
              lo=5.5, hi=5.8, note="paper: ~5.68x at 8000 KV entries"),
        Check("fig7.mistral.speedup_at_100",
              by["mistral-large-3-675b"][0], lo=2.8, hi=3.2,
              note="paper: ~3x at 100 KV entries"),
        Check("fig7.mistral.speedup_at_8000",
              by["mistral-large-3-675b"][-1], lo=5.4, hi=5.8,
              note="paper: ~5.65x at 8000 KV entries"),
        Check("fig7.min_speedup",
              min(min(r["speedups"]) for r in out_rows), lo=1.5,
              note="peer reload consistently faster than host reload"),
    ]

    payload = {"name": "fig7_kv_latency", "rows": out_rows,
               "pipeline_rows": pipe_out,
               "checks": [c.to_dict() for c in checks]}
    save_result(out_dir, "fig7_kv_latency", payload)
    return payload


if __name__ == "__main__":
    from benchmarks.common import RESULTS_DIR
    run(RESULTS_DIR)
