"""Fig 13 (repo-original) — fidelity-tiered KV demotion.

The fidelity PR lets the store demote evicted KV blocks at reduced
precision: the per-SLO :class:`FidelityPolicy` keeps latency-class
blocks at FP16 (bit-exact) while batch-class blocks ride the wire as
int4 (per-block scale + packed nibbles, quantized by the fused Pallas
``quantize_demote`` kernel and restored by ``dequantize_reload``).  The
quantize/dequantize passes are charged on the engine clock
(``nbytes / hbm_bw`` each way), so the bet is explicit: a 4x wire-byte
reduction against two extra HBM sweeps.

This benchmark measures that bet per hardware family (H100+NVLink /
TPU v5e+ICI) on a preemption-heavy fair-share workload at two capacity
points:

  * **tight** — the fig4-style knee: 4 requests, 2 batch rows, a local
    slot pool small enough that fair-share preemption demotes and
    reloads KV every scheduling quantum.
  * **ample** — slack capacity: nothing evicts, so fidelity-on must be
    a byte-for-byte no-op.

Headline checks: latency-class tokens are BIT-IDENTICAL with the
policy on (FP16 demotion is the seed path), batch-class link bytes
shrink >= 3x at the tight point, the async clock is STRICTLY lower for
the quantized batch class (fewer wire bytes beat the quantize tax),
and the clock identity holds in every cell.
"""
from __future__ import annotations

import dataclasses
from pathlib import Path
from typing import Dict, List, Optional, Tuple

from benchmarks.common import Check, fmt_table, save_result

NUM_REQUESTS = 4
MAX_NEW_TOKENS = 12
BLOCK_SIZE = 8
# tight: 2 batch rows + a small slot pool -> fair-share preemption demotes
# KV every quantum.  ample: every request gets a row and slots are slack,
# so nothing ever evicts and fidelity-on must be a no-op.
BATCH = {"tight": 2, "ample": NUM_REQUESTS}
SLOTS = {"tight": 10, "ample": 64}
SEED = 0

HW_MODELS = {"h100-nvlink-2gpu": "H100_NVLINK", "tpu-v5e": "TPU_V5E"}


def _hardware(hw: str):
    from repro.core import tiers
    return getattr(tiers, HW_MODELS[hw])


def _policy():
    from repro.core import Fidelity, FidelityPolicy
    return FidelityPolicy(mode="slo", batch=Fidelity.INT4)


def _run_cell(cfg, params, hw: str, capacity: str, slo: str,
              fidelity: bool) -> Tuple[dict, List[tuple]]:
    from repro.core import HarvestAllocator
    from repro.serving.engine import HarvestServingEngine
    MiB = 2**20
    eng = HarvestServingEngine(
        cfg, params, max_batch=BATCH[capacity], block_size=BLOCK_SIZE,
        num_local_slots=SLOTS[capacity], max_seq_len=96,
        allocator=HarvestAllocator({1: 64 * MiB}),
        hardware=_hardware(hw), scheduler="fair", mode="async",
        fidelity_policy=_policy() if fidelity else None)
    reqs = [eng.submit_request(prompt=[2 + i, 5, 7, 11, 13 + i],
                               max_new_tokens=MAX_NEW_TOKENS, slo=slo)
            for i in range(NUM_REQUESTS)]
    stats = eng.run(max_steps=4000)
    outputs = [tuple(r.output) for r in reqs]
    xfer = stats.metrics.get("transfer", {})
    link_bytes = sum(v for k, v in xfer.items() if k.endswith("_bytes"))
    fid = stats.metrics.get("fid", {})
    return {
        "clock_s": stats.clock_s,
        "tokens": stats.tokens_out,
        "preemptions": stats.preemptions,
        "link_bytes": link_bytes,
        "demote_quantized": fid.get("demote_quantized", 0),
        "reload_dequantized": fid.get("reload_dequantized", 0),
        "bytes_saved": fid.get("bytes_saved", 0),
        "dequant_s": fid.get("dequant_s", 0.0),
        "identity_ok": float(stats.check_clock_identity()),
    }, outputs


def run(out_dir: Path, hw: str = "h100-nvlink-2gpu",
        fast: bool = False) -> dict:
    import jax

    from repro.configs import get_config
    from repro.models import model as M

    if hw not in HW_MODELS:
        raise ValueError(f"unknown hardware family {hw!r}; expected one of "
                         f"{sorted(HW_MODELS)}")
    capacities = ("tight",) if fast else ("tight", "ample")

    cfg = dataclasses.replace(get_config("yi-6b").reduced(), num_layers=2)
    params = M.init_params(jax.random.PRNGKey(SEED), cfg)

    rows: List[dict] = []
    table = []
    snapshot: Optional[Dict[str, dict]] = None
    for capacity in capacities:
        for slo in ("latency", "batch"):
            off, out_off = _run_cell(cfg, params, hw, capacity, slo, False)
            fid, out_fid = _run_cell(cfg, params, hw, capacity, slo, True)
            row = {
                "capacity": capacity, "slo": slo,
                "tokens_match": out_off == out_fid,
                "off": off, "fidelity": fid,
                "link_bytes_ratio": (off["link_bytes"] / fid["link_bytes"]
                                     if fid["link_bytes"]
                                     else float("inf")
                                     if off["link_bytes"] else 1.0),
            }
            rows.append(row)
            table.append([
                capacity, slo,
                "yes" if row["tokens_match"] else "NO",
                str(fid["demote_quantized"]),
                f"{off['link_bytes'] / 2**10:.1f}",
                f"{fid['link_bytes'] / 2**10:.1f}",
                f"{row['link_bytes_ratio']:.2f}x",
                f"{off['clock_s'] * 1e6:.3f}",
                f"{fid['clock_s'] * 1e6:.3f}",
                f"{fid['dequant_s'] * 1e9:.1f}"])
            if capacity == "tight" and slo == "batch":
                snapshot = {"fid": {k: v for k, v in
                            {"demote_quantized": fid["demote_quantized"],
                             "reload_dequantized": fid["reload_dequantized"],
                             "bytes_saved": fid["bytes_saved"],
                             "dequant_s": fid["dequant_s"]}.items()}}

    print(f"Fig 13 — fidelity-tiered KV demotion ({hw}; slo policy, "
          f"batch class -> int4):")
    print(fmt_table(
        ["capacity", "class", "tokens=", "demotes", "off KiB", "fid KiB",
         "ratio", "off clock us", "fid clock us", "dequant ns"], table))
    print()

    by = {(r["capacity"], r["slo"]): r for r in rows}
    knee = by[("tight", "batch")]
    lat = by[("tight", "latency")]
    checks = [
        Check("fig13.latency_tokens_bit_identical",
              float(all(r["tokens_match"] for r in rows
                        if r["slo"] == "latency")), lo=1.0,
              note="latency-class demotion stays FP16: tokens are "
                   "bit-identical to the fidelity-off baseline"),
        Check("fig13.latency_clock_unchanged",
              float(lat["off"]["clock_s"] == lat["fidelity"]["clock_s"]),
              lo=1.0,
              note="FP16 demotion moves the same wire bytes, so the "
                   "latency-class clock is exactly the baseline's"),
        Check("fig13.batch_link_bytes_reduction", knee["link_bytes_ratio"],
              lo=3.0,
              note="int4 demotion shrinks batch-class link bytes >= 3x at "
                   "the tight-capacity knee (4x payload minus the "
                   "per-block scale)"),
        Check("fig13.batch_clock_strictly_lower",
              float(knee["fidelity"]["clock_s"] < knee["off"]["clock_s"]),
              lo=1.0,
              note="fewer wire bytes beat the quantize/dequantize HBM "
                   "sweeps: the quantized batch class finishes strictly "
                   "earlier on the async clock"),
        Check("fig13.batch_quantized_demotes",
              float(knee["fidelity"]["demote_quantized"]), lo=1.0,
              note="the tight cell actually exercises the quantize path"),
        Check("fig13.batch_tokens_complete",
              float(knee["fidelity"]["tokens"]
                    == NUM_REQUESTS * MAX_NEW_TOKENS), lo=1.0,
              note="quantized KV still decodes the full token budget"),
        Check("fig13.ample_capacity_noop", float(all(
            r["tokens_match"]
            and r["off"]["link_bytes"] == r["fidelity"]["link_bytes"]
            and r["fidelity"]["demote_quantized"] == 0
            for r in rows if r["capacity"] == "ample")), lo=1.0,
              note="with slack capacity nothing evicts, so the policy is "
                   "a byte-for-byte no-op in every class"),
        Check("fig13.clock_identity", float(all(
            r[sysname]["identity_ok"] for r in rows
            for sysname in ("off", "fidelity"))), lo=1.0,
              note="clock identity holds in every cell with the "
                   "quantize/dequantize compute riding reload_s"),
    ]

    payload = {"name": "fig13_fidelity_tiers", "hw": hw, "rows": rows,
               "checks": [c.to_dict() for c in checks],
               "metrics": snapshot or {}}
    save_result(out_dir, "fig13_fidelity_tiers", payload)
    return payload


if __name__ == "__main__":
    import argparse

    from benchmarks.common import RESULTS_DIR
    ap = argparse.ArgumentParser()
    ap.add_argument("--hw", default="h100-nvlink-2gpu",
                    choices=sorted(HW_MODELS))
    ap.add_argument("--tiny", "--fast", dest="fast", action="store_true",
                    help="CI mode: tight capacity only")
    args = ap.parse_args()
    run(RESULTS_DIR, hw=args.hw, fast=args.fast)
