"""Paper Fig 5 — decode throughput gain from peer-GPU expert offload.

Setup mirrors the paper (§4.4/§4.5): MoE-Lightning test bench semantics with
micro-batch 324 x 14 micro-batches (N = 4,536 tokens), 32 decode steps, 50%
of experts offloaded, averaged over 5 trials.  Peer offload (Harvest over
NVLink) vs CPU offload (CGOPipe over PCIe).

Claims validated:
  * throughput gains range +48% .. >110% across the four models;
  * Phi-3.5-MoE's gain is ~2x Qwen2-MoE's (fewer experts + smaller fan-out
    -> higher temporal locality);
  * gains come from serving expert misses from peer HBM only (routing,
    batching, attention untouched — the simulator shares every other code
    path between the two configurations).
"""
from __future__ import annotations

from pathlib import Path

from benchmarks.common import Check, fmt_table, save_result
from repro.configs import PAPER_ARCHS, get_config
from repro.core.runtime import HarvestRuntime
from repro.core.simulator import AccessModelConfig, simulate_moe_decode
from repro.core.tiers import H100_NVLINK

# The paper runs 5 trials x 32 generated tokens; the per-step gains are
# stationary, so the default harness uses 2x8 (the CPU-python pipeline sim
# is O(steps x layers x microbatches)); pass trials/decode_steps for the
# paper-exact setting.
TRIALS = 2
DECODE_STEPS = 8


def run(out_dir: Path, trials: int = TRIALS,
        decode_steps: int = DECODE_STEPS, timeline: bool = False) -> dict:
    """``timeline=True`` re-runs the peer configuration on the
    TransferEngine's event-driven clock (one trial, same seeds) and records
    the resulting tokens/s next to the analytic number — the claim checks
    always validate the analytic (golden) path."""
    hw = H100_NVLINK
    # one runtime for the whole figure: its TransferEngine accounts every
    # simulated peer fetch into the unified metrics snapshot saved below
    runtime = HarvestRuntime(hardware=hw)
    rows, out_rows = [], []
    gains = {}
    for arch in PAPER_ARCHS:
        cfg = get_config(arch)
        peer_tps, host_tps = [], []
        for t in range(trials):
            am = AccessModelConfig(seed=t)
            p = simulate_moe_decode(cfg, hw, 0.5, use_peer=True,
                                    decode_steps=decode_steps, access=am,
                                    runtime=runtime)
            h = simulate_moe_decode(cfg, hw, 0.5, use_peer=False,
                                    decode_steps=decode_steps, access=am,
                                    runtime=runtime)
            peer_tps.append(p.tokens_per_s)
            host_tps.append(h.tokens_per_s)
        peer = sum(peer_tps) / trials
        host = sum(host_tps) / trials
        gain = peer / host - 1
        gains[arch] = gain
        row = {"model": arch, "host_tps": host, "peer_tps": peer,
               "gain": gain,
               "distinct_experts_per_ub": p.distinct_experts_per_ub}
        if timeline:
            # a separate runtime so the analytic metrics snapshot saved
            # below stays pure (one configuration, not a merged sum)
            tl = simulate_moe_decode(
                cfg, hw, 0.5, use_peer=True, decode_steps=decode_steps,
                access=AccessModelConfig(seed=0),
                runtime=HarvestRuntime(hardware=hw), use_timeline=True)
            row["peer_tps_timeline"] = tl.tokens_per_s
        rows.append([arch, f"{host:.0f}", f"{peer:.0f}", f"+{gain*100:.0f}%"])
        out_rows.append(row)

    checks = [
        Check("fig5.min_gain_pct", min(gains.values()) * 100, lo=40, hi=60,
              note="paper: gains start at +48%"),
        Check("fig5.max_gain_pct", max(gains.values()) * 100, lo=105,
              note="paper: gains exceed +110%"),
        Check("fig5.phi35_vs_qwen2_ratio",
              gains["phi-3.5-moe"] / gains["qwen2-moe"], lo=1.6, hi=2.6,
              note="paper: Phi-3.5-MoE ~2x the speedup of Qwen2-MoE"),
        Check("fig5.all_positive", min(gains.values()), lo=0.0,
              note="peer offload never loses to CPU offload"),
    ]

    print("Fig 5 — decode throughput at 50% experts offloaded "
          f"({trials} trials x {decode_steps} steps):")
    print(fmt_table(["model", "CPU offload tok/s", "Harvest tok/s", "gain"],
                    rows))

    snap = runtime.stats()
    payload = {"name": "fig5_moe_throughput", "rows": out_rows,
               "metrics": snap,
               "transfer_metrics": snap.get("transfer", {}),  # back-compat
               "checks": [c.to_dict() for c in checks]}
    save_result(out_dir, "fig5_moe_throughput", payload)
    return payload


if __name__ == "__main__":
    from benchmarks.common import RESULTS_DIR
    run(RESULTS_DIR)
