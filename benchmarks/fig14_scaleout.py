"""Fig 14 (repo-original) — scale-out harvesting over the DCN host tier.

Three parts, one per layer of the scale-out story:

**A. Disaggregated prefill/decode (real engine).**  On a 4-host DCN
preset, the fig10 SLO-serving workload runs twice at the knee rate:
colocated (prefill stalls the decode hosts) vs disaggregated (a shared
prefill pool streams finished KV blocks over DCN; decode hosts adopt
them like prefix-cache hits).  TTFT deadlines are calibrated on the
*uncongested* colocated system — 2x its latency-class p99 at the lowest
fig10 rate — so the knee cells answer the operator's question: does the
target provisioned under light load survive the rush hour?  Decoded
tokens must be IDENTICAL (disaggregation re-times requests, never
re-decodes them), the KV streams must ride coalesced DCN transfers
(PR 4 composition: one wire setup per prefill chunk, not per block),
and disaggregation must strictly lift SLO goodput at the knee.

**B. Host scaling (vectorized sweep model).**  ``repro.serving.sweep``
replays a diurnal trace across 1/2/4-host clusters, colocated and
disaggregated, at a rate that saturates a single host.  Checks: the
cluster makespan shrinks with hosts, and disaggregation cuts mean TTFT
at 4 hosts (prefill windows leave the decode clock).

**C. The vectorized event loop (perf refactor).**  The same trace at
million-request scale through both step loops — the scalar
engine-accounting-style reference and the run-leaping vectorized
refactor.  Checks: bit-identical admit/first-token/finish times and
clock (the refactor is an accounting change, not a model change) and a
>=10x walltime speedup at the 1M x 4-host point (the fast CI sweep
runs a smaller trace against a looser bound).
"""
from __future__ import annotations

import dataclasses
import time
from pathlib import Path
from typing import Dict, List, Optional, Tuple

import numpy as np

from benchmarks.common import Check, fmt_table, save_result

# ---- Part A: fig10 serving constants at the knee, on 4-host presets
NUM_REQUESTS = 16
MAX_NEW_TOKENS = 10
BLOCK_SIZE = 8
LOCAL_SLOTS = 10
MAX_BATCH = 2
SEED = 3
RATE_CALIBRATE = 2e4           # uncongested: where the SLO is provisioned
RATE_KNEE = 4e5                # the fig10 knee: where it must survive
PREFILL_WORKERS = 3
MAX_STEPS = 8000

# hw leg -> (4-host topology preset, sweep-model hardware family)
HW_FAMILIES = {
    "h100-nvlink-2gpu": ("h100-dcn-4host", "h100"),
    "tpu-v5e": ("v5e-dcn-4host", "tpu-v5e"),
}

# ---- Part B/C: vectorized sweep-model scales
SWEEP_RATE = 2e3               # req/s — saturates one host, loads four
SWEEP_N = {False: 80_000, True: 20_000}          # full / fast
PERF_N = {False: 1_000_000, True: 120_000}       # full / fast
PERF_SPEEDUP_LO = {False: 10.0, True: 4.0}       # full bound is the claim
PERF_OUT_LEN = (16, 97)
IDENT_N = 4_000


# ------------------------------------------------------- Part A (engine)
def _workload(rate: float, ttft_slo_s: Optional[float]):
    from repro.serving import TenantSpec, Workload
    return Workload(
        num_requests=NUM_REQUESTS, arrival="poisson", rate=rate, seed=SEED,
        vocab=(3, 250),
        tenants=(
            TenantSpec("interactive", weight=2, slo="latency", priority=1,
                       prompt_len=(18, 23), max_new_tokens=MAX_NEW_TOKENS,
                       ttft_slo_s=ttft_slo_s),
            TenantSpec("background", weight=1, slo="batch",
                       prompt_len=(18, 23), max_new_tokens=MAX_NEW_TOKENS)))


def _server(cfg, params, topo_name: str, disaggregated: bool):
    from repro.core import (HarvestRuntime, TopologyAwarePolicy,
                            get_topology, kv_block_bytes)
    from repro.serving import HarvestServer
    topo = get_topology(topo_name)
    budget = 4 * 5 * kv_block_bytes(cfg, BLOCK_SIZE)
    runtime = HarvestRuntime(topo.device_budgets(budget), topology=topo,
                             policy=TopologyAwarePolicy(topo))
    kwargs = (dict(disaggregated=True, prefill_workers=PREFILL_WORKERS)
              if disaggregated else {})
    return HarvestServer(cfg, params, runtime=runtime, max_batch=MAX_BATCH,
                         block_size=BLOCK_SIZE, num_local_slots=LOCAL_SLOTS,
                         scheduler="fcfs", mode="async", **kwargs)


def _run_cell(cfg, params, topo_name: str, disaggregated: bool, rate: float,
              ttft_slo_s: Optional[float]) -> Tuple[dict, List[tuple]]:
    srv = _server(cfg, params, topo_name, disaggregated)
    stats = srv.run(_workload(rate, ttft_slo_s), max_steps=MAX_STEPS)
    outputs = [tuple(h.tokens) for h in srv.handles]
    lat = stats.latency_percentiles("latency")
    xfer = stats.metrics.get("transfer", {})
    dcn_submitted = sum(v for k, v in xfer.items()
                        if k.startswith("q.dcn") and k.endswith(".submitted"))
    dcn_coalesced = sum(v for k, v in xfer.items()
                        if k.startswith("q.dcn") and k.endswith(".coalesced"))
    return {
        "clock_s": stats.clock_s,
        "tokens": stats.tokens_out,
        "goodput_latency": stats.goodput("latency"),
        "slo_attainment_latency": stats.slo_attainment("latency"),
        "ttft_p99_latency": lat["ttft_p99"],
        "queue_wait_p99_latency": lat["queue_wait_p99"],
        "dcn_submitted": dcn_submitted,
        "dcn_coalesced": dcn_coalesced,
    }, outputs


def _part_a(hw: str) -> Tuple[dict, List[Check], List[List[str]]]:
    import jax

    from repro.configs import get_config
    from repro.models import model as M

    topo_name, _ = HW_FAMILIES[hw]
    cfg = dataclasses.replace(get_config("yi-6b").reduced(), num_layers=2)
    params = M.init_params(jax.random.PRNGKey(0), cfg)

    # provision the TTFT target on the uncongested colocated system
    calib, _ = _run_cell(cfg, params, topo_name, False, RATE_CALIBRATE, None)
    ttft_slo = 2.0 * calib["ttft_p99_latency"]

    coloc, out_c = _run_cell(cfg, params, topo_name, False, RATE_KNEE,
                             ttft_slo)
    disagg, out_d = _run_cell(cfg, params, topo_name, True, RATE_KNEE,
                              ttft_slo)
    lift = (disagg["goodput_latency"] / coloc["goodput_latency"]
            if coloc["goodput_latency"] else float("inf"))
    ttft_ratio = (coloc["ttft_p99_latency"] / disagg["ttft_p99_latency"]
                  if disagg["ttft_p99_latency"] else float("inf"))
    rows = {
        "topology": topo_name, "rate": RATE_KNEE, "ttft_slo_s": ttft_slo,
        "tokens_match": out_c == out_d,
        "colocated": coloc, "disaggregated": disagg,
        "goodput_lift": lift, "ttft_p99_ratio": ttft_ratio,
    }
    checks = [
        Check("fig14.disagg_tokens_identical", float(out_c == out_d), lo=1.0,
              note="disaggregation re-times requests, never re-decodes: "
                   "tokens bit-identical to the colocated engine"),
        Check("fig14.disagg_goodput_knee_lift", lift, lo=1.0 + 1e-3,
              note="at the fig10 knee, disaggregated prefill strictly "
                   "lifts TTFT-SLO goodput over colocated serving"),
        Check("fig14.disagg_ttft_p99_improves", ttft_ratio, lo=1.0 + 1e-3,
              note="pool prefill + DCN streaming takes prefill windows "
                   "off the decode clock: latency-class TTFT p99 drops"),
        Check("fig14.disagg_streams_coalesced_dcn",
              float(disagg["dcn_coalesced"]), lo=1.0,
              note="KV streams ride coalesced DCN transfers (one wire "
                   "setup per prefill chunk, not per block — PR 4 "
                   "composition on dcn lanes)"),
    ]
    table = [
        ["colocated", f"{coloc['goodput_latency']:.0f}",
         f"{coloc['slo_attainment_latency']:.0%}",
         f"{coloc['ttft_p99_latency'] * 1e6:.1f}",
         f"{coloc['clock_s'] * 1e6:.1f}", "-"],
        ["disaggregated", f"{disagg['goodput_latency']:.0f}",
         f"{disagg['slo_attainment_latency']:.0%}",
         f"{disagg['ttft_p99_latency'] * 1e6:.1f}",
         f"{disagg['clock_s'] * 1e6:.1f}",
         f"{disagg['dcn_submitted']:.0f}/{disagg['dcn_coalesced']:.0f}"],
    ]
    return rows, checks, table


# -------------------------------------------------- Part B (sweep model)
def _part_b(hw: str, fast: bool) -> Tuple[dict, List[Check], List[List[str]]]:
    from repro.serving import SweepConfig, SweepTrace, simulate

    _, family = HW_FAMILIES[hw]
    n = SWEEP_N[fast]
    trace = SweepTrace.generate("diurnal", rate=SWEEP_RATE, n=n, seed=SEED)
    rows: List[dict] = []
    table: List[List[str]] = []
    by_key: Dict[Tuple[int, bool], dict] = {}
    for hosts in (1, 2, 4):
        for disagg in ((False, True) if hosts == 4 else (False,)):
            cfg = SweepConfig.from_family(family, hosts=hosts,
                                          disaggregated=disagg)
            res = simulate(trace, cfg, vectorized=True)
            ttft = res.ttft(trace)
            row = {
                "hosts": hosts, "disaggregated": disagg,
                "clock_s": res.clock_s,
                "throughput_tok_s": res.throughput(trace),
                "ttft_mean_s": float(ttft.mean()),
                "ttft_p99_s": float(np.percentile(ttft, 99)),
                "walltime_s": res.walltime_s,
                "max_rss_mb": res.max_rss_mb,
            }
            rows.append(row)
            by_key[(hosts, disagg)] = row
            table.append([
                str(hosts), "disagg" if disagg else "coloc",
                f"{res.clock_s:.1f}", f"{row['throughput_tok_s']:.0f}",
                f"{row['ttft_mean_s'] * 1e3:.2f}",
                f"{row['walltime_s']:.2f}"])
    scale_ratio = (by_key[(1, False)]["clock_s"]
                   / by_key[(4, False)]["clock_s"])
    ttft_ratio = (by_key[(4, False)]["ttft_mean_s"]
                  / by_key[(4, True)]["ttft_mean_s"])
    checks = [
        Check("fig14.scaleout_clock_shrinks", scale_ratio, lo=2.0,
              note="4 decode hosts finish the saturating diurnal trace "
                   ">=2x sooner than one (round-robin scale-out)"),
        Check("fig14.sweep_disagg_ttft_improves", ttft_ratio, lo=1.0 + 1e-3,
              note="at 4 hosts, pool prefill cuts mean TTFT vs colocated "
                   "(prefill windows leave the decode clock)"),
    ]
    return {"n": n, "rate": SWEEP_RATE, "family": family,
            "rows": rows}, checks, table


# --------------------------------------------- Part C (loop equivalence)
def _identical(a: "np.ndarray", b: "np.ndarray") -> bool:
    return bool(np.array_equal(a, b))


def _part_c(hw: str, fast: bool) -> Tuple[dict, List[Check], List[List[str]]]:
    from repro.serving import SweepConfig, SweepTrace, simulate

    _, family = HW_FAMILIES[hw]

    # bit-identity: scalar vs vectorized on small traces, every mode
    ident = True
    ident_cells = []
    trace_i = SweepTrace.generate("poisson", rate=1e3, n=IDENT_N, seed=7)
    for hosts in (1, 4):
        for disagg in (False, True):
            cfg = SweepConfig.from_family(family, hosts=hosts,
                                          disaggregated=disagg)
            rs = simulate(trace_i, cfg, vectorized=False)
            rv = simulate(trace_i, cfg, vectorized=True)
            same = (rs.clock_s == rv.clock_s
                    and _identical(rs.host_clock_s, rv.host_clock_s)
                    and _identical(rs.admit_t, rv.admit_t)
                    and _identical(rs.first_token_t, rv.first_token_t)
                    and _identical(rs.finish_t, rv.finish_t)
                    and _identical(rs.tokens, rv.tokens))
            ident = ident and same
            ident_cells.append({"hosts": hosts, "disaggregated": disagg,
                                "identical": same})

    # speedup: the million-request diurnal trace across 4 hosts
    n = PERF_N[fast]
    trace_p = SweepTrace.generate("diurnal", rate=2e4, n=n, seed=1,
                                  out_len=PERF_OUT_LEN)
    cfg_p = SweepConfig.from_family(family, hosts=4)
    res_s = simulate(trace_p, cfg_p, vectorized=False)
    res_v = simulate(trace_p, cfg_p, vectorized=True)
    same_p = (res_s.clock_s == res_v.clock_s
              and _identical(res_s.finish_t, res_v.finish_t))
    speedup = (res_s.walltime_s / res_v.walltime_s
               if res_v.walltime_s else float("inf"))
    rows = {
        "identity_cells": ident_cells,
        "perf": {"n": n, "hosts": 4, "out_len": list(PERF_OUT_LEN),
                 "clock_s": res_v.clock_s,
                 "scalar_walltime_s": res_s.walltime_s,
                 "vector_walltime_s": res_v.walltime_s,
                 "max_rss_mb": res_v.max_rss_mb,
                 "speedup": speedup, "identical": same_p},
    }
    checks = [
        Check("fig14.vector_loop_bit_identical", float(ident and same_p),
              lo=1.0,
              note="vectorized step loop matches the scalar reference "
                   "bit-for-bit in tokens, per-request times and clock "
                   "across hosts x {coloc, disagg} and the perf trace"),
        Check("fig14.vector_loop_speedup", speedup,
              lo=PERF_SPEEDUP_LO[fast],
              note=f"run-leaping refactor vs engine-style per-step "
                   f"accounting on the {n:,}-request diurnal trace "
                   f"across 4 hosts (full bound 10x; fast CI trace "
                   f"uses a looser bound)"),
    ]
    table = [[f"{n:,}", "4", f"{res_s.walltime_s:.2f}",
              f"{res_v.walltime_s:.2f}", f"{speedup:.1f}x",
              "yes" if (ident and same_p) else "NO"]]
    return rows, checks, table


# ----------------------------------------------------------------- driver
def run(out_dir: Path, hw: str = "h100-nvlink-2gpu",
        fast: bool = False) -> dict:
    wall_t0 = time.perf_counter()
    if hw not in HW_FAMILIES:
        raise ValueError(f"unknown hardware family {hw!r}; expected one of "
                         f"{sorted(HW_FAMILIES)}")

    a_rows, a_checks, a_table = _part_a(hw)
    b_rows, b_checks, b_table = _part_b(hw, fast)
    c_rows, c_checks, c_table = _part_c(hw, fast)

    print(f"Fig 14 — scale-out harvesting ({hw}):")
    print(f"A. disaggregated prefill/decode at the fig10 knee "
          f"({a_rows['topology']}, TTFT SLO {a_rows['ttft_slo_s']:.2e}s, "
          f"tokens identical: {a_rows['tokens_match']}):")
    print(fmt_table(["mode", "goodput tok/s", "SLO%", "ttft99 us",
                     "clock us", "dcn xfers/coal"], a_table))
    print(f"B. host scaling, vectorized sweep model "
          f"({b_rows['n']:,} diurnal requests, {b_rows['family']}):")
    print(fmt_table(["hosts", "mode", "clock s", "tok/s", "ttft ms",
                     "wall s"], b_table))
    print("C. scalar vs vectorized event loop:")
    print(fmt_table(["requests", "hosts", "scalar s", "vector s", "speedup",
                     "identical"], c_table))
    print()

    checks = a_checks + b_checks + c_checks
    payload = {"name": "fig14_scaleout", "hw": hw,
               "part_a": a_rows, "part_b": b_rows, "part_c": c_rows,
               "checks": [c.to_dict() for c in checks],
               "runtime_s": time.perf_counter() - wall_t0,
               "fast": fast}
    save_result(out_dir, "fig14_scaleout", payload)
    return payload


if __name__ == "__main__":
    import argparse

    from benchmarks.common import RESULTS_DIR
    ap = argparse.ArgumentParser()
    ap.add_argument("--hw", default="h100-nvlink-2gpu",
                    choices=sorted(HW_FAMILIES))
    ap.add_argument("--tiny", "--fast", dest="fast", action="store_true",
                    help="CI mode: smaller sweep/perf traces")
    args = ap.parse_args()
    run(RESULTS_DIR, hw=args.hw, fast=args.fast)
