"""Fig 8 (repo-original) — multi-peer harvesting: peer-count x volatility.

The paper's testbed stops at 2 GPUs; this benchmark asks the question the
production mesh cares about: *what does one more harvestable peer buy?*
The async serving engine runs a contended decode workload (local KV pool
far smaller than the working set, fair-scheduler preemption churn) over an
N-peer interconnect :class:`~repro.core.tiers.Topology`, sweeping

  * **peer count** 1 -> 8 on the NVLink-mesh preset (or a v5e ICI torus
    with ``hw="tpu-v5e"``) — every peer adds a pair of directional link
    lanes AND harvestable capacity, so eviction/reload bursts spread
    across devices instead of serialising on one FIFO;
  * **trace volatility** — the cluster-trace monitor ticks on the
    *simulated transfer timeline* (mid-pipeline revocations) with
    correlated per-device shocks, so placement has to keep working while
    budgets move under it.

Reported per cell: simulated clock, token throughput, stall/writeback
time, revocations, and the per-device ``q.<lane>.*`` occupancy windows.
The receipts for the headline claim come from the TransferEngine's submit
log: two transfers on distinct peer devices were provably *in flight at
the same simulated time* — exactly what the single-lane PEER_HBM model
could not do.
"""
from __future__ import annotations

import dataclasses
from pathlib import Path
from typing import Dict, List, Optional

from benchmarks.common import Check, fmt_table, save_result

PEER_COUNTS = (1, 2, 4, 8)
VOLATILITIES = (0.0, 2.0)
NUM_REQUESTS = 6
MAX_NEW_TOKENS = 10
BLOCK_SIZE = 8
LOCAL_SLOTS = 10
BLOCKS_PER_PEER = 6          # harvestable budget per peer, in KV blocks
MONITOR_INTERVAL_S = 15e-6   # trace tick cadence on the simulated clock


def _topology(hw: str, num_peers: int):
    from repro.core import nvlink_mesh, tpu_v5e_torus
    if hw == "tpu-v5e":
        # a (num_peers+1)x1 ICI ring slice: peer d is d hops out
        return tpu_v5e_torus((num_peers + 1, 1))
    return nvlink_mesh(num_peers)


def _run_engine(cfg, params, topology, volatility: float, seed: int = 0):
    import numpy as np

    from repro.core import (ClusterTrace, ClusterTraceConfig, HarvestRuntime,
                            TopologyAwarePolicy, kv_block_bytes)
    from repro.serving.engine import HarvestServingEngine

    block_bytes = kv_block_bytes(cfg, BLOCK_SIZE)
    budget = BLOCKS_PER_PEER * block_bytes
    trace = None
    if volatility > 0:
        trace = ClusterTrace(ClusterTraceConfig(
            num_devices=topology.num_peers, capacity_bytes=budget,
            seed=seed, volatility=volatility, correlation=0.6,
            job_arrival_p=0.15, job_size_frac=(0.4, 0.9),
            job_lifetime=(4, 16)))
    runtime = HarvestRuntime(
        topology.device_budgets(budget), topology=topology,
        policy=TopologyAwarePolicy(topology), trace=trace,
        monitor_interval_s=MONITOR_INTERVAL_S if trace else None)
    # keep the submit log: the overlap check wants exact busy intervals,
    # not just the per-lane envelope metrics
    runtime.transfers.record_log = True
    eng = HarvestServingEngine(
        cfg, params, max_batch=2, block_size=BLOCK_SIZE,
        num_local_slots=LOCAL_SLOTS, runtime=runtime, scheduler="fair",
        mode="async")
    rng = np.random.default_rng(seed)
    for i in range(NUM_REQUESTS):
        n = 18 + int(rng.integers(0, 12))
        eng.submit(list(rng.integers(3, min(cfg.vocab_size, 250), size=n)),
                   MAX_NEW_TOKENS)
    stats = eng.run(max_steps=2000)
    return eng, stats


def _peer_lane_windows(metrics: Dict[str, dict]) -> Dict[str, tuple]:
    """Per-peer-lane (first_issue_t, last_ready_t, busy_s) occupancy."""
    q = metrics.get("transfer", {})
    lanes: Dict[str, tuple] = {}
    for key in q:
        if not key.startswith("q.peer") or not key.endswith(".submitted"):
            continue
        lane = key[len("q."):-len(".submitted")]
        lanes[lane] = (q.get(f"q.{lane}.first_issue_t", 0.0),
                       q.get(f"q.{lane}.last_ready_t", 0.0),
                       q.get(f"q.{lane}.busy_s", 0.0))
    return lanes


def _peer_transfers_overlap(log) -> bool:
    """True iff two transfers on DISTINCT peer devices were in flight at
    the same simulated time — the exact proof that multi-peer transfers
    pipeline.  Works on the TransferEngine submit log (a transfer occupies
    its lane over ``[ready_t - seconds, ready_t]``), not on whole-run lane
    envelopes, so an idle-gap interleaving cannot fake an overlap."""
    spans = sorted((t.ready_t - t.seconds, t.ready_t, t.device)
                   for t in log
                   if t.channel.startswith("peer") and t.device is not None)
    busy_until: Dict[int, float] = {}     # device -> latest ready seen
    for start, ready, dev in spans:
        if any(start < r for d, r in busy_until.items() if d != dev):
            return True
        busy_until[dev] = max(busy_until.get(dev, 0.0), ready)
    return False


def run(out_dir: Path, peer_counts=PEER_COUNTS, volatilities=VOLATILITIES,
        hw: str = "h100-nvlink-2gpu", fast: bool = False) -> dict:
    import jax

    from repro.configs import get_config
    from repro.models import model as M

    if fast:
        peer_counts = tuple(p for p in peer_counts if p <= 2) or (1, 2)
        volatilities = volatilities[:1]

    cfg = dataclasses.replace(get_config("yi-6b").reduced(), num_layers=2)
    params = M.init_params(jax.random.PRNGKey(0), cfg)

    rows: List[dict] = []
    table = []
    snapshot: Optional[Dict[str, dict]] = None
    for vol in volatilities:
        for peers in peer_counts:
            topo = _topology(hw, peers)
            eng, st = _run_engine(cfg, params, topo, vol)
            lanes = _peer_lane_windows(st.metrics)
            alloc = st.metrics.get("allocator", {})
            row = {
                "topology": topo.name, "peers": peers, "volatility": vol,
                "clock_s": st.clock_s, "throughput": st.throughput(),
                "tokens": st.tokens_out, "steps": st.steps,
                "stall_s": st.stall_s, "writeback_s": st.writeback_s,
                "preemptions": st.preemptions,
                "revocations": alloc.get("revocations", 0),
                "failed_allocs": alloc.get("failed", 0),
                "evict_to_host": st.metrics.get("kv", {}).get(
                    "evict_to_host", 0),
                "lanes": {k: {"first_issue_t": v[0], "last_ready_t": v[1],
                              "busy_s": v[2]} for k, v in lanes.items()},
                "lanes_overlap": _peer_transfers_overlap(
                    eng.runtime.transfers.log),
            }
            rows.append(row)
            table.append([peers, vol, f"{st.clock_s * 1e3:.3f}",
                          f"{st.throughput():.0f}",
                          f"{st.stall_s * 1e3:.3f}", len(lanes),
                          "yes" if row["lanes_overlap"] else "no",
                          row["revocations"]])
            if peers == max(peer_counts):
                snapshot = st.metrics
    print("Fig 8 — peer scaling (async engine, contended KV workload):")
    print(fmt_table(["peers", "vol", "clock ms", "tok/s", "stall ms",
                     "peer lanes", "overlap", "revoked"], table))
    print()

    def cell(peers, vol):
        return next(r for r in rows
                    if r["peers"] == peers and r["volatility"] == vol)

    lo_p, hi_p = min(peer_counts), max(peer_counts)
    checks = []
    for vol in volatilities:
        base, best = cell(lo_p, vol), cell(hi_p, vol)
        checks.append(Check(
            f"fig8.clock_improves_{lo_p}to{hi_p}_vol{vol:g}",
            base["clock_s"] / best["clock_s"], lo=1.0 + 1e-9,
            note=f"async clock strictly improves {lo_p} -> {hi_p} peers"))
    if 4 in peer_counts:
        # the headline claim, on the stable contended workload: every lane
        # pair added between 1 and 4 peers strictly tightens the clock
        vol0 = min(volatilities)
        checks.append(Check(
            "fig8.clock_improves_1to4",
            cell(1, vol0)["clock_s"] / cell(4, vol0)["clock_s"],
            lo=1.0 + 1e-9,
            note="async clock strictly improves 1 -> 4 mesh peers"))
    multi = [r for r in rows if r["peers"] >= 2]
    checks.append(Check(
        "fig8.lane_overlap",
        float(all(r["lanes_overlap"] for r in multi)) if multi else 0.0,
        lo=1.0, note="distinct peers' lanes busy at overlapping sim times"))
    checks.append(Check(
        "fig8.tokens_invariant",
        float(len({r["tokens"] for r in rows}) == 1), lo=1.0,
        note="topology changes when bytes move, never what is decoded"))

    payload = {"name": "fig8_peer_scaling", "hw": hw, "rows": rows,
               "checks": [c.to_dict() for c in checks],
               "metrics": snapshot or {}}
    save_result(out_dir, "fig8_peer_scaling", payload)
    return payload


if __name__ == "__main__":
    from benchmarks.common import RESULTS_DIR
    run(RESULTS_DIR)
