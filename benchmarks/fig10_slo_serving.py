"""Fig 10 (repo-original) — SLO-classed serving under clock-driven arrivals.

The paper's throughput-under-dynamic-availability claim only means
something relative to a traffic shape; this benchmark serves seeded
Poisson request streams through the request-lifecycle API
(:class:`~repro.serving.server.HarvestServer`) and measures **SLO
goodput** — output tokens of requests that met every deadline they
carried, per simulated second — as arrival rate and SLO mix vary.

Axes per hardware family (H100+NVLink / TPU v5e+ICI):

  * **arrival rate** — below the knee requests barely overlap and every
    configuration meets its deadlines; past the knee the fair scheduler
    churns the KV working set and reload latency lands on TTFT/e2e.
  * **SLO mix** — latency-heavy vs batch-heavy tenant blends (the
    latency class carries TTFT + e2e deadlines, batch is deadline-free).
  * **harvesting on/off** — identical engines, identical workloads; the
    only difference is where evicted KV blocks land: peer HBM over the
    fast link (harvest) vs host DRAM (the fallback tier).

Deadlines are calibrated per family, not hand-picked: the harvest
configuration runs the highest swept rate once without deadlines, and
the SLO is set to 2x its latency-class p99 (TTFT and e2e) — the targets
an operator would provision on the harvested system with 2x margin.
Every cell then answers: does this configuration sustain those targets?

Headline checks: decoded tokens are IDENTICAL across harvest/host and
the legacy all-at-once submission path (the lifecycle API re-times
requests, never re-decodes them), goodput is never worse with
harvesting, and at >= 1 swept rate harvesting strictly lifts SLO
goodput (the knee).
"""
from __future__ import annotations

import dataclasses
from pathlib import Path
from typing import Dict, List, Optional

from benchmarks.common import Check, fmt_table, save_result

RATES = (2e4, 1e5, 4e5)        # requests per simulated second
MIXES = {"lat-heavy": (2, 1), "batch-heavy": (1, 2)}   # latency:batch weights
NUM_REQUESTS = 6
MAX_NEW_TOKENS = 10
BLOCK_SIZE = 8
LOCAL_SLOTS = 10
MAX_BATCH = 2
SEED = 3

HW_MODELS = {"h100-nvlink-2gpu": "H100_NVLINK", "tpu-v5e": "TPU_V5E"}


def _hardware(hw: str):
    from repro.core import tiers
    return getattr(tiers, HW_MODELS[hw])


def _workload(mix: str, rate: float, slo: Optional[Dict[str, float]]):
    from repro.serving import TenantSpec, Workload
    w_lat, w_bat = MIXES[mix]
    slo = slo or {}
    return Workload(
        num_requests=NUM_REQUESTS, arrival="poisson", rate=rate, seed=SEED,
        vocab=(3, 250),
        tenants=(
            TenantSpec("interactive", weight=w_lat, slo="latency",
                       priority=1, prompt_len=(18, 23),
                       max_new_tokens=MAX_NEW_TOKENS,
                       ttft_slo_s=slo.get("ttft"), e2e_slo_s=slo.get("e2e")),
            TenantSpec("background", weight=w_bat, slo="batch",
                       prompt_len=(18, 23), max_new_tokens=MAX_NEW_TOKENS)))


def _server(cfg, params, hw: str, harvest: bool):
    from repro.core import HarvestRuntime, kv_block_bytes
    from repro.serving import HarvestServer
    block_bytes = kv_block_bytes(cfg, BLOCK_SIZE)
    # peer budget fits the churned working sets (harvest) or is zero so
    # every eviction falls back to the host tier (the comparison system)
    budget = 4 * 5 * block_bytes if harvest else 0
    runtime = HarvestRuntime({1: budget}, hardware=_hardware(hw))
    return HarvestServer(cfg, params, runtime=runtime, max_batch=MAX_BATCH,
                         block_size=BLOCK_SIZE, num_local_slots=LOCAL_SLOTS,
                         scheduler="fair", mode="async")


def _run_cell(cfg, params, hw: str, harvest: bool, mix: str, rate: float,
              slo: Optional[Dict[str, float]]):
    srv = _server(cfg, params, hw, harvest)
    stats = srv.run(_workload(mix, rate, slo), max_steps=4000)
    outputs = [tuple(h.tokens) for h in srv.handles]
    lat = stats.latency_percentiles("latency")
    return {
        "clock_s": stats.clock_s,
        "tokens": stats.tokens_out,
        "goodput": stats.goodput(),
        "goodput_latency": stats.goodput("latency"),
        "slo_attainment_latency": stats.slo_attainment("latency"),
        "ttft_p99_latency": lat["ttft_p99"],
        "e2e_p99_latency": lat["e2e_p99"],
        "queue_wait_p99_latency": lat["queue_wait_p99"],
        "preemptions": stats.preemptions,
        "evict_peer": stats.metrics["kv"]["evict_to_peer"],
        "evict_host": stats.metrics["kv"]["evict_to_host"],
    }, outputs, stats


def _legacy_reference(cfg, params, hw: str, mix: str) -> List[tuple]:
    """The compat path: same prompts, all submitted up-front through
    ``engine.submit`` — the pre-lifecycle serving surface."""
    srv = _server(cfg, params, hw, harvest=True)
    for sr in _workload(mix, RATES[0], None).generate():
        srv.engine.submit(sr.prompt, sr.max_new_tokens)
    srv.engine.run(max_steps=4000)
    # finished order is retire order; report in req_id (submission) order
    return [tuple(r.output)
            for r in sorted(srv.engine.finished, key=lambda r: r.req_id)]


def _calibrate_slo(cfg, params, hw: str, mix: str) -> Dict[str, float]:
    """2x the harvest system's latency-class p99 at the highest rate."""
    cell, _, _ = _run_cell(cfg, params, hw, harvest=True, mix=mix,
                           rate=max(RATES), slo=None)
    return {"ttft": 2.0 * cell["ttft_p99_latency"],
            "e2e": 2.0 * cell["e2e_p99_latency"]}


def run(out_dir: Path, hw: str = "h100-nvlink-2gpu", rates=RATES,
        fast: bool = False) -> dict:
    import time

    import jax

    from repro.configs import get_config
    from repro.models import model as M

    wall_t0 = time.perf_counter()
    if hw not in HW_MODELS:
        raise ValueError(f"unknown hardware family {hw!r}; expected one of "
                         f"{sorted(HW_MODELS)}")
    mixes = list(MIXES)
    if fast:
        rates = (min(rates), max(rates))
        mixes = mixes[:1]

    cfg = dataclasses.replace(get_config("yi-6b").reduced(), num_layers=2)
    params = M.init_params(jax.random.PRNGKey(0), cfg)

    rows: List[dict] = []
    table = []
    snapshot: Optional[Dict[str, dict]] = None
    for mix in mixes:
        slo = _calibrate_slo(cfg, params, hw, mix)
        legacy = _legacy_reference(cfg, params, hw, mix)
        for rate in rates:
            hv, out_hv, st_hv = _run_cell(cfg, params, hw, True, mix, rate,
                                          slo)
            ho, out_ho, _ = _run_cell(cfg, params, hw, False, mix, rate, slo)
            row = {
                "mix": mix, "rate": rate,
                "slo_ttft_s": slo["ttft"], "slo_e2e_s": slo["e2e"],
                "tokens_match": out_hv == out_ho,
                "tokens_match_legacy": out_hv == legacy,
                "harvest": hv, "host_only": ho,
                "goodput_lift": (hv["goodput"] / ho["goodput"]
                                 if ho["goodput"] else float("inf")),
            }
            rows.append(row)
            table.append([
                mix, f"{rate:g}",
                "yes" if row["tokens_match"]
                and row["tokens_match_legacy"] else "NO",
                f"{hv['goodput']:.0f}", f"{ho['goodput']:.0f}",
                f"{row['goodput_lift']:.2f}x",
                f"{hv['slo_attainment_latency']:.0%}",
                f"{ho['slo_attainment_latency']:.0%}",
                f"{hv['ttft_p99_latency'] * 1e6:.1f}",
                f"{ho['ttft_p99_latency'] * 1e6:.1f}",
                hv["preemptions"]])
            if rate == max(rates) and mix == mixes[0]:
                snapshot = st_hv.metrics
    print(f"Fig 10 — SLO serving under clocked Poisson arrivals ({hw}; "
          f"SLO = 2x harvest p99 at the top rate):")
    print(fmt_table(
        ["mix", "req/s", "tokens=", "harvest tok/s", "host tok/s", "lift",
         "SLO% hv", "SLO% host", "ttft99 hv us", "ttft99 host us",
         "preempt"], table))
    print()

    checks = [
        Check("fig10.tokens_invariant",
              float(all(r["tokens_match"] and r["tokens_match_legacy"]
                        for r in rows)), lo=1.0,
              note="the lifecycle API re-times requests, never re-decodes "
                   "them: identical tokens across harvest/host-only and "
                   "the legacy all-at-once submission path"),
        Check("fig10.goodput_never_worse",
              min(r["goodput_lift"] for r in rows), lo=1.0 - 1e-9,
              note="SLO-goodput with harvesting is never below the "
                   "host-fallback system at any swept rate/mix"),
        Check("fig10.goodput_knee_lift",
              max(r["goodput_lift"] for r in rows), lo=1.0 + 1e-3,
              note="at the knee, peer harvesting strictly lifts SLO "
                   "goodput over host-fallback serving"),
        Check("fig10.knee_exercised_tiers",
              float(max(max(r["harvest"]["evict_peer"] for r in rows),
                        0)), lo=1.0,
              note="the sweep actually drove eviction churn through the "
                   "peer tier (the knee is a harvesting regime, not a "
                   "no-op)"),
    ]

    payload = {"name": "fig10_slo_serving", "hw": hw, "rows": rows,
               "checks": [c.to_dict() for c in checks],
               # wall-clock of this run() — the CI perf gate compares the
               # fast-sweep runtime against benchmarks/perf_baseline.json
               # and fails on a >2x regression
               "runtime_s": time.perf_counter() - wall_t0,
               "fast": fast,
               "metrics": snapshot or {}}
    save_result(out_dir, "fig10_slo_serving", payload)
    return payload


if __name__ == "__main__":
    import argparse

    from benchmarks.common import RESULTS_DIR
    ap = argparse.ArgumentParser()
    ap.add_argument("--hw", default="h100-nvlink-2gpu",
                    choices=sorted(HW_MODELS))
    ap.add_argument("--tiny", "--fast", dest="fast", action="store_true",
                    help="CI mode: fewest rates, one mix")
    args = ap.parse_args()
    run(RESULTS_DIR, hw=args.hw, fast=args.fast)
