"""CI wall-clock perf gate.

Compares the ``runtime_s`` recorded in benchmark result JSONs against
the committed baselines in ``benchmarks/perf_baseline.json`` and exits
non-zero when any measured runtime exceeds ``--factor`` (default 2x)
times its baseline — a hot-path regression gate, not a latency SLO:
the baselines carry machine headroom so runner jitter passes and only
real slowdowns (an accidentally quadratic step loop, a de-hoisted
constant) trip it.

Usage::

    PYTHONPATH=src python -m benchmarks.perf_gate --hw tpu-v5e \
        [--results results] [--factor 2.0]

Benchmarks listed in the baseline file but missing from the results
directory are skipped (the gate only judges what actually ran); a
result that ran in full (non ``--fast``) mode is skipped too, since
baselines are calibrated for the fast sweep.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

BASELINE_PATH = Path(__file__).resolve().parent / "perf_baseline.json"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--hw", required=True,
                    help="hardware leg the results were produced under")
    ap.add_argument("--results", default=None,
                    help="results directory (default: repo results/)")
    ap.add_argument("--factor", type=float, default=2.0,
                    help="fail when runtime_s > factor * baseline")
    args = ap.parse_args(argv)

    from benchmarks.common import RESULTS_DIR
    results_dir = Path(args.results) if args.results else RESULTS_DIR
    baselines = json.loads(BASELINE_PATH.read_text())

    failed = []
    for name, per_hw in baselines.items():
        if name.startswith("_"):
            continue
        base = per_hw.get(args.hw)
        if base is None:
            continue
        p = results_dir / f"{name}.json"
        if not p.exists():
            print(f"perf-gate: {name}: no result at {p}, skipping")
            continue
        payload = json.loads(p.read_text())
        runtime = payload.get("runtime_s")
        if runtime is None:
            print(f"perf-gate: {name}: result has no runtime_s, skipping")
            continue
        if not payload.get("fast", False):
            print(f"perf-gate: {name}: full (non-fast) run, skipping")
            continue
        limit = args.factor * base["runtime_s"]
        verdict = "FAIL" if runtime > limit else "ok"
        print(f"perf-gate: {name} [{args.hw}]: {runtime:.1f}s "
              f"(baseline {base['runtime_s']:.1f}s, limit {limit:.1f}s) "
              f"{verdict}")
        if runtime > limit:
            failed.append(name)
    if failed:
        print(f"perf-gate: FAILED: {', '.join(failed)} — hot-path "
              f"runtime regressed past {args.factor}x the committed "
              f"baseline (benchmarks/perf_baseline.json)")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
