"""Fig 9 (repo-original) — coalesced transfer batching + chunked striping.

The paper's Fig 3 shows small-object transfers dominated by per-transfer
setup (34–194 µs on the calibrated links); a decode step that touches
``k`` KV blocks pays ``k`` setups when every block is its own submission.
This benchmark measures what the :class:`~repro.core.coalesce
.TransferPlanner` buys back:

  * **Engine sweep** — the async serving engine on a preemption-heavy
    workload whose resumed prefixes span ``k`` blocks (objects/step axis),
    per-object submission vs coalesced batching.  Decoded tokens must be
    IDENTICAL (the planner re-schedules transfers, never placement) while
    the simulated clock and the small-object transfer time (total lane
    busy seconds) drop.
  * **Stripe sweep** — one expert-sized object on the v5e torus ICI link,
    chunk size x stripe ways: chunks ride link-disjoint sub-lanes with
    chunk-granular completion, so a half-object prefix wait returns
    strictly before full completion, and more ways strictly tighten full
    completion.

Headline checks: identical tokens with a strictly lower async clock at
>= 4 blocks/step, and >= 1.5x lower small-object transfer time at the
8-blocks/step point.
"""
from __future__ import annotations

import dataclasses
from pathlib import Path
from typing import Dict, List, Optional

from benchmarks.common import Check, fmt_table, save_result

BLOCKS_PER_STEP = (1, 2, 4, 8)
BLOCK_SIZE = 8
NUM_REQUESTS = 5
MAX_NEW_TOKENS = 10
STRIPE_WAYS = (2, 4)
CHUNK_KIB = (256, 1024)
STRIPE_OBJECT_MIB = 8


def _run_engine(cfg, params, k_blocks: int, coalesce: bool, seed: int = 0):
    import numpy as np

    from repro.core import (CoalesceConfig, HarvestRuntime, kv_block_bytes)
    from repro.core.tiers import H100_NVLINK
    from repro.serving.engine import HarvestServingEngine

    block_bytes = kv_block_bytes(cfg, BLOCK_SIZE)
    # local pool barely fits one working set -> fair-scheduler churn
    # evicts/resumes whole k-block prefixes every quantum
    slots = k_blocks + 4
    runtime = HarvestRuntime(
        {1: 4 * (k_blocks + 2) * block_bytes}, hardware=H100_NVLINK,
        coalesce=CoalesceConfig() if coalesce else None)
    eng = HarvestServingEngine(
        cfg, params, max_batch=2, block_size=BLOCK_SIZE,
        num_local_slots=slots, runtime=runtime, scheduler="fair",
        mode="async")
    rng = np.random.default_rng(seed)
    for _ in range(NUM_REQUESTS):
        n = k_blocks * BLOCK_SIZE - 2     # resumed prefix spans k blocks
        eng.submit(list(rng.integers(3, min(cfg.vocab_size, 250), size=n)),
                   MAX_NEW_TOKENS)
    stats = eng.run(max_steps=2000)
    outputs = sorted(tuple(r.output) for r in eng.finished)
    q = stats.metrics.get("transfer", {})
    busy_s = sum(v for k, v in q.items() if k.endswith(".busy_s"))
    return stats, busy_s, outputs


def _stripe_cell(ways: int, chunk_kib: int):
    """One expert-sized transfer on the v5e striped ICI link: returns
    (full completion s, half-prefix wait s, chunks)."""
    from repro.core import CoalesceConfig, Tier, TransferEngine, TransferPlanner
    from repro.core.tiers import tpu_v5e_torus

    nbytes = STRIPE_OBJECT_MIB * 2**20 + 12345   # non-divisible on purpose
    topo = tpu_v5e_torus((2, 2))
    te = TransferEngine(None, topology=topo)
    planner = TransferPlanner(te, CoalesceConfig(
        stripe_ways=ways, chunk_nbytes=chunk_kib << 10,
        min_stripe_nbytes=1 << 20))
    op = te.transfer("expert", nbytes, Tier.PEER_HBM, Tier.LOCAL_HBM,
                     device=1)
    flat_s = op.seconds
    chunks = planner.prepare([op])
    assert sum(c.nbytes for c in chunks) == nbytes, \
        "striping must conserve bytes (short tail chunk, no padding)"
    submitted, _eff = planner.submit(chunks)
    half = te.wait_for(submitted, prefix_nbytes=nbytes // 2)
    full = te.wait_for(submitted)
    return {"ways": ways, "chunk_kib": chunk_kib, "flat_s": flat_s,
            "full_s": full, "half_prefix_s": half, "chunks": len(chunks)}


def run(out_dir: Path, blocks_per_step=BLOCKS_PER_STEP,
        fast: bool = False) -> dict:
    import jax

    from repro.configs import get_config
    from repro.models import model as M

    if fast:
        blocks_per_step = tuple(k for k in blocks_per_step if k >= 4) \
            or (4, 8)

    cfg = dataclasses.replace(get_config("yi-6b").reduced(), num_layers=2)
    params = M.init_params(jax.random.PRNGKey(0), cfg)

    rows: List[dict] = []
    table = []
    snapshot: Optional[Dict[str, dict]] = None
    for k in blocks_per_step:
        st0, busy0, out0 = _run_engine(cfg, params, k, coalesce=False)
        st1, busy1, out1 = _run_engine(cfg, params, k, coalesce=True)
        co = st1.metrics.get("coalesce", {})
        row = {
            "blocks_per_step": k,
            "tokens_match": out0 == out1,
            "per_object": {"clock_s": st0.clock_s, "tokens": st0.tokens_out,
                           "throughput": st0.throughput(),
                           "transfer_busy_s": busy0},
            "coalesced": {"clock_s": st1.clock_s, "tokens": st1.tokens_out,
                          "throughput": st1.throughput(),
                          "transfer_busy_s": busy1},
            "clock_speedup": st0.clock_s / st1.clock_s,
            "transfer_speedup": busy0 / busy1 if busy1 else float("inf"),
            "batches": co.get("batches", 0),
            "batch_members": co.get("batch_members", 0),
            "preemptions": st1.preemptions,
        }
        rows.append(row)
        table.append([k, "yes" if row["tokens_match"] else "NO",
                      f"{st0.clock_s * 1e3:.3f}", f"{st1.clock_s * 1e3:.3f}",
                      f"{row['clock_speedup']:.2f}x",
                      f"{row['transfer_speedup']:.2f}x",
                      row["batches"],
                      f"{co.get('saved_setup_s', 0.0) * 1e3:.3f}"])
        if k == max(blocks_per_step):
            snapshot = st1.metrics
    print("Fig 9a — transfer coalescing (async engine, resume-heavy "
          "workload):")
    print(fmt_table(["blk/step", "tokens=", "per-obj ms", "coalesced ms",
                     "clock", "xfer time", "batches", "saved ms"], table))
    print()

    stripe_rows = [_stripe_cell(w, c) for w in STRIPE_WAYS
                   for c in CHUNK_KIB]
    print("Fig 9b — chunked multi-lane striping (v5e torus ICI, "
          f"{STRIPE_OBJECT_MIB} MiB object):")
    print(fmt_table(
        ["ways", "chunk KiB", "chunks", "full ms", "half-prefix ms"],
        [[r["ways"], r["chunk_kib"], r["chunks"], f"{r['full_s'] * 1e3:.3f}",
          f"{r['half_prefix_s'] * 1e3:.3f}"] for r in stripe_rows]))
    print()

    def cell(k):
        return next(r for r in rows if r["blocks_per_step"] == k)

    checks = [Check(
        "fig9.tokens_invariant",
        float(all(r["tokens_match"] for r in rows)), lo=1.0,
        note="coalescing re-schedules transfers, never placement — "
             "decoded tokens are bit-identical")]
    for k in blocks_per_step:
        if k >= 4:
            checks.append(Check(
                f"fig9.clock_strictly_lower_{k}blk",
                cell(k)["clock_speedup"], lo=1.0 + 1e-9,
                note=f"async+coalesce clock strictly below async "
                     f"per-object at {k} blocks/step"))
    if 8 in blocks_per_step:
        checks.append(Check(
            "fig9.transfer_time_8blk", cell(8)["transfer_speedup"], lo=1.5,
            note=">=1.5x lower small-object transfer time (lane busy "
                 "seconds) at the 8-blocks/step point"))
    checks.append(Check(
        "fig9.stripe_prefix_early",
        float(all(r["half_prefix_s"] < r["full_s"] - 1e-12
                  for r in stripe_rows)), lo=1.0,
        note="chunk-granular completion: a half-object prefix wait "
             "returns strictly before full completion"))
    for c in CHUNK_KIB:
        w_lo, w_hi = min(STRIPE_WAYS), max(STRIPE_WAYS)
        full = {r["ways"]: r["full_s"] for r in stripe_rows
                if r["chunk_kib"] == c}
        checks.append(Check(
            f"fig9.stripe_ways_monotone_chunk{c}",
            full[w_lo] / full[w_hi], lo=1.0 + 1e-9,
            note=f"{w_hi}-way striping strictly beats {w_lo}-way "
                 f"({c} KiB chunks)"))

    payload = {"name": "fig9_coalescing", "rows": rows,
               "stripe_rows": stripe_rows,
               "checks": [c.to_dict() for c in checks],
               "metrics": snapshot or {}}
    save_result(out_dir, "fig9_coalescing", payload)
    return payload


if __name__ == "__main__":
    from benchmarks.common import RESULTS_DIR
    run(RESULTS_DIR)
