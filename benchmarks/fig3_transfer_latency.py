"""Paper Fig 3 — GPU<->GPU vs GPU<->CPU transfer latency of memory chunks.

The paper sweeps chunk sizes on its 2xH100 NVLink testbed and annotates the
expert sizes of four MoE models; the peer/host speedup is "consistently
high, ranging from 7.5x for the very small Tiny Phi model to 9.5x for the
much bigger Mixtral 8x7B".  We run the same sweep through the calibrated
H100 hardware model (repro.core.tiers.H100_NVLINK) and check the per-model
speedups land in the paper's band.
"""
from __future__ import annotations

from pathlib import Path

from benchmarks.common import Check, fmt_table, save_result
from repro.configs import PAPER_ARCHS, get_config
from repro.core.tiers import H100_NVLINK, expert_bytes


def run(out_dir: Path) -> dict:
    hw = H100_NVLINK

    # generic chunk sweep (the x-axis of Fig 3)
    sweep = []
    for mib in (1, 4, 16, 64, 128, 256, 512):
        nbytes = mib * 2**20
        th = hw.host_link.transfer_time(nbytes)
        tp = hw.peer_link.transfer_time(nbytes)
        sweep.append({"chunk_mib": mib, "host_ms": th * 1e3,
                      "peer_ms": tp * 1e3, "speedup": th / tp})

    # expert-size markers for the paper's four MoE models
    models = []
    for arch in PAPER_ARCHS:
        cfg = get_config(arch)
        eb = expert_bytes(cfg)
        th = hw.host_link.transfer_time(eb)
        tp = hw.peer_link.transfer_time(eb)
        models.append({"model": arch, "expert_mib": eb / 2**20,
                       "host_ms": th * 1e3, "peer_ms": tp * 1e3,
                       "speedup": th / tp})

    by = {m["model"]: m for m in models}
    speedups = [m["speedup"] for m in models]
    checks = [
        Check("fig3.tiny_phi_speedup", by["phi-tiny-moe"]["speedup"],
              lo=7.2, hi=7.9, note="paper: 7.5x for Tiny Phi"),
        Check("fig3.mixtral_speedup", by["mixtral-8x7b"]["speedup"],
              lo=9.2, hi=9.8, note="paper: 9.5x for Mixtral-8x7B"),
        Check("fig3.min_speedup", min(speedups), lo=7.2,
              note="paper: consistently high, >=7.5x"),
        Check("fig3.max_speedup", max(speedups), hi=9.8,
              note="paper band tops out at 9.5x"),
    ]

    print("Fig 3 — transfer latency, peer (NVLink) vs host (PCIe):")
    print(fmt_table(
        ["chunk", "host ms", "peer ms", "speedup"],
        [[f"{s['chunk_mib']} MiB", f"{s['host_ms']:.3f}",
          f"{s['peer_ms']:.3f}", f"{s['speedup']:.2f}x"] for s in sweep]))
    print()
    print(fmt_table(
        ["model (expert size)", "host ms", "peer ms", "speedup"],
        [[f"{m['model']} ({m['expert_mib']:.0f} MiB)", f"{m['host_ms']:.3f}",
          f"{m['peer_ms']:.3f}", f"{m['speedup']:.2f}x"] for m in models]))

    payload = {"name": "fig3_transfer_latency", "sweep": sweep,
               "models": models, "checks": [c.to_dict() for c in checks]}
    save_result(out_dir, "fig3_transfer_latency", payload)
    return payload


if __name__ == "__main__":
    from benchmarks.common import RESULTS_DIR
    run(RESULTS_DIR)
