"""Benchmark harness entrypoint: ``PYTHONPATH=src python -m benchmarks.run``.

Runs one benchmark per paper artifact (Fig 2/3/5/6/7, Table 1) plus the
roofline report derived from the multi-pod dry-run, validates every claim
band, writes per-benchmark JSON to ``results/`` and prints the summary.

Flags:
  --only fig5,fig7     run a subset
  --fast               fewer simulator trials/steps (CI mode)
"""
from __future__ import annotations

import argparse
import sys
import time

from benchmarks.common import RESULTS_DIR, Check, summarize_checks

BENCHES = ["fig2", "fig3", "table1", "fig5", "fig6", "fig7", "fig8",
           "fig9", "fig10", "fig11", "fig12", "fig13", "fig14", "fig15",
           "roofline"]


def _call(name: str, fast: bool, hw: str):
    if name == "fig2":
        from benchmarks import fig2_cluster_cdf as m
        return m.run(RESULTS_DIR)
    if name == "fig3":
        from benchmarks import fig3_transfer_latency as m
        return m.run(RESULTS_DIR)
    if name == "table1":
        from benchmarks import table1_model_zoo as m
        return m.run(RESULTS_DIR)
    if name == "fig5":
        from benchmarks import fig5_moe_throughput as m
        return m.run(RESULTS_DIR, trials=2 if fast else 5,
                     decode_steps=8 if fast else 32, timeline=not fast)
    if name == "fig6":
        from benchmarks import fig6_offload_sweep as m
        return m.run(RESULTS_DIR, decode_steps=4 if fast else 8)
    if name == "fig7":
        from benchmarks import fig7_kv_latency as m
        return m.run(RESULTS_DIR)
    if name == "fig8":
        from benchmarks import fig8_peer_scaling as m
        return m.run(RESULTS_DIR, hw=hw, fast=fast)
    if name == "fig9":
        from benchmarks import fig9_coalescing as m
        return m.run(RESULTS_DIR, fast=fast)
    if name == "fig10":
        from benchmarks import fig10_slo_serving as m
        return m.run(RESULTS_DIR, hw=hw, fast=fast)
    if name == "fig11":
        from benchmarks import fig11_prefix_sharing as m
        return m.run(RESULTS_DIR, hw=hw, fast=fast)
    if name == "fig12":
        from benchmarks import fig12_continuous_batching as m
        return m.run(RESULTS_DIR, hw=hw, fast=fast)
    if name == "fig13":
        from benchmarks import fig13_fidelity_tiers as m
        return m.run(RESULTS_DIR, hw=hw, fast=fast)
    if name == "fig14":
        from benchmarks import fig14_scaleout as m
        return m.run(RESULTS_DIR, hw=hw, fast=fast)
    if name == "fig15":
        from benchmarks import fig15_stability as m
        return m.run(RESULTS_DIR, hw=hw, fast=fast)
    if name == "roofline":
        from benchmarks import roofline as m
        return m.run(RESULTS_DIR)
    raise ValueError(f"unknown benchmark {name!r}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of " + ",".join(BENCHES))
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--hw", default="h100-nvlink-2gpu",
                    choices=["h100-nvlink-2gpu", "tpu-v5e"],
                    help="hardware family for the per-family benchmarks "
                         "(fig8 topology sweep, fig10 SLO serving, fig11 "
                         "prefix sharing, fig12 continuous batching, fig13 "
                         "fidelity tiers, fig14 scale-out, fig15 stability "
                         "control): NVLink mesh vs TPU v5e ICI torus")
    args = ap.parse_args(argv)

    names = args.only.split(",") if args.only else BENCHES
    all_checks, failed = [], []
    for name in names:
        print("=" * 78)
        print(f"== {name}")
        print("=" * 78)
        t0 = time.time()
        payload = _call(name, args.fast, args.hw)
        checks = [Check(**{k: v for k, v in c.items() if k != "ok"})
                  for c in payload.get("checks", [])]
        all_checks += checks
        bad = [c for c in checks if not c.ok]
        failed += bad
        print(f"\n-- {name}: {len(checks) - len(bad)}/{len(checks)} checks "
              f"pass ({time.time() - t0:.1f}s)")
        print(summarize_checks(checks))
        print()

    print("=" * 78)
    n_ok = len(all_checks) - len(failed)
    print(f"TOTAL: {n_ok}/{len(all_checks)} claim checks pass")
    if failed:
        print("FAILED:")
        for c in failed:
            print(f"  {c.name} = {c.value:.4g} not in [{c.lo}, {c.hi}]")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
