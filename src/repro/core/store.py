"""HarvestStore — the generic tiered-object layer every Harvest client shares.

The paper's two applications — expert weights (§4) and KV cache entries
(§5) — are both "objects with a durability class placed across
{local, peer, host} tiers".  This module is the single implementation of
that shape:

  * :class:`HarvestStore` owns the residency table
    (``ObjectKey -> ObjectEntry``), the peer-then-host eviction ladder,
    revocation handling for both durability classes, and the
    promote / demote / pin primitives.  Clients (the KV block table, the
    expert rebalancer, or any future object class — SSM states, prefix
    caches, LoRA adapters) register objects and policy hooks instead of
    re-implementing residency bookkeeping.
  * :class:`Durability` is the application's contract with revocation:
    ``BACKED`` objects have (or get, on eviction) an authoritative host
    copy and fall back to host transparently; ``RECONSTRUCTIBLE`` objects
    are peer-only and transition to the explicit ``LOST`` residency state,
    so a dropped object can never be confused with a freshly allocated one.
  * :class:`TransferEngine` centralises all simulated transfer-time
    accounting (previously scattered across ``ReloadOp.seconds``,
    ``ExpertRebalancer.fetch`` and the engine's ``_apply_ops``) and owns
    the event-driven transfer timeline: a simulated clock plus one FIFO
    queue per directional link lane.  Lanes are *per peer device*: with an
    interconnect :class:`~repro.core.tiers.Topology` attached, a transfer
    that names peer device ``d`` rides ``peer{d}_in``/``peer{d}_out`` and
    is charged that device's :class:`~repro.core.tiers.LinkSpec`, so
    transfers to distinct peers pipeline in parallel while each pair
    serialises FIFO.  Device 1 keeps the legacy ``peer_in``/``peer_out``
    lane names (the 2-device compat mapping); ``host_in``/``host_out``
    stay single-laned — there is one PCIe path to DRAM.  The legacy
    batched ``schedule`` reduction remains as the sync-mode compat
    wrapper.
  * :class:`MetricsRegistry` is the unified, namespaced counter store that
    replaces the per-component ad-hoc ``stats`` dicts.

All times are seconds, sizes bytes.
"""
from __future__ import annotations

import collections
import enum
from dataclasses import dataclass
from typing import Callable, Dict, Hashable, Iterable, List, Optional, Set, Tuple

import numpy as np

from repro.core.allocator import HarvestAllocator, HarvestHandle
from repro.core.tiers import HardwareModel, Tier, Topology

ObjectKey = Hashable


class Durability(enum.Enum):
    """What revocation is allowed to cost the application (paper §3.2)."""
    BACKED = "backed"                    # host copy authoritative; revocation
                                         # falls back to host transparently
    RECONSTRUCTIBLE = "reconstructible"  # peer-only; revocation loses the
                                         # payload and the client recomputes


class Residency(enum.Enum):
    """Where an object currently lives.  LOST is an explicit terminal state
    for revoked RECONSTRUCTIBLE objects — not a sentinel encoded in other
    fields."""
    LOCAL = "local"
    PEER = "peer"
    HOST = "host"
    LOST = "lost"


_RESIDENCY_TIER = {
    Residency.LOCAL: Tier.LOCAL_HBM,
    Residency.PEER: Tier.PEER_HBM,
    Residency.HOST: Tier.HOST_DRAM,
}
_TIER_RESIDENCY = {v: k for k, v in _RESIDENCY_TIER.items()}


class LostObjectError(RuntimeError):
    """Raised when a client touches an object whose payload was revoked."""


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------


class Counters(dict):
    """A dict of counters: reading a missing key yields 0, so ``c[k] += v``
    needs no setdefault dance and pre-seeded keys keep stable print order."""

    def __missing__(self, key):
        return 0


class MetricsRegistry:
    """Namespaced counters shared by every component of one runtime.

    ``counters("kv")`` always returns the same live dict, so a client's
    ``stats`` attribute and the registry's snapshot view the same numbers.
    """

    def __init__(self):
        self._namespaces: Dict[str, Counters] = {}

    def counters(self, namespace: str, keys: Iterable[str] = ()) -> Counters:
        ns = self._namespaces.setdefault(namespace, Counters())
        for k in keys:
            ns.setdefault(k, 0)
        return ns

    def namespaces(self) -> List[str]:
        return list(self._namespaces)

    def snapshot(self) -> Dict[str, dict]:
        return {name: dict(ns) for name, ns in self._namespaces.items()}


# ---------------------------------------------------------------------------
# transfers
# ---------------------------------------------------------------------------


@dataclass
class Transfer:
    """One simulated tier-to-tier move.

    A freshly minted transfer is *pending*: it carries a size and a raw
    link time (``seconds``) but no position on the timeline.  Sync clients
    sum pending transfers with :meth:`TransferEngine.schedule`; async
    clients :meth:`TransferEngine.submit` them onto the per-link FIFO
    queues, which stamps ``issue_t``/``ready_t``, and later complete them
    with :meth:`TransferEngine.drain_until`.
    """
    key: ObjectKey
    src: Tier
    dst: Tier
    nbytes: int
    seconds: float
    client: str = "default"
    device: Optional[int] = None   # peer device the payload lives on/moves to
    # --- timeline fields (live only once submitted) ---
    issue_t: float = 0.0     # simulated time the transfer was enqueued
    ready_t: float = 0.0     # simulated time the payload is usable at dst
    channel: str = ""        # directional link lane the transfer occupies
    done: bool = True        # un-submitted transfers count as complete


def _link_name(src: Tier, dst: Tier) -> str:
    pair = {src, dst}
    if pair == {Tier.LOCAL_HBM}:
        return "hbm"
    if Tier.HOST_DRAM in pair:
        return "host"
    return "peer"


#: the peer device whose lanes keep the legacy un-numbered names — the
#: 2-device presets put their single peer at device 1, so pre-topology
#: metrics keys (``q.peer_in.*``) and goldens stay stable
LEGACY_PEER_DEVICE = 1


def channel_name(src: Tier, dst: Tier, device: Optional[int] = None) -> str:
    """Directional lane of a physical link, per peer device.

    NVLink / ICI / PCIe are full duplex: writes out of local HBM
    (evictions) and reads into local HBM (reloads) move on opposite
    directions of the same link and do not contend with each other.  Each
    direction serialises its own FIFO queue.  Peer links are additionally
    *per device*: transfers touching peer device ``d`` ride
    ``peer{d}_in``/``peer{d}_out`` so distinct peers never queue behind
    each other; device :data:`LEGACY_PEER_DEVICE` (and transfers naming no
    device) keep the legacy ``peer_in``/``peer_out`` names.  The host path
    is one physical PCIe link regardless of which peer is involved.
    """
    base = _link_name(src, dst)
    if base == "hbm":
        return base
    if base == "peer" and device is not None and device != LEGACY_PEER_DEVICE:
        base = f"peer{device}"
    return f"{base}_in" if dst is Tier.LOCAL_HBM else f"{base}_out"


class TransferEngine:
    """Single source of truth for simulated transfer times.

    Every tier move in the system is minted here, so per-link byte/time
    accounting lands in one metrics namespace instead of three stats dicts.

    The engine also owns the *simulated transfer timeline*: a clock
    (``now``) plus one FIFO queue per directional link lane.  ``submit``
    enqueues a minted transfer (stamping ``issue_t``/``ready_t`` from the
    lane's busy-until time and any in-flight transfer of the same key) and
    ``drain_until`` advances the clock, completing everything whose
    ``ready_t`` has passed.  The legacy :meth:`schedule` — a pure
    pre-summed-seconds reduction — is kept as the sync-mode compat wrapper
    and is what the seed-equivalence goldens exercise.
    """

    def __init__(self, hardware: HardwareModel,
                 metrics: Optional[MetricsRegistry] = None,
                 topology: Optional[Topology] = None):
        self.hw = topology.hardware if (hardware is None and topology) \
            else hardware
        self.topology = topology
        self.metrics = metrics or MetricsRegistry()
        self._stats = self.metrics.counters("transfer")
        self.now: float = 0.0
        self._channel_busy: Dict[str, float] = {}
        self._inflight: Dict[str, "collections.deque[Transfer]"] = {}
        self._key_busy: Dict[ObjectKey, Transfer] = {}
        # opt-in submit log (benchmarks reconstruct exact per-lane busy
        # intervals from it; off by default — it grows without bound)
        self.record_log: bool = False
        self.log: List[Transfer] = []

    def lane_for(self, src: Tier, dst: Tier,
                 device: Optional[int] = None) -> str:
        """The directional lane a (src, dst, device) transfer occupies.

        Per-device peer lanes exist only when an interconnect topology is
        attached AND the device is one of its peers: a flat
        :class:`HardwareModel` declares ONE peer link, so every peer
        transfer keeps the legacy single lane pair no matter how callers
        number their devices.
        """
        if self.topology is None or device not in self.topology.peer_links:
            device = None
        return channel_name(src, dst, device)

    def estimate(self, nbytes: int, src: Tier, dst: Tier,
                 device: Optional[int] = None) -> float:
        """Link time of a hypothetical transfer (no accounting) — the
        topology's per-device link when one is attached and named."""
        if self.topology is not None:
            return self.topology.transfer_time(nbytes, src, dst, device)
        return self.hw.transfer_time(nbytes, src, dst)

    def transfer(self, key: ObjectKey, nbytes: int, src: Tier, dst: Tier,
                 extra_latency: float = 0.0, client: str = "default",
                 device: Optional[int] = None) -> Transfer:
        seconds = self.estimate(nbytes, src, dst, device) + extra_latency
        link = _link_name(src, dst)
        self._stats[f"{client}.{link}_s"] += seconds
        self._stats[f"{client}.{link}_n"] += 1
        self._stats[f"{client}.{link}_bytes"] += nbytes
        return Transfer(key, src, dst, nbytes, seconds, client=client,
                        device=device)

    def schedule(self, transfers: Iterable[Transfer],
                 overlap_links: bool = False) -> float:
        """Total wall time for a batch of transfers (sync compat path).

        Default is serial issue (one DMA queue — matches the engine's
        original accounting).  With ``overlap_links`` the batch is grouped
        by physical link (peer ICI/NVLink vs host PCIe): each link
        serialises its own transfers, distinct links run concurrently.
        The event-driven path (:meth:`submit` + :meth:`drain_until`)
        supersedes this for async clients.
        """
        if not overlap_links:
            return float(sum(t.seconds for t in transfers))
        per_link: Dict[str, float] = {}
        for t in transfers:
            link = _link_name(t.src, t.dst)
            per_link[link] = per_link.get(link, 0.0) + t.seconds
        return max(per_link.values(), default=0.0)

    def overlap(self, compute_s: float, transfer_s: float,
                enabled: bool = True) -> float:
        """CGOPipe-style overlap: transfers hide under compute when enabled."""
        return max(compute_s, transfer_s) if enabled else compute_s + transfer_s

    # ------------------------------------------------------------- timeline
    def submit(self, t: Transfer) -> Transfer:
        """Enqueue a pending transfer on its directional link lane.

        The transfer starts once the lane is free AND any in-flight
        transfer of the same key has completed (a reload of a block whose
        eviction write-back is still on the wire must wait for it), and
        becomes ready ``seconds`` later.  Per-lane FIFO order is preserved
        by construction: ``ready_t`` is non-decreasing within a lane.
        """
        ch = self.lane_for(t.src, t.dst, t.device)
        t.channel = ch
        t.issue_t = self.now
        start = max(self.now, self._channel_busy.get(ch, 0.0))
        dep = self._key_busy.get(t.key)
        if dep is not None and not dep.done:
            start = max(start, dep.ready_t)
        t.ready_t = start + t.seconds
        t.done = False
        self._channel_busy[ch] = t.ready_t
        self._key_busy[t.key] = t
        q = self._inflight.setdefault(ch, collections.deque())
        q.append(t)
        if self.record_log:
            self.log.append(t)
        if not self._stats[f"q.{ch}.submitted"]:
            self._stats[f"q.{ch}.first_issue_t"] = t.issue_t
        self._stats[f"q.{ch}.submitted"] += 1
        self._stats[f"q.{ch}.busy_s"] += t.seconds
        self._stats[f"q.{ch}.last_ready_t"] = t.ready_t
        self._stats[f"q.{ch}.depth"] = len(q)
        if len(q) > self._stats[f"q.{ch}.peak"]:
            self._stats[f"q.{ch}.peak"] = len(q)
        return t

    def drain_until(self, t: float) -> List[Transfer]:
        """Advance the clock to ``t`` (never backwards) and complete every
        in-flight transfer whose ``ready_t`` has passed.  Returns the
        completed transfers."""
        if t > self.now:
            self.now = t
        done: List[Transfer] = []
        for ch, q in self._inflight.items():
            while q and q[0].ready_t <= self.now:
                tr = q.popleft()
                tr.done = True
                if self._key_busy.get(tr.key) is tr:
                    del self._key_busy[tr.key]
                self._stats[f"q.{ch}.completed"] += 1
                self._stats[f"q.{ch}.depth"] = len(q)
                done.append(tr)
        return done

    def advance(self, seconds: float) -> List[Transfer]:
        """Let simulated time pass (a compute window) and drain."""
        return self.drain_until(self.now + seconds)

    def wait_for(self, transfers: Iterable[Transfer]) -> float:
        """Block the clock until every given transfer has completed;
        returns the new ``now``.  Already-complete transfers are free."""
        target = max((t.ready_t for t in transfers if not t.done),
                     default=self.now)
        if target > self.now:
            self.drain_until(target)
        return self.now

    def pending(self, channel: Optional[str] = None) -> int:
        """Number of in-flight transfers (optionally on one lane)."""
        if channel is not None:
            return len(self._inflight.get(channel, ()))
        return sum(len(q) for q in self._inflight.values())

    def channel_busy_until(self, channel: str) -> float:
        """Simulated time the lane's queue runs dry (>= ``now``)."""
        return max(self.now, self._channel_busy.get(channel, 0.0))

    def queue_depths(self) -> Dict[str, int]:
        return {ch: len(q) for ch, q in self._inflight.items() if q}


# ---------------------------------------------------------------------------
# residency table
# ---------------------------------------------------------------------------


@dataclass
class ObjectEntry:
    """One object's placement.  Clients may subclass to carry domain fields
    (the KV block table adds ``base_pos``/``filled``)."""
    state: Residency = Residency.HOST
    durability: Durability = Durability.BACKED
    local_slot: Optional[int] = None
    handle: Optional[HarvestHandle] = None   # live only while state is PEER
    host_copy: bool = False                  # an authoritative host copy exists
    hotness: float = 0.0                     # EWMA of client-defined heat
    pinned: bool = False                     # never evicted from local
    nbytes: int = 0

    @property
    def tier(self) -> Optional[Tier]:
        return _RESIDENCY_TIER.get(self.state)


class HarvestStore:
    """Residency table + tier ladder for one client's object class.

    A store is parameterised by the client name (metrics namespace and
    allocator fairness tag), the default object size, an optional local
    slot pool (``num_local_slots=None`` means the local tier is unmanaged —
    e.g. pinned expert weights), and the default durability class.
    """

    #: every counter the store itself may bump — clients pre-seed a subset
    EVENTS = ("allocated", "freed", "evict_to_peer", "evict_to_host",
              "reload_peer", "reload_host", "revocations", "recomputes",
              "migrations", "demotions")

    def __init__(self, allocator: HarvestAllocator, transfers: TransferEngine,
                 *, client: str = "default", object_nbytes: int = 0,
                 num_local_slots: Optional[int] = None,
                 durability: Durability = Durability.BACKED,
                 store_payload: bool = False,
                 metrics: Optional[MetricsRegistry] = None,
                 owner_fn: Optional[Callable[[ObjectKey], Hashable]] = None,
                 entry_factory: Callable[..., ObjectEntry] = ObjectEntry,
                 stat_keys: Iterable[str] = ()):
        self.allocator = allocator
        self.transfers = transfers
        self.client = client
        self.object_nbytes = object_nbytes
        self.durability = durability
        self.entry_factory = entry_factory
        # owners group keys for pinning / bulk eviction / bulk release; the
        # default matches (request_id, block_idx)-style composite keys
        self.owner_fn = owner_fn or (
            lambda k: k[0] if isinstance(k, tuple) else k)
        self.stats = (metrics or transfers.metrics).counters(
            client, keys=stat_keys)

        self.table: Dict[ObjectKey, ObjectEntry] = {}
        self.lru: "collections.OrderedDict[ObjectKey, None]" = \
            collections.OrderedDict()
        self.num_local_slots = num_local_slots
        self.free_slots: List[int] = (
            list(range(num_local_slots)) if num_local_slots is not None else [])
        self.pinned_owners: Set = set()

        self.store_payload = store_payload
        self._payload: Dict[ObjectKey, np.ndarray] = {}
        # policy hooks: called with (key, local_slot) so the embedding layer
        # (e.g. the serving engine's pool arrays) can move real payloads
        # alongside the placement
        self.evict_hook: Optional[Callable[[ObjectKey, int], None]] = None
        self.reload_hook: Optional[Callable[[ObjectKey, int], None]] = None

    # ------------------------------------------------------------ lifecycle
    def register(self, key: ObjectKey, *, state: Residency = Residency.HOST,
                 durability: Optional[Durability] = None,
                 nbytes: Optional[int] = None, pinned: bool = False,
                 **extra) -> ObjectEntry:
        """Track an object that already exists in some tier (no transfer)."""
        assert key not in self.table, f"object {key} already registered"
        durability = durability or self.durability
        ent = self.entry_factory(
            state=state, durability=durability,
            nbytes=self.object_nbytes if nbytes is None else nbytes,
            pinned=pinned,
            host_copy=(durability is Durability.BACKED
                       or state is Residency.HOST),
            **extra)
        self.table[key] = ent
        return ent

    def allocate_local(self, key: ObjectKey, *, nbytes: Optional[int] = None,
                       **extra) -> Tuple[int, List[Transfer]]:
        """Place a NEW object in a local slot, evicting LRU if needed."""
        assert key not in self.table, f"object {key} already allocated"
        assert self.num_local_slots is not None, \
            f"{self.client}: store has no managed local pool"
        ops: List[Transfer] = []
        if not self.free_slots:
            ops.extend(self._evict_one(exclude_owner=self.owner_fn(key)))
        slot = self.free_slots.pop()
        self.table[key] = self.entry_factory(
            state=Residency.LOCAL, durability=self.durability,
            nbytes=self.object_nbytes if nbytes is None else nbytes,
            local_slot=slot, **extra)
        self.lru[key] = None
        self.stats["allocated"] += 1
        return slot, ops

    def release(self, key: ObjectKey) -> None:
        """Stop tracking an object, freeing its slot / peer segment."""
        ent = self.table.pop(key)
        if ent.state is Residency.LOCAL and self.num_local_slots is not None:
            self.free_slots.append(ent.local_slot)
        elif ent.state is Residency.PEER and ent.handle is not None:
            self.allocator.harvest_free(ent.handle)
        self.lru.pop(key, None)
        self._payload.pop(key, None)
        self.stats["freed"] += 1

    def release_owner(self, owner) -> None:
        for key in [k for k in self.table if self.owner_fn(k) == owner]:
            self.release(key)

    # ------------------------------------------------------------- eviction
    def _evict_one(self, exclude_owner=None,
                   victim: Optional[ObjectKey] = None,
                   exclude_key: Optional[ObjectKey] = None) -> List[Transfer]:
        """Evict one local object down the ladder: peer first, host fallback.

        Victims from other owners are preferred; when only the excluded
        owner's objects remain local (single-request long-context), its LRU
        object other than ``exclude_key`` is evicted instead.
        """
        if victim is None:
            fallback = None
            for key in self.lru:
                ent = self.table[key]
                if (ent.state is not Residency.LOCAL or ent.pinned
                        or self.owner_fn(key) in self.pinned_owners):
                    continue
                if exclude_owner is None or self.owner_fn(key) != exclude_owner:
                    victim = key
                    break
                if fallback is None and key != exclude_key:
                    fallback = key
            if victim is None:
                victim = fallback
        if victim is None:
            raise RuntimeError(
                f"{self.client}: local pool exhausted — no evictable object")
        ent = self.table[victim]
        if self.evict_hook is not None:
            self.evict_hook(victim, ent.local_slot)
        if self.num_local_slots is not None:
            self.free_slots.append(ent.local_slot)
        ent.local_slot = None
        self.lru.pop(victim, None)

        ops: List[Transfer] = []
        h = self.allocator.harvest_alloc(
            ent.nbytes, hints={"hot": ent.hotness}, client=self.client)
        if h is not None:
            ent.state = Residency.PEER
            ent.handle = h
            self.allocator.harvest_register_cb(
                h, lambda handle, key=victim: self._on_revoked(
                    key, handle.device))
            ops.append(self.transfers.transfer(
                victim, ent.nbytes, Tier.LOCAL_HBM, Tier.PEER_HBM,
                client=self.client, device=h.device))
            self.stats["evict_to_peer"] += 1
            self.stats[f"dev{h.device}.evictions"] += 1
            if ent.durability is Durability.BACKED:
                ent.host_copy = True   # written back asynchronously
        else:
            ent.state = Residency.HOST
            ent.host_copy = True       # the host write IS the eviction
            ops.append(self.transfers.transfer(
                victim, ent.nbytes, Tier.LOCAL_HBM, Tier.HOST_DRAM,
                client=self.client))
            self.stats["evict_to_host"] += 1
        return ops

    def evict_owner(self, owner) -> List[Transfer]:
        """Preemption support (paper §6.3): push ALL of an owner's local
        objects out to the peer/host tiers."""
        ops: List[Transfer] = []
        self.pinned_owners.discard(owner)
        for key in sorted(k for k in self.table if self.owner_fn(k) == owner):
            if self.table[key].state is Residency.LOCAL:
                ops.extend(self._evict_one(victim=key))
        return ops

    # --------------------------------------------------------------- reload
    def ensure_local(self, key: ObjectKey) -> List[Transfer]:
        """Fetch-mode reload: make an object local (LRU-touch it either way)."""
        ent = self.table[key]
        self.lru.pop(key, None)
        self.lru[key] = None     # touch
        if ent.state is Residency.LOCAL:
            return []
        if ent.state is Residency.LOST:
            raise LostObjectError(
                f"{self.client}: object {key} was revoked without a host "
                "copy — the client must reconstruct it")
        ops: List[Transfer] = []
        slot = None
        if self.num_local_slots is not None:
            if not self.free_slots:
                ops.extend(self._evict_one(
                    exclude_owner=self.owner_fn(key), exclude_key=key))
            slot = self.free_slots.pop()
        src = ent.tier
        device = None
        if ent.state is Residency.PEER:
            self.stats["reload_peer"] += 1
            if ent.handle is not None:
                device = ent.handle.device
                self.stats[f"dev{device}.reloads"] += 1
                self.allocator.harvest_free(ent.handle)
                ent.handle = None
        else:
            self.stats["reload_host"] += 1
        ent.state = Residency.LOCAL
        ent.local_slot = slot
        if self.reload_hook is not None:
            self.reload_hook(key, slot)
        ops.append(self.transfers.transfer(
            key, ent.nbytes, src, Tier.LOCAL_HBM, client=self.client,
            device=device))
        return ops

    # ------------------------------------------------------ promote / demote
    def promote_to_peer(self, key: ObjectKey) -> Optional[Transfer]:
        """Migrate a host-resident object into peer HBM (background path —
        the move is not charged to any request's critical path).  Returns
        the pending transfer (truthy) so timeline clients can ``submit``
        it, or None when the object is not promotable."""
        ent = self.table[key]
        if ent.state is not Residency.HOST:
            return None
        h = self.allocator.harvest_alloc(
            ent.nbytes, hints={"hot": ent.hotness}, client=self.client)
        if h is None:
            return None
        self.allocator.harvest_register_cb(
            h, lambda handle, key=key: self._on_revoked(key, handle.device))
        ent.state = Residency.PEER
        ent.handle = h
        if ent.durability is Durability.RECONSTRUCTIBLE:
            ent.host_copy = False   # the class does not pay for host backing
        op = self.transfers.transfer(key, ent.nbytes, Tier.HOST_DRAM,
                                     Tier.PEER_HBM, client=self.client,
                                     device=h.device)
        self.stats["migrations"] += 1
        self.stats[f"dev{h.device}.migrations"] += 1
        return op

    def demote(self, key: ObjectKey) -> None:
        """Voluntarily release a peer-resident object back to host."""
        ent = self.table[key]
        if ent.state is Residency.PEER and ent.handle is not None:
            self.allocator.harvest_free(ent.handle)
            ent.state = Residency.HOST
            ent.handle = None
            ent.host_copy = True    # the demotion write re-materialises it
            self.stats["demotions"] += 1

    def pin(self, key: ObjectKey, pinned: bool = True) -> None:
        self.table[key].pinned = pinned

    # ------------------------------------------------------------ revocation
    def _on_revoked(self, key: ObjectKey,
                    device: Optional[int] = None) -> None:
        ent = self.table.get(key)
        if ent is None or ent.state is not Residency.PEER:
            return
        ent.handle = None
        self.stats["revocations"] += 1
        if device is not None:
            self.stats[f"dev{device}.revocations"] += 1
        if ent.host_copy:
            ent.state = Residency.HOST    # transparent fallback (BACKED)
        else:
            ent.state = Residency.LOST    # explicit loss (RECONSTRUCTIBLE)
            self.stats["recomputes"] += 1
            self._payload.pop(key, None)

    # -------------------------------------------------------------- hotness
    def touch_hotness(self, key: ObjectKey, sample: float,
                      alpha: float) -> None:
        """EWMA-update an object's heat: h <- alpha*h + (1-alpha)*sample."""
        ent = self.table[key]
        ent.hotness = alpha * ent.hotness + (1 - alpha) * sample

    def hottest(self, state: Residency, limit: Optional[int] = None
                ) -> List[Tuple[ObjectKey, ObjectEntry]]:
        cand = [(k, e) for k, e in self.table.items() if e.state is state]
        cand.sort(key=lambda kv: -kv[1].hotness)
        return cand if limit is None else cand[:limit]

    # -------------------------------------------------------------- queries
    def device_of(self, key: ObjectKey) -> Optional[int]:
        """Peer device an object's payload lives on (None unless PEER)."""
        ent = self.table.get(key)
        if ent is None or ent.handle is None:
            return None
        return ent.handle.device

    def is_lost(self, key: ObjectKey) -> bool:
        ent = self.table.get(key)
        return ent is not None and ent.state is Residency.LOST

    def tier_counts(self) -> Dict[str, int]:
        out = {r.value: 0 for r in Residency}
        for ent in self.table.values():
            out[ent.state.value] += 1
        return out

    def owner_keys(self, owner) -> List[ObjectKey]:
        return sorted(k for k in self.table if self.owner_fn(k) == owner)

    def residency_of(self, owner) -> List[Optional[Tier]]:
        return [self.table[k].tier for k in self.owner_keys(owner)]

    # -------------------------------------------------------------- payloads
    def write_payload(self, key: ObjectKey, data: np.ndarray) -> None:
        if self.store_payload:
            self._payload[key] = np.asarray(data)

    def read_payload(self, key: ObjectKey) -> Optional[np.ndarray]:
        return self._payload.get(key)
