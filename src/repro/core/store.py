"""HarvestStore — the generic tiered-object layer every Harvest client shares.

The paper's two applications — expert weights (§4) and KV cache entries
(§5) — are both "objects with a durability class placed across
{local, peer, host} tiers".  This module is the single implementation of
that shape:

  * :class:`HarvestStore` owns the residency table
    (``ObjectKey -> ObjectEntry``), the peer-then-host eviction ladder,
    revocation handling for both durability classes, and the
    promote / demote / pin primitives.  Clients (the KV block table, the
    expert rebalancer, or any future object class — SSM states, prefix
    caches, LoRA adapters) register objects and policy hooks instead of
    re-implementing residency bookkeeping.
  * :class:`Durability` is the application's contract with revocation:
    ``BACKED`` objects have (or get, on eviction) an authoritative host
    copy and fall back to host transparently; ``RECONSTRUCTIBLE`` objects
    are peer-only and transition to the explicit ``LOST`` residency state,
    so a dropped object can never be confused with a freshly allocated one.
  * :class:`TransferEngine` centralises all simulated transfer-time
    accounting (previously scattered across ``ReloadOp.seconds``,
    ``ExpertRebalancer.fetch`` and the engine's ``_apply_ops``) and owns
    the event-driven transfer timeline: a simulated clock plus one FIFO
    queue per directional link lane.  Lanes are *per peer device*: with an
    interconnect :class:`~repro.core.tiers.Topology` attached, a transfer
    that names peer device ``d`` rides ``peer{d}_in``/``peer{d}_out`` and
    is charged that device's :class:`~repro.core.tiers.LinkSpec`, so
    transfers to distinct peers pipeline in parallel while each pair
    serialises FIFO.  Device 1 keeps the legacy ``peer_in``/``peer_out``
    lane names (the 2-device compat mapping); ``host_in``/``host_out``
    stay single-laned — there is one PCIe path to DRAM.  The legacy
    batched ``schedule`` reduction remains as the sync-mode compat
    wrapper.
  * :class:`MetricsRegistry` is the unified, namespaced counter store that
    replaces the per-component ad-hoc ``stats`` dicts.

All times are seconds, sizes bytes.
"""
from __future__ import annotations

import collections
import enum
from dataclasses import dataclass
from typing import Callable, Dict, Hashable, Iterable, List, Optional, Set, Tuple

import numpy as np

from repro.core.allocator import HarvestAllocator, HarvestHandle
from repro.core.tiers import Fidelity, HardwareModel, Tier, Topology

ObjectKey = Hashable


class Durability(enum.Enum):
    """What revocation is allowed to cost the application (paper §3.2)."""
    BACKED = "backed"                    # host copy authoritative; revocation
                                         # falls back to host transparently
    RECONSTRUCTIBLE = "reconstructible"  # peer-only; revocation loses the
                                         # payload and the client recomputes


class Residency(enum.Enum):
    """Where an object currently lives.  LOST is an explicit terminal state
    for revoked RECONSTRUCTIBLE objects — not a sentinel encoded in other
    fields."""
    LOCAL = "local"
    PEER = "peer"
    HOST = "host"
    SSD = "ssd"
    LOST = "lost"


_RESIDENCY_TIER = {
    Residency.LOCAL: Tier.LOCAL_HBM,
    Residency.PEER: Tier.PEER_HBM,
    Residency.HOST: Tier.HOST_DRAM,
    Residency.SSD: Tier.LOCAL_SSD,
}
_TIER_RESIDENCY = {v: k for k, v in _RESIDENCY_TIER.items()}


class LostObjectError(RuntimeError):
    """Raised when a client touches an object whose payload was revoked."""


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------


class Counters(dict):
    """A dict of counters: reading a missing key yields 0, so ``c[k] += v``
    needs no setdefault dance and pre-seeded keys keep stable print order."""

    def __missing__(self, key):
        return 0


class MetricsRegistry:
    """Namespaced counters shared by every component of one runtime.

    ``counters("kv")`` always returns the same live dict, so a client's
    ``stats`` attribute and the registry's snapshot view the same numbers.
    """

    def __init__(self):
        self._namespaces: Dict[str, Counters] = {}

    def counters(self, namespace: str, keys: Iterable[str] = ()) -> Counters:
        ns = self._namespaces.setdefault(namespace, Counters())
        for k in keys:
            ns.setdefault(k, 0)
        return ns

    def namespaces(self) -> List[str]:
        return list(self._namespaces)

    def snapshot(self) -> Dict[str, dict]:
        return {name: dict(ns) for name, ns in self._namespaces.items()}


# ---------------------------------------------------------------------------
# transfers
# ---------------------------------------------------------------------------


@dataclass(slots=True)
class Transfer:
    """One simulated tier-to-tier move.

    A freshly minted transfer is *pending*: it carries a size and a raw
    link time (``seconds``) but no position on the timeline.  Sync clients
    sum pending transfers with :meth:`TransferEngine.schedule`; async
    clients :meth:`TransferEngine.submit` them onto the per-link FIFO
    queues, which stamps ``issue_t``/``ready_t``, and later complete them
    with :meth:`TransferEngine.drain_until`.
    """
    key: ObjectKey
    src: Tier
    dst: Tier
    nbytes: int
    seconds: float
    client: str = "default"
    device: Optional[int] = None   # peer device the payload lives on/moves to
    # --- coalescing / striping fields (set by the TransferPlanner) ---
    parent: Optional[ObjectKey] = None  # object key a stripe chunk belongs to
    offset: int = 0          # chunk's byte offset within its parent object
    lane: Optional[str] = None   # forced lane (stripe sub-lanes); None = route
    batch_id: int = 0        # coalesced-batch membership (0 = solo submission)
    #: precision of the payload ON THE WIRE — ``nbytes`` is already the
    #: fidelity-scaled wire size; the planner refuses to coalesce or stripe
    #: transfers of mixed fidelity into one batch (one gather kernel call
    #: packs one dtype)
    fidelity: Fidelity = Fidelity.FP16
    # --- timeline fields (live only once submitted) ---
    issue_t: float = 0.0     # simulated time the transfer was enqueued
    ready_t: float = 0.0     # simulated time the payload is usable at dst
    lane_s: float = 0.0      # lane occupancy actually charged (== seconds
                             # solo; less the saved setup inside a batch)
    channel: str = ""        # directional link lane the transfer occupies
    done: bool = True        # un-submitted transfers count as complete

    @property
    def dep_key(self) -> ObjectKey:
        """Key same-object ordering chains on: the parent for stripe chunks
        (siblings must NOT serialise on each other — see
        :meth:`TransferEngine.submit_chunks`), else the object key."""
        return self.key if self.parent is None else self.parent


def _link_name(src: Tier, dst: Tier) -> str:
    pair = {src, dst}
    if pair == {Tier.LOCAL_HBM}:
        return "hbm"
    if Tier.LOCAL_SSD in pair:
        return "ssd"
    if Tier.HOST_DRAM in pair:
        return "host"
    return "peer"


#: the peer device whose lanes keep the legacy un-numbered names — the
#: 2-device presets put their single peer at device 1, so pre-topology
#: metrics keys (``q.peer_in.*``) and goldens stay stable
LEGACY_PEER_DEVICE = 1


class _LaneKeys:
    """Pre-interned per-lane metrics keys (one instance per lane, built on
    first submission) — the hot submit/drain paths index counters through
    these instead of re-formatting ``f"q.{ch}.*"`` strings per event."""
    __slots__ = ("submitted", "first_issue_t", "busy_s", "last_ready_t",
                 "depth", "peak", "completed")

    def __init__(self, ch: str):
        self.submitted = f"q.{ch}.submitted"
        self.first_issue_t = f"q.{ch}.first_issue_t"
        self.busy_s = f"q.{ch}.busy_s"
        self.last_ready_t = f"q.{ch}.last_ready_t"
        self.depth = f"q.{ch}.depth"
        self.peak = f"q.{ch}.peak"
        self.completed = f"q.{ch}.completed"


def channel_name(src: Tier, dst: Tier, device: Optional[int] = None,
                 host: int = 0) -> str:
    """Directional lane of a physical link, per peer device.

    NVLink / ICI / PCIe are full duplex: writes out of local HBM
    (evictions) and reads into local HBM (reloads) move on opposite
    directions of the same link and do not contend with each other.  Each
    direction serialises its own FIFO queue.  Peer links are additionally
    *per device*: transfers touching peer device ``d`` ride
    ``peer{d}_in``/``peer{d}_out`` so distinct peers never queue behind
    each other; device :data:`LEGACY_PEER_DEVICE` (and transfers naming no
    device) keep the legacy ``peer_in``/``peer_out`` names.  The host path
    is one physical PCIe link regardless of which peer is involved.

    A nonzero ``host`` places the peer device on a REMOTE host: the
    transfer rides that host's shared ``dcn{h}_in``/``dcn{h}_out`` lane
    pair instead of a per-device lane — there is one DCN NIC pair per host
    pair, so a remote host's devices contend for it while distinct remote
    hosts still pipeline in parallel.
    """
    base = _link_name(src, dst)
    if base == "hbm":
        return base
    if base == "peer":
        if host:
            base = f"dcn{host}"
        elif device is not None and device != LEGACY_PEER_DEVICE:
            base = f"peer{device}"
    return f"{base}_in" if dst is Tier.LOCAL_HBM else f"{base}_out"


class TransferEngine:
    """Single source of truth for simulated transfer times.

    Every tier move in the system is minted here, so per-link byte/time
    accounting lands in one metrics namespace instead of three stats dicts.

    The engine also owns the *simulated transfer timeline*: a clock
    (``now``) plus one FIFO queue per directional link lane.  ``submit``
    enqueues a minted transfer (stamping ``issue_t``/``ready_t`` from the
    lane's busy-until time and any in-flight transfer of the same key) and
    ``drain_until`` advances the clock, completing everything whose
    ``ready_t`` has passed.  The legacy :meth:`schedule` — a pure
    pre-summed-seconds reduction — is kept as the sync-mode compat wrapper
    and is what the seed-equivalence goldens exercise.
    """

    def __init__(self, hardware: HardwareModel,
                 metrics: Optional[MetricsRegistry] = None,
                 topology: Optional[Topology] = None):
        self.hw = topology.hardware if (hardware is None and topology) \
            else hardware
        self.topology = topology
        self.metrics = metrics or MetricsRegistry()
        self._stats = self.metrics.counters("transfer")
        self.now: float = 0.0
        self._channel_busy: Dict[str, float] = {}
        self._inflight: Dict[str, "collections.deque[Transfer]"] = {}
        self._key_busy: Dict[ObjectKey, Transfer] = {}
        self._batch_seq: int = 0
        # hot-path caches: routed LinkSpec per (src, dst, device) and
        # pre-interned metrics keys per lane / per (client, link) — the
        # per-event f-string formatting showed up hot in the 1M-request
        # sweeps (the keys are invariant per lane, only the counts change)
        self._spec_cache: Dict[Tuple, "object"] = {}
        self._lane_keys: Dict[str, _LaneKeys] = {}
        self._client_keys: Dict[Tuple[str, str], Tuple[str, str, str]] = {}
        # opt-in submit log (benchmarks reconstruct exact per-lane busy
        # intervals from it; off by default — it grows without bound)
        self.record_log: bool = False
        self.log: List[Transfer] = []

    def lane_of(self, t: Transfer) -> str:
        """The directional lane a pending transfer will occupy: its forced
        ``lane`` (stripe sub-lanes) or the routed one.  The single routing
        rule shared by submission, coalescing and reload-plan grouping."""
        return t.lane or self.lane_for(t.src, t.dst, t.device)

    def lane_for(self, src: Tier, dst: Tier,
                 device: Optional[int] = None) -> str:
        """The directional lane a (src, dst, device) transfer occupies.

        Per-device peer lanes exist only when an interconnect topology is
        attached AND the device is one of its peers: a flat
        :class:`HardwareModel` declares ONE peer link, so every peer
        transfer keeps the legacy single lane pair no matter how callers
        number their devices.  A peer device the topology places on a
        remote host routes to that host's shared ``dcn{h}`` lane pair.
        """
        host = 0
        if self.topology is None or device not in self.topology.peer_links:
            device = None
        elif self.topology.device_hosts:
            host = self.topology.host_of(device)
        return channel_name(src, dst, device, host)

    def link_spec(self, src: Tier, dst: Tier,
                  device: Optional[int] = None):
        """The :class:`~repro.core.tiers.LinkSpec` a (src, dst, device)
        transfer is charged against — the coalescing/striping layer reads
        its setup ``latency`` and link-disjoint ``paths`` from here.
        Routed specs are cached per (src, dst, device): the topology is
        immutable, and the repeated ``link()`` dict walks (plus the fresh
        hbm LinkSpec it constructs) showed up hot in the sweep loops."""
        ck = (src, dst, device)
        spec = self._spec_cache.get(ck)
        if spec is None:
            spec = (self.topology.link(src, dst, device)
                    if self.topology is not None
                    else self.hw.link(src, dst))
            self._spec_cache[ck] = spec
        return spec

    def estimate(self, nbytes: int, src: Tier, dst: Tier,
                 device: Optional[int] = None,
                 fidelity: Optional[Fidelity] = None) -> float:
        """Link time of a hypothetical transfer (no accounting) — the
        topology's per-device link when one is attached and named.
        ``fidelity`` scales ``nbytes`` (a full-precision object size) down
        to the wire size that precision actually moves."""
        if fidelity is not None:
            nbytes = fidelity.wire_bytes(nbytes)
        return self.link_spec(src, dst, device).transfer_time(nbytes)

    def transfer(self, key: ObjectKey, nbytes: int, src: Tier, dst: Tier,
                 extra_latency: float = 0.0, client: str = "default",
                 device: Optional[int] = None,
                 fidelity: Optional[Fidelity] = None) -> Transfer:
        """Mint a pending transfer of a full-precision-size-``nbytes``
        object.  A quantized ``fidelity`` moves (and accounts) only the
        wire bytes of that precision; FP16 (the default) is byte-exact
        with the seed accounting."""
        fid = fidelity or Fidelity.FP16
        wire = fid.wire_bytes(nbytes)
        seconds = self.estimate(wire, src, dst, device) + extra_latency
        link = _link_name(src, dst)
        ks = self._client_keys.get((client, link))
        if ks is None:
            ks = (f"{client}.{link}_s", f"{client}.{link}_n",
                  f"{client}.{link}_bytes")
            self._client_keys[(client, link)] = ks
        self._stats[ks[0]] += seconds
        self._stats[ks[1]] += 1
        self._stats[ks[2]] += wire
        return Transfer(key, src, dst, wire, seconds, client=client,
                        device=device, fidelity=fid)

    def schedule(self, transfers: Iterable[Transfer],
                 overlap_links: bool = False) -> float:
        """Total wall time for a batch of transfers (sync compat path).

        Default is serial issue (one DMA queue — matches the engine's
        original accounting).  With ``overlap_links`` the batch is grouped
        by physical link (peer ICI/NVLink vs host PCIe): each link
        serialises its own transfers, distinct links run concurrently.
        The event-driven path (:meth:`submit` + :meth:`drain_until`)
        supersedes this for async clients.
        """
        if not overlap_links:
            return float(sum(t.seconds for t in transfers))
        per_link: Dict[str, float] = {}
        for t in transfers:
            link = _link_name(t.src, t.dst)
            per_link[link] = per_link.get(link, 0.0) + t.seconds
        return max(per_link.values(), default=0.0)

    def overlap(self, compute_s: float, transfer_s: float,
                enabled: bool = True) -> float:
        """CGOPipe-style overlap: transfers hide under compute when enabled."""
        return max(compute_s, transfer_s) if enabled else compute_s + transfer_s

    # ------------------------------------------------------------- timeline
    def _enqueue(self, t: Transfer, ch: str, lane_s: float,
                 start: float) -> Transfer:
        """Place a pending transfer on lane ``ch`` occupying ``lane_s``
        seconds from ``start``.  Shared by the solo, coalesced and striped
        submission paths; per-lane FIFO order is preserved because every
        caller derives ``start`` from the lane's busy-until time."""
        t.channel = ch
        t.issue_t = self.now
        t.lane_s = lane_s
        t.ready_t = start + lane_s
        t.done = False
        self._channel_busy[ch] = t.ready_t
        self._key_busy[t.dep_key] = t
        q = self._inflight.setdefault(ch, collections.deque())
        q.append(t)
        if self.record_log:
            self.log.append(t)
        ks = self._lane_keys.get(ch)
        if ks is None:
            ks = self._lane_keys[ch] = _LaneKeys(ch)
        stats = self._stats
        if not stats[ks.submitted]:
            stats[ks.first_issue_t] = t.issue_t
        stats[ks.submitted] += 1
        stats[ks.busy_s] += lane_s
        stats[ks.last_ready_t] = t.ready_t
        stats[ks.depth] = len(q)
        if len(q) > stats[ks.peak]:
            stats[ks.peak] = len(q)
        return t

    def submit(self, t: Transfer, not_before: float = 0.0) -> Transfer:
        """Enqueue a pending transfer on its directional link lane.

        The transfer starts once the lane is free AND any in-flight
        transfer of the same key has completed (a reload of a block whose
        eviction write-back is still on the wire must wait for it), and
        becomes ready ``seconds`` later.  Per-lane FIFO order is preserved
        by construction: ``ready_t`` is non-decreasing within a lane.

        ``not_before`` floors the start time at a future production event
        the payload waits on that is NOT itself a transfer — e.g. a
        disaggregated prefill chunk finishing on its pool worker before
        its KV blocks can enter the DCN stream.
        """
        ch = self.lane_of(t)
        start = max(self.now, not_before, self._channel_busy.get(ch, 0.0))
        dep = self._key_busy.get(t.dep_key)
        if dep is not None and not dep.done:
            start = max(start, dep.ready_t)
        return self._enqueue(t, ch, t.seconds, start)

    def submit_coalesced(self, members: Iterable[Transfer],
                         not_before: float = 0.0) -> List[Transfer]:
        """Submit same-lane transfers as ONE batched lane occupancy.

        The batch pays the lane's per-transfer setup latency once (the
        simulated analogue of a single multi-slot ``harvest_gather`` call):
        member 0 keeps its full ``seconds``; every later member occupies
        only its bytes time.  Completion still resolves per member —
        ``ready_t`` is stamped at each member's cumulative byte boundary,
        so a waiter on one object never waits for the whole batch's tail.

        ``not_before`` floors the batch start at a production event that
        is not itself a transfer (a disaggregated prefill chunk finishing
        on another host), exactly like :meth:`submit`'s floor.

        Members that route to a different lane, carry a different wire
        fidelity (one batched submission models one fused gather kernel
        call, and one kernel packs one dtype), or whose object has an
        unresolved in-flight transfer (same-key ordering), fall back to
        the solo :meth:`submit` path — a dependency must not stall the
        batch.
        """
        members = list(members)
        if not members:
            return []
        out: List[Transfer] = []
        ch = self.lane_of(members[0])
        fid = members[0].fidelity
        batched: List[Transfer] = []
        solo: List[Transfer] = []
        for t in members:
            lane_t = self.lane_of(t)
            dep = self._key_busy.get(t.dep_key)
            if (lane_t != ch or t.fidelity is not fid
                    or (dep is not None and not dep.done)):
                solo.append(t)
            else:
                batched.append(t)
        # the batch goes FIRST: a dependency-blocked member would otherwise
        # head-of-line-block the lane's FIFO while it waits for its dep
        if len(batched) >= 2:
            setup = self.link_spec(batched[0].src, batched[0].dst,
                                   batched[0].device).latency
            self._batch_seq += 1
            start = max(self.now, not_before,
                        self._channel_busy.get(ch, 0.0))
            saved = 0.0
            for i, t in enumerate(batched):
                lane_s = t.seconds if i == 0 else max(t.seconds - setup, 0.0)
                saved += t.seconds - lane_s
                t.batch_id = self._batch_seq
                self._enqueue(t, ch, lane_s, start)
                start = t.ready_t
                out.append(t)
            self._stats[f"q.{ch}.coalesced"] += 1
            self._stats[f"q.{ch}.coalesced_members"] += len(batched)
            self._stats[f"q.{ch}.coalesced_saved_s"] += saved
        else:
            solo = batched + solo
        for t in solo:
            out.append(self.submit(t, not_before=not_before))
        return out

    def split(self, t: Transfer, ways: int, chunk_nbytes: int
              ) -> List[Transfer]:
        """Re-mint one pending transfer as chunk transfers striped across
        ``ways`` link-disjoint sub-lanes (``<lane>.s<k>``), each sustaining
        the link's per-path bandwidth.  The last chunk may be short — a
        non-divisible object size pads nothing and loses nothing.  The
        per-client link metrics are re-stated from the whole object to its
        chunks (bytes conserved; the chunk count replaces the single
        transfer count)."""
        link = self.link_spec(t.src, t.dst, t.device)
        ways = max(1, min(ways, link.paths))
        chunk_nbytes = max(1, chunk_nbytes)   # a 0-byte chunk never advances
        if ways <= 1 or t.nbytes <= chunk_nbytes:
            return [t]
        base = t.lane or self.lane_for(t.src, t.dst, t.device)
        path_bw = link.path_bandwidth
        extra = max(0.0, t.seconds - link.transfer_time(t.nbytes))
        chunks: List[Transfer] = []
        off = 0
        i = 0
        while off < t.nbytes:
            nb = min(chunk_nbytes, t.nbytes - off)
            chunks.append(Transfer(
                key=("~chunk", t.key, i), src=t.src, dst=t.dst, nbytes=nb,
                seconds=link.latency + nb / path_bw + (extra if i == 0 else 0),
                client=t.client, device=t.device, parent=t.key, offset=off,
                lane=f"{base}.s{i % ways}"))
            off += nb
            i += 1
        link_name = _link_name(t.src, t.dst)
        self._stats[f"{t.client}.{link_name}_s"] += \
            sum(c.seconds for c in chunks) - t.seconds
        self._stats[f"{t.client}.{link_name}_n"] += len(chunks) - 1
        self._stats[f"q.{base}.stripe_objects"] += 1
        self._stats[f"q.{base}.stripe_chunks"] += len(chunks)
        self._stats[f"q.{base}.stripe_ways"] = max(
            ways, self._stats[f"q.{base}.stripe_ways"])
        return chunks

    def submit_chunks(self, chunks: Iterable[Transfer]) -> List[Transfer]:
        """Striped submission: the chunks of ONE object ride their assigned
        sub-lanes concurrently, coalesced per sub-lane (one setup each).

        An in-flight transfer of the parent key (the object's eviction
        write-back) delays every chunk; afterwards the parent key maps to
        the LAST-finishing chunk, so a future same-key transfer chains on
        stripe completion, never on a partial prefix.
        """
        chunks = list(chunks)
        if not chunks:
            return []
        pkey = chunks[0].parent
        dep = self._key_busy.get(pkey)
        floor = dep.ready_t if (dep is not None and not dep.done) else self.now
        per_lane: Dict[str, List[Transfer]] = {}
        for t in chunks:
            per_lane.setdefault(t.lane, []).append(t)
        self._batch_seq += 1
        last: Optional[Transfer] = None
        for ch, members in per_lane.items():
            setup = self.link_spec(members[0].src, members[0].dst,
                                   members[0].device).latency
            start = max(floor, self.now, self._channel_busy.get(ch, 0.0))
            for i, t in enumerate(members):
                lane_s = t.seconds if i == 0 else max(t.seconds - setup, 0.0)
                t.batch_id = self._batch_seq
                self._enqueue(t, ch, lane_s, start)
                start = t.ready_t
                if last is None or t.ready_t > last.ready_t:
                    last = t
        self._key_busy[pkey] = last
        return chunks

    def drain_until(self, t: float) -> List[Transfer]:
        """Advance the clock to ``t`` (never backwards) and complete every
        in-flight transfer whose ``ready_t`` has passed.  Returns the
        completed transfers."""
        if t > self.now:
            self.now = t
        done: List[Transfer] = []
        key_busy, stats = self._key_busy, self._stats
        for ch, q in self._inflight.items():
            if not q or q[0].ready_t > self.now:
                continue
            ks = self._lane_keys[ch]
            while q and q[0].ready_t <= self.now:
                tr = q.popleft()
                tr.done = True
                if key_busy.get(tr.dep_key) is tr:
                    del key_busy[tr.dep_key]
                stats[ks.completed] += 1
                done.append(tr)
            stats[ks.depth] = len(q)
        return done

    def advance(self, seconds: float) -> List[Transfer]:
        """Let simulated time pass (a compute window) and drain."""
        return self.drain_until(self.now + seconds)

    def wait_for(self, transfers: Iterable[Transfer],
                 prefix_nbytes: Optional[int] = None) -> float:
        """Block the clock until every given transfer has completed;
        returns the new ``now``.  Already-complete transfers are free.

        ``prefix_nbytes`` is the chunk-granular completion contract of a
        striped reload: only the stripe chunks covering byte range
        ``[0, prefix_nbytes)`` of their parent object are waited on, so a
        consumer that needs an object's prefix resumes as soon as that
        prefix has landed.  Non-chunk transfers are always waited on.
        """
        target = self.now
        for t in transfers:
            if t.done:
                continue
            if (prefix_nbytes is not None and t.parent is not None
                    and t.offset >= prefix_nbytes):
                continue
            target = max(target, t.ready_t)
        if target > self.now:
            self.drain_until(target)
        return self.now

    def retarget(self, old: ObjectKey, new: ObjectKey) -> None:
        """Re-key the same-object ordering chain: a future transfer of
        ``new`` chains behind any in-flight transfer submitted under
        ``old`` (used by :meth:`HarvestStore.rekey` — a block published
        into the prefix trie keeps its in-flight write-back ordering)."""
        t = self._key_busy.pop(old, None)
        if t is None:
            return
        if t.parent is not None:
            t.parent = new
        else:
            t.key = new
        self._key_busy[new] = t

    def inflight_for(self, key: ObjectKey) -> Optional[Transfer]:
        """The in-flight transfer currently moving ``key`` (None when the
        object is quiescent).  A step that needs a block another path
        already submitted (a prefetch, an earlier resume) attaches to this
        transfer instead of double-submitting."""
        t = self._key_busy.get(key)
        return t if (t is not None and not t.done) else None

    def pending(self, channel: Optional[str] = None) -> int:
        """Number of in-flight transfers (optionally on one lane)."""
        if channel is not None:
            return len(self._inflight.get(channel, ()))
        return sum(len(q) for q in self._inflight.values())

    def channel_busy_until(self, channel: str) -> float:
        """Simulated time the lane's queue runs dry (>= ``now``)."""
        return max(self.now, self._channel_busy.get(channel, 0.0))

    def queue_depths(self) -> Dict[str, int]:
        return {ch: len(q) for ch, q in self._inflight.items() if q}


# ---------------------------------------------------------------------------
# residency table
# ---------------------------------------------------------------------------

_MISSING = object()   # rekey's "key absent from LRU" sentinel


@dataclass
class ObjectEntry:
    """One object's placement.  Clients may subclass to carry domain fields
    (the KV block table adds ``base_pos``/``filled``)."""
    state: Residency = Residency.HOST
    durability: Durability = Durability.BACKED
    local_slot: Optional[int] = None
    handle: Optional[HarvestHandle] = None   # live only while state is PEER
    host_copy: bool = False                  # an authoritative host copy exists
    hotness: float = 0.0                     # EWMA of client-defined heat
    pinned: bool = False                     # never evicted from local
    nbytes: int = 0                          # FULL-precision payload size
    #: precision of the DEMOTED copy (peer/host/SSD parking + wire format).
    #: The local slot always holds full precision: quantize-on-demote sets
    #: this, dequantize-on-reload clears it back to FP16.
    fidelity: Fidelity = Fidelity.FP16
    #: extra holders beyond the base owner (prefix-cache leases).  While
    #: positive, :meth:`HarvestStore.release` drops one reference instead
    #: of freeing — a retiring request can never free a block the trie (or
    #: another lessee) still reads.
    refcount: int = 0

    @property
    def tier(self) -> Optional[Tier]:
        return _RESIDENCY_TIER.get(self.state)


class HarvestStore:
    """Residency table + tier ladder for one client's object class.

    A store is parameterised by the client name (metrics namespace and
    allocator fairness tag), the default object size, an optional local
    slot pool (``num_local_slots=None`` means the local tier is unmanaged —
    e.g. pinned expert weights), and the default durability class.
    """

    #: every counter the store itself may bump — clients pre-seed a subset
    EVENTS = ("allocated", "freed", "evict_to_peer", "evict_to_host",
              "evict_to_ssd", "reload_peer", "reload_host", "reload_ssd",
              "revocations", "recomputes", "migrations", "demotions")

    #: pre-seeded ``fid.*`` counters (the fidelity-policy metrics contract)
    FID_KEYS = ("bytes_saved", "demote_quantized", "reload_dequantized",
                "quant_s", "dequant_s")

    def __init__(self, allocator: HarvestAllocator, transfers: TransferEngine,
                 *, client: str = "default", object_nbytes: int = 0,
                 num_local_slots: Optional[int] = None,
                 durability: Durability = Durability.BACKED,
                 store_payload: bool = False,
                 metrics: Optional[MetricsRegistry] = None,
                 owner_fn: Optional[Callable[[ObjectKey], Hashable]] = None,
                 entry_factory: Callable[..., ObjectEntry] = ObjectEntry,
                 stat_keys: Iterable[str] = (),
                 ssd_tier: bool = False,
                 host_capacity_bytes: Optional[int] = None):
        self.allocator = allocator
        self.transfers = transfers
        self.client = client
        self.object_nbytes = object_nbytes
        self.durability = durability
        self.entry_factory = entry_factory
        #: cold tier below host: RECONSTRUCTIBLE evictions that find no
        #: peer room park on local NVMe instead of paying for host DRAM,
        #: and BACKED write-backs overflow to it once ``host_capacity_bytes``
        #: is exhausted.  Off by default — the seed ladder is unchanged.
        self.ssd_tier = ssd_tier
        self.host_capacity_bytes = host_capacity_bytes
        # owners group keys for pinning / bulk eviction / bulk release; the
        # default matches (request_id, block_idx)-style composite keys
        self.owner_fn = owner_fn or (
            lambda k: k[0] if isinstance(k, tuple) else k)
        self.stats = (metrics or transfers.metrics).counters(
            client, keys=stat_keys)

        self.table: Dict[ObjectKey, ObjectEntry] = {}
        self.lru: "collections.OrderedDict[ObjectKey, None]" = \
            collections.OrderedDict()
        self.num_local_slots = num_local_slots
        self.free_slots: List[int] = (
            list(range(num_local_slots)) if num_local_slots is not None else [])
        self.pinned_owners: Set = set()

        self.store_payload = store_payload
        self._payload: Dict[ObjectKey, np.ndarray] = {}
        #: optional :class:`~repro.core.coalesce.TransferPlanner` — when
        #: attached (HarvestRuntime built with a CoalesceConfig), the
        #: placement methods emit *plans*: large objects leave as chunk
        #: transfers striped over link-disjoint sub-lanes, and callers hand
        #: whole step plans back to the planner for same-lane batching.
        #: None (default) keeps the seed-exact loose-transfer path.
        self.planner = None
        # policy hooks: called with (key, local_slot) so the embedding layer
        # (e.g. the serving engine's pool arrays) can move real payloads
        # alongside the placement
        self.evict_hook: Optional[Callable[[ObjectKey, int], None]] = None
        self.reload_hook: Optional[Callable[[ObjectKey, int], None]] = None
        #: fidelity policy hook: maps an object key to the
        #: :class:`~repro.core.tiers.Fidelity` its demoted copy travels at.
        #: None (default) keeps every demotion at FP16 — the seed-exact
        #: path.  Set by the serving engine from its per-SLO
        #: :class:`~repro.core.policy.FidelityPolicy`.
        self.fidelity_fn: Optional[Callable[[ObjectKey], Fidelity]] = None
        self.fid_stats = (metrics or transfers.metrics).counters(
            "fid", keys=self.FID_KEYS)

    def _prepare(self, ops: List[Transfer]) -> List[Transfer]:
        """Planner pass over freshly minted transfers (striping); identity
        when no planner is attached — the compat path."""
        return ops if self.planner is None else self.planner.prepare(ops)

    # ------------------------------------------------------------ lifecycle
    def register(self, key: ObjectKey, *, state: Residency = Residency.HOST,
                 durability: Optional[Durability] = None,
                 nbytes: Optional[int] = None, pinned: bool = False,
                 **extra) -> ObjectEntry:
        """Track an object that already exists in some tier (no transfer)."""
        assert key not in self.table, f"object {key} already registered"
        durability = durability or self.durability
        ent = self.entry_factory(
            state=state, durability=durability,
            nbytes=self.object_nbytes if nbytes is None else nbytes,
            pinned=pinned,
            host_copy=(durability is Durability.BACKED
                       or state is Residency.HOST),
            **extra)
        self.table[key] = ent
        return ent

    def allocate_local(self, key: ObjectKey, *, nbytes: Optional[int] = None,
                       **extra) -> Tuple[int, List[Transfer]]:
        """Place a NEW object in a local slot, evicting LRU if needed."""
        assert key not in self.table, f"object {key} already allocated"
        assert self.num_local_slots is not None, \
            f"{self.client}: store has no managed local pool"
        ops: List[Transfer] = []
        if not self.free_slots:
            ops.extend(self._evict_one(exclude_owner=self.owner_fn(key)))
        slot = self.free_slots.pop()
        self.table[key] = self.entry_factory(
            state=Residency.LOCAL, durability=self.durability,
            nbytes=self.object_nbytes if nbytes is None else nbytes,
            local_slot=slot, **extra)
        self.lru[key] = None
        self.stats["allocated"] += 1
        return slot, self._prepare(ops)

    def incref(self, key: ObjectKey) -> int:
        """Add one shared reference (a prefix-cache lease).  Each
        :meth:`release` drops one reference before any actual free."""
        ent = self.table[key]
        ent.refcount += 1
        return ent.refcount

    def release(self, key: ObjectKey) -> bool:
        """Drop one reference; free the object only when none remain.

        Unshared objects (``refcount == 0``, the default) free
        immediately — the legacy semantics.  Shared objects decrement and
        stay tracked, so a retiring owner can never free a block the
        prefix trie or another lessee still references.  Returns True iff
        the object was actually freed."""
        ent = self.table[key]
        if ent.refcount > 0:
            ent.refcount -= 1
            self.stats["ref_drops"] += 1
            return False
        self.table.pop(key)
        if ent.state is Residency.LOCAL and self.num_local_slots is not None:
            self.free_slots.append(ent.local_slot)
        elif ent.state is Residency.PEER and ent.handle is not None:
            self.allocator.harvest_free(ent.handle)
        self.lru.pop(key, None)
        self._payload.pop(key, None)
        self.stats["freed"] += 1
        return True

    def release_owner(self, owner) -> None:
        for key in [k for k in self.table if self.owner_fn(k) == owner]:
            self.release(key)

    def rekey(self, old: ObjectKey, new: ObjectKey) -> ObjectEntry:
        """Transfer an entry to a new key in place: slot, handle, payload,
        LRU recency and any in-flight transfer follow the object.  This is
        how a retiring request's prompt block becomes a content-addressed
        prefix-cache block without moving a byte."""
        assert new not in self.table, f"rekey target {new} already tracked"
        ent = self.table.pop(old)
        self.table[new] = ent
        if self.lru.pop(old, _MISSING) is not _MISSING:
            self.lru[new] = None
        if old in self._payload:
            self._payload[new] = self._payload.pop(old)
        if ent.state is Residency.PEER and ent.handle is not None:
            # re-register the revocation callback under the new key — the
            # old closure would no-op against a key no longer in the table
            self.allocator.harvest_register_cb(
                ent.handle,
                lambda handle, key=new: self._on_revoked(key, handle.device))
        self.transfers.retarget(old, new)
        return ent

    # ------------------------------------------------------------- eviction
    def _evict_one(self, exclude_owner=None,
                   victim: Optional[ObjectKey] = None,
                   exclude_key: Optional[ObjectKey] = None) -> List[Transfer]:
        """Evict one local object down the ladder: peer first, host fallback.

        Victims from other owners are preferred; when only the excluded
        owner's objects remain local (single-request long-context), its LRU
        object other than ``exclude_key`` is evicted instead.
        """
        if victim is None:
            fallback = None
            for key in self.lru:
                ent = self.table[key]
                if (ent.state is not Residency.LOCAL or ent.pinned
                        or self.owner_fn(key) in self.pinned_owners):
                    continue
                if exclude_owner is None or self.owner_fn(key) != exclude_owner:
                    victim = key
                    break
                if fallback is None and key != exclude_key:
                    fallback = key
            if victim is None:
                victim = fallback
        if victim is None:
            raise RuntimeError(
                f"{self.client}: local pool exhausted — no evictable object")
        ent = self.table[victim]
        # the fidelity the demoted copy travels at is decided BEFORE the
        # evict hook fires: the embedding layer (the serving engine's
        # quantize-on-demote path) reads ``ent.fidelity`` to pick the
        # kernel that packs the payload out of the pool
        fid = Fidelity.FP16
        if self.fidelity_fn is not None:
            fid = self.fidelity_fn(victim) or Fidelity.FP16
        ent.fidelity = fid
        quant_s = 0.0
        if fid.is_quantized:
            # fused quantize_demote: one full-precision read pass over the
            # block through local HBM, charged on the same clock as the
            # eviction transfer it feeds
            quant_s = ent.nbytes / self.transfers.hw.hbm_bw
            self.fid_stats["demote_quantized"] += 1
            self.fid_stats["quant_s"] += quant_s
            self.fid_stats["bytes_saved"] += \
                ent.nbytes - fid.wire_bytes(ent.nbytes)
            self.fid_stats[f"demote_{fid.value}"] += 1
        if self.evict_hook is not None:
            self.evict_hook(victim, ent.local_slot)
        if self.num_local_slots is not None:
            self.free_slots.append(ent.local_slot)
        ent.local_slot = None
        self.lru.pop(victim, None)

        ops: List[Transfer] = []
        wire = fid.wire_bytes(ent.nbytes)
        # hints: "refs" marks shared prefix-cache blocks (hot trie
        # interiors) — placement policies steer them to stable peers,
        # because revoking a block many future requests would hit costs
        # more than revoking a private one.  A quantized block asks the
        # allocator for its WIRE size — half (int8/fp8) or a quarter
        # (int4) of the peer slot a full-precision block would take.
        h = self.allocator.harvest_alloc(
            wire, hints={"hot": ent.hotness, "refs": ent.refcount},
            client=self.client)
        if h is not None:
            ent.state = Residency.PEER
            ent.handle = h
            self.allocator.harvest_register_cb(
                h, lambda handle, key=victim: self._on_revoked(
                    key, handle.device))
            ops.append(self.transfers.transfer(
                victim, ent.nbytes, Tier.LOCAL_HBM, Tier.PEER_HBM,
                extra_latency=quant_s, client=self.client, device=h.device,
                fidelity=fid))
            self.stats["evict_to_peer"] += 1
            self.stats[f"dev{h.device}.evictions"] += 1
            if ent.durability is Durability.BACKED:
                ent.host_copy = True   # written back asynchronously
        elif self._ssd_rung(ent, wire):
            # cold tier: RECONSTRUCTIBLE objects get a durable option
            # cheaper than host DRAM (and strictly better than LOST);
            # BACKED write-backs land here once host capacity is spent
            ent.state = Residency.SSD
            ent.host_copy = False      # the SSD copy is the backing copy
            ops.append(self.transfers.transfer(
                victim, ent.nbytes, Tier.LOCAL_HBM, Tier.LOCAL_SSD,
                extra_latency=quant_s, client=self.client, fidelity=fid))
            self.stats["evict_to_ssd"] += 1
        else:
            ent.state = Residency.HOST
            ent.host_copy = True       # the host write IS the eviction
            ops.append(self.transfers.transfer(
                victim, ent.nbytes, Tier.LOCAL_HBM, Tier.HOST_DRAM,
                extra_latency=quant_s, client=self.client, fidelity=fid))
            self.stats["evict_to_host"] += 1
        return ops

    def _ssd_rung(self, ent: ObjectEntry, wire: int) -> bool:
        """Whether a peer-less eviction takes the SSD rung instead of host:
        RECONSTRUCTIBLE objects always do (they otherwise pay host DRAM
        for payloads the class declared droppable), BACKED objects only
        once the host budget is spent."""
        if not self.ssd_tier:
            return False
        if ent.durability is Durability.RECONSTRUCTIBLE:
            return True
        return (self.host_capacity_bytes is not None
                and self._host_wire_bytes() + wire > self.host_capacity_bytes)

    def _host_wire_bytes(self) -> int:
        """Wire bytes currently parked in HOST residency (the overflow
        meter for ``host_capacity_bytes``; async BACKED peer copies are
        not counted — they are shadows, not placements)."""
        return sum(e.fidelity.wire_bytes(e.nbytes)
                   for e in self.table.values()
                   if e.state is Residency.HOST)

    def evict_owner(self, owner) -> List[Transfer]:
        """Preemption support (paper §6.3): push ALL of an owner's local
        objects out to the peer/host tiers."""
        ops: List[Transfer] = []
        self.pinned_owners.discard(owner)
        for key in sorted(k for k in self.table if self.owner_fn(k) == owner):
            if self.table[key].state is Residency.LOCAL:
                ops.extend(self._evict_one(victim=key))
        return self._prepare(ops)

    # --------------------------------------------------------------- reload
    def ensure_local(self, key: ObjectKey) -> List[Transfer]:
        """Fetch-mode reload: make an object local (LRU-touch it either way)."""
        ent = self.table[key]
        self.lru.pop(key, None)
        self.lru[key] = None     # touch
        if ent.state is Residency.LOCAL:
            return []
        if ent.state is Residency.LOST:
            raise LostObjectError(
                f"{self.client}: object {key} was revoked without a host "
                "copy — the client must reconstruct it")
        ops: List[Transfer] = []
        slot = None
        if self.num_local_slots is not None:
            if not self.free_slots:
                ops.extend(self._evict_one(
                    exclude_owner=self.owner_fn(key), exclude_key=key))
            slot = self.free_slots.pop()
        src = ent.tier
        device = None
        if ent.state is Residency.PEER:
            self.stats["reload_peer"] += 1
            if ent.handle is not None:
                device = ent.handle.device
                self.stats[f"dev{device}.reloads"] += 1
                self.allocator.harvest_free(ent.handle)
                ent.handle = None
        elif ent.state is Residency.SSD:
            self.stats["reload_ssd"] += 1
        else:
            self.stats["reload_host"] += 1
        fid = ent.fidelity
        dequant_s = 0.0
        if fid.is_quantized:
            # fused dequantize_reload: one full-precision write pass back
            # into the local pool, charged on the reload's critical path
            dequant_s = ent.nbytes / self.transfers.hw.hbm_bw
            self.fid_stats["reload_dequantized"] += 1
            self.fid_stats["dequant_s"] += dequant_s
        ent.state = Residency.LOCAL
        ent.local_slot = slot
        if self.reload_hook is not None:
            # the hook runs while ``ent.fidelity`` still names the wire
            # precision — the embedding layer picks its dequantize kernel
            # from it
            self.reload_hook(key, slot)
        ops.append(self.transfers.transfer(
            key, ent.nbytes, src, Tier.LOCAL_HBM, extra_latency=dequant_s,
            client=self.client, device=device, fidelity=fid))
        ent.fidelity = Fidelity.FP16   # the local slot holds full precision
        return self._prepare(ops)

    # ------------------------------------------------------ promote / demote
    def promote_to_peer(self, key: ObjectKey):
        """Migrate a host-resident object into peer HBM (background path —
        the move is not charged to any request's critical path).  Returns
        the pending transfer (truthy) so timeline clients can ``submit``
        it, or None when the object is not promotable.  With a planner
        attached the promotion is emitted as a *plan* — a (possibly
        chunk-striped) transfer list — instead of one loose transfer."""
        ent = self.table[key]
        if ent.state is not Residency.HOST:
            return None
        h = self.allocator.harvest_alloc(
            ent.fidelity.wire_bytes(ent.nbytes),
            hints={"hot": ent.hotness, "refs": ent.refcount},
            client=self.client)
        if h is None:
            return None
        self.allocator.harvest_register_cb(
            h, lambda handle, key=key: self._on_revoked(key, handle.device))
        ent.state = Residency.PEER
        ent.handle = h
        if ent.durability is Durability.RECONSTRUCTIBLE:
            ent.host_copy = False   # the class does not pay for host backing
        op = self.transfers.transfer(key, ent.nbytes, Tier.HOST_DRAM,
                                     Tier.PEER_HBM, client=self.client,
                                     device=h.device, fidelity=ent.fidelity)
        self.stats["migrations"] += 1
        self.stats[f"dev{h.device}.migrations"] += 1
        return op if self.planner is None else self._prepare([op])

    def demote(self, key: ObjectKey) -> None:
        """Voluntarily release a peer-resident object back to host."""
        ent = self.table[key]
        if ent.state is Residency.PEER and ent.handle is not None:
            self.allocator.harvest_free(ent.handle)
            ent.state = Residency.HOST
            ent.handle = None
            ent.host_copy = True    # the demotion write re-materialises it
            self.stats["demotions"] += 1

    def pin(self, key: ObjectKey, pinned: bool = True) -> None:
        self.table[key].pinned = pinned

    # ------------------------------------------------------------ revocation
    def _on_revoked(self, key: ObjectKey,
                    device: Optional[int] = None) -> None:
        ent = self.table.get(key)
        if ent is None or ent.state is not Residency.PEER:
            return
        ent.handle = None
        self.stats["revocations"] += 1
        if device is not None:
            self.stats[f"dev{device}.revocations"] += 1
        if ent.host_copy:
            ent.state = Residency.HOST    # transparent fallback (BACKED)
        else:
            ent.state = Residency.LOST    # explicit loss (RECONSTRUCTIBLE)
            self.stats["recomputes"] += 1
            self._payload.pop(key, None)

    # -------------------------------------------------------------- hotness
    def touch_hotness(self, key: ObjectKey, sample: float,
                      alpha: float) -> None:
        """EWMA-update an object's heat: h <- alpha*h + (1-alpha)*sample."""
        ent = self.table[key]
        ent.hotness = alpha * ent.hotness + (1 - alpha) * sample

    def hottest(self, state: Residency, limit: Optional[int] = None
                ) -> List[Tuple[ObjectKey, ObjectEntry]]:
        cand = [(k, e) for k, e in self.table.items() if e.state is state]
        cand.sort(key=lambda kv: -kv[1].hotness)
        return cand if limit is None else cand[:limit]

    # -------------------------------------------------------------- queries
    def device_of(self, key: ObjectKey) -> Optional[int]:
        """Peer device an object's payload lives on (None unless PEER)."""
        ent = self.table.get(key)
        if ent is None or ent.handle is None:
            return None
        return ent.handle.device

    def is_lost(self, key: ObjectKey) -> bool:
        ent = self.table.get(key)
        return ent is not None and ent.state is Residency.LOST

    def tier_counts(self) -> Dict[str, int]:
        out = {r.value: 0 for r in Residency}
        for ent in self.table.values():
            out[ent.state.value] += 1
        return out

    def fidelity_counts(self) -> Dict[str, int]:
        """Tracked objects per demoted-copy fidelity (LOCAL objects are
        full precision by construction and count under fp16)."""
        out = {f.value: 0 for f in Fidelity}
        for ent in self.table.values():
            out[ent.fidelity.value] += 1
        return out

    def owner_keys(self, owner) -> List[ObjectKey]:
        return sorted(k for k in self.table if self.owner_fn(k) == owner)

    def residency_of(self, owner) -> List[Optional[Tier]]:
        return [self.table[k].tier for k in self.owner_keys(owner)]

    # -------------------------------------------------------------- payloads
    def write_payload(self, key: ObjectKey, data: np.ndarray) -> None:
        if self.store_payload:
            self._payload[key] = np.asarray(data)

    def read_payload(self, key: ObjectKey) -> Optional[np.ndarray]:
        return self._payload.get(key)
