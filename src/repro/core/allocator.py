"""The Harvest runtime: opportunistic peer-memory allocation with revocation.

Implements the paper's API (§3.2):

    harvest_alloc(size, hints)   -> HarvestHandle | None
    harvest_free(handle)
    harvest_register_cb(handle, cb)

A controller (:class:`HarvestAllocator`) tracks the *harvestable* byte budget
of every peer device, hands out segments from a per-device free list, and —
when external pressure shrinks a device's budget — revokes allocations in a
strict drain -> invalidate -> notify order.  Correctness never depends on a
peer allocation surviving: callers keep an authoritative copy (weights) or
reconstruct (KV/recurrent state).

On CUDA the handle wraps a device pointer; functionally in JAX it names a
(device, offset, size) region that higher layers map to pool-array slots.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.policy import BestFitPolicy, PlacementPolicy, PlacementRequest


@dataclass(frozen=True)
class HarvestHandle:
    """(device, offset, size) — the unique id of a peer allocation."""
    handle_id: int
    device: int
    offset: int
    size: int
    client: str = "default"

    @property
    def key(self) -> Tuple[int, int, int]:
        return (self.device, self.offset, self.size)


class RevokedError(RuntimeError):
    pass


@dataclass
class _FreeList:
    """Address-ordered free list with first/best-fit and coalescing."""
    capacity: int
    segments: List[Tuple[int, int]] = field(default_factory=list)  # (off, size)

    def __post_init__(self):
        if not self.segments:
            self.segments = [(0, self.capacity)]

    def best_fit(self, size: int) -> Optional[int]:
        best = None
        for off, seg in self.segments:
            if seg >= size and (best is None or seg < best[1]):
                best = (off, seg)
        if best is None:
            return None
        off, seg = best
        self.segments.remove((off, seg))
        if seg > size:
            self.segments.append((off + size, seg - size))
            self.segments.sort()
        return off

    def release(self, off: int, size: int) -> None:
        """Return ``[off, off+size)`` to the free list.

        A release that overlaps an already-free segment is a double free
        (or a size/offset corruption): silently coalescing it would
        fabricate free bytes and let a later allocation alias a live
        segment, so it is rejected loudly instead.
        """
        if size <= 0 or off < 0 or off + size > self.capacity:
            raise ValueError(
                f"release of [{off}, {off + size}) outside freelist "
                f"capacity {self.capacity}")
        for o, s in self.segments:
            if off < o + s and o < off + size:
                raise ValueError(
                    f"double free: released segment [{off}, {off + size}) "
                    f"overlaps free segment [{o}, {o + s})")
        self.segments.append((off, size))
        self.segments.sort()
        merged: List[Tuple[int, int]] = []
        for o, s in self.segments:
            if merged and merged[-1][0] + merged[-1][1] == o:
                merged[-1] = (merged[-1][0], merged[-1][1] + s)
            else:
                merged.append((o, s))
        self.segments = [(o, s) for o, s in merged]

    @property
    def free_bytes(self) -> int:
        return sum(s for _, s in self.segments)

    @property
    def largest_free(self) -> int:
        return max((s for _, s in self.segments), default=0)

    def fragmentation(self) -> float:
        free = self.free_bytes
        return 0.0 if free == 0 else 1.0 - self.largest_free / free


@dataclass
class _Device:
    device_id: int
    budget: int                       # harvestable bytes (can shrink/grow)
    freelist: _FreeList = None        # sized to max budget; shrink = revoke
    used: int = 0
    churn: float = 0.0                # EWMA of |budget delta| (stability policy)

    def __post_init__(self):
        if self.freelist is None:
            self.freelist = _FreeList(self.budget)


class HarvestAllocator:
    """Controller for opportunistic peer HBM allocation."""

    #: stats counter names (one namespace in the runtime's MetricsRegistry)
    STAT_KEYS = ("allocs", "failed", "revocations", "frees")

    def __init__(self, device_budgets: Dict[int, int],
                 policy: Optional[PlacementPolicy] = None,
                 metrics=None):
        self._devices: Dict[int, _Device] = {
            d: _Device(d, b) for d, b in device_budgets.items()}
        self._policy = policy or BestFitPolicy()
        self._handles: Dict[int, HarvestHandle] = {}
        self._cbs: Dict[int, Callable[[HarvestHandle], None]] = {}
        self._alloc_order: List[int] = []        # handle ids, oldest first
        self._inflight: Dict[int, int] = {}      # handle -> outstanding DMA ops
        self._ids = itertools.count(1)
        # `metrics` is a MetricsRegistry (duck-typed to avoid an import cycle
        # with repro.core.store); standalone allocators keep a plain dict
        if metrics is not None:
            self.stats = metrics.counters("allocator", keys=self.STAT_KEYS)
        else:
            self.stats = {k: 0 for k in self.STAT_KEYS}

    @property
    def policy(self) -> PlacementPolicy:
        """The live placement policy (the stability controller tunes its
        churn appetite through this)."""
        return self._policy

    # ---------------------------------------------------------------- API
    def harvest_alloc(self, size: int, hints: Optional[dict] = None,
                      client: str = "default") -> Optional[HarvestHandle]:
        hints = hints or {}
        req = PlacementRequest(size=size, client=client, hints=hints)
        order = self._policy.rank(self._snapshot(), req)
        for dev_id in order:
            dev = self._devices[dev_id]
            if dev.budget - dev.used < size:
                continue
            off = dev.freelist.best_fit(size)
            if off is None:
                continue
            h = HarvestHandle(next(self._ids), dev_id, off, size, client)
            dev.used += size
            self._handles[h.handle_id] = h
            self._alloc_order.append(h.handle_id)
            self._policy.on_alloc(req, dev_id)
            self.stats["allocs"] += 1
            return h
        self.stats["failed"] += 1
        return None

    def harvest_free(self, handle: HarvestHandle) -> None:
        if handle.handle_id not in self._handles:
            raise RevokedError(f"handle {handle.handle_id} already revoked/freed")
        self._release(handle)
        self.stats["frees"] += 1

    def harvest_register_cb(self, handle: HarvestHandle,
                            cb: Callable[[HarvestHandle], None]) -> None:
        if handle.handle_id not in self._handles:
            raise RevokedError(f"handle {handle.handle_id} already revoked/freed")
        self._cbs[handle.handle_id] = cb

    # ----------------------------------------------------- DMA bookkeeping
    def begin_io(self, handle: HarvestHandle) -> None:
        self._inflight[handle.handle_id] = self._inflight.get(handle.handle_id, 0) + 1

    def end_io(self, handle: HarvestHandle) -> None:
        n = self._inflight.get(handle.handle_id, 0) - 1
        if n <= 0:
            self._inflight.pop(handle.handle_id, None)
        else:
            self._inflight[handle.handle_id] = n

    # ------------------------------------------------------- availability
    def update_budget(self, device_id: int, new_budget: int) -> List[HarvestHandle]:
        """External pressure changed a device's harvestable budget.

        If current usage exceeds the new budget, revoke allocations (newest
        first) until usage fits.  Returns the revoked handles (callbacks have
        already fired, post-drain, in revocation order).
        """
        dev = self._devices[device_id]
        dev.churn = 0.9 * dev.churn + 0.1 * abs(new_budget - dev.budget)
        dev.budget = new_budget
        revoked = []
        if dev.used > dev.budget:
            for hid in reversed(list(self._alloc_order)):
                if dev.used <= dev.budget:
                    break
                h = self._handles.get(hid)
                if h is None or h.device != device_id:
                    continue
                self._revoke(h)
                revoked.append(h)
        return revoked

    def _revoke(self, handle: HarvestHandle) -> None:
        # 1. drain in-flight DMA/kernels touching the region
        self._drain(handle)
        # 2. invalidate the placement entry
        cb = self._cbs.pop(handle.handle_id, None)
        self._release(handle)
        self.stats["revocations"] += 1
        self._bump(f"dev{handle.device}.revocations")
        # 3. notify the application
        if cb is not None:
            cb(handle)

    def _bump(self, key: str) -> None:
        # per-device keys are open-ended; standalone allocators keep a plain
        # dict, so seed on first use instead of relying on Counters
        self.stats[key] = self.stats.get(key, 0) + 1

    def _drain(self, handle: HarvestHandle) -> None:
        # Functional stand-in for stream/event synchronisation: revocation is
        # not allowed to complete while IO on the region is outstanding.
        if self._inflight.get(handle.handle_id):
            raise RuntimeError(
                f"revoking handle {handle.handle_id} with in-flight IO; "
                "callers must end_io() (stream-sync) before the runtime ticks")

    def _release(self, handle: HarvestHandle) -> None:
        dev = self._devices[handle.device]
        dev.freelist.release(handle.offset, handle.size)
        dev.used -= handle.size
        del self._handles[handle.handle_id]
        self._cbs.pop(handle.handle_id, None)
        self._alloc_order.remove(handle.handle_id)

    # ------------------------------------------------------------ queries
    def _snapshot(self) -> Dict[int, dict]:
        return {
            d.device_id: {
                "free": d.budget - d.used,
                "used": d.used,
                "largest_free": min(d.freelist.largest_free,
                                    max(d.budget - d.used, 0)),
                "fragmentation": d.freelist.fragmentation(),
                "churn": d.churn,
                "budget": d.budget,
            }
            for d in self._devices.values()
        }

    def live_handles(self) -> List[HarvestHandle]:
        return list(self._handles.values())

    def device_view(self) -> Dict[int, dict]:
        return self._snapshot()

    def is_live(self, handle: HarvestHandle) -> bool:
        return handle.handle_id in self._handles
