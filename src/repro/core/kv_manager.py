"""KVOffloadManager — the paged-KV client of :class:`HarvestStore` (§5).

Extends the vLLM-style paged KV manager with a *unified block table*: every
logical block maps to a residency entry in {local HBM, peer HBM, host DRAM}.
All residency mechanics — the LRU eviction ladder (peer first, host
fallback), revocation fallback, transfer-time accounting — live in the
generic store; this client only adds block-table semantics (per-request
block keys, fill tracking, payload shape) on top.  Durability is an
application choice:

  host_backed — eviction to peer ALSO materialises a host copy; revocation
                falls back to host transparently (paper's durable mode).
  lossy       — peer-only; revocation moves the block to the explicit LOST
                residency state and the request must recompute it (paper's
                reconstructible mode).

The manager tracks both the *placement* (bytes, any scale — used by the
dry-run and the simulator) and optionally the *payload* (real numpy block
arrays — used by the serving engine and tests).

Shared-block residency (PR 6, :mod:`repro.core.prefix_cache`): a request
may *adopt* a content-addressed trie block instead of allocating its own.
The mapping ``shared[(req, j)] -> content_key`` resolves the request's
logical block id onto the shared entry everywhere the manager touches the
table, the lease table guarantees at most ONE live request maps a shared
block at a time (the decode kernel's ``slot_req`` binds each pool slot to
a single batch row), and a second concurrent consumer gets a
copy-on-write split instead — shared blocks are never mutated and never
aliased into two rows.  ``free_request`` routes every release through the
store's refcount, so retiring can never free a block the trie or another
owner still references.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.allocator import HarvestAllocator
from repro.core.store import (Durability, HarvestStore, MetricsRegistry,
                              ObjectEntry, ObjectKey, Residency, Transfer,
                              TransferEngine)
from repro.core.tiers import HardwareModel, Tier, kv_block_bytes

BlockId = Tuple[int, int]    # (request_id, block_index_within_request)

#: back-compat alias — a reload op IS a store transfer
ReloadOp = Transfer

DURABILITY = {
    "host_backed": Durability.BACKED,
    "lossy": Durability.RECONSTRUCTIBLE,
}

KV_STAT_KEYS = ("evict_to_peer", "evict_to_host", "evict_to_ssd",
                "reload_peer", "reload_host", "reload_ssd", "revocations",
                "recomputes", "allocated", "freed", "ref_drops")


@dataclass
class BlockEntry(ObjectEntry):
    """Store entry + the block-table fields the decode path reads/writes."""
    base_pos: int = 0
    filled: int = 0                            # tokens written


@dataclass
class ReloadPlan:
    """One step's batched reload plan for a set of blocks.

    Built by :meth:`KVOffloadManager.plan_reloads`: duplicate keys submit
    once, blocks whose reload is already on the wire contribute the
    in-flight transfer (``attached``) instead of a double submission, and
    a LOST block stops the plan at that point so the caller can recompute
    the prefix — with everything planned before it still charged, exactly
    like the per-block loop it replaces.
    """
    ops: List[Transfer] = field(default_factory=list)      # to charge+submit
    touched: List[BlockId] = field(default_factory=list)   # now-local blocks
    attached: List[Transfer] = field(default_factory=list)  # in-flight waits
    lost: Optional[BlockId] = None          # first LOST block hit (if any)
    deduped: int = 0                        # repeated keys dropped

    def by_lane(self, engine: TransferEngine) -> Dict[str, List[Transfer]]:
        """The plan's transfers keyed by the directional link lane each
        occupies (``TransferEngine.lane_of`` — the same routing rule the
        coalescing layer batches over)."""
        out: Dict[str, List[Transfer]] = {}
        for t in self.ops:
            out.setdefault(engine.lane_of(t), []).append(t)
        return out


class KVOffloadManager:
    def __init__(self, cfg: ModelConfig, allocator: HarvestAllocator,
                 hardware: HardwareModel, block_size: int,
                 num_local_slots: int, durability: str = "host_backed",
                 store_payload: bool = False, num_kv_layers: int = 0,
                 client: str = "kv",
                 transfers: Optional[TransferEngine] = None,
                 metrics: Optional[MetricsRegistry] = None,
                 ssd_tier: bool = False,
                 host_capacity_bytes: Optional[int] = None):
        self.cfg = cfg
        self.allocator = allocator
        self.hw = hardware
        self.block_size = block_size
        self.block_nbytes = kv_block_bytes(cfg, block_size)
        self.durability = durability
        self.client = client
        self.num_local_slots = num_local_slots
        self.n_kv_layers = num_kv_layers
        self.store = HarvestStore(
            allocator, transfers or TransferEngine(hardware, metrics),
            client=client, object_nbytes=self.block_nbytes,
            num_local_slots=num_local_slots,
            durability=DURABILITY[durability], store_payload=store_payload,
            entry_factory=BlockEntry, stat_keys=KV_STAT_KEYS,
            ssd_tier=ssd_tier, host_capacity_bytes=host_capacity_bytes)
        #: shared-block residency: (req, block_idx) -> content key of the
        #: adopted prefix-cache block.  Resolved on every table access.
        self.shared: Dict[BlockId, "ObjectKey"] = {}
        #: content key -> the ONE request currently leasing it (slot_req
        #: maps each pool slot to a single batch row, so concurrent
        #: consumers must COW-split instead of double-leasing)
        self.lessee: Dict["ObjectKey", int] = {}

    # ------------------------------------------------------- store views
    @property
    def stats(self) -> Dict[str, int]:
        return self.store.stats

    @property
    def table(self) -> Dict[BlockId, BlockEntry]:
        return self.store.table

    @property
    def free_slots(self) -> List[int]:
        return self.store.free_slots

    @property
    def pinned(self) -> set:
        """Requests whose blocks must not be evicted this step (the decode
        working set — vLLM pins the active batch the same way)."""
        return self.store.pinned_owners

    @pinned.setter
    def pinned(self, owners) -> None:
        self.store.pinned_owners = set(owners)

    @property
    def evict_hook(self):
        return self.store.evict_hook

    @evict_hook.setter
    def evict_hook(self, fn) -> None:
        self.store.evict_hook = fn

    @property
    def reload_hook(self):
        return self.store.reload_hook

    @reload_hook.setter
    def reload_hook(self, fn) -> None:
        self.store.reload_hook = fn

    @property
    def fidelity_fn(self):
        return self.store.fidelity_fn

    @fidelity_fn.setter
    def fidelity_fn(self, fn) -> None:
        self.store.fidelity_fn = fn

    # ------------------------------------------------------------- alloc
    def allocate_block(self, req: int, block_idx: int, base_pos: int
                       ) -> Tuple[int, List[ReloadOp]]:
        """Get a local slot for a new block, evicting if necessary."""
        return self.store.allocate_local((req, block_idx), base_pos=base_pos)

    # ------------------------------------------------------ shared blocks
    def resolve(self, bid: BlockId) -> ObjectKey:
        """The store key a logical block id actually reads: its adopted
        content key when shared, else the id itself."""
        return self.shared.get(bid, bid)

    def adopt_block(self, req: int, block_idx: int, ckey: ObjectKey
                    ) -> List[ReloadOp]:
        """Lease a prefix-cache content block as this request's block
        ``block_idx`` — zero copy.  The entry is made local (the returned
        reloads are the ONLY cost a cache hit pays), pinned for the term
        of the lease (the decode read set must not churn mid-step), and
        its refcount incremented so no other owner's retire can free it.
        The caller must have checked :meth:`lessee_of` — double-leasing
        is a programming error (two batch rows cannot share a slot).
        """
        assert ckey not in self.lessee, \
            f"content block {ckey} already leased to request " \
            f"{self.lessee[ckey]} — COW-split instead"
        ops = self.store.ensure_local(ckey)
        self.store.incref(ckey)
        self.store.pin(ckey)
        self.lessee[ckey] = req
        self.shared[(req, block_idx)] = ckey
        return ops

    def lessee_of(self, ckey: ObjectKey) -> Optional[int]:
        """The request currently leasing a content block (None = free to
        adopt)."""
        return self.lessee.get(ckey)

    def cow_split(self, req: int, block_idx: int, ckey: ObjectKey
                  ) -> Tuple[int, List[ReloadOp], List[ReloadOp]]:
        """Copy-on-write split: materialise a private copy of a content
        block another live request is leasing.  Returns
        ``(slot, reload_ops, alloc_ops)`` — the reloads make the source
        local (critical path: this request's prefill reads it), the alloc
        ops are any eviction the private slot forced (write-back path).
        The engine copies the pool payload ``source slot -> slot``; the
        store payload (authoritative once evicted) is copied here so the
        private block survives its own eviction ladder independently.
        Shared blocks are never mutated: the split happens BEFORE any
        write could target the divergence block.
        """
        reload_ops = self.store.ensure_local(ckey)
        slot, alloc_ops = self.store.allocate_local(
            (req, block_idx), base_pos=block_idx * self.block_size)
        ent = self.table[(req, block_idx)]
        ent.filled = self.block_size
        src = self.store.read_payload(ckey)
        if src is not None:
            self.store.write_payload((req, block_idx), np.array(src))
        return slot, reload_ops, alloc_ops

    def release_leases(self, req: int) -> None:
        """Return every content block the request leases to the trie:
        unpin, drop the lease, and decrement the refcount (the store frees
        only when the trie no longer holds the block either)."""
        for bid in [b for b in self.shared if b[0] == req]:
            ckey = self.shared.pop(bid)
            if self.lessee.get(ckey) == req:
                del self.lessee[ckey]
            ent = self.store.table.get(ckey)
            if ent is not None:
                ent.pinned = False
                self.store.release(ckey)

    def free_request(self, req: int) -> None:
        """Release a request's blocks — through the refcount: leased
        content blocks drop one reference (never freed out from under the
        trie or a later lessee), private blocks free immediately."""
        self.release_leases(req)
        self.store.release_owner(req)

    # ----------------------------------------------------------- evict
    def evict_request(self, req: int) -> List[ReloadOp]:
        """Preemption support (paper §6.3): push ALL of a request's local
        blocks out to the peer/host tiers."""
        return self.store.evict_owner(req)

    # ----------------------------------------------------------- reload
    def ensure_resident(self, req: int, block_idx: int) -> List[ReloadOp]:
        """Fetch-mode reload: make a block local before the step."""
        return self.store.ensure_local(self.resolve((req, block_idx)))

    def plan_reloads(self, bids, seen: Optional[set] = None) -> ReloadPlan:
        """Batched reload plan for the blocks a step is about to read.

        Logical ids resolve through the shared-block map first (an adopted
        prefix block plans — and dedups — under its content key, and
        ``plan.touched`` carries the resolved key so the caller's
        slot/row mapping lands on the entry the kernel actually reads).
        Deduplicates repeated keys within the step (``seen`` may be shared
        across calls to extend the dedup window), attaches the in-flight
        transfer of any block that is already being moved — a block needed
        by both a prefetch and the critical path submits ONCE, with the
        critical waiter riding the existing transfer — and stops at the
        first LOST block (``plan.lost``) so the caller can recompute, with
        the ops planned before it still charged.
        """
        plan = ReloadPlan()
        seen = set() if seen is None else seen
        for bid in bids:
            bid = self.resolve(bid)
            if bid in seen:
                plan.deduped += 1
                self.stats["reload_deduped"] += 1
                continue
            seen.add(bid)
            if bid not in self.store.table:
                continue
            if self.store.is_lost(bid):
                plan.lost = bid
                break
            ops = self.store.ensure_local(bid)
            plan.ops.extend(ops)
            plan.touched.append(bid)
            if not ops:
                tr = self.store.transfers.inflight_for(bid)
                if tr is not None:
                    plan.attached.append(tr)
        return plan

    def is_lost(self, req: int, block_idx: int) -> bool:
        """True iff a lossy revocation dropped this block's payload."""
        return self.store.is_lost(self.resolve((req, block_idx)))

    def device_of(self, req: int, block_idx: int) -> Optional[int]:
        """Peer device a PEER-resident block lives on (else None)."""
        return self.store.device_of(self.resolve((req, block_idx)))

    # --------------------------------------------------------- prefetch
    def plan_prefetch(self, running, waiting=(), depth: int = 1
                      ) -> List[BlockId]:
        """Blocks the next steps will read that are not local yet.

        ``running`` is an iterable of ``(req_id, pos)`` pairs: for each, the
        candidates are the blocks covering the append boundary — block
        ``pos // block_size`` through ``depth`` blocks ahead — that already
        exist in the table (a resumed request may own non-local tail
        blocks).  ``waiting`` is an iterable of request ids about to be
        re-admitted (preempted requests next in scheduler order): their
        whole resident prefix is a candidate.  LOST blocks are excluded —
        they need recompute, not a transfer.  Candidates are ordered
        running-first (nearest deadline) and deduplicated; the
        :class:`~repro.core.prefetch.Prefetcher` applies slot and link
        budgets on top.
        """
        out: List[BlockId] = []
        seen: set = set()

        def consider(bid: BlockId) -> None:
            if bid in seen:
                return
            seen.add(bid)
            ent = self.store.table.get(bid)
            if ent is None or ent.state in (Residency.LOCAL, Residency.LOST):
                return
            out.append(bid)

        for req, pos in running:
            j0 = pos // self.block_size
            for j in range(j0, j0 + depth + 1):
                consider((req, j))
        for req in waiting:
            for bid in self.store.owner_keys(req):
                consider(bid)
        return out

    # ------------------------------------------------------------ queries
    def residency(self, req: int) -> List[Optional[Tier]]:
        return self.store.residency_of(req)

    def tier_counts(self) -> Dict[str, int]:
        return self.store.tier_counts()

    # --------------------------------------------------------- payloads
    def write_payload(self, req: int, block_idx: int, data: np.ndarray) -> None:
        self.store.write_payload((req, block_idx), data)

    def read_payload(self, req: int, block_idx: int) -> Optional[np.ndarray]:
        return self.store.read_payload((req, block_idx))
