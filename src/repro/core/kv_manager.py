"""KVOffloadManager — Harvest applied to the paged KV cache (paper §5).

Extends the vLLM-style paged KV manager with a *unified block table*: every
logical block maps to a residency entry in {local HBM, peer HBM, host DRAM}.
Under local-pool pressure, blocks evict to peer HBM when `harvest_alloc`
succeeds, else to host DRAM.  A reload brings a non-local block back before
(fetch mode) or during (in-place mode) the decode step.  Durability is an
application choice:

  host_backed — eviction to peer ALSO materialises a host copy; revocation
                falls back to host transparently (paper's durable mode).
  lossy       — peer-only; revocation drops the block and the request must
                recompute it (paper's reconstructible mode).

The manager tracks both the *placement* (bytes, any scale — used by the
dry-run and the simulator) and optionally the *payload* (real numpy block
arrays — used by the serving engine and tests).
"""
from __future__ import annotations

import collections
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.allocator import HarvestAllocator, HarvestHandle
from repro.core.tiers import HardwareModel, Tier, kv_block_bytes

BlockId = Tuple[int, int]    # (request_id, block_index_within_request)


@dataclass
class BlockEntry:
    tier: Tier
    local_slot: Optional[int] = None
    handle: Optional[HarvestHandle] = None     # peer tier
    host_copy: bool = False
    base_pos: int = 0
    filled: int = 0                            # tokens written


@dataclass
class ReloadOp:
    block: BlockId
    src: Tier
    seconds: float


class KVOffloadManager:
    def __init__(self, cfg: ModelConfig, allocator: HarvestAllocator,
                 hardware: HardwareModel, block_size: int,
                 num_local_slots: int, durability: str = "host_backed",
                 store_payload: bool = False, num_kv_layers: int = 0,
                 client: str = "kv"):
        self.cfg = cfg
        self.allocator = allocator
        self.hw = hardware
        self.block_size = block_size
        self.block_nbytes = kv_block_bytes(cfg, block_size)
        self.durability = durability
        self.client = client
        self.num_local_slots = num_local_slots
        self.free_slots = list(range(num_local_slots))
        self.lru = collections.OrderedDict()   # block -> None, LRU order
        self.table: Dict[BlockId, BlockEntry] = {}
        # requests whose blocks must not be evicted this step (the decode
        # working set — vLLM pins the active batch the same way)
        self.pinned: set = set()
        self.stats = {"evict_to_peer": 0, "evict_to_host": 0, "reload_peer": 0,
                      "reload_host": 0, "revocations": 0, "recomputes": 0,
                      "allocated": 0, "freed": 0}
        # optional real payload stores (small-scale tests / serving engine)
        self.store_payload = store_payload
        self.n_kv_layers = num_kv_layers
        self._payload: Dict[BlockId, np.ndarray] = {}   # (L,2,bs,nkv,hd) per block
        # engine hooks: called with (block_id, local_slot) so the serving
        # engine can move the actual pool payload alongside the placement
        self.evict_hook = None     # before a local slot is released
        self.reload_hook = None    # after a local slot is (re)assigned

    # ------------------------------------------------------------- alloc
    def allocate_block(self, req: int, block_idx: int, base_pos: int
                       ) -> Tuple[int, List[ReloadOp]]:
        """Get a local slot for a new block, evicting if necessary."""
        bid = (req, block_idx)
        assert bid not in self.table, f"block {bid} already allocated"
        ops = []
        if not self.free_slots:
            ops.extend(self._evict_one(exclude_req=req))
        slot = self.free_slots.pop()
        self.table[bid] = BlockEntry(tier=Tier.LOCAL_HBM, local_slot=slot,
                                     base_pos=base_pos)
        self.lru[bid] = None
        self.stats["allocated"] += 1
        return slot, ops

    def free_request(self, req: int) -> None:
        for bid in [b for b in self.table if b[0] == req]:
            self._drop(bid)
            self.stats["freed"] += 1

    def _drop(self, bid: BlockId) -> None:
        ent = self.table.pop(bid)
        if ent.tier == Tier.LOCAL_HBM:
            self.free_slots.append(ent.local_slot)
        elif ent.tier == Tier.PEER_HBM and ent.handle is not None:
            self.allocator.harvest_free(ent.handle)
        self.lru.pop(bid, None)
        self._payload.pop(bid, None)

    # ----------------------------------------------------------- evict
    def _evict_one(self, exclude_req: Optional[int] = None,
                   victim: Optional[BlockId] = None,
                   exclude_block: Optional[BlockId] = None) -> List[ReloadOp]:
        """Evict the LRU local block: peer first, host fallback.

        Victims from other requests are preferred; when only the excluded
        request's own blocks remain local (single-request long-context), its
        LRU block other than ``exclude_block`` is evicted instead.
        """
        if victim is None:
            fallback = None
            for bid in self.lru:
                ent = self.table[bid]
                if ent.tier != Tier.LOCAL_HBM or bid[0] in self.pinned:
                    continue
                if exclude_req is None or bid[0] != exclude_req:
                    victim = bid
                    break
                if fallback is None and bid != exclude_block:
                    fallback = bid
            if victim is None:
                victim = fallback
        if victim is None:
            raise RuntimeError("KV pool exhausted: no evictable block")
        ent = self.table[victim]
        if self.evict_hook is not None:
            self.evict_hook(victim, ent.local_slot)
        self.free_slots.append(ent.local_slot)
        ent.local_slot = None
        self.lru.pop(victim)

        h = self.allocator.harvest_alloc(self.block_nbytes, client=self.client)
        ops = []
        if h is not None:
            ent.tier = Tier.PEER_HBM
            ent.handle = h
            self.allocator.harvest_register_cb(
                h, lambda handle, bid=victim: self._on_revoked(bid))
            ops.append(ReloadOp(victim, Tier.PEER_HBM, self.hw.transfer_time(
                self.block_nbytes, Tier.LOCAL_HBM, Tier.PEER_HBM)))
            self.stats["evict_to_peer"] += 1
            if self.durability == "host_backed":
                ent.host_copy = True   # written back asynchronously
        else:
            ent.tier = Tier.HOST_DRAM
            ent.host_copy = True
            ops.append(ReloadOp(victim, Tier.HOST_DRAM, self.hw.transfer_time(
                self.block_nbytes, Tier.LOCAL_HBM, Tier.HOST_DRAM)))
            self.stats["evict_to_host"] += 1
        return ops

    # ----------------------------------------------------------- reload
    def ensure_resident(self, req: int, block_idx: int) -> List[ReloadOp]:
        """Fetch-mode reload: make a block local before the step."""
        bid = (req, block_idx)
        ent = self.table[bid]
        self.lru.pop(bid, None)
        self.lru[bid] = None     # touch
        if ent.tier == Tier.LOCAL_HBM:
            return []
        ops = []
        if not self.free_slots:
            ops.extend(self._evict_one(exclude_req=req, exclude_block=bid))
        slot = self.free_slots.pop()
        src = ent.tier
        seconds = self.hw.transfer_time(self.block_nbytes, src, Tier.LOCAL_HBM)
        if src == Tier.PEER_HBM:
            self.stats["reload_peer"] += 1
            if ent.handle is not None:
                self.allocator.harvest_free(ent.handle)
                ent.handle = None
        else:
            self.stats["reload_host"] += 1
        ent.tier = Tier.LOCAL_HBM
        ent.local_slot = slot
        if self.reload_hook is not None:
            self.reload_hook(bid, slot)
        ops.append(ReloadOp(bid, src, seconds))
        return ops

    def evict_request(self, req: int) -> List[ReloadOp]:
        """Preemption support (paper §6.3): push ALL of a request's local
        blocks out to the peer/host tiers."""
        ops = []
        self.pinned.discard(req)
        for bid in sorted(b for b in self.table if b[0] == req):
            if self.table[bid].tier == Tier.LOCAL_HBM:
                ops.extend(self._evict_one(victim=bid))
        return ops

    def is_lost(self, req: int, block_idx: int) -> bool:
        """True if a lossy revocation dropped this block's payload."""
        ent = self.table.get((req, block_idx))
        return ent is not None and ent.filled == 0 and ent.tier != Tier.LOCAL_HBM \
            and not ent.host_copy

    # -------------------------------------------------------- revocation
    def _on_revoked(self, bid: BlockId) -> None:
        ent = self.table.get(bid)
        if ent is None or ent.tier != Tier.PEER_HBM:
            return
        ent.handle = None
        self.stats["revocations"] += 1
        if ent.host_copy:
            ent.tier = Tier.HOST_DRAM      # transparent fallback (durable)
        else:
            # lossy: block is gone; the request re-materialises it
            ent.tier = Tier.HOST_DRAM
            ent.filled = 0
            self.stats["recomputes"] += 1
            self._payload.pop(bid, None)

    # ------------------------------------------------------------ queries
    def residency(self, req: int) -> List[Tier]:
        blocks = sorted(b for b in self.table if b[0] == req)
        return [self.table[b].tier for b in blocks]

    def tier_counts(self) -> Dict[str, int]:
        out = {t.value: 0 for t in Tier}
        for ent in self.table.values():
            out[ent.tier.value] += 1
        return out

    # --------------------------------------------------------- payloads
    def write_payload(self, req: int, block_idx: int, data: np.ndarray) -> None:
        if self.store_payload:
            self._payload[(req, block_idx)] = np.asarray(data)

    def read_payload(self, req: int, block_idx: int) -> Optional[np.ndarray]:
        return self._payload.get((req, block_idx))
