"""Harvested prefix cache — radix-trie cross-request KV sharing (new in PR 6).

Production traffic is dominated by shared system prompts; the KV blocks
of a shared prefix are identical across every request that carries it, so
recomputing them per request wastes exactly the prefill flops a cache
would save.  This module keeps those blocks alive *after* their request
retires, content-addressed and placed across the Harvest tiers — which is
the combination no existing system ships: a prefix cache whose cold tier
is *harvested peer GPU memory* rather than host DRAM.

Structure
---------
A radix trie keyed on chained token-block digests::

    digest_j = blake2b(digest_{j-1} || tokens[j*bs : (j+1)*bs])

so a trie path IS a prompt prefix (in full blocks) and two different
prefixes can never alias a node.  Every node owns one content-addressed
entry ``("px", digest)`` in the *same* :class:`~repro.core.store.HarvestStore`
the per-request block table uses — the entry keeps its local slot (the
live pool payload), can be demoted to peer/host by the store's ordinary
LRU pressure (the trie entry needs no retargeting: the key is stable,
only the residency changes), and is reloaded through the same
:class:`~repro.core.store.TransferEngine` lanes as any other block.

Lifecycle
---------
* **publish** (at retire): instead of freeing a request's full prompt
  blocks, :meth:`~repro.core.store.HarvestStore.rekey` transfers each one
  to its content key — zero bytes move, the pool slot and any in-flight
  write-back follow the object.  Duplicate content (another request
  published the same prefix first) is deduplicated: the private twin is
  freed normally.
* **match** (at admission/prefill): longest-prefix walk; each matched
  block is either *adopted* zero-copy (leased: the entry is pinned local,
  its refcount incremented, and only the possibly peer→local reload is
  charged) or — when another live request already leases it — *COW-split*
  into a private copy, so a shared block is never mapped to two batch
  rows (the decode kernel's ``slot_req`` maps each slot to exactly one
  row) and never mutated.
* **evict** (capacity): leaf-first LRU over trie nodes.  A node whose
  entry is leased (``refcount > 0``) is unevictable — dropping it would
  free a block a live request reads; it stays until the lease returns.
  Interior nodes are evicted only once their children are gone (an
  orphaned descendant chain could never be matched again).

Refcount contract (shared with :class:`HarvestStore`): the trie's own
hold is the entry's *base* ownership (``refcount == 0``); every lease is
one extra reference.  ``release`` drops a reference before it frees, so
whichever of {trie eviction, lessee retire} happens last performs the
actual free — the double-free class of bugs is structurally gone.

Metrics land in the ``prefix.*`` namespace: ``hits`` / ``hit_blocks`` /
``lookup_blocks`` (hit rate), ``peer_hits`` (matched blocks that were
peer-resident — the paper's harvested-tier wins), ``cow_splits``,
``published`` / ``dedup``, ``evictions`` and ``lost_pruned``.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.store import MetricsRegistry, ObjectKey, Residency
from repro.core.tiers import Fidelity

#: counters pre-seeded in the ``prefix`` namespace (stable print order)
PREFIX_STAT_KEYS = (
    "lookups", "lookup_blocks", "hits", "hit_blocks", "hit_tokens",
    "local_hits", "peer_hits", "host_hits", "cow_splits",
    "published", "dedup", "relinked", "evictions", "lost_pruned", "nodes")


def block_digests(tokens: Sequence[int], block_size: int) -> List[str]:
    """Chained content digests of the FULL blocks of a token sequence.

    Only blocks entirely covered by ``tokens`` get a digest — a partial
    tail block is private to its request (its future fill diverges).
    Chaining makes each digest position-dependent: block ``j`` of prefix A
    and block ``j`` of prefix B collide only if their whole first
    ``j + 1`` blocks are identical, which is exactly the sharing
    condition for causal-attention KV state.
    """
    if block_size <= 0:
        raise ValueError(f"block_size must be positive, got {block_size}")
    out: List[str] = []
    prev = b""
    arr = np.asarray(tokens, dtype=np.int64)
    for j in range(len(arr) // block_size):
        h = hashlib.blake2b(
            prev + arr[j * block_size:(j + 1) * block_size].tobytes(),
            digest_size=16)
        prev = h.digest()
        out.append(prev.hex())
    return out


@dataclass
class PrefixCacheConfig:
    """Knobs for the prefix trie.

    ``capacity_blocks`` bounds the number of cached blocks (trie nodes);
    beyond it, leaf-first LRU eviction frees unleased entries.
    ``hot_alpha`` is the hotness-EWMA weight applied on every hit — hit
    blocks (weighted by their interior fan-out) carry higher ``hotness``
    into the store's placement hints, steering them to stable peers.
    ``fidelity`` is the precision the cache's content is addressed at:
    digest keys include it (except FP16, which keeps the seed key shape),
    so a quantized cached block can never alias — and never be served in
    place of — a full-precision one.
    """
    capacity_blocks: int = 256
    hot_alpha: float = 0.5
    fidelity: Fidelity = Fidelity.FP16

    def __post_init__(self):
        if self.capacity_blocks <= 0:
            raise ValueError(f"capacity_blocks must be positive, "
                             f"got {self.capacity_blocks}")
        if not 0.0 <= self.hot_alpha < 1.0:
            raise ValueError(f"hot_alpha must be in [0, 1), "
                             f"got {self.hot_alpha}")
        if not isinstance(self.fidelity, Fidelity):
            raise TypeError(f"fidelity must be a Fidelity, "
                            f"got {self.fidelity!r}")


@dataclass(eq=False)
class TrieNode:
    """One cached block: a radix-trie edge labelled by its chain digest."""
    digest: str
    key: ObjectKey                      # ("px", digest) in the block store
    parent: Optional["TrieNode"]
    depth: int = 0                      # block index == base_pos // bs
    children: Dict[str, "TrieNode"] = field(default_factory=dict)
    last_use: int = 0                   # trie-LRU tick


class PrefixCache:
    """Radix-trie prefix cache over one :class:`KVOffloadManager`'s store.

    The cache owns no payloads and no slots — every cached block is an
    ordinary store entry that the tier ladder (eviction to peer/host,
    revocation, reload) manages like any other.  The trie adds reachability
    (digest chain -> key), the refcount discipline, and its own capacity
    eviction on top.
    """

    def __init__(self, kv_mgr, config: Optional[PrefixCacheConfig] = None,
                 metrics: Optional[MetricsRegistry] = None):
        self.kv = kv_mgr
        self.store = kv_mgr.store
        self.bs = kv_mgr.block_size
        self.cfg = config or PrefixCacheConfig()
        self.stats = (metrics or self.store.transfers.metrics).counters(
            "prefix", keys=PREFIX_STAT_KEYS)
        self.root = TrieNode("", None, None, depth=-1)
        self.nodes: Dict[str, TrieNode] = {}     # digest -> node (1:1)
        self._tick = 0

    # ------------------------------------------------------------- helpers
    def content_key(self, digest: str) -> ObjectKey:
        """Store key of a cached block's content at the cache's fidelity.

        FP16 keeps the seed's ``("px", digest)`` shape (back-compat with
        persisted metrics/goldens); a quantized cache appends the fidelity
        value, so the same prompt content cached at different precisions
        occupies distinct, never-aliasing store entries.
        """
        if self.cfg.fidelity is Fidelity.FP16:
            return ("px", digest)
        return ("px", digest, self.cfg.fidelity.value)

    def __len__(self) -> int:
        return len(self.nodes)

    def _entry_alive(self, node: TrieNode):
        """The node's store entry, or None when it died underneath the
        trie (freed, revoked LOST, or never fully filled)."""
        ent = self.store.table.get(node.key)
        if ent is None or ent.state is Residency.LOST:
            return None
        if getattr(ent, "filled", self.bs) < self.bs:
            return None
        return ent

    def _touch(self, node: TrieNode) -> None:
        self._tick += 1
        node.last_use = self._tick

    def _unlink(self, node: TrieNode, stat: str) -> None:
        """Drop one node and its subtree from the trie, releasing each
        store entry (refcount-routed: leased entries survive as plain
        store objects until their lessee frees them)."""
        stack = [node]
        if node.parent is not None:
            node.parent.children.pop(node.digest, None)
        while stack:
            n = stack.pop()
            stack.extend(n.children.values())
            n.children.clear()
            self.nodes.pop(n.digest, None)
            if n.key in self.store.table:
                self.store.release(n.key)
            self.stats[stat] += 1
        self.stats["nodes"] = len(self.nodes)

    # -------------------------------------------------------------- lookup
    def probe(self, tokens: Sequence[int]) -> int:
        """Matched token count of the longest cached prefix — side-effect
        free (no counters, no LRU touch).  Admission-time TTFT estimates
        use this so shedding decisions see the post-cache prefill cost."""
        node = self.root
        m = 0
        for d in block_digests(tokens, self.bs):
            child = node.children.get(d)
            if child is None or self._entry_alive(child) is None:
                break
            node = child
            m += 1
        return m * self.bs

    def match(self, tokens: Sequence[int]
              ) -> List[Tuple[int, ObjectKey]]:
        """Longest-prefix lookup: ``[(block_idx, content_key), ...]`` for
        the matched chain, LRU/hotness-touched.  Dead nodes found on the
        walk (entry freed or revoked LOST) are pruned with their subtree —
        a chain with a hole can never be consistently reused."""
        self.stats["lookups"] += 1
        self.stats["lookup_blocks"] += len(tokens) // self.bs
        out: List[Tuple[int, ObjectKey]] = []
        node = self.root
        for j, d in enumerate(block_digests(tokens, self.bs)):
            child = node.children.get(d)
            if child is None:
                break
            if self._entry_alive(child) is None:
                self._unlink(child, "lost_pruned")
                break
            self._touch(child)
            # interior fan-out weights the heat: a node many prefixes pass
            # through is the one whose demotion/revocation hurts most
            self.store.touch_hotness(child.key, 1.0 + len(child.children),
                                     self.cfg.hot_alpha)
            out.append((j, child.key))
            node = child
        if out:
            self.stats["hits"] += 1
            self.stats["hit_blocks"] += len(out)
            self.stats["hit_tokens"] += len(out) * self.bs
        return out

    # ------------------------------------------------------------- publish
    def publish(self, req_id: int, prompt: Sequence[int]) -> int:
        """Retire-time publication: transfer the request's full prompt
        blocks into the trie (rekey, zero copy) instead of freeing them.

        Blocks whose content is already cached are deduplicated (the
        private twin is freed by the caller's ``free_request``); blocks
        the request itself *adopted* from the trie are simply touched.
        Publication stops at the first unpublishable block (missing,
        LOST, or partially filled) — the chain must stay contiguous.
        Returns the number of newly published blocks.
        """
        node = self.root
        new = 0
        for j, d in enumerate(block_digests(prompt, self.bs)):
            bid = (req_id, j)
            child = node.children.get(d)
            if child is not None and self._entry_alive(child) is not None:
                self._touch(child)
                if bid not in self.kv.shared:
                    self.stats["dedup"] += 1
                node = child
                continue
            if child is not None:          # dead node in the path
                self._unlink(child, "lost_pruned")
            ckey = self.content_key(d)
            if ckey in self.store.table:
                # content survives outside the trie (its node was pruned
                # while a lease held the entry alive): re-link and restore
                # the trie's base hold so the lessee's release cannot free
                ent = self.store.table[ckey]
                if ent.state is Residency.LOST or \
                        getattr(ent, "filled", self.bs) < self.bs:
                    break
                self.store.incref(ckey)
                self.stats["relinked"] += 1
            else:
                ent = self.kv.table.get(bid)
                if ent is None or ent.state is Residency.LOST \
                        or ent.filled < self.bs:
                    break
                self.store.rekey(bid, ckey)
                ent.pinned = False         # trie blocks ride the LRU ladder
                self.stats["published"] += 1
                new += 1
            child = TrieNode(d, ckey, node, depth=j)
            node.children[d] = child
            self.nodes[d] = child
            self._touch(child)
            node = child
        self.stats["nodes"] = len(self.nodes)
        self._evict_to_capacity()
        return new

    # ------------------------------------------------------------ eviction
    def _evict_to_capacity(self) -> int:
        """Leaf-first LRU trie eviction down to ``capacity_blocks``.

        Only leaves are candidates (evicting an interior node would orphan
        a still-matchable chain) and only unleased entries may be freed —
        ``refcount > 0`` blocks are locally unevictable by the trie; the
        *store* may still demote them tier-wise, which the trie does not
        even need to observe (content keys are residency-stable).
        """
        evicted = 0
        while len(self.nodes) > self.cfg.capacity_blocks:
            victim: Optional[TrieNode] = None
            for n in self.nodes.values():
                if n.children:
                    continue
                ent = self.store.table.get(n.key)
                if ent is not None and ent.refcount > 0:
                    continue               # leased: unevictable until freed
                if victim is None or n.last_use < victim.last_use:
                    victim = n
            if victim is None:
                break                      # every leaf is leased — stop
            self._unlink(victim, "evictions")
            evicted += 1
        return evicted
