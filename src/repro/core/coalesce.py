"""Transfer coalescing + chunked multi-lane striping (the Fig-3 gap).

The calibrated links in :mod:`repro.core.tiers` charge 34–194 µs of setup
per :class:`~repro.core.store.Transfer`, so small-object traffic — a decode
step's KV-block reloads, a preemption's write-back burst — is dominated by
per-transfer setup when every object is its own submission, exactly the
regime the paper's Fig 3 measures.  The Pallas ``harvest_gather`` kernel
already moves a *batch* of slots in one call; this module is the runtime's
matching transfer-plan layer, sitting between placement decisions (the
:class:`~repro.core.store.HarvestStore` ladder, which stays byte-identical)
and the :class:`~repro.core.store.TransferEngine` timeline:

  * **Coalescing** — transfers issued in one step that ride the same
    directional link lane are submitted as ONE batched lane occupancy
    paying one setup latency plus summed bytes
    (:meth:`TransferEngine.submit_coalesced`).  Batch membership is
    threaded through ``Transfer.batch_id`` and completion still resolves
    per object: each member's ``ready_t`` lands at its cumulative byte
    boundary inside the batch.
  * **Striping** — objects at least ``min_stripe_nbytes`` big (expert
    weights) are split into ``chunk_nbytes`` chunks round-robined across
    up to ``stripe_ways`` of the link's link-disjoint paths
    (``LinkSpec.paths`` — 12 NVLink links, 4 torus ICI paths), each
    sustaining the per-path bandwidth.  Chunk-granular completion means
    ``wait_for(ops, prefix_nbytes=...)`` returns as soon as the needed
    prefix has landed, instead of at the whole object's tail.

The planner is attached by :class:`~repro.core.runtime.HarvestRuntime`
(``coalesce=CoalesceConfig(...)``) and threaded through the serving
engine, prefetcher and pipeline simulator.  With no planner attached every
code path is bit-exact with the per-object seed behaviour — coalescing is
an opt-in overlay, never a silent re-costing.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from repro.core.store import (MetricsRegistry, Transfer, TransferEngine)
from repro.core.tiers import Tier


@dataclass
class CoalesceConfig:
    """Knobs for the transfer coalescing/striping layer.

    ``enabled`` turns same-lane batching on; ``max_batch`` caps members per
    coalesced submission (one DMA descriptor list has finite length);
    ``stripe_ways`` (0/1 = off) is how many link-disjoint paths a large
    object is striped over, bounded by the lane's ``LinkSpec.paths``;
    ``chunk_nbytes`` the stripe chunk size (non-divisible object sizes get
    a short tail chunk); ``min_stripe_nbytes`` the size floor below which
    an object is never striped (chunking a KV block would only add setup).
    """
    enabled: bool = True
    max_batch: int = 16
    stripe_ways: int = 0
    chunk_nbytes: int = 1 << 20
    min_stripe_nbytes: int = 4 << 20

    def __post_init__(self):
        if self.max_batch < 2:
            raise ValueError(f"max_batch={self.max_batch}: a batch needs at "
                             "least 2 members (use enabled=False to turn "
                             "coalescing off)")
        if self.stripe_ways < 0:
            raise ValueError(f"stripe_ways={self.stripe_ways} must be >= 0 "
                             "(0/1 = striping off)")
        if self.chunk_nbytes <= 0 or self.min_stripe_nbytes <= 0:
            raise ValueError(
                f"chunk_nbytes={self.chunk_nbytes} and min_stripe_nbytes="
                f"{self.min_stripe_nbytes} must be positive — a zero-byte "
                "chunk stream never advances")


class TransferPlanner:
    """Turns loose per-object transfers into batched/striped submissions.

    ``prepare`` is the placement-side pass (stripe large objects into
    chunk transfers); ``submit`` is the timeline-side pass (group a step's
    transfers by lane and coalesce each group).  The planner only ever
    re-*schedules* transfers — placement decisions, byte counts and
    per-object completion semantics are untouched, which is what keeps
    decoded tokens bit-identical to per-object submission.
    """

    STAT_KEYS = ("batches", "batch_members", "solo", "saved_setup_s",
                 "striped_objects", "stripe_chunks")

    def __init__(self, engine: TransferEngine,
                 config: Optional[CoalesceConfig] = None,
                 metrics: Optional[MetricsRegistry] = None):
        self.te = engine
        self.cfg = config or CoalesceConfig()
        self.stats = (metrics or engine.metrics).counters(
            "coalesce", keys=self.STAT_KEYS)

    # ----------------------------------------------------- placement side
    def prepare(self, ops: List[Transfer]) -> List[Transfer]:
        """Striping pass over freshly minted transfers: objects big enough
        to amortise chunk setup leave as chunk transfers spread over the
        lane's link-disjoint sub-lanes; everything else passes through."""
        out: List[Transfer] = []
        for t in ops:
            out.extend(self._maybe_split(t))
        return out

    def _maybe_split(self, t: Transfer) -> List[Transfer]:
        ways = self.cfg.stripe_ways
        if (ways <= 1 or t.parent is not None
                or t.nbytes < self.cfg.min_stripe_nbytes):
            return [t]
        chunks = self.te.split(t, ways, self.cfg.chunk_nbytes)
        if len(chunks) > 1:
            self.stats["striped_objects"] += 1
            self.stats["stripe_chunks"] += len(chunks)
        return chunks

    # ------------------------------------------------------ timeline side
    def submit(self, ops: List[Transfer]
               ) -> Tuple[List[Transfer], float]:
        """Submit one step's planned transfers onto the timeline.

        Stripe chunks are grouped per parent object and ride their
        sub-lanes concurrently; plain transfers are grouped per lane and
        coalesced in issue order (``max_batch`` members per batch).
        Returns ``(submitted transfers, effective lane seconds)`` — the
        effective seconds are what the batch actually occupies, i.e. the
        sum of per-object times minus the setup latencies the batching
        saved, which is what callers charge to their accounting.
        """
        submitted: List[Transfer] = []
        by_stripe: Dict = {}
        by_lane: Dict[Tuple[str, "Fidelity"], List[Transfer]] = {}
        lane_order: List[Tuple[str, "Fidelity"]] = []
        for t in ops:
            if t.parent is not None:
                # one stripe = the chunks of ONE original transfer: keyed
                # by direction too, so a write-back and a reload of the
                # same object never merge into one concurrent stripe (the
                # reload's chunks must chain behind the write-back via the
                # parent-key dependency instead).  Chunks inherit their
                # parent's fidelity, so a stripe is fidelity-homogeneous
                # by construction.
                by_stripe.setdefault((t.parent, t.src, t.dst), []).append(t)
                continue
            # batches must be fidelity-homogeneous as well as same-lane:
            # one coalesced submission models ONE fused gather kernel
            # call, and one kernel packs one wire dtype — mixed-precision
            # members split into separate batches instead of merging
            ch = (self.te.lane_of(t), t.fidelity)
            if ch not in by_lane:
                lane_order.append(ch)
            by_lane.setdefault(ch, []).append(t)
        for chunks in by_stripe.values():
            submitted.extend(self.te.submit_chunks(chunks))
        for ch in lane_order:
            members = by_lane[ch]
            if not self.cfg.enabled or len(members) == 1:
                for t in members:
                    submitted.append(self.te.submit(t))
                self.stats["solo"] += len(members)
                continue
            for lo in range(0, len(members), self.cfg.max_batch):
                group = members[lo:lo + self.cfg.max_batch]
                before = sum(t.seconds for t in group)
                done = self.te.submit_coalesced(group)
                submitted.extend(done)
                n_batched = sum(1 for t in done if t.batch_id)
                if n_batched > 1:
                    self.stats["batches"] += 1
                    self.stats["batch_members"] += n_batched
                self.stats["solo"] += len(done) - n_batched
                self.stats["saved_setup_s"] += \
                    before - sum(t.lane_s for t in done)
        effective = sum(t.lane_s for t in submitted)
        return submitted, effective

    # -------------------------------------------------------- projections
    def projected_lane_s(self, nbytes: int, src: Tier, dst: Tier,
                         device: Optional[int] = None,
                         first_on_lane: bool = True) -> float:
        """Lane seconds a candidate transfer would occupy if issued into
        the current window: the full link time when it opens a batch, the
        bytes-only marginal cost when it joins one.  The prefetcher's link
        budgets count coalesced batches through this."""
        est = self.te.estimate(nbytes, src, dst, device)
        if self.cfg.enabled and not first_on_lane:
            est = max(est - self.te.link_spec(src, dst, device).latency, 0.0)
        return est
