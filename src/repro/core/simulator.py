"""CGOPipe-style pipeline simulator for MoE decode throughput (paper §4).

MoE-Lightning's CGOPipe partitions the batch into micro-batches and overlaps
expert-weight transfers for micro-batch i+1 with compute for micro-batch i.
Harvest does not change the pipeline — it changes *where* an expert miss is
served from.  The simulator reproduces the paper's Fig 5 (throughput at 50%
experts offloaded) and Fig 6 (throughput vs offload fraction): per layer and
micro-batch,

    t_layer = max(t_compute(µb_i), t_fetch(µb_{i+1}))

with t_fetch summing misses over the tier link (PCIe for CPU offload,
NVLink/ICI for Harvest) and t_compute the max of the FLOP and HBM-read times.

Expert access patterns follow the paper's observations: Zipf-skewed
popularity with query-dependent drift (hotspots move), so small-fan-out
models (Phi-3.5) reuse experts across micro-batches more than wide-fan-out
models (Qwen2-MoE).

Two overlap evaluators share every other code path: the analytic default
(per micro-batch ``max``, golden-equivalent with the seed) and
``use_timeline=True``, which plays the same fetch schedule through the
:class:`~repro.core.store.TransferEngine`'s event-driven clock and FIFO
link lanes (real queueing, cold-start fill).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable, Optional, Set

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.rebalancer import ExpertRebalancer
from repro.core.store import TransferEngine
from repro.core.tiers import HardwareModel, Tier, expert_bytes


@dataclass
class AccessModelConfig:
    zipf_alpha: float = 0.9        # expert popularity skew
    drift_every: int = 64          # micro-batches between hotspot shifts
    seed: int = 0


class ExpertAccessModel:
    """Zipf-skewed, drifting expert activation sampler."""

    def __init__(self, num_experts: int, top_k: int, cfg: AccessModelConfig):
        self.E = num_experts
        self.k = top_k
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)
        self._perm = self.rng.permutation(self.E)
        self._count = 0
        ranks = np.arange(1, self.E + 1, dtype=np.float64)
        self._base_p = ranks ** -cfg.zipf_alpha
        self._base_p /= self._base_p.sum()

    def _maybe_drift(self):
        self._count += 1
        if self._count % self.cfg.drift_every == 0:
            # hotspots shift unpredictably across queries (Doucet et al.)
            swap = self.rng.choice(self.E, size=max(2, self.E // 8),
                                   replace=False)
            self._perm[swap] = self._perm[self.rng.permutation(swap)]

    def sample_microbatch(self, tokens: int) -> np.ndarray:
        """Returns (tokens, k) expert assignments for one micro-batch."""
        self._maybe_drift()
        p = self._base_p[np.argsort(self._perm)]
        out = np.empty((tokens, self.k), dtype=np.int64)
        for j in range(self.k):   # sample without replacement per token (approx)
            out[:, j] = self.rng.choice(self.E, size=tokens, p=p)
        return out


@dataclass
class SimResult:
    tokens_per_s: float
    t_compute: float
    t_fetch: float
    fetch_by_tier: dict
    distinct_experts_per_ub: float


# MoE-Lightning runs attention on the CPU (KV cache lives in host DRAM) —
# that CPU-side attention is the compute floor the fetches overlap with.
# Calibration constants (documented in EXPERIMENTS.md §Paper-claims): the
# per-microbatch framework overhead models routing/sampling/kernel-launch and
# CPU<->GPU sync costs of the MoE-Lightning test bench.
CPU_MEM_BW = 90e9           # bytes/s effective host DRAM bandwidth
DEFAULT_CTX_LEN = 250       # MTBench prompt + generated tokens (average)
UB_OVERHEAD_PER_DM = 2e-3   # s per µb per layer per 1024 d_model (framework)
HOST_XFER_LAT = 0.5e-3      # per-expert-transfer latency, PCIe path (paging)
PEER_XFER_LAT = 50e-6       # per-expert-transfer latency, NVLink path

# Per-model framework-overhead calibration (seconds per µb per layer),
# measured constants in the spirit of MoE-Lightning's HRM performance model.
# The d_model-proportional default over-penalizes SlimMoE-compressed models
# whose d_model is wide but whose per-layer work is tiny (Phi-tiny's experts
# are 12 MiB vs Mixtral's 336 MiB); the override reproduces the paper's
# measured test-bench throughput for that model (Fig 5).
UB_OVERHEAD_OVERRIDES = {"phi-tiny-moe": 0.19e-3}


def simulate_moe_decode(cfg: ModelConfig, hw: HardwareModel,
                        offload_fraction: float, use_peer: bool,
                        micro_batch: int = 324, num_micro_batches: int = 14,
                        decode_steps: int = 32,
                        access: Optional[AccessModelConfig] = None,
                        rebalancer: Optional[ExpertRebalancer] = None,
                        peer_capacity_fraction: float = 1.0,
                        ctx_len: int = DEFAULT_CTX_LEN,
                        cpu_mem_bw: float = CPU_MEM_BW,
                        runtime=None, use_timeline: bool = False,
                        planner=None) -> SimResult:
    """Simulate decode throughput (tokens/s) for one configuration.

    offload_fraction of the experts are NOT local; with ``use_peer`` the
    offloaded set is served from peer HBM (up to ``peer_capacity_fraction``
    of it), else from host DRAM over the slow link.

    ``runtime`` (a :class:`repro.core.runtime.HarvestRuntime`) supplies the
    TransferEngine so peer-fetch accounting lands in the caller's unified
    metrics; a live rebalancer (e.g. ``runtime.clients["moe"]``) overrides
    the static residency split.

    ``use_timeline=False`` (default, golden-equivalent) evaluates the
    CGOPipe overlap analytically: per micro-batch,
    ``max(t_compute, t_fetch)``.  ``use_timeline=True`` runs the same
    pipeline on the TransferEngine's event timeline instead: micro-batch
    i+1's expert fetches are ``submit``-ted at the start of micro-batch
    i's compute window and the pipeline stalls only when a micro-batch's
    own fetches are not ready, so per-link FIFO queueing and cold-start
    fill are modelled rather than assumed away.  (The host-side HRM
    choice of CPU-FFN-vs-PCIe is an analytic-mode refinement; timeline
    mode always fetches over the link.)

    ``planner`` (a :class:`~repro.core.coalesce.TransferPlanner`,
    defaulting to the runtime's) applies to timeline mode only: each
    micro-batch's expert fetches are striped (large experts leave as chunk
    transfers over link-disjoint sub-lanes) and submitted as coalesced
    per-lane batches — one transfer setup per lane per micro-batch instead
    of one per missed expert.
    """
    mc = cfg.moe
    te = runtime.transfers if runtime is not None else TransferEngine(hw)
    if planner is None and runtime is not None:
        planner = getattr(runtime, "planner", None)
    if rebalancer is None and runtime is not None:
        rebalancer = runtime.clients.get("moe")
    am = ExpertAccessModel(mc.num_experts, mc.top_k,
                           access or AccessModelConfig())
    e_bytes = expert_bytes(cfg)
    n_moe = cfg.num_moe_layers
    n_dense = cfg.num_layers - n_moe

    # residency: experts [0, n_local) local; offloaded ones on peer or host
    n_local = int(round(mc.num_experts * (1 - offload_fraction)))
    n_peer = int(round((mc.num_experts - n_local) * peer_capacity_fraction)) \
        if use_peer else 0

    def tier_of(e: int) -> Tier:
        if rebalancer is not None:
            return rebalancer.tier_of(0, int(e))
        if e < n_local:
            return Tier.LOCAL_HBM
        if e < n_local + n_peer:
            return Tier.PEER_HBM
        return Tier.HOST_DRAM

    def device_of(e: int):
        # with a live rebalancer the fetch is charged to (and, in timeline
        # mode, rides the lane of) the expert's actual peer device; the
        # static split has no device identity — legacy single-lane path
        return rebalancer.device_of(0, int(e)) if rebalancer is not None \
            else None

    # per-token compute cost (active params) — decode is weight-read bound
    pc = cfg.param_counts()
    active_flops_tok = 2 * pc["active"] / 1  # 2 FLOP per param per token
    dense_bytes_layer = (pc["total"] - n_moe * mc.num_experts * e_bytes) \
        / max(cfg.num_layers, 1)
    # CPU attention (MoE-Lightning keeps KV in host DRAM): per layer per
    # micro-batch, read the micro-batch's KV working set from DRAM.
    kv_tok_layer = 2 * cfg.num_kv_heads * cfg.resolved_head_dim * 2  # bytes
    cpu_attn_ub_layer = micro_batch * ctx_len * kv_tok_layer / cpu_mem_bw
    ub_overhead = UB_OVERHEAD_OVERRIDES.get(
        cfg.name, UB_OVERHEAD_PER_DM * cfg.d_model / 1024)

    total_time = 0.0
    total_fetch = 0.0
    total_compute = 0.0
    fetch_by_tier = {t.value: 0.0 for t in Tier}
    distinct_acc = 0.0
    n_ub_total = 0

    for _ in range(decode_steps):
        # one decode step: every layer, pipeline over micro-batches
        ub_experts = [np.unique(am.sample_microbatch(micro_batch))
                      for _ in range(num_micro_batches)]
        distinct_acc += float(np.mean([len(u) for u in ub_experts]))
        n_ub_total += 1

        # compute time per micro-batch per MoE layer
        def t_compute_ub(experts: np.ndarray) -> float:
            flop_t = micro_batch * active_flops_tok / cfg.num_layers / hw.peak_flops
            hbm_t = (len(experts) * e_bytes + dense_bytes_layer) / hw.hbm_bw
            return max(flop_t, hbm_t) + cpu_attn_ub_layer + ub_overhead

        def miss_split(experts: np.ndarray):
            """(peer_seconds, host_missed_bytes, host_n, transfer_ops)"""
            peer_t, host_b, host_n = 0.0, 0, 0
            ops = []
            for e in experts:
                tier = tier_of(int(e))
                if tier == Tier.LOCAL_HBM:
                    continue
                if tier == Tier.PEER_HBM:
                    op = te.transfer(int(e), e_bytes, Tier.PEER_HBM,
                                     Tier.LOCAL_HBM,
                                     extra_latency=PEER_XFER_LAT,
                                     client="sim", device=device_of(int(e)))
                    peer_t += op.seconds
                    fetch_by_tier[tier.value] += op.seconds
                    ops.append(op)
                else:
                    host_b += e_bytes
                    host_n += 1
                    if use_timeline:
                        op = te.transfer(int(e), e_bytes, Tier.HOST_DRAM,
                                         Tier.LOCAL_HBM,
                                         extra_latency=HOST_XFER_LAT,
                                         client="sim")
                        fetch_by_tier[Tier.HOST_DRAM.value] += op.seconds
                        ops.append(op)
            return peer_t, host_b, host_n, ops

        step_t = 0.0
        for _layer in range(n_moe):
            comp = [t_compute_ub(u) for u in ub_experts]
            splits = [miss_split(u) for u in ub_experts]
            if use_timeline:
                # event-driven CGOPipe: µb i+1's fetches are issued at the
                # start of µb i's compute window; µb i's compute starts
                # only once its own fetches are ready.  µb 0 pays the
                # cold-start fill.
                ub_ops = [s[3] for s in splits]

                def issue(ops):
                    if planner is None:
                        for op in ops:
                            te.submit(op)
                        return ops
                    # coalesced batch per lane; large experts striped
                    return planner.submit(planner.prepare(ops))[0]

                t0 = te.now
                ub_ops[0] = issue(ub_ops[0])
                te.wait_for(ub_ops[0])
                for i in range(num_micro_batches):
                    if i + 1 < num_micro_batches:
                        ub_ops[i + 1] = issue(ub_ops[i + 1])
                    te.advance(comp[i])
                    if i + 1 < num_micro_batches:
                        te.wait_for(ub_ops[i + 1])
                t = te.now - t0
                total_fetch += sum(op.lane_s for ops in ub_ops for op in ops)
            else:
                # Host-resident misses: MoE-Lightning's HRM picks the
                # cheaper of
                #  (A) fetch over PCIe, overlapped with compute (CGOPipe), or
                #  (B) compute the expert FFN on the CPU — DRAM-bound,
                #      serialised with CPU attention on the same memory bus.
                t = 0.0
                for i in range(num_micro_batches):
                    peer_t, host_b, host_n, _ops = splits[i]
                    pcie_t = host_b / hw.host_link.bandwidth \
                        + host_n * HOST_XFER_LAT
                    cpu_ffn_t = host_b / cpu_mem_bw
                    opt_a = max(comp[i], pcie_t + peer_t)  # overlap transfers
                    opt_b = comp[i] + cpu_ffn_t if peer_t <= comp[i] \
                        else max(comp[i] + cpu_ffn_t, peer_t)
                    t += min(opt_a, opt_b)
                    total_fetch += min(pcie_t, cpu_ffn_t) + peer_t
                    if pcie_t < cpu_ffn_t:
                        fetch_by_tier[Tier.HOST_DRAM.value] += pcie_t
                    else:
                        fetch_by_tier[Tier.HOST_DRAM.value] += cpu_ffn_t
            step_t += t
            total_compute += sum(comp)
        # dense layers: resident weights, but still CPU attention
        dense_t = n_dense * num_micro_batches * (
            max(micro_batch * active_flops_tok / cfg.num_layers / hw.peak_flops,
                dense_bytes_layer / hw.hbm_bw) + cpu_attn_ub_layer + ub_overhead)
        if use_timeline:
            te.advance(dense_t)
        step_t += dense_t
        total_time += step_t

    tokens = decode_steps * micro_batch * num_micro_batches
    return SimResult(
        tokens_per_s=tokens / total_time,
        t_compute=total_compute,
        t_fetch=total_fetch,
        fetch_by_tier=fetch_by_tier,
        distinct_experts_per_ub=distinct_acc / max(n_ub_total, 1),
    )
