"""Expert Rebalancer — Harvest applied to MoE expert weights (paper §4).

At server start a user-defined subset of experts is resident in local HBM;
the rest live in host DRAM (authoritative copy, always kept — expert weights
are the "backed" durability class).  As peer memory becomes available the
rebalancer migrates the *hottest* non-local experts into peer HBM via
``harvest_alloc``; on revocation the residency entry falls back to host and
future fetches take the slow path again.  Routing, batching and the FFN math
are untouched (the paper's "no model code changes" property) — residency only
changes *where a miss is served from*.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.allocator import HarvestAllocator, HarvestHandle
from repro.core.tiers import HardwareModel, Tier, expert_bytes

ExpertId = Tuple[int, int]   # (moe_layer_index, expert_index)


@dataclass
class ExpertEntry:
    tier: Tier
    handle: Optional[HarvestHandle] = None
    hotness: float = 0.0      # EWMA of per-step activation count
    pinned_local: bool = False


class ExpertRebalancer:
    def __init__(self, cfg: ModelConfig, allocator: HarvestAllocator,
                 hardware: HardwareModel, local_fraction: float = 0.5,
                 ewma: float = 0.8, client: str = "moe"):
        assert cfg.moe is not None
        self.cfg = cfg
        self.allocator = allocator
        self.hw = hardware
        self.ewma = ewma
        self.client = client
        self.expert_nbytes = expert_bytes(cfg)
        self.residency: Dict[ExpertId, ExpertEntry] = {}
        self.stats = {"peer_hits": 0, "host_hits": 0, "local_hits": 0,
                      "migrations": 0, "revocations": 0}

        n_moe = cfg.num_moe_layers
        E = cfg.moe.num_experts
        n_local = int(E * local_fraction)
        for li in range(n_moe):
            for e in range(E):
                local = e < n_local
                self.residency[(li, e)] = ExpertEntry(
                    tier=Tier.LOCAL_HBM if local else Tier.HOST_DRAM,
                    pinned_local=local)

    # ------------------------------------------------------------- access
    def record_access(self, layer: int, experts: np.ndarray) -> None:
        """EWMA-update hotness from this step's routing decisions."""
        counts = np.bincount(np.asarray(experts).reshape(-1),
                             minlength=self.cfg.moe.num_experts)
        for e, c in enumerate(counts):
            ent = self.residency[(layer, e)]
            ent.hotness = self.ewma * ent.hotness + (1 - self.ewma) * float(c)

    def fetch(self, layer: int, expert: int) -> Tuple[Tier, float]:
        """Resolve one expert fetch; returns (tier served from, seconds)."""
        ent = self.residency[(layer, expert)]
        if ent.tier == Tier.LOCAL_HBM:
            self.stats["local_hits"] += 1
            return ent.tier, self.hw.transfer_time(
                self.expert_nbytes, Tier.LOCAL_HBM, Tier.LOCAL_HBM)
        if ent.tier == Tier.PEER_HBM:
            self.stats["peer_hits"] += 1
            return ent.tier, self.hw.transfer_time(
                self.expert_nbytes, Tier.PEER_HBM, Tier.LOCAL_HBM)
        self.stats["host_hits"] += 1
        return ent.tier, self.hw.transfer_time(
            self.expert_nbytes, Tier.HOST_DRAM, Tier.LOCAL_HBM)

    # --------------------------------------------------------- rebalance
    def rebalance(self, max_migrations: int = 16) -> int:
        """Migrate hottest host-resident experts into available peer HBM."""
        host_resident = [(eid, ent) for eid, ent in self.residency.items()
                         if ent.tier == Tier.HOST_DRAM]
        host_resident.sort(key=lambda kv: -kv[1].hotness)
        done = 0
        for eid, ent in host_resident[:max_migrations * 4]:
            if done >= max_migrations:
                break
            h = self.allocator.harvest_alloc(self.expert_nbytes,
                                             client=self.client)
            if h is None:
                break
            self.allocator.harvest_register_cb(
                h, lambda handle, eid=eid: self._on_revoked(eid))
            ent.tier = Tier.PEER_HBM
            ent.handle = h
            self.stats["migrations"] += 1
            done += 1
        return done

    def _on_revoked(self, eid: ExpertId) -> None:
        """Revocation callback: invalidate, fall back to host (authoritative)."""
        ent = self.residency[eid]
        ent.tier = Tier.HOST_DRAM
        ent.handle = None
        self.stats["revocations"] += 1

    def demote(self, layer: int, expert: int) -> None:
        """Voluntarily release a peer-resident expert (policy-driven)."""
        ent = self.residency[(layer, expert)]
        if ent.tier == Tier.PEER_HBM and ent.handle is not None:
            self.allocator.harvest_free(ent.handle)
            ent.tier = Tier.HOST_DRAM
            ent.handle = None

    # ------------------------------------------------------------ queries
    def tier_of(self, layer: int, expert: int) -> Tier:
        return self.residency[(layer, expert)].tier

    def residency_fractions(self) -> Dict[str, float]:
        n = len(self.residency)
        out = {t.value: 0 for t in Tier}
        for ent in self.residency.values():
            out[ent.tier.value] += 1
        return {k: v / n for k, v in out.items()}
