"""Expert Rebalancer — the MoE-weights client of :class:`HarvestStore` (§4).

At server start a user-defined subset of experts is resident in local HBM;
the rest live in host DRAM (authoritative copy, always kept — expert weights
are the BACKED durability class).  As peer memory becomes available the
rebalancer migrates the *hottest* non-local experts into peer HBM via the
store's promote primitive; on revocation the store falls the entry back to
host and future fetches take the slow path again.  Routing, batching and the
FFN math are untouched (the paper's "no model code changes" property) —
residency only changes *where a miss is served from*.

Hotness-ranked migration is a policy loop over the generic store, not a
parallel residency implementation: the store owns the table, revocation
wiring and transfer accounting.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.allocator import HarvestAllocator
from repro.core.store import (Durability, HarvestStore, MetricsRegistry,
                              ObjectEntry, Residency, TransferEngine)
from repro.core.tiers import HardwareModel, Tier, expert_bytes

ExpertId = Tuple[int, int]   # (moe_layer_index, expert_index)

MOE_STAT_KEYS = ("peer_hits", "host_hits", "local_hits", "migrations",
                 "revocations")

_HIT_STAT = {
    Residency.LOCAL: "local_hits",
    Residency.PEER: "peer_hits",
    Residency.HOST: "host_hits",
}


class ExpertRebalancer:
    def __init__(self, cfg: ModelConfig, allocator: HarvestAllocator,
                 hardware: HardwareModel, local_fraction: float = 0.5,
                 ewma: float = 0.8, client: str = "moe",
                 transfers: Optional[TransferEngine] = None,
                 metrics: Optional[MetricsRegistry] = None):
        assert cfg.moe is not None
        self.cfg = cfg
        self.allocator = allocator
        self.hw = hardware
        self.ewma = ewma
        self.client = client
        self.expert_nbytes = expert_bytes(cfg)
        # expert weights: no managed local slot pool (the local set is pinned
        # at startup), BACKED durability (host copy is always authoritative)
        self.store = HarvestStore(
            allocator, transfers or TransferEngine(hardware, metrics),
            client=client, object_nbytes=self.expert_nbytes,
            num_local_slots=None, durability=Durability.BACKED,
            stat_keys=MOE_STAT_KEYS)

        n_local = int(cfg.moe.num_experts * local_fraction)
        for li in range(cfg.num_moe_layers):
            for e in range(cfg.moe.num_experts):
                local = e < n_local
                self.store.register(
                    (li, e),
                    state=Residency.LOCAL if local else Residency.HOST,
                    pinned=local)

    # ------------------------------------------------------- store views
    @property
    def stats(self) -> Dict[str, int]:
        return self.store.stats

    @property
    def residency(self) -> Dict[ExpertId, ObjectEntry]:
        return self.store.table

    # ------------------------------------------------------------- access
    def record_access(self, layer: int, experts: np.ndarray) -> None:
        """EWMA-update hotness from this step's routing decisions."""
        counts = np.bincount(np.asarray(experts).reshape(-1),
                             minlength=self.cfg.moe.num_experts)
        for e, c in enumerate(counts):
            self.store.touch_hotness((layer, e), float(c), self.ewma)

    def fetch(self, layer: int, expert: int) -> Tuple[Tier, float]:
        """Resolve one expert fetch; returns (tier served from, seconds)."""
        ent = self.store.table[(layer, expert)]
        self.stats[_HIT_STAT[ent.state]] += 1
        op = self.store.transfers.transfer(
            (layer, expert), self.expert_nbytes, ent.tier, Tier.LOCAL_HBM,
            client=self.client, device=self.device_of(layer, expert))
        return ent.tier, op.seconds

    # --------------------------------------------------------- rebalance
    def plan_promotions(self, limit: int) -> list:
        """Hottest host-resident experts, best promotion candidates first.

        This is the rebalancer's prefetch hook: the
        :class:`~repro.core.prefetch.Prefetcher` consumes the plan during
        compute windows the same way ``rebalance`` does eagerly.
        """
        return [eid for eid, _ent in
                self.store.hottest(Residency.HOST, limit=limit)]

    def rebalance(self, max_migrations: int = 16) -> int:
        """Migrate hottest host-resident experts into available peer HBM."""
        done = 0
        for eid in self.plan_promotions(max_migrations * 4):
            if done >= max_migrations:
                break
            if not self.store.promote_to_peer(eid):
                break
            done += 1
        return done

    def demote(self, layer: int, expert: int) -> None:
        """Voluntarily release a peer-resident expert (policy-driven)."""
        self.store.demote((layer, expert))

    # ------------------------------------------------------------ queries
    def tier_of(self, layer: int, expert: int) -> Tier:
        return self.store.table[(layer, expert)].tier

    def device_of(self, layer: int, expert: int):
        """Peer device a PEER-resident expert lives on (else None)."""
        return self.store.device_of((layer, expert))

    def residency_fractions(self) -> Dict[str, float]:
        counts = self.store.tier_counts()
        n = max(len(self.store.table), 1)
        return {k: v / n for k, v in counts.items()}
