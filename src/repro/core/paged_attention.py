"""Paged decode attention over tiered Harvest KV pools.

The KV cache is a pool of fixed-size blocks (vLLM PagedAttention), with an
*inverted* block table: per pool slot, which request owns it and which
position range it covers.  This layout makes the pool dimension shardable
over arbitrary mesh axes — each shard computes flash-decode partials
(m, l, acc) over its local slots and partials merge associatively via
log-sum-exp, first across pools/tiers, then across mesh shards with
pmax/psum.  That associativity is what lets Harvest's *peer tier* join the
attention in place (beyond-paper "inplace" mode) instead of being copied to
local HBM first (paper-faithful "fetch" mode).

Shapes (one shard / one tier):
  q:        (b, nq, hd)            current-token queries
  pool_k/v: (n_slots, bs, nkv, hd) block pool
  slot_req: (n_slots,) int32       owning request (-1 = free slot)
  slot_base:(n_slots,) int32       position of the block's first token
  q_pos:    (b,) int32             current decode position per request
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

NEG_INF = -1e30


class Partials(NamedTuple):
    m: jnp.ndarray    # (b, nkv, gq)        running max
    l: jnp.ndarray    # (b, nkv, gq)        running denominator
    acc: jnp.ndarray  # (b, nkv, gq, hd)    running numerator


def pool_partials(q, pool_k, pool_v, slot_req, slot_base, q_pos,
                  cfg: ModelConfig) -> Partials:
    """Flash-decode partials of one pool (tier) on one shard."""
    b, nq, hd = q.shape
    n_slots, bs, nkv, _ = pool_k.shape
    gq = nq // nkv
    f32 = jnp.float32
    scale = hd ** -0.5

    req = jnp.clip(slot_req, 0, b - 1)
    qn = jnp.take(q, req, axis=0).astype(f32) * scale        # (n, nq, hd)
    qn = qn.reshape(n_slots, nkv, gq, hd)

    # Dots take bf16 operands with f32 MXU accumulation.  Upcasting the pool
    # (pool.astype(f32)) instead makes XLA hoist a full f32 pool copy out of
    # the layer scan and rewrite the bf16 pool through a convert fusion every
    # layer — ~80% of decode HBM traffic at 80 layers (EXPERIMENTS.md §Perf).
    s = jnp.einsum("nKgh,nsKh->nKgs", qn.astype(pool_k.dtype), pool_k,
                   preferred_element_type=f32)               # (n,nkv,gq,bs)
    if cfg.logit_softcap:
        s = cfg.logit_softcap * jnp.tanh(s / cfg.logit_softcap)

    pos = slot_base[:, None] + jnp.arange(bs, dtype=jnp.int32)[None, :]
    qp = jnp.take(q_pos, req, axis=0)[:, None]               # (n, 1)
    valid = (slot_req[:, None] >= 0) & (pos <= qp) & (pos >= 0)
    if cfg.sliding_window is not None:
        valid &= pos > (qp - cfg.sliding_window)
    if cfg.attention_chunk is not None:
        valid &= (pos // cfg.attention_chunk) == (qp // cfg.attention_chunk)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)

    m_n = jnp.max(s, axis=-1)                                # (n,nkv,gq)
    p = jnp.exp(s - m_n[..., None])
    p = jnp.where(valid[:, None, None, :], p, 0.0)
    l_n = jnp.sum(p, axis=-1)
    acc_n = jnp.einsum("nKgs,nsKh->nKgh", p.astype(pool_v.dtype), pool_v,
                       preferred_element_type=f32)

    # merge per-slot partials into per-request partials (segment LSE)
    seg = jnp.where(slot_req >= 0, slot_req, b)              # b = trash row
    m_r = jax.ops.segment_max(m_n, seg, num_segments=b + 1)[:b]
    m_r = jnp.maximum(m_r, NEG_INF)                          # empty -> -inf-ish
    corr = jnp.exp(m_n - jnp.take(m_r, jnp.clip(seg, 0, b - 1), axis=0))
    corr = jnp.where((slot_req >= 0)[:, None, None], corr, 0.0)
    l_r = jax.ops.segment_sum(l_n * corr, seg, num_segments=b + 1)[:b]
    acc_r = jax.ops.segment_sum(acc_n * corr[..., None], seg,
                                num_segments=b + 1)[:b]
    return Partials(m=m_r, l=l_r, acc=acc_r)


def merge_partials(parts: Sequence[Partials]) -> Partials:
    """Associative LSE merge across tiers/pools."""
    out = parts[0]
    for p in parts[1:]:
        m = jnp.maximum(out.m, p.m)
        c0 = jnp.exp(out.m - m)
        c1 = jnp.exp(p.m - m)
        out = Partials(m=m,
                       l=out.l * c0 + p.l * c1,
                       acc=out.acc * c0[..., None] + p.acc * c1[..., None])
    return out


def finalize(parts: Partials, axis_names: Sequence[str] = ()) -> jnp.ndarray:
    """Cross-shard merge (pmax/psum over ``axis_names``) and normalisation.

    Returns (b, nq, hd) f32. Call inside shard_map when the pool dim is
    mesh-sharded; with no axis names it is a plain normalisation.
    """
    m, l, acc = parts
    if axis_names:
        m_g = jax.lax.pmax(m, axis_names)
        corr = jnp.exp(m - m_g)
        l = jax.lax.psum(l * corr, axis_names)
        acc = jax.lax.psum(acc * corr[..., None], axis_names)
        m = m_g
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    b, nkv, gq, hd = out.shape
    return out.reshape(b, nkv * gq, hd)


def append_kv(pool_k, pool_v, k_new, v_new, local_slot, offset):
    """Scatter this step's (k, v) into the pool.

    k_new/v_new: (b, nkv, hd);  local_slot/offset: (b,) int32.  Requests whose
    current block lives on another shard carry local_slot == n_slots, which
    the scatter's drop mode ignores.
    """
    pool_k = pool_k.at[local_slot, offset].set(k_new.astype(pool_k.dtype),
                                               mode="drop")
    pool_v = pool_v.at[local_slot, offset].set(v_new.astype(pool_v.dtype),
                                               mode="drop")
    return pool_k, pool_v


def paged_decode_attention(q, pools, q_pos, cfg: ModelConfig,
                           axis_names: Sequence[str] = ()) -> jnp.ndarray:
    """Attention of one decode token against the union of KV pools.

    ``pools`` is a sequence of (pool_k, pool_v, slot_req, slot_base) tuples —
    typically [local] (paper-faithful fetch mode: peer blocks were copied in
    before the step) or [local, peer] (in-place mode: the harvested tier joins
    the softmax directly).
    """
    parts = [pool_partials(q, pk, pv, sr, sb, q_pos, cfg)
             for (pk, pv, sr, sb) in pools]
    merged = merge_partials(parts)
    return finalize(merged, axis_names)
