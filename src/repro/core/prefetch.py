"""Cross-step prefetcher — speculative reloads issued under compute windows.

The serving engine's decode step has a compute window (weight-read bound,
:mod:`repro.core.simulator`'s observation) during which the peer and host
links would otherwise sit idle.  The :class:`Prefetcher` fills that window:

  * **KV blocks** (paper §5): ``KVOffloadManager.plan_prefetch`` names the
    non-local blocks the next steps will read — the append-boundary blocks
    of running requests plus the resident prefix of preempted requests
    about to be re-admitted.  The prefetcher reloads them peer→local (or
    host→local) on the event-driven transfer timeline so they are ready
    before the step that reads them, instead of stalling that step.
  * **Expert weights** (paper §4): via ``ExpertRebalancer.plan_promotions``
    the prefetcher promotes the hottest host-resident experts into peer
    HBM, so the next expert miss is served over the fast link.

Two budgets bound speculation:

  * **free local slots** — a prefetch only ever fills a *free* slot, and
    the slot floor (``min_free_slots`` raised per window by the engine's
    worst-case next allocations) guarantees it is never the reason a
    later allocation evicts.  Placement decisions therefore never change,
    which keeps decoded tokens bit-identical to the sync engine under
    ``host_backed`` durability.  (Under ``lossy`` durability with
    revocation churn, a prefetched block has simply left the peer tier
    *before* a revocation could drop it — prefetch can only reduce
    recomputes, never add them, but rescuing a block that the sync run
    lost legitimately changes that run's tokens.)
  * **link budget** — a prefetch (or hot-expert promotion) is skipped when
    its lane's queue is already projected busy past the current compute
    window (``window_slack`` scales the window), so speculative traffic
    never delays the demand fetches queued ahead of it.

Every issued transfer is tracked until the engine either *claims* the
block (a later step reads it — a **hit**) or the block is evicted / its
request freed before any read (a **waste**).  The counters land in the
shared :class:`~repro.core.store.MetricsRegistry` under ``prefetch``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional

from repro.core.store import (MetricsRegistry, ObjectKey, Tier, Transfer,
                              TransferEngine, channel_name)


@dataclass
class PrefetchConfig:
    """Knobs for the cross-step prefetcher.

    ``prefetch_depth`` is how many future append-boundary blocks per
    running request are eligible; ``resume_lookahead`` how many
    head-of-line preempted waiters get their prefix warmed;
    ``min_free_slots`` the local-slot floor prefetch must never consume;
    ``max_inflight`` the cap on outstanding speculative transfers;
    ``window_slack`` the fraction of the compute window a lane may be
    filled to; ``expert_migrations`` the number of hot host-resident
    experts promoted per window (0 disables the rebalancer hook).
    """
    prefetch_depth: int = 1
    resume_lookahead: int = 2
    min_free_slots: int = 2
    max_inflight: int = 8
    window_slack: float = 1.0
    expert_migrations: int = 0


class Prefetcher:
    STAT_KEYS = ("issued", "hits", "wasted", "skipped_slots",
                 "skipped_budget", "expert_promotions")

    def __init__(self, kv, transfers: TransferEngine,
                 config: Optional[PrefetchConfig] = None, *,
                 rebalancer=None, planner=None,
                 metrics: Optional[MetricsRegistry] = None):
        self.kv = kv
        self.te = transfers
        self.cfg = config or PrefetchConfig()
        self.rebalancer = rebalancer
        #: optional :class:`~repro.core.coalesce.TransferPlanner`: a
        #: window's prefetches then land as coalesced batches (link budgets
        #: charge one setup per lane per window, not one per block)
        self.planner = planner
        self.stats = (metrics or transfers.metrics).counters(
            "prefetch", keys=self.STAT_KEYS)
        #: runtime budget throttle in (0, 1] — the stability controller
        #: lowers it when peer revocations spike so speculative traffic
        #: stops competing with demand reloads; 1.0 is bit-exact with
        #: the un-throttled prefetcher
        self.throttle = 1.0
        #: block -> its in-flight speculative reload (claimed or wasted later)
        self.inflight: Dict[ObjectKey, Transfer] = {}

    # ------------------------------------------------------------- issue
    def run(self, window_s: float, running=(), waiting=(),
            slot_floor: Optional[int] = None) -> List[Transfer]:
        """Issue speculative transfers for one compute window.

        ``running``/``waiting`` are the engine's request lists.
        ``slot_floor`` raises ``min_free_slots`` for this window — the
        engine passes its worst-case next allocations (append blocks +
        head-of-line prefill) so a prefetch can never be the reason a
        later allocation evicts.  Returns the KV transfers issued this
        window (already submitted on the timeline) so the caller can
        account their seconds; expert promotions ride the timeline too but
        are background moves, accounted only by the transfer metrics.
        """
        issued: List[Transfer] = []
        pending: List[Transfer] = []      # planner path: batch-submitted
        lane_load: Dict[str, float] = {}  # this window's projected lane use
        floor = max(self.cfg.min_free_slots, slot_floor or 0)
        run_pairs = [(r.req_id, r.pos) for r in running]
        wait_ids = [r.req_id for r in waiting
                    if not r.needs_prefill][:self.cfg.resume_lookahead]
        budget_end = (self.te.now
                      + window_s * self.cfg.window_slack * self.throttle)
        max_inflight = max(int(self.cfg.max_inflight * self.throttle), 1)
        for bid in self.kv.plan_prefetch(run_pairs, wait_ids,
                                         depth=self.cfg.prefetch_depth):
            if bid in self.inflight:
                continue
            if len(self.inflight) >= max_inflight:
                break
            if len(self.kv.free_slots) <= floor:
                self.stats["skipped_slots"] += 1
                break
            ent = self.kv.table[bid]
            # link budgets are per *device* lane: a prefetch from peer 3
            # only has to fit in peer3_in's window, regardless of how busy
            # the other peers' lanes are
            dev = ent.handle.device if ent.handle is not None else None
            ch = self.te.lane_for(ent.tier, Tier.LOCAL_HBM, dev)
            if self.planner is not None:
                # coalesced budget: the window's first transfer on a lane
                # opens the batch (full setup + bytes); the rest only add
                # their bytes time — budgets count batches, not members
                est = self.planner.projected_lane_s(
                    ent.nbytes, ent.tier, Tier.LOCAL_HBM, dev,
                    first_on_lane=ch not in lane_load)
            else:
                est = self.te.estimate(ent.nbytes, ent.tier,
                                       Tier.LOCAL_HBM, dev)
            if (self.te.channel_busy_until(ch) + lane_load.get(ch, 0.0)
                    + est > budget_end):
                self.stats["skipped_budget"] += 1
                continue
            # free slots guaranteed above, so this never evicts
            ops = self.kv.ensure_resident(*bid)
            if self.planner is not None:
                pending.extend(ops)
                lane_load[ch] = lane_load.get(ch, 0.0) + est
            else:
                for op in ops:
                    self.te.submit(op)
            if ops:
                self.inflight[bid] = ops[-1]
                self.stats["issued"] += 1
                issued.extend(ops)
        if pending:
            self.planner.submit(pending)
        self._promote_experts(budget_end)
        return issued

    def _promote_experts(self, budget_end: float) -> None:
        """Hot-expert promotion (rebalancer hook): host->peer moves on the
        timeline, bounded by the same link budget as KV prefetch."""
        if self.rebalancer is None or not self.cfg.expert_migrations:
            return
        store = self.rebalancer.store
        ch = channel_name(Tier.HOST_DRAM, Tier.PEER_HBM)
        done = 0
        lane_load = 0.0
        pending: List[Transfer] = []
        for eid in self.rebalancer.plan_promotions(
                self.cfg.expert_migrations * 4):
            if done >= self.cfg.expert_migrations:
                break
            if self.planner is not None:
                est = self.planner.projected_lane_s(
                    store.table[eid].nbytes, Tier.HOST_DRAM, Tier.PEER_HBM,
                    first_on_lane=not pending)
            else:
                est = self.te.estimate(store.table[eid].nbytes,
                                       Tier.HOST_DRAM, Tier.PEER_HBM)
            if self.te.channel_busy_until(ch) + lane_load + est > budget_end:
                self.stats["skipped_budget"] += 1
                break
            op = store.promote_to_peer(eid)
            if not op:
                break
            ops = op if isinstance(op, list) else [op]
            if self.planner is not None:
                pending.extend(ops)
                lane_load += est
            else:
                for o in ops:
                    self.te.submit(o)
            done += 1
        if pending:
            self.planner.submit(pending)
        self.stats["expert_promotions"] += done

    # ----------------------------------------------------------- outcome
    def claim(self, bid: ObjectKey) -> Optional[Transfer]:
        """A step is about to read ``bid``: if it was prefetched, count the
        hit and hand the transfer back so the step can wait on it."""
        tr = self.inflight.pop(bid, None)
        if tr is not None:
            self.stats["hits"] += 1
        return tr

    def on_evict(self, bid: ObjectKey) -> None:
        """The block left local HBM before any read — the prefetch was
        wasted (and its slot churned for nothing)."""
        if self.inflight.pop(bid, None) is not None:
            self.stats["wasted"] += 1

    def cancel_owner(self, owner) -> None:
        """The owner's blocks were freed (request finished or its prefix is
        being recomputed) — unclaimed prefetches are waste."""
        for bid in [b for b in self.inflight
                    if isinstance(b, tuple) and b[0] == owner]:
            del self.inflight[bid]
            self.stats["wasted"] += 1
