"""Placement policies for the Harvest controller.

The paper's prototype uses best-fit; §3.2 names locality, fairness,
interference and stability as alternative objectives.  All are implemented
here as composable rankers: a policy orders candidate peer devices for a
request, the allocator takes the first that fits.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional


@dataclass(frozen=True)
class PlacementRequest:
    size: int
    client: str = "default"
    hints: dict = field(default_factory=dict)   # e.g. {"requester_device": 3}


class PlacementPolicy:
    def rank(self, devices: Dict[int, dict], req: PlacementRequest) -> List[int]:
        raise NotImplementedError

    def on_alloc(self, req: PlacementRequest, device_id: int) -> None:
        pass


class BestFitPolicy(PlacementPolicy):
    """Minimise leftover contiguous space (the paper's default)."""

    def rank(self, devices, req):
        fitting = [(d, v) for d, v in devices.items()
                   if v["largest_free"] >= req.size]
        fitting.sort(key=lambda kv: kv[1]["largest_free"] - req.size)
        return [d for d, _ in fitting]


class WorstFitPolicy(PlacementPolicy):
    """Maximise leftover space (lower fragmentation under churn)."""

    def rank(self, devices, req):
        fitting = [(d, v) for d, v in devices.items()
                   if v["largest_free"] >= req.size]
        fitting.sort(key=lambda kv: -(kv[1]["largest_free"] - req.size))
        return [d for d, _ in fitting]


class LocalityPolicy(PlacementPolicy):
    """Prefer ICI-adjacent peers (paper §8: topology-aware placement).

    Distance = ring hop count on the device ring; ties broken best-fit.
    """

    def __init__(self, num_devices: int):
        self.n = num_devices

    def _dist(self, a: int, b: int) -> int:
        d = abs(a - b) % self.n
        return min(d, self.n - d)

    def rank(self, devices, req):
        src = req.hints.get("requester_device", 0)
        fitting = [(d, v) for d, v in devices.items()
                   if v["largest_free"] >= req.size]
        fitting.sort(key=lambda kv: (self._dist(src, kv[0]),
                                     kv[1]["largest_free"] - req.size))
        return [d for d, _ in fitting]


class StabilityPolicy(PlacementPolicy):
    """Prefer peers with low budget churn (fewer future revocations)."""

    def rank(self, devices, req):
        fitting = [(d, v) for d, v in devices.items()
                   if v["largest_free"] >= req.size]
        fitting.sort(key=lambda kv: (kv[1]["churn"],
                                     kv[1]["largest_free"] - req.size))
        return [d for d, _ in fitting]


class TopologyAwarePolicy(PlacementPolicy):
    """Bandwidth-weighted best-fit that knows the interconnect (paper §8).

    Candidate devices are scored by the *expected cost of using them*:

      * the transfer time of this object over the device's own
        :class:`~repro.core.tiers.LinkSpec` (a striped 4-link ICI peer
        beats a distant single-path one; a PCIe-switch peer is a last
        resort);
      * a churn penalty — devices whose harvestable budget moves a lot
        (high EWMA of ``|budget delta|``) are likely to revoke, so the
        expected cost of placing there includes a re-fetch;
      * a spread penalty — recently chosen devices are deprioritised so
        concurrent placements fan out across link *lanes* instead of
        serialising on one peer's FIFO; hot objects (``hints["hot"]``)
        spread harder, because they are the ones whose reloads contend.

    Shared prefix-cache blocks (``hints["refs"] > 0`` — leased trie
    interiors) scale the churn penalty up by their reference count: a
    revocation there costs every future request that would have hit the
    prefix, so such blocks steer toward stable peers even when a churny
    one is nearer.

    Ties resolve best-fit (tightest remaining segment), so on a
    single-peer topology the ranking degenerates to the paper's default.
    """

    def __init__(self, topology, churn_weight: float = 4.0,
                 spread_weight: float = 0.5, decay: float = 0.5):
        self.topology = topology
        self.churn_weight = churn_weight
        self.spread_weight = spread_weight
        self.decay = decay
        #: runtime multiplier on the churn penalty — the stability
        #: controller raises it during revocation storms so placement
        #: backs off volatile peers; 1.0 (the default) is bit-exact with
        #: the pre-controller ranking
        self.churn_scale = 1.0
        self._recent: Dict[int, float] = {}   # EWMA of recent placements

    def rank(self, devices, req):
        from repro.core.tiers import Tier
        fitting = [(d, v) for d, v in devices.items()
                   if v["largest_free"] >= req.size]
        hot = 1.0 + float(req.hints.get("hot", 0.0) or 0.0)
        refs = 1.0 + float(req.hints.get("refs", 0) or 0)

        def score(d, v):
            t = self.topology.transfer_time(req.size, Tier.PEER_HBM,
                                            Tier.LOCAL_HBM, device=d)
            churn = v["churn"] / max(v["budget"], 1)
            lane = self._recent.get(d, 0.0)
            return t * (1.0 + self.churn_weight * self.churn_scale
                        * refs * churn
                        + self.spread_weight * hot * lane)

        fitting.sort(key=lambda kv: (score(*kv),
                                     kv[1]["largest_free"] - req.size))
        return [d for d, _ in fitting]

    def on_alloc(self, req, device_id):
        for d in list(self._recent):
            self._recent[d] *= self.decay
        self._recent[device_id] = self._recent.get(device_id, 0.0) + 1.0


class FairnessPolicy(PlacementPolicy):
    """Per-client byte budget wrapped around an inner policy."""

    def __init__(self, inner: PlacementPolicy, per_client_bytes: int):
        self.inner = inner
        self.cap = per_client_bytes
        self.usage: Dict[str, int] = {}

    def rank(self, devices, req):
        if self.usage.get(req.client, 0) + req.size > self.cap:
            return []
        return self.inner.rank(devices, req)

    def on_alloc(self, req, device_id):
        self.usage[req.client] = self.usage.get(req.client, 0) + req.size
        self.inner.on_alloc(req, device_id)

    def on_free(self, client: str, size: int):
        self.usage[client] = max(0, self.usage.get(client, 0) - size)


POLICIES = {
    "best_fit": BestFitPolicy,
    "worst_fit": WorstFitPolicy,
    "locality": LocalityPolicy,
    "stability": StabilityPolicy,
    "topology": TopologyAwarePolicy,     # requires a Topology argument
}


# ---------------------------------------------------------------------------
# fidelity policy (per-SLO-class demotion precision)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class FidelityPolicy:
    """What precision a KV block demotes at, per SLO class.

    ``mode``:
      off    — every demotion stays FP16 (the seed behaviour).
      slo    — per-class mapping: latency-class blocks keep FP16 (their
               tokens must be bit-identical to the fidelity-off baseline),
               throughput/batch classes quantize on demote and dequantize
               on critical reload.  Shared prefix-trie blocks keep
               ``shared`` fidelity (FP16 by default) because one quantized
               demotion would degrade every future adopter of the prefix,
               including latency-class hits.
      always — every demotion (shared blocks included) rides ``batch``'s
               fidelity; the maximum-capacity setting for offline fleets.
    """
    mode: str = "slo"
    latency: "Fidelity" = None           # type: ignore[assignment]
    throughput: "Fidelity" = None        # type: ignore[assignment]
    batch: "Fidelity" = None             # type: ignore[assignment]
    shared: "Fidelity" = None            # type: ignore[assignment]

    def __post_init__(self):
        from repro.core.tiers import Fidelity
        if self.mode not in ("off", "slo", "always"):
            raise ValueError(f"FidelityPolicy: unknown mode {self.mode!r} — "
                             "one of ('off', 'slo', 'always')")
        defaults = {"latency": Fidelity.FP16, "throughput": Fidelity.INT8,
                    "batch": Fidelity.INT8, "shared": Fidelity.FP16}
        for name, default in defaults.items():
            val = getattr(self, name)
            if val is None:
                object.__setattr__(self, name, default)
            elif not isinstance(val, Fidelity):
                raise TypeError(f"FidelityPolicy.{name}: expected a "
                                f"Fidelity, got {val!r}")

    def fidelity_for(self, slo: Optional[str],
                     shared: bool = False) -> "Fidelity":
        """The demotion fidelity for a block owned by an ``slo``-class
        request (``shared=True`` for prefix-trie content blocks)."""
        from repro.core.tiers import Fidelity
        if self.mode == "off":
            return Fidelity.FP16
        if self.mode == "always":
            return self.batch
        if shared:
            return self.shared
        return {"latency": self.latency, "throughput": self.throughput,
                "batch": self.batch}.get(slo or "", Fidelity.FP16)


def _fidelity_policy_presets() -> Dict[str, FidelityPolicy]:
    return {
        "off": FidelityPolicy(mode="off"),
        "slo": FidelityPolicy(mode="slo"),
        "always": FidelityPolicy(mode="always"),
    }


#: CLI-facing presets (``--fidelity-policy`` on launch/serve.py)
FIDELITY_POLICIES = _fidelity_policy_presets()
