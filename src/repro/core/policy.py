"""Placement policies for the Harvest controller.

The paper's prototype uses best-fit; §3.2 names locality, fairness,
interference and stability as alternative objectives.  All are implemented
here as composable rankers: a policy orders candidate peer devices for a
request, the allocator takes the first that fits.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional


@dataclass(frozen=True)
class PlacementRequest:
    size: int
    client: str = "default"
    hints: dict = field(default_factory=dict)   # e.g. {"requester_device": 3}


class PlacementPolicy:
    def rank(self, devices: Dict[int, dict], req: PlacementRequest) -> List[int]:
        raise NotImplementedError

    def on_alloc(self, req: PlacementRequest, device_id: int) -> None:
        pass


class BestFitPolicy(PlacementPolicy):
    """Minimise leftover contiguous space (the paper's default)."""

    def rank(self, devices, req):
        fitting = [(d, v) for d, v in devices.items()
                   if v["largest_free"] >= req.size]
        fitting.sort(key=lambda kv: kv[1]["largest_free"] - req.size)
        return [d for d, _ in fitting]


class WorstFitPolicy(PlacementPolicy):
    """Maximise leftover space (lower fragmentation under churn)."""

    def rank(self, devices, req):
        fitting = [(d, v) for d, v in devices.items()
                   if v["largest_free"] >= req.size]
        fitting.sort(key=lambda kv: -(kv[1]["largest_free"] - req.size))
        return [d for d, _ in fitting]


class LocalityPolicy(PlacementPolicy):
    """Prefer ICI-adjacent peers (paper §8: topology-aware placement).

    Distance = ring hop count on the device ring; ties broken best-fit.
    """

    def __init__(self, num_devices: int):
        self.n = num_devices

    def _dist(self, a: int, b: int) -> int:
        d = abs(a - b) % self.n
        return min(d, self.n - d)

    def rank(self, devices, req):
        src = req.hints.get("requester_device", 0)
        fitting = [(d, v) for d, v in devices.items()
                   if v["largest_free"] >= req.size]
        fitting.sort(key=lambda kv: (self._dist(src, kv[0]),
                                     kv[1]["largest_free"] - req.size))
        return [d for d, _ in fitting]


class StabilityPolicy(PlacementPolicy):
    """Prefer peers with low budget churn (fewer future revocations)."""

    def rank(self, devices, req):
        fitting = [(d, v) for d, v in devices.items()
                   if v["largest_free"] >= req.size]
        fitting.sort(key=lambda kv: (kv[1]["churn"],
                                     kv[1]["largest_free"] - req.size))
        return [d for d, _ in fitting]


class FairnessPolicy(PlacementPolicy):
    """Per-client byte budget wrapped around an inner policy."""

    def __init__(self, inner: PlacementPolicy, per_client_bytes: int):
        self.inner = inner
        self.cap = per_client_bytes
        self.usage: Dict[str, int] = {}

    def rank(self, devices, req):
        if self.usage.get(req.client, 0) + req.size > self.cap:
            return []
        return self.inner.rank(devices, req)

    def on_alloc(self, req, device_id):
        self.usage[req.client] = self.usage.get(req.client, 0) + req.size
        self.inner.on_alloc(req, device_id)

    def on_free(self, client: str, size: int):
        self.usage[client] = max(0, self.usage.get(client, 0) - size)


POLICIES = {
    "best_fit": BestFitPolicy,
    "worst_fit": WorstFitPolicy,
    "locality": LocalityPolicy,
    "stability": StabilityPolicy,
}
