"""Harvest core: the paper's contribution as a composable JAX-side runtime.

Public API (construct these):
  runtime     — HarvestRuntime: the facade composing allocator + monitor +
                policy + store; every entry point builds one of these
  store       — HarvestStore: generic tiered-object residency (local/peer/
                host/LOST), durability classes, TransferEngine, metrics

Components (the runtime wires these for you):
  allocator   — harvest_alloc / harvest_free / harvest_register_cb + revocation
  policy      — best-fit (paper default), locality, fairness, stability
  monitor     — peer-availability monitor + Fig-2-calibrated cluster trace
  tiers       — local HBM / peer HBM / host DRAM cost model + interconnect
                Topology presets (2-GPU NVLink, NVLink mesh, PCIe switch,
                v5e 2D-torus ICI) with per-peer-device LinkSpecs
  rebalancer  — MoE expert residency, a thin store client (paper §4)
  kv_manager  — paged KV unified block table, a thin store client (paper §5)
  coalesce    — transfer coalescing (one setup per lane per step) + chunked
                multi-lane striping of large objects, between placement
                and the transfer timeline
  prefetch    — cross-step speculative reloads issued under compute windows
                on the TransferEngine's event timeline
  prefix_cache — harvested prefix cache: radix-trie cross-request KV
                sharing over the HarvestStore (content-addressed,
                refcounted, publish-on-retire)
  paged_attention — tier-aware flash-decode partials + LSE merge
  simulator   — CGOPipe pipeline model reproducing Fig 5/6
"""
from repro.core.allocator import HarvestAllocator, HarvestHandle, RevokedError
from repro.core.coalesce import CoalesceConfig, TransferPlanner
from repro.core.kv_manager import (BlockEntry, KVOffloadManager, ReloadOp,
                                   ReloadPlan)
from repro.core.monitor import ClusterTrace, ClusterTraceConfig, PeerMonitor
from repro.core.policy import (FIDELITY_POLICIES, BestFitPolicy,
                               FairnessPolicy, FidelityPolicy, LocalityPolicy,
                               PlacementRequest, StabilityPolicy,
                               TopologyAwarePolicy, WorstFitPolicy)
from repro.core.prefetch import Prefetcher, PrefetchConfig
from repro.core.prefix_cache import (PrefixCache, PrefixCacheConfig,
                                     block_digests)
from repro.core.rebalancer import ExpertRebalancer
from repro.core.runtime import HarvestRuntime
from repro.core.simulator import (AccessModelConfig, ExpertAccessModel,
                                  SimResult, simulate_moe_decode)
from repro.core.store import (Durability, HarvestStore, LostObjectError,
                              MetricsRegistry, ObjectEntry, Residency,
                              Transfer, TransferEngine, channel_name)
from repro.core.tiers import (HARDWARE, H100_DCN_LINK, H100_NVLINK,
                              TOPOLOGIES, TPU_V5E, V5E_DCN_LINK, Fidelity,
                              HardwareModel, LinkSpec, Tier, Topology,
                              expert_bytes, get_topology, h100_dcn,
                              kv_block_bytes, kv_entry_bytes, multihost,
                              nvlink_2gpu, nvlink_mesh, pcie_switch,
                              tpu_v5e_torus, v5e_dcn)
