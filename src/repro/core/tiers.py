"""Memory-tier model, transfer cost model and interconnect topology.

The paper's cost model (Figures 3 and 7) is a bandwidth/latency model over
two links: host<->GPU over PCIe 5.0 and GPU<->GPU over 12 NVLink links.  Our
TPU adaptation keeps the same *structure* — a slow host link and a fast peer
link — with v5e-class constants.  Both parameter sets ship here so the paper
benchmarks (fig3/fig7) can run with the paper's hardware and the roofline
with the TPU's.

:class:`Topology` generalises the single fast/slow pair to an N-device
interconnect: every peer device has its own :class:`LinkSpec` from the
compute device, so transfers to distinct peers can ride distinct link
lanes in parallel and placement can trade link bandwidth against device
churn.  :class:`HardwareModel` (one anonymous peer) survives as the
2-device compat surface — ``Topology.link(..., device=None)`` degrades to
exactly ``HardwareModel.link``.

All times are seconds, sizes bytes.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple


class Tier(enum.Enum):
    LOCAL_HBM = "local"    # compute device HBM (authoritative for hot state)
    PEER_HBM = "peer"      # harvested peer-device HBM (transient, revocable)
    HOST_DRAM = "host"     # host memory (authoritative backing store)
    LOCAL_SSD = "ssd"      # local NVMe cold tier (capacity, not speed)


class Fidelity(enum.Enum):
    """Precision a KV block travels and parks at on the cold tiers.

    Full fidelity (FP16) is the wire format the seed shipped: a block's
    ``nbytes`` IS what moves.  The quantized fidelities shrink the wire
    and parking footprint by an integer ratio (per-block absmax scale —
    see ``kernels/harvest_copy``): INT8 and FP8-e4m3 halve a bf16 block,
    INT4 packs two weights per byte for a 4x cut.  The LOCAL slot always
    holds full precision — fidelity is a property of the *demoted* copy,
    cleared when the block is dequantized back on reload.
    """
    FP16 = "fp16"
    INT8 = "int8"
    FP8 = "fp8"
    INT4 = "int4"

    @property
    def ratio(self) -> Tuple[int, int]:
        """(numerator, denominator) of quantized-bytes / fp16-bytes."""
        return _FIDELITY_RATIO[self]

    @property
    def is_quantized(self) -> bool:
        return self is not Fidelity.FP16

    def wire_bytes(self, nbytes: int) -> int:
        """Bytes a block of full-precision size ``nbytes`` occupies at
        this fidelity: exact for FP16 (seed goldens stay bit-exact), the
        integer-ratio cut plus one f32 per-block scale otherwise."""
        if self is Fidelity.FP16:
            return int(nbytes)
        num, den = _FIDELITY_RATIO[self]
        return int(nbytes) * num // den + FIDELITY_SCALE_BYTES


_FIDELITY_RATIO = {
    Fidelity.FP16: (1, 1),
    Fidelity.INT8: (1, 2),
    Fidelity.FP8: (1, 2),
    Fidelity.INT4: (1, 4),
}

#: per-block quantization metadata (one f32 absmax scale) that rides the
#: wire with every quantized block
FIDELITY_SCALE_BYTES = 4


@dataclass(frozen=True)
class LinkSpec:
    bandwidth: float       # bytes / second (effective, not marketing peak)
    latency: float         # per-transfer fixed cost (s)
    #: number of link-disjoint physical paths this link aggregates (12 NVLink
    #: links, 4 torus ICI paths).  ``bandwidth`` is the AGGREGATE across all
    #: paths; a single chunk stream striped onto one path sustains
    #: ``bandwidth / paths``.  The flat cost model ignores this — only the
    #: :class:`~repro.core.coalesce.TransferPlanner`'s chunked striping
    #: schedules individual paths.
    paths: int = 1

    def transfer_time(self, nbytes: int) -> float:
        return self.latency + nbytes / self.bandwidth

    @property
    def path_bandwidth(self) -> float:
        """Effective bandwidth of ONE of the link-disjoint paths."""
        return self.bandwidth / max(self.paths, 1)


# Local NVMe used when a preset does not calibrate its own: a datacenter
# gen4 drive sustains ~5 GB/s at ~120 us submission+seek overhead.
DEFAULT_SSD_LINK = LinkSpec(bandwidth=5e9, latency=120e-6)


@dataclass(frozen=True)
class HardwareModel:
    name: str
    peer_link: LinkSpec    # fast device<->device path
    host_link: LinkSpec    # device<->host path
    hbm_bw: float          # bytes/s local HBM
    peak_flops: float      # bf16 FLOP/s per chip
    hbm_bytes: int         # HBM capacity per device
    ssd_link: LinkSpec = DEFAULT_SSD_LINK   # local NVMe cold-tier path

    def link(self, src: Tier, dst: Tier) -> LinkSpec:
        pair = {src, dst}
        if pair == {Tier.LOCAL_HBM}:
            return LinkSpec(self.hbm_bw, 0.0)
        if Tier.LOCAL_SSD in pair:
            # SSD checked before host: a host->SSD spill and a
            # device->SSD writeback both bottleneck on the drive
            return self.ssd_link
        if Tier.HOST_DRAM in pair:
            return self.host_link
        return self.peer_link

    def transfer_time(self, nbytes: int, src: Tier, dst: Tier) -> float:
        return self.link(src, dst).transfer_time(nbytes)


# The paper's testbed: Azure NC80adis H100 v5 — 2x H100, PCIe 5.0,
# 12 NVLink links between the two GPUs.  Effective bandwidths/latencies are
# calibrated so the chunk-transfer microbenchmark (Fig 3) reproduces the
# paper's measured 7.5x (Phi-tiny expert, ~15 MiB) to 9.5x (Mixtral expert,
# ~336 MiB) peer/host speedup band: 12 NVLink4 links sustain ~425 GB/s with
# ~25 us transfer setup; PCIe5 x16 with driver staging sustains ~44 GB/s
# effective with ~110 us setup (pageable-copy staging dominates small sizes).
H100_NVLINK = HardwareModel(
    name="h100-nvlink-2gpu",
    peer_link=LinkSpec(bandwidth=425e9, latency=34.2e-6, paths=12),
    host_link=LinkSpec(bandwidth=44e9, latency=194e-6),
    hbm_bw=3.35e12,
    peak_flops=989e12,
    hbm_bytes=80 * 2**30,
    # local NVMe (gen5 datacenter drive behind the same PCIe switch as the
    # host path): ~6.5 GB/s effective sequential, ~110 us submission cost
    ssd_link=LinkSpec(bandwidth=6.5e9, latency=110e-6),
)

# TPU v5e-class chip (the production-mesh target of this repo).
# ICI: ~50 GB/s per link; a 2D-torus chip has 4 links, but a point-to-point
# fetch uses one path -> 45 GB/s effective single-path, 4x when striped.
# Host path: PCIe gen3-class host interconnect, ~16 GB/s effective.
TPU_V5E = HardwareModel(
    name="tpu-v5e",
    peer_link=LinkSpec(bandwidth=45e9, latency=6e-6),
    host_link=LinkSpec(bandwidth=16e9, latency=25e-6),
    hbm_bw=819e9,
    peak_flops=197e12,
    hbm_bytes=16 * 2**30,
    # host-attached NVMe over the shared gen3-class host interconnect:
    # ~3 GB/s effective, ~175 us submission cost
    ssd_link=LinkSpec(bandwidth=3e9, latency=175e-6),
)

HARDWARE = {m.name: m for m in (H100_NVLINK, TPU_V5E)}


# ---------------------------------------------------------------------------
# interconnect topology
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Topology:
    """An N-device interconnect: per-peer-device links from the compute device.

    ``hardware`` supplies the per-chip constants (local HBM, peak FLOPs,
    host link) and the *default* peer link used when a transfer names no
    device — the 2-device compat path, bit-exact with the flat
    :class:`HardwareModel` cost model.  ``peer_links`` maps each harvestable
    peer device id to the link it is reached over; distinct devices get
    distinct directional lanes in the
    :class:`~repro.core.store.TransferEngine`, so transfers to different
    peers pipeline in parallel on the simulated clock.

    Scale-out: ``device_hosts`` places peer devices on hosts (host 0 is the
    compute device's own host and the default for unmapped devices).  A
    device on a remote host is reached over that host's DCN link — its
    ``peer_links`` entry IS the inter-host :class:`LinkSpec` (the preset
    factories enforce this), so the flat cost model, coalescing, striping
    and fidelity wire-bytes all price DCN traffic with no special cases.
    The :class:`~repro.core.store.TransferEngine` gives each remote host a
    shared ``dcn{h}_in``/``dcn{h}_out`` lane pair (one NIC per host pair —
    a host's devices contend for it, unlike per-device NVLink lanes).
    """
    name: str
    hardware: HardwareModel
    peer_links: Dict[int, LinkSpec] = field(default_factory=dict)
    #: peer device id -> host index (0 = the compute device's host).
    device_hosts: Dict[int, int] = field(default_factory=dict)
    #: remote host index -> DCN link from host 0 to that host.
    dcn_links: Dict[int, LinkSpec] = field(default_factory=dict)

    def __post_init__(self):
        for d, h in self.device_hosts.items():
            if d not in self.peer_links:
                raise ValueError(f"device_hosts names unknown device {d}")
            if h and h not in self.dcn_links:
                raise ValueError(f"device {d} on host {h} but no dcn_links "
                                 f"entry for host {h}")

    @property
    def devices(self) -> Tuple[int, ...]:
        """Harvestable peer device ids, ascending."""
        return tuple(sorted(self.peer_links))

    @property
    def num_peers(self) -> int:
        return len(self.peer_links)

    # ------------------------------------------------------------- hosts
    @property
    def hosts(self) -> Tuple[int, ...]:
        """All host indices, ascending (host 0 always present)."""
        return tuple(sorted({0, *self.device_hosts.values()}))

    @property
    def num_hosts(self) -> int:
        return len(self.hosts)

    def host_of(self, device: Optional[int]) -> int:
        """Host a peer device lives on (0 = local host, the default)."""
        if device is None:
            return 0
        return self.device_hosts.get(device, 0)

    def devices_on(self, host: int) -> Tuple[int, ...]:
        """Peer device ids on one host, ascending."""
        return tuple(d for d in self.devices if self.host_of(d) == host)

    def dcn_link(self, host: int) -> LinkSpec:
        """The DCN link from host 0 to a remote host."""
        return self.dcn_links[host]

    def peer_link(self, device: Optional[int] = None) -> LinkSpec:
        if device is None:
            return self.hardware.peer_link
        return self.peer_links.get(device, self.hardware.peer_link)

    def link(self, src: Tier, dst: Tier,
             device: Optional[int] = None) -> LinkSpec:
        pair = {src, dst}
        if pair == {Tier.LOCAL_HBM}:
            return LinkSpec(self.hardware.hbm_bw, 0.0)
        if Tier.LOCAL_SSD in pair:
            return self.hardware.ssd_link
        if Tier.HOST_DRAM in pair:
            return self.hardware.host_link
        return self.peer_link(device)

    def transfer_time(self, nbytes: int, src: Tier, dst: Tier,
                      device: Optional[int] = None) -> float:
        return self.link(src, dst, device).transfer_time(nbytes)

    def device_budgets(self, harvestable_bytes: int) -> Dict[int, int]:
        """Uniform per-peer harvestable budget map (allocator constructor
        shorthand for the presets)."""
        return {d: int(harvestable_bytes) for d in self.devices}


def nvlink_2gpu() -> Topology:
    """The paper's testbed: 2x H100, all 12 NVLink links to the single peer.
    This is the compat preset — one peer (device 1), the same link constants
    as :data:`H100_NVLINK`, and the legacy ``peer_in``/``peer_out`` lane
    names, so seed goldens stay bit-exact."""
    return Topology("h100-nvlink-2gpu",
                    H100_NVLINK, {1: H100_NVLINK.peer_link})


def nvlink_mesh(num_peers: int) -> Topology:
    """NVSwitch-fabric mesh (HGX board or NVLink-switched domain): every
    peer reachable at full per-pair NVLink bandwidth, so the fabric's
    parallelism is across *lanes*, not shared bandwidth.  ``num_peers=1``
    coincides with the 2-GPU preset's link constants; 8 peers model one
    compute GPU harvesting a 9-GPU NVLink domain (switched domains span
    boards — NVL-class racks reach 72)."""
    if not 1 <= num_peers <= 16:
        raise ValueError(f"num_peers={num_peers}: cap one NVLink-switched "
                         "domain at 16 peers here (NVL72-scale domains "
                         "deserve their own calibrated preset)")
    return Topology(f"h100-nvlink-mesh-{num_peers + 1}gpu", H100_NVLINK,
                    {d: H100_NVLINK.peer_link
                     for d in range(1, num_peers + 1)})


# PCIe-switch peer path: no NVLink — peer DMA hops through a shared PCIe5
# switch.  Effective per-pair bandwidth is the switch's x16 share with P2P
# overheads (~26 GB/s) and setup cost close to the host path's.
PCIE_P2P_LINK = LinkSpec(bandwidth=26e9, latency=150e-6)


def pcie_switch(num_peers: int) -> Topology:
    """Fallback topology for boxes without NVLink: peers behind one PCIe
    switch.  Distinct devices still get distinct duplex lanes (the switch
    is non-blocking for disjoint endpoint pairs) but each lane is slow."""
    return Topology(f"pcie-switch-{num_peers + 1}gpu", H100_NVLINK,
                    {d: PCIE_P2P_LINK for d in range(1, num_peers + 1)})


def tpu_v5e_torus(grid: Tuple[int, int] = (2, 2),
                  stripe: bool = True) -> Topology:
    """TPU v5e 2D-torus ICI.  The compute chip sits at (0, 0); every other
    chip in the ``grid`` is a harvestable peer.  A point-to-point fetch on
    one ICI path sustains ~45 GB/s; with ``stripe`` the transfer is striped
    over the torus's 4 link-disjoint paths (4x bandwidth — the
    production-mesh configuration).  Per-hop switching adds latency, so
    distant peers are reachable but measurably worse — exactly the gradient
    topology-aware placement exploits."""
    nx, ny = grid
    if nx * ny < 2:
        raise ValueError(f"grid {grid}: need at least one peer chip")
    base = TPU_V5E.peer_link
    links: Dict[int, LinkSpec] = {}
    for x in range(nx):
        for y in range(ny):
            if (x, y) == (0, 0):
                continue
            hops = min(x, nx - x) + min(y, ny - y)   # torus wrap-around
            bw = base.bandwidth * (4 if stripe else 1)
            links[x * ny + y] = LinkSpec(bandwidth=bw,
                                         latency=base.latency * hops,
                                         paths=4 if stripe else 1)
    return Topology(f"tpu-v5e-torus-{nx}x{ny}" + ("-striped" if stripe else ""),
                    TPU_V5E, links)


# ---------------------------------------------------------------------------
# multi-host (DCN) presets
# ---------------------------------------------------------------------------

# Inter-host datacenter-network links.  A GPU cluster's 4x400G RDMA rails
# sustain ~50 GB/s effective between one host pair at ~12 us setup
# (GPUDirect-RDMA, QP already established), spread over many switch-disjoint
# paths — so chunked striping keeps paying off across hosts.  The TPU-pod
# DCN path is slimmer: ~25 GB/s effective at ~30 us.
H100_DCN_LINK = LinkSpec(bandwidth=50e9, latency=12e-6, paths=16)
V5E_DCN_LINK = LinkSpec(bandwidth=25e9, latency=30e-6, paths=8)


def multihost(base: Topology, num_hosts: int, remote_peers: int,
              dcn: LinkSpec, name: Optional[str] = None) -> Topology:
    """Extend a single-host topology with ``num_hosts - 1`` remote hosts,
    each contributing ``remote_peers`` harvestable devices over one shared
    ``dcn`` link.  Remote device ids continue densely after the local ones;
    their ``peer_links`` entry is the DCN spec itself so every existing
    cost-model seam (flat estimate, coalesce, stripe, fidelity wire bytes)
    prices them correctly with no special-casing."""
    if num_hosts < 2:
        raise ValueError(f"num_hosts={num_hosts}: need at least one remote "
                         "host (use the single-host preset otherwise)")
    links = dict(base.peer_links)
    device_hosts = dict(base.device_hosts)
    nxt = max(links, default=0) + 1
    for h in range(1, num_hosts):
        for _ in range(remote_peers):
            links[nxt] = dcn
            device_hosts[nxt] = h
            nxt += 1
    dcn_links = {h: dcn for h in range(1, num_hosts)}
    return Topology(name or f"{base.name}-{num_hosts}host",
                    base.hardware, links, device_hosts, dcn_links)


def h100_dcn(num_hosts: int = 2, local_peers: int = 1,
             remote_peers: int = 3) -> Topology:
    """Scale-out H100 preset: one NVLink domain plus ``num_hosts - 1``
    remote hosts harvested over the RDMA fabric.  Each remote host exposes
    ``remote_peers`` idle GPUs whose HBM is reachable at DCN cost; all of a
    host's devices share that host's ``dcn{h}`` lane pair."""
    return multihost(nvlink_mesh(local_peers) if local_peers > 1
                     else nvlink_2gpu(),
                     num_hosts, remote_peers, H100_DCN_LINK,
                     name=f"h100-dcn-{num_hosts}host")


def v5e_dcn(num_hosts: int = 2, remote_peers: int = 3) -> Topology:
    """Scale-out TPU v5e preset: one 2x2 ICI torus plus remote v5e hosts
    over the pod DCN."""
    return multihost(tpu_v5e_torus((2, 2)), num_hosts, remote_peers,
                     V5E_DCN_LINK, name=f"v5e-dcn-{num_hosts}host")


#: CLI-facing presets (``--topology`` on launch/serve.py, fig8 sweeps).
TOPOLOGIES = {
    "nvlink-2gpu": nvlink_2gpu,
    "nvlink-mesh-4": lambda: nvlink_mesh(3),
    "nvlink-mesh-8": lambda: nvlink_mesh(7),
    "pcie-switch-4": lambda: pcie_switch(3),
    "v5e-torus-2x2": lambda: tpu_v5e_torus((2, 2)),
    "v5e-torus-4x2": lambda: tpu_v5e_torus((4, 2)),
    "h100-dcn-2host": lambda: h100_dcn(2),
    "h100-dcn-4host": lambda: h100_dcn(4),
    "v5e-dcn-2host": lambda: v5e_dcn(2),
    "v5e-dcn-4host": lambda: v5e_dcn(4),
}


def get_topology(name: str) -> Topology:
    try:
        return TOPOLOGIES[name]()
    except KeyError:
        raise KeyError(f"unknown topology {name!r} — one of "
                       f"{sorted(TOPOLOGIES)}") from None


def expert_bytes(cfg, dtype_bytes: int = 2) -> int:
    """Size of one expert's parameters (the unit the Expert Rebalancer moves)."""
    mc = cfg.moe
    mats = 3 if cfg.gated_mlp else 2
    return mats * cfg.d_model * mc.d_ff_expert * dtype_bytes


def kv_entry_bytes(num_layers: int, num_kv_heads: int, head_dim: int,
                   dtype_bytes: int = 2) -> int:
    """Bytes of one token's KV across all layers (the paper's 'KV cache entry')."""
    return num_layers * 2 * num_kv_heads * head_dim * dtype_bytes


def kv_block_bytes(cfg, block_size: int, dtype_bytes: int = 2) -> int:
    from repro.models.model import num_kv_layers
    return kv_entry_bytes(num_kv_layers(cfg), cfg.num_kv_heads,
                          cfg.resolved_head_dim, dtype_bytes) * block_size
