"""Memory-tier model and transfer cost model.

The paper's cost model (Figures 3 and 7) is a bandwidth/latency model over
two links: host<->GPU over PCIe 5.0 and GPU<->GPU over 12 NVLink links.  Our
TPU adaptation keeps the same *structure* — a slow host link and a fast peer
link — with v5e-class constants.  Both parameter sets ship here so the paper
benchmarks (fig3/fig7) can run with the paper's hardware and the roofline
with the TPU's.

All times are seconds, sizes bytes.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass


class Tier(enum.Enum):
    LOCAL_HBM = "local"    # compute device HBM (authoritative for hot state)
    PEER_HBM = "peer"      # harvested peer-device HBM (transient, revocable)
    HOST_DRAM = "host"     # host memory (authoritative backing store)


@dataclass(frozen=True)
class LinkSpec:
    bandwidth: float       # bytes / second (effective, not marketing peak)
    latency: float         # per-transfer fixed cost (s)

    def transfer_time(self, nbytes: int) -> float:
        return self.latency + nbytes / self.bandwidth


@dataclass(frozen=True)
class HardwareModel:
    name: str
    peer_link: LinkSpec    # fast device<->device path
    host_link: LinkSpec    # device<->host path
    hbm_bw: float          # bytes/s local HBM
    peak_flops: float      # bf16 FLOP/s per chip
    hbm_bytes: int         # HBM capacity per device

    def link(self, src: Tier, dst: Tier) -> LinkSpec:
        pair = {src, dst}
        if pair == {Tier.LOCAL_HBM}:
            return LinkSpec(self.hbm_bw, 0.0)
        if Tier.HOST_DRAM in pair:
            return self.host_link
        return self.peer_link

    def transfer_time(self, nbytes: int, src: Tier, dst: Tier) -> float:
        return self.link(src, dst).transfer_time(nbytes)


# The paper's testbed: Azure NC80adis H100 v5 — 2x H100, PCIe 5.0,
# 12 NVLink links between the two GPUs.  Effective bandwidths/latencies are
# calibrated so the chunk-transfer microbenchmark (Fig 3) reproduces the
# paper's measured 7.5x (Phi-tiny expert, ~15 MiB) to 9.5x (Mixtral expert,
# ~336 MiB) peer/host speedup band: 12 NVLink4 links sustain ~425 GB/s with
# ~25 us transfer setup; PCIe5 x16 with driver staging sustains ~44 GB/s
# effective with ~110 us setup (pageable-copy staging dominates small sizes).
H100_NVLINK = HardwareModel(
    name="h100-nvlink-2gpu",
    peer_link=LinkSpec(bandwidth=425e9, latency=34.2e-6),
    host_link=LinkSpec(bandwidth=44e9, latency=194e-6),
    hbm_bw=3.35e12,
    peak_flops=989e12,
    hbm_bytes=80 * 2**30,
)

# TPU v5e-class chip (the production-mesh target of this repo).
# ICI: ~50 GB/s per link; a 2D-torus chip has 4 links, but a point-to-point
# fetch uses one path -> 45 GB/s effective single-path, 4x when striped.
# Host path: PCIe gen3-class host interconnect, ~16 GB/s effective.
TPU_V5E = HardwareModel(
    name="tpu-v5e",
    peer_link=LinkSpec(bandwidth=45e9, latency=6e-6),
    host_link=LinkSpec(bandwidth=16e9, latency=25e-6),
    hbm_bw=819e9,
    peak_flops=197e12,
    hbm_bytes=16 * 2**30,
)

HARDWARE = {m.name: m for m in (H100_NVLINK, TPU_V5E)}


def expert_bytes(cfg, dtype_bytes: int = 2) -> int:
    """Size of one expert's parameters (the unit the Expert Rebalancer moves)."""
    mc = cfg.moe
    mats = 3 if cfg.gated_mlp else 2
    return mats * cfg.d_model * mc.d_ff_expert * dtype_bytes


def kv_entry_bytes(num_layers: int, num_kv_heads: int, head_dim: int,
                   dtype_bytes: int = 2) -> int:
    """Bytes of one token's KV across all layers (the paper's 'KV cache entry')."""
    return num_layers * 2 * num_kv_heads * head_dim * dtype_bytes


def kv_block_bytes(cfg, block_size: int, dtype_bytes: int = 2) -> int:
    from repro.models.model import num_kv_layers
    return kv_entry_bytes(num_kv_layers(cfg), cfg.num_kv_heads,
                          cfg.resolved_head_dim, dtype_bytes) * block_size
