"""HarvestRuntime — the facade composing allocator + monitor + policy + store.

Every entry point (serving engine, pipeline simulator, launchers,
benchmarks, examples) constructs ONE of these instead of hand-wiring the
four components.  The runtime owns:

  * the :class:`HarvestAllocator` (peer budgets + placement policy),
  * the :class:`TransferEngine` (all simulated transfer accounting),
  * the :class:`MetricsRegistry` (one namespaced counter store for the
    allocator, every client store, and the transfer engine),
  * optionally a :class:`PeerMonitor` driving revocations from a cluster
    trace,
  * a registry of per-client :class:`HarvestStore` instances.

Clients are factories on the runtime: ``runtime.kv_manager(...)`` and
``runtime.rebalancer(...)`` return the paper's two applications already
wired into the shared allocator / transfer engine / metrics; new object
classes (SSM states, prefix caches, LoRA adapters) use
``runtime.create_store(...)`` directly and get the same residency ladder,
revocation handling and accounting for free.

    runtime = HarvestRuntime(device_budgets={0: 8 << 30, 1: 8 << 30},
                             hardware=H100_NVLINK,
                             trace_config=ClusterTraceConfig(num_devices=2))
    kv = runtime.kv_manager(cfg, block_size=16, num_local_slots=64)
    reb = runtime.rebalancer(cfg, local_fraction=0.5)
    runtime.tick()                      # external pressure -> revocations
    print(runtime.stats())              # unified metrics snapshot
"""
from __future__ import annotations

from typing import Dict, Optional

from repro.configs.base import ModelConfig
from repro.core.allocator import HarvestAllocator
from repro.core.coalesce import CoalesceConfig, TransferPlanner
from repro.core.kv_manager import KVOffloadManager
from repro.core.monitor import ClusterTrace, ClusterTraceConfig, PeerMonitor
from repro.core.policy import PlacementPolicy
from repro.core.rebalancer import ExpertRebalancer
from repro.core.store import HarvestStore, MetricsRegistry, TransferEngine
from repro.core.tiers import H100_NVLINK, HardwareModel, Topology


class HarvestRuntime:
    def __init__(self, device_budgets: Optional[Dict[int, int]] = None, *,
                 hardware: Optional[HardwareModel] = None,
                 topology: Optional[Topology] = None,
                 policy: Optional[PlacementPolicy] = None,
                 allocator: Optional[HarvestAllocator] = None,
                 trace: Optional[ClusterTrace] = None,
                 trace_config: Optional[ClusterTraceConfig] = None,
                 monitor: Optional[PeerMonitor] = None,
                 reserve_bytes: int = 0,
                 monitor_interval_s: Optional[float] = None,
                 coalesce: Optional[CoalesceConfig] = None,
                 metrics: Optional[MetricsRegistry] = None):
        self.metrics = metrics or MetricsRegistry()
        if hardware is None:
            hardware = topology.hardware if topology else H100_NVLINK
        self.hardware = hardware
        self.topology = topology
        self.allocator = allocator or HarvestAllocator(
            dict(device_budgets or {}), policy=policy, metrics=self.metrics)
        self.transfers = TransferEngine(hardware, self.metrics,
                                        topology=topology)
        #: transfer coalescing/striping layer (None = per-object compat
        #: path): attached to every client store this runtime creates
        self.planner: Optional[TransferPlanner] = (
            TransferPlanner(self.transfers, coalesce, metrics=self.metrics)
            if coalesce is not None else None)
        if monitor is None and (trace is not None or trace_config is not None):
            trace = trace or ClusterTrace(trace_config)
            monitor = PeerMonitor(self.allocator, trace,
                                  capacity_bytes=trace.cfg.capacity_bytes,
                                  reserve_bytes=reserve_bytes,
                                  tick_interval_s=monitor_interval_s,
                                  metrics=self.metrics,
                                  devices=(list(topology.devices)
                                           if topology else None))
        self.monitor = monitor
        self.stores: Dict[str, HarvestStore] = {}
        self.clients: Dict[str, object] = {}

    # ----------------------------------------------------------- factories
    def create_store(self, client: str, **kwargs) -> HarvestStore:
        """A tiered store for a NEW object class — the extension seam."""
        store = HarvestStore(self.allocator, self.transfers, client=client,
                             metrics=self.metrics, **kwargs)
        store.planner = self.planner
        self.stores[client] = store
        return store

    def kv_manager(self, cfg: ModelConfig, *, block_size: int,
                   num_local_slots: int, durability: str = "host_backed",
                   store_payload: bool = False, num_kv_layers: int = 0,
                   client: str = "kv", ssd_tier: bool = False,
                   host_capacity_bytes: Optional[int] = None
                   ) -> KVOffloadManager:
        """The paper's §5 application: paged KV cache entries."""
        mgr = KVOffloadManager(
            cfg, self.allocator, self.hardware, block_size, num_local_slots,
            durability=durability, store_payload=store_payload,
            num_kv_layers=num_kv_layers, client=client,
            transfers=self.transfers, metrics=self.metrics,
            ssd_tier=ssd_tier, host_capacity_bytes=host_capacity_bytes)
        mgr.store.planner = self.planner
        self.stores[client] = mgr.store
        self.clients[client] = mgr
        return mgr

    def rebalancer(self, cfg: ModelConfig, *, local_fraction: float = 0.5,
                   ewma: float = 0.8, client: str = "moe"
                   ) -> ExpertRebalancer:
        """The paper's §4 application: MoE expert weights."""
        reb = ExpertRebalancer(
            cfg, self.allocator, self.hardware, local_fraction=local_fraction,
            ewma=ewma, client=client, transfers=self.transfers,
            metrics=self.metrics)
        reb.store.planner = self.planner
        self.stores[client] = reb.store
        self.clients[client] = reb
        return reb

    def server(self, cfg: ModelConfig, params, **kwargs):
        """The request-lifecycle serving front door
        (:class:`~repro.serving.server.HarvestServer`) over this
        runtime: SLO-classed requests arriving on the transfer-engine
        clock, pluggable admission, per-request latency records.  Engine
        kwargs (``scheduler``, ``mode``, ``prefetch``, ``admission``,
        pool geometry, …) pass through."""
        from repro.serving.server import HarvestServer
        return HarvestServer(cfg, params, runtime=self, **kwargs)

    def prefetcher(self, kv_client: str = "kv",
                   moe_client: Optional[str] = None,
                   config=None):
        """A cross-step :class:`~repro.core.prefetch.Prefetcher` over this
        runtime's transfer timeline, wired to an existing KV client (and
        optionally the expert rebalancer for hot-expert promotion)."""
        from repro.core.prefetch import Prefetcher
        kv = self.clients[kv_client]
        reb = self.clients.get(moe_client) if moe_client else None
        return Prefetcher(kv, self.transfers, config, rebalancer=reb,
                          planner=self.planner, metrics=self.metrics)

    # ------------------------------------------------------------- control
    @property
    def clock(self) -> float:
        """The simulated time of this runtime's transfer timeline."""
        return self.transfers.now

    def drain(self, until: Optional[float] = None):
        """Complete in-flight transfers up to ``until`` (default: now)."""
        return self.transfers.drain_until(
            self.transfers.now if until is None else until)

    def tick(self, steps: int = 1) -> Optional[Dict[int, int]]:
        """Advance the availability monitor (external pressure -> budget
        updates -> revocations).  No-op without a monitor."""
        budgets = None
        if self.monitor is not None:
            for _ in range(steps):
                budgets = self.monitor.tick()
        return budgets

    def poll_pressure(self) -> int:
        """Timeline-driven pressure: let the monitor fire one trace tick
        per configured interval of simulated transfer-clock time.  Called
        by async-mode hosts at stage boundaries so revocations land
        mid-pipeline.  Returns the number of ticks fired."""
        if self.monitor is None:
            return 0
        return self.monitor.poll(self.transfers.now)

    # ------------------------------------------------------------- queries
    def stats(self) -> Dict[str, dict]:
        """One snapshot of every component's counters.  The ``device``
        namespace is the allocator's live per-device view (occupancy,
        budget, churn EWMA) flattened to ``dev{d}.{field}`` keys so it
        rides the same reporting pipeline as the counters."""
        out = self.metrics.snapshot()
        out.setdefault("allocator", dict(self.allocator.stats))
        # live per-store fidelity census: demoted copies currently resident
        # at a reduced precision (FP16-resident blocks are the baseline and
        # stay out of the snapshot so fidelity-off runs are unchanged)
        fid_blocks = {
            f"{name}.blocks_{f}": n
            for name, store in sorted(self.stores.items())
            for f, n in sorted(store.fidelity_counts().items())
            if n and f != "fp16"}
        if fid_blocks:
            out.setdefault("fid", {}).update(fid_blocks)
        out["device"] = {
            f"dev{d}.{k}": v
            for d, view in sorted(self.allocator.device_view().items())
            for k, v in sorted(view.items())}
        return out

    def tier_counts(self) -> Dict[str, Dict[str, int]]:
        return {name: store.tier_counts()
                for name, store in self.stores.items()}
