"""Peer-memory availability monitor + synthetic cluster trace.

The paper motivates Harvest with the Alibaba gpu-v2020 trace (Fig 2):
~68% of machines use <=20% of GPU memory and ~87% use <=50%.  We generate a
synthetic trace calibrated to those anchors — each device's external memory
usage is a mean-reverting (OU-like) walk around a base level drawn from a
three-band mixture, with Poisson job arrivals/departures producing the
step changes that trigger Harvest revocations.

The :class:`PeerMonitor` turns a trace into budget updates on the allocator:
harvestable = capacity - external_usage - reserve.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.allocator import HarvestAllocator

# Fig 2 anchor points: P(usage <= 0.2) ~= 0.68, P(usage <= 0.5) ~= 0.87.
BANDS = [
    (0.68, 0.02, 0.20),
    (0.19, 0.20, 0.50),
    (0.13, 0.50, 0.95),
]


@dataclass
class ClusterTraceConfig:
    num_devices: int = 8
    capacity_bytes: int = 16 * 2**30
    seed: int = 0
    # temporal dynamics
    mean_revert: float = 0.2       # OU pull toward the base level
    noise: float = 0.008           # fraction-of-capacity per step
    job_arrival_p: float = 0.015   # per device per step
    job_size_frac: Tuple[float, float] = (0.02, 0.12)
    job_lifetime: Tuple[int, int] = (5, 30)
    # volatility scales BOTH the OU noise and the job-arrival rate (the
    # fig8 sweep axis); correlation mixes a cluster-wide common shock into
    # every device's noise — real clusters schedule jobs in waves, so peer
    # budgets move together instead of independently.  Defaults reproduce
    # the legacy trace draw-for-draw.
    volatility: float = 1.0
    correlation: float = 0.0
    # synchronized multi-peer revocation storms: every ``storm_interval``
    # ticks, ALL devices gain ``storm_frac`` of capacity of external
    # usage for ``storm_duration`` ticks — the correlation axis pushed to
    # its limit (a cluster-wide scheduling wave that slams every peer
    # budget at once, the stability controller's adversarial scenario).
    # The schedule is deterministic (consumes NO rng draws), so the
    # default ``None`` keeps seeded legacy traces bit-exact.
    storm_interval: Optional[int] = None
    storm_duration: int = 4
    storm_frac: float = 0.5

    def __post_init__(self):
        if self.storm_interval is not None:
            if self.storm_interval <= 0:
                raise ValueError(f"storm_interval must be positive, got "
                                 f"{self.storm_interval}")
            if not 0 < self.storm_duration <= self.storm_interval:
                raise ValueError(
                    f"storm_duration must be in (0, storm_interval], got "
                    f"{self.storm_duration}")
            if not 0.0 < self.storm_frac <= 1.0:
                raise ValueError(f"storm_frac must be in (0, 1], got "
                                 f"{self.storm_frac}")


class ClusterTrace:
    """Synthetic per-device external memory usage over discrete time."""

    def __init__(self, cfg: ClusterTraceConfig):
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)
        w = np.array([b[0] for b in BANDS])
        band = self.rng.choice(len(BANDS), size=cfg.num_devices, p=w / w.sum())
        lo = np.array([BANDS[b][1] for b in band])
        hi = np.array([BANDS[b][2] for b in band])
        self.base = self.rng.uniform(lo, hi)
        # jobs ride ON TOP of the base level; recentre the base by the
        # expected steady-state job load so the *total* usage marginal stays
        # on the Fig 2 band mixture (arrival_p x mean size x mean lifetime).
        mean_size = 0.5 * (cfg.job_size_frac[0] + cfg.job_size_frac[1])
        mean_life = 0.5 * (cfg.job_lifetime[0] + cfg.job_lifetime[1])
        self._job_load = cfg.job_arrival_p * cfg.volatility \
            * mean_size * mean_life
        self.base = np.clip(self.base - self._job_load, 0.01, 1.0)
        self.level = self.base.copy()
        self.jobs: List[List[tuple]] = [[] for _ in range(cfg.num_devices)]
        self.t = 0

    def step(self) -> np.ndarray:
        """Advance one tick; returns external usage in bytes per device."""
        c = self.cfg
        self.t += 1
        # OU mean reversion + noise (optionally correlated across devices)
        self.level += c.mean_revert * (self.base - self.level)
        sigma = c.noise * c.volatility
        if c.correlation > 0.0:
            rho = min(c.correlation, 1.0)
            common = self.rng.normal(0.0, 1.0)
            idio = self.rng.normal(0.0, 1.0, size=len(self.level))
            self.level += sigma * (rho * common
                                   + np.sqrt(1.0 - rho * rho) * idio)
        else:
            # legacy draw sequence — keeps seeded traces bit-exact
            self.level += self.rng.normal(0, sigma, size=len(self.level))
        # job arrivals / departures (the revocation drivers)
        arrival_p = min(c.job_arrival_p * c.volatility, 1.0)
        for d in range(c.num_devices):
            self.jobs[d] = [(sz, end) for sz, end in self.jobs[d] if end > self.t]
            if self.rng.random() < arrival_p:
                sz = self.rng.uniform(*c.job_size_frac)
                life = self.rng.integers(*c.job_lifetime)
                self.jobs[d].append((sz, self.t + int(life)))
        job_usage = np.array([sum(sz for sz, _ in js) for js in self.jobs])
        usage = np.clip(self.level + job_usage, 0.0, 1.0)
        # synchronized storm window: a deterministic tick schedule (no rng
        # draws — disabled configs stay draw-for-draw legacy-exact)
        if c.storm_interval is not None \
                and self.t % c.storm_interval < c.storm_duration:
            usage = np.clip(usage + c.storm_frac, 0.0, 1.0)
        return (usage * c.capacity_bytes).astype(np.int64)

    def sample_usage_fractions(self, n_machines: int, n_snapshots: int = 100
                               ) -> np.ndarray:
        """Machine-level usage snapshots for the Fig 2 CDF benchmark."""
        rng = np.random.default_rng(self.cfg.seed + 1)
        w = np.array([b[0] for b in BANDS])
        band = rng.choice(len(BANDS), size=(n_snapshots, n_machines), p=w / w.sum())
        lo = np.take([b[1] for b in BANDS], band)
        hi = np.take([b[2] for b in BANDS], band)
        return rng.uniform(lo, hi)


class PeerMonitor:
    """Feeds trace ticks into the allocator as budget updates.

    Two drive modes:

      * **stepwise** (legacy): the host calls :meth:`tick` whenever it
        decides external pressure should advance — e.g. every N scheduler
        iterations, or between benchmark runs.
      * **timeline** (``tick_interval_s`` set): the host calls
        :meth:`poll` with the TransferEngine's simulated ``now`` at stage
        boundaries; the monitor fires one trace tick per elapsed interval.
        Pressure then lands *mid-pipeline* — a revocation can hit while
        the victim device's lanes still carry in-flight transfers, which
        is exactly the failure mode the paper's drain -> invalidate ->
        notify order exists for.
    """

    def __init__(self, allocator: HarvestAllocator, trace: ClusterTrace,
                 capacity_bytes: int, reserve_bytes: int = 0,
                 tick_interval_s: Optional[float] = None, metrics=None,
                 devices: Optional[List[int]] = None):
        self.allocator = allocator
        self.trace = trace
        self.capacity = capacity_bytes
        self.reserve = reserve_bytes
        self.tick_interval_s = tick_interval_s
        # trace row i drives allocator device devices[i]; the default keeps
        # the legacy identity mapping (devices 0..num_devices-1) — topology
        # presets number peers 1..N, so their hosts pass topology.devices
        self.devices = (list(devices) if devices is not None
                        else list(range(trace.cfg.num_devices)))
        if len(self.devices) != trace.cfg.num_devices:
            raise ValueError(
                f"device mapping ({len(self.devices)} devices: "
                f"{self.devices}) does not match the trace width "
                f"({trace.cfg.num_devices}) — a narrower trace would "
                "silently leave peers unpressured")
        self.revocation_log: List[tuple] = []
        self._last_poll: Optional[float] = None
        # duck-typed MetricsRegistry (avoids an import cycle with store)
        self.stats = (metrics.counters("monitor", keys=("ticks",
                                                        "revocations"))
                      if metrics is not None else None)

    def tick(self) -> Dict[int, int]:
        usage = self.trace.step()
        budgets = {}
        for dev, used in zip(self.devices, usage):
            budget = max(int(self.capacity - used - self.reserve), 0)
            revoked = self.allocator.update_budget(dev, budget)
            for h in revoked:
                self.revocation_log.append((self.trace.t, h))
            if self.stats is not None and revoked:
                self.stats["revocations"] += len(revoked)
                self.stats[f"dev{dev}.revocations"] += len(revoked)
            budgets[dev] = budget
        if self.stats is not None:
            self.stats["ticks"] += 1
        return budgets

    def poll(self, now: float) -> int:
        """Timeline drive: fire one tick per ``tick_interval_s`` of
        simulated time elapsed since the previous poll.  Returns the
        number of ticks fired.  No-op unless an interval is configured."""
        if self.tick_interval_s is None:
            return 0
        if self._last_poll is None:
            self._last_poll = now
            return 0
        n = int((now - self._last_poll) / self.tick_interval_s)
        for _ in range(n):
            self.tick()
        self._last_poll += n * self.tick_interval_s
        return n
