"""Training loop: jitted fused step + data pipeline + checkpointing."""
from __future__ import annotations

import functools
import time
from pathlib import Path
from typing import Optional

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.data.pipeline import SyntheticCorpus, make_batches
from repro.models import model as M
from repro.train.checkpoint import restore_checkpoint, save_checkpoint
from repro.train.optim import adamw_init, train_step


def cosine_lr(step: int, *, base: float, warmup: int, total: int,
              floor_frac: float = 0.1) -> float:
    if step < warmup:
        return base * (step + 1) / warmup
    t = (step - warmup) / max(total - warmup, 1)
    return base * (floor_frac + (1 - floor_frac) * 0.5 * (1 + np.cos(np.pi * t)))


def train(cfg: ModelConfig, *, steps: int, batch: int, seq_len: int,
          lr: float = 3e-4, seed: int = 0, rules=None,
          ckpt_dir: Optional[str] = None, ckpt_every: int = 200,
          log_every: int = 10, resume: Optional[str] = None):
    rng = jax.random.PRNGKey(seed)
    params = M.init_params(rng, cfg)
    opt = adamw_init(params)
    start_step = 0
    if resume:
        params, opt, start_step = restore_checkpoint(resume, params, opt)

    step_fn = jax.jit(
        functools.partial(train_step, cfg=cfg, rules=rules),
        donate_argnums=(0, 1))

    corpus = SyntheticCorpus(vocab_size=cfg.vocab_size, seed=seed)
    batches = make_batches(corpus, batch, seq_len)
    for _ in range(start_step):      # resume: fast-forward the data stream
        next(batches)
    history = []
    t0 = time.time()
    tokens_seen = 0
    for step in range(start_step, steps):
        b = next(batches)
        cur_lr = cosine_lr(step, base=lr, warmup=min(100, steps // 10 + 1),
                           total=steps)
        params, opt, metrics = step_fn(params, opt, b, lr=cur_lr)
        tokens_seen += batch * seq_len
        if step % log_every == 0 or step == steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            dt = time.time() - t0
            rec = dict(step=step, lr=cur_lr, tok_s=tokens_seen / max(dt, 1e-9),
                       **m)
            history.append(rec)
            print(f"step {step:5d}  loss {m['loss']:.4f}  nll {m['nll']:.4f}  "
                  f"gnorm {m['grad_norm']:.2f}  lr {cur_lr:.2e}  "
                  f"{rec['tok_s']:.0f} tok/s", flush=True)
        if ckpt_dir and (step + 1) % ckpt_every == 0:
            save_checkpoint(Path(ckpt_dir) / f"step_{step+1:06d}.npz",
                            params, opt, step + 1)
    if ckpt_dir:
        save_checkpoint(Path(ckpt_dir) / "final.npz", params, opt, steps)
    return params, opt, history
