"""AdamW optimizer (pytree-native) and the fused train step.

Optimizer state is kept in fp32 and shards exactly like the parameters
(the schema's PartitionSpecs apply leaf-for-leaf), which is what makes the
2D FSDP x tensor sharding hold for the full training footprint.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import model as M


class AdamWState(NamedTuple):
    step: jnp.ndarray     # () int32
    mu: Any               # first moment  (fp32, params-shaped)
    nu: Any               # second moment (fp32, params-shaped)


def adamw_init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                      nu=jax.tree.map(jnp.copy, zeros))


def adamw_update(params, grads, state: AdamWState, *, lr: float = 3e-4,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1, grad_clip: float = 1.0):
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-12))
    step = state.step + 1
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m_new = b1 * m + (1 - b1) * g
        v_new = b2 * v + (1 - b2) * jnp.square(g)
        update = (m_new / c1) / (jnp.sqrt(v_new / c2) + eps)
        update = update + weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * update
        return p_new.astype(p.dtype), m_new, v_new

    flat, treedef = jax.tree.flatten(params)
    gflat = jax.tree.leaves(grads)
    mflat = jax.tree.leaves(state.mu)
    vflat = jax.tree.leaves(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat, gflat, mflat, vflat)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_mu = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_nu = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_params, AdamWState(step, new_mu, new_nu), gnorm


def train_step(params, opt_state: AdamWState, batch, cfg, rules=None,
               lr: float = 3e-4):
    """One fused loss+grad+AdamW step (the dry-run's train lowering)."""
    (loss, metrics), grads = jax.value_and_grad(
        M.loss_fn, has_aux=True)(params, batch, cfg, rules)
    new_params, new_opt, gnorm = adamw_update(params, grads, opt_state, lr=lr)
    metrics = dict(metrics, loss=loss, grad_norm=gnorm)
    return new_params, new_opt, metrics
