"""Checkpointing: flat-key npz save/restore for params + optimizer state.

Path-keyed so a checkpoint survives schema reordering; no pickle, no
framework lock-in — a checkpoint is a plain npz any tool can read.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Any, Tuple

import jax
import numpy as np

from repro.train.optim import AdamWState


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.name == "bfloat16":      # npz cannot round-trip bf16
            key += "@bfloat16"
            arr = arr.view(np.uint16)
        flat[key] = arr
    return flat


def save_checkpoint(path, params, opt_state: AdamWState, step: int,
                    metadata: dict = None) -> None:
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    flat = {f"params/{k}": v for k, v in _flatten(params).items()}
    flat.update({f"opt/mu/{k}": v for k, v in _flatten(opt_state.mu).items()})
    flat.update({f"opt/nu/{k}": v for k, v in _flatten(opt_state.nu).items()})
    flat["opt/step"] = np.asarray(opt_state.step)
    flat["meta/step"] = np.asarray(step)
    np.savez(path, **flat)
    if metadata:
        Path(str(path) + ".json").write_text(json.dumps(metadata, indent=2))


def restore_checkpoint(path, params_template, opt_template: AdamWState
                       ) -> Tuple[Any, AdamWState, int]:
    """Restore into the template's structure (shapes are validated)."""
    data = np.load(path)

    def rebuild(template, prefix):
        flat_t = jax.tree_util.tree_flatten_with_path(template)
        leaves = []
        for path_t, leaf in flat_t[0]:
            key = prefix + "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                                    for p in path_t)
            if key in data:
                arr = data[key]
            else:                                   # bf16 stored as uint16
                import ml_dtypes
                arr = data[key + "@bfloat16"].view(ml_dtypes.bfloat16)
            assert arr.shape == tuple(leaf.shape), (key, arr.shape, leaf.shape)
            leaves.append(arr.astype(leaf.dtype))
        return jax.tree_util.tree_unflatten(flat_t[1], leaves)

    params = rebuild(params_template, "params/")
    opt = AdamWState(step=np.asarray(data["opt/step"]),
                     mu=rebuild(opt_template.mu, "opt/mu/"),
                     nu=rebuild(opt_template.nu, "opt/nu/"))
    return params, opt, int(data["meta/step"])
