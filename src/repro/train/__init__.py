from repro.train.optim import AdamWState, adamw_init, adamw_update, train_step
