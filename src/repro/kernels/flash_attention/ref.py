"""Pure-jnp oracle for the flash-attention kernel (materialised softmax)."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, sq: int, scale: Optional[float] = None,
                        sliding_window: Optional[int] = None,
                        attention_chunk: Optional[int] = None):
    """q: (B, gq*sq, hd);  k, v: (B, sk, hd) — same folding as the kernel."""
    B, qrows, hd = q.shape
    sk = k.shape[1]
    scale = hd ** -0.5 if scale is None else scale
    s = jnp.einsum("bqh,bkh->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    q_pos = (jnp.arange(qrows) % sq)[:, None]
    k_pos = jnp.arange(sk)[None, :]
    mask = k_pos <= q_pos
    if sliding_window is not None:
        mask &= k_pos > q_pos - sliding_window
    if attention_chunk is not None:
        mask &= (k_pos // attention_chunk) == (q_pos // attention_chunk)
    s = jnp.where(mask[None], s, NEG_INF)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = jnp.where(mask[None], p, 0.0)
    out = jnp.einsum("bqk,bkh->bqh", p, v.astype(jnp.float32))
    out = out / jnp.maximum(p.sum(-1, keepdims=True), 1e-30)
    return out.astype(q.dtype)
