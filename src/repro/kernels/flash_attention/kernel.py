"""Pallas TPU flash-attention (prefill path).

Grid: (batch*kv_heads, q_blocks, kv_blocks) with the kv dimension
"arbitrary" (sequential) so the online-softmax carry lives in VMEM scratch.
GQA is handled by folding the q-head group into the q rows: q arrives as
(b*nkv, gq*sq, hd) with the group-local position = row % sq, so one kv-head's
K/V block serves all of its gq query heads without materialising repeated KV.

Block shapes are MXU-aligned (last dim = head_dim, second-to-last multiples
of 128 where the model allows).  Masks (causal / sliding-window / chunked
local attention) are computed from global positions derived from program ids.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  sq: int, q_blk: int, kv_blk: int, n_kv_blocks: int,
                  scale: float, sliding_window: Optional[int],
                  attention_chunk: Optional[int]):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[...].astype(jnp.float32) * scale          # (q_blk, hd)
    k = k_ref[...].astype(jnp.float32)                  # (kv_blk, hd)
    v = v_ref[...].astype(jnp.float32)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (q_blk, kv_blk)

    # positions: q rows fold the GQA group — position = global_row % sq
    rows = qi * q_blk + jax.lax.broadcasted_iota(jnp.int32, (q_blk, kv_blk), 0)
    q_pos = rows % sq
    k_pos = ki * kv_blk + jax.lax.broadcasted_iota(jnp.int32, (q_blk, kv_blk), 1)
    mask = k_pos <= q_pos
    if sliding_window is not None:
        mask &= k_pos > q_pos - sliding_window
    if attention_chunk is not None:
        mask &= (k_pos // attention_chunk) == (q_pos // attention_chunk)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    p = jnp.where(mask, p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == n_kv_blocks - 1)
    def _finalize():
        o_ref[...] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
                      ).astype(o_ref.dtype)


def flash_attention(q, k, v, *, sq: int, scale: Optional[float] = None,
                    sliding_window: Optional[int] = None,
                    attention_chunk: Optional[int] = None,
                    q_block: int = 128, kv_block: int = 128,
                    interpret: bool = True):
    """q: (B, gq*sq, hd);  k, v: (B, sk, hd).  Returns (B, gq*sq, hd)."""
    B, qrows, hd = q.shape
    sk = k.shape[1]
    assert qrows % sq == 0, "q rows must fold the GQA group evenly"
    q_block = min(q_block, qrows)
    kv_block = min(kv_block, sk)
    assert qrows % q_block == 0, (qrows, q_block)
    assert sk % kv_block == 0, (sk, kv_block)
    n_q = qrows // q_block
    n_kv = sk // kv_block
    scale = hd ** -0.5 if scale is None else scale

    kern = functools.partial(
        _flash_kernel, sq=sq, q_blk=q_block, kv_blk=kv_block,
        n_kv_blocks=n_kv, scale=scale, sliding_window=sliding_window,
        attention_chunk=attention_chunk)

    return pl.pallas_call(
        kern,
        grid=(B, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((None, q_block, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((None, kv_block, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((None, kv_block, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((None, q_block, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, qrows, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_block, 1), jnp.float32),
            pltpu.VMEM((q_block, 1), jnp.float32),
            pltpu.VMEM((q_block, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
