"""Jit'd public wrapper for the flash-attention kernel.

``mha(q, k, v)`` takes model-layout tensors (b, s, n, hd) and handles the
GQA fold; on TPU the Pallas kernel runs compiled, elsewhere interpret=True
executes the same kernel body on CPU.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=(
    "sliding_window", "attention_chunk", "q_block", "kv_block", "interpret"))
def mha(q, k, v, *, sliding_window: Optional[int] = None,
        attention_chunk: Optional[int] = None, q_block: int = 128,
        kv_block: int = 128, interpret: Optional[bool] = None):
    """q: (b, sq, nq, hd);  k, v: (b, sk, nkv, hd) -> (b, sq, nq, hd)."""
    b, sq, nq, hd = q.shape
    sk, nkv = k.shape[1], k.shape[2]
    gq = nq // nkv
    interp = (not _on_tpu()) if interpret is None else interpret

    # fold: (b, sq, nkv, gq, hd) -> (b*nkv, gq*sq, hd)
    qf = q.reshape(b, sq, nkv, gq, hd).transpose(0, 2, 3, 1, 4)
    qf = qf.reshape(b * nkv, gq * sq, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(b * nkv, sk, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(b * nkv, sk, hd)

    of = flash_attention(qf, kf, vf, sq=sq, sliding_window=sliding_window,
                         attention_chunk=attention_chunk, q_block=q_block,
                         kv_block=kv_block, interpret=interp)
    o = of.reshape(b, nkv, gq, sq, hd).transpose(0, 3, 1, 2, 4)
    return o.reshape(b, sq, nq, hd)
