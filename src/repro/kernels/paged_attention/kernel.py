"""Pallas TPU paged decode attention over a Harvest KV block pool.

One grid step attends one request's query heads (for one kv head) to one
KV block resolved through the *block table* — the table is a scalar-prefetch
operand so the BlockSpec index_map can chase it (the TPU analogue of vLLM's
pointer-chasing PagedAttention).  The pool slot dimension is the unit the
Harvest KVOffloadManager moves between tiers; this kernel only ever sees
local-HBM-resident slots (fetch mode) — in-place peer attention merges
partials at the JAX level (core/paged_attention.py).

Grid: (b, nkv, max_blocks_per_req), last dim sequential with the
online-softmax carry in VMEM scratch.

Scalar operands:
  block_table: (b, max_blk) int32 pool-slot id per request block (-1 = none)
  q_pos:       (b,) int32 current decode position (masks unfilled tail)
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_kernel(tclamp_ref, table_ref, qpos_ref, q_ref, pk_ref, pv_ref, o_ref,
                  m_scr, l_scr, acc_scr, *, bs: int, n_blk: int, scale: float,
                  sliding_window: Optional[int],
                  attention_chunk: Optional[int]):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[...].astype(jnp.float32) * scale          # (gq, hd)
    k = pk_ref[...].astype(jnp.float32)                 # (bs, hd)
    v = pv_ref[...].astype(jnp.float32)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (gq, bs)

    qp = qpos_ref[b]
    pos = j * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)[0]
    valid = (pos <= qp) & (table_ref[b, j] >= 0)
    if sliding_window is not None:
        valid &= pos > qp - sliding_window
    if attention_chunk is not None:
        valid &= (pos // attention_chunk) == (qp // attention_chunk)
    s = jnp.where(valid[None, :], s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    p = jnp.where(valid[None, :], p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(j == n_blk - 1)
    def _finalize():
        o_ref[...] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
                      ).astype(o_ref.dtype)


def paged_attention(q, pool_k, pool_v, block_table, q_pos, *,
                    scale: Optional[float] = None,
                    sliding_window: Optional[int] = None,
                    attention_chunk: Optional[int] = None,
                    interpret: bool = True):
    """q: (b, nq, hd); pool_k/v: (n_slots, bs, nkv, hd);
    block_table: (b, max_blk) int32; q_pos: (b,) int32 -> (b, nq, hd)."""
    b, nq, hd = q.shape
    n_slots, bs, nkv, _ = pool_k.shape
    gq = nq // nkv
    max_blk = block_table.shape[1]
    scale = hd ** -0.5 if scale is None else scale

    qr = q.reshape(b, nkv, gq, hd)
    table_c = jnp.maximum(block_table, 0).astype(jnp.int32)

    kern = functools.partial(
        _paged_kernel, bs=bs, n_blk=max_blk, scale=scale,
        sliding_window=sliding_window, attention_chunk=attention_chunk)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b, nkv, max_blk),
        in_specs=[
            pl.BlockSpec((None, None, gq, hd),
                         lambda b, K, j, tc, t, qp: (b, K, 0, 0)),
            # chase the block table: slot = clamped_table[b, j]
            pl.BlockSpec((None, bs, None, hd),
                         lambda b, K, j, tc, t, qp: (tc[b, j], 0, K, 0)),
            pl.BlockSpec((None, bs, None, hd),
                         lambda b, K, j, tc, t, qp: (tc[b, j], 0, K, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, gq, hd),
                               lambda b, K, j, tc, t, qp: (b, K, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((gq, 1), jnp.float32),
            pltpu.VMEM((gq, 1), jnp.float32),
            pltpu.VMEM((gq, hd), jnp.float32),
        ],
    )

    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, nkv, gq, hd), q.dtype),
        interpret=interpret,
    )(table_c, block_table.astype(jnp.int32), q_pos.astype(jnp.int32),
      qr, pool_k, pool_v)
    return out.reshape(b, nq, hd)
