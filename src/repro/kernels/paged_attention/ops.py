"""Jit'd wrapper for the paged decode-attention kernel."""
from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.kernels.paged_attention.kernel import paged_attention


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=(
    "sliding_window", "attention_chunk", "interpret"))
def decode_attention(q, pool_k, pool_v, block_table, q_pos, *,
                     sliding_window: Optional[int] = None,
                     attention_chunk: Optional[int] = None,
                     interpret: Optional[bool] = None):
    interp = (not _on_tpu()) if interpret is None else interpret
    return paged_attention(q, pool_k, pool_v, block_table, q_pos,
                           sliding_window=sliding_window,
                           attention_chunk=attention_chunk,
                           interpret=interp)
