"""Oracle for the paged decode-attention kernel: gather blocks densely and
run materialised softmax attention."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


def paged_attention_ref(q, pool_k, pool_v, block_table, q_pos, *,
                        scale: Optional[float] = None,
                        sliding_window: Optional[int] = None,
                        attention_chunk: Optional[int] = None):
    """Same signature as the kernel; dense gather reference."""
    b, nq, hd = q.shape
    n_slots, bs, nkv, _ = pool_k.shape
    gq = nq // nkv
    max_blk = block_table.shape[1]
    scale = hd ** -0.5 if scale is None else scale

    tab = jnp.maximum(block_table, 0)
    # (b, max_blk, bs, nkv, hd) -> (b, max_blk*bs, nkv, hd)
    kg = jnp.take(pool_k, tab.reshape(-1), axis=0).reshape(
        b, max_blk, bs, nkv, hd).reshape(b, max_blk * bs, nkv, hd)
    vg = jnp.take(pool_v, tab.reshape(-1), axis=0).reshape(
        b, max_blk, bs, nkv, hd).reshape(b, max_blk * bs, nkv, hd)

    pos = jnp.arange(max_blk * bs)[None, :]                 # block j covers j*bs..
    valid = (pos <= q_pos[:, None]) & jnp.repeat(block_table >= 0, bs, axis=1)
    if sliding_window is not None:
        valid &= pos > (q_pos[:, None] - sliding_window)
    if attention_chunk is not None:
        valid &= (pos // attention_chunk) == (q_pos[:, None] // attention_chunk)

    qr = q.reshape(b, nkv, gq, hd).astype(jnp.float32) * scale
    s = jnp.einsum("bKgh,bsKh->bKgs", qr, kg.astype(jnp.float32))
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = jnp.where(valid[:, None, None, :], p, 0.0)
    out = jnp.einsum("bKgs,bsKh->bKgh", p, vg.astype(jnp.float32))
    out = out / jnp.maximum(p.sum(-1, keepdims=True), 1e-30)
    return out.reshape(b, nq, hd).astype(q.dtype)
