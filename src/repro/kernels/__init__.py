"""Pallas TPU kernels (validated with interpret=True on CPU).

  flash_attention — prefill attention (causal / SWA / chunked-local, GQA fold)
  paged_attention — decode attention over the Harvest KV block pool
                    (scalar-prefetch block-table chasing)
  moe_ffn         — fused gated expert FFN over dispatch buffers
  harvest_copy    — chunked tier-to-tier block gather + fused gather→scatter
                    pool-to-pool copy (the Harvest data movers)

Each package: kernel.py (pl.pallas_call + BlockSpec), ops.py (jit wrapper,
TPU-compiled vs CPU-interpret dispatch), ref.py (pure-jnp oracle).
"""
