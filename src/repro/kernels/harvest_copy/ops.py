"""Jit'd wrappers for the harvest tier-copy kernels."""
from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.kernels.harvest_copy.kernel import harvest_gather, harvest_scatter


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def gather_blocks(src_pool, slot_ids, *, chunk: int = 512,
                  interpret: Optional[bool] = None):
    interp = (not _on_tpu()) if interpret is None else interpret
    return harvest_gather(src_pool, slot_ids, chunk=chunk, interpret=interp)


@jax.jit
def scatter_blocks(dst_pool, staging, slot_ids):
    return harvest_scatter(dst_pool, staging, slot_ids)
