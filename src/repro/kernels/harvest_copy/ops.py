"""Jit'd wrappers for the harvest tier-copy kernels.

The wrappers validate slot ids EAGERLY (before tracing) so out-of-range
ids raise :class:`IndexError` instead of becoming silently dropped writes
inside the jit'd scatter — see ``harvest_scatter``'s contract.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.kernels.harvest_copy.kernel import (_check_slot_ids,
                                               harvest_copy, harvest_gather,
                                               harvest_scatter)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def _gather_jit(src_pool, slot_ids, *, chunk, interpret):
    return harvest_gather(src_pool, slot_ids, chunk=chunk, interpret=interpret)


def gather_blocks(src_pool, slot_ids, *, chunk: int = 512,
                  interpret: Optional[bool] = None):
    interp = (not _on_tpu()) if interpret is None else interpret
    _check_slot_ids(slot_ids, src_pool.shape[0], "gather_blocks")
    return _gather_jit(src_pool, slot_ids, chunk=chunk, interpret=interp)


@jax.jit
def _scatter_jit(dst_pool, staging, slot_ids):
    return harvest_scatter(dst_pool, staging, slot_ids)


def scatter_blocks(dst_pool, staging, slot_ids):
    _check_slot_ids(slot_ids, dst_pool.shape[0], "scatter_blocks")
    return _scatter_jit(dst_pool, staging, slot_ids)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def _copy_jit(src_pool, dst_pool, src_ids, dst_ids, *, chunk, interpret):
    return harvest_copy(src_pool, dst_pool, src_ids, dst_ids, chunk=chunk,
                        interpret=interpret)


def copy_blocks(src_pool, dst_pool, src_ids, dst_ids, *, chunk: int = 512,
                interpret: Optional[bool] = None):
    """Fused gather→scatter: move ``src_pool[src_ids]`` straight into
    ``dst_pool[dst_ids]`` with no dense staging buffer."""
    interp = (not _on_tpu()) if interpret is None else interpret
    _check_slot_ids(src_ids, src_pool.shape[0], "copy_blocks(src)")
    _check_slot_ids(dst_ids, dst_pool.shape[0], "copy_blocks(dst)")
    return _copy_jit(src_pool, dst_pool, src_ids, dst_ids, chunk=chunk,
                     interpret=interp)
