"""Jit'd wrappers for the harvest tier-copy kernels.

The wrappers validate slot ids EAGERLY (before tracing) so out-of-range
ids raise :class:`IndexError` instead of becoming silently dropped writes
inside the jit'd scatter — see ``harvest_scatter``'s contract.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.harvest_copy.kernel import (FIDELITY_QMAX, _check_slot_ids,
                                               _packed_width,
                                               dequantize_reload,
                                               harvest_copy, harvest_gather,
                                               harvest_scatter,
                                               quantize_demote)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def _gather_jit(src_pool, slot_ids, *, chunk, interpret):
    return harvest_gather(src_pool, slot_ids, chunk=chunk, interpret=interpret)


def gather_blocks(src_pool, slot_ids, *, chunk: int = 512,
                  interpret: Optional[bool] = None):
    interp = (not _on_tpu()) if interpret is None else interpret
    _check_slot_ids(slot_ids, src_pool.shape[0], "gather_blocks")
    return _gather_jit(src_pool, slot_ids, chunk=chunk, interpret=interp)


@jax.jit
def _scatter_jit(dst_pool, staging, slot_ids):
    return harvest_scatter(dst_pool, staging, slot_ids)


def scatter_blocks(dst_pool, staging, slot_ids):
    _check_slot_ids(slot_ids, dst_pool.shape[0], "scatter_blocks")
    return _scatter_jit(dst_pool, staging, slot_ids)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def _copy_jit(src_pool, dst_pool, src_ids, dst_ids, *, chunk, interpret):
    return harvest_copy(src_pool, dst_pool, src_ids, dst_ids, chunk=chunk,
                        interpret=interpret)


def copy_blocks(src_pool, dst_pool, src_ids, dst_ids, *, chunk: int = 512,
                interpret: Optional[bool] = None):
    """Fused gather→scatter: move ``src_pool[src_ids]`` straight into
    ``dst_pool[dst_ids]`` with no dense staging buffer."""
    interp = (not _on_tpu()) if interpret is None else interpret
    _check_slot_ids(src_ids, src_pool.shape[0], "copy_blocks(src)")
    _check_slot_ids(dst_ids, dst_pool.shape[0], "copy_blocks(dst)")
    return _copy_jit(src_pool, dst_pool, src_ids, dst_ids, chunk=chunk,
                     interpret=interp)


# ---------------------------------------------------------------------------
# fidelity: quantize-on-demote / dequantize-on-reload
# ---------------------------------------------------------------------------


def _check_fidelity(fidelity: str, what: str) -> None:
    if fidelity not in FIDELITY_QMAX:
        raise ValueError(f"{what}: unknown fidelity {fidelity!r} — one of "
                         f"{sorted(FIDELITY_QMAX)}")


def _check_pool(pool, what: str) -> None:
    if getattr(pool, "ndim", None) != 2:
        raise ValueError(f"{what}: pool must be 2-D (n_slots, block_elems), "
                         f"got shape {getattr(pool, 'shape', None)}")
    if not jnp.issubdtype(pool.dtype, jnp.floating):
        raise TypeError(f"{what}: pool dtype {pool.dtype} is not floating — "
                        "quantization needs a full-precision source")


@functools.partial(jax.jit, static_argnames=("fidelity", "interpret"))
def _quantize_jit(src_pool, slot_ids, *, fidelity, interpret):
    return quantize_demote(src_pool, slot_ids, fidelity=fidelity,
                           interpret=interpret)


def quantize_blocks(src_pool, slot_ids, *, fidelity: str = "int8",
                    interpret: Optional[bool] = None):
    """Quantize-on-demote: pack ``src_pool[slot_ids]`` into the wire
    fidelity's ``(values, scales)`` pair in one fused pass.  Validates
    fidelity, pool shape/dtype and slot ids EAGERLY (before tracing)."""
    _check_fidelity(fidelity, "quantize_blocks")
    _check_pool(src_pool, "quantize_blocks")
    _check_slot_ids(slot_ids, src_pool.shape[0], "quantize_blocks")
    interp = (not _on_tpu()) if interpret is None else interpret
    return _quantize_jit(src_pool, slot_ids, fidelity=fidelity,
                         interpret=interp)


@functools.partial(jax.jit, static_argnames=("fidelity", "interpret"))
def _dequantize_jit(dst_pool, values, scales, slot_ids, *, fidelity,
                    interpret):
    return dequantize_reload(dst_pool, values, scales, slot_ids,
                             fidelity=fidelity, interpret=interpret)


def dequantize_blocks(dst_pool, values, scales, slot_ids, *,
                      fidelity: str = "int8",
                      interpret: Optional[bool] = None):
    """Dequantize-on-reload: unpack+rescale ``values``/``scales`` into
    ``dst_pool[slot_ids]``; untouched slots are preserved via the output
    alias.  Validates shapes/dtypes/ids EAGERLY (before tracing)."""
    _check_fidelity(fidelity, "dequantize_blocks")
    _check_pool(dst_pool, "dequantize_blocks")
    _check_slot_ids(slot_ids, dst_pool.shape[0], "dequantize_blocks")
    m = slot_ids.shape[0]
    elems = dst_pool.shape[1]
    width = _packed_width(elems + (elems % 2 if fidelity == "int4" else 0),
                          fidelity)
    if tuple(values.shape) != (m, width):
        raise ValueError(
            f"dequantize_blocks: values shape {tuple(values.shape)} does not "
            f"match {m} blocks of packed width {width} at {fidelity}")
    if tuple(scales.shape) != (m, 1):
        raise ValueError(f"dequantize_blocks: scales shape "
                         f"{tuple(scales.shape)} != ({m}, 1)")
    interp = (not _on_tpu()) if interpret is None else interpret
    return _dequantize_jit(dst_pool, values, scales, slot_ids,
                           fidelity=fidelity, interpret=interp)
