"""Pallas TPU chunked tier-copy kernels (the Harvest data movers).

``harvest_gather`` pulls a batch of KV blocks / expert shards out of a
source pool into a dense staging buffer, chunk by chunk.  The slot list is
a scalar-prefetch operand, so the BlockSpec index_map chases it exactly
like the runtime's reload plan — this is the TPU analogue of the batched
cudaMemcpyPeerAsync the paper issues on a reload, and Pallas's grid
pipeline gives the double-buffering (copy chunk i+1 while chunk i lands)
for free.

``harvest_copy`` is the fused gather→scatter: one kernel moves slots from
a source pool straight into destination pool slots, skipping the dense
staging round-trip entirely — the output aliases the destination pool, so
untouched slots are preserved and only the copied blocks' chunks are
written.  This is the kernel the runtime's coalesced reload plan models:
one submission, one setup, per-slot completion as the grid walks the
batch.

Non-divisible block sizes are handled by padding the trailing chunk
(gather/copy) instead of asserting; out-of-range slot ids raise instead of
silently dropping writes.

Grids: (num_blocks_to_copy, chunks_per_block).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _copy_kernel(ids_ref, src_ref, dst_ref):
    dst_ref[...] = src_ref[...]


def _fused_copy_kernel(src_ids_ref, dst_ids_ref, src_ref, dst_in_ref,
                       dst_ref):
    dst_ref[...] = src_ref[...]


def _check_slot_ids(slot_ids, n_slots: int, what: str) -> None:
    """Eagerly reject out-of-range slot ids (a scatter that silently drops
    a reload's payload is a data-loss bug, not a masking convenience).
    Traced ids (inside an outer jit) cannot be validated here — the jit'd
    wrappers in ops.py validate before tracing."""
    if isinstance(slot_ids, jax.core.Tracer):
        return
    ids = np.asarray(slot_ids)
    if ids.size and (ids.min() < 0 or ids.max() >= n_slots):
        bad = ids[(ids < 0) | (ids >= n_slots)]
        raise IndexError(
            f"{what}: slot ids {bad.tolist()} out of range for a pool of "
            f"{n_slots} slots — refusing to drop the writes")


def _chunking(elems: int, chunk: int):
    """(clamped chunk, padded elems, n_chunks): non-divisible block sizes
    are padded up to a whole trailing chunk instead of crashing."""
    chunk = max(1, min(chunk, elems))
    pad = (-elems) % chunk
    return chunk, elems + pad, (elems + pad) // chunk


def harvest_gather(src_pool, slot_ids, *, chunk: int = 512,
                   interpret: bool = True):
    """src_pool: (n_slots, block_elems); slot_ids: (m,) int32
    -> (m, block_elems) staging buffer."""
    n_slots, elems = src_pool.shape
    _check_slot_ids(slot_ids, n_slots, "harvest_gather")
    m = slot_ids.shape[0]
    chunk, padded, n_chunks = _chunking(elems, chunk)
    if padded != elems:
        src_pool = jnp.pad(src_pool, ((0, 0), (0, padded - elems)))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(m, n_chunks),
        in_specs=[
            pl.BlockSpec((None, chunk), lambda i, j, ids: (ids[i], j)),
        ],
        out_specs=pl.BlockSpec((None, chunk), lambda i, j, ids: (i, j)),
    )
    out = pl.pallas_call(
        _copy_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, padded), src_pool.dtype),
        interpret=interpret,
    )(slot_ids.astype(jnp.int32), src_pool)
    return out[:, :elems] if padded != elems else out


def harvest_copy(src_pool, dst_pool, src_ids, dst_ids, *, chunk: int = 512,
                 interpret: bool = True):
    """Fused gather→scatter: dst_pool[dst_ids[i]] <- src_pool[src_ids[i]].

    One pallas_call, no dense staging buffer: the source BlockSpec chases
    ``src_ids`` while the output BlockSpec chases ``dst_ids``, and the
    output aliases ``dst_pool`` so every slot outside the copy set is
    preserved.  Returns the updated destination pool.
    """
    n_src, elems = src_pool.shape
    n_dst, elems_d = dst_pool.shape
    assert elems == elems_d, \
        f"pool block sizes differ: src {elems} vs dst {elems_d}"
    assert src_ids.shape == dst_ids.shape, \
        f"id list shapes differ: {src_ids.shape} vs {dst_ids.shape}"
    _check_slot_ids(src_ids, n_src, "harvest_copy(src)")
    _check_slot_ids(dst_ids, n_dst, "harvest_copy(dst)")
    m = src_ids.shape[0]
    chunk, padded, n_chunks = _chunking(elems, chunk)
    if padded != elems:
        src_pool = jnp.pad(src_pool, ((0, 0), (0, padded - elems)))
        dst_pool = jnp.pad(dst_pool, ((0, 0), (0, padded - elems)))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(m, n_chunks),
        in_specs=[
            pl.BlockSpec((None, chunk), lambda i, j, sids, dids: (sids[i], j)),
            pl.BlockSpec((None, chunk), lambda i, j, sids, dids: (dids[i], j)),
        ],
        out_specs=pl.BlockSpec((None, chunk),
                               lambda i, j, sids, dids: (dids[i], j)),
    )
    out = pl.pallas_call(
        _fused_copy_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(dst_pool.shape, dst_pool.dtype),
        # operand 3 = dst_pool (after the 2 scalar-prefetch id lists and
        # src_pool): aliasing it into the output preserves untouched slots
        input_output_aliases={3: 0},
        interpret=interpret,
    )(src_ids.astype(jnp.int32), dst_ids.astype(jnp.int32), src_pool,
      dst_pool)
    return out[:, :elems] if padded != elems else out


def harvest_scatter(dst_pool, staging, slot_ids, *, interpret: bool = True):
    """Write staging rows back into pool slots (reload completion).

    Implemented with a jnp scatter (aliasing-safe); the gather above is the
    bandwidth-critical direction.  Out-of-range slot ids raise instead of
    silently dropping the write — a reload whose payload lands nowhere is
    data loss, not a masking convenience.
    """
    _check_slot_ids(slot_ids, dst_pool.shape[0], "harvest_scatter")
    return dst_pool.at[slot_ids].set(staging.astype(dst_pool.dtype),
                                     mode="drop")
