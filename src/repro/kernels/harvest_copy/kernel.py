"""Pallas TPU chunked tier-copy kernels (the Harvest data movers).

``harvest_gather`` pulls a batch of KV blocks / expert shards out of a
source pool into a dense staging buffer, chunk by chunk.  The slot list is
a scalar-prefetch operand, so the BlockSpec index_map chases it exactly
like the runtime's reload plan — this is the TPU analogue of the batched
cudaMemcpyPeerAsync the paper issues on a reload, and Pallas's grid
pipeline gives the double-buffering (copy chunk i+1 while chunk i lands)
for free.

``harvest_copy`` is the fused gather→scatter: one kernel moves slots from
a source pool straight into destination pool slots, skipping the dense
staging round-trip entirely — the output aliases the destination pool, so
untouched slots are preserved and only the copied blocks' chunks are
written.  This is the kernel the runtime's coalesced reload plan models:
one submission, one setup, per-slot completion as the grid walks the
batch.

Non-divisible block sizes are handled by padding the trailing chunk
(gather/copy) instead of asserting; out-of-range slot ids raise instead of
silently dropping writes.

Grids: (num_blocks_to_copy, chunks_per_block).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _copy_kernel(ids_ref, src_ref, dst_ref):
    dst_ref[...] = src_ref[...]


def _fused_copy_kernel(src_ids_ref, dst_ids_ref, src_ref, dst_in_ref,
                       dst_ref):
    dst_ref[...] = src_ref[...]


def _check_slot_ids(slot_ids, n_slots: int, what: str) -> None:
    """Eagerly reject out-of-range slot ids (a scatter that silently drops
    a reload's payload is a data-loss bug, not a masking convenience).
    Traced ids (inside an outer jit) cannot be validated here — the jit'd
    wrappers in ops.py validate before tracing."""
    if isinstance(slot_ids, jax.core.Tracer):
        return
    ids = np.asarray(slot_ids)
    if ids.size and (ids.min() < 0 or ids.max() >= n_slots):
        bad = ids[(ids < 0) | (ids >= n_slots)]
        raise IndexError(
            f"{what}: slot ids {bad.tolist()} out of range for a pool of "
            f"{n_slots} slots — refusing to drop the writes")


def _chunking(elems: int, chunk: int):
    """(clamped chunk, padded elems, n_chunks): non-divisible block sizes
    are padded up to a whole trailing chunk instead of crashing."""
    chunk = max(1, min(chunk, elems))
    pad = (-elems) % chunk
    return chunk, elems + pad, (elems + pad) // chunk


def harvest_gather(src_pool, slot_ids, *, chunk: int = 512,
                   interpret: bool = True):
    """src_pool: (n_slots, block_elems); slot_ids: (m,) int32
    -> (m, block_elems) staging buffer."""
    n_slots, elems = src_pool.shape
    _check_slot_ids(slot_ids, n_slots, "harvest_gather")
    m = slot_ids.shape[0]
    chunk, padded, n_chunks = _chunking(elems, chunk)
    if padded != elems:
        src_pool = jnp.pad(src_pool, ((0, 0), (0, padded - elems)))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(m, n_chunks),
        in_specs=[
            pl.BlockSpec((None, chunk), lambda i, j, ids: (ids[i], j)),
        ],
        out_specs=pl.BlockSpec((None, chunk), lambda i, j, ids: (i, j)),
    )
    out = pl.pallas_call(
        _copy_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, padded), src_pool.dtype),
        interpret=interpret,
    )(slot_ids.astype(jnp.int32), src_pool)
    return out[:, :elems] if padded != elems else out


def harvest_copy(src_pool, dst_pool, src_ids, dst_ids, *, chunk: int = 512,
                 interpret: bool = True):
    """Fused gather→scatter: dst_pool[dst_ids[i]] <- src_pool[src_ids[i]].

    One pallas_call, no dense staging buffer: the source BlockSpec chases
    ``src_ids`` while the output BlockSpec chases ``dst_ids``, and the
    output aliases ``dst_pool`` so every slot outside the copy set is
    preserved.  Returns the updated destination pool.
    """
    n_src, elems = src_pool.shape
    n_dst, elems_d = dst_pool.shape
    assert elems == elems_d, \
        f"pool block sizes differ: src {elems} vs dst {elems_d}"
    assert src_ids.shape == dst_ids.shape, \
        f"id list shapes differ: {src_ids.shape} vs {dst_ids.shape}"
    _check_slot_ids(src_ids, n_src, "harvest_copy(src)")
    _check_slot_ids(dst_ids, n_dst, "harvest_copy(dst)")
    m = src_ids.shape[0]
    chunk, padded, n_chunks = _chunking(elems, chunk)
    if padded != elems:
        src_pool = jnp.pad(src_pool, ((0, 0), (0, padded - elems)))
        dst_pool = jnp.pad(dst_pool, ((0, 0), (0, padded - elems)))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(m, n_chunks),
        in_specs=[
            pl.BlockSpec((None, chunk), lambda i, j, sids, dids: (sids[i], j)),
            pl.BlockSpec((None, chunk), lambda i, j, sids, dids: (dids[i], j)),
        ],
        out_specs=pl.BlockSpec((None, chunk),
                               lambda i, j, sids, dids: (dids[i], j)),
    )
    out = pl.pallas_call(
        _fused_copy_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(dst_pool.shape, dst_pool.dtype),
        # operand 3 = dst_pool (after the 2 scalar-prefetch id lists and
        # src_pool): aliasing it into the output preserves untouched slots
        input_output_aliases={3: 0},
        interpret=interpret,
    )(src_ids.astype(jnp.int32), dst_ids.astype(jnp.int32), src_pool,
      dst_pool)
    return out[:, :elems] if padded != elems else out


# ---------------------------------------------------------------------------
# fidelity kernels: quantize-on-demote / dequantize-on-reload
# ---------------------------------------------------------------------------

#: symmetric quantization range per wire fidelity (e4m3's largest finite
#: value is 448; int4 packs two's-complement nibbles, so ±7 keeps the
#: packing sign-safe)
FIDELITY_QMAX = {"int8": 127.0, "fp8": 448.0, "int4": 7.0}

#: storage dtype of the packed value plane per wire fidelity
FIDELITY_QDTYPE = {"int8": jnp.int8, "fp8": jnp.float8_e4m3fn,
                   "int4": jnp.uint8}


def _packed_width(elems: int, fidelity: str) -> int:
    """Columns of the packed value plane for a block of ``elems`` weights
    (int4 packs two nibbles per byte; a non-divisible tail pads)."""
    return (elems + 1) // 2 if fidelity == "int4" else elems


def _quantize_kernel(ids_ref, src_ref, val_ref, scale_ref, *, fidelity):
    """One grid step = one gathered block row: absmax scale, quantize,
    pack — no dense full-precision staging of the batch.  The ``(None,
    width)`` BlockSpecs squeeze the slot dim, so refs are 1-D here."""
    row = src_ref[...].astype(jnp.float32).reshape(-1)
    absmax = jnp.max(jnp.abs(row))
    # all-zero blocks quantize to zeros with a unit scale instead of a NaN
    scale = jnp.where(absmax == 0.0, 1.0, absmax / FIDELITY_QMAX[fidelity])
    scale_ref[...] = jnp.full(scale_ref.shape, scale, dtype=jnp.float32)
    x = row / scale
    if fidelity == "int8":
        out = jnp.clip(jnp.round(x), -127, 127).astype(jnp.int8)
    elif fidelity == "fp8":
        out = x.astype(jnp.float8_e4m3fn)
    else:  # int4: two's-complement nibbles, two weights per byte
        q = jnp.clip(jnp.round(x), -7, 7).astype(jnp.int32)
        q = q.reshape(-1, 2)
        out = ((q[:, 0] & 15) | ((q[:, 1] & 15) << 4)).astype(jnp.uint8)
    val_ref[...] = out.reshape(val_ref.shape)


def _dequantize_kernel(ids_ref, val_ref, scale_ref, dst_in_ref, dst_ref,
                       *, fidelity):
    scale = scale_ref[...].reshape(-1)[0]
    q = val_ref[...].reshape(-1)
    if fidelity == "int4":
        b = q.astype(jnp.int32)
        lo = b & 15
        lo = lo - 2 * (lo & 8)          # sign-extend the nibble
        hi = (b >> 4) & 15
        hi = hi - 2 * (hi & 8)
        x = jnp.stack([lo, hi], axis=-1).reshape(-1).astype(jnp.float32)
    else:
        x = q.astype(jnp.float32)
    dst_ref[...] = (x * scale).reshape(dst_ref.shape).astype(dst_ref.dtype)


def quantize_demote(src_pool, slot_ids, *, fidelity: str = "int8",
                    interpret: bool = True):
    """Fused gather→quantize→pack for a demotion batch.

    ``src_pool``: (n_slots, block_elems) float pool; ``slot_ids``: (m,)
    rows being demoted.  Returns ``(values, scales)`` — the packed wire
    payload (m, packed_width) in the fidelity's storage dtype and the
    per-block f32 absmax scales (m, 1).  One pass: the source BlockSpec
    chases the slot list exactly like ``harvest_gather``, so the batch is
    never staged densely at full precision.
    """
    if fidelity not in FIDELITY_QMAX:
        raise ValueError(f"quantize_demote: unknown fidelity {fidelity!r} — "
                         f"one of {sorted(FIDELITY_QMAX)}")
    n_slots, elems = src_pool.shape
    _check_slot_ids(slot_ids, n_slots, "quantize_demote")
    m = slot_ids.shape[0]
    # int4 packs nibble pairs: pad an odd block width (the pad lane
    # quantizes to zero and is sliced off on reload)
    padded = elems + (elems % 2 if fidelity == "int4" else 0)
    if padded != elems:
        src_pool = jnp.pad(src_pool, ((0, 0), (0, padded - elems)))
    width = _packed_width(padded, fidelity)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(m,),
        in_specs=[
            pl.BlockSpec((None, padded), lambda i, ids: (ids[i], 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, width), lambda i, ids: (i, 0)),
            pl.BlockSpec((None, 1), lambda i, ids: (i, 0)),
        ],
    )
    values, scales = pl.pallas_call(
        functools.partial(_quantize_kernel, fidelity=fidelity),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((m, width), FIDELITY_QDTYPE[fidelity]),
            jax.ShapeDtypeStruct((m, 1), jnp.float32),
        ],
        interpret=interpret,
    )(slot_ids.astype(jnp.int32), src_pool)
    return values, scales


def dequantize_reload(dst_pool, values, scales, slot_ids, *,
                      fidelity: str = "int8", interpret: bool = True):
    """Fused unpack→dequantize→scatter for a reload batch.

    Writes ``values[i] * scales[i]`` into ``dst_pool[slot_ids[i]]``; the
    output aliases the destination pool (``input_output_aliases``) so
    every slot outside the reload set is preserved bit-exactly.  Returns
    the updated pool.  ``slot_ids`` must be unique — two reloads landing
    in one slot is a plan bug, not a race to resolve here.
    """
    if fidelity not in FIDELITY_QMAX:
        raise ValueError(f"dequantize_reload: unknown fidelity {fidelity!r} "
                         f"— one of {sorted(FIDELITY_QMAX)}")
    n_slots, elems = dst_pool.shape
    _check_slot_ids(slot_ids, n_slots, "dequantize_reload")
    m = slot_ids.shape[0]
    padded = elems + (elems % 2 if fidelity == "int4" else 0)
    width = _packed_width(padded, fidelity)
    assert values.shape == (m, width), \
        f"dequantize_reload: values shape {values.shape} != ({m}, {width})"
    if padded != elems:
        dst_pool_in = jnp.pad(dst_pool, ((0, 0), (0, padded - elems)))
    else:
        dst_pool_in = dst_pool

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(m,),
        in_specs=[
            pl.BlockSpec((None, width), lambda i, ids: (i, 0)),
            pl.BlockSpec((None, 1), lambda i, ids: (i, 0)),
            pl.BlockSpec((None, padded), lambda i, ids: (ids[i], 0)),
        ],
        out_specs=pl.BlockSpec((None, padded), lambda i, ids: (ids[i], 0)),
    )
    out = pl.pallas_call(
        functools.partial(_dequantize_kernel, fidelity=fidelity),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(dst_pool_in.shape, dst_pool.dtype),
        # operand 3 = dst_pool (after the id list, values and scales):
        # aliasing it into the output preserves untouched slots
        input_output_aliases={3: 0},
        interpret=interpret,
    )(slot_ids.astype(jnp.int32), values, scales, dst_pool_in)
    return out[:, :elems] if padded != elems else out


def harvest_scatter(dst_pool, staging, slot_ids, *, interpret: bool = True):
    """Write staging rows back into pool slots (reload completion).

    Implemented with a jnp scatter (aliasing-safe); the gather above is the
    bandwidth-critical direction.  Out-of-range slot ids raise instead of
    silently dropping the write — a reload whose payload lands nowhere is
    data loss, not a masking convenience.
    """
    _check_slot_ids(slot_ids, dst_pool.shape[0], "harvest_scatter")
    return dst_pool.at[slot_ids].set(staging.astype(dst_pool.dtype),
                                     mode="drop")
