"""Pallas TPU chunked tier-copy kernel (the Harvest data mover).

Gathers a batch of KV blocks / expert shards out of a source pool into a
dense staging buffer, chunk by chunk.  The slot list is a scalar-prefetch
operand, so the BlockSpec index_map chases it exactly like the runtime's
reload plan — this is the TPU analogue of the batched cudaMemcpyPeerAsync
the paper issues on a reload, and Pallas's grid pipeline gives the
double-buffering (copy chunk i+1 while chunk i lands) for free.

Grid: (num_blocks_to_copy, chunks_per_block).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _copy_kernel(ids_ref, src_ref, dst_ref):
    dst_ref[...] = src_ref[...]


def harvest_gather(src_pool, slot_ids, *, chunk: int = 512,
                   interpret: bool = True):
    """src_pool: (n_slots, block_elems); slot_ids: (m,) int32
    -> (m, block_elems) staging buffer."""
    n_slots, elems = src_pool.shape
    m = slot_ids.shape[0]
    chunk = min(chunk, elems)
    assert elems % chunk == 0
    n_chunks = elems // chunk

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(m, n_chunks),
        in_specs=[
            pl.BlockSpec((None, chunk), lambda i, j, ids: (ids[i], j)),
        ],
        out_specs=pl.BlockSpec((None, chunk), lambda i, j, ids: (i, j)),
    )
    return pl.pallas_call(
        _copy_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, elems), src_pool.dtype),
        interpret=interpret,
    )(slot_ids.astype(jnp.int32), src_pool)


def harvest_scatter(dst_pool, staging, slot_ids, *, interpret: bool = True):
    """Write staging rows back into pool slots (reload completion).

    Implemented with a jnp scatter (aliasing-safe); the gather above is the
    bandwidth-critical direction.
    """
    return dst_pool.at[slot_ids].set(staging.astype(dst_pool.dtype),
                                     mode="drop")
