"""Oracle for the harvest gather/scatter data movers."""
import jax.numpy as jnp


def harvest_gather_ref(src_pool, slot_ids):
    return jnp.take(src_pool, slot_ids, axis=0)


def harvest_scatter_ref(dst_pool, staging, slot_ids):
    return dst_pool.at[slot_ids].set(staging.astype(dst_pool.dtype),
                                     mode="drop")


def harvest_copy_ref(src_pool, dst_pool, src_ids, dst_ids):
    """Fused gather->scatter oracle (no staging buffer)."""
    return dst_pool.at[dst_ids].set(
        jnp.take(src_pool, src_ids, axis=0).astype(dst_pool.dtype))


def quantize_demote_ref(src_pool, slot_ids, fidelity: str = "int8"):
    """Dense oracle for the fused quantize kernel: gather, per-row absmax
    scale, quantize, pack."""
    from repro.kernels.harvest_copy.kernel import FIDELITY_QMAX
    rows = jnp.take(src_pool, slot_ids, axis=0).astype(jnp.float32)
    if fidelity == "int4" and rows.shape[1] % 2:
        rows = jnp.pad(rows, ((0, 0), (0, 1)))
    absmax = jnp.max(jnp.abs(rows), axis=1, keepdims=True)
    scales = jnp.where(absmax == 0.0, 1.0,
                       absmax / FIDELITY_QMAX[fidelity])
    x = rows / scales
    if fidelity == "int8":
        values = jnp.clip(jnp.round(x), -127, 127).astype(jnp.int8)
    elif fidelity == "fp8":
        values = x.astype(jnp.float8_e4m3fn)
    elif fidelity == "int4":
        q = jnp.clip(jnp.round(x), -7, 7).astype(jnp.int32)
        q = q.reshape(q.shape[0], -1, 2)
        values = ((q[..., 0] & 15) | ((q[..., 1] & 15) << 4)).astype(jnp.uint8)
    else:
        raise ValueError(f"unknown fidelity {fidelity!r}")
    return values, scales.astype(jnp.float32)


def dequantize_reload_ref(dst_pool, values, scales, slot_ids,
                          fidelity: str = "int8"):
    """Dense oracle for the fused dequantize kernel: unpack, rescale,
    scatter into the pool (untouched slots preserved)."""
    if fidelity == "int4":
        b = values.astype(jnp.int32)
        lo = (b & 15) - 2 * (b & 8)
        hi = ((b >> 4) & 15) - 2 * ((b >> 4) & 8)
        x = jnp.stack([lo, hi], axis=-1).reshape(values.shape[0], -1)
        x = x.astype(jnp.float32)
    elif fidelity in ("int8", "fp8"):
        x = values.astype(jnp.float32)
    else:
        raise ValueError(f"unknown fidelity {fidelity!r}")
    rows = (x * scales)[:, :dst_pool.shape[1]]
    return dst_pool.at[slot_ids].set(rows.astype(dst_pool.dtype),
                                     mode="drop")
