"""Oracle for the harvest gather/scatter data movers."""
import jax.numpy as jnp


def harvest_gather_ref(src_pool, slot_ids):
    return jnp.take(src_pool, slot_ids, axis=0)


def harvest_scatter_ref(dst_pool, staging, slot_ids):
    return dst_pool.at[slot_ids].set(staging.astype(dst_pool.dtype),
                                     mode="drop")


def harvest_copy_ref(src_pool, dst_pool, src_ids, dst_ids):
    """Fused gather->scatter oracle (no staging buffer)."""
    return dst_pool.at[dst_ids].set(
        jnp.take(src_pool, src_ids, axis=0).astype(dst_pool.dtype))
