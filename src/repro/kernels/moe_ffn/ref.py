"""Oracle for the fused expert-FFN kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def moe_ffn_ref(xd, wi, wg, wo, *, activation: str = "silu"):
    x = xd.astype(jnp.float32)
    h = jnp.einsum("ecd,edf->ecf", x, wi.astype(jnp.float32))
    g = jnp.einsum("ecd,edf->ecf", x, wg.astype(jnp.float32))
    if activation == "silu":
        g = jax.nn.silu(g)
    elif activation == "gelu":
        g = jax.nn.gelu(g)
    elif activation == "relu2":
        g = jnp.square(jax.nn.relu(g))
    else:
        raise ValueError(activation)
    out = jnp.einsum("ecf,efd->ecd", g * h, wo.astype(jnp.float32))
    return out.astype(xd.dtype)
