"""Jit'd wrapper for the fused expert-FFN kernel."""
from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.kernels.moe_ffn.kernel import moe_ffn


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=(
    "activation", "c_block", "f_block", "interpret"))
def expert_ffn(xd, wi, wg, wo, *, activation: str = "silu",
               c_block: int = 128, f_block: int = 256,
               interpret: Optional[bool] = None):
    interp = (not _on_tpu()) if interpret is None else interpret
    return moe_ffn(xd, wi, wg, wo, activation=activation,
                   c_block=c_block, f_block=f_block, interpret=interp)
