"""Pallas TPU fused expert-FFN kernel: out[e] = (act(x@wg) * (x@wi)) @ wo.

Grid (experts, capacity_blocks, ff_blocks); the ff dimension is sequential
and the (c_blk, d) output accumulates in VMEM scratch, so the (C, d_ff)
gated intermediate never hits HBM.  The expert grid dimension is the unit
the Harvest Expert Rebalancer places across tiers — the kernel itself only
sees dispatch buffers whose weights are already local-HBM resident.

VMEM working set per step (targets):
  x (c_blk, d) + wi/wg (d, f_blk) + wo (f_blk, d) + acc (c_blk, d)
  with c_blk=128, f_blk=256, d<=5120: ~2.6 MB weights + 2.6 MB acc  < VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _moe_ffn_kernel(x_ref, wi_ref, wg_ref, wo_ref, o_ref, acc_scr, *,
                    n_f_blocks: int, activation: str):
    fi = pl.program_id(2)

    @pl.when(fi == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    x = x_ref[...].astype(jnp.float32)            # (c_blk, d)
    wi = wi_ref[...].astype(jnp.float32)          # (d, f_blk)
    wg = wg_ref[...].astype(jnp.float32)
    wo = wo_ref[...].astype(jnp.float32)          # (f_blk, d)

    h = jnp.dot(x, wi, preferred_element_type=jnp.float32)
    g = jnp.dot(x, wg, preferred_element_type=jnp.float32)
    if activation == "silu":
        g = g * jax.nn.sigmoid(g)
    elif activation == "gelu":
        g = jax.nn.gelu(g)
    elif activation == "relu2":
        g = jnp.square(jnp.maximum(g, 0.0))
    else:
        raise ValueError(activation)
    acc_scr[...] += jnp.dot(g * h, wo, preferred_element_type=jnp.float32)

    @pl.when(fi == n_f_blocks - 1)
    def _finalize():
        o_ref[...] = acc_scr[...].astype(o_ref.dtype)


def moe_ffn(xd, wi, wg, wo, *, activation: str = "silu",
            c_block: int = 128, f_block: int = 256,
            interpret: bool = True):
    """xd: (E, C, d);  wi/wg: (E, d, f);  wo: (E, f, d) -> (E, C, d)."""
    E, C, d = xd.shape
    f = wi.shape[2]
    c_block = min(c_block, C)
    f_block = min(f_block, f)
    assert C % c_block == 0 and f % f_block == 0
    n_c = C // c_block
    n_f = f // f_block

    kern = functools.partial(_moe_ffn_kernel, n_f_blocks=n_f,
                             activation=activation)
    return pl.pallas_call(
        kern,
        grid=(E, n_c, n_f),
        in_specs=[
            pl.BlockSpec((None, c_block, d), lambda e, c, fi: (e, c, 0)),
            pl.BlockSpec((None, d, f_block), lambda e, c, fi: (e, 0, fi)),
            pl.BlockSpec((None, d, f_block), lambda e, c, fi: (e, 0, fi)),
            pl.BlockSpec((None, f_block, d), lambda e, c, fi: (e, fi, 0)),
        ],
        out_specs=pl.BlockSpec((None, c_block, d), lambda e, c, fi: (e, c, 0)),
        out_shape=jax.ShapeDtypeStruct((E, C, d), xd.dtype),
        scratch_shapes=[pltpu.VMEM((c_block, d), jnp.float32)],
        interpret=interpret,
    )(xd, wi, wg, wo)
