"""Phi-tiny-MoE [arXiv:2404.14219; SlimMoE] — paper Table 1: 3.8B total /
1.1B active, 16 experts top-2.  Dims solved to match the published
total/active counts (d_model 2304, 36 heads GQA kv=9, d_ff_expert 915
-> 3.81B / 0.98B)."""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="phi-tiny-moe",
    family="moe",
    source="arXiv:2404.14219 (paper Table 1)",
    num_layers=32,
    d_model=2304,
    num_heads=36,
    num_kv_heads=9,
    head_dim=64,
    d_ff=915,
    vocab_size=32064,
    moe=MoEConfig(num_experts=16, top_k=2, d_ff_expert=915, layer_period=1),
)
