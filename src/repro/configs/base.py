"""Config system for the Harvest reproduction framework.

One :class:`ModelConfig` describes any architecture in the assigned pool
(dense / MoE / SSM / hybrid / VLM / audio).  Architecture files live next to
this module (``src/repro/configs/<arch_id>.py``) and export ``CONFIG``.

The config is a frozen dataclass so it can be closed over by jitted functions
and hashed as a static argument.
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts FFN configuration."""

    num_experts: int
    top_k: int
    d_ff_expert: int
    # every `layer_period`-th layer is MoE (1 = every layer, 2 = interleaved)
    layer_period: int = 1
    num_shared_experts: int = 0
    d_ff_shared: int = 0
    router_jitter: float = 0.0
    # aux load-balance loss weight (train only)
    lb_loss_weight: float = 0.01
    # dispatch capacity factor (tokens_per_expert = t*k/E * cf)
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2-style selective state space configuration."""

    state_dim: int = 64
    conv_width: int = 4
    expand: int = 2
    head_dim: int = 64           # mamba2 multi-head: d_inner / head_dim heads
    chunk_size: int = 256        # SSD block scan chunk


@dataclass(frozen=True)
class HybridConfig:
    """Zamba2-style hybrid: Mamba2 backbone + shared attention block."""

    attn_period: int = 6         # shared attention block applied every N layers
    shared_attention: bool = True  # one set of attn weights reused (zamba2 signature)


@dataclass(frozen=True)
class XLSTMConfig:
    """xLSTM: alternating mLSTM / sLSTM blocks (scanned as pairs)."""

    slstm_every: int = 8         # one sLSTM block per `slstm_every` layers
    proj_factor_mlstm: float = 2.0
    proj_factor_slstm: float = 1.3334
    conv_width: int = 4


@dataclass(frozen=True)
class ModalityConfig:
    """Frontend stub description (VLM vision encoder / audio codec).

    Per the build instructions the frontend itself is NOT implemented; the
    launcher's ``input_specs`` supplies precomputed embeddings of the shape
    declared here and the decoder backbone consumes them.
    """

    kind: str                    # "vision" | "audio"
    # vision: number of patch embeddings prepended to the token stream
    num_prefix_embeddings: int = 0
    # audio (EnCodec): parallel codebooks, each with its own vocab + lm head
    num_codebooks: int = 1
    # M-RoPE 3D position sections (t, h, w) summing to head_dim//2
    mrope_sections: Optional[Tuple[int, int, int]] = None


# ---------------------------------------------------------------------------
# Main config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | vlm | audio
    source: str                  # citation (arXiv / hf model card)

    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: int = 0            # 0 -> d_model // num_heads

    # attention flavour
    rope_style: str = "rope"     # "rope" | "mrope" | "none"
    rope_theta: float = 10_000.0
    sliding_window: Optional[int] = None     # SWA window (h2o-danube3)
    attention_chunk: Optional[int] = None    # chunked local attention (llama4)
    qk_norm: bool = False
    attn_bias: bool = False
    logit_softcap: Optional[float] = None

    # mlp flavour
    activation: str = "silu"     # "silu" | "gelu" | "relu2" (nemotron squared relu)
    mlp_bias: bool = False
    gated_mlp: bool = True       # SwiGLU-style gate; False -> plain 2-matrix MLP

    # norms / embeddings
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    parallel_block: bool = False  # command-r style parallel attn+mlp

    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    modality: Optional[ModalityConfig] = None

    dtype: str = "bfloat16"

    # ------------------------------------------------------------------
    # Derived helpers
    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // self.num_kv_heads

    def is_moe_layer(self, layer_idx: int) -> bool:
        if self.moe is None:
            return False
        return (layer_idx % self.moe.layer_period) == (self.moe.layer_period - 1)

    @property
    def num_moe_layers(self) -> int:
        if self.moe is None:
            return 0
        return sum(self.is_moe_layer(i) for i in range(self.num_layers))

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic decode: SSM/hybrid state, SWA, or chunked attention."""
        return (
            self.family in ("ssm", "hybrid")
            or self.sliding_window is not None
            or self.attention_chunk is not None
        )

    @property
    def has_kv_cache(self) -> bool:
        """Pure-SSM stacks keep recurrent state instead of a KV cache."""
        return self.family != "ssm"

    # ------------------------------------------------------------------
    # Parameter counting (used by Table 1 bench and the roofline's 6ND)
    # ------------------------------------------------------------------
    def param_counts(self) -> dict:
        d, hd = self.d_model, self.resolved_head_dim
        nq, nkv = self.num_heads, self.num_kv_heads
        attn = d * hd * nq + 2 * d * hd * nkv + hd * nq * d  # q,k,v,o

        def ffn_params(d_ff: int) -> int:
            mats = 3 if self.gated_mlp else 2
            return mats * d * d_ff

        total = 0
        active = 0
        for i in range(self.num_layers):
            layer_total = 0
            layer_active = 0
            if self.family == "ssm" and self.xlstm is not None:
                # handled coarsely: mLSTM block ~ 4*d*(pf*d) + sLSTM ~ 4*d*d
                pf = self.xlstm.proj_factor_mlstm
                layer_total = int(4 * d * pf * d)
                layer_active = layer_total
            elif self.family in ("hybrid",) and self.ssm is not None:
                d_in = self.ssm.expand * d
                layer_total = 2 * d * d_in + d_in * d  # in/out proj (approx)
                layer_active = layer_total
            else:
                layer_total += attn
                layer_active += attn
                if self.is_moe_layer(i):
                    e = ffn_params(self.moe.d_ff_expert)
                    layer_total += self.moe.num_experts * e
                    layer_active += self.moe.top_k * e
                    if self.moe.num_shared_experts:
                        s = ffn_params(self.moe.d_ff_shared) * self.moe.num_shared_experts
                        layer_total += s
                        layer_active += s
                elif self.d_ff:
                    f = ffn_params(self.d_ff)
                    layer_total += f
                    layer_active += f
            total += layer_total
            active += layer_active
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        if self.modality is not None and self.modality.num_codebooks > 1:
            emb = self.modality.num_codebooks * self.vocab_size * d * 2
        total += emb
        active += emb
        return {"total": total, "active": active}

    # ------------------------------------------------------------------
    # Reduced variant for CPU smoke tests
    # ------------------------------------------------------------------
    def reduced(self) -> "ModelConfig":
        """Same family, tiny dims: 2 layers, d_model<=512, <=4 experts."""
        d_model = min(self.d_model, 256)
        num_heads = min(self.num_heads, 4)
        num_kv_heads = min(self.num_kv_heads, max(1, num_heads // self.q_per_kv if self.q_per_kv else num_heads))
        num_kv_heads = max(1, min(num_kv_heads, num_heads))
        while num_heads % num_kv_heads:
            num_kv_heads -= 1
        head_dim = min(self.resolved_head_dim, 64)
        changes = dict(
            num_layers=2,
            d_model=d_model,
            num_heads=num_heads,
            num_kv_heads=num_kv_heads,
            head_dim=head_dim,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            sliding_window=64 if self.sliding_window else None,
            attention_chunk=64 if self.attention_chunk else None,
        )
        if self.moe is not None:
            changes["moe"] = dataclasses.replace(
                self.moe,
                num_experts=min(self.moe.num_experts, 4),
                top_k=min(self.moe.top_k, 2),
                d_ff_expert=min(self.moe.d_ff_expert, 256),
                d_ff_shared=min(self.moe.d_ff_shared, 256),
                layer_period=1,
                capacity_factor=8.0,   # lossless dispatch for exactness tests
            )
        if self.ssm is not None:
            changes["ssm"] = dataclasses.replace(
                self.ssm, state_dim=min(self.ssm.state_dim, 16), head_dim=32,
                chunk_size=32,
            )
        if self.hybrid is not None:
            changes["hybrid"] = dataclasses.replace(self.hybrid, attn_period=2)
        if self.xlstm is not None:
            changes["xlstm"] = dataclasses.replace(self.xlstm, slstm_every=2)
        if self.modality is not None:
            changes["modality"] = dataclasses.replace(
                self.modality,
                num_prefix_embeddings=min(self.modality.num_prefix_embeddings, 8),
                mrope_sections=(16, 8, 8) if self.modality.mrope_sections else None,
            )
        return dataclasses.replace(self, name=self.name + "-smoke", **changes)


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


ASSIGNED_ARCHS = [
    "qwen2-vl-72b",
    "llama4-maverick-400b-a17b",
    "zamba2-7b",
    "command-r-35b",
    "xlstm-1.3b",
    "nemotron-4-15b",
    "h2o-danube-3-4b",
    "yi-6b",
    "musicgen-medium",
    "dbrx-132b",
]

# the paper's own MoE zoo (Table 1) used by the Fig 5/6 benchmarks
PAPER_ARCHS = ["mixtral-8x7b", "qwen2-moe", "phi-3.5-moe", "phi-tiny-moe"]


def _module_name(arch_id: str) -> str:
    return arch_id.replace("-", "_").replace(".", "_")


def get_config(arch_id: str) -> ModelConfig:
    """Load ``CONFIG`` from ``repro.configs.<arch_id>`` (dashes -> underscores)."""
    mod = importlib.import_module(f"repro.configs.{_module_name(arch_id)}")
    return mod.CONFIG


def all_configs() -> dict:
    return {a: get_config(a) for a in ASSIGNED_ARCHS + PAPER_ARCHS}


def dryrun_pairs() -> list:
    """Every (arch, shape) pair exercised by the dry-run, with documented skips."""
    pairs = []
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        for shape in INPUT_SHAPES.values():
            if shape.name == "long_500k" and not cfg.supports_long_context:
                continue  # skip documented in DESIGN.md §Arch-applicability
            pairs.append((arch, shape.name))
    return pairs
