"""Phi-3.5-MoE [arXiv:2404.14219] — paper Table 1: 60.8B total / 6.6B active,
16 experts top-2."""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="phi-3.5-moe",
    family="moe",
    source="arXiv:2404.14219 (paper Table 1)",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=6400,
    vocab_size=32064,
    sliding_window=131072,
    moe=MoEConfig(num_experts=16, top_k=2, d_ff_expert=6400, layer_period=1),
)
