"""Llama-4 Maverick 400B-A17B [hf:meta-llama/Llama-4-Scout-17B-16E family].

Interleaved MoE (every other layer), 128 routed experts top-1 plus one shared
expert; chunked local attention (iRoPE-style) keeps decode sub-quadratic, so
this arch runs the long_500k shape. Early-fusion multimodality is out of the
backbone's scope (text token stream here).
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=8192,                      # dense layers' FFN
    vocab_size=202048,
    rope_theta=500_000.0,
    attention_chunk=8192,           # chunked local attention
    qk_norm=True,
    moe=MoEConfig(
        num_experts=128,
        top_k=1,
        d_ff_expert=8192,
        layer_period=2,             # interleaved: every other layer MoE
        num_shared_experts=1,
        d_ff_shared=8192,
    ),
)
