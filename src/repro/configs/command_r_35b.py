"""Command-R 35B [hf:CohereForAI/c4ai-command-r-v01].

Dense GQA, no biases, parallel attention+MLP block, tied embeddings.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b",
    family="dense",
    source="hf:CohereForAI/c4ai-command-r-v01",
    num_layers=40,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=22528,
    vocab_size=256000,
    rope_theta=8_000_000.0,
    parallel_block=True,
    tie_embeddings=True,
)
