"""H2O-Danube3-4B [arXiv:2401.16818].

Llama/Mistral-mix dense GQA with sliding-window attention; the window bounds
the KV working set so long_500k decode runs.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-3-4b",
    family="dense",
    source="arXiv:2401.16818",
    num_layers=24,
    d_model=3840,
    num_heads=32,
    num_kv_heads=8,
    d_ff=10240,
    vocab_size=32000,
    sliding_window=4096,
)
