"""Qwen2-MoE [arXiv:2407.10671] — paper Table 1: 14.3B total / 2.7B active,
64 experts top-4 (fine-grained) + shared expert."""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen2-moe",
    family="moe",
    source="arXiv:2407.10671 (paper Table 1)",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=5632,
    vocab_size=151936,
    moe=MoEConfig(num_experts=64, top_k=4, d_ff_expert=1408, layer_period=1,
                  num_shared_experts=1, d_ff_shared=5632),
)
