"""Qwen2-VL-72B transformer backbone [arXiv:2409.12191].

M-RoPE (3D t/h/w rotary sections), dynamic-resolution vision frontend is a
stub supplying patch embeddings; the decoder consumes them as a prefix.
"""
from repro.configs.base import ModelConfig, ModalityConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    source="arXiv:2409.12191",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=29568,
    vocab_size=152064,
    rope_style="mrope",
    rope_theta=1_000_000.0,
    attn_bias=True,  # qwen2 uses qkv bias
    modality=ModalityConfig(
        kind="vision",
        num_prefix_embeddings=1024,     # patch embeddings prepended
        mrope_sections=(16, 24, 24),    # t/h/w sections of head_dim//2 = 64
    ),
)
