"""Zamba2-7B hybrid [arXiv:2411.15242].

Mamba2 backbone with a single SHARED attention block applied periodically —
the shared transformer block is zamba2's signature. 81 layers, MHA (kv=32).
"""
from repro.configs.base import ModelConfig, SSMConfig, HybridConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    source="arXiv:2411.15242",
    num_layers=81,
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,
    d_ff=14336,                     # shared block's FFN
    vocab_size=32000,
    ssm=SSMConfig(state_dim=64, conv_width=4, expand=2, head_dim=64),
    hybrid=HybridConfig(attn_period=6, shared_attention=True),
)
