"""xLSTM-1.3B [arXiv:2405.04517].

Alternating mLSTM (matrix-memory, parallelizable) and sLSTM (scalar, scan)
blocks; no FFN (d_ff=0) — blocks carry their own up/down projections.
Recurrent state is O(1) in sequence length, so long_500k runs.
"""
from repro.configs.base import ModelConfig, XLSTMConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    source="arXiv:2405.04517",
    num_layers=48,
    d_model=2048,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    rope_style="none",
    xlstm=XLSTMConfig(slstm_every=8, proj_factor_mlstm=2.0),
)
