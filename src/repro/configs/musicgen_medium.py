"""MusicGen-medium decoder backbone [arXiv:2306.05284].

Decoder-only over EnCodec tokens: 4 parallel codebooks (vocab 2048 each) with
a delay interleaving pattern; codebook embeddings are summed at the input and
4 LM heads predict the next frame. Text conditioning enters as stub prefix
embeddings (the conditioner itself is out of scope per the build carve-out).
"""
from repro.configs.base import ModelConfig, ModalityConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    source="arXiv:2306.05284",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    rope_style="none",          # musicgen uses learned/sinusoidal positions
    gated_mlp=False,
    activation="gelu",
    modality=ModalityConfig(kind="audio", num_codebooks=4,
                            num_prefix_embeddings=64),
)
