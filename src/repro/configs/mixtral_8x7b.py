"""Mixtral-8x7B [arXiv:2401.04088] — paper Table 1: 47.0B total / 13.0B active,
8 experts top-2."""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    source="arXiv:2401.04088 (paper Table 1)",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    sliding_window=4096,
    moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=14336, layer_period=1),
)
