"""Trip-count-aware cost analysis over compiled HLO text.

XLA's ``compiled.cost_analysis()`` counts the body of a ``while`` loop ONCE
(verified empirically: a length-10 scanned matmul reports 1 matmul's FLOPs).
Every model here scans over layers, so FLOPs / bytes / collective bytes from
the stock analysis are undercounted by ~num_layers for the scanned part.

This module re-derives the three roofline inputs from ``compiled.as_text()``:

  * ``dot_flops``        — 2 x |out| x contracted-dim product per dot/conv,
  * ``hbm_bytes``        — operand+result bytes of top-level (unfused) ops,
  * ``collective_bytes`` — ring-model bytes per collective type,

each multiplied by the product of enclosing ``while`` trip counts, which
post-optimization HLO exposes as ``backend_config={"known_trip_count":
{"n":"32"}, ...}``.

The HBM-byte model counts traffic at fusion boundaries: ops *inside* a
fusion computation stay in registers/VMEM (that is what fusion means), so
summing operand/result sizes of the ops at the top level of non-fusion
computations approximates bytes moved through HBM.
"""
from __future__ import annotations

import json
import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
               "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
               "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0, "s4": 1,
               "u4": 1}

_COMP_HEADER = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(")
_OP_LINE = re.compile(
    r"^\s+(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")
_SHAPE = re.compile(r"(f64|f32|bf16|f16|s64|u64|s32|u32|s16|u16|s8|u8|s4|u4|"
                    r"pred|f8e4m3fn|f8e5m2|token)\[([0-9,]*)\]")
_CALLED_ONE = re.compile(
    r"(?:calls|to_apply|body|condition|true_computation|false_computation)"
    r"=%([\w.\-]+)")
_CALLED_MANY = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE = re.compile(r"replica_groups=\{\{([^}]*)\}")

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute")

# opcodes that produce no HBM traffic of their own
_NO_TRAFFIC = {"parameter", "constant", "get-tuple-element", "bitcast",
               "tuple", "after-all", "iota", "partition-id", "replica-id"}


def _type_bytes(type_str: str) -> int:
    """Bytes of an HLO type string (handles tuples by summing elements)."""
    total = 0
    for dt, dims in _SHAPE.findall(type_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> Optional[Tuple[str, List[int]]]:
    m = _SHAPE.search(type_str)
    if not m:
        return None
    dt, dims = m.groups()
    return dt, [int(d) for d in dims.split(",") if d]


@dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    rest: str                  # operands + attributes tail of the line


@dataclass
class Computation:
    name: str
    is_entry: bool = False
    is_fusion: bool = False
    ops: List[Op] = field(default_factory=list)


def parse_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in hlo.splitlines():
        if line and not line[0].isspace():
            m = _COMP_HEADER.match(line)
            if m:
                name = m.group(2)
                cur = Computation(name, is_entry=bool(m.group(1)),
                                  is_fusion="fused_computation" in name
                                  or name.startswith("wrapped_"))
                comps[name] = cur
            continue
        if cur is None or "=" not in line:
            continue
        m = _OP_LINE.match(line)
        if m:
            cur.ops.append(Op(*m.groups()))
    return comps


def _called_comps(op: Op) -> List[str]:
    out = [m.group(1) for m in _CALLED_ONE.finditer(op.rest)]
    for m in _CALLED_MANY.finditer(op.rest):
        out += [n.strip().lstrip("%") for n in m.group(1).split(",")]
    return out


def comp_multipliers(comps: Dict[str, Computation]) -> Dict[str, float]:
    """Product of enclosing while trip counts per computation."""
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:       # fall back: the computation named main-ish
        entry = comps.get("main") or next(iter(comps.values()))
    mult: Dict[str, float] = defaultdict(float)

    def visit(name: str, m: float):
        if name not in comps:
            return
        if mult[name] >= m:          # already visited with >= multiplier
            return
        mult[name] = m
        for op in comps[name].ops:
            child_m = m
            if op.opcode == "while":
                tm = _TRIP.search(op.rest)
                child_m = m * (int(tm.group(1)) if tm else 1)
            for callee in _called_comps(op):
                visit(callee, child_m)

    visit(entry.name, 1.0)
    return dict(mult)


def _group_size(op: Op, default_group: int) -> int:
    m = _GROUPS_IOTA.search(op.rest)
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUPS_BRACE.search(op.rest)
    if m:
        return max(len(m.group(1).split(",")), 1)
    return max(default_group, 1)


@dataclass
class HLOCost:
    dot_flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: float = 0.0
    collectives: Dict[str, float] = field(default_factory=dict)
    collective_counts: Dict[str, float] = field(default_factory=dict)

    def as_dict(self) -> dict:
        d = dict(self.collectives)
        d["counts"] = {k: v for k, v in self.collective_counts.items()}
        return d


def analyze(hlo: str, default_group: int = 1) -> HLOCost:
    comps = parse_computations(hlo)
    mult = comp_multipliers(comps)
    # name -> type_str for operand shape lookup (dot contracting dims)
    shapes: Dict[str, str] = {}
    ops_by_name: Dict[str, Op] = {}
    for c in comps.values():
        for op in c.ops:
            shapes[op.name] = op.type_str
            ops_by_name[op.name] = op

    # Pure dtype/layout fusions (wrapped_convert etc.): the XLA CPU backend
    # materializes f32 copies of bf16 tensors because it has no native bf16
    # arithmetic; a TPU reads bf16 directly.  Charge such fusions zero
    # traffic and charge their consumers at the SOURCE dtype.
    pure_convert: Dict[str, bool] = {}
    for cname, c in comps.items():
        if c.is_fusion:
            body = [o for o in c.ops
                    if o.opcode not in ("parameter", "constant")]
            pure_convert[cname] = bool(body) and all(
                o.opcode in _PASSTHROUGH for o in body)

    # comp of each op, caller site of each computation, param lists
    comp_of: Dict[str, str] = {}
    params_of_comp: Dict[str, List[str]] = defaultdict(list)
    caller_of_comp: Dict[str, Op] = {}
    for cname, c in comps.items():
        idx_params = []
        for op in c.ops:
            comp_of[op.name] = cname
            if op.opcode == "parameter":
                pm = re.match(r"\s*(\d+)", op.rest)
                idx_params.append((int(pm.group(1)) if pm else len(idx_params),
                                   op.name))
            for callee in _called_comps(op):
                caller_of_comp[callee] = op
        params_of_comp[cname] = [n for _, n in sorted(idx_params)]

    def _dtype_bytes_of(type_str: str) -> int:
        m = _SHAPE.search(type_str)
        return DTYPE_BYTES[m.group(1)] if m else 0

    _src_memo: Dict[str, int] = {}

    def _src_dtype_bytes(name: str, depth: int = 0) -> int:
        """Element width of the ultimate producer, through dtype-promotion
        chains: passthrough ops, slicing, fusion roots, parameters (via the
        call site), and get-tuple-element of tuples / while carries."""
        if name in _src_memo:
            return _src_memo[name]
        if depth > 100 or name not in ops_by_name:
            return 0
        op = ops_by_name[name]
        _src_memo[name] = _dtype_bytes_of(op.type_str)  # cycle guard
        out = _src_memo[name]
        refs = _operand_names(op)
        if op.opcode in _PASSTHROUGH or op.opcode in _SLICING \
                or op.opcode == "dynamic-update-slice":
            if refs:
                out = _src_dtype_bytes(refs[0], depth + 1) or out
        elif op.opcode == "fusion":
            callee = next((cn for cn in _called_comps(op) if cn in comps),
                          None)
            if callee and comps[callee].ops:
                root = comps[callee].ops[-1]
                out = _src_dtype_bytes(root.name, depth + 1) or out
        elif op.opcode == "parameter":
            cname = comp_of.get(name)
            caller = caller_of_comp.get(cname)
            if caller is not None:
                try:
                    pidx = params_of_comp[cname].index(name)
                except ValueError:
                    pidx = -1
                crefs = _operand_names(caller)
                if 0 <= pidx < len(crefs):
                    out = _src_dtype_bytes(crefs[pidx], depth + 1) or out
        elif op.opcode == "get-tuple-element":
            im = re.search(r"index=(\d+)", op.rest)
            if refs and im:
                k = int(im.group(1))
                base = refs[0]
                # hop through while/params to the defining tuple
                hops = 0
                while base in ops_by_name and hops < 20:
                    bop = ops_by_name[base]
                    if bop.opcode == "while":
                        base = _operand_names(bop)[0]
                    elif bop.opcode == "parameter":
                        cname = comp_of.get(base)
                        caller = caller_of_comp.get(cname)
                        if caller is None:
                            break
                        base = _operand_names(caller)[0] \
                            if _operand_names(caller) else base
                        if caller.opcode != "while" and base == refs[0]:
                            break
                    elif bop.opcode == "tuple":
                        brefs = _operand_names(bop)
                        if k < len(brefs):
                            out = _src_dtype_bytes(brefs[k], depth + 1) or out
                        break
                    else:
                        break
                    hops += 1
        _src_memo[name] = out
        return out

    def src_scale(operand_name: str, res_type: str) -> float:
        """min(1, source-dtype / result-dtype) through convert chains."""
        res_b = _dtype_bytes_of(res_type)
        src_b = _src_dtype_bytes(operand_name)
        if res_b and src_b and src_b < res_b:
            return src_b / res_b
        return 1.0

    cost = HLOCost(collectives={k: 0.0 for k in COLLECTIVE_OPS},
                   collective_counts={k: 0.0 for k in COLLECTIVE_OPS})

    for c in comps.values():
        m = mult.get(c.name, 0.0)
        if m <= 0:
            continue
        for op in c.ops:
            # ---- dot/conv FLOPs (counted inside fusions too) -------------
            if op.opcode in ("dot", "convolution"):
                out = _shape_dims(op.type_str)
                if out is not None:
                    _, odims = out
                    n_out = 1
                    for d in odims:
                        n_out *= d
                    k = 1
                    cm = _CONTRACT.search(op.rest)
                    if cm:
                        # lhs operand = first %ref in the operand list
                        opnd = re.match(r"\s*%?([\w.\-]+)", op.rest)
                        lhs_dims = None
                        if opnd and opnd.group(1) in shapes:
                            sh = _shape_dims(shapes[opnd.group(1)])
                            lhs_dims = sh[1] if sh else None
                        if lhs_dims:
                            for ci in cm.group(1).split(","):
                                if ci:
                                    idx = int(ci)
                                    if idx < len(lhs_dims):
                                        k *= lhs_dims[idx]
                    cost.dot_flops += m * 2.0 * n_out * k
            # ---- collective bytes (ring accounting) ----------------------
            base = next((k for k in COLLECTIVE_OPS
                         if op.opcode == k or op.opcode == k + "-start"), None)
            if base is not None and not op.opcode.endswith("-done"):
                nbytes = _type_bytes(op.type_str)
                opnds = _operand_names(op)
                if opnds:       # charge at the pre-promotion source dtype
                    nbytes *= src_scale(opnds[0], op.type_str)
                if base == "all-gather":
                    # result type is the gathered (full) buffer
                    g = _group_size(op, default_group)
                    moved = nbytes * (g - 1) / g
                elif base == "all-reduce":
                    g = _group_size(op, default_group)
                    moved = 2 * nbytes * (g - 1) / g
                elif base == "reduce-scatter":
                    g = _group_size(op, default_group)
                    moved = nbytes * (g - 1)   # result is the shard
                elif base == "all-to-all":
                    g = _group_size(op, default_group)
                    moved = nbytes * (g - 1) / g
                else:  # collective-permute
                    moved = nbytes
                cost.collectives[base] += m * moved
                cost.collective_counts[base] += m
                cost.collective_bytes += m * moved
            # ---- HBM traffic at fusion boundaries ------------------------
            if not c.is_fusion and op.opcode not in _NO_TRAFFIC:
                cost.hbm_bytes += m * _op_traffic(op, comps, shapes,
                                                  pure_convert, src_scale)
    return cost


_SLICING = {"dynamic-slice", "slice", "gather"}


def _operand_names(op: Op) -> List[str]:
    # operands end at the first close-paren; attributes (calls=, body=,
    # metadata=...) follow it, so no name-based filtering is needed
    head = op.rest.split(")")[0]
    return [r.group(1) for r in re.finditer(r"%([\w.\-]+)", head)]


def _op_traffic(op: Op, comps: Dict[str, Computation],
                shapes: Dict[str, str], pure_convert=None,
                src_scale=None) -> float:
    """HBM bytes moved by one top-level op.

    Slicing ops read only what they produce; dynamic-update-slice writes only
    the update region; fusions are analysed per-parameter so a fused
    dynamic-slice of a big loop-carried buffer (the lax.scan pattern) is
    charged the slice, not the buffer.  Reads resolve through dtype-promotion
    chains (``src_scale``) so a CPU-backend f32 copy of a bf16 tensor is
    charged at bf16 width, matching the TPU target.
    """
    out_bytes = _type_bytes(op.type_str)
    operands = _operand_names(op)

    def in_cost(name: str) -> float:
        b = _type_bytes(shapes.get(name, ""))
        if src_scale is not None and name in shapes:
            b *= src_scale(name, shapes[name])
        return b

    if op.opcode in _SLICING:
        return 2.0 * out_bytes
    if op.opcode == "dynamic-update-slice":
        upd = _type_bytes(shapes.get(operands[1], "")) if len(operands) > 1 \
            else out_bytes
        return 2.0 * upd
    if op.opcode == "fusion":
        comp = None
        for cname in _called_comps(op):
            if cname in comps:
                comp = comps[cname]
                break
        if comp is not None:
            if pure_convert is not None and pure_convert.get(comp.name):
                return 0.0      # dtype-copy fusion: absent on TPU
            return _fusion_traffic(op, comp, shapes, out_bytes, operands,
                                   in_cost)

    in_bytes = sum(in_cost(o) for o in operands)
    return out_bytes + in_bytes


_PASSTHROUGH = {"convert", "bitcast", "copy", "reshape", "transpose"}


def _fusion_traffic(op: Op, comp: Computation, shapes: Dict[str, str],
                    out_bytes: float, operands: List[str],
                    in_cost=None) -> float:
    """HBM traffic of one fusion call, with TPU in-place-DUS semantics.

    Convert/bitcast chains are resolved through: the XLA CPU backend has no
    native bf16 dot, so it upcasts operands and emits full-pool
    convert(dus(convert(param), update)) round-trips for the lax.scan KV
    update pattern; a TPU emits a native in-place DUS fusion that writes
    only the update region.  We charge the TPU semantics (and document the
    CPU artifact in EXPERIMENTS.md).
    """
    inner = {o.name: o for o in comp.ops}
    param_of: Dict[str, int] = {}
    consumers: Dict[str, List[Op]] = defaultdict(list)
    for iop in comp.ops:
        if iop.opcode == "parameter":
            pm = re.match(r"\s*(\d+)", iop.rest)
            if pm:
                param_of[iop.name] = int(pm.group(1))
        else:
            for ref in _operand_names(iop):
                consumers[ref].append(iop)

    def resolve(name: str) -> str:
        """Follow pure dtype/layout chains back to their source op."""
        seen = 0
        while name in inner and inner[name].opcode in _PASSTHROUGH \
                and seen < 32:
            refs = _operand_names(inner[name])
            if not refs:
                break
            name = refs[0]
            seen += 1
        return name

    def real_consumers(name: str) -> List[Op]:
        """Consumers reached through pure dtype/layout chains."""
        out, stack, seen = [], [name], set()
        while stack:
            n = stack.pop()
            for co in consumers.get(n, []):
                if co.name in seen:
                    continue
                seen.add(co.name)
                if co.opcode in _PASSTHROUGH:
                    stack.append(co.name)
                else:
                    out.append(co)
        return out

    _INPLACE = ("dynamic-update-slice", "scatter")

    def upd_bytes(iop: Op) -> float:
        # dus(target, update, idx...) / scatter(target, indices, updates)
        refs = _operand_names(iop)
        k = 1 if iop.opcode == "dynamic-update-slice" else 2
        if len(refs) > k:
            src = refs[k]
            if src in inner:
                return _type_bytes(inner[src].type_str)
            return _type_bytes(shapes.get(src, ""))
        return 0.0

    reads = 0.0
    dus_on_param = 0.0
    for pname, pidx in param_of.items():
        if pidx < len(operands):
            full = in_cost(operands[pidx]) if in_cost is not None \
                else _type_bytes(shapes.get(operands[pidx], ""))
        else:
            full = 0.0
        cons = real_consumers(pname)
        if not cons:
            continue
        if all(co.opcode in _INPLACE
               and resolve(_operand_names(co)[0]) == pname for co in cons):
            # parameter only serves as in-place update target (TPU aliases
            # the donated buffer): charge the updated rows, not the buffer
            u = sum(upd_bytes(co) for co in cons)
            reads += u
            dus_on_param = max(dus_on_param, u)
        elif all(co.opcode in _SLICING for co in cons):
            reads += sum(_type_bytes(co.type_str) for co in cons)
        else:
            reads += full
    # root resolving to an in-place update on a param -> write update only
    root = comp.ops[-1] if comp.ops else None
    writes = out_bytes
    if root is not None:
        rsrc = resolve(root.name)
        if rsrc in inner and inner[rsrc].opcode in _INPLACE and dus_on_param:
            writes = dus_on_param
    return reads + writes
