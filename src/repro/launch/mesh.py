"""Production meshes and sharding-rule construction.

Single pod: (data=16, model=16) = 256 chips (v5e-class).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the pod axis is pure
data parallelism (batch + KV pool blocks shard over it; weights/optimizer
stay FSDP-within-pod so no per-layer gather crosses the pod boundary —
only the gradient all-reduce does).

``make_production_mesh`` is a function (never a module-level constant) so
importing this module touches no jax device state.
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.models.sharding import DEFAULT_RULES, ShardingRules


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_rules(mesh, *, kv_axes: Optional[tuple] = None) -> ShardingRules:
    """Logical->physical rules for the given mesh (pod-aware)."""
    rules = dict(DEFAULT_RULES)
    multi_pod = "pod" in mesh.shape
    if multi_pod:
        rules["act_batch"] = ("pod", "data")
        rules["kv_blocks"] = ("pod", "data", "model")
    if kv_axes is not None:
        rules["kv_blocks"] = kv_axes
    return ShardingRules(mesh=mesh, rules=rules)


def total_shards(rules: ShardingRules, logical: str = "kv_blocks") -> int:
    return rules.axis_size(rules.axis(logical))
