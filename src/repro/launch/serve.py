"""Serving launcher: Harvest engine over a reduced model on this host.

  PYTHONPATH=src python -m repro.launch.serve --arch yi-6b \
      --num-requests 16 --scheduler fair --peer-budget-mb 2

Multi-peer topologies (per-device link lanes, topology-aware placement,
timeline-driven pressure when combined with --with-churn --mode async):

  PYTHONPATH=src python -m repro.launch.serve --topology nvlink-mesh-4 \
      --mode async --prefetch --with-churn

Coalesced transfer batching + chunked striping (one setup latency per
link lane per step; objects over --stripe-min-mb ride link-disjoint
sub-lanes with chunk-granular completion):

  PYTHONPATH=src python -m repro.launch.serve --topology v5e-torus-2x2 \
      --coalesce --stripe 4 --prefetch

Request-lifecycle serving (clock-driven arrivals, SLO classes, admission
policies; per-class TTFT/TPOT percentiles + SLO-goodput in the summary):

  PYTHONPATH=src python -m repro.launch.serve --workload poisson \
      --arrival-rate 20000 --tenants latency:2,batch:1 --slo-ms 1.5 \
      --admission deadline --scheduler fair

Harvested prefix cache (radix-trie cross-request KV sharing: retired
prompts publish their blocks, later requests sharing the system prompt
skip that part of prefill — the summary prints the hit rate):

  PYTHONPATH=src python -m repro.launch.serve --workload poisson \
      --prefix-cache --prefix-share 0.8 --scheduler fair

Continuous batching (iteration-level slot refill is on for async mode;
chunked prefill interleaves long prompts with decode steps, and the
speculative-decode seam charges draft/verify windows on the same clock
— the summary prints batch occupancy + bubble time):

  PYTHONPATH=src python -m repro.launch.serve --workload poisson \
      --chunk-prefill-tokens 32 --spec-draft 4 --spec-accept-rate 0.7
"""
from __future__ import annotations

import argparse

import jax
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--num-requests", type=int, default=8)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=3)
    ap.add_argument("--block-size", type=int, default=8)
    ap.add_argument("--local-slots", type=int, default=16)
    ap.add_argument("--peer-budget-mb", type=float, default=4.0)
    ap.add_argument("--scheduler", choices=["fcfs", "fair"], default="fcfs")
    ap.add_argument("--durability", choices=["host_backed", "lossy"],
                    default="host_backed")
    ap.add_argument("--with-churn", action="store_true",
                    help="drive revocations from the cluster trace monitor")
    ap.add_argument("--topology", default=None,
                    help="interconnect preset (nvlink-2gpu, nvlink-mesh-4, "
                         "nvlink-mesh-8, pcie-switch-4, v5e-torus-2x2, "
                         "v5e-torus-4x2): per-peer-device link lanes + "
                         "topology/churn-aware placement; default keeps the "
                         "flat 2-device model")
    ap.add_argument("--monitor-interval-us", type=float, default=None,
                    help="drive trace ticks on the simulated transfer "
                         "clock every N microseconds (async mode only; "
                         "default: one tick every 4 scheduler steps)")
    ap.add_argument("--mode", choices=["sync", "async"], default="sync",
                    help="clock mode: legacy pre-summed vs event timeline")
    ap.add_argument("--prefetch", action="store_true",
                    help="cross-step prefetch (implies --mode async)")
    ap.add_argument("--coalesce", action="store_true",
                    help="batch same-lane transfers issued in one step into "
                         "a single submission paying one setup latency "
                         "(implies --mode async)")
    ap.add_argument("--stripe", type=int, default=0, metavar="WAYS",
                    help="stripe objects >= --stripe-min-mb into chunks over "
                         "N link-disjoint sub-lanes with chunk-granular "
                         "completion (implies --coalesce)")
    ap.add_argument("--stripe-chunk-kb", type=int, default=1024,
                    help="stripe chunk size in KiB (default 1024)")
    ap.add_argument("--stripe-min-mb", type=float, default=4.0,
                    help="size floor in MiB below which objects are never "
                         "striped (default 4)")
    ap.add_argument("--workload", default="legacy",
                    choices=["legacy", "poisson", "bursty", "diurnal"],
                    help="arrival process driving the request-lifecycle "
                         "API (requests become visible at their clock "
                         "arrival time); 'legacy' submits everything "
                         "up-front through the compat wrapper")
    ap.add_argument("--arrival-rate", type=float, default=20000.0,
                    help="mean arrival rate in requests per SIMULATED "
                         "second (the transfer-engine clock runs in "
                         "sub-millisecond territory for reduced models)")
    ap.add_argument("--slo-ms", type=float, default=None,
                    help="TTFT SLO for the latency class in simulated ms "
                         "(e2e SLO is 10x); default: no deadlines")
    ap.add_argument("--tenants", default="throughput:1",
                    help="comma-separated SLO-class mix 'class:weight' "
                         "(classes: latency, throughput, batch), e.g. "
                         "'latency:2,batch:1'")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="enable the harvested prefix cache: retired "
                         "prompts' KV blocks are published into a radix "
                         "trie over the block store and later requests "
                         "sharing the prefix skip that part of prefill")
    ap.add_argument("--prefix-share", type=float, default=0.0,
                    metavar="FRAC",
                    help="fraction of each tenant's requests carrying a "
                         "shared system prompt (lifecycle workloads only; "
                         "pairs naturally with --prefix-cache)")
    ap.add_argument("--prefix-len", type=int, default=32,
                    help="shared system-prompt length in tokens "
                         "(default 32)")
    ap.add_argument("--admission", default="all",
                    choices=["all", "headroom", "deadline"],
                    help="admission policy in front of the scheduler")
    ap.add_argument("--chunk-prefill-tokens", type=int, default=None,
                    metavar="N",
                    help="split prefills into resumable chunks of N tokens "
                         "interleaved with decode steps (implies --mode "
                         "async); long prompts stop stalling latency-class "
                         "decodes")
    ap.add_argument("--spec-draft", type=int, default=0, metavar="K",
                    help="speculative-decode seam: charge K draft tokens + "
                         "one verify pass per landed token on the simulated "
                         "clock (0 = off; emitted tokens are unchanged)")
    ap.add_argument("--spec-accept-rate", type=float, default=0.7,
                    help="per-position draft acceptance probability for "
                         "--spec-draft (default 0.7)")
    ap.add_argument("--fidelity-policy", default="off",
                    help="per-SLO-class demotion precision: 'off' keeps "
                         "every demoted block FP16, 'slo' quantizes "
                         "throughput/batch-class blocks to int8 on demote "
                         "(latency class stays bit-exact), 'always' "
                         "quantizes every demotion including shared prefix "
                         "blocks (max capacity, offline fleets)")
    ap.add_argument("--cold-tier", action="store_true",
                    help="add the LOCAL_SSD cold tier below host DRAM: "
                         "reconstructible evictions take the cheaper SSD "
                         "rung instead of LOST, durable write-backs "
                         "overflow host onto SSD (needs --mode async: the "
                         "ladder charges the event timeline)")
    ap.add_argument("--controller", default="off",
                    choices=["off", "stability"],
                    help="closed-loop stability controller: estimates "
                         "arrival/service/KV rates online, computes the "
                         "stability region, and sheds/defers + caps the "
                         "batch + throttles prefetch/harvest appetite "
                         "when the workload leaves it (needs --mode "
                         "async: the control loop ticks on the event "
                         "timeline)")
    ap.add_argument("--ctrl-tick-us", type=float, default=None,
                    metavar="US",
                    help="controller tick period in simulated "
                         "microseconds (default: 8x the weight-pass "
                         "time)")
    ap.add_argument("--ctrl-headroom", type=float, default=None,
                    metavar="FRAC",
                    help="fraction of effective capacity the engaged "
                         "controller keeps free (default 0.15)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.monitor_interval_us and not args.with_churn:
        ap.error("--monitor-interval-us needs --with-churn (there is no "
                 "monitor to drive without a cluster trace)")
    if args.monitor_interval_us and args.mode != "async" and not args.prefetch:
        ap.error("--monitor-interval-us needs --mode async: timeline-driven "
                 "pressure fires on the event clock; sync mode keeps the "
                 "legacy every-4-steps drive")
    if not 0.0 <= args.prefix_share <= 1.0:
        ap.error("--prefix-share must be in [0, 1]")
    if args.prefix_share > 0 and args.workload == "legacy":
        ap.error("--prefix-share needs a lifecycle workload (--workload "
                 "poisson|bursty|diurnal): the legacy path draws prompts "
                 "without tenant prompt pools")
    if args.chunk_prefill_tokens is not None and args.chunk_prefill_tokens <= 0:
        ap.error(f"--chunk-prefill-tokens must be positive, got "
                 f"{args.chunk_prefill_tokens}")
    if args.spec_draft < 0:
        ap.error(f"--spec-draft must be >= 0, got {args.spec_draft}")
    if args.spec_draft and not 0.0 <= args.spec_accept_rate <= 1.0:
        ap.error(f"--spec-accept-rate must be in [0, 1], got "
                 f"{args.spec_accept_rate}")
    if args.fidelity_policy not in ("off", "slo", "always"):
        ap.error(f"unknown --fidelity-policy {args.fidelity_policy!r} "
                 "(one of: off, slo, always)")
    if args.cold_tier and args.mode != "async" and not (
            args.prefetch or args.coalesce or args.stripe
            or args.chunk_prefill_tokens is not None):
        ap.error("--cold-tier needs --mode async: the SSD rung of the "
                 "eviction ladder charges the event timeline")
    if args.controller != "off" and args.mode != "async" and not (
            args.prefetch or args.coalesce or args.stripe
            or args.chunk_prefill_tokens is not None):
        ap.error("--controller stability needs --mode async: the control "
                 "loop ticks on the event timeline")
    if args.controller == "off" and (args.ctrl_tick_us is not None
                                     or args.ctrl_headroom is not None):
        ap.error("--ctrl-tick-us/--ctrl-headroom need --controller "
                 "stability (there is no control loop to configure)")
    if args.ctrl_tick_us is not None and args.ctrl_tick_us <= 0:
        ap.error(f"--ctrl-tick-us must be positive, got "
                 f"{args.ctrl_tick_us}")
    if args.ctrl_headroom is not None \
            and not 0.0 <= args.ctrl_headroom < 0.9:
        ap.error(f"--ctrl-headroom must be in [0, 0.9), got "
                 f"{args.ctrl_headroom}")

    from repro.configs import get_config
    from repro.core import (ClusterTrace, ClusterTraceConfig, CoalesceConfig,
                            HarvestRuntime, PrefetchConfig,
                            TopologyAwarePolicy, get_topology)
    from repro.models import model as M
    from repro.serving import (ControllerConfig, SpecDecodeConfig,
                               TenantSpec, Workload)

    cfg = get_config(args.arch).reduced()
    params = M.init_params(jax.random.PRNGKey(args.seed), cfg)
    budget = int(args.peer_budget_mb * 2**20)
    topology = get_topology(args.topology) if args.topology else None
    budgets = (topology.device_budgets(budget) if topology
               else {0: budget, 1: budget})
    trace = None
    if args.with_churn:
        trace = ClusterTrace(ClusterTraceConfig(
            num_devices=len(budgets), capacity_bytes=2 * budget,
            seed=args.seed, job_arrival_p=0.3, job_size_frac=(0.2, 0.6)))
    coalesce = None
    if args.coalesce or args.stripe:
        coalesce = CoalesceConfig(
            stripe_ways=args.stripe,
            chunk_nbytes=args.stripe_chunk_kb << 10,
            min_stripe_nbytes=int(args.stripe_min_mb * 2**20))
    runtime = HarvestRuntime(
        budgets, trace=trace, topology=topology,
        policy=TopologyAwarePolicy(topology) if topology else None,
        coalesce=coalesce,
        monitor_interval_s=(args.monitor_interval_us * 1e-6
                            if args.monitor_interval_us else None))

    mode = "async" if (args.prefetch or coalesce is not None
                       or args.chunk_prefill_tokens is not None) else args.mode
    spec = (SpecDecodeConfig(draft_tokens=args.spec_draft,
                             accept_rate=args.spec_accept_rate)
            if args.spec_draft else None)
    controller = None
    if args.controller == "stability":
        ctrl_kwargs = {}
        if args.ctrl_tick_us is not None:
            ctrl_kwargs["tick_interval_s"] = args.ctrl_tick_us * 1e-6
        if args.ctrl_headroom is not None:
            ctrl_kwargs["headroom"] = args.ctrl_headroom
        controller = ControllerConfig(**ctrl_kwargs)
    server = runtime.server(
        cfg, params, max_batch=args.max_batch, block_size=args.block_size,
        num_local_slots=args.local_slots,
        scheduler=args.scheduler, durability=args.durability, seed=args.seed,
        mode=mode, prefetch=PrefetchConfig() if args.prefetch else None,
        admission=args.admission, prefix_cache=args.prefix_cache,
        chunk_prefill_tokens=args.chunk_prefill_tokens, spec_decode=spec,
        fidelity_policy=args.fidelity_policy, cold_tier=args.cold_tier,
        controller=controller)
    eng = server.engine

    if args.workload == "legacy":
        # compat wrapper: every request visible at clock 0, one class
        rng = np.random.default_rng(args.seed)
        reqs = []
        for _ in range(args.num_requests):
            n = int(rng.integers(5, 40))
            reqs.append(eng.submit(
                list(rng.integers(3, min(cfg.vocab_size, 250), size=n)),
                args.max_new_tokens))
        stats = eng.run()
    else:
        tenants = []
        for part in args.tenants.split(","):
            klass, _, weight = part.partition(":")
            klass = klass.strip()
            slo_s = args.slo_ms * 1e-3 if args.slo_ms else None
            tenants.append(TenantSpec(
                klass, weight=float(weight or 1), slo=klass,
                priority=1 if klass == "latency" else 0,
                prompt_len=(5, 20) if klass == "latency" else (5, 40),
                max_new_tokens=args.max_new_tokens,
                ttft_slo_s=slo_s if klass == "latency" else None,
                e2e_slo_s=slo_s * 10 if (slo_s and klass == "latency")
                else None,
                prefix_share=args.prefix_share,
                prefix_len=args.prefix_len))
        workload = Workload(
            num_requests=args.num_requests, arrival=args.workload,
            rate=args.arrival_rate, seed=args.seed, tenants=tuple(tenants),
            vocab=(3, min(cfg.vocab_size, 250)))
        stats = server.run(workload)
        reqs = [h._req for h in server.handles]
    served = [r for r in eng.finished if r.state == "done"]
    print(f"\n{len(served)}/{len(reqs)} requests served "
          f"({stats.rejected} shed by admission)")
    print(stats.summary())
    print(f"kv manager: {dict(eng.kv_mgr.stats)}")
    print(f"allocator:  {dict(eng.allocator.stats)}")
    print(f"tiers:      {runtime.tier_counts()}")
    for r in served[:4]:
        print(f"  req {r.req_id} [{r.slo}] t={r.arrival_t * 1e3:.3f}ms: "
              f"{len(r.prompt)} prompt -> {r.output[:8]}…")


if __name__ == "__main__":
    main()
