import os
# 512 placeholder devices for the production meshes; the two while-loop LICM
# passes are disabled for TPU dtype fidelity: the CPU backend has no native
# bf16 dot, so it upcasts operands to f32 and (with LICM on) hoists full
# f32 copies of every loop-carried weight/KV-pool stack out of the layer
# scan — phantom buffers a TPU, with native bf16 MXU ops, never allocates.
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    "--xla_disable_hlo_passes=while-loop-invariant-code-motion,"
    "while-loop-expensive-invariant-code-motion")

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, extract memory/cost/collective analyses, and append
JSONL records that feed EXPERIMENTS.md §Dry-run and §Roofline.

The XLA_FLAGS line above MUST run before any other import (jax locks the
device count on first init) — do not move it.

Usage:
  python -m repro.launch.dryrun --arch yi-6b --shape train_4k --mesh pod
  python -m repro.launch.dryrun --all [--mesh pod|multipod|both] [--force]
"""

import argparse
import json
import math
import re
import subprocess
import sys
import time
from pathlib import Path

DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
               "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
               "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

COLLECTIVE_RE = re.compile(
    r"=\s*(?P<result>[^=]*?)\s*"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?P<start>-start)?\(")
SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|s64|u64|s32|u32|s16|u16|s8|u8|pred|"
                      r"f8e4m3fn|f8e5m2)\[([0-9,]*)\]")
GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def parse_collectives(hlo: str, default_group: int) -> dict:
    """Per-device bytes moved through links, by collective type.

    Ring-algorithm accounting: all-gather/reduce-scatter/all-to-all move
    (g-1)/g of the full buffer per device; all-reduce moves 2x that;
    collective-permute moves the full buffer once.
    """
    out = {"all-reduce": 0.0, "all-gather": 0.0, "reduce-scatter": 0.0,
           "all-to-all": 0.0, "collective-permute": 0.0}
    counts = dict.fromkeys(out, 0)
    for line in hlo.splitlines():
        m = COLLECTIVE_RE.search(line)
        if m is None or "-done" in line:
            continue
        op = m.group("op")
        result = m.group("result")
        nbytes = 0
        for dt, dims in SHAPE_RE.findall(result):
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * DTYPE_BYTES[dt]
        g = default_group
        gm = GROUPS_RE.search(line)
        if gm:
            g = int(gm.group(2))
        else:
            gb = GROUPS_BRACE_RE.search(line)
            if gb:
                g = len(gb.group(1).split(","))
        g = max(g, 1)
        if op == "all-reduce":
            moved = 2 * nbytes * (g - 1) / g
        elif op == "collective-permute":
            moved = nbytes
        else:
            moved = nbytes * (g - 1) / g
        out[op] += moved
        counts[op] += 1
    out["counts"] = counts
    return out


def model_flops(cfg, shape) -> float:
    pc = cfg.param_counts()
    n = pc["active"]
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch          # decode: one token/request


def harvest_record(cfg, peer_fraction: float) -> dict:
    """Tier-link context for a dry-run record, via the HarvestRuntime facade.

    Uses the runtime's TransferEngine (the single source of transfer-time
    truth) to report what one expert / one KV block costs to reload from
    each non-local tier on the production hardware model.
    """
    from repro.core.runtime import HarvestRuntime
    from repro.core.tiers import TPU_V5E, Tier, expert_bytes, kv_block_bytes

    rt = HarvestRuntime(hardware=TPU_V5E)
    out = {"hardware": TPU_V5E.name, "peer_fraction": peer_fraction}
    units = {}
    if cfg.moe is not None:
        units["expert"] = expert_bytes(cfg)
    if cfg.has_kv_cache:
        units["kv_block_16"] = kv_block_bytes(cfg, 16)
    for name, nbytes in units.items():
        peer = rt.transfers.transfer(name, nbytes, Tier.PEER_HBM,
                                     Tier.LOCAL_HBM, client="dryrun").seconds
        host = rt.transfers.transfer(name, nbytes, Tier.HOST_DRAM,
                                     Tier.LOCAL_HBM, client="dryrun").seconds
        out[name] = {"bytes": nbytes, "peer_reload_s": peer,
                     "host_reload_s": host, "peer_speedup": host / peer}
    return out


def run_one(arch: str, shape_name: str, mesh_kind: str,
            harvest_inplace: bool = False, peer_fraction: float = 0.0) -> dict:
    import jax
    from repro.configs import INPUT_SHAPES, get_config
    from repro.core.tiers import TPU_V5E
    from repro.launch.mesh import make_production_mesh, make_rules
    from repro.launch.specs import build_lowering

    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    multi_pod = mesh_kind == "multipod"
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = make_rules(mesh)
    n_dev = math.prod(mesh.devices.shape)

    fn, args, shardings = build_lowering(cfg, shape, rules,
                                         harvest_inplace=harvest_inplace,
                                         peer_fraction=peer_fraction)
    from repro.launch.hlo_analysis import analyze as analyze_hlo

    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "devices": n_dev, "harvest_inplace": harvest_inplace,
           "peer_fraction": peer_fraction, "ok": False,
           "harvest": harvest_record(cfg, peer_fraction)}
    # donation mirrors production: train updates (params, opt) in place,
    # decode updates the KV/state pools in place
    donate = {"train": (0, 1), "decode": (1,), "prefill": ()}[shape.kind]
    t0 = time.time()
    try:
        with mesh:
            jitted = jax.jit(fn, in_shardings=shardings, donate_argnums=donate)
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

            ma = compiled.memory_analysis()
            ca = compiled.cost_analysis() or {}
            hlo = compiled.as_text()
            coll = parse_collectives(hlo, default_group=n_dev)
            # trip-count-aware analysis: XLA's cost_analysis counts while
            # (lax.scan) bodies ONCE; every model here scans over layers.
            hcost = analyze_hlo(hlo, default_group=n_dev)

        flops_xla = float(ca.get("flops", 0.0))
        bytes_xla = float(ca.get("bytes accessed", 0.0))
        # corrected terms: parsed dot FLOPs / fusion-boundary HBM traffic /
        # ring-model collective bytes, each x enclosing while trip counts
        flops_dev = max(hcost.dot_flops, flops_xla)
        bytes_dev = max(hcost.hbm_bytes, bytes_xla)
        coll_bytes_dev = hcost.collective_bytes
        hw = TPU_V5E
        compute_term = flops_dev / hw.peak_flops
        memory_term = bytes_dev / hw.hbm_bw
        collective_term = coll_bytes_dev / hw.peer_link.bandwidth
        mf = model_flops(cfg, shape)
        mf_dev = mf / n_dev
        rec.update(
            ok=True,
            lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
            mem=dict(
                argument_bytes=ma.argument_size_in_bytes,
                output_bytes=ma.output_size_in_bytes,
                temp_bytes=ma.temp_size_in_bytes,
                alias_bytes=ma.alias_size_in_bytes,
                total_bytes=(ma.argument_size_in_bytes + ma.output_size_in_bytes
                             + ma.temp_size_in_bytes - ma.alias_size_in_bytes),
            ),
            cost=dict(flops_per_device=flops_dev, bytes_per_device=bytes_dev,
                      flops_xla=flops_xla, bytes_xla=bytes_xla,
                      dot_flops_parsed=hcost.dot_flops,
                      hbm_bytes_parsed=hcost.hbm_bytes),
            collectives=hcost.as_dict(),
            collectives_untripped=coll,
            roofline=dict(
                compute_term_s=compute_term,
                memory_term_s=memory_term,
                collective_term_s=collective_term,
                bottleneck=max(
                    [("compute", compute_term), ("memory", memory_term),
                     ("collective", collective_term)], key=lambda kv: kv[1])[0],
                model_flops_per_device=mf_dev,
                useful_flops_ratio=(mf_dev / flops_dev) if flops_dev else None,
            ),
        )
    except Exception as e:  # noqa: BLE001 — a dry-run failure is a bug report
        rec["error"] = f"{type(e).__name__}: {e}"[:2000]
    rec["wall_s"] = round(time.time() - t0, 2)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--harvest-inplace", action="store_true")
    ap.add_argument("--peer-fraction", type=float, default=0.0)
    ap.add_argument("--out", default="results/dryrun.jsonl")
    ap.add_argument("--timeout", type=int, default=3000)
    args = ap.parse_args()

    out_path = Path(args.out)
    out_path.parent.mkdir(parents=True, exist_ok=True)

    if args.all:
        from repro.configs import dryrun_pairs
        done = set()
        if out_path.exists() and not args.force:
            for line in out_path.read_text().splitlines():
                try:
                    r = json.loads(line)
                    if r.get("ok"):
                        done.add((r["arch"], r["shape"], r["mesh"],
                                  r.get("harvest_inplace", False)))
                except json.JSONDecodeError:
                    pass
        meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]
        pairs = dryrun_pairs()
        todo = [(a, s, m) for m in meshes for (a, s) in pairs
                if (a, s, m, args.harvest_inplace) not in done]
        print(f"{len(todo)} lowerings to run ({len(done)} cached)")
        for i, (a, s, m) in enumerate(todo):
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", a, "--shape", s, "--mesh", m, "--out", args.out]
            if args.harvest_inplace:
                cmd.append("--harvest-inplace")
            if args.peer_fraction:
                cmd += ["--peer-fraction", str(args.peer_fraction)]
            print(f"[{i+1}/{len(todo)}] {a} x {s} x {m}", flush=True)
            try:
                subprocess.run(cmd, timeout=args.timeout, check=False)
            except subprocess.TimeoutExpired:
                with out_path.open("a") as f:
                    f.write(json.dumps({
                        "arch": a, "shape": s, "mesh": m, "ok": False,
                        "error": f"timeout after {args.timeout}s"}) + "\n")
        return

    assert args.arch and args.shape, "--arch/--shape required without --all"
    rec = run_one(args.arch, args.shape, args.mesh,
                  harvest_inplace=args.harvest_inplace,
                  peer_fraction=args.peer_fraction)
    with out_path.open("a") as f:
        f.write(json.dumps(rec) + "\n")
    status = "OK" if rec["ok"] else f"FAIL: {rec.get('error', '?')}"
    print(f"{args.arch} x {args.shape} x {args.mesh}: {status} "
          f"({rec['wall_s']}s)")
    if rec["ok"]:
        r = rec["roofline"]
        print(f"  mem/device: {rec['mem']['total_bytes']/2**30:.2f} GiB  "
              f"bottleneck: {r['bottleneck']}")
        print(f"  terms: compute {r['compute_term_s']*1e3:.2f}ms  "
              f"memory {r['memory_term_s']*1e3:.2f}ms  "
              f"collective {r['collective_term_s']*1e3:.2f}ms")


if __name__ == "__main__":
    main()
