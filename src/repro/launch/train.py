"""Training launcher.

CPU example (quickstart scale):
  PYTHONPATH=src python -m repro.launch.train --arch yi-6b --reduced \
      --steps 200 --batch 8 --seq-len 128

On a real TPU pod the same entry point shards with the production mesh
(--mesh pod|multipod) via the schema PartitionSpecs.
"""
from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="2-layer smoke-scale variant (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--mesh", choices=["none", "pod", "multipod"],
                    default="none")
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.train.loop import train

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    rules = None
    if args.mesh != "none":
        from repro.launch.mesh import make_production_mesh, make_rules
        mesh = make_production_mesh(multi_pod=args.mesh == "multipod")
        rules = make_rules(mesh)
    train(cfg, steps=args.steps, batch=args.batch, seq_len=args.seq_len,
          lr=args.lr, seed=args.seed, rules=rules, ckpt_dir=args.ckpt_dir)


if __name__ == "__main__":
    main()
