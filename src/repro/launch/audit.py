import os
os.environ.setdefault(
    "XLA_FLAGS",
    "--xla_force_host_platform_device_count=512 "
    "--xla_disable_hlo_passes=while-loop-invariant-code-motion,"
    "while-loop-expensive-invariant-code-motion")

"""Dry-run profiler: top HBM-traffic / collective / dot-FLOP contributors.

The hypothesis->change->measure loop's "profile" on a CPU-only container:
lower + compile one (arch x shape x mesh), run the trip-count-aware HLO
analysis, and print the heaviest ops with their while-loop multipliers.

Usage:
  PYTHONPATH=src python -m repro.launch.audit --arch qwen2-vl-72b \
      --shape decode_32k [--mesh pod] [--top 20] [--dump /tmp/x.hlo]
"""

import argparse
import math


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod"])
    ap.add_argument("--top", type=int, default=18)
    ap.add_argument("--dump", default=None)
    ap.add_argument("--harvest-inplace", action="store_true")
    ap.add_argument("--peer-fraction", type=float, default=0.0)
    args = ap.parse_args()

    import jax
    from repro.configs import INPUT_SHAPES, get_config
    from repro.launch import hlo_analysis as H
    from repro.launch.mesh import make_production_mesh, make_rules
    from repro.launch.specs import build_lowering

    cfg = get_config(args.arch)
    shape = INPUT_SHAPES[args.shape]
    mesh = make_production_mesh(multi_pod=args.mesh == "multipod")
    rules = make_rules(mesh)
    n_dev = math.prod(mesh.devices.shape)
    fn, fargs, shardings = build_lowering(
        cfg, shape, rules, harvest_inplace=args.harvest_inplace,
        peer_fraction=args.peer_fraction)
    donate = {"train": (0, 1), "decode": (1,), "prefill": ()}[shape.kind]
    with mesh:
        compiled = jax.jit(fn, in_shardings=shardings,
                           donate_argnums=donate).lower(*fargs).compile()
        hlo = compiled.as_text()
        ma = compiled.memory_analysis()
    if args.dump:
        open(args.dump, "w").write(hlo)

    comps = H.parse_computations(hlo)
    mult = H.comp_multipliers(comps)
    shapes = {}
    for c in comps.values():
        for op in c.ops:
            shapes[op.name] = op.type_str

    total = (ma.argument_size_in_bytes + ma.output_size_in_bytes
             + ma.temp_size_in_bytes - ma.alias_size_in_bytes)
    print(f"memory/device: {total / 2**30:.2f} GiB  "
          f"(arg {ma.argument_size_in_bytes / 2**30:.2f} + temp "
          f"{ma.temp_size_in_bytes / 2**30:.2f} + out "
          f"{ma.output_size_in_bytes / 2**30:.2f} - alias "
          f"{ma.alias_size_in_bytes / 2**30:.2f})")

    traffic, colls, dots = [], [], []
    for c in comps.values():
        m = mult.get(c.name, 0.0)
        if m <= 0:
            continue
        for op in c.ops:
            base = next((k for k in H.COLLECTIVE_OPS if op.opcode == k
                         or op.opcode == k + "-start"), None)
            if base is not None:
                nb = H._type_bytes(op.type_str)
                colls.append((m * nb, m, base, op.type_str[:70],
                              op.rest.split("metadata")[0][:40]))
            if op.opcode in ("dot", "convolution") and c.is_fusion is False:
                pass
            if c.is_fusion:
                continue
            if op.opcode in H._NO_TRAFFIC:
                continue
            t = H._op_traffic(op, comps, shapes)
            traffic.append((m * t, m, op.opcode, op.name[:34],
                            op.type_str[:64]))

    cost = H.analyze(hlo, default_group=n_dev)
    print(f"\ntotals: dot {cost.dot_flops / 1e12:.2f} TFLOP  "
          f"hbm {cost.hbm_bytes / 2**30:.1f} GiB  "
          f"coll {cost.collective_bytes / 2**30:.2f} GiB")
    print(f"collectives: " + "  ".join(
        f"{k}={v / 2**30:.2f}GiB/n={cost.collective_counts[k]:.0f}"
        for k, v in cost.collectives.items() if v))

    print(f"\ntop {args.top} HBM-traffic ops (bytes x trip-count):")
    for r in sorted(traffic, reverse=True)[:args.top]:
        print(f"  {r[0] / 2**30:8.2f}GiB x{r[1]:5.0f} {r[2]:22s} "
              f"{r[3]:34s} {r[4]}")
    print(f"\ntop {min(args.top, len(colls))} collectives:")
    for r in sorted(colls, reverse=True)[:args.top]:
        print(f"  {r[0] / 2**30:8.3f}GiB x{r[1]:5.0f} {r[2]:19s} {r[3]}")


if __name__ == "__main__":
    main()
