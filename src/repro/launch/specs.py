"""ShapeDtypeStruct input specs for every (architecture x input-shape) pair.

``build_lowering(cfg, shape, rules)`` returns (step_fn, abstract_args,
in_shardings) — everything ``jax.jit(...).lower()`` needs, with zero device
allocation (the shannon/kernels dry-run pattern).

Shape kinds:
  train    -> train_step (loss + grad + AdamW update)
  prefill  -> prefill    (full-sequence forward, returns last logits + KV)
  decode   -> serve_step (ONE token against the Harvest KV pools / states)
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig
from repro.launch.mesh import make_rules, total_shards
from repro.models import model as M
from repro.models import ssm as S
from repro.models import xlstm as X
from repro.models.sharding import ShardingRules, logical_to_spec
from repro.train.optim import adamw_init, train_step

KV_BLOCK_SIZE = 256
DECODE_HEADROOM_BLOCKS = 1


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def _shard(rules: Optional[ShardingRules], sds, *logical):
    if rules is None:
        return None
    return NamedSharding(rules.mesh,
                         logical_to_spec(rules, *logical, shape=sds.shape))


# ---------------------------------------------------------------------------
# Batch specs (train / prefill)
# ---------------------------------------------------------------------------


def abstract_batch(cfg: ModelConfig, shape: InputShape, with_labels: bool):
    b, s = shape.global_batch, shape.seq_len
    npre = cfg.modality.num_prefix_embeddings if cfg.modality else 0
    s_tok = s - npre
    ncb = cfg.modality.num_codebooks if cfg.modality else 1
    tok_shape = (b, s_tok, ncb) if (cfg.family == "audio" and ncb > 1) \
        else (b, s_tok)
    batch = {
        "tokens": _sds(tok_shape, jnp.int32),
        "positions": _sds((b, s), jnp.int32),
    }
    if npre:
        batch["prefix_embeddings"] = _sds((b, npre, cfg.d_model), jnp.bfloat16)
    if cfg.rope_style == "mrope":
        batch["positions_3d"] = _sds((b, s, 3), jnp.int32)
    if with_labels:
        batch["labels"] = _sds(tok_shape, jnp.int32)
    return batch


def batch_shardings(cfg, batch, rules: Optional[ShardingRules]):
    if rules is None:
        return None
    out = {}
    for k, v in batch.items():
        logical = ("act_batch",) + (None,) * (len(v.shape) - 1)
        out[k] = _shard(rules, v, *logical)
    return out


# ---------------------------------------------------------------------------
# Decode state specs
# ---------------------------------------------------------------------------


def blocks_per_request(cfg: ModelConfig, seq_len: int,
                       block_size: int = KV_BLOCK_SIZE) -> int:
    """KV working set in blocks: SWA/chunked attention bound it."""
    span = seq_len
    if cfg.sliding_window is not None:
        span = min(span, cfg.sliding_window)
    if cfg.attention_chunk is not None:
        span = min(span, cfg.attention_chunk)
    return math.ceil(span / block_size) + DECODE_HEADROOM_BLOCKS


def abstract_decode_state(cfg: ModelConfig, shape: InputShape,
                          rules: Optional[ShardingRules],
                          block_size: int = KV_BLOCK_SIZE,
                          peer_fraction: float = 0.0):
    b, seq = shape.global_batch, shape.seq_len
    nkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    L_kv = M.num_kv_layers(cfg)
    shards = total_shards(rules) if rules is not None else 1

    def pools(n_needed):
        n_slots = shards * math.ceil(n_needed / shards)
        return M.KVPools(
            pool_k=_sds((L_kv, n_slots, block_size, nkv, hd), jnp.bfloat16),
            pool_v=_sds((L_kv, n_slots, block_size, nkv, hd), jnp.bfloat16),
            slot_req=_sds((n_slots,), jnp.int32),
            slot_base=_sds((n_slots,), jnp.int32),
            append_slot=_sds((b,), jnp.int32),
            append_off=_sds((b,), jnp.int32),
        )

    kv = peer = None
    if L_kv:
        n_needed = b * blocks_per_request(cfg, seq, block_size)
        kv = pools(n_needed)
        if peer_fraction > 0:
            peer = pools(max(int(n_needed * peer_fraction), shards))

    states = None
    if cfg.family == "hybrid":
        states = jax.eval_shape(
            lambda: jax.tree.map(
                lambda t: jnp.broadcast_to(t, (cfg.num_layers,) + t.shape),
                S.init_ssm_state(cfg, b)))
    elif cfg.family == "ssm":
        per = cfg.xlstm.slstm_every
        n_super = cfg.num_layers // per
        states = jax.eval_shape(lambda: (
            jax.tree.map(lambda t: jnp.broadcast_to(
                t, (n_super, per - 1) + t.shape), X.init_mlstm_state(cfg, b)),
            jax.tree.map(lambda t: jnp.broadcast_to(
                t, (n_super,) + t.shape), X.init_slstm_state(cfg, b)),
        ))

    ncb = cfg.modality.num_codebooks if cfg.modality else 1
    tokens = _sds((b, ncb), jnp.int32) if (cfg.family == "audio" and ncb > 1) \
        else _sds((b,), jnp.int32)
    p3 = _sds((b, 3), jnp.int32) if cfg.rope_style == "mrope" else None
    return M.DecodeState(tokens=tokens, pos=_sds((b,), jnp.int32), kv=kv,
                         peer=peer, states=states, positions_3d=p3)


def decode_state_shardings(cfg, state: M.DecodeState,
                           rules: Optional[ShardingRules]):
    if rules is None:
        return None

    def pool_shardings(kv):
        if kv is None:
            return None
        return M.KVPools(
            pool_k=_shard(rules, kv.pool_k, None, "kv_blocks", None, None, None),
            pool_v=_shard(rules, kv.pool_v, None, "kv_blocks", None, None, None),
            slot_req=_shard(rules, kv.slot_req, "kv_blocks"),
            slot_base=_shard(rules, kv.slot_base, "kv_blocks"),
            append_slot=_shard(rules, kv.append_slot, None),
            append_off=_shard(rules, kv.append_off, None),
        )

    def state_shardings(states):
        if states is None:
            return None
        def leaf(s):
            # (stack dims..., b, heads-ish...) — shard batch where divisible
            logical = [None] * len(s.shape)
            for i, d in enumerate(s.shape):
                pass
            # find the batch dim: hybrid (L, b, ...), xlstm (ns, per, b, ...)
            return s
        # shard batch + heads dims by name convention
        if cfg.family == "hybrid":
            return S.SSMState(
                s=_shard(rules, states.s, None, "act_batch", "state_heads",
                         None, None),
                conv=_shard(rules, states.conv, None, "act_batch", None, None),
            )
        mst, sst = states
        msh = X.MLSTMState(
            c=_shard(rules, mst.c, None, None, "act_batch", "state_heads",
                     None, None),
            n=_shard(rules, mst.n, None, None, "act_batch", "state_heads", None),
            m=_shard(rules, mst.m, None, None, "act_batch", "state_heads"),
            conv=_shard(rules, mst.conv, None, None, "act_batch", None, None),
        )
        ssh = X.SLSTMState(
            c=_shard(rules, sst.c, None, "act_batch", "state_heads", None),
            n=_shard(rules, sst.n, None, "act_batch", "state_heads", None),
            m=_shard(rules, sst.m, None, "act_batch", "state_heads", None),
            h=_shard(rules, sst.h, None, "act_batch", "state_heads", None),
        )
        return (msh, ssh)

    rep = NamedSharding(rules.mesh, P())
    return M.DecodeState(
        tokens=rep, pos=rep,
        kv=pool_shardings(state.kv),
        peer=pool_shardings(state.peer),
        states=state_shardings(state.states),
        positions_3d=rep if state.positions_3d is not None else None,
    )


# ---------------------------------------------------------------------------
# Lowering builder
# ---------------------------------------------------------------------------


def build_lowering(cfg: ModelConfig, shape: InputShape,
                   rules: Optional[ShardingRules],
                   harvest_inplace: bool = False,
                   peer_fraction: float = 0.0):
    """Returns (fn, abstract_args, in_shardings)."""
    params = M.abstract_params(cfg)
    pspecs = M.param_specs(cfg, rules)
    psh = None if rules is None else jax.tree.map(
        lambda s: NamedSharding(rules.mesh, s), pspecs,
        is_leaf=lambda x: isinstance(x, P))

    if shape.kind == "train":
        batch = abstract_batch(cfg, shape, with_labels=True)
        opt = jax.eval_shape(adamw_init, params)
        osh = None if rules is None else jax.eval_shape(adamw_init, params)
        if rules is not None:
            rep = NamedSharding(rules.mesh, P())
            osh = type(opt)(step=rep,
                            mu=jax.tree.map(lambda s: NamedSharding(rules.mesh, s),
                                            pspecs,
                                            is_leaf=lambda x: isinstance(x, P)),
                            nu=jax.tree.map(lambda s: NamedSharding(rules.mesh, s),
                                            pspecs,
                                            is_leaf=lambda x: isinstance(x, P)))

        def fn(params, opt_state, batch):
            return train_step(params, opt_state, batch, cfg, rules)

        args = (params, opt, batch)
        shardings = None if rules is None else (
            psh, osh, batch_shardings(cfg, batch, rules))
        return fn, args, shardings

    if shape.kind == "prefill":
        batch = abstract_batch(cfg, shape, with_labels=False)

        def fn(params, batch):
            logits, out = M.prefill(params, batch, cfg, rules)
            return logits, out.kv, out.states

        args = (params, batch)
        shardings = None if rules is None else (
            psh, batch_shardings(cfg, batch, rules))
        return fn, args, shardings

    # decode — batch-REPLICATED over the data axis (§Perf iteration 2):
    # with batch sharded over "data", GSPMD must all-gather every 2D-sharded
    # weight each step (~weights x 15/16 over ICI, the dominant decode
    # collective).  One decode token is compute-trivial, so replicating the
    # batch lets GSPMD contract against the local weight shard and
    # all-reduce the (tiny) activations instead; weights stay 2D-sharded at
    # rest.  KV pools keep their (data, model) slot sharding.
    import dataclasses as _dc
    import os as _os
    baseline = _os.environ.get("HARVEST_DECODE_BASELINE") == "1"
    # batch replication pays only when per-request state is KV-paged (the
    # pools shard over kv_blocks regardless); SSM/hybrid recurrent state
    # scales with batch and must keep its act_batch sharding
    replicate_ok = cfg.family not in ("ssm", "hybrid")
    rules_d = rules if (rules is None or baseline or not replicate_ok)         else _dc.replace(rules, rules={**rules.rules, "act_batch": None})
    state = abstract_decode_state(cfg, shape, rules_d,
                                  peer_fraction=peer_fraction)

    def fn(params, state):
        return M.serve_step(params, state, cfg, rules_d,
                            harvest_inplace=harvest_inplace,
                            carried_pools=not baseline)

    args = (params, state)
    shardings = None if rules is None else (
        psh, decode_state_shardings(cfg, state, rules_d))
    return fn, args, shardings
