"""Unified decoder-only model covering all assigned families.

Entry points:
  forward(params, batch, cfg, rules)            -> (logits, aux)   train/score
  loss_fn(params, batch, cfg, rules)            -> (loss, metrics)
  prefill(params, batch, cfg, rules)            -> (logits_last, KVStack, states)
  serve_step(params, state, cfg, rules, ...)    -> (logits, new_state)  1 token

Layers are stacked and scanned (``lax.scan`` + remat) so the HLO stays
compact at 80 layers.  Decode attention runs against the tiered Harvest KV
pools (repro/core/paged_attention), with the pool slot dimension sharded
across the whole mesh (flash-decode partials + LSE merge).
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core import paged_attention as pa
from repro.models import layers as L
from repro.models import ssm as S
from repro.models import xlstm as X
from repro.models.moe import moe_layer
from repro.models.sharding import ShardingRules, shard, shard_map
from repro.models.params import (  # noqa: F401  (re-exported)
    abstract_params, build_schema, init_params, param_count, param_specs)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------


def embed(params, tokens, cfg: ModelConfig, rules=None):
    if cfg.family == "audio" and cfg.modality.num_codebooks > 1:
        # tokens: (b, s, ncb) — sum the codebook embeddings (MusicGen)
        ncb = cfg.modality.num_codebooks
        x = sum(jnp.take(params["embed"][c], tokens[..., c], axis=0)
                for c in range(ncb))
    else:
        x = jnp.take(params["embed"], tokens, axis=0)
    return shard(x, rules, "act_batch", "act_seq", "act_embed")


def unembed(params, x, cfg: ModelConfig, rules=None):
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.family == "audio" and cfg.modality.num_codebooks > 1:
        logits = jnp.einsum("bsd,cdv->bscv", x, params["lm_head"])
    elif cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    return shard(logits, rules, "act_batch", "act_seq", "vocab")


# ---------------------------------------------------------------------------
# Dense / MoE transformer blocks (full-sequence path)
# ---------------------------------------------------------------------------


def _attn_sublayer(x, lp, cfg, positions, rules, positions_3d):
    u = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
    a, kv = L.attention_layer(u, lp["attn"], cfg, positions, rules, positions_3d)
    return u, a, kv


def dense_block(x, lp, cfg: ModelConfig, positions, rules=None,
                positions_3d=None, is_moe=False):
    """Pre-LN block. Returns (x, kv, aux)."""
    aux = jnp.zeros((), jnp.float32)
    if cfg.parallel_block:
        u, a, kv = _attn_sublayer(x, lp, cfg, positions, rules, positions_3d)
        m = L.mlp(u, lp["mlp"], cfg, rules)
        x = x + a + m
        return x, kv, aux
    u, a, kv = _attn_sublayer(x, lp, cfg, positions, rules, positions_3d)
    x = x + a
    u2 = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
    if is_moe:
        y, aux = moe_layer(u2, lp["moe"], cfg, rules)
    else:
        y = L.mlp(u2, lp["mlp"], cfg, rules)
    x = x + y
    x = shard(x, rules, "act_batch", "act_seq", "act_embed")
    return x, kv, aux


# ---------------------------------------------------------------------------
# Full-sequence forward (train / prefill)
# ---------------------------------------------------------------------------


class ForwardOut(NamedTuple):
    hidden: jnp.ndarray          # (b, s, d)
    kv: Optional[Any]            # stacked (L_kv, b, s, nkv, hd) k and v
    states: Optional[Any]        # SSM / xLSTM final states
    aux: jnp.ndarray             # scalar aux loss (MoE load balance)


def _scan(body, x, stacks, length=None):
    return jax.lax.scan(jax.checkpoint(body), x, stacks, length=length)


def backbone(params, x, positions, cfg: ModelConfig, rules=None,
             positions_3d=None, want_kv: bool = True) -> ForwardOut:
    fam = cfg.family

    if fam in ("dense", "vlm", "audio") or (fam == "moe" and cfg.moe.layer_period == 1):
        is_moe = fam == "moe"

        def body(h, lp):
            h, kv, aux = dense_block(h, lp, cfg, positions, rules,
                                     positions_3d, is_moe=is_moe)
            return h, (kv if want_kv else None, aux)

        x, (kvs, auxs) = _scan(body, x, params["layers"])
        return ForwardOut(x, kvs, None, auxs.sum())

    if fam == "moe":  # interleaved dense/moe pairs (llama4)
        def body(h, lps):
            dlp, mlp_ = lps
            h, kv1, _ = dense_block(h, dlp, cfg, positions, rules,
                                    positions_3d, is_moe=False)
            h, kv2, aux = dense_block(h, mlp_, cfg, positions, rules,
                                      positions_3d, is_moe=True)
            kv = jax.tree.map(lambda a, b: jnp.stack([a, b]), kv1, kv2) \
                if want_kv else None
            return h, (kv, aux)

        x, (kvs, auxs) = _scan(body, x, (params["blocks"]["dense"],
                                         params["blocks"]["moe"]))
        # (n_pairs, 2, b, s, nkv, hd) -> (L, b, s, nkv, hd)
        if want_kv:
            kvs = jax.tree.map(lambda t: t.reshape((-1,) + t.shape[2:]), kvs)
        return ForwardOut(x, kvs, None, auxs.sum())

    if fam == "hybrid":
        return _hybrid_backbone(params, x, positions, cfg, rules,
                                want_kv=want_kv)

    if fam == "ssm":
        return _xlstm_backbone(params, x, cfg, rules)

    raise ValueError(fam)


def _hybrid_backbone(params, x, positions, cfg: ModelConfig, rules=None,
                     in_states=None, single_token=False, want_kv=True):
    """Zamba2: mamba2 stack with ONE shared attention block every N layers."""
    per = cfg.hybrid.attn_period
    n_super = cfg.num_layers // per
    n_tail = cfg.num_layers - n_super * per
    shared = params["shared_attn"]

    def mamba_one(h, lp, st):
        u = L.rms_norm(h, lp["ln1"], cfg.norm_eps)
        y, new_st = S.mamba2_layer(u, lp["mamba"], cfg, rules, st, single_token)
        return h + y, new_st

    def split_stack(tree, n_head, inner):
        head = jax.tree.map(lambda t: t[:n_head * inner].reshape(
            (n_head, inner) + t.shape[1:]), tree)
        tail = jax.tree.map(lambda t: t[n_head * inner:], tree)
        return head, tail

    head_params, tail_params = split_stack(params["mamba_layers"], n_super, per)
    if in_states is None:
        st0 = S.init_ssm_state(cfg, x.shape[0])
        states_head = jax.tree.map(
            lambda t: jnp.broadcast_to(t, (n_super, per) + t.shape), st0)
        states_tail = jax.tree.map(
            lambda t: jnp.broadcast_to(t, (n_tail,) + t.shape), st0)
    else:
        states_head, states_tail = split_stack(in_states, n_super, per)

    def super_body(h, inp):
        lps, sts = inp

        def inner(h2, inp2):
            lp, st = inp2
            h2, new_st = mamba_one(h2, lp, S.SSMState(*st))
            return h2, tuple(new_st)

        h, new_sts = jax.lax.scan(inner, h, (lps, tuple(sts)))
        u, a, kv = _attn_sublayer(h, shared, cfg, positions, rules, None)
        h = h + a
        h = h + L.mlp(L.rms_norm(h, shared["ln2"], cfg.norm_eps), shared["mlp"],
                      cfg, rules)
        return h, (new_sts, kv if want_kv else None)

    x, (new_head_states, kvs) = _scan(super_body, x,
                                      (head_params, tuple(states_head)))

    def tail_body(h, inp):
        lp, st = inp
        h, new_st = mamba_one(h, lp, S.SSMState(*st))
        return h, tuple(new_st)

    if n_tail:
        x, new_tail_states = jax.lax.scan(tail_body, x,
                                          (tail_params, tuple(states_tail)))
    else:
        new_tail_states = tuple(states_tail)

    states = jax.tree.map(
        lambda a, b: jnp.concatenate([a.reshape((-1,) + a.shape[2:]), b]),
        S.SSMState(*new_head_states), S.SSMState(*new_tail_states))
    return ForwardOut(x, kvs, states, jnp.zeros((), jnp.float32))


def _xlstm_backbone(params, x, cfg: ModelConfig, rules=None,
                    in_states=None, single_token=False):
    per = cfg.xlstm.slstm_every
    n_super = cfg.num_layers // per
    b = x.shape[0]

    if in_states is None:
        m0 = X.init_mlstm_state(cfg, b)
        s0 = X.init_slstm_state(cfg, b)
        m_states = jax.tree.map(
            lambda t: jnp.broadcast_to(t, (n_super, per - 1) + t.shape), m0)
        s_states = jax.tree.map(
            lambda t: jnp.broadcast_to(t, (n_super,) + t.shape), s0)
    else:
        m_states, s_states = in_states

    def super_body(h, inp):
        mlps, msts, slp, sst = inp

        def inner(h2, inp2):
            lp, st = inp2
            h2, new_st = X.mlstm_block(h2, lp, cfg, rules, X.MLSTMState(*st),
                                       single_token)
            return h2, tuple(new_st)

        h, new_msts = jax.lax.scan(inner, h, (mlps, tuple(msts)))
        h, new_sst = X.slstm_block(h, slp, cfg, rules, X.SLSTMState(*sst),
                                   single_token)
        return h, (new_msts, tuple(new_sst))

    x, (new_m, new_s) = _scan(
        super_body, x,
        (params["supers"]["mlstm"], tuple(m_states),
         params["supers"]["slstm"], tuple(s_states)))
    states = (X.MLSTMState(*new_m), X.SLSTMState(*new_s))
    return ForwardOut(x, None, states, jnp.zeros((), jnp.float32))


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------


def forward(params, batch, cfg: ModelConfig, rules=None, want_kv: bool = False):
    """batch: dict(tokens, positions[, prefix_embeddings, positions_3d])."""
    x = embed(params, batch["tokens"], cfg, rules)
    if cfg.modality is not None and cfg.modality.num_prefix_embeddings:
        # frontend stub: precomputed patch/frame/conditioning embeddings
        x = jnp.concatenate(
            [batch["prefix_embeddings"].astype(x.dtype), x], axis=1)
    positions = batch["positions"]
    if cfg.rope_style == "none" and cfg.family == "audio":
        x = x + L.sinusoidal_positions(positions, cfg.d_model).astype(x.dtype)[..., :x.shape[-1]]
    out = backbone(params, x, positions, cfg, rules,
                   batch.get("positions_3d"), want_kv=want_kv)
    logits = unembed(params, out.hidden, cfg, rules)
    return logits, out


def loss_fn(params, batch, cfg: ModelConfig, rules=None):
    logits, out = forward(params, batch, cfg, rules)
    labels = batch["labels"]
    npre = (cfg.modality.num_prefix_embeddings if cfg.modality else 0)
    if npre:
        logits = logits[:, npre:]
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    if labels.ndim != logits.ndim - 1:          # (b, s) or (b, s, ncb)
        raise ValueError("labels must be one rank below logits")
    # one-hot contraction instead of take_along_axis: a vocab-dim gather
    # forces GSPMD to all-gather the (b, s, V) logits; the one-hot product
    # reduces over the sharded vocab axis locally + psum.
    onehot = jax.nn.one_hot(jnp.maximum(labels, 0), logits.shape[-1],
                            dtype=logits.dtype)
    onehot = shard(onehot, rules,
                   "act_batch", *((None,) * (logits.ndim - 2)), "vocab")
    tgt = jnp.sum(logits.astype(jnp.float32) * onehot.astype(jnp.float32),
                  axis=-1)
    mask = (labels >= 0).astype(jnp.float32)
    nll = jnp.sum((lse - tgt) * mask) / jnp.maximum(mask.sum(), 1.0)
    aux = out.aux * (cfg.moe.lb_loss_weight if cfg.moe else 0.0)
    return nll + aux, {"nll": nll, "aux": out.aux}


def prefill(params, batch, cfg: ModelConfig, rules=None):
    """Full-sequence pass returning last-token logits + cache material."""
    logits, out = forward(params, batch, cfg, rules, want_kv=True)
    return logits[:, -1], out


# ---------------------------------------------------------------------------
# Decode (serve_step) — one token against the Harvest-tiered KV pools
# ---------------------------------------------------------------------------


class KVPools(NamedTuple):
    """Paged KV state shared across attention layers (slot dim shardable)."""
    pool_k: jnp.ndarray      # (L_kv, n_slots, bs, nkv, hd)
    pool_v: jnp.ndarray
    slot_req: jnp.ndarray    # (n_slots,) int32, -1 = free
    slot_base: jnp.ndarray   # (n_slots,) int32 first position of block
    append_slot: jnp.ndarray  # (b,) int32 global slot receiving this step's kv
    append_off: jnp.ndarray   # (b,) int32 offset within that slot


class DecodeState(NamedTuple):
    tokens: jnp.ndarray      # (b,) or (b, ncb) last emitted token(s)
    pos: jnp.ndarray         # (b,) int32 current position
    kv: Optional[KVPools]
    peer: Optional[KVPools]  # harvested peer tier (in-place mode)
    states: Optional[Any]    # SSM / xLSTM recurrent states
    positions_3d: Optional[jnp.ndarray] = None


def num_kv_layers(cfg: ModelConfig) -> int:
    if cfg.family == "ssm":
        return 0
    if cfg.family == "hybrid":
        return cfg.num_layers // cfg.hybrid.attn_period
    return cfg.num_layers


def _decode_attention_carried(q, pools_full, layer, state, k_new, v_new,
                              cfg, rules, peer_full=None):
    """One layer's paged attention against the CARRIED full pools.

    ``pools_full``: (L_kv, n_slots, bs, nkv, hd) k and v, loop-carried so the
    append is a 3-index scatter writing only the b updated rows — keeping
    pools as scan xs/ys instead rewrites every layer's full slice each step
    (2x the pool traffic; EXPERIMENTS.md §Perf iteration 3).
    """
    kvp = state.kv
    mesh_shape = dict(rules.mesh.shape) if rules is not None else {}

    def local_fn(q, pkf, pvf, lyr, sr, sb, k_new, v_new, a_slot, a_off,
                 ppkf=None, ppvf=None, psr=None, psb=None, axis_names=()):
        n_slots = pkf.shape[1]
        if axis_names:
            idx = 0
            for a in axis_names:
                idx = idx * mesh_shape[a] + jax.lax.axis_index(a)
            base = idx * n_slots
        else:
            base = 0
        ls = a_slot - base
        ls = jnp.where((ls >= 0) & (ls < n_slots), ls, n_slots)
        pkf = pkf.at[lyr, ls, a_off].set(k_new.astype(pkf.dtype), mode="drop")
        pvf = pvf.at[lyr, ls, a_off].set(v_new.astype(pvf.dtype), mode="drop")
        pk = jax.lax.dynamic_index_in_dim(pkf, lyr, 0, keepdims=False)
        pv = jax.lax.dynamic_index_in_dim(pvf, lyr, 0, keepdims=False)
        pools = [(pk, pv, sr, sb)]
        if ppkf is not None:
            ppk = jax.lax.dynamic_index_in_dim(ppkf, lyr, 0, keepdims=False)
            ppv = jax.lax.dynamic_index_in_dim(ppvf, lyr, 0, keepdims=False)
            pools.append((ppk, ppv, psr, psb))
        out = pa.paged_decode_attention(q, pools, state.pos, cfg, axis_names)
        return out.astype(q.dtype), pkf, pvf

    pkf, pvf = pools_full
    peer_args = ()
    if peer_full is not None:
        pp = state.peer
        peer_args = (peer_full[0], peer_full[1], pp.slot_req, pp.slot_base)

    if rules is None:
        return local_fn(q, pkf, pvf, layer, kvp.slot_req, kvp.slot_base,
                        k_new, v_new, kvp.append_slot, kvp.append_off,
                        *peer_args)

    axes = rules.rules.get("kv_blocks", ("data", "model"))
    if isinstance(axes, str):
        axes = (axes,)
    pool_spec = P(None, axes)
    slot_spec = P(axes)
    rep = P()
    in_specs = [rep, pool_spec, pool_spec, rep, slot_spec, slot_spec,
                rep, rep, rep, rep]
    if peer_args:
        in_specs += [pool_spec, pool_spec, slot_spec, slot_spec]
    fn = functools.partial(local_fn, axis_names=axes)
    return shard_map(
        fn, mesh=rules.mesh, in_specs=tuple(in_specs),
        out_specs=(rep, pool_spec, pool_spec), check_vma=False,
    )(q, pkf, pvf, layer, kvp.slot_req, kvp.slot_base, k_new, v_new,
      kvp.append_slot, kvp.append_off, *peer_args)


def _decode_attention(q, layer_pools, q_pos, cfg, rules, peer_layer_pools=None):
    """One layer's paged attention (+ KV append), mesh-aware."""
    b = q.shape[0]

    mesh_shape = dict(rules.mesh.shape) if rules is not None else {}

    def local_fn(q, pk, pv, sr, sb, k_new, v_new, a_slot, a_off,
                 ppk=None, ppv=None, psr=None, psb=None, axis_names=()):
        n_slots = pk.shape[0]
        if axis_names:
            idx = 0
            for a in axis_names:
                idx = idx * mesh_shape[a] + jax.lax.axis_index(a)
            base = idx * n_slots
        else:
            base = 0
        ls = a_slot - base
        ls = jnp.where((ls >= 0) & (ls < n_slots), ls, n_slots)
        pk, pv = pa.append_kv(pk, pv, k_new, v_new, ls, a_off)
        pools = [(pk, pv, sr, sb)]
        if ppk is not None:
            pools.append((ppk, ppv, psr, psb))
        out = pa.paged_decode_attention(q, pools, q_pos, cfg, axis_names)
        return out.astype(q.dtype), pk, pv

    pk, pv, sr, sb, k_new, v_new, a_slot, a_off = layer_pools
    peer_args = peer_layer_pools or ()

    if rules is None:
        return local_fn(q, pk, pv, sr, sb, k_new, v_new, a_slot, a_off,
                        *peer_args)

    axes = rules.rules.get("kv_blocks", ("data", "model"))
    if isinstance(axes, str):
        axes = (axes,)
    pool_spec = P(axes)
    rep = P()
    in_specs = [rep, pool_spec, pool_spec, pool_spec, pool_spec,
                rep, rep, rep, rep] + [pool_spec] * len(peer_args)
    fn = functools.partial(local_fn, axis_names=axes)
    return shard_map(
        fn, mesh=rules.mesh, in_specs=tuple(in_specs),
        out_specs=(rep, pool_spec, pool_spec), check_vma=False,
    )(q, pk, pv, sr, sb, k_new, v_new, a_slot, a_off, *peer_args)


def _decode_attn_sublayer_carried(x, lp, cfg, state: DecodeState, pools_full,
                                  layer, rules, peer_full=None):
    """x: (b, 1, d). Returns (attn_out (b,1,d), updated full pools)."""
    u = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
    q, k, v = L.attention_qkv(u, lp["attn"], cfg, rules)
    positions = state.pos[:, None]
    p3 = state.positions_3d[:, None] if state.positions_3d is not None else None
    q = L.position_embedding(q, positions, cfg, p3)
    k = L.position_embedding(k, positions, cfg, p3)
    o, new_pk, new_pv = _decode_attention_carried(
        q[:, 0], pools_full, layer, state, k[:, 0], v[:, 0], cfg, rules,
        peer_full)
    y = jnp.einsum("bnh,nhd->bd", o.astype(x.dtype), lp["attn"]["wo"])
    return y[:, None], (new_pk, new_pv)


def _decode_attn_sublayer(x, lp, cfg, state: DecodeState, layer_kv, rules,
                          peer_layer_kv=None):
    """x: (b, 1, d). Returns (attn_out (b,1,d), updated pool slices)."""
    u = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
    q, k, v = L.attention_qkv(u, lp["attn"], cfg, rules)
    positions = state.pos[:, None]
    p3 = state.positions_3d[:, None] if state.positions_3d is not None else None
    q = L.position_embedding(q, positions, cfg, p3)
    k = L.position_embedding(k, positions, cfg, p3)

    pk, pv = layer_kv
    kvp = state.kv
    pools = (pk, pv, kvp.slot_req, kvp.slot_base,
             k[:, 0], v[:, 0], kvp.append_slot, kvp.append_off)
    peer = None
    if peer_layer_kv is not None:
        pp = state.peer
        peer = (peer_layer_kv[0], peer_layer_kv[1], pp.slot_req, pp.slot_base)
    o, new_pk, new_pv = _decode_attention(q[:, 0], pools, state.pos, cfg,
                                          rules, peer)
    y = jnp.einsum("bnh,nhd->bd", o.astype(x.dtype), lp["attn"]["wo"])
    return y[:, None], (new_pk, new_pv)


def serve_step(params, state: DecodeState, cfg: ModelConfig, rules=None,
               harvest_inplace: bool = False, carried_pools: bool = True):
    """Decode ONE token for every active request. Returns (logits, state)."""
    tokens = state.tokens
    x = embed(params, tokens[:, None] if tokens.ndim == 1 else tokens[:, None, :],
              cfg, rules)
    if cfg.rope_style == "none" and cfg.family == "audio":
        x = x + L.sinusoidal_positions(state.pos[:, None], cfg.d_model
                                       ).astype(x.dtype)
    fam = cfg.family
    aux_ignored = jnp.zeros((), jnp.float32)
    new_kv = state.kv
    new_states = state.states

    use_peer = harvest_inplace and state.peer is not None
    peer_kv_stack = (state.peer.pool_k, state.peer.pool_v) if use_peer else None

    if fam in ("dense", "vlm", "audio", "moe"):
        interleaved = fam == "moe" and cfg.moe.layer_period == 2

        def one_layer(h, lp, layer_kv, peer_slice, is_moe):
            a, new_slice = _decode_attn_sublayer(h, lp, cfg, state, layer_kv,
                                                 rules, peer_slice)
            if cfg.parallel_block:
                # parallel block: attn and mlp both read ln1(x)
                u = L.rms_norm(h, lp["ln1"], cfg.norm_eps)
                h = h + a + L.mlp(u, lp["mlp"], cfg, rules)
                return h, new_slice
            h = h + a
            u2 = L.rms_norm(h, lp["ln2"], cfg.norm_eps)
            if is_moe:
                y, _ = moe_layer(u2, lp["moe"], cfg, rules)
                h = h + y
            else:
                h = h + L.mlp(u2, lp["mlp"], cfg, rules)
            return h, new_slice

        if not interleaved and not carried_pools:
            # §Perf baseline variant: pools as scan xs/ys (full per-layer
            # slice rewrite each step) — kept for before/after measurement
            def body(h, inp):
                lp, pk, pv, peer = inp
                h, new_slice = one_layer(h, lp, (pk, pv),
                                         peer if use_peer else None,
                                         fam == "moe")
                return h, new_slice

            xs = (params["layers"], state.kv.pool_k, state.kv.pool_v,
                  peer_kv_stack if use_peer else state.kv.pool_k)
            x, (pks, pvs) = jax.lax.scan(body, x, xs)
        elif not interleaved:
            # pools ride in the scan CARRY: the KV append is a 3-index
            # scatter into the full pool (writes only b rows/layer) instead
            # of a full per-layer slice rewrite through scan ys (§Perf it.3)
            def body(carry, lp):
                h, pkf, pvf, lyr = carry
                a, (pkf, pvf) = _decode_attn_sublayer_carried(
                    h, lp, cfg, state, (pkf, pvf), lyr, rules,
                    peer_kv_stack if use_peer else None)
                if cfg.parallel_block:
                    u = L.rms_norm(h, lp["ln1"], cfg.norm_eps)
                    h = h + a + L.mlp(u, lp["mlp"], cfg, rules)
                else:
                    h = h + a
                    u2 = L.rms_norm(h, lp["ln2"], cfg.norm_eps)
                    if fam == "moe":
                        y, _ = moe_layer(u2, lp["moe"], cfg, rules)
                        h = h + y
                    else:
                        h = h + L.mlp(u2, lp["mlp"], cfg, rules)
                return (h, pkf, pvf, lyr + 1), None

            (x, pks, pvs, _), _ = jax.lax.scan(
                body, (x, state.kv.pool_k, state.kv.pool_v,
                       jnp.zeros((), jnp.int32)), params["layers"])
        else:
            def body(h, inp):
                dlp, mlp_, pk, pv, peer = inp
                h, s1 = one_layer(h, dlp, (pk[0], pv[0]),
                                  (peer[0][0], peer[1][0]) if use_peer else None,
                                  False)
                h, s2 = one_layer(h, mlp_, (pk[1], pv[1]),
                                  (peer[0][1], peer[1][1]) if use_peer else None,
                                  True)
                return h, (jnp.stack([s1[0], s2[0]]), jnp.stack([s1[1], s2[1]]))

            nk = num_kv_layers(cfg)
            pk2 = state.kv.pool_k.reshape((nk // 2, 2) + state.kv.pool_k.shape[1:])
            pv2 = state.kv.pool_v.reshape((nk // 2, 2) + state.kv.pool_v.shape[1:])
            if use_peer:
                ppk2 = peer_kv_stack[0].reshape(pk2.shape[:2] + peer_kv_stack[0].shape[1:])
                ppv2 = peer_kv_stack[1].reshape(pv2.shape[:2] + peer_kv_stack[1].shape[1:])
                peer_xs = (ppk2, ppv2)
            else:
                peer_xs = (pk2, pv2)
            x, (pks, pvs) = jax.lax.scan(
                body, x, (params["blocks"]["dense"], params["blocks"]["moe"],
                          pk2, pv2, peer_xs))
            pks = pks.reshape((-1,) + pks.shape[2:])
            pvs = pvs.reshape((-1,) + pvs.shape[2:])
        new_kv = state.kv._replace(pool_k=pks, pool_v=pvs)

    elif fam == "hybrid":
        per = cfg.hybrid.attn_period
        n_super = cfg.num_layers // per
        n_tail = cfg.num_layers - n_super * per
        shared = params["shared_attn"]

        def split_stack(tree, n_head, inner):
            head = jax.tree.map(lambda t: t[:n_head * inner].reshape(
                (n_head, inner) + t.shape[1:]), tree)
            tail = jax.tree.map(lambda t: t[n_head * inner:], tree)
            return head, tail

        head_p, tail_p = split_stack(params["mamba_layers"], n_super, per)
        head_s, tail_s = split_stack(state.states, n_super, per)

        def mamba_one(h, lp, st):
            u = L.rms_norm(h, lp["ln1"], cfg.norm_eps)
            y, new_st = S.mamba2_layer(u, lp["mamba"], cfg, rules,
                                       S.SSMState(*st), single_token=True)
            return h + y, tuple(new_st)

        def super_body(h, inp):
            lps, sts, pk, pv, peer = inp

            def inner(h2, inp2):
                lp, st = inp2
                return mamba_one(h2, lp, st)

            h, new_sts = jax.lax.scan(inner, h, (lps, tuple(sts)))
            a, new_slice = _decode_attn_sublayer(
                h, shared, cfg, state, (pk, pv), rules,
                peer if use_peer else None)
            h = h + a
            h = h + L.mlp(L.rms_norm(h, shared["ln2"], cfg.norm_eps),
                          shared["mlp"], cfg, rules)
            return h, (new_sts, new_slice)

        xs = (head_p, tuple(head_s), state.kv.pool_k, state.kv.pool_v,
              peer_kv_stack if use_peer else (state.kv.pool_k, state.kv.pool_v))
        x, (new_head_s, (pks, pvs)) = jax.lax.scan(super_body, x, xs)

        def tail_body(h, inp):
            lp, st = inp
            return mamba_one(h, lp, st)

        if n_tail:
            x, new_tail_s = jax.lax.scan(tail_body, x, (tail_p, tuple(tail_s)))
        else:
            new_tail_s = tuple(tail_s)
        new_states = jax.tree.map(
            lambda a, b: jnp.concatenate([a.reshape((-1,) + a.shape[2:]), b]),
            S.SSMState(*new_head_s), S.SSMState(*new_tail_s))
        new_kv = state.kv._replace(pool_k=pks, pool_v=pvs)

    elif fam == "ssm":
        out = _xlstm_backbone(params, x, cfg, rules, state.states,
                              single_token=True)
        x, new_states = out.hidden, out.states

    logits = unembed(params, x, cfg, rules)[:, 0]
    new_tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    new_state = state._replace(
        tokens=new_tokens, pos=state.pos + 1, kv=new_kv, states=new_states)
    return logits, new_state
