"""xLSTM blocks [arXiv:2405.04517]: mLSTM (matrix memory, chunkwise-parallel)
and sLSTM (scalar memory, sequential scan with exponential gating).

mLSTM uses the stabilized chunkwise form (log-space gate accumulation with a
running max ``m``) so training does not store an O(seq) trail of
(hd x hd) matrix-memory carries — only chunk-boundary states.  The
single-step recurrence (`mlstm_step`) is the decode path and the oracle the
chunkwise form is tested against.

Recurrent state is Harvest's "lossy + reconstructible" durability class.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import layer_norm, rms_norm
from repro.models.sharding import shard, shard_map

LOG_EPS = -1e30


class MLSTMState(NamedTuple):
    c: jnp.ndarray   # (b, nh, hd, hd) f32 stabilized matrix memory
    n: jnp.ndarray   # (b, nh, hd) f32 normalizer
    m: jnp.ndarray   # (b, nh) f32 running log-max
    conv: jnp.ndarray  # (b, W-1, d_inner) conv tail


class SLSTMState(NamedTuple):
    c: jnp.ndarray   # (b, nh, hd)
    n: jnp.ndarray   # (b, nh, hd)
    m: jnp.ndarray   # (b, nh, hd)
    h: jnp.ndarray   # (b, nh, hd)


def xlstm_dims(cfg: ModelConfig):
    xc = cfg.xlstm
    d_inner = int(cfg.d_model * xc.proj_factor_mlstm)
    nh = cfg.num_heads
    hd = d_inner // nh
    return d_inner, nh, hd


# ---------------------------------------------------------------------------
# mLSTM cell
# ---------------------------------------------------------------------------


def mlstm_step(q, k, v, i_raw, f_raw, state: MLSTMState):
    """Single-token stabilized mLSTM recurrence (decode path + oracle).

    q,k,v: (b, nh, hd);  i_raw,f_raw: (b, nh).
    """
    f32 = jnp.float32
    q, k, v = q.astype(f32), k.astype(f32), v.astype(f32)
    hd = q.shape[-1]
    logf = jax.nn.log_sigmoid(f_raw.astype(f32))
    m_new = jnp.maximum(logf + state.m, i_raw.astype(f32))
    f_s = jnp.exp(logf + state.m - m_new)
    i_s = jnp.exp(i_raw.astype(f32) - m_new)
    k_sc = k / (hd ** 0.5)
    c_new = state.c * f_s[..., None, None] + i_s[..., None, None] * (
        k_sc[..., :, None] * v[..., None, :])
    n_new = state.n * f_s[..., None] + i_s[..., None] * k_sc
    num = jnp.einsum("bnh,bnhd->bnd", q, c_new)
    denom = jnp.maximum(jnp.abs(jnp.einsum("bnh,bnh->bn", q, n_new)),
                        jnp.exp(-m_new))
    h = num / denom[..., None]
    return h, MLSTMState(c_new, n_new, m_new, state.conv)


def mlstm_chunkwise(q, k, v, i_raw, f_raw, state: Optional[MLSTMState],
                    chunk: int = 256):
    """Chunkwise-parallel stabilized mLSTM.

    q,k,v: (b, s, nh, hd);  i_raw,f_raw: (b, s, nh).
    Returns (h: (b, s, nh, hd), final (c, n, m)).
    """
    f32 = jnp.float32
    b, s, nh, hd = q.shape
    chunk = min(chunk, s)
    nchunks = -(-s // chunk)
    pad = nchunks * chunk - s
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        i_raw = jnp.pad(i_raw, ((0, 0), (0, pad), (0, 0)), constant_values=LOG_EPS)
        f_raw = jnp.pad(f_raw, ((0, 0), (0, pad), (0, 0)), constant_values=30.0)

    def chunked(x):
        x = x.astype(f32)
        return x.reshape((b, nchunks, chunk) + x.shape[2:]).swapaxes(0, 1)

    qc, kc, vc = chunked(q), chunked(k), chunked(v / 1.0)
    ic, fc = chunked(i_raw), chunked(f_raw)
    kc = kc / (hd ** 0.5)
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))

    def body(carry, inp):
        C_prev, n_prev, m_prev = carry
        qq, kk, vv, ii, ff = inp                   # (b,q,nh,hd) / (b,q,nh)
        logf = jax.nn.log_sigmoid(ff)
        bcum = jnp.cumsum(logf, axis=1)            # (b,q,nh)
        btot = bcum[:, -1]                         # (b,nh)
        # intra log weights D[i,j] = bcum_i - bcum_j + ilog_j  (j <= i)
        D = bcum[:, :, None, :] - bcum[:, None, :, :] + ii[:, None, :, :]
        D = jnp.where(causal[None, :, :, None], D, LOG_EPS)
        # inter log weight g_i = bcum_i + m_prev
        g = bcum + m_prev[:, None, :]
        m_i = jnp.maximum(jnp.max(D, axis=2), g)   # (b,q,nh)
        S = jnp.einsum("binh,bjnh->bijn", qq, kk) * jnp.exp(D - m_i[:, :, None, :])
        num = jnp.einsum("bijn,bjnh->binh", S, vv)
        num = num + jnp.einsum("binh,bnhd->bind", qq, C_prev) * \
            jnp.exp(g - m_i)[..., None]
        nrm = jnp.sum(S, axis=2) + jnp.einsum("binh,bnh->bin", qq, n_prev) * \
            jnp.exp(g - m_i)
        h = num / jnp.maximum(jnp.abs(nrm), jnp.exp(-m_i))[..., None]
        # state update
        w = btot[:, None, :] - bcum + ii           # (b,q,nh) log weight per j
        m_new = jnp.maximum(btot + m_prev, jnp.max(w, axis=1))
        scale_prev = jnp.exp(btot + m_prev - m_new)
        wts = jnp.exp(w - m_new[:, None, :])
        C_new = C_prev * scale_prev[..., None, None] + jnp.einsum(
            "bjn,bjnh,bjnd->bnhd", wts, kk, vv)
        n_new = n_prev * scale_prev[..., None] + jnp.einsum("bjn,bjnh->bnh", wts, kk)
        return (C_new, n_new, m_new), h

    if state is None:
        c0 = jnp.zeros((b, nh, hd, hd), f32)
        n0 = jnp.zeros((b, nh, hd), f32)
        m0 = jnp.full((b, nh), LOG_EPS, f32)
    else:
        c0, n0, m0 = state.c, state.n, state.m
    (cf, nf, mf), hs = jax.lax.scan(
        jax.checkpoint(body), (c0, n0, m0), (qc, kc, vc, ic, fc))
    h = hs.swapaxes(0, 1).reshape(b, nchunks * chunk, nh, hd)[:, :s]
    return h, (cf, nf, mf)


def mlstm_block(x, p, cfg: ModelConfig, rules=None,
                state: Optional[MLSTMState] = None, single_token: bool = False
                ) -> Tuple[jnp.ndarray, MLSTMState]:
    """Full mLSTM block: LN -> up-proj -> conv -> qkv -> cell -> gate -> down."""
    d_inner, nh, hd = xlstm_dims(cfg)
    b, s, _ = x.shape
    u = rms_norm(x, p["ln"], cfg.norm_eps)
    up = jnp.einsum("bsd,dk->bsk", u, p["w_up"])   # (b, s, 2*d_inner)
    xm, z = jnp.split(up, 2, axis=-1)

    # causal depthwise conv feeding q/k
    W = p["conv_w"].shape[0]
    tail = state.conv if state is not None else jnp.zeros((b, W - 1, d_inner), x.dtype)
    xp = jnp.concatenate([tail, xm], axis=1)
    conv = sum(xp[:, i:i + s] * p["conv_w"][i] for i in range(W))
    conv = jax.nn.silu(conv + p["conv_b"])
    new_tail = xp[:, xp.shape[1] - (W - 1):]

    def heads(t, w):  # per-head block-diagonal projection
        return jnp.einsum("bsnh,nhk->bsnk", t.reshape(b, s, nh, hd), w)

    q = heads(conv, p["wq"])
    k = heads(conv, p["wk"])
    v = heads(xm, p["wv"])
    q = shard(q, rules, "act_batch", "act_seq", "state_heads", None)
    gates = jnp.einsum("bsk,kg->bsg", xm, p["w_gates"]) + p["b_gates"]
    i_raw, f_raw = jnp.split(gates.reshape(b, s, nh, 2), 2, axis=-1)
    i_raw, f_raw = i_raw[..., 0], f_raw[..., 0]

    if single_token:
        st = state if state is not None else init_mlstm_state(cfg, b)
        h, new_state = mlstm_step(q[:, 0], k[:, 0], v[:, 0],
                                  i_raw[:, 0], f_raw[:, 0], st)
        h = h[:, None]
        new_state = MLSTMState(new_state.c, new_state.n, new_state.m, new_tail)
    else:
        h, (cf, nf, mf) = mlstm_chunkwise(q, k, v, i_raw, f_raw, state)
        new_state = MLSTMState(cf, nf, mf, new_tail)

    h = rms_norm(h.astype(x.dtype), p["out_norm"], cfg.norm_eps)
    h = h.reshape(b, s, d_inner) * jax.nn.silu(z)
    y = jnp.einsum("bsk,kd->bsd", h, p["w_down"])
    return x + y, new_state


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def slstm_block(x, p, cfg: ModelConfig, rules=None,
                state: Optional[SLSTMState] = None, single_token: bool = False
                ) -> Tuple[jnp.ndarray, SLSTMState]:
    """sLSTM block: LN -> sequential exp-gated scalar cell -> GN -> GEGLU MLP."""
    nh = cfg.num_heads
    d = cfg.d_model
    hd = d // nh
    b, s, _ = x.shape
    f32 = jnp.float32

    u = rms_norm(x, p["ln"], cfg.norm_eps)
    # input contributions for 4 gates (z, i, f, o): (b, s, nh, 4, hd)
    gx = jnp.einsum("bsd,dngk->bsngk", u, p["w_in"]) + p["b_in"]

    if state is None:
        state = init_slstm_state(cfg, b)

    def cell(carry, g_t):
        c, n, m, h_prev = carry
        # recurrent contribution (block-diagonal per head)
        gr = jnp.einsum("bnh,nhgk->bngk", h_prev, p["w_rec"])
        g = g_t.astype(f32) + gr
        z_t = jnp.tanh(g[:, :, 0])
        i_t = g[:, :, 1]
        f_t = g[:, :, 2]
        o_t = jax.nn.sigmoid(g[:, :, 3])
        logf = jax.nn.log_sigmoid(f_t)
        m_new = jnp.maximum(logf + m, i_t)
        i_s = jnp.exp(i_t - m_new)
        f_s = jnp.exp(logf + m - m_new)
        c_new = f_s * c + i_s * z_t
        n_new = f_s * n + i_s
        h_new = o_t * c_new / jnp.maximum(n_new, 1e-6)
        return (c_new, n_new, m_new, h_new), h_new

    if single_token:
        (c, n, m, h), _ = cell((state.c, state.n, state.m, state.h), gx[:, 0])
        hs = h[:, None]
        new_state = SLSTMState(c, n, m, h)
    else:
        def scan_cells(gx_l, c0, n0, m0, h0, w_rec):
            def cell_l(carry, g_t):
                c, n, m, h_prev = carry
                gr = jnp.einsum("bnh,nhgk->bngk", h_prev, w_rec)
                g = g_t.astype(f32) + gr
                z_t = jnp.tanh(g[:, :, 0])
                i_t, f_t = g[:, :, 1], g[:, :, 2]
                o_t = jax.nn.sigmoid(g[:, :, 3])
                logf = jax.nn.log_sigmoid(f_t)
                m_new = jnp.maximum(logf + m, i_t)
                i_s = jnp.exp(i_t - m_new)
                f_s = jnp.exp(logf + m - m_new)
                c_new = f_s * c + i_s * z_t
                n_new = f_s * n + i_s
                h_new = o_t * c_new / jnp.maximum(n_new, 1e-6)
                return (c_new, n_new, m_new, h_new), h_new

            (c, n, m, h), hs = jax.lax.scan(
                cell_l, (c0, n0, m0, h0), gx_l.swapaxes(0, 1))
            return hs.swapaxes(0, 1), c, n, m, h

        dax = rules.axis("act_batch") if rules is not None else None
        if dax is not None and b % rules.axis_size(dax) == 0:
            # manual shard_map over the batch axis: the cell recurrence is
            # tiny and fully batch-parallel, but under plain GSPMD the
            # transpose of the scan psums the replicated w_rec GRADIENT
            # every token step (384 GiB/step measured at seq 4096 — §Perf
            # iteration 6); inside a manual region AD accumulates the grad
            # locally and reduces ONCE at exit.
            from jax.sharding import PartitionSpec as P
            daxes = (dax,) if isinstance(dax, str) else tuple(dax)
            bspec = P(daxes)
            hs, c, n, m, h = shard_map(
                scan_cells, mesh=rules.mesh,
                in_specs=(bspec, bspec, bspec, bspec, bspec, P()),
                out_specs=(bspec,) * 5, check_vma=False,
            )(gx, state.c, state.n, state.m, state.h,
              p["w_rec"].astype(f32))
        else:
            hs, c, n, m, h = scan_cells(gx, state.c, state.n, state.m,
                                        state.h, p["w_rec"].astype(f32))
        new_state = SLSTMState(c, n, m, h)

    hs = rms_norm(hs.astype(x.dtype), p["gn"], cfg.norm_eps)
    y = x + jnp.einsum("bsnh,nhd->bsd", hs, p["w_out"])

    # post GEGLU MLP (proj factor 4/3)
    u2 = rms_norm(y, p["ln2"], cfg.norm_eps)
    hh = jnp.einsum("bsd,df->bsf", u2, p["mlp_wi"])
    gg = jnp.einsum("bsd,df->bsf", u2, p["mlp_wg"])
    y = y + jnp.einsum("bsf,fd->bsd", jax.nn.gelu(gg) * hh, p["mlp_wo"])
    return y, new_state


def init_mlstm_state(cfg: ModelConfig, batch: int) -> MLSTMState:
    d_inner, nh, hd = xlstm_dims(cfg)
    return MLSTMState(
        c=jnp.zeros((batch, nh, hd, hd), jnp.float32),
        n=jnp.zeros((batch, nh, hd), jnp.float32),
        m=jnp.full((batch, nh), LOG_EPS, jnp.float32),
        conv=jnp.zeros((batch, cfg.xlstm.conv_width - 1, d_inner), jnp.bfloat16),
    )


def init_slstm_state(cfg: ModelConfig, batch: int) -> SLSTMState:
    nh = cfg.num_heads
    hd = cfg.d_model // nh
    z = jnp.zeros((batch, nh, hd), jnp.float32)
    return SLSTMState(c=z, n=z, m=jnp.full((batch, nh, hd), LOG_EPS, jnp.float32),
                      h=z)
