"""Logical-axis sharding rules (MaxText-style).

Model code annotates tensors with *logical* axis names; a :class:`ShardingRules`
maps those to physical mesh axes.  With ``rules=None`` every annotation is a
no-op, so the same model code runs on a single CPU device (smoke tests) and on
the 512-chip production mesh (dry-run) unchanged.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Tuple, Union

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

# jax.shard_map was promoted out of jax.experimental in newer releases
# (renaming check_rep -> check_vma along the way); resolve whichever this
# jax ships so model code has one spelling, the new one.
if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:                                    # pragma: no cover - version compat
    from jax.experimental.shard_map import shard_map as _experimental_shard_map

    def shard_map(f, /, **kwargs):
        if "check_vma" in kwargs:
            kwargs["check_rep"] = kwargs.pop("check_vma")
        return _experimental_shard_map(f, **kwargs)

Axis = Union[None, str, Tuple[str, ...]]


# Default logical -> physical mapping for the (data, model) production mesh.
# "fsdp" style: weight embed dims shard over the data axis.
DEFAULT_RULES = {
    # activations
    "act_batch": "data",
    "act_seq": None,
    "act_embed": "model",   # residual-stream tensor sharding (remat residuals)
    "act_heads": "model",
    "act_ff": "model",
    # weights
    "embed_fsdp": "data",      # d_model dim of weight matrices
    "heads": "model",          # attention head output dims
    "kv_heads": "model",       # only used when num_kv_heads % axis_size == 0
    "ff": "model",             # dense FFN hidden dim
    "experts": "model",        # MoE expert dim
    "expert_capacity": "data",  # dispatch-buffer capacity dim (see moe.py)
    "expert_ff": None,
    "vocab": "model",
    # kv-cache / recurrent state
    "kv_blocks": ("data", "model"),   # paged KV pool block dim (flash-decode)
    "kv_seq": "model",                # prefill KV stack sequence dim
    "state_heads": "model",           # SSM / xLSTM recurrent state heads
    # layer-stacking dim is never sharded
    "layers": None,
}


@dataclass(frozen=True)
class ShardingRules:
    mesh: jax.sharding.Mesh
    rules: dict = field(default_factory=lambda: dict(DEFAULT_RULES))

    def axis(self, logical: Optional[str]) -> Axis:
        if logical is None:
            return None
        return self.rules.get(logical)

    def spec(self, *logical_axes: Optional[str]) -> P:
        """Build a PartitionSpec, dropping mappings that don't divide evenly.

        Divisibility is the caller's job for weights (schema checks it); this
        just translates names.
        """
        return P(*[self.axis(a) for a in logical_axes])

    def sharding(self, *logical_axes: Optional[str]) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(*logical_axes))

    def axis_size(self, mesh_axis: Axis) -> int:
        if mesh_axis is None:
            return 1
        if isinstance(mesh_axis, str):
            mesh_axis = (mesh_axis,)
        n = 1
        for a in mesh_axis:
            n *= self.mesh.shape[a]
        return n


def logical_to_spec(rules: Optional[ShardingRules], *logical_axes, shape=None) -> P:
    """Translate logical axes to a PartitionSpec, dropping any mapping that
    does not divide the corresponding dimension of ``shape`` evenly."""
    if rules is None:
        return P()
    axes = [rules.axis(a) for a in logical_axes]
    if shape is not None:
        for i, ax in enumerate(axes):
            if ax is None:
                continue
            if shape[i] % rules.axis_size(ax) != 0:
                axes[i] = None
    return P(*axes)


def shard(x, rules: Optional[ShardingRules], *logical_axes):
    """Apply a sharding constraint by logical axis names (no-op if rules None)."""
    if rules is None:
        return x
    spec = logical_to_spec(rules, *logical_axes, shape=x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(rules.mesh, spec))
