"""Mixture-of-Experts layer with capacity-based sort dispatch.

Dispatch is the argsort/segment scheme (Megablocks-style dense capacity
buffers, no (t, E, C) one-hot): tokens are sorted by expert id, ranked within
their expert segment, and scattered into an (E, C, d) dispatch buffer.  The
expert dimension shards over the ``model`` mesh axis (expert parallelism);
expert weight d_model dims shard over ``data`` (FSDP).  GSPMD inserts the
all-to-all / all-gather collectives.

The Harvest Expert Rebalancer (repro/core/rebalancer.py) manages *which* copy
of each expert's weights is fed here (local HBM / harvested peer HBM / host
DRAM) — the math below is placement-agnostic, which is exactly the paper's
"no model code changes" property.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.layers import _activation, mlp
from repro.models.sharding import shard, shard_map


def router_topk(logits, top_k: int):
    """Top-k routing with softmax-renormalised gate weights.

    logits: (t, E) float32. Returns (weights (t,k), ids (t,k), probs (t,E)).
    """
    probs = jax.nn.softmax(logits, axis=-1)
    weights, ids = jax.lax.top_k(probs, top_k)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    return weights, ids, probs


def load_balance_loss(probs, ids, num_experts: int) -> jnp.ndarray:
    """Switch-style auxiliary loss: E * sum_e f_e * P_e."""
    t = probs.shape[0]
    counts = jnp.zeros((num_experts,), jnp.float32).at[ids.reshape(-1)].add(1.0)
    frac_tokens = counts / jnp.maximum(ids.size, 1)
    mean_probs = probs.mean(axis=0)
    return num_experts * jnp.sum(frac_tokens * mean_probs)


def build_dispatch(ids, weights, num_experts: int, capacity: int):
    """Build capacity-buffer dispatch indices from top-k assignments.

    ids/weights: (t, k).  Returns
      slot_token: (E*C,) int32 — token index feeding each expert slot (t = empty)
      slot_weight: (E*C,) f32 — combine weight for that slot
    Tokens over capacity are dropped (standard capacity-factor semantics).
    """
    t, k = ids.shape
    flat_ids = ids.reshape(-1)                     # (t*k,)
    flat_w = weights.reshape(-1)
    token_of = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)

    order = jnp.argsort(flat_ids, stable=True)     # group by expert
    sorted_ids = flat_ids[order]
    # rank within expert segment
    seg_start = jnp.searchsorted(sorted_ids, jnp.arange(num_experts), side="left")
    rank = jnp.arange(t * k, dtype=jnp.int32) - seg_start[sorted_ids].astype(jnp.int32)

    slot = sorted_ids.astype(jnp.int32) * capacity + rank
    slot = jnp.where(rank < capacity, slot, num_experts * capacity)  # drop OOB

    slot_token = jnp.full((num_experts * capacity,), t, jnp.int32)
    slot_token = slot_token.at[slot].set(token_of[order], mode="drop")
    slot_weight = jnp.zeros((num_experts * capacity,), jnp.float32)
    slot_weight = slot_weight.at[slot].set(flat_w[order], mode="drop")
    return slot_token, slot_weight


def expert_ffn(xd, p, cfg: ModelConfig, rules=None):
    """Apply each expert's FFN to its dispatch buffer.

    xd: (E, C, d);  p["wi"]/p["wg"]: (E, d, ffe);  p["wo"]: (E, ffe, d).
    """
    act = _activation(cfg.activation)
    h = jnp.einsum("ecd,edf->ecf", xd, p["wi"])
    g = jnp.einsum("ecd,edf->ecf", xd, p["wg"])
    h = act(g) * h
    h = shard(h, rules, "experts", None, None)
    return jnp.einsum("ecf,efd->ecd", h, p["wo"])


def moe_layer(x, p, cfg: ModelConfig, rules=None,
              capacity_factor: Optional[float] = None
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """MoE FFN sublayer. x: (b, s, d) -> (y, aux_loss).

    With a batch-sharded mesh the dispatch/combine runs LOCALLY per data
    shard (shard_map over the batch axis, expert axis left to GSPMD):
    a global (E, C, d) buffer built from batch-sharded tokens forces either
    full replication of the expert compute across the data axis or an
    all-reduce of the combined (t, d) output — both measured catastrophic
    (EXPERIMENTS.md §Perf iterations 4-5).  Locally, each data shard routes
    its own t/16 tokens into per-shard capacity buffers; the only cross-
    shard traffic left is the per-layer FSDP weight gather and the combine
    psum over the expert (model) axis.
    """
    mc = cfg.moe
    if capacity_factor is None:
        capacity_factor = mc.capacity_factor
    ax = rules.axis("act_batch") if rules is not None else None
    eax = rules.axis("experts") if rules is not None else None
    if (ax is not None and eax is not None
            and x.shape[0] % rules.axis_size(ax) == 0
            and mc.num_experts % rules.axis_size(eax) == 0):
        return _moe_layer_local(x, p, cfg, rules, capacity_factor)
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)

    logits = jnp.einsum("td,de->te", xt, p["router"]).astype(jnp.float32)
    if mc.router_jitter:
        logits = logits  # jitter only in training loops that thread an rng
    weights, ids, probs = router_topk(logits, mc.top_k)
    aux = load_balance_loss(probs, ids, mc.num_experts)

    capacity = max(int(t * mc.top_k / mc.num_experts * capacity_factor), 4)
    slot_token, slot_weight = build_dispatch(ids, weights, mc.num_experts, capacity)

    # gather tokens into (E, C, d); empty slots read token index t -> fill 0.
    # (This path serves CPU/smoke runs, indivisible meshes and the
    # batch-replicated decode shardings; batch-sharded training uses
    # _moe_layer_local.  An "expert_capacity"@data constraint here was
    # measured to REGRESS decode — §Perf iteration 4 — and is superseded.)
    xd = jnp.take(xt, slot_token, axis=0, mode="fill", fill_value=0)
    xd = xd.reshape(mc.num_experts, capacity, d)
    xd = shard(xd, rules, "experts", None, None)

    out = expert_ffn(xd, p, cfg, rules)            # (E, C, d)
    out = out.reshape(mc.num_experts * capacity, d)

    y = jnp.zeros((t + 1, d), x.dtype)             # row t = drop bucket
    y = y.at[slot_token].add(out * slot_weight[:, None].astype(x.dtype))
    y = y[:t]

    if mc.num_shared_experts:
        y = y + mlp(xt[None], p["shared"], cfg, rules)[0]

    y = y.reshape(b, s, d)
    y = shard(y, rules, "act_batch", "act_seq", "act_embed")
    return y, aux


def _moe_core(xt, p, cfg: ModelConfig, rules, capacity_factor: float):
    """Router + dispatch + expert FFN + combine over a flat token batch."""
    mc = cfg.moe
    t, d = xt.shape
    logits = jnp.einsum("td,de->te", xt, p["router"]).astype(jnp.float32)
    weights, ids, probs = router_topk(logits, mc.top_k)
    aux = load_balance_loss(probs, ids, mc.num_experts)

    capacity = max(int(t * mc.top_k / mc.num_experts * capacity_factor), 4)
    slot_token, slot_weight = build_dispatch(ids, weights, mc.num_experts,
                                             capacity)
    xd = jnp.take(xt, slot_token, axis=0, mode="fill", fill_value=0)
    xd = xd.reshape(mc.num_experts, capacity, d)
    xd = shard(xd, rules, "experts", None, None)

    out = expert_ffn(xd, p, cfg, rules)            # (E, C, d)
    out = out.reshape(mc.num_experts * capacity, d)
    y = jnp.zeros((t + 1, d), xt.dtype)            # row t = drop bucket
    y = y.at[slot_token].add(out * slot_weight[:, None].astype(xt.dtype))
    return y[:t], aux


def _moe_layer_local(x, p, cfg: ModelConfig, rules,
                     capacity_factor: float):
    """Fully-manual expert parallelism (shard_map over BOTH mesh axes).

    Per (data i, model j) device: route the local t/|data| tokens with the
    (replicated) router, keep the E/|model| experts owned by j, gather the
    FSDP-sharded expert weights over the data axis, run the FFN, and psum
    the combined output over the model axis.  Explicit collectives per
    layer: weight all-gather (~weights/|model| bytes) + combine psum
    (~2 x local activations) — versus the global-dispatch path whose
    (E, C, d) buffer is replicated over data (16x redundant FLOPs) or
    all-reduced whole (§Perf iterations 4-5).
    """
    mc = cfg.moe
    b, s_len, d = x.shape
    dax = rules.axis("act_batch")
    eax = rules.axis("experts")
    dsize, esize = rules.axis_size(dax), rules.axis_size(eax)
    if mc.num_experts % esize:
        raise ValueError(f"{mc.num_experts} experts not divisible by "
                         f"expert axis {esize}")
    e_loc = mc.num_experts // esize
    b_loc = b // dsize
    t_loc = b_loc * s_len

    def local(xl, router, wi, wg, wo):
        # gather FSDP (data-axis) weight shards; experts stay local to j
        router = jax.lax.all_gather(router, dax, axis=0, tiled=True)
        wi = jax.lax.all_gather(wi, dax, axis=1, tiled=True)
        wg = jax.lax.all_gather(wg, dax, axis=1, tiled=True)
        wo = jax.lax.all_gather(wo, dax, axis=2, tiled=True)

        xt = xl.reshape(t_loc, d)
        logits = jnp.einsum("td,de->te", xt, router).astype(jnp.float32)
        weights, ids, probs = router_topk(logits, mc.top_k)
        aux = load_balance_loss(probs, ids, mc.num_experts)

        capacity = max(int(t_loc * mc.top_k / mc.num_experts
                           * capacity_factor), 4)
        slot_token, slot_weight = build_dispatch(ids, weights,
                                                 mc.num_experts, capacity)
        e0 = jax.lax.axis_index(eax) * (e_loc * capacity)
        own_tok = jax.lax.dynamic_slice_in_dim(slot_token, e0,
                                               e_loc * capacity)
        own_w = jax.lax.dynamic_slice_in_dim(slot_weight, e0,
                                             e_loc * capacity)

        xd = jnp.take(xt, own_tok, axis=0, mode="fill", fill_value=0)
        xd = xd.reshape(e_loc, capacity, d)
        act = _activation(cfg.activation)
        h = jnp.einsum("ecd,edf->ecf", xd, wi)
        g = jnp.einsum("ecd,edf->ecf", xd, wg)
        out = jnp.einsum("ecf,efd->ecd", act(g) * h, wo)
        out = out.reshape(e_loc * capacity, d)

        y = jnp.zeros((t_loc + 1, d), xt.dtype)    # row t_loc = drop bucket
        y = y.at[own_tok].add(out * own_w[:, None].astype(xt.dtype))
        y = jax.lax.psum(y, eax)                   # combine across experts
        return y[:t_loc].reshape(b_loc, s_len, d), aux[None] / dsize

    daxes = (dax,) if isinstance(dax, str) else tuple(dax)
    eaxes = (eax,) if isinstance(eax, str) else tuple(eax)
    y, aux = shard_map(
        local, mesh=rules.mesh,
        in_specs=(P(daxes), P(daxes), P(eaxes, daxes), P(eaxes, daxes),
                  P(eaxes, None, daxes)),
        out_specs=(P(daxes), P(daxes)),
        check_vma=False,
    )(x, p["router"], p["wi"], p["wg"], p["wo"])
    y = shard(y, rules, "act_batch", "act_seq", "act_embed")
    if mc.num_shared_experts:
        y = y + mlp(x, p["shared"], cfg, rules)
    return y, aux.sum()
