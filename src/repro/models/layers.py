"""Core transformer layers: norms, rotary embeddings (RoPE / M-RoPE),
memory-bounded (chunked, online-softmax) attention, and MLP variants.

All functions are pure; per-layer parameters arrive as dicts of arrays.
Attention here is the *training / prefill* path (full sequence); single-token
decode against the paged Harvest KV pool lives in ``repro/core/paged_attention``
and ``repro/kernels/paged_attention``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.sharding import shard

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm(x, scale, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * scale.astype(jnp.float32)).astype(dtype)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    return y.astype(dtype)


# ---------------------------------------------------------------------------
# Rotary embeddings
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., seq, heads, head_dim); positions: (..., seq) int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                          # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    angles = angles[..., None, :]                          # broadcast over heads
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions_3d, theta: float, sections):
    """Qwen2-VL multimodal RoPE.

    positions_3d: (..., seq, 3) — (t, h, w) position ids. ``sections`` gives
    how many of the head_dim/2 frequency slots each of t/h/w owns
    (sum(sections) == head_dim // 2).
    """
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                          # (hd/2,)
    # section id per frequency slot: 0->t, 1->h, 2->w
    sec = jnp.concatenate([
        jnp.full((sections[0],), 0), jnp.full((sections[1],), 1),
        jnp.full((sections[2],), 2),
    ])
    pos = jnp.take_along_axis(
        positions_3d.astype(jnp.float32),                  # (..., seq, 3)
        jnp.broadcast_to(sec, positions_3d.shape[:-1] + (hd // 2,)).astype(jnp.int32),
        axis=-1,
    )                                                      # (..., seq, hd/2)
    angles = (pos * freqs)[..., None, :]
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def position_embedding(x, positions, cfg: ModelConfig, positions_3d=None):
    if cfg.rope_style == "rope":
        return apply_rope(x, positions, cfg.rope_theta)
    if cfg.rope_style == "mrope":
        sections = cfg.modality.mrope_sections
        if positions_3d is None:  # text-only: all three sections share pos
            positions_3d = jnp.broadcast_to(positions[..., None], positions.shape + (3,))
        return apply_mrope(x, positions_3d, cfg.rope_theta, sections)
    return x  # "none": musicgen/xlstm use non-rotary positions


def sinusoidal_positions(positions, d_model: int):
    """MusicGen-style sinusoidal embedding added to the input stream."""
    half = d_model // 2
    freqs = jnp.exp(-jnp.log(10_000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# Chunked (online-softmax) attention — memory-bounded pure-jnp path.
# The Pallas flash kernel (repro/kernels/flash_attention) is the TPU hot path;
# this implementation is its oracle and the dry-run lowering path.
# ---------------------------------------------------------------------------


def _band_mask(q_pos, k_pos, cfg: ModelConfig):
    """(q, k) boolean mask combining causal + sliding-window + chunked-local."""
    m = k_pos[None, :] <= q_pos[:, None]                   # causal
    if cfg.sliding_window is not None:
        m &= k_pos[None, :] > (q_pos[:, None] - cfg.sliding_window)
    if cfg.attention_chunk is not None:                    # llama4 chunked local
        m &= (k_pos[None, :] // cfg.attention_chunk) == (q_pos[:, None] // cfg.attention_chunk)
    return m


def _attn_layout(rules, nq, sq):
    """Pick how attention intermediates shard over the tensor axis.

    Preferred: shard the q-head dim ("heads" mode, nq divisible by the axis).
    Fallback: shard the q-sequence dim ("seq" mode — sequence parallelism;
    each chip owns a slice of q rows, no cross-chip softmax reduction).
    """
    if rules is None:
        return None, None
    ax = rules.axis("act_heads")
    size = rules.axis_size(ax)
    if ax is None or size == 1:
        return None, None
    if nq % size == 0:
        return "heads", ax
    if sq % size == 0:
        return "seq", ax
    return None, None


def chunked_attention(q, k, v, q_positions, k_positions, cfg: ModelConfig,
                      kv_chunk: int = 1024, logit_softcap=None, rules=None):
    """Causal GQA attention with online softmax over KV chunks.

    q: (b, sq, nq, hd);  k, v: (b, sk, nkv, hd)
    q_positions: (b, sq);  k_positions: (b, sk)
    Returns (b, sq, nq, hd).

    Sharding: the (b, sq, nq, C) score tensor must shard over the tensor
    axis or it dominates memory.  When nq divides the axis we expand KV to
    q heads (a (nq)->(nkv,gq) reshape of head-sharded q cannot propagate
    through GSPMD) and shard heads; otherwise (llama4's 40 heads on a
    16-way axis) we shard the q-*sequence* dim instead — each chip owns a
    slice of q rows end-to-end, so no collective enters the softmax.
    Operands stay bf16 with f32 MXU accumulation (preferred_element_type);
    an f32 expanded-KV stack would otherwise be hoisted out of the scan.
    """
    b, sq, nq, hd = q.shape
    sk, nkv = k.shape[1], k.shape[2]
    gq = nq // nkv
    scale = hd ** -0.5
    mode, ax = _attn_layout(rules, nq, sq)
    bax = rules.axis("act_batch") if rules is not None else None

    def cst(x, spec):
        if rules is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.NamedSharding(rules.mesh, spec))

    kv_chunk = min(kv_chunk, sk)
    n_chunks = -(-sk // kv_chunk)
    pad = n_chunks * kv_chunk - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_positions = jnp.pad(k_positions, ((0, 0), (0, pad)), constant_values=-(10 ** 9))

    kc = k.reshape(b, n_chunks, kv_chunk, nkv, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, kv_chunk, nkv, hd).transpose(1, 0, 2, 3, 4)
    pc = k_positions.reshape(b, n_chunks, kv_chunk).transpose(1, 0, 2)
    expand_kv = mode == "heads"
    if not expand_kv:
        # grouped-GQA einsum path; shard q rows over the tensor axis
        q = q.reshape(b, sq, nkv, gq, hd)

    if mode == "heads":      # q (b,sq,nq,hd); m/l (b,sq,nq)
        qspec, mspec = P(bax, None, ax, None), P(bax, None, ax)
        accspec = P(bax, None, ax, None)
    elif mode == "seq":      # q (b,sq,nkv,gq,hd); m/l (b,sq,nkv,gq)
        qspec, mspec = P(bax, ax), P(bax, ax)
        accspec = P(bax, ax)
    else:
        qspec = mspec = accspec = P(bax)
    qf = cst(q * jnp.asarray(scale, q.dtype), qspec)

    def body(carry, chunk):
        m_prev, l_prev, acc_prev = carry
        kj, vj, posj = chunk                        # (b, C, nkv, hd), (b, C)
        mask = jax.vmap(lambda qp, kp: _band_mask(qp, kp, cfg))(q_positions, posj)
        if expand_kv:
            kj = cst(jnp.repeat(kj, gq, axis=2), P(bax, None, ax, None))
            vj = cst(jnp.repeat(vj, gq, axis=2), P(bax, None, ax, None))
            s = jnp.einsum("bqnh,bcnh->bqnc", qf, kj,
                           preferred_element_type=jnp.float32)
            mask = mask[:, :, None, :]              # (b, sq, 1, C)
        else:
            s = jnp.einsum("bqKgh,bcKh->bqKgc", qf, kj,
                           preferred_element_type=jnp.float32)
            mask = mask[:, :, None, None, :]        # (b, sq, 1, 1, C)
        if logit_softcap:
            s = logit_softcap * jnp.tanh(s / logit_softcap)
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=-1)
        if expand_kv:
            upd = jnp.einsum("bqnc,bcnh->bqnh", p.astype(vj.dtype), vj,
                             preferred_element_type=jnp.float32)
        else:
            upd = jnp.einsum("bqKgc,bcKh->bqKgh", p.astype(vj.dtype), vj,
                             preferred_element_type=jnp.float32)
        acc_new = acc_prev * corr[..., None] + upd
        return (m_new, l_new, acc_new), None

    heads_shape = (nq,) if expand_kv else (nkv, gq)
    m0 = cst(jnp.full((b, sq) + heads_shape, NEG_INF, jnp.float32), mspec)
    l0 = cst(jnp.zeros((b, sq) + heads_shape, jnp.float32), mspec)
    a0 = cst(jnp.zeros((b, sq) + heads_shape + (hd,), jnp.float32), accspec)
    (m, l, acc), _ = jax.lax.scan(jax.checkpoint(body), (m0, l0, a0),
                                  (kc, vc, pc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, sq, nq, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# Attention layer (projections + rotary + chunked attention)
# ---------------------------------------------------------------------------


def attention_qkv(x, p, cfg: ModelConfig, rules=None):
    """Project x -> (q, k, v) with GQA head layout and optional QK-norm."""
    hd = cfg.resolved_head_dim
    q = jnp.einsum("bsd,dnh->bsnh", x, p["wq"])
    k = jnp.einsum("bsd,dnh->bsnh", x, p["wk"])
    v = jnp.einsum("bsd,dnh->bsnh", x, p["wv"])
    if cfg.attn_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = shard(q, rules, "act_batch", "act_seq", "act_heads", None)
    k = shard(k, rules, "act_batch", "act_seq", None, None)
    v = shard(v, rules, "act_batch", "act_seq", None, None)
    return q, k, v


def attention_layer(x, p, cfg: ModelConfig, positions, rules=None,
                    positions_3d=None):
    """Full-sequence attention sublayer (train / prefill). Returns (y, (k, v))."""
    q, k, v = attention_qkv(x, p, cfg, rules)
    q = position_embedding(q, positions, cfg, positions_3d)
    k = position_embedding(k, positions, cfg, positions_3d)
    o = chunked_attention(q, k, v, positions, positions, cfg,
                          logit_softcap=cfg.logit_softcap, rules=rules)
    o = shard(o, rules, "act_batch", "act_seq", "act_heads", None)
    y = jnp.einsum("bsnh,nhd->bsd", o, p["wo"])
    # emitted KV (prefill cache material) shards its seq dim over "model" —
    # kv_heads are usually < the model axis, so seq is the shardable dim
    k = shard(k, rules, "act_batch", "kv_seq", None, None)
    v = shard(v, rules, "act_batch", "kv_seq", None, None)
    return y, (k, v)


# ---------------------------------------------------------------------------
# MLP variants
# ---------------------------------------------------------------------------


def _activation(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return jax.nn.gelu
    if name == "relu2":  # nemotron squared relu
        return lambda u: jnp.square(jax.nn.relu(u))
    raise ValueError(name)


def mlp(x, p, cfg: ModelConfig, rules=None):
    act = _activation(cfg.activation)
    h = jnp.einsum("bsd,df->bsf", x, p["wi"])
    if cfg.mlp_bias:
        h = h + p["bi"]
    if cfg.gated_mlp:
        g = jnp.einsum("bsd,df->bsf", x, p["wg"])
        h = act(g) * h
    else:
        h = act(h)
    h = shard(h, rules, "act_batch", "act_seq", "act_ff")
    y = jnp.einsum("bsf,fd->bsd", h, p["wo"])
    if cfg.mlp_bias:
        y = y + p["bo"]
    return y
