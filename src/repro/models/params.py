"""Parameter schema: one declaration drives init, abstract shapes (dry-run)
and PartitionSpecs (GSPMD in_shardings).

Every parameter is a :class:`ParamDef` with a shape, logical sharding axes
(translated by ``ShardingRules``) and an init spec.  ``init_params`` /
``abstract_params`` / ``param_specs`` all walk the same schema, so shapes and
shardings cannot drift apart.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.sharding import ShardingRules, logical_to_spec
from repro.models.ssm import ssm_dims
from repro.models.xlstm import xlstm_dims


@dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]      # logical sharding axes, len == ndim
    init: str = "normal"                 # normal | zeros | ones | const:<v>
    dtype: str = "bfloat16"

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _stack(defs: dict, n: int, extra: int = 0) -> dict:
    """Prefix every ParamDef with stacked leading dim(s) (the scan axis)."""
    out = {}
    lead = (n,) if not extra else (n, extra)
    lead_axes = ("layers",) * len(lead)
    for k, v in defs.items():
        if isinstance(v, dict):
            out[k] = _stack(v, n, extra)
        else:
            out[k] = ParamDef(lead + v.shape, lead_axes + v.axes, v.init, v.dtype)
    return out


# ---------------------------------------------------------------------------
# Per-sublayer schemas
# ---------------------------------------------------------------------------


def attention_schema(cfg: ModelConfig) -> dict:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    nq, nkv = cfg.num_heads, cfg.num_kv_heads
    s = {
        "wq": ParamDef((d, nq, hd), ("embed_fsdp", "heads", None)),
        "wk": ParamDef((d, nkv, hd), ("embed_fsdp", "kv_heads", None)),
        "wv": ParamDef((d, nkv, hd), ("embed_fsdp", "kv_heads", None)),
        "wo": ParamDef((nq, hd, d), ("heads", None, "embed_fsdp")),
    }
    if cfg.attn_bias:
        s["bq"] = ParamDef((nq, hd), ("heads", None), "zeros")
        s["bk"] = ParamDef((nkv, hd), ("kv_heads", None), "zeros")
        s["bv"] = ParamDef((nkv, hd), ("kv_heads", None), "zeros")
    if cfg.qk_norm:
        s["q_norm"] = ParamDef((hd,), (None,), "ones")
        s["k_norm"] = ParamDef((hd,), (None,), "ones")
    return s


def mlp_schema(cfg: ModelConfig, d_ff: Optional[int] = None) -> dict:
    d = cfg.d_model
    f = d_ff if d_ff is not None else cfg.d_ff
    s = {
        "wi": ParamDef((d, f), ("embed_fsdp", "ff")),
        "wo": ParamDef((f, d), ("ff", "embed_fsdp")),
    }
    if cfg.gated_mlp:
        s["wg"] = ParamDef((d, f), ("embed_fsdp", "ff"))
    return s


def moe_schema(cfg: ModelConfig) -> dict:
    mc = cfg.moe
    d = cfg.d_model
    s = {
        "router": ParamDef((d, mc.num_experts), ("embed_fsdp", None), dtype="float32"),
        "wi": ParamDef((mc.num_experts, d, mc.d_ff_expert),
                       ("experts", "embed_fsdp", None)),
        "wg": ParamDef((mc.num_experts, d, mc.d_ff_expert),
                       ("experts", "embed_fsdp", None)),
        "wo": ParamDef((mc.num_experts, mc.d_ff_expert, d),
                       ("experts", None, "embed_fsdp")),
    }
    if mc.num_shared_experts:
        s["shared"] = mlp_schema(cfg, mc.d_ff_shared * mc.num_shared_experts)
    return s


def mamba_schema(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    sc = cfg.ssm
    d_inner, nh, conv_dim = ssm_dims(cfg)
    in_dim = d_inner + conv_dim + nh               # z, xBC, dt
    return {
        "in_proj": ParamDef((d, in_dim), ("embed_fsdp", "ff")),
        "conv_w": ParamDef((sc.conv_width, conv_dim), (None, "ff")),
        "conv_b": ParamDef((conv_dim,), ("ff",), "zeros"),
        "dt_bias": ParamDef((nh,), (None,), "const:-2.0", "float32"),
        "A_log": ParamDef((nh,), (None,), "const:0.5", "float32"),
        "D": ParamDef((nh,), (None,), "ones", "float32"),
        "norm": ParamDef((d_inner,), ("ff",), "ones"),
        "out_proj": ParamDef((d_inner, d), ("ff", "embed_fsdp")),
    }


def mlstm_schema(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    d_inner, nh, hd = xlstm_dims(cfg)
    W = cfg.xlstm.conv_width
    return {
        "ln": ParamDef((d,), (None,), "ones"),
        "w_up": ParamDef((d, 2 * d_inner), ("embed_fsdp", "ff")),
        "conv_w": ParamDef((W, d_inner), (None, "ff")),
        "conv_b": ParamDef((d_inner,), ("ff",), "zeros"),
        "wq": ParamDef((nh, hd, hd), ("state_heads", None, None)),
        "wk": ParamDef((nh, hd, hd), ("state_heads", None, None)),
        "wv": ParamDef((nh, hd, hd), ("state_heads", None, None)),
        "w_gates": ParamDef((d_inner, 2 * nh), ("ff", None)),
        "b_gates": ParamDef((2 * nh,), (None,), "const:3.0"),
        "out_norm": ParamDef((hd,), (None,), "ones"),
        "w_down": ParamDef((d_inner, d), ("ff", "embed_fsdp")),
    }


def slstm_schema(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    nh = cfg.num_heads
    hd = d // nh
    f2 = int(d * cfg.xlstm.proj_factor_slstm)
    return {
        "ln": ParamDef((d,), (None,), "ones"),
        "w_in": ParamDef((d, nh, 4, hd), ("embed_fsdp", "state_heads", None, None)),
        "b_in": ParamDef((nh, 4, hd), ("state_heads", None, None), "zeros"),
        "w_rec": ParamDef((nh, hd, 4, hd), ("state_heads", None, None, None)),
        "gn": ParamDef((hd,), (None,), "ones"),
        "w_out": ParamDef((nh, hd, d), ("state_heads", None, "embed_fsdp")),
        "ln2": ParamDef((d,), (None,), "ones"),
        "mlp_wi": ParamDef((d, f2), ("embed_fsdp", "ff")),
        "mlp_wg": ParamDef((d, f2), ("embed_fsdp", "ff")),
        "mlp_wo": ParamDef((f2, d), ("ff", "embed_fsdp")),
    }


def _norms(cfg: ModelConfig, parallel: bool) -> dict:
    d = cfg.d_model
    s = {"ln1": ParamDef((d,), (None,), "ones")}
    if not parallel:
        s["ln2"] = ParamDef((d,), (None,), "ones")
    return s


# ---------------------------------------------------------------------------
# Whole-model schema
# ---------------------------------------------------------------------------


def build_schema(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    schema: dict = {}

    # embeddings
    if cfg.family == "audio" and cfg.modality.num_codebooks > 1:
        ncb = cfg.modality.num_codebooks
        schema["embed"] = ParamDef((ncb, cfg.vocab_size, d),
                                   (None, "vocab", "embed_fsdp"))
        schema["lm_head"] = ParamDef((ncb, d, cfg.vocab_size),
                                     (None, "embed_fsdp", "vocab"))
    else:
        schema["embed"] = ParamDef((cfg.vocab_size, d), ("vocab", "embed_fsdp"))
        if not cfg.tie_embeddings:
            schema["lm_head"] = ParamDef((d, cfg.vocab_size),
                                         ("embed_fsdp", "vocab"))
    schema["final_norm"] = ParamDef((d,), (None,), "ones")

    fam = cfg.family
    if fam in ("dense", "vlm", "audio") or (fam == "moe" and cfg.moe.layer_period == 1):
        layer = dict(_norms(cfg, cfg.parallel_block))
        layer["attn"] = attention_schema(cfg)
        if fam == "moe":
            layer["moe"] = moe_schema(cfg)
        else:
            layer["mlp"] = mlp_schema(cfg)
        schema["layers"] = _stack(layer, cfg.num_layers)
    elif fam == "moe":  # interleaved (llama4): scan over (dense, moe) pairs
        period = cfg.moe.layer_period
        assert period == 2 and cfg.num_layers % 2 == 0
        dense_layer = dict(_norms(cfg, False))
        dense_layer["attn"] = attention_schema(cfg)
        dense_layer["mlp"] = mlp_schema(cfg)
        moe_layer = dict(_norms(cfg, False))
        moe_layer["attn"] = attention_schema(cfg)
        moe_layer["moe"] = moe_schema(cfg)
        schema["blocks"] = {
            "dense": _stack(dense_layer, cfg.num_layers // 2),
            "moe": _stack(moe_layer, cfg.num_layers // 2),
        }
    elif fam == "hybrid":
        mamba_layer = {"ln1": ParamDef((d,), (None,), "ones"),
                       "mamba": mamba_schema(cfg)}
        schema["mamba_layers"] = _stack(mamba_layer, cfg.num_layers)
        # zamba2 signature: ONE shared attention+MLP block, reused periodically
        schema["shared_attn"] = dict(_norms(cfg, False))
        schema["shared_attn"]["attn"] = attention_schema(cfg)
        schema["shared_attn"]["mlp"] = mlp_schema(cfg)
    elif fam == "ssm":  # xlstm: super-blocks of (slstm_every-1 mLSTM + 1 sLSTM)
        per = cfg.xlstm.slstm_every
        assert cfg.num_layers % per == 0
        n_super = cfg.num_layers // per
        schema["supers"] = {
            "mlstm": _stack(mlstm_schema(cfg), n_super, per - 1),
            "slstm": _stack(slstm_schema(cfg), n_super),
        }
    else:
        raise ValueError(fam)
    return schema


# ---------------------------------------------------------------------------
# Schema walkers
# ---------------------------------------------------------------------------


def _walk(schema, fn, path=()):
    if isinstance(schema, ParamDef):
        return fn(path, schema)
    return {k: _walk(v, fn, path + (k,)) for k, v in schema.items()}


def init_params(rng, cfg: ModelConfig):
    """Materialise parameters (smoke/reduced configs only)."""
    schema = build_schema(cfg)
    counter = [0]

    def make(path, pd: ParamDef):
        counter[0] += 1
        key = jax.random.fold_in(rng, counter[0])
        dtype = jnp.dtype(pd.dtype)
        if pd.init == "zeros":
            return jnp.zeros(pd.shape, dtype)
        if pd.init == "ones":
            return jnp.ones(pd.shape, dtype)
        if pd.init.startswith("const:"):
            return jnp.full(pd.shape, float(pd.init.split(":")[1]), dtype)
        fan_in = pd.shape[-2] if len(pd.shape) >= 2 else pd.shape[-1]
        scale = min(0.02, 1.0 / math.sqrt(max(fan_in, 1)))
        return (jax.random.normal(key, pd.shape, jnp.float32) * scale).astype(dtype)

    return _walk(schema, make)


def abstract_params(cfg: ModelConfig):
    """ShapeDtypeStruct tree — the dry-run's allocation-free stand-in."""
    return _walk(build_schema(cfg),
                 lambda p, pd: jax.ShapeDtypeStruct(pd.shape, jnp.dtype(pd.dtype)))


def param_specs(cfg: ModelConfig, rules: Optional[ShardingRules]):
    """PartitionSpec tree (divisibility-checked against each shape)."""
    return _walk(build_schema(cfg),
                 lambda p, pd: logical_to_spec(rules, *pd.axes, shape=pd.shape))


def param_count(params) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
