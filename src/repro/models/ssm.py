"""Mamba2 (SSD) selective state-space layer.

Prefill/train uses the chunked SSD algorithm (intra-chunk quadratic +
inter-chunk state recurrence); decode uses the O(1) single-token recurrence.
The recurrent state is the "reconstructible transient state" case of the
Harvest durability model: it may live in the lossy peer tier and be rebuilt
by re-running prefill if revoked.

Shapes follow the Mamba2 paper with ngroups=1:
  d_inner = expand * d_model,  nheads = d_inner // head_dim
  state S: (b, nheads, head_dim, state_dim)
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import rms_norm
from repro.models.sharding import shard


class SSMState(NamedTuple):
    s: jnp.ndarray        # (b, nh, hd, N) fp32 — SSM state
    conv: jnp.ndarray     # (b, W-1, conv_dim) — causal-conv tail


def ssm_dims(cfg: ModelConfig):
    sc = cfg.ssm
    d_inner = sc.expand * cfg.d_model
    nheads = d_inner // sc.head_dim
    conv_dim = d_inner + 2 * sc.state_dim          # x, B, C go through the conv
    return d_inner, nheads, conv_dim


def _causal_conv(u, w, b, tail=None):
    """Depthwise causal conv1d.  u: (b, s, c);  w: (W, c);  tail: (b, W-1, c)."""
    W = w.shape[0]
    if tail is None:
        tail = jnp.zeros(u.shape[:1] + (W - 1,) + u.shape[2:], u.dtype)
    up = jnp.concatenate([tail, u], axis=1)
    out = sum(up[:, i:i + u.shape[1]] * w[i] for i in range(W))
    new_tail = up[:, up.shape[1] - (W - 1):]
    return jax.nn.silu(out + b), new_tail


def _ssd_chunked(xh, dt, A, B, C, chunk: int, s0=None):
    """Chunked SSD scan.

    xh: (b, s, nh, hd)   dt: (b, s, nh)   A: (nh,)  B, C: (b, s, N)
    Returns (y: (b, s, nh, hd), final state (b, nh, hd, N)).
    """
    b, s, nh, hd = xh.shape
    N = B.shape[-1]
    nchunks = -(-s // chunk)
    pad = nchunks * chunk - s
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))

    f32 = jnp.float32
    # chunk-major layout for lax.scan: (c, b, q, ...)
    xc = xh.reshape(b, nchunks, chunk, nh, hd).astype(f32).transpose(1, 0, 2, 3, 4)
    dtc = dt.reshape(b, nchunks, chunk, nh).astype(f32).transpose(1, 0, 2, 3)
    Bc = B.reshape(b, nchunks, chunk, N).astype(f32).transpose(1, 0, 2, 3)
    Cc = C.reshape(b, nchunks, chunk, N).astype(f32).transpose(1, 0, 2, 3)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))

    def scan_body(S_prev, inp):
        xq, dtq, Bq, Cq = inp                      # (b,q,nh,hd) (b,q,nh) (b,q,N)
        dA = dtq * A[None, None, :]
        cum = jnp.cumsum(dA, axis=1)               # (b,q,nh) log-decay
        # intra-chunk: L[i,j] = exp(cum_i - cum_j), j <= i
        diff = cum[:, :, None, :] - cum[:, None, :, :]          # (b,i,j,nh)
        L = jnp.where(mask[None, :, :, None], jnp.exp(diff), 0.0)
        CB = jnp.einsum("bin,bjn->bij", Cq, Bq)
        y_intra = jnp.einsum("bijh,bij,bjh,bjhd->bihd", L, CB, dtq, xq)
        # inter-chunk contribution from carried state
        y_inter = jnp.einsum("bin,bih,bhdn->bihd", Cq, jnp.exp(cum), S_prev)
        # state update
        decay_to_end = jnp.exp(cum[:, -1:, :] - cum)            # (b,q,nh)
        S_local = jnp.einsum("bjh,bjh,bjn,bjhd->bhdn",
                             decay_to_end, dtq, Bq, xq)
        S_new = S_prev * jnp.exp(cum[:, -1, :])[..., None, None] + S_local
        return S_new, y_intra + y_inter

    if s0 is None:
        s0 = jnp.zeros((b, nh, hd, N), f32)
    S_final, yc = jax.lax.scan(scan_body, s0, (xc, dtc, Bc, Cc))
    y = yc.transpose(1, 0, 2, 3, 4).reshape(b, nchunks * chunk, nh, hd)
    return y[:, :s], S_final


def mamba2_layer(x, p, cfg: ModelConfig, rules=None,
                 state: Optional[SSMState] = None, single_token: bool = False
                 ) -> Tuple[jnp.ndarray, SSMState]:
    """Mamba2 sublayer.  x: (b, s, d).  Returns (y, new_state)."""
    sc = cfg.ssm
    d_inner, nheads, conv_dim = ssm_dims(cfg)
    b, s, d = x.shape

    zxbcdt = jnp.einsum("bsd,dk->bsk", x, p["in_proj"])
    z, xbc, dt = jnp.split(zxbcdt, [d_inner, d_inner + conv_dim], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))

    conv_tail = state.conv if state is not None else None
    xbc, new_tail = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_tail)
    xs, B, C = jnp.split(xbc, [d_inner, d_inner + sc.state_dim], axis=-1)
    xh = xs.reshape(b, s, nheads, sc.head_dim)
    xh = shard(xh, rules, "act_batch", "act_seq", "state_heads", None)

    A = -jnp.exp(p["A_log"].astype(jnp.float32))               # (nh,)
    s_prev = state.s if state is not None else None

    if single_token:
        # O(1) recurrence: S = S * exp(dt A) + dt B x ; y = C S
        f32 = jnp.float32
        dt1 = dt[:, 0].astype(f32)                              # (b, nh)
        dA = jnp.exp(dt1 * A[None, :])
        if s_prev is None:
            s_prev = jnp.zeros((b, nheads, sc.head_dim, sc.state_dim), f32)
        Bx = jnp.einsum("bh,bn,bhd->bhdn", dt1, B[:, 0].astype(f32),
                        xh[:, 0].astype(f32))
        S = s_prev * dA[..., None, None] + Bx
        y = jnp.einsum("bn,bhdn->bhd", C[:, 0].astype(f32), S)[:, None]
    else:
        y, S = _ssd_chunked(xh, dt, A, B.astype(jnp.float32), C.astype(jnp.float32),
                            sc.chunk_size, s_prev)

    y = y + xh.astype(jnp.float32) * p["D"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(b, s, d_inner).astype(x.dtype)
    # gated RMSNorm (mamba2): norm(y * silu(z))
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = jnp.einsum("bsk,kd->bsd", y, p["out_proj"])
    return out, SSMState(s=S, conv=new_tail)


def init_ssm_state(cfg: ModelConfig, batch: int) -> SSMState:
    sc = cfg.ssm
    d_inner, nheads, conv_dim = ssm_dims(cfg)
    return SSMState(
        s=jnp.zeros((batch, nheads, sc.head_dim, sc.state_dim), jnp.float32),
        conv=jnp.zeros((batch, sc.conv_width - 1, conv_dim), jnp.bfloat16),
    )
