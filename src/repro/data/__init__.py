from repro.data.pipeline import ByteTokenizer, SyntheticCorpus, make_batches
