"""Data pipeline: byte-level tokenizer + synthetic corpus + batch iterator.

The synthetic corpus is a mixture of (a) Zipf-sampled "vocabulary" text with
Markov structure (so a ~100M model trains to a visibly dropping loss) and
(b) repeated shared prefixes — the prefix-reuse pattern §6.2 of the paper
identifies as Harvest's best case for KV caching.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, List, Optional

import numpy as np


class ByteTokenizer:
    """UTF-8 byte tokenizer with a small reserved-special region."""

    PAD, BOS, EOS = 0, 1, 2
    OFFSET = 3

    @property
    def vocab_size(self) -> int:
        return 256 + self.OFFSET

    def encode(self, text: str, bos: bool = True) -> List[int]:
        ids = [b + self.OFFSET for b in text.encode("utf-8")]
        return ([self.BOS] if bos else []) + ids

    def decode(self, ids) -> str:
        bs = bytes(max(0, int(i) - self.OFFSET) for i in ids
                   if int(i) >= self.OFFSET)
        return bs.decode("utf-8", errors="replace")


@dataclass
class SyntheticCorpus:
    """Markov-structured token stream with shared-prefix injection."""

    vocab_size: int
    seed: int = 0
    order_vocab: int = 512          # working vocabulary (Zipf head)
    shared_prefix_rate: float = 0.25
    prefix_len: int = 64

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        v = min(self.order_vocab, self.vocab_size - 1)
        # sparse Markov transitions: each token has a few likely successors
        self._succ = rng.integers(1, v, size=(v, 4))
        self._zipf_p = (1.0 / np.arange(1, v + 1)) ** 1.1
        self._zipf_p /= self._zipf_p.sum()
        self._v = v
        self._shared_prefix = rng.integers(1, v, size=self.prefix_len)
        self._rng = rng

    def sample_sequence(self, length: int) -> np.ndarray:
        rng = self._rng
        out = np.empty(length, np.int64)
        start = 0
        if rng.random() < self.shared_prefix_rate:
            n = min(self.prefix_len, length)
            out[:n] = self._shared_prefix[:n]
            start = n
        tok = int(rng.choice(self._v, p=self._zipf_p))
        for i in range(start, length):
            if rng.random() < 0.15:
                tok = int(rng.choice(self._v, p=self._zipf_p))
            else:
                tok = int(self._succ[tok % self._v, rng.integers(4)])
            out[i] = tok
        return out % self.vocab_size


def make_batches(corpus: SyntheticCorpus, batch: int, seq_len: int,
                 num_batches: Optional[int] = None) -> Iterator[dict]:
    """Yields train batches: tokens (b, s), labels = next token, positions."""
    n = 0
    positions = np.broadcast_to(np.arange(seq_len), (batch, seq_len)).copy()
    while num_batches is None or n < num_batches:
        seqs = np.stack([corpus.sample_sequence(seq_len + 1)
                         for _ in range(batch)])
        yield {
            "tokens": seqs[:, :-1].astype(np.int32),
            "labels": seqs[:, 1:].astype(np.int32),
            "positions": positions.astype(np.int32),
        }
        n += 1
