"""Seeded workload generators: arrival processes x lengths x tenant mixes.

The paper's headline claim is throughput under *dynamic* memory
availability; whether opportunistic harvesting pays off depends on the
traffic shape it serves.  This module generates the clock-driven request
streams the :class:`~repro.serving.server.HarvestServer` consumes:

  * **arrival processes** (all on the simulated transfer-engine clock,
    seeded and deterministic): ``poisson`` (memoryless open-loop
    arrivals), ``bursty`` (on/off: exponential bursts separated by idle
    gaps — the regime where admission policy decides stability),
    ``diurnal`` (a sinusoidal rate ramp thinned from a Poisson majorant —
    the daily traffic swell harvesting rides), and ``trace`` (replay of
    explicit arrival times);
  * **length distributions** for prompt and output tokens: fixed,
    uniform, or truncated lognormal (production prompt lengths are
    heavy-tailed — "Mind the Memory Gap", arXiv:2503.08311);
  * **tenant mixes**: weighted :class:`TenantSpec` entries crossing an
    SLO class (``latency | throughput | batch``) with per-tenant length
    distributions, priorities and deadlines.

``Workload.generate()`` returns :class:`ServeRequest`s sorted by arrival
time; the same ``(spec, seed)`` pair always yields the identical stream.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.serving.scheduler import SLO_CLASSES
from repro.serving.server import ServeRequest

#: length spec: an int (fixed), a (lo, hi) tuple (uniform, inclusive lo,
#: exclusive hi), or {"lognormal": (mean, sigma), "lo": .., "hi": ..}
LengthSpec = Union[int, Tuple[int, int], Dict]


def sample_length(rng: np.random.Generator, spec: LengthSpec) -> int:
    if isinstance(spec, int):
        if spec <= 0:
            raise ValueError(f"fixed length must be positive, got {spec}")
        return spec
    if isinstance(spec, dict):
        mean, sigma = spec["lognormal"]
        lo, hi = spec.get("lo", 1), spec.get("hi", 1 << 30)
        return int(np.clip(round(rng.lognormal(mean, sigma)), lo, hi))
    lo, hi = spec
    if not 0 < lo < hi:
        raise ValueError(f"uniform length bounds must satisfy 0 < lo < hi, "
                         f"got ({lo}, {hi})")
    return int(rng.integers(lo, hi))


# --------------------------------------------------------------- arrivals
def poisson_arrivals(rng: np.random.Generator, rate: float, n: int
                     ) -> np.ndarray:
    """Open-loop memoryless arrivals at ``rate`` req/s (simulated)."""
    if rate <= 0:
        raise ValueError(f"arrival rate must be positive, got {rate}")
    return np.cumsum(rng.exponential(1.0 / rate, size=n))


def bursty_arrivals(rng: np.random.Generator, rate: float, n: int, *,
                    burst: int = 4, duty: float = 0.25) -> np.ndarray:
    """On/off arrivals: bursts of ``burst`` back-to-back Poisson arrivals
    at ``rate / duty`` (the on-phase rate), separated by off gaps sized so
    the *long-run* rate is still ``rate``.  ``duty`` is the fraction of
    time spent in the on phase."""
    if not 0 < duty <= 1:
        raise ValueError(f"duty must be in (0, 1], got {duty}")
    if burst <= 0:
        raise ValueError(f"burst must be positive, got {burst}")
    on_rate = rate / duty
    gap_mean = burst / rate * (1.0 - duty)
    times, t = [], 0.0
    while len(times) < n:
        for _ in range(min(burst, n - len(times))):
            t += rng.exponential(1.0 / on_rate)
            times.append(t)
        t += rng.exponential(gap_mean) if gap_mean > 0 else 0.0
    return np.asarray(times)


def diurnal_arrivals(rng: np.random.Generator, rate: float, n: int, *,
                     peak_ratio: float = 3.0,
                     period_s: Optional[float] = None) -> np.ndarray:
    """Sinusoidal rate ramp (mean ``rate``, peak ``peak_ratio *`` trough)
    thinned from a Poisson majorant — a compressed day on the simulated
    clock.  ``period_s`` defaults to the span ``n`` mean-rate arrivals
    cover, so one run sees one full swell."""
    if peak_ratio < 1:
        raise ValueError(f"peak_ratio must be >= 1, got {peak_ratio}")
    if period_s is None:
        period_s = n / rate
    # lambda(t) = rate * (1 + a*sin(2 pi t / T)), a in [0, 1)
    a = (peak_ratio - 1.0) / (peak_ratio + 1.0)
    lam_max = rate * (1.0 + a)
    times, t = [], 0.0
    while len(times) < n:
        t += rng.exponential(1.0 / lam_max)
        lam = rate * (1.0 + a * np.sin(2 * np.pi * t / period_s))
        if rng.uniform() * lam_max <= lam:
            times.append(t)
    return np.asarray(times)


def diurnal_arrivals_bulk(rng: np.random.Generator, rate: float, n: int, *,
                          peak_ratio: float = 3.0,
                          period_s: Optional[float] = None) -> np.ndarray:
    """Vectorized :func:`diurnal_arrivals` for million-request traces.

    Same sinusoidal thinned-Poisson process, but candidates are drawn and
    thinned in numpy chunks instead of one Python-loop draw at a time
    (~100x faster at n=1e6).  Deterministic given ``rng``, but NOT
    draw-for-draw identical to the scalar generator — the chunked
    thinning consumes the random stream in a different order (all gaps,
    then all acceptance uniforms, per chunk), so the same seed yields a
    different (equally valid) realisation of the same process.  Use the
    scalar generator where seed-stable goldens matter; use this for
    scale sweeps where only the process matters.
    """
    if peak_ratio < 1:
        raise ValueError(f"peak_ratio must be >= 1, got {peak_ratio}")
    if rate <= 0:
        raise ValueError(f"arrival rate must be positive, got {rate}")
    if period_s is None:
        period_s = n / rate
    a = (peak_ratio - 1.0) / (peak_ratio + 1.0)
    lam_max = rate * (1.0 + a)
    out = np.empty(n)
    filled, t = 0, 0.0
    while filled < n:
        # majorant acceptance averages 1/(1+a) >= 1/2 — oversample ~2.2x
        # so most traces finish in one or two chunks
        m = max(1024, int((n - filled) * 2.2))
        ts = t + np.cumsum(rng.exponential(1.0 / lam_max, size=m))
        lam = rate * (1.0 + a * np.sin(2 * np.pi * ts / period_s))
        acc = ts[rng.uniform(size=m) * lam_max <= lam]
        take = min(acc.size, n - filled)
        out[filled:filled + take] = acc[:take]
        filled += take
        t = float(ts[-1])
    return out


def ramp_arrivals(rng: np.random.Generator, rate: float, n: int, *,
                  start_ratio: float = 0.25,
                  end_ratio: float = 2.5,
                  ramp_s: Optional[float] = None) -> np.ndarray:
    """Linear rate ramp from ``start_ratio * rate`` to ``end_ratio *
    rate`` over ``ramp_s`` seconds (then held at the end rate), thinned
    from a Poisson majorant.

    The stability-controller's adversarial scenario: pick ratios that
    straddle the engine's saturation point and the ramp drives the
    system *through* the knee instead of parking on one side of it.
    ``ramp_s`` defaults to the span ``n`` arrivals cover at the ramp's
    mean rate, so one run sees the whole climb."""
    if rate <= 0:
        raise ValueError(f"arrival rate must be positive, got {rate}")
    if not 0 < start_ratio < end_ratio:
        raise ValueError(f"need 0 < start_ratio < end_ratio, got "
                         f"({start_ratio}, {end_ratio})")
    if ramp_s is None:
        ramp_s = n / (rate * 0.5 * (start_ratio + end_ratio))
    if ramp_s <= 0:
        raise ValueError(f"ramp_s must be positive, got {ramp_s}")
    lam_max = rate * end_ratio
    times, t = [], 0.0
    while len(times) < n:
        t += rng.exponential(1.0 / lam_max)
        frac = min(t / ramp_s, 1.0)
        lam = rate * (start_ratio + (end_ratio - start_ratio) * frac)
        if rng.uniform() * lam_max <= lam:
            times.append(t)
    return np.asarray(times)


def flood_arrivals(rng: np.random.Generator, rate: float, n: int, *,
                   flood_ratio: float = 6.0,
                   flood_start: float = 0.3,
                   flood_frac: float = 0.4) -> np.ndarray:
    """Piecewise-constant rate with one flood window: ``rate`` outside,
    ``flood_ratio * rate`` inside ``[T*flood_start,
    T*(flood_start+flood_frac))`` where ``T`` is the span ``n`` arrivals
    cover at the blended mean rate — one tenant suddenly flooding an
    otherwise steady mix.  Thinned from a Poisson majorant."""
    if rate <= 0:
        raise ValueError(f"arrival rate must be positive, got {rate}")
    if flood_ratio < 1:
        raise ValueError(f"flood_ratio must be >= 1, got {flood_ratio}")
    if not (0.0 <= flood_start and flood_frac > 0.0
            and flood_start + flood_frac <= 1.0):
        raise ValueError(
            f"flood window must satisfy 0 <= flood_start, flood_frac > 0, "
            f"flood_start + flood_frac <= 1; got "
            f"({flood_start}, {flood_frac})")
    mean_rate = rate * (1.0 + (flood_ratio - 1.0) * flood_frac)
    span = n / mean_rate
    lo, hi = span * flood_start, span * (flood_start + flood_frac)
    lam_max = rate * flood_ratio
    times, t = [], 0.0
    while len(times) < n:
        t += rng.exponential(1.0 / lam_max)
        lam = lam_max if lo <= t < hi else rate
        if rng.uniform() * lam_max <= lam:
            times.append(t)
    return np.asarray(times)


def trace_arrivals(times: Sequence[float]) -> np.ndarray:
    """Replay explicit arrival times (must be sorted, non-negative)."""
    arr = np.asarray(list(times), dtype=float)
    if arr.size and (np.any(np.diff(arr) < 0) or arr[0] < 0):
        raise ValueError("trace arrival times must be sorted and >= 0")
    return arr


ARRIVALS = {"poisson": poisson_arrivals, "bursty": bursty_arrivals,
            "diurnal": diurnal_arrivals, "ramp": ramp_arrivals,
            "flood": flood_arrivals}


# ---------------------------------------------------------------- tenants
@dataclass(frozen=True)
class TenantSpec:
    """One traffic class in a multi-tenant mix.

    ``prefix_share`` is the fraction of this tenant's requests that carry
    a shared system prompt, drawn from a per-tenant pool of
    ``num_prefixes`` prompts of length ``prefix_len`` (prepended to the
    request's own body).  This is the traffic shape the harvested prefix
    cache (:mod:`repro.core.prefix_cache`) monetises — production
    multi-tenant serving is dominated by a few system prompts per tenant.
    The default 0.0 generates the legacy stream bit-exactly.
    """
    name: str
    weight: float = 1.0
    slo: str = "throughput"            # latency | throughput | batch
    priority: int = 0
    prompt_len: LengthSpec = (5, 40)
    max_new_tokens: LengthSpec = 16
    ttft_slo_s: Optional[float] = None
    e2e_slo_s: Optional[float] = None
    prefix_share: float = 0.0          # fraction carrying a shared prefix
    num_prefixes: int = 4              # size of the tenant's prompt pool
    prefix_len: LengthSpec = 32        # shared system-prompt length

    def __post_init__(self):
        if self.weight <= 0:
            raise ValueError(f"tenant weight must be positive, "
                             f"got {self.weight}")
        if self.slo not in SLO_CLASSES:
            raise ValueError(f"unknown SLO class {self.slo!r}; expected "
                             f"one of {SLO_CLASSES}")
        if not 0.0 <= self.prefix_share <= 1.0:
            raise ValueError(f"prefix_share must be in [0, 1], "
                             f"got {self.prefix_share}")
        if self.num_prefixes <= 0:
            raise ValueError(f"num_prefixes must be positive, "
                             f"got {self.num_prefixes}")


@dataclass
class Workload:
    """A seeded, clock-driven request stream.

    ``arrival`` names a generator in :data:`ARRIVALS` (or ``"trace"``
    with explicit ``arrival_kwargs={"times": [...]}``); ``rate`` is
    requests per *simulated* second on the transfer-engine clock.  Each
    arrival draws a tenant by weight, then that tenant's lengths.
    """
    num_requests: int = 8
    arrival: str = "poisson"
    rate: float = 1000.0
    seed: int = 0
    tenants: Tuple[TenantSpec, ...] = (TenantSpec("default"),)
    arrival_kwargs: Dict = field(default_factory=dict)
    vocab: Tuple[int, int] = (3, 250)   # prompt token id range
    start_t: float = 0.0                # offset on the engine clock

    def __post_init__(self):
        if self.num_requests <= 0:
            raise ValueError(f"num_requests must be positive, "
                             f"got {self.num_requests}")
        if not self.tenants:
            raise ValueError("a workload needs at least one tenant")
        if self.arrival != "trace" and self.arrival not in ARRIVALS:
            raise ValueError(f"unknown arrival process {self.arrival!r}; "
                             f"expected one of "
                             f"{(*ARRIVALS, 'trace')}")

    def generate(self) -> List[ServeRequest]:
        # independent child streams for arrival times vs request bodies vs
        # shared prefixes: the arrival process may consume a rate-dependent
        # number of draws (diurnal thinning), and the cross-rate invariant
        # "rate re-times but never re-draws prompts" must hold structurally.
        # The prefix stream is third, so enabling prefix_share never
        # perturbs the two legacy streams (seed-stable goldens).
        arrival_rng, rng, prefix_rng = (
            np.random.default_rng(s) for s in
            np.random.SeedSequence(self.seed).spawn(3))
        if self.arrival == "trace":
            times = trace_arrivals(self.arrival_kwargs["times"])
            if len(times) != self.num_requests:
                raise ValueError(
                    f"trace has {len(times)} arrivals but num_requests="
                    f"{self.num_requests}")
        else:
            times = ARRIVALS[self.arrival](arrival_rng, self.rate,
                                           self.num_requests,
                                           **self.arrival_kwargs)
        weights = np.asarray([t.weight for t in self.tenants])
        weights = weights / weights.sum()
        picks = rng.choice(len(self.tenants), size=self.num_requests,
                           p=weights)
        lo, hi = self.vocab
        # per-tenant shared system-prompt pools, from the prefix stream
        pools: Dict[str, List[List[int]]] = {
            ten.name: [list(prefix_rng.integers(
                lo, hi, size=sample_length(prefix_rng, ten.prefix_len)))
                for _ in range(ten.num_prefixes)]
            for ten in self.tenants if ten.prefix_share > 0}
        out: List[ServeRequest] = []
        for t, pick in zip(times, picks):
            ten = self.tenants[pick]
            n_prompt = sample_length(rng, ten.prompt_len)
            n_out = sample_length(rng, ten.max_new_tokens)
            prompt = list(rng.integers(lo, hi, size=n_prompt))
            if ten.prefix_share > 0 and \
                    prefix_rng.random() < ten.prefix_share:
                pool = pools[ten.name]
                prompt = pool[int(prefix_rng.integers(len(pool)))] + prompt
            out.append(ServeRequest(
                prompt=prompt,
                max_new_tokens=n_out,
                arrival_t=self.start_t + float(t),
                slo=ten.slo, priority=ten.priority, tenant=ten.name,
                ttft_slo_s=ten.ttft_slo_s, e2e_slo_s=ten.e2e_slo_s))
        return out
