from repro.serving.admission import (ADMISSION, AdmissionPolicy,
                                     AdmissionView, KVHeadroomAdmission,
                                     SLODeadlineAdmission)
from repro.serving.engine import (EngineStats, HarvestServingEngine,
                                  RequestRecord, SpecDecodeConfig)
from repro.serving.scheduler import (SCHEDULERS, SLO_CLASSES,
                                     CompletelyFairScheduler, FCFSScheduler,
                                     Request)
from repro.serving.server import HarvestServer, RequestHandle, ServeRequest
from repro.serving.sweep import (SweepConfig, SweepResult, SweepTrace,
                                 simulate)
from repro.serving.workload import (ARRIVALS, TenantSpec, Workload,
                                    bursty_arrivals, diurnal_arrivals,
                                    diurnal_arrivals_bulk, poisson_arrivals,
                                    trace_arrivals)
