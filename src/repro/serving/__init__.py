from repro.serving.engine import EngineStats, HarvestServingEngine
from repro.serving.scheduler import (SCHEDULERS, CompletelyFairScheduler,
                                     FCFSScheduler, Request)
