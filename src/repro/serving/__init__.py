from repro.serving.admission import (ADMISSION, AdmissionPolicy,
                                     AdmissionView, KVHeadroomAdmission,
                                     SLODeadlineAdmission,
                                     StabilityAdmission)
from repro.serving.control import (ControllerConfig, EwmaMean,
                                   StabilityController, WindowedRate,
                                   WindowedSum)
from repro.serving.engine import (EngineStats, HarvestServingEngine,
                                  RequestRecord, SpecDecodeConfig)
from repro.serving.scheduler import (SCHEDULERS, SLO_CLASSES,
                                     CompletelyFairScheduler, FCFSScheduler,
                                     Request)
from repro.serving.server import HarvestServer, RequestHandle, ServeRequest
from repro.serving.sweep import (SweepConfig, SweepResult, SweepTrace,
                                 simulate)
from repro.serving.workload import (ARRIVALS, TenantSpec, Workload,
                                    bursty_arrivals, diurnal_arrivals,
                                    diurnal_arrivals_bulk, flood_arrivals,
                                    poisson_arrivals, ramp_arrivals,
                                    trace_arrivals)
