"""Scale-out sweep simulator: million-request cluster serving model.

``simulate`` replays a :class:`SweepTrace` through an analytic model of
an H-host harvest cluster — per-host continuous batching with quantized
refill, the spill ladder charged per lane (local peers -> DCN peers ->
host DRAM), and optional disaggregated prefill (a shared prefill-worker
pool streaming KV over DCN, adopted by the decode hosts).  It is NOT
the serving engine: no model forward, no block store — just the
clock/cost model, so a 1M-request diurnal trace across 4 hosts runs in
seconds instead of hours (fig14 sweeps hosts x disaggregation x trace
scale with it).

Two interchangeable step loops implement the same semantics:

* ``vectorized=False`` — the reference loop, a faithful transliteration
  of the engine's per-step accounting style: per-request objects,
  per-step ``LinkSpec`` method calls, per-step metrics-dict updates
  with formatted string keys.  This is the "before" of the hot-path
  refactor.
* ``vectorized=True`` — the refactored loop: per-lane constants hoisted
  into a ``__slots__`` holder, arrival/length/cost arrays precomputed
  in numpy, metrics accumulated in locals, and run-leaping — a whole
  refill quantum advanced with ONE cost evaluation plus Q clock adds
  instead of Q full accounting passes.  >=10x faster at the
  1M-request scale (fig14 measures it).

The two loops are **bit-identical in tokens and clock**: both advance
the host clock through the exact same sequence of IEEE-754 adds and
record the same per-request admit/first-token/finish times
(``tests/test_scaleout.py`` holds a hypothesis property test over
seeded Poisson/bursty workloads).  Metrics counters are NOT part of
that contract — the vectorized loop accumulates ``Q * w`` where the
scalar loop adds ``w`` Q times.

Model semantics (identical in both loops):

* requests are assigned round-robin (``i % hosts``) over the
  arrival-sorted trace; hosts are independent except for the shared
  prefill pool (disaggregated mode) and the remote-host spill budget;
* admission is FCFS at refill boundaries (every ``refill_interval``
  decode steps, the quantization that makes run-leaping possible;
  ``refill_interval=1`` recovers engine-style per-step refill): a
  request occupies one of ``max_batch`` rows from admission until the
  boundary after its last token.  Colocated prefill charges its window
  ``max(prompt_len * t_flop_tok, t_weights)`` on the host clock —
  prefill stalls decode, which is the disaggregation motivation.
  Disaggregated prefill runs in the pool: the request becomes
  admissible once its KV stream lands on the decode host
  (``prefill_end + dcn_time(blocks)``), with its first token already
  minted at ``prefill_end``;
* a decode step costs ``max(n_active * t_flop_tok, t_weights)``
  overlapped with reloading the spilled working set: KV blocks beyond
  ``local_slots`` spill to harvested local-peer memory, then DCN-peer
  memory on other hosts, then host DRAM; each lane is charged
  ``latency + bytes / bandwidth`` per step and the step takes the
  slowest of compute and the three reload lanes.
"""
from __future__ import annotations

import heapq
import time
from dataclasses import dataclass, field, replace
from typing import Dict, Optional, Tuple, Union

import numpy as np

from repro.core.tiers import (H100_DCN_LINK, H100_NVLINK, TPU_V5E,
                              V5E_DCN_LINK, LinkSpec)
from repro.serving.workload import (LengthSpec, bursty_arrivals,
                                    diurnal_arrivals_bulk, poisson_arrivals)

__all__ = ["SweepConfig", "SweepTrace", "SweepResult", "simulate"]


def _max_rss_mb() -> float:
    """Process peak RSS in MiB (0.0 where the resource module is absent,
    e.g. non-POSIX).  ``ru_maxrss`` is KiB on Linux, bytes on macOS —
    memory regressions in the million-request sweeps show up here next
    to ``walltime_s``."""
    try:
        import resource
        import sys
        peak = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
        return peak / 2**20 if sys.platform == "darwin" else peak / 1024.0
    except ImportError:
        return 0.0


# ----------------------------------------------------------------- config
@dataclass(frozen=True)
class SweepConfig:
    """Cluster geometry + analytic cost model for one sweep point.

    Defaults are the H100 family serving a ~6.1B active-parameter model;
    :meth:`from_family` derives the link/compute constants from the
    calibrated :mod:`repro.core.tiers` hardware models.
    """
    hosts: int = 1
    max_batch: int = 32                 # decode rows per host
    local_slots: int = 96               # local-HBM KV block slots per host
    peer_blocks: int = 64               # harvested local-peer blocks per host
    dcn_blocks: int = 128               # harvested blocks per REMOTE host
    block_size: int = 16                # tokens per KV block
    block_bytes: float = float(2 << 20)
    refill_interval: int = 8            # decode steps between admissions
    t_flop_tok: float = 2 * 6.1e9 / H100_NVLINK.peak_flops
    t_weights: float = 2 * 6.1e9 / H100_NVLINK.hbm_bw
    peer_bw: float = H100_NVLINK.peer_link.bandwidth
    peer_lat: float = H100_NVLINK.peer_link.latency
    dcn_bw: float = H100_DCN_LINK.bandwidth
    dcn_lat: float = H100_DCN_LINK.latency
    host_bw: float = H100_NVLINK.host_link.bandwidth
    host_lat: float = H100_NVLINK.host_link.latency
    disaggregated: bool = False
    prefill_workers: int = 4            # shared pool size (disaggregated)

    def __post_init__(self):
        if self.hosts < 1:
            raise ValueError(f"hosts must be >= 1, got {self.hosts}")
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {self.max_batch}")
        if self.refill_interval < 1:
            raise ValueError(f"refill_interval must be >= 1, "
                             f"got {self.refill_interval}")
        if self.disaggregated and self.prefill_workers < 1:
            raise ValueError(f"prefill_workers must be >= 1, "
                             f"got {self.prefill_workers}")
        if min(self.local_slots, self.peer_blocks, self.dcn_blocks) < 0:
            raise ValueError("tier block budgets must be >= 0")

    @classmethod
    def from_family(cls, family: str, *, hosts: int = 1,
                    active_params: float = 6.1e9, **overrides
                    ) -> "SweepConfig":
        """Derive the cost constants from a calibrated hardware family
        (``"h100"``/``"h100-nvlink-2gpu"`` or ``"tpu-v5e"``/``"v5e"``)."""
        if family.startswith("h100"):
            hw, dcn = H100_NVLINK, H100_DCN_LINK
        elif family in ("tpu-v5e", "v5e"):
            hw, dcn = TPU_V5E, V5E_DCN_LINK
        else:
            raise ValueError(f"unknown hardware family {family!r}; expected "
                             f"'h100*' or 'tpu-v5e'")
        kw = dict(
            hosts=hosts,
            t_flop_tok=2 * active_params / hw.peak_flops,
            t_weights=2 * active_params / hw.hbm_bw,
            peer_bw=hw.peer_link.bandwidth, peer_lat=hw.peer_link.latency,
            dcn_bw=dcn.bandwidth, dcn_lat=dcn.latency,
            host_bw=hw.host_link.bandwidth, host_lat=hw.host_link.latency,
        )
        kw.update(overrides)
        return cls(**kw)

    def with_(self, **overrides) -> "SweepConfig":
        return replace(self, **overrides)


class _HostConsts:
    """Per-lane constants hoisted out of the vectorized step loop — no
    ``LinkSpec`` method calls or dataclass attribute chases in the hot
    path.  The float expressions downstream must stay bit-identical to
    the scalar loop's ``LinkSpec.transfer_time`` calls:
    ``latency + nbytes / bandwidth``."""
    __slots__ = ("rows", "quantum", "t_flop", "t_weights", "local_slots",
                 "peer_cap", "dcn_cap", "block_bytes", "peer_lat",
                 "peer_bw", "dcn_lat", "dcn_bw", "host_lat", "host_bw")

    def __init__(self, cfg: SweepConfig):
        self.rows = cfg.max_batch
        self.quantum = cfg.refill_interval
        self.t_flop = cfg.t_flop_tok
        self.t_weights = cfg.t_weights
        self.local_slots = cfg.local_slots
        self.peer_cap = cfg.peer_blocks
        self.dcn_cap = cfg.dcn_blocks * (cfg.hosts - 1)
        self.block_bytes = cfg.block_bytes
        self.peer_lat = cfg.peer_lat
        self.peer_bw = cfg.peer_bw
        self.dcn_lat = cfg.dcn_lat
        self.dcn_bw = cfg.dcn_bw
        self.host_lat = cfg.host_lat
        self.host_bw = cfg.host_bw


# ------------------------------------------------------------------ trace
def _bulk_lengths(rng: np.random.Generator, spec: LengthSpec, n: int
                  ) -> np.ndarray:
    """Vectorized :func:`~repro.serving.workload.sample_length`."""
    if isinstance(spec, int):
        if spec <= 0:
            raise ValueError(f"fixed length must be positive, got {spec}")
        return np.full(n, spec, dtype=np.int64)
    if isinstance(spec, dict):
        mean, sigma = spec["lognormal"]
        lo, hi = spec.get("lo", 1), spec.get("hi", 1 << 30)
        draw = np.round(rng.lognormal(mean, sigma, size=n))
        return np.clip(draw, lo, hi).astype(np.int64)
    lo, hi = spec
    if not 0 < lo < hi:
        raise ValueError(f"uniform length bounds must satisfy 0 < lo < hi, "
                         f"got ({lo}, {hi})")
    return rng.integers(lo, hi, size=n, dtype=np.int64)


@dataclass
class SweepTrace:
    """Arrival-sorted request arrays for the sweep simulator."""
    arrival_t: np.ndarray
    prompt_len: np.ndarray
    out_len: np.ndarray

    def __post_init__(self):
        self.arrival_t = np.ascontiguousarray(self.arrival_t, dtype=float)
        self.prompt_len = np.ascontiguousarray(self.prompt_len,
                                               dtype=np.int64)
        self.out_len = np.ascontiguousarray(self.out_len, dtype=np.int64)
        n = self.arrival_t.shape[0]
        if not (self.prompt_len.shape[0] == self.out_len.shape[0] == n):
            raise ValueError("trace arrays must have equal length")
        if n and (np.any(np.diff(self.arrival_t) < 0)
                  or self.arrival_t[0] < 0):
            raise ValueError("arrival times must be sorted and >= 0")
        if n and (self.prompt_len.min() < 1 or self.out_len.min() < 1):
            raise ValueError("prompt/output lengths must be >= 1")

    @property
    def n(self) -> int:
        return self.arrival_t.shape[0]

    @classmethod
    def generate(cls, process: str = "poisson", rate: float = 1000.0,
                 n: int = 1024, seed: int = 0, *,
                 prompt_len: LengthSpec = (16, 129),
                 out_len: LengthSpec = (8, 57),
                 **arrival_kwargs) -> "SweepTrace":
        """Seeded bulk trace: arrivals from ``poisson | bursty | diurnal``
        (diurnal uses the vectorized generator — million-request traces
        build in milliseconds), lengths drawn vectorized from the same
        specs :class:`~repro.serving.workload.TenantSpec` uses."""
        a_rng, l_rng = (np.random.default_rng(s)
                        for s in np.random.SeedSequence(seed).spawn(2))
        if process == "poisson":
            t = poisson_arrivals(a_rng, rate, n)
        elif process == "bursty":
            t = bursty_arrivals(a_rng, rate, n, **arrival_kwargs)
        elif process == "diurnal":
            t = diurnal_arrivals_bulk(a_rng, rate, n, **arrival_kwargs)
        else:
            raise ValueError(f"unknown arrival process {process!r}; expected "
                             f"poisson | bursty | diurnal")
        return cls(t, _bulk_lengths(l_rng, prompt_len, n),
                   _bulk_lengths(l_rng, out_len, n))


# ----------------------------------------------------------------- result
@dataclass
class SweepResult:
    clock_s: float                      # max over host clocks
    host_clock_s: np.ndarray
    host: np.ndarray                    # per-request host assignment
    admit_t: np.ndarray
    first_token_t: np.ndarray
    finish_t: np.ndarray
    tokens: np.ndarray                  # decoded tokens per request
    walltime_s: float = 0.0             # real seconds simulate() took
    max_rss_mb: float = 0.0             # process peak RSS after the run
    metrics: Dict[str, float] = field(default_factory=dict)

    def ttft(self, trace: SweepTrace) -> np.ndarray:
        return self.first_token_t - trace.arrival_t

    def e2e(self, trace: SweepTrace) -> np.ndarray:
        return self.finish_t - trace.arrival_t

    def goodput(self, trace: SweepTrace, *,
                ttft_slo_s: Optional[float] = None,
                e2e_slo_s: Optional[float] = None) -> float:
        """SLO-goodput: requests/s (over the cluster makespan) that met
        every given deadline."""
        ok = np.ones(trace.n, dtype=bool)
        if ttft_slo_s is not None:
            ok &= self.ttft(trace) <= ttft_slo_s
        if e2e_slo_s is not None:
            ok &= self.e2e(trace) <= e2e_slo_s
        if self.clock_s <= 0:
            return 0.0
        return float(ok.sum()) / self.clock_s

    def throughput(self, trace: SweepTrace) -> float:
        """Decoded tokens/s over the cluster makespan."""
        if self.clock_s <= 0:
            return 0.0
        return float(self.tokens.sum()) / self.clock_s


# ------------------------------------------------- shared prep (both loops)
def _pool_transform(arr: np.ndarray, pfw: np.ndarray, stream_s: np.ndarray,
                    workers: int) -> Tuple[np.ndarray, np.ndarray]:
    """Disaggregated prefill-pool schedule, shared by both loops.

    Global FCFS over ``workers`` prefill servers: request i starts at
    ``max(arrival_i, earliest free worker)``, holds its worker for its
    prefill window, then streams its KV over DCN.  Returns
    ``(first_token_t, stream_done_t)`` — the decode hosts admit at
    ``stream_done_t`` exactly like a prefix-cache adoption.
    """
    n = arr.shape[0]
    ft0 = np.empty(n)
    eff = np.empty(n)
    free = [0.0] * workers
    heapq.heapify(free)
    push, pop = heapq.heappush, heapq.heappop
    for i in range(n):
        s = pop(free)
        a = arr[i]
        if s < a:
            s = a
        e = s + pfw[i]
        push(free, e)
        ft0[i] = e
        eff[i] = e + stream_s[i]
    return ft0, eff


# --------------------------------------------------- scalar reference loop
class _SimReq:
    """Per-request record for the scalar loop — deliberately a plain
    attribute-bag, matching the engine's object-per-request style the
    vectorized loop replaces."""

    def __init__(self, g: int, rem, blocks):
        self.g = g                      # global trace index
        self.rem = rem                  # decode steps left after token 0
        self.blocks = blocks            # KV working-set blocks


def _simulate_host_scalar(eff, pfw, blocks, rem0, ft0, gidx, cfg,
                          admit_t, first_t, finish_t, mets, h):
    """Reference per-step loop, engine-accounting style.

    Every decode step: recompute the working set by walking the active
    request objects, price each spill lane through ``LinkSpec``
    objects, update formatted-string metrics keys.  Semantically
    authoritative; the vectorized loop must match its tokens and clock
    bit-for-bit.
    """
    peer_link = LinkSpec(cfg.peer_bw, cfg.peer_lat)
    dcn_link = LinkSpec(cfg.dcn_bw, cfg.dcn_lat)
    host_link = LinkSpec(cfg.host_bw, cfg.host_lat)
    disagg = cfg.disaggregated
    quantum = cfg.refill_interval
    dcn_cap = cfg.dcn_blocks * (cfg.hosts - 1)
    m = eff.shape[0]
    t = 0.0
    head = 0
    free = cfg.max_batch
    active = []
    while head < m or active:
        # ---- refill boundary: release finished rows, admit FCFS
        released = 0
        for r in active:
            if r.rem <= 0:
                released += 1
        if released:
            active = [r for r in active if r.rem > 0]
            free += released
        while head < m and free > 0 and eff[head] <= t:
            j = head
            head += 1
            g = gidx[j]
            admit_t[g] = t
            if disagg:
                first_t[g] = ft0[j]
            else:
                t = t + pfw[j]          # prefill stalls the host
                first_t[g] = t
            r = rem0[j]
            if r == 0:
                finish_t[g] = t        # single-token request: no row
            else:
                active.append(_SimReq(g, r, blocks[j]))
                free -= 1
        if not active:
            if head >= m:
                break
            nx = eff[head]
            if nx > t:
                t = nx                  # idle jump to the next arrival
            continue
        # ---- one refill quantum, accounted step by step
        for _ in range(quantum):
            n_act = len(active)
            ws = 0
            for r in active:
                ws += r.blocks
            w = n_act * cfg.t_flop_tok
            if w < cfg.t_weights:
                w = cfg.t_weights
            spill = ws - cfg.local_slots
            if spill > 0:
                p = spill if spill < cfg.peer_blocks else cfg.peer_blocks
                lane_t = peer_link.transfer_time(p * cfg.block_bytes)
                mets[f"h{h}.lane.peer.busy_s"] = \
                    mets.get(f"h{h}.lane.peer.busy_s", 0.0) + lane_t
                if lane_t > w:
                    w = lane_t
                spill -= p
            if spill > 0:
                d = spill if spill < dcn_cap else dcn_cap
                if d > 0:
                    lane_t = dcn_link.transfer_time(d * cfg.block_bytes)
                    mets[f"h{h}.lane.dcn.busy_s"] = \
                        mets.get(f"h{h}.lane.dcn.busy_s", 0.0) + lane_t
                    if lane_t > w:
                        w = lane_t
                    spill -= d
            if spill > 0:
                lane_t = host_link.transfer_time(spill * cfg.block_bytes)
                mets[f"h{h}.lane.host.busy_s"] = \
                    mets.get(f"h{h}.lane.host.busy_s", 0.0) + lane_t
                if lane_t > w:
                    w = lane_t
            t += w
            decoded = 0
            for r in active:
                rm = r.rem
                if rm > 0:
                    rm -= 1
                    r.rem = rm
                    decoded += 1
                    if rm == 0:
                        finish_t[r.g] = t
            mets[f"h{h}.steps"] = mets.get(f"h{h}.steps", 0.0) + 1
            mets[f"h{h}.busy_s"] = mets.get(f"h{h}.busy_s", 0.0) + w
            mets[f"h{h}.decoded"] = mets.get(f"h{h}.decoded", 0.0) + decoded
    return t


# ------------------------------------------------------- vectorized loop
def _simulate_host_vector(eff, pfw, blocks, rem0, ft0, gidx, cfg,
                          admit_t, first_t, finish_t, mets, h):
    """Refactored loop: hoisted lane constants, run-leaping over whole
    refill quanta, bulk finish lookup through the per-quantum clock
    sequence.  Bit-identical tokens and clock to the scalar loop — the
    clock advances through the very same sequence of float adds; only
    the bookkeeping around those adds is batched.
    """
    c = _HostConsts(cfg)
    disagg = cfg.disaggregated
    quantum = c.quantum
    t_flop = c.t_flop
    t_weights = c.t_weights
    local_slots = c.local_slots
    peer_cap = c.peer_cap
    dcn_cap = c.dcn_cap
    bb = c.block_bytes
    peer_lat, peer_bw = c.peer_lat, c.peer_bw
    dcn_lat, dcn_bw = c.dcn_lat, c.dcn_bw
    host_lat, host_bw = c.host_lat, c.host_bw
    m = eff.shape[0]
    # numpy scalar indexing costs ~200ns a touch; the hot loop reads
    # every request a handful of times, so stage the per-host columns as
    # plain lists (same float64 values — tolist() is exact) and scatter
    # the results back in one vectorized assignment at the end
    eff_l = eff.tolist()
    pfw_l = pfw.tolist()
    blocks_l = blocks.tolist()
    rem0_l = rem0.tolist()
    ft0_l = ft0.tolist() if disagg else eff_l
    admit_l = [0.0] * m
    first_l = [0.0] * m
    finish_l = [0.0] * m
    heappush, heappop = heapq.heappush, heapq.heappop
    t = 0.0
    head = 0
    free = c.rows
    n_act = 0
    act = []            # min-heap of (absolute finish step, position, blocks)
    step_now = 0        # absolute decode-step counter
    ws = 0              # working-set blocks (incremental)
    tseq = [0.0] * quantum              # clock after each add of a quantum
    steps = 0.0
    busy_s = 0.0
    decoded = 0.0
    peer_busy = dcn_busy = host_busy = 0.0
    while head < m or act:
        # ---- refill boundary: admit FCFS (finished rows were released
        # at the end of the quantum that finished them — same boundary)
        while head < m and free > 0 and eff_l[head] <= t:
            j = head
            head += 1
            admit_l[j] = t
            if disagg:
                first_l[j] = ft0_l[j]
            else:
                t = t + pfw_l[j]
                first_l[j] = t
            r = rem0_l[j]
            if r == 0:
                finish_l[j] = t
            else:
                b = blocks_l[j]
                heappush(act, (step_now + r, j, b))
                n_act += 1
                free -= 1
                ws += b
                decoded += r
        if not act:
            if head >= m:
                break
            nx = eff_l[head]
            if nx > t:
                t = nx
            continue
        # ---- one refill quantum, leapt: price once, add Q times
        w = n_act * t_flop
        if w < t_weights:
            w = t_weights
        spill = ws - local_slots
        if spill > 0:
            p = spill if spill < peer_cap else peer_cap
            lane_t = peer_lat + (p * bb) / peer_bw
            peer_busy += quantum * lane_t
            if lane_t > w:
                w = lane_t
            spill -= p
        if spill > 0:
            d = spill if spill < dcn_cap else dcn_cap
            if d > 0:
                lane_t = dcn_lat + (d * bb) / dcn_bw
                dcn_busy += quantum * lane_t
                if lane_t > w:
                    w = lane_t
                spill -= d
        if spill > 0:
            lane_t = host_lat + (spill * bb) / host_bw
            host_busy += quantum * lane_t
            if lane_t > w:
                w = lane_t
        for i in range(quantum):
            t += w
            tseq[i] = t
        nxt = step_now + quantum
        while act and act[0][0] <= nxt:
            d, j, b = heappop(act)
            finish_l[j] = tseq[d - step_now - 1]
            n_act -= 1
            free += 1
            ws -= b
        step_now = nxt
        steps += quantum
        busy_s += quantum * w
    admit_t[gidx] = admit_l
    first_t[gidx] = first_l
    finish_t[gidx] = finish_l
    mets[f"h{h}.steps"] = steps
    mets[f"h{h}.busy_s"] = busy_s
    mets[f"h{h}.decoded"] = decoded
    if peer_busy:
        mets[f"h{h}.lane.peer.busy_s"] = peer_busy
    if dcn_busy:
        mets[f"h{h}.lane.dcn.busy_s"] = dcn_busy
    if host_busy:
        mets[f"h{h}.lane.host.busy_s"] = host_busy
    return t


# --------------------------------------------------------------- driver
def simulate(trace: SweepTrace, cfg: SweepConfig, *,
             vectorized: bool = True) -> SweepResult:
    """Replay ``trace`` through the cluster model.

    Both values of ``vectorized`` produce bit-identical per-request
    times, tokens and clock; the flag selects the reference per-step
    loop vs the run-leaping refactor (the fig14 perf benchmark measures
    the gap).  Shared preparation — cost arrays, the round-robin host
    split, the disaggregated prefill-pool schedule — is identical work
    on an identical code path for both.
    """
    n = trace.n
    H = cfg.hosts
    arr = trace.arrival_t
    plen = trace.prompt_len
    outn = trace.out_len
    bs = cfg.block_size
    t0 = time.perf_counter()
    # engine cost model, vectorized over the whole trace (shared prep):
    # prefill window max(prompt * t_flop_tok, t_weights); block capacity
    # ceil((prompt + out + 1) / block_size) + 1 (the engine's
    # _blocks_needed formula)
    pfw = np.maximum(plen * cfg.t_flop_tok, cfg.t_weights)
    blocks = (plen + outn + 1 + bs - 1) // bs + 1
    rem0 = outn - 1
    host = np.arange(n, dtype=np.int64) % H
    if cfg.disaggregated:
        stream_s = cfg.dcn_lat + blocks * cfg.block_bytes / cfg.dcn_bw
        ft0, eff = _pool_transform(arr, pfw, stream_s, cfg.prefill_workers)
    else:
        ft0, eff = arr, arr
    admit_t = np.full(n, np.nan)
    first_t = np.full(n, np.nan)
    finish_t = np.full(n, np.nan)
    mets: Dict[str, float] = {}
    run = _simulate_host_vector if vectorized else _simulate_host_scalar
    host_clock = np.zeros(H)
    for h in range(H):
        gidx = np.nonzero(host == h)[0]
        if cfg.disaggregated:
            # admission order on a decode host is stream-arrival order
            gidx = gidx[np.argsort(eff[gidx], kind="stable")]
        host_clock[h] = run(eff[gidx], pfw[gidx], blocks[gidx],
                            rem0[gidx], ft0[gidx], gidx, cfg,
                            admit_t, first_t, finish_t, mets, h)
    walltime = time.perf_counter() - t0
    return SweepResult(
        clock_s=float(host_clock.max()) if H else 0.0,
        host_clock_s=host_clock, host=host, admit_t=admit_t,
        first_token_t=first_t, finish_t=finish_t, tokens=outn.copy(),
        walltime_s=walltime, max_rss_mb=_max_rss_mb(), metrics=mets)
