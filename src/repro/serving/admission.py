"""Admission policies: clock-driven gatekeepers in front of the schedulers.

The engine's FCFS/CFS schedulers decide *which row* an admissible request
takes; an :class:`AdmissionPolicy` decides *whether a queued request is
admissible at all* at the current simulated clock.  The policy sees one
:class:`AdmissionView` per scheduler step (the capacity picture at
``now``) and partitions the waiting queue into

  * **eligible** — passed to the capacity filter + scheduler, in the
    order the scheduler should consider them (a policy may reorder, e.g.
    latency-class-first);
  * **shed** — rejected now (load shedding): the engine retires them in
    state ``rejected`` without running a single prefill flop, the
    queueing-stability move when the KV-memory bound makes the queue
    divergent (Nie et al., arXiv:2605.04595).

Everything not in either list is *deferred*: it stays queued, FIFO, and
is reconsidered next step.  The base policy is unconditional (legacy
behaviour, bit-exact with the pre-lifecycle engine); ``headroom`` keeps a
reserve of local KV slots free to absorb decode growth without
preemption churn; ``deadline`` sheds requests that can no longer meet
their TTFT SLO and lets latency-class traffic jump the queue.
"""
from __future__ import annotations

from typing import Callable, List, Tuple

from repro.serving.scheduler import Request


class AdmissionView:
    """The capacity picture a policy may inspect at admission time."""

    def __init__(self, *, now: float, free_rows: int, num_slots: int,
                 pinned_blocks: int, num_running: int,
                 blocks_needed: Callable[[Request], int],
                 est_prefill_s: Callable[[Request], float],
                 pending_prefill_s: float = 0.0):
        self.now = now
        self.free_rows = free_rows
        self.num_slots = num_slots              # local KV pool capacity
        self.pinned_blocks = pinned_blocks      # running working sets
        self.num_running = num_running
        self.blocks_needed = blocks_needed      # per-request working set
        self.est_prefill_s = est_prefill_s      # lower-bound service time
        #: prefill seconds already committed ahead of this admission pass
        #: (in-flight chunked prefills of running requests)
        self.pending_prefill_s = pending_prefill_s


class AdmissionPolicy:
    """Unconditional admission: every queued request is eligible, in FIFO
    order.  This is the legacy (and default) behaviour."""

    name = "all"

    def select(self, waiting: List[Request], view: AdmissionView
               ) -> Tuple[List[Request], List[Request]]:
        """Return ``(eligible_in_order, shed)``."""
        return list(waiting), []


class KVHeadroomAdmission(AdmissionPolicy):
    """KV-headroom-aware admission: only admit while the projected pinned
    working set leaves ``headroom_frac`` of the local pool free.

    Admitting up to the brim forces the fair scheduler into eviction
    churn the moment any running request grows a block; holding a reserve
    trades queue wait for fewer preemption-induced reloads.  When nothing
    is running the head-of-line request is always eligible — a pool
    smaller than the reserve must not deadlock the server.
    """

    name = "headroom"

    def __init__(self, headroom_frac: float = 0.25):
        if not 0.0 <= headroom_frac < 1.0:
            raise ValueError(
                f"headroom_frac must be in [0, 1), got {headroom_frac}")
        self.headroom_frac = headroom_frac

    def select(self, waiting, view):
        cap = view.num_slots * (1.0 - self.headroom_frac)
        pinned = view.pinned_blocks
        eligible: List[Request] = []
        for r in waiting:
            need = view.blocks_needed(r)
            if pinned + need > cap:
                if not eligible and view.num_running == 0:
                    eligible.append(r)   # starvation guard
                break                    # defer the rest, keep FIFO
            pinned += need
            eligible.append(r)
        return eligible, []


class SLODeadlineAdmission(AdmissionPolicy):
    """SLO-deadline-aware admission: shed what cannot make its deadline,
    serve the latency class first.

    A queued request whose TTFT deadline is already unreachable (its
    prefill alone lands past the deadline) is shed immediately instead
    of burning prefill compute on a token that arrives too late; the
    survivors are ordered priority-desc, deadline-asc, then FIFO.
    Requests that already produced a token are never shed — their TTFT is
    history and their KV investment is sunk.

    The reachability check walks the queue in admission order carrying a
    prefill *backlog*: the in-flight chunked-prefill seconds the engine
    already committed (``view.pending_prefill_s``) plus the estimated
    prefill of every request kept ahead in this same pass.  Without the
    backlog each request is judged as if it would prefill first, so the
    policy admits a convoy whose tail it then misses.
    """

    name = "deadline"

    def __init__(self, slack: float = 1.0):
        if slack <= 0:
            raise ValueError(f"slack must be positive, got {slack}")
        self.slack = slack

    def select(self, waiting, view):
        inf = float("inf")
        order = sorted(waiting, key=lambda r: (
            -r.priority,
            r.ttft_deadline_t if r.ttft_deadline_t is not None else inf,
            r.arrival_t, r.req_id))
        keep: List[Request] = []
        shed: List[Request] = []
        backlog = view.pending_prefill_s
        for r in order:
            ddl = r.ttft_deadline_t
            est = view.est_prefill_s(r)
            if (ddl is not None and r.first_token_t is None
                    and view.now + backlog + est * self.slack > ddl):
                shed.append(r)
                continue
            keep.append(r)
            if r.needs_prefill:
                backlog += est
        return keep, shed


class StabilityAdmission(AdmissionPolicy):
    """Closed-loop admission driven by a
    :class:`~repro.serving.control.StabilityController`.

    While the controller is **disengaged** (the workload sits inside the
    stability region) the wrapped ``inner`` policy decides verbatim —
    the controller is a provable no-op.  While **engaged**:

      * requests are ordered priority-desc, TTFT-deadline-carriers
        first (deadline-asc), then FIFO;
      * TTFT-unreachable and E2E-unreachable requests are *shed* (the
        E2E check prices the remaining decode at the uncongested
        per-token floor, so only the certainly hopeless are claimed —
        static policies cannot shed a flood of deadline-free-TTFT
        work, this one can);
      * deadline-free requests queued longer than
        ``controller.shed_wait_s()`` are shed — the queue is divergent,
        waiting longer only grows it;
      * survivors are admitted only while the controller's
        regime-dependent row cap (``batch_cap``) and block budget
        (``eff_blocks * (1 - headroom)``) hold; the rest *defer*.

    Not registered in :data:`ADMISSION` — it needs a live controller,
    so the engine wires it when constructed with ``controller=``.
    """

    name = "stability"

    def __init__(self, controller, inner: "AdmissionPolicy | None" = None):
        self.ctrl = controller
        self.inner = inner or AdmissionPolicy()

    def select(self, waiting, view):
        if not self.ctrl.engaged:
            return self.inner.select(waiting, view)
        inf = float("inf")
        order = sorted(waiting, key=lambda r: (
            -r.priority,
            r.ttft_deadline_t if r.ttft_deadline_t is not None else inf,
            r.arrival_t, r.req_id))
        eligible: List[Request] = []
        shed: List[Request] = []
        backlog = view.pending_prefill_s
        rows = max(self.ctrl.batch_cap - view.num_running, 0)
        budget = self.ctrl.block_budget(view) - view.pinned_blocks
        max_wait = self.ctrl.shed_wait_s()
        slack = self.ctrl.cfg.slack
        deferred = 0
        for r in order:
            ttft_ddl = r.ttft_deadline_t
            e2e_ddl = r.e2e_deadline_t
            est = view.est_prefill_s(r) if r.needs_prefill else 0.0
            if (ttft_ddl is not None and r.first_token_t is None
                    and view.now + backlog + est * slack > ttft_ddl):
                shed.append(r)
                continue
            if e2e_ddl is not None:
                rem = max(r.max_new_tokens - len(r.output), 1)
                eta = (view.now + backlog
                       + (est + rem * self.ctrl.tpot_plan(r.slo)) * slack)
                if eta > e2e_ddl:
                    shed.append(r)
                    continue
            if (ttft_ddl is None and e2e_ddl is None
                    and view.now - r.enqueue_t > max_wait):
                shed.append(r)
                continue
            need = view.blocks_needed(r)
            if rows <= 0 or need > budget:
                if not eligible and view.num_running == 0 and rows > 0:
                    eligible.append(r)   # starvation guard: never deadlock
                    rows -= 1
                    continue
                deferred += 1            # defer, reconsider next step
                continue
            rows -= 1
            budget -= need
            eligible.append(r)
            if r.needs_prefill:
                backlog += est
        self.ctrl.stats["shed"] += len(shed)
        self.ctrl.stats["deferred"] += deferred
        return eligible, shed


ADMISSION = {
    "all": AdmissionPolicy,
    "headroom": KVHeadroomAdmission,
    "deadline": SLODeadlineAdmission,
}
