"""Closed-loop stability control for the harvest serving engine.

Every admission/fidelity/prefetch policy shipped so far is *static*: a
diurnal ramp through the saturation point, a correlated peer-revocation
storm, or one tenant flooding a multi-tenant mix pushes the engine past
its stability region with no recourse but queue blowup.  This module
closes the loop:

  estimators  ->  stability region  ->  controller (actuators)

* **Online estimators** — per-SLO-class windowed arrival rates, EWMA
  service times / KV block-seconds (seeded from arrival-time
  predictions, switched to retire-time actuals once enough requests
  complete), and an effective harvestable-capacity estimate that
  discounts volatile peer memory by the observed revocation rate
  (``monitor.*``/``allocator.revocations`` counters).

* **Stability region** — the queueing-theoretic condition of Nie et
  al. (arXiv:2605.04595) adapted to the harvest pools: the system is
  stable iff KV demand ``sum_c lam_c * E[KV block-seconds]_c`` stays
  below the effective block supply *and* row demand
  ``lam * E[service]`` stays below the batch rows.  ``rho`` is the max
  of the two utilisations; engagement is hysteretic (enter above
  ``enter_rho``, exit below ``exit_rho``) so the controller does not
  chatter at the knee.

* **Actuators** (all gated on ``engaged`` — a controller that never
  engages is a provable no-op, bit-exact in tokens *and* clock):

  ===================  ====================================================
  admission            :class:`repro.serving.admission.StabilityAdmission`
                       sheds deadline-unreachable work, bounds the pinned
                       working set to ``eff * (1 - headroom)`` blocks
  batch-size cap       regime-dependent cap on the engine refill loop
                       ("Mind the Memory Gap", arXiv:2503.08311: past the
                       weights/flops crossover a bigger batch only adds
                       KV pressure)
  prefetch budget      scales :class:`~repro.core.prefetch.Prefetcher`
                       window/inflight budgets down when revocations spike
  harvest appetite     scales the churn penalty of
                       :class:`~repro.core.policy.TopologyAwarePolicy` up
                       so placement avoids storming peers
  ===================  ====================================================

The controller ticks on the transfer-engine clock (``poll(now)``, same
drive pattern as :class:`repro.core.monitor.PeerMonitor`) and publishes
its state as ``ctrl.*`` metrics plus a one-line :meth:`summary`.
"""
from __future__ import annotations

import math
from collections import deque
from dataclasses import dataclass
from typing import Deque, Dict, Optional, Tuple

from repro.serving.scheduler import SLO_CLASSES

__all__ = [
    "WindowedRate", "WindowedSum", "EwmaMean", "ControllerConfig",
    "StabilityController",
]


# --------------------------------------------------------------- estimators
class WindowedRate:
    """Sliding-window event rate: ``count(window) / window_s``.

    Events are observed at (non-decreasing) clock timestamps; the rate
    at ``now`` counts events in ``(now - window_s, now]``.  Unbiased for
    a Poisson process (relative error ~ ``1/sqrt(lam * window_s)``).

    **Cold start.**  Before a full window has elapsed since the first
    event, dividing by ``window_s`` underestimates a sustained rate by
    ``elapsed / window_s`` — enough to hide a burst from the stability
    region until its deadlines are already blown.  Once a few events
    exist (``MIN_COLD_EVENTS``, so a lone early pair cannot fake a
    spike) the rate divides by the elapsed span instead, converging to
    the plain windowed estimate as ``elapsed`` reaches ``window_s``.
    """

    #: events required before the cold-start (elapsed-span) estimate is
    #: trusted over the conservative full-window division
    MIN_COLD_EVENTS = 4

    __slots__ = ("window_s", "_events", "_t0")

    def __init__(self, window_s: float):
        if window_s <= 0:
            raise ValueError(f"window_s must be > 0, got {window_s}")
        self.window_s = float(window_s)
        self._events: Deque[float] = deque()
        self._t0: Optional[float] = None

    def observe(self, t: float) -> None:
        if self._t0 is None:
            self._t0 = t
        self._events.append(t)

    def _purge(self, now: float) -> None:
        lo = now - self.window_s
        ev = self._events
        while ev and ev[0] <= lo:
            ev.popleft()

    def count(self, now: float) -> int:
        self._purge(now)
        return sum(1 for t in self._events if t <= now)

    def rate(self, now: float) -> float:
        n = self.count(now)
        span = self.window_s
        if self._t0 is not None and n >= self.MIN_COLD_EVENTS:
            elapsed = now - self._t0
            if 0.0 < elapsed < span:
                span = elapsed
        return n / span


class WindowedSum:
    """Sliding-window sum of weighted events (e.g. tokens/s)."""

    __slots__ = ("window_s", "_events")

    def __init__(self, window_s: float):
        if window_s <= 0:
            raise ValueError(f"window_s must be > 0, got {window_s}")
        self.window_s = float(window_s)
        self._events: Deque[Tuple[float, float]] = deque()

    def observe(self, t: float, x: float) -> None:
        self._events.append((t, x))

    def rate(self, now: float) -> float:
        lo = now - self.window_s
        ev = self._events
        while ev and ev[0][0] <= lo:
            ev.popleft()
        return sum(x for t, x in ev if t <= now) / self.window_s


class EwmaMean:
    """Exponentially-weighted mean with a sample counter.

    The first sample initialises the mean directly, so short runs are
    not biased toward zero; ``n`` lets callers gate on "enough actual
    observations to trust over the prior".
    """

    __slots__ = ("alpha", "value", "n")

    def __init__(self, alpha: float = 0.25):
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"alpha must be in (0, 1], got {alpha}")
        self.alpha = alpha
        self.value = 0.0
        self.n = 0

    def update(self, x: float) -> float:
        self.value = x if self.n == 0 else (
            (1.0 - self.alpha) * self.value + self.alpha * x)
        self.n += 1
        return self.value

    def get(self, default: float = 0.0) -> float:
        return self.value if self.n else default


class _ClassEstimator:
    """Per-SLO-class load estimators.

    Predictions (``*_pred``) are updated on *arrival* from prompt/output
    lengths and the engine's hardware constants; actuals (``*_act``)
    from retired :class:`~repro.serving.engine.RequestRecord`\\ s.  The
    ``*_hat`` accessors prefer actuals once ``min_n`` samples exist.
    """

    __slots__ = ("arrivals", "arr_count", "tokens", "blocks",
                 "service_pred", "service_act", "kv_pred", "kv_act",
                 "tpot_act")

    def __init__(self, window_s: float, alpha: float):
        self.arrivals = WindowedRate(window_s)
        self.arr_count = 0
        self.tokens = WindowedSum(window_s)
        self.blocks = EwmaMean(alpha)
        self.service_pred = EwmaMean(alpha)
        self.service_act = EwmaMean(alpha)
        self.kv_pred = EwmaMean(alpha)
        self.kv_act = EwmaMean(alpha)
        self.tpot_act = EwmaMean(alpha)

    def service_hat(self, min_n: int) -> float:
        if self.service_act.n >= min_n:
            return self.service_act.value
        return self.service_pred.get()

    def kv_seconds_hat(self, min_n: int) -> float:
        if self.kv_act.n >= min_n:
            return self.kv_act.value
        return self.kv_pred.get()


# ------------------------------------------------------------ configuration
@dataclass(frozen=True)
class ControllerConfig:
    """Knobs for :class:`StabilityController`.

    ``tick_interval_s``/``window_s`` default to multiples of the
    engine's weight-pass time at :meth:`~StabilityController.attach`
    so the loop tracks the hardware's natural timescale.
    """

    #: control-loop period on the transfer clock (None: 8x weight pass)
    tick_interval_s: Optional[float] = None
    #: arrival/token rate estimation window (None: 32 ticks)
    window_s: Optional[float] = None
    #: fraction of effective capacity kept free while engaged
    headroom: float = 0.15
    #: hysteresis: engage above, disengage below
    enter_rho: float = 1.0
    exit_rho: float = 0.7
    #: EWMA smoothing for service/KV/revocation estimates
    ewma_alpha: float = 0.25
    #: actual-sample count before actuals override arrival predictions
    min_actual_samples: int = 3
    #: engaged: shed deadline-free requests queued > factor * E[service]
    shed_wait_factor: float = 8.0
    #: deadline-reachability slack multiplier (like SLODeadlineAdmission)
    slack: float = 1.0
    #: peer-capacity discount gain vs revocation rate
    rev_gain: float = 1.0
    #: prefetch-budget throttle gain vs revocation rate (and its floor)
    prefetch_gain: float = 1.0
    min_prefetch_scale: float = 0.25
    #: churn-penalty scale gain vs revocation rate
    churn_gain: float = 4.0

    def __post_init__(self):
        if not 0.0 <= self.headroom < 0.9:
            raise ValueError(f"headroom must be in [0, 0.9), "
                             f"got {self.headroom}")
        if not 0.0 < self.exit_rho < self.enter_rho:
            raise ValueError(
                f"need 0 < exit_rho < enter_rho, got "
                f"exit={self.exit_rho} enter={self.enter_rho}")
        if self.tick_interval_s is not None and self.tick_interval_s <= 0:
            raise ValueError("tick_interval_s must be > 0")
        if self.window_s is not None and self.window_s <= 0:
            raise ValueError("window_s must be > 0")
        if not 0.0 < self.min_prefetch_scale <= 1.0:
            raise ValueError("min_prefetch_scale must be in (0, 1]")
        if self.min_actual_samples < 1:
            raise ValueError("min_actual_samples must be >= 1")


# -------------------------------------------------------------- controller
class StabilityController:
    """Closed-loop stability controller for one serving engine.

    Lifecycle: construct (optionally with a :class:`ControllerConfig`),
    pass as ``controller=`` to the engine, which calls :meth:`attach`;
    the engine then feeds :meth:`on_arrival`/:meth:`on_retire` and
    drives :meth:`poll` from its step loop.
    """

    #: counter names pre-seeded in the ``ctrl`` metrics namespace
    STAT_KEYS = ("ticks", "engages", "disengages", "engaged_ticks",
                 "shed", "deferred")

    def __init__(self, cfg: Optional[ControllerConfig] = None):
        self.cfg = cfg or ControllerConfig()
        self.engine = None
        self.engaged = False
        # region state (refreshed every tick)
        self.rho = 0.0
        self.rho_mem = 0.0
        self.rho_rows = 0.0
        self.rho_queue = 0.0
        self.eff_blocks = 0.0
        self.lam_total = 0.0
        self.rev_rate = 0.0
        # actuator state
        self.batch_cap = 0
        self.prefetch_scale = 1.0
        self.churn_scale = 1.0
        self.stats: Dict[str, float] = {k: 0 for k in self.STAT_KEYS}
        self._est: Dict[str, _ClassEstimator] = {}
        self._last_tick_t: Optional[float] = None
        self._arr_prev: Dict[str, int] = {}
        self._last_load_t: Optional[float] = None
        self._last_rev: float = 0.0
        self._last_rev_t: Optional[float] = None
        self._rev_ewma = EwmaMean(self.cfg.ewma_alpha)

    # ------------------------------------------------------------- wiring
    def attach(self, engine) -> None:
        """Bind to a :class:`~repro.serving.engine.HarvestServingEngine`."""
        if self.engine is not None and self.engine is not engine:
            raise ValueError("controller is already attached to an engine")
        self.engine = engine
        self.tick_interval_s = (self.cfg.tick_interval_s
                                or 8.0 * engine._t_weights)
        self.window_s = self.cfg.window_s or 32.0 * self.tick_interval_s
        self._t_step_hat = max(engine._t_weights, engine._t_flop_tok)
        self.batch_cap = engine.B
        self.stats = engine.runtime.metrics.counters(
            "ctrl", keys=self.STAT_KEYS)
        for c in SLO_CLASSES:
            self._est[c] = _ClassEstimator(self.window_s,
                                           self.cfg.ewma_alpha)

    def _class(self, slo: str) -> _ClassEstimator:
        return self._est.get(slo) or self._est["throughput"]

    # ------------------------------------------------------ observations
    def on_arrival(self, r) -> None:
        """A request became visible to the engine at ``r.arrival_t``."""
        est = self._class(r.slo)
        est.arrivals.observe(r.arrival_t)
        est.arr_count += 1
        blocks = float(self._blocks_for(r))
        svc = self._predict_service(r)
        est.blocks.update(blocks)
        est.service_pred.update(svc)
        est.kv_pred.update(blocks * svc)

    def on_retire(self, record, blocks: int) -> None:
        """A request retired into :class:`EngineStats` (done or shed)."""
        if record.state != "done" or record.finish_t is None:
            return
        est = self._class(record.slo)
        start = record.admit_t if record.admit_t is not None \
            else record.arrival_t
        svc = max(record.finish_t - start, 0.0)
        est.service_act.update(svc)
        est.kv_act.update(float(blocks) * svc)
        est.tokens.observe(record.finish_t, float(record.output_tokens))
        if record.first_token_t is not None and record.output_tokens > 1:
            est.tpot_act.update(
                (record.finish_t - record.first_token_t)
                / (record.output_tokens - 1))

    def _blocks_for(self, r) -> int:
        e = self.engine
        return math.ceil(
            (len(r.prompt) + r.max_new_tokens + 1) / e.bs) + 1

    def _predict_service(self, r) -> float:
        e = self.engine
        prefill = max(len(r.prompt) * e._t_flop_tok, e._t_weights)
        return prefill + r.max_new_tokens * self._t_step_hat

    # --------------------------------------------------------- estimates
    def service_hat(self, slo: Optional[str] = None) -> float:
        """E[admit -> finish] seconds for ``slo`` (overall when None)."""
        m = self.cfg.min_actual_samples
        if slo is not None:
            v = self._class(slo).service_hat(m)
            if v > 0:
                return v
        vals = [e.service_hat(m) for e in self._est.values()]
        vals = [v for v in vals if v > 0]
        return sum(vals) / len(vals) if vals else self._t_step_hat

    def tpot_hat(self, slo: Optional[str] = None) -> float:
        """Per-decoded-token seconds estimate (observed, congestion
        included) — published as a gauge, NOT used for shedding."""
        if slo is not None:
            est = self._class(slo)
            if est.tpot_act.n >= self.cfg.min_actual_samples:
                return est.tpot_act.value
        for est in self._est.values():
            if est.tpot_act.n >= self.cfg.min_actual_samples:
                return est.tpot_act.value
        return self._t_step_hat

    def tpot_plan(self, slo: Optional[str] = None) -> float:
        """Per-token decode seconds admission *plans* with: the
        uncongested step floor.  The observed TPOT tail is exactly the
        congestion the engaged controller is correcting — pricing the
        remaining decode at it would shed requests the controlled
        system can in fact serve.  Shedding must only claim the
        certainly hopeless, so reachability uses the floor."""
        return self._t_step_hat

    def blocks_hat(self) -> float:
        """Arrival-rate-weighted mean KV blocks per request."""
        num = den = 0.0
        for est in self._est.values():
            w = max(float(est.arrivals.count(self._now())),
                    1.0 if est.blocks.n else 0.0)
            num += w * est.blocks.get()
            den += w
        return num / den if den > 0 else 1.0

    def block_budget(self, view=None) -> int:
        """Engaged working-set bound: ``eff * (1 - headroom)`` blocks,
        floored at the local pool — local slots cannot be revoked, so
        the headroom discount only guards expansion into the *harvested*
        surplus.  Without the floor a revocation storm (eff collapsing
        toward ``n_slots``) would veto admission even onto rows the
        local pool sustains, and deferred requests age into shed."""
        eff = int(self.eff_blocks * (1.0 - self.cfg.headroom))
        local = self.engine.n_slots if self.engine is not None else 1
        return max(eff, local, 1)

    def shed_wait_s(self) -> float:
        return self.cfg.shed_wait_factor * self.service_hat()

    def _now(self) -> float:
        return self.engine._now() if self.engine is not None else 0.0

    def _revocation_total(self) -> float:
        rt = self.engine.runtime
        mon = rt.metrics.counters("monitor")
        alloc = rt.allocator.stats
        return float(max(mon.get("revocations", 0),
                         alloc.get("revocations", 0)))

    # ------------------------------------------------------------- ticks
    def poll(self, now: float) -> int:
        """Fire control ticks for the elapsed clock (monitor-style)."""
        if self._last_tick_t is None:
            self._last_tick_t = now
            self._last_rev = self._revocation_total()
            self._last_rev_t = now
            return 0
        n = int((now - self._last_tick_t) / self.tick_interval_s)
        if n <= 0:
            return 0
        self._last_tick_t += n * self.tick_interval_s
        # the tick recomputes from instantaneous estimates, so firing the
        # backlog once (at `now`) is equivalent to n identical ticks
        self.stats["ticks"] += n
        self._tick(now)
        if self.engaged:
            self.stats["engaged_ticks"] += n
        return n

    def _tick(self, now: float) -> None:
        e = self.engine
        cfg = self.cfg
        # --- revocation rate (events/s, EWMA-smoothed counter deltas)
        total = self._revocation_total()
        dt = now - (self._last_rev_t if self._last_rev_t is not None
                    else now)
        if dt > 0:
            self._rev_ewma.update((total - self._last_rev) / dt)
            self._last_rev, self._last_rev_t = total, now
        self.rev_rate = self._rev_ewma.get()
        svc = self.service_hat()
        # --- effective capacity: local blocks plus peer blocks discounted
        # by the chance a block is revoked within one service time
        peer_bytes = sum(v["budget"]
                         for v in e.runtime.allocator.device_view().values())
        peer_blocks = peer_bytes / max(e.kv_mgr.block_nbytes, 1)
        discount = 1.0 / (1.0 + cfg.rev_gain * self.rev_rate * svc)
        self.eff_blocks = e.n_slots + peer_blocks * discount
        # --- stability region: KV-block-seconds demand vs supply, and
        # row-seconds demand vs batch rows (Nie et al. 2605.04595)
        m = cfg.min_actual_samples
        # aliasing guard: one stalled step (a reload convoy under a
        # burst) can span the whole estimator window, so every arrival
        # in the burst ages out before the next observation.  When the
        # gap since the last load observation exceeds the window, the
        # inter-tick arrival count over that gap is the sharper rate
        # estimate; inside the window the trailing-window rate rules, so
        # in-region runs (fine-grained steps) are untouched.
        dt_load = (now - self._last_load_t
                   if self._last_load_t is not None else 0.0)
        kv_demand = row_demand = lam_total = 0.0
        for slo, est in self._est.items():
            lam = est.arrivals.rate(now)
            if dt_load >= self.window_s:
                prev = self._arr_prev.get(slo, 0)
                lam = max(lam, (est.arr_count - prev) / dt_load)
            self._arr_prev[slo] = est.arr_count
            lam_total += lam
            kv_demand += lam * est.kv_seconds_hat(m)
            row_demand += lam * est.service_hat(m)
        self._last_load_t = now
        self.lam_total = lam_total
        self.rho_mem = kv_demand / max(self.eff_blocks, 1e-12)
        self.rho_rows = row_demand / max(float(e.B), 1e-12)
        # standing-queue pressure: a burst that already aged out of the
        # arrival window still left its offered load in the waiting
        # queue.  The queue's drain time (at full batch) measured in
        # estimator windows is a rate-free load signal: in-region runs
        # hold at most a couple of requests (<< 1), a divergent queue
        # cannot hide.
        self.rho_queue = (len(e.waiting) * svc
                          / max(float(e.B) * self.window_s, 1e-12))
        self.rho = max(self.rho_mem, self.rho_rows, self.rho_queue)
        # --- hysteresis.  A queued request whose deadline already passed
        # is direct evidence of an out-of-region excursion (the rate
        # estimators can miss one aliased burst, its victims cannot):
        # engage to shed it rather than admit it into a blown SLO.
        if not self.engaged and (self.rho > cfg.enter_rho
                                 or self._expired_waiting(now)):
            self.engaged = True
            self.stats["engages"] += 1
        elif self.engaged and self.rho < cfg.exit_rho \
                and not self._expired_waiting(now):
            self.engaged = False
            self.stats["disengages"] += 1
        self._actuate()
        self._publish()

    def _expired_waiting(self, now: float) -> bool:
        """True while the waiting queue holds a request whose deadline
        already passed.  Disengaging at that instant would hand those
        requests to the inner policy, which admits them into a blown
        TTFT; one more engaged admission pass sheds them first, and the
        controller lets go on the next tick."""
        for r in self.engine.waiting:
            if (r.ttft_deadline_t is not None and r.first_token_t is None
                    and now > r.ttft_deadline_t):
                return True
            if r.e2e_deadline_t is not None and now > r.e2e_deadline_t:
                return True
        return False

    def _actuate(self) -> None:
        e = self.engine
        cfg = self.cfg
        if not self.engaged:
            # every actuator restored to its passive value: disengaged
            # (or never-engaged) runs are bit-exact with controller=None
            self.batch_cap = e.B
            self.prefetch_scale = 1.0
            self.churn_scale = 1.0
        else:
            # regime-dependent batch cap: memory-feasible rows, bounded by
            # the weights/flops crossover (past it a bigger batch is
            # flops-bound and only adds KV pressure)
            bstar = max(int(math.ceil(e._t_weights
                                      / max(e._t_flop_tok, 1e-30))), 1)
            bhat = max(self.blocks_hat(), 1e-12)
            # the local slot pool cannot be revoked: rows it sustains are
            # always memory-feasible, only the *harvested* surplus above
            # that is discounted under revocation pressure.  Rows are
            # counted round-to-nearest, not floored: ``blocks_hat`` is a
            # noisy EWMA, and flooring turns an estimate of 1.98
            # sustainable rows into a cap of 1 — serializing the batch
            # (and blowing every queued deadline) over estimator noise,
            # when the marginal row spills at most a block.
            local_rows = max(int(e.n_slots / bhat + 0.5), 1)
            mem_rows = max(
                int(self.eff_blocks * (1.0 - cfg.headroom) / bhat + 0.5),
                local_rows)
            self.batch_cap = max(1, min(e.B, bstar, mem_rows))
            pressure = self.rev_rate * self.service_hat()
            self.prefetch_scale = max(
                cfg.min_prefetch_scale,
                1.0 / (1.0 + cfg.prefetch_gain * pressure))
            self.churn_scale = 1.0 + cfg.churn_gain * pressure
        if e.prefetcher is not None:
            e.prefetcher.throttle = self.prefetch_scale
        pol = e.runtime.allocator.policy
        if pol is not None and hasattr(pol, "churn_scale"):
            pol.churn_scale = self.churn_scale

    def _publish(self) -> None:
        s = self.stats
        s["engaged"] = int(self.engaged)
        s["rho"] = self.rho
        s["rho_mem"] = self.rho_mem
        s["rho_rows"] = self.rho_rows
        s["rho_queue"] = self.rho_queue
        s["eff_blocks"] = self.eff_blocks
        s["lam_total"] = self.lam_total
        s["rev_rate"] = self.rev_rate
        s["batch_cap"] = self.batch_cap
        s["prefetch_scale"] = self.prefetch_scale
        s["churn_scale"] = self.churn_scale

    # ------------------------------------------------------------ report
    def summary(self) -> str:
        """One-line region + actuator state for logs and reports."""
        return (f"ctrl: rho {self.rho:.2f} "
                f"(mem {self.rho_mem:.2f} rows {self.rho_rows:.2f}) "
                f"eff {self.eff_blocks:.1f} blk "
                f"lam {self.lam_total:.3g}/s "
                f"rev {self.rev_rate:.3g}/s "
                f"{'ENGAGED' if self.engaged else 'idle'} "
                f"cap {self.batch_cap} "
                f"pf x{self.prefetch_scale:.2f} "
                f"churn x{self.churn_scale:.2f} "
                f"ticks {int(self.stats.get('ticks', 0))} "
                f"shed {int(self.stats.get('shed', 0))}")
